#!/usr/bin/env python3
"""Docs cross-reference check (CI gate; no third-party deps).

Fails (exit 1) when:

  * any ``DESIGN.md §N`` citation — in ``src/``, ``benchmarks/``,
    ``tests/``, ``examples/`` Python sources or any ``*.md`` — names a
    section that does not exist as a ``## §N`` heading in DESIGN.md, or
    DESIGN.md itself is missing;
  * any relative markdown link ``[text](path)`` in a ``*.md`` file points
    at a file that does not exist (http(s)/mailto/pure-anchor links are
    ignored; ``SNIPPETS.md`` is exempt — it quotes external repos).

Run locally:  python .github/check_doc_links.py
Also enforced by tests/test_docs_links.py so tier-1 catches it pre-push.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
PY_DIRS = ("src", "benchmarks", "tests", "examples")
SKIP_MD = {"SNIPPETS.md"}  # quotes other repos; its links are not ours
EXTERNAL = ("http://", "https://", "mailto:")

# "DESIGN.md §2", "DESIGN.md §3-§4" — capture the trailing §-list.
DESIGN_REF = re.compile(r"DESIGN\.md((?:\s*[-–]?\s*§\d+)*)")
SECTION = re.compile(r"§(\d+)")
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^##\s*§(\d+)\b", re.MULTILINE)


def design_sections() -> set[str]:
    design = ROOT / "DESIGN.md"
    if not design.exists():
        print("FAIL: DESIGN.md does not exist")
        sys.exit(1)
    return set(HEADING.findall(design.read_text(encoding="utf-8")))


def iter_files():
    for d in PY_DIRS:
        yield from sorted((ROOT / d).rglob("*.py"))
    for p in sorted(ROOT.rglob("*.md")):
        if ".git" not in p.parts and ".cache" not in p.parts:
            yield p


def main() -> int:
    sections = design_sections()
    errors: list[str] = []

    for path in iter_files():
        rel = path.relative_to(ROOT)
        text = path.read_text(encoding="utf-8", errors="replace")

        for m in DESIGN_REF.finditer(text):
            for sec in SECTION.findall(m.group(1)):
                if sec not in sections:
                    line = text[: m.start()].count("\n") + 1
                    errors.append(
                        f"{rel}:{line}: cites DESIGN.md §{sec} but DESIGN.md "
                        f"has only §{{{', '.join(sorted(sections, key=int))}}}"
                    )

        if path.suffix == ".md" and path.name not in SKIP_MD:
            for m in MD_LINK.finditer(text):
                target = m.group(1)
                if target.startswith(EXTERNAL) or target.startswith("#"):
                    continue
                rel_target = target.split("#", 1)[0]
                if not rel_target:
                    continue
                if not (path.parent / rel_target).exists():
                    line = text[: m.start()].count("\n") + 1
                    errors.append(f"{rel}:{line}: broken link -> {target}")

    if errors:
        print(f"FAIL: {len(errors)} broken doc reference(s)")
        for e in errors:
            print("  " + e)
        return 1
    print(f"OK: all DESIGN.md § citations resolve (sections: "
          f"§{', §'.join(sorted(sections, key=int))}) and markdown links exist")
    return 0


if __name__ == "__main__":
    sys.exit(main())
