"""Figures 8/9: Predictive alpha under increasingly strict SLAs.

alpha in {1, 2} across a ladder of SLA budgets (fractions of the exhaustive
P99); reports latency percentiles, SLA compliance, RBO, mean fraction of
ranges processed, and the complete/safe/unsafe termination split (Fig 9).
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.anytime import Predictive, run_query_anytime
from repro.core.metrics import rbo
from repro.core.oracle import exhaustive_topk
from repro.core.range_daat import Engine


def run():
    corpus = common.bench_corpus()
    ql = common.bench_queries(corpus, n=120, seed=5)
    queries = [ql.terms[i] for i in range(ql.n_queries)]
    idx = common.bench_index(corpus, "clustered_bp")
    eng = Engine(idx, k=10)
    common.warmup_engine(eng, queries)

    base_times = []
    exhaustive = {}
    for i, q in enumerate(queries):
        res = run_query_anytime(eng, eng.plan(q), policy=None)
        base_times.append(res.elapsed_ms)
        exhaustive[i] = exhaustive_topk(idx, q, 10)[0].tolist()
    p99 = float(np.percentile(base_times, 99))

    rows = []
    for frac in (0.5, 0.25, 0.1, 0.05):
        budget = p99 * frac
        for alpha in (1.0, 2.0):
            times, vals, fracs = [], [], []
            split = {"exhausted": 0, "safe": 0, "policy": 0}
            for i, q in enumerate(queries):
                plan = eng.plan(q)
                res = run_query_anytime(
                    eng, plan, policy=Predictive(alpha), budget_ms=budget
                )
                times.append(res.elapsed_ms)
                vals.append(rbo(res.doc_ids.tolist(), exhaustive[i], phi=0.8))
                fracs.append(res.ranges_processed / idx.n_ranges)
                split[res.exit_reason] += 1
            t = np.asarray(times)
            rows.append(
                {
                    "bench": "F8_alpha",
                    "sla_frac_of_p99": frac,
                    "budget_ms": round(budget, 2),
                    "alpha": alpha,
                    **{k: round(v, 2) for k, v in common.percentiles(t).items()},
                    "miss_pct": round(100 * float((t > budget).mean()), 2),
                    "sla_met": bool(np.percentile(t, 99) <= budget),
                    "rbo": round(float(np.mean(vals)), 4),
                    "frac_ranges": round(float(np.mean(fracs)), 3),
                    "split_complete": split["exhausted"],
                    "split_safe": split["safe"],
                    "split_unsafe": split["policy"],
                }
            )
    common.save_result("F8_alpha", rows)
    return rows
