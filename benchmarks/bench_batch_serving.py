"""Batched anytime serving: queries/sec and per-query P99 vs batch size.

Compares, over the same index and query log:

  * ``seq-host``   — the paper's host-driven loop (one jitted step per range,
                     wall-clock between steps; core.anytime, policy-free);
  * ``seq-device`` — one ``device_traverse`` dispatch per query;
  * ``batch-N``    — the serving subsystem: shape-bucketed
                     ``BatchEngine.run_batch`` at N in {1, 8, 32}, micro-
                     batches cut from the log in arrival order.

Per-query latency for a micro-batch is the batch service time (every member
waits for the dispatch); throughput is end-to-end wall clock. A budgeted
variant (per-query postings cap) shows the anytime knob under batching.
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks import common
from repro.core.anytime import run_query_anytime
from repro.serving import BatchEngine, BucketSpec

BATCH_SIZES = (1, 8, 32)
BUDGET = 20_000  # postings — the anytime knob for the budgeted rows


def _row(name, batch, times_ms, wall_s, n, budget="unlimited"):
    return {
        "bench": "batch_serving",
        "engine": name,
        "batch": batch,
        "budget": budget,
        "qps": round(n / wall_s, 2),
        **{k + "_ms": round(v, 3) for k, v in common.percentiles(times_ms).items()},
    }


def _serve_batched(beng, plans, bs, budget=None):
    """Replay plans in arrival-order micro-batches of bs; time each batch."""
    times, t0 = [], time.perf_counter()
    for lo in range(0, len(plans), bs):
        chunk = plans[lo : lo + bs]
        b = None if budget is None else [budget] * len(chunk)
        t1 = time.perf_counter()
        beng.run_batch(chunk, budget_postings=b)
        ms = (time.perf_counter() - t1) * 1e3
        times.extend([ms] * len(chunk))  # every member waits for the batch
    return times, time.perf_counter() - t0


def run(small: bool | None = None):
    if small is None:
        small = os.environ.get("REPRO_BENCH_SMALL") == "1"
    if small:
        from repro.data.synth import make_corpus, make_query_log

        corpus = make_corpus(n_docs=4000, n_terms=3000, n_topics=8,
                             mean_doc_len=80, seed=0)
        ql = make_query_log(corpus, n_queries=64, seed=7)
        idx = common.build_index_cached(
            corpus, cache_dir=common.CACHE, n_ranges=8, strategy="clustered",
        )
    else:
        corpus = common.bench_corpus()
        ql = common.bench_queries(corpus, n=96, seed=7)
        idx = common.bench_index(corpus, "clustered_bp")
    eng = common.make_engine(idx, k=10)
    queries = [ql.terms[i] for i in range(ql.n_queries)]
    n = len(queries)
    plans = [eng.plan(q) for q in queries]

    rows = []

    # Sequential host-driven loop (the baseline the batch path must beat).
    common.warmup_engine(eng, queries)
    times, t0 = [], time.perf_counter()
    for q, plan in zip(queries, plans):
        res = run_query_anytime(eng, plan, policy=None)
        times.append(res.elapsed_ms)
    host_wall = time.perf_counter() - t0
    rows.append(_row("seq-host", 1, times, host_wall, n))

    # Sequential device-driven loop.
    times, t0 = [], time.perf_counter()
    for plan in plans:
        t1 = time.perf_counter()
        eng.traverse(plan).state.vals.block_until_ready()
        times.append((time.perf_counter() - t1) * 1e3)
    rows.append(_row("seq-device", 1, times, time.perf_counter() - t0, n))

    # Batched serving engine at each batch size, unlimited and budgeted.
    for bs in BATCH_SIZES:
        beng = BatchEngine(eng, BucketSpec(max_batch=bs))
        widths = {beng.spec.width_bucket(p.blk_tab.shape[1]) for p in plans}
        beng.warmup(sorted(widths))  # compile outside the timed region
        for budget, label in ((None, "unlimited"), (BUDGET, str(BUDGET))):
            times, wall = _serve_batched(beng, plans, bs, budget)
            r = _row(f"batch-{bs}", bs, times, wall, n, budget=label)
            r["programs"] = sorted(beng.compiled_shapes)
            rows.append(r)

    seq_qps = rows[0]["qps"]
    for r in rows:
        r["speedup_vs_seq_host"] = round(r["qps"] / seq_qps, 2)
    common.save_result("batch_serving", rows)
    return rows


if __name__ == "__main__":
    for row in run(small="--small" in sys.argv):
        print(row)
