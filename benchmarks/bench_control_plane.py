"""Control plane: replica-group throughput and live-reshard availability.

Two claims (DESIGN.md §9), over the same index and query log:

  * **replicas** — q/s through the ``ControlPlane`` at 1 vs 2 replicas of a
    2-shard engine. Execution path is reported per row: ``replica mesh``
    when the runtime exposes >= replicas x shards devices (run standalone
    with ``--mesh`` for a forced 4-device CPU mesh), else the wrapped
    engine's fallback — on 1 CPU core the fallback rows measure replication
    *overhead* (same math, same core), which is the honest number this
    container can produce; mesh rows measure the speedup.

  * **reshard availability** — queries served *during* a live staged
    cutover (``start_reshard`` + ``drain_once`` interleaving) vs a
    stop-the-world rebuild of the same new layout (carve + engine build +
    warmup with the queue blocked). The live path keeps serving through
    every step; the stop-the-world window serves zero.

Small sizes honour ``REPRO_BENCH_SMALL=1`` (the CI headline job).
"""

from __future__ import annotations

import os
import sys

# Standalone invocation: force a 4-device CPU mesh before jax initializes.
if __name__ == "__main__" and "--mesh" in sys.argv and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import time

import numpy as np

from benchmarks import common

BATCH = 16
N_SHARDS = 2
REPLICAS = (1, 2)


def _build(small: bool):
    from repro.core.range_daat import Engine
    from repro.data.synth import make_corpus, make_query_log

    if small:
        corpus = make_corpus(n_docs=4000, n_terms=3000, n_topics=8,
                             mean_doc_len=80, seed=0)
        ql = make_query_log(corpus, n_queries=64, seed=7)
        idx = common.build_index_cached(
            corpus, cache_dir=common.CACHE, n_ranges=8, strategy="clustered",
        )
    else:
        corpus = common.bench_corpus()
        ql = common.bench_queries(corpus, n=96, seed=7)
        idx = common.bench_index(corpus, "clustered_bp")
    eng = Engine(idx, k=10)
    return idx, eng, [ql.terms[i] for i in range(ql.n_queries)]


def _serve_all(plane, queries, batch):
    t0 = time.perf_counter()
    served = plane.replay(queries, batch_size=batch)
    wall = time.perf_counter() - t0
    return len(served), wall


def run(small: bool | None = None):
    import jax

    from repro.control import ControlPlane
    from repro.core.clustered_index import shard_device_index
    from repro.serving import BucketSpec, ShardedBatchEngine, ShardedEngine

    if small is None:
        small = os.environ.get("REPRO_BENCH_SMALL") == "1"
    idx, eng, queries = _build(small)
    n = len(queries)
    spec = BucketSpec(max_batch=BATCH)
    rows = []

    # ---------------------------------------------------- replica throughput
    for n_rep in REPLICAS:
        need = n_rep * N_SHARDS
        plane = ControlPlane(
            eng, n_shards=N_SHARDS, n_replicas=n_rep, spec=spec,
            use_mesh=None if jax.device_count() >= need else False,
        )
        if plane.stats()["replica_mesh"]:
            path = "replica mesh"
        elif plane.sengine.mesh is not None:
            path = "shard mesh"
        else:
            path = "vmap fallback"
        plane.replay(queries[: 2 * BATCH], batch_size=BATCH)  # warm programs
        served, wall = _serve_all(plane, queries, BATCH)
        rows.append({
            "bench": "control_plane",
            "mode": f"replicas-{n_rep}",
            "path": path,
            "shards": N_SHARDS,
            "replicas": n_rep,
            "batch": BATCH,
            "served": served,
            "qps": round(served / wall, 2),
        })

    # ------------------------------------------------- reshard availability
    plane = ControlPlane(
        eng, n_shards=N_SHARDS, spec=spec,
        use_mesh=None if jax.device_count() >= N_SHARDS else False,
    )
    plane.replay(queries[: 2 * BATCH], batch_size=BATCH)  # warm
    R = idx.n_ranges
    live_cuts = plane.cuts
    # A genuinely different layout: move the middle boundary by one range.
    mid = int(live_cuts[1])
    new_cuts = np.asarray([0, mid + 1 if mid + 1 < R else mid - 1, R])

    # Live: interleave one micro-batch per cutover step, then keep serving.
    qi = 0
    task = plane.start_reshard(new_cuts)
    t0 = time.perf_counter()
    served_live = 0
    while plane.reshard_task is not None:
        for _ in range(BATCH):
            plane.submit(queries[qi % n])
            qi += 1
        served_live += len(plane.drain_once())
    live_window = time.perf_counter() - t0
    rows.append({
        "bench": "control_plane",
        "mode": "reshard-live",
        "path": "staged cutover",
        "served_during": served_live,
        "window_s": round(live_window, 4),
        "qps_during": round(served_live / max(live_window, 1e-9), 2),
        "steps": task.steps_done,
    })

    # Stop-the-world: rebuild + warm the same layout with the queue blocked.
    t0 = time.perf_counter()
    shards = shard_device_index(idx, cuts=new_cuts)
    se = ShardedEngine(
        eng, N_SHARDS, use_mesh=False, shards=shards
    )
    sbeng = ShardedBatchEngine(se, spec)
    widths = sorted({spec.width_bucket(eng.plan(q).blk_tab.shape[1])
                     for q in queries[:BATCH]})
    sbeng.warmup(widths)
    stw_window = time.perf_counter() - t0
    rows.append({
        "bench": "control_plane",
        "mode": "reshard-stop-the-world",
        "path": "full rebuild",
        "served_during": 0,
        "window_s": round(stw_window, 4),
        "qps_during": 0.0,
    })

    common.save_result("control_plane", rows)
    return rows


if __name__ == "__main__":
    for row in run(small="--small" in sys.argv):
        print(row)
