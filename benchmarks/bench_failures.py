"""Table 8: failure analysis — queries sampled across the RBO spectrum.

For each sampled query under an SLA-limited Predictive run: answer-bearing
ranges processed / required (Ans.), ranges processed (Proc.), deepest
answer-bearing range in the BoundSum ordering (Dpst.), and the mean depth
(Avg.) — reproducing the paper's diagnosis that failures are queries whose
answers scatter across many deep ranges.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.anytime import Predictive, run_query_anytime
from repro.core.metrics import rbo
from repro.core.oracle import exhaustive_topk
from repro.core.range_daat import Engine


def run():
    corpus = common.bench_corpus()
    ql = common.bench_queries(corpus, n=150, seed=8)
    idx = common.bench_index(corpus, "clustered_bp")
    eng = Engine(idx, k=10)
    queries = [ql.terms[i] for i in range(ql.n_queries)]
    common.warmup_engine(eng, queries)

    base = []
    for q in queries[:50]:
        base.append(run_query_anytime(eng, eng.plan(q), policy=None).elapsed_ms)
    budget = float(np.percentile(base, 99)) * 0.25

    recs = []
    for i, q in enumerate(queries):
        plan = eng.plan(q)
        res = run_query_anytime(eng, plan, policy=Predictive(1.0), budget_ms=budget)
        oid, _ = exhaustive_topk(idx, q, 10)
        if oid.size == 0:
            continue
        r_of = np.searchsorted(idx.range_ends, oid, side="right")
        ans_ranges = sorted(set(int(r) for r in r_of))
        # Depth of each answer-bearing range in the BoundSum ordering.
        pos = {int(r): int(np.nonzero(plan.order_host == r)[0][0]) for r in ans_ranges}
        processed_set = set(int(plan.order_host[j]) for j in range(res.ranges_processed))
        recs.append(
            {
                "bench": "T8_failures",
                "rbo": round(rbo(res.doc_ids.tolist(), oid.tolist(), phi=0.8), 3),
                "ans_processed": sum(1 for r in ans_ranges if r in processed_set),
                "ans_total": len(ans_ranges),
                "proc": int(res.ranges_processed),
                "deepest": max(pos.values()) + 1,
                "avg_depth": round(float(np.mean([p + 1 for p in pos.values()])), 1),
                "qlen": int((q >= 0).sum()),
            }
        )

    # Sample ~3 queries per RBO band, mirroring the table.
    bands = [(0.999, 1.01), (0.6, 0.9), (0.3, 0.6), (0.05, 0.3), (-0.01, 0.05)]
    rows = []
    for lo, hi in bands:
        members = [r for r in recs if lo <= r["rbo"] < hi]
        members.sort(key=lambda r: -r["rbo"])
        rows.extend(members[:3])
    # Aggregate correlation: scattered answers <-> low RBO.
    lows = [r for r in recs if r["rbo"] < 0.7]
    highs = [r for r in recs if r["rbo"] > 0.95]
    if lows and highs:
        rows.append(
            {
                "bench": "T8_failures",
                "summary": True,
                "mean_avg_depth_low_rbo": round(
                    float(np.mean([r["avg_depth"] for r in lows])), 2
                ),
                "mean_avg_depth_high_rbo": round(
                    float(np.mean([r["avg_depth"] for r in highs])), 2
                ),
                "n_low": len(lows),
                "n_high": len(highs),
            }
        )
    common.save_result("T8_failures", rows)
    return rows
