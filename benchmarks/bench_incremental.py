"""S5: incremental artifacts — append+publish vs full rebuild, chain reopen.

Three claims (DESIGN.md §10), over one corpus and a stream of deltas:

  * **append+publish vs rebuild+publish** — wall-clock to extend a
    published artifact by a ~5% corpus delta (``index_io.append_index``:
    materialize parent, plan + apply the delta, publish a delta segment)
    vs rebuilding the concatenated corpus from scratch and re-publishing
    every array. The delta path skips re-clustering, re-inverting, and
    re-writing the base — the cheap-update property the document-ordered
    layout buys (paper §1).

  * **reopen-from-chain vs compacted** — ``load_index`` wall-clock at
    chain lengths 1/2/4/8 (each link re-applies its delta) vs reopening
    the compacted base: the price of deferring compaction.

  * **parity** — the chain head's materialized fingerprint equals the
    compacted artifact's (bitwise invariant, measured rather than assumed).

Small sizes honour ``REPRO_BENCH_SMALL=1`` (the CI headline job).
"""

from __future__ import annotations

import os
import shutil
import sys
import tempfile

from benchmarks import common

CHAIN_LENGTHS = (1, 2, 4, 8)


def _corpora(small: bool):
    from repro.data.synth import make_corpus

    if small:
        n_docs, n_terms, n_topics, doc_len = 4000, 3000, 8, 80
    else:
        n_docs, n_terms, n_topics, doc_len = 16000, 8000, 16, 120
    delta_docs = n_docs // 20  # a ~5% append per link
    base = make_corpus(n_docs=n_docs, n_terms=n_terms, n_topics=n_topics,
                       mean_doc_len=doc_len, seed=0)
    deltas = [
        make_corpus(n_docs=delta_docs, n_terms=n_terms, n_topics=n_topics,
                    mean_doc_len=doc_len, seed=100 + i)
        for i in range(max(CHAIN_LENGTHS))
    ]
    return base, deltas


def run(small: bool | None = None):
    from repro import index_io
    from repro.core.clustered_index import build_index
    from repro.data.synth import concat_corpora

    if small is None:
        small = os.environ.get("REPRO_BENCH_SMALL") == "1"
    base_corpus, deltas = _corpora(small)
    n_ranges = 8 if small else 16
    rows = []
    tmp = tempfile.mkdtemp(prefix="bench_incremental_")
    try:
        base_path = os.path.join(tmp, "base")
        with common.Timer() as t_base:
            index = build_index(base_corpus, n_ranges=n_ranges, strategy="clustered")
            index_io.save_index(index, base_path, impact_dtype="int8")
        rows.append({
            "bench": "incremental",
            "op": "base build+publish",
            "docs": base_corpus.n_docs,
            "ms": round(t_base.ms, 1),
        })

        # -------------------------------- append+publish vs rebuild+publish
        head = os.path.join(tmp, "chain_1")
        with common.Timer() as t_append:
            ext = index_io.append_index(base_path, deltas[0], head)
        rows.append({
            "bench": "incremental",
            "op": "append+publish",
            "docs": deltas[0].n_docs,
            "chain_length": 1,
            "ms": round(t_append.ms, 1),
        })

        cat = concat_corpora(base_corpus, deltas[0])
        rebuilt_path = os.path.join(tmp, "rebuilt")
        with common.Timer() as t_rebuild:
            rebuilt = build_index(cat, n_ranges=n_ranges + 1, strategy="clustered")
            index_io.save_index(rebuilt, rebuilt_path, impact_dtype="int8")
        rows.append({
            "bench": "incremental",
            "op": "rebuild+publish",
            "docs": cat.n_docs,
            "ms": round(t_rebuild.ms, 1),
            "speedup_vs_rebuild": round(t_rebuild.ms / max(t_append.ms, 1e-9), 2),
        })

        # ------------------------------------------- chain length sweep
        parent = head
        for i in range(1, max(CHAIN_LENGTHS)):
            nxt = os.path.join(tmp, f"chain_{i + 1}")
            ext = index_io.append_index(parent, deltas[i], nxt)
            parent = nxt
        for length in CHAIN_LENGTHS:
            head_l = os.path.join(tmp, f"chain_{length}")
            with common.Timer() as t_open:
                loaded = index_io.load_index(head_l)
            rows.append({
                "bench": "incremental",
                "op": "reopen-chain",
                "chain_length": length,
                "docs": loaded.n_docs,
                "ms": round(t_open.ms, 1),
            })

        compacted = os.path.join(tmp, "compacted")
        with common.Timer() as t_compact:
            index_io.compact(parent, compacted)
        with common.Timer() as t_open_c:
            comp = index_io.load_index(compacted)
        rows.append({
            "bench": "incremental",
            "op": "reopen-compacted",
            "chain_length": max(CHAIN_LENGTHS),
            "compact_ms": round(t_compact.ms, 1),
            "ms": round(t_open_c.ms, 1),
            # The §10 invariant, measured: chain head == compacted, bitwise.
            "parity_bitwise": bool(comp.fingerprint() == ext.fingerprint()),
        })
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    common.save_result("incremental", rows)
    return rows


if __name__ == "__main__":
    for row in run(small="--small" in sys.argv):
        print(row)
