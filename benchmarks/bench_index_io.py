"""S3: index lifecycle — artifact size on disk, load wall-time, HBM bytes.

Builds the cluster-skipping index once (cached), saves it as a versioned
artifact at int32 and int8 impact storage (DESIGN.md §8), and reports per
dtype: bytes on disk, save/load wall-time (eager and memory-mapped), the
device HBM footprint from ``space_report()["device_bytes"]``, and a
bitwise parity check of the loaded artifact's ``device_traverse`` top-k
against the in-memory build — the acceptance contract of the lifecycle
subsystem, measured rather than assumed.
"""

from __future__ import annotations

import os
import shutil
import tempfile

import numpy as np

from benchmarks import common
from repro import index_io
from repro.core.range_daat import Engine

N_PARITY_QUERIES = 20


def _dir_bytes(path: str) -> int:
    total = 0
    for root, _, files in os.walk(path):
        total += sum(os.path.getsize(os.path.join(root, f)) for f in files)
    return total


def _topk(engine: Engine, q: np.ndarray):
    res = engine.traverse(engine.plan(q))
    return np.asarray(res.state.ids).tolist(), np.asarray(res.state.vals).tolist()


def run(small: bool | None = None):
    if small is None:
        small = os.environ.get("REPRO_BENCH_SMALL") == "1"
    if small:
        from repro.data.synth import make_corpus, make_query_log

        corpus = make_corpus(n_docs=4000, n_terms=3000, n_topics=8,
                             mean_doc_len=80, seed=0)
        queries = make_query_log(corpus, n_queries=N_PARITY_QUERIES, seed=1)
        index = common.build_index_cached(
            corpus, cache_dir=common.CACHE, n_ranges=8, strategy="clustered",
        )
    else:
        corpus = common.bench_corpus()
        queries = common.bench_queries(corpus, n=N_PARITY_QUERIES)
        index = common.bench_index(corpus, "clustered_bp")
    ref = Engine(index, k=10)
    common.warmup_engine(ref, [queries.terms[i] for i in range(3)])

    rows = []
    tmp = tempfile.mkdtemp(prefix="bench_index_io_")
    try:
        for impact_dtype in ("int32", "int8"):
            path = os.path.join(tmp, f"artifact_{impact_dtype}")
            with common.Timer() as t_save:
                index_io.save_index(index, path, impact_dtype=impact_dtype)
            with common.Timer() as t_load:
                loaded = index_io.load_index(path)
            with common.Timer() as t_mmap:
                index_io.load_index(path, mmap=True)

            eng = Engine(loaded, k=10, impact_dtype=impact_dtype)
            common.warmup_engine(eng, [queries.terms[i] for i in range(3)])
            parity = all(
                _topk(eng, queries.terms[i]) == _topk(ref, queries.terms[i])
                for i in range(queries.n_queries)
            )
            dev = index.space_report(impact_dtype)["device_bytes"]
            rows.append(
                {
                    "bench": "S3_index_io",
                    "impact_dtype": impact_dtype,
                    "disk_mb": round(_dir_bytes(path) / 1e6, 3),
                    "save_ms": round(t_save.ms, 2),
                    "load_ms_eager": round(t_load.ms, 2),
                    "load_ms_mmap": round(t_mmap.ms, 2),
                    "hbm_impacts_bytes": dev["impacts"],
                    "hbm_postings_bytes": dev["postings"],
                    "hbm_total_bytes": dev["total"],
                    "fingerprint_stable": loaded.fingerprint() == index.fingerprint(),
                    "parity_bitwise": parity,
                }
            )
        i32 = rows[0]
        for r in rows:
            r["hbm_impacts_ratio_vs_int32"] = round(
                i32["hbm_impacts_bytes"] / r["hbm_impacts_bytes"], 2
            )
            r["hbm_postings_ratio_vs_int32"] = round(
                i32["hbm_postings_bytes"] / r["hbm_postings_bytes"], 2
            )
            r["hbm_total_ratio_vs_int32"] = round(
                i32["hbm_total_bytes"] / r["hbm_total_bytes"], 2
            )
            r["disk_ratio_vs_int32"] = round(i32["disk_mb"] / r["disk_mb"], 2)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    common.save_result("S3_index_io", rows)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
