"""Table 2: index space consumption across index types and orderings.

Variants: Default (single range) vs Clustered (32 topical ranges), each
under Random and Reordered (BP within clusters / global BP) docid
assignments, plus the impact-ordered JASS index. Logical bytes at
paper-matched widths (DESIGN.md §7 note 4).
"""

from __future__ import annotations

from benchmarks import common
from repro.core.saat import build_impact_index


def run():
    corpus = common.bench_corpus()
    rows = []
    variants = [
        ("Default", "Random", "random", 1),
        ("Default", "Reordered", "bp", 1),
        ("Clustered", "Random", "clustered_random", common.N_RANGES),
        ("Clustered", "Reordered", "clustered_bp", common.N_RANGES),
    ]
    base = {}
    for index_type, ordering, strategy, n_ranges in variants:
        idx = common.bench_index(corpus, strategy, n_ranges=n_ranges)
        rep = idx.space_report()
        ii = build_impact_index(idx)
        jass = ii.space_gib(idx.quantizer.bits)
        if ordering == "Random":
            base[index_type] = rep["total_gib"]
        rows.append(
            {
                "bench": "T2_index_space",
                "index_type": index_type,
                "ordering": ordering,
                **{
                    k: round(v * 1024, 3)
                    for k, v in rep.items()
                    if k.endswith("_gib")  # MiB; device_bytes has its own bench (S3)
                },
                "jass_postings_mib": round(jass * 1024, 3),
                "overhead_vs_default": round(
                    rep["total_gib"]
                    / common.bench_index(corpus, "random", 1).space_report()["total_gib"],
                    3,
                ),
            }
        )
    common.save_result("T2_index_space", rows)
    return rows
