"""In-flight (slot-swapping) serving vs micro-batching at saturating load.

Both servers get the identical query log submitted in one burst (offered
load far above capacity) and drain it completely; per-query latency is
queue wait + service (identical attribution on both paths), throughput is
end-to-end wall clock.

The effect under test: a micro-batch's vmapped dispatch runs until its
*slowest* lane finishes (``lax.cond`` lowers to ``select``), so a batch
pays ``batch x max(ranges)`` lane-iterations while the straggler holds
finished batchmates' slots idle. The in-flight loop refills a lane the
quantum after it exits, so lane-iterations track ``sum(ranges)`` instead —
decisively better q/s and p99 when per-query work is skewed, which safe
termination makes the common case on clustered indexes.
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks import common
from repro.serving import (
    BatchEngine,
    BucketSpec,
    InflightServer,
    MicroBatchServer,
    SlaBudgeter,
)

SLOTS = 8  # lanes: micro-batch max_batch == in-flight n_slots
QUANTUM = 2

# Filled by run(): metrics-registry snapshot + instrumented-vs-noop q/s
# from the observability overhead row. run.py folds it into BENCH_<id>.json.
OBS_SNAPSHOT = None
BUDGET = 20_000  # postings — the anytime knob for the budgeted rows
LIGHT_PER_HEAVY = 3  # skewed mix: navigational 1-term : exploratory log


class FixedBudgeter(SlaBudgeter):
    """Constant postings cap: makes the two servers' budgets identical."""

    def __init__(self, cap):
        super().__init__(sla_ms=float("inf"))
        self.cap = cap

    def budgets(self, n, plans=None):
        return np.full(n, self.cap, dtype=np.int32)


def _row(server, lanes, times_ms, wall_s, n, skew, budget="unlimited", **extra):
    return {
        "bench": "inflight",
        "server": server,
        "lanes": lanes,
        "budget": budget,
        "qps": round(n / wall_s, 2),
        **{k + "_ms": round(v, 3) for k, v in common.percentiles(times_ms).items()},
        "ranges_skew_p99_over_p50": skew,
        **extra,
    }


def _drain_micro(eng, queries, budgeter):
    beng = BatchEngine(eng, BucketSpec(max_batch=SLOTS))
    plans = [eng.plan(q) for q in queries]
    beng.warmup(sorted({beng.spec.width_bucket(p.blk_tab.shape[1]) for p in plans}))
    srv = MicroBatchServer(beng, budgeter, max_batch=SLOTS)
    t0 = time.perf_counter()
    for q in queries:
        srv.submit(q)
    served = []
    while srv.pending:
        served.extend(srv.drain_once())
    wall = time.perf_counter() - t0
    return [s.latency_ms for s in served], wall, served


def _drain_inflight(eng, queries, budgeter, obs=None, on_step=None):
    beng = BatchEngine(eng, BucketSpec(max_batch=SLOTS))
    # Warm the (n_slots, width) programs outside the timed region.
    warm = InflightServer(
        beng, SlaBudgeter(sla_ms=float("inf")), n_slots=SLOTS, quantum=QUANTUM
    )
    warm.replay(queries[: 2 * SLOTS])
    kw = {"obs": obs} if obs is not None else {}
    srv = InflightServer(beng, budgeter, n_slots=SLOTS, quantum=QUANTUM, **kw)
    t0 = time.perf_counter()
    for q in queries:
        srv.submit(q)
    if on_step is None:
        served = srv.run_until_idle()
    else:
        # Same loop as run_until_idle, with the operations poll (SLO
        # sampling + detectors) inside the timed region — the overhead row
        # charges the full §14 stack, not just passive metric writes.
        served = []
        while srv.pending or srv.active:
            served.extend(srv.step())
            on_step()
    wall = time.perf_counter() - t0
    return [s.latency_ms for s in served], wall, served


def _skewed_mix(ql, n_terms: int, seed: int = 1):
    """Interleave exploratory log queries with 3x as many navigational
    1-term queries. The light queries safe-terminate after a few ranges;
    the heavy ones traverse most of the order — the per-query work skew
    that makes a micro-batch convoy around its slowest lane."""
    rng = np.random.default_rng(seed)
    heavy = [ql.terms[i] for i in range(ql.n_queries)]
    light = [
        np.asarray([t], np.int32)
        for t in rng.integers(0, n_terms, size=LIGHT_PER_HEAVY * len(heavy))
    ]
    mix = heavy + light
    rng.shuffle(mix)
    return mix


def run(small: bool | None = None):
    if small is None:
        small = os.environ.get("REPRO_BENCH_SMALL") == "1"
    if small:
        from repro.data.synth import make_corpus, make_query_log

        corpus = make_corpus(n_docs=8000, n_terms=3000, n_topics=8,
                             mean_doc_len=120, seed=0)
        ql = make_query_log(corpus, n_queries=24, seed=7)
        idx = common.build_index_cached(
            corpus, cache_dir=common.CACHE, n_ranges=16, strategy="clustered",
        )
        n_terms = 3000
    else:
        corpus = common.bench_corpus()
        ql = common.bench_queries(corpus, n=32, seed=7)
        idx = common.bench_index(corpus, "clustered_bp")
        n_terms = common.N_TERMS
    eng = common.make_engine(idx, k=10)
    queries = _skewed_mix(ql, n_terms)
    n = len(queries)

    # Workload skew (what slot-swapping exploits): ranges processed to safe
    # termination per query, p99/p50.
    ranges = [
        int(eng.traverse(eng.plan(q)).ranges_processed) for q in queries
    ]
    pr = common.percentiles(ranges)
    skew = round(pr["p99"] / max(pr["p50"], 1e-9), 2)

    rows = []
    for budget, label, mk in (
        (None, "unlimited", lambda: SlaBudgeter(sla_ms=float("inf"))),
        (BUDGET, str(BUDGET), lambda: FixedBudgeter(BUDGET)),
    ):
        times, wall, served = _drain_micro(eng, queries, mk())
        rows.append(_row(f"microbatch-{SLOTS}", SLOTS, times, wall, n, skew,
                         budget=label))
        times, wall, served = _drain_inflight(eng, queries, mk())
        mean_q = round(float(np.mean([s.quanta for s in served])), 2)
        rows.append(_row(f"inflight-{SLOTS}x{QUANTUM}", SLOTS, times, wall, n,
                         skew, budget=label, mean_quanta=mean_q))

    for r in rows:
        if r["server"].startswith("inflight"):
            base = next(
                x for x in rows
                if x["server"].startswith("microbatch") and x["budget"] == r["budget"]
            )
            r["qps_vs_microbatch"] = round(r["qps"] / max(base["qps"], 1e-9), 2)
            r["p99_vs_microbatch"] = round(
                r["p99_ms"] / max(base["p99_ms"], 1e-9), 3
            )

    # Observability overhead (ISSUE 8/9 acceptance: < 5% q/s regression):
    # drain the unlimited in-flight workload with a no-op handle and with
    # the *full* §14 stack — metrics, tracing at sample rate 1.0, the
    # dispatch profiler, plus an SLO tracker and drift detectors polled on
    # every step inside the timed loop — in alternating pairs, reporting
    # the ratio of per-side median walls so a single container hiccup
    # cannot swing the figure. Both q/s numbers land in OBS_SNAPSHOT,
    # which run.py attaches to BENCH_<id>.json.
    from repro.obs import Instrumentation
    from repro.obs.detect import DriftMonitor, default_serving_detectors
    from repro.obs.slo import SloTracker, default_serving_slos

    reps = 17  # container timing jitter is ~10%; many reps + median tame it
    obs = Instrumentation.make(sample_rate=1.0, profile=True)
    tracker = SloTracker(obs, default_serving_slos(sla_ms=100.0))
    monitor = default_serving_detectors(
        DriftMonitor(obs), server="inflight"
    )
    steps = [0]

    def ops_poll():
        # Detectors and SLO snapshots every step; the full windowed burn
        # evaluation (a few dozen gauge writes) every 8th. The shortest
        # burn window is 5 minutes — even at 1/8 cadence this evaluates
        # orders of magnitude more often than any operational poller.
        steps[0] += 1
        tracker.sample()
        if steps[0] % 8 == 0:
            tracker.evaluate()
        monitor.poll()

    def _noop_drain():
        return _drain_inflight(
            eng, queries, SlaBudgeter(sla_ms=float("inf"))
        )[1]

    def _obs_drain():
        return _drain_inflight(
            eng, queries, SlaBudgeter(sla_ms=float("inf"), obs=obs), obs=obs,
            on_step=ops_poll,
        )

    # One untimed pair first: the earlier rows warmed the uninstrumented
    # path only, and the first instrumented drain pays once-only costs
    # (tracker/detector setup, first histogram allocations) that belong to
    # startup, not steady-state overhead.
    _noop_drain()
    _obs_drain()

    # Freeze the collector for the measured pairs: the instrumented side
    # allocates more, so with gc live it also triggers more generational
    # sweeps — each proportional to the whole bench-harness heap, which is
    # several rows of retired results by now. That charges harness heap
    # size to the instrumentation, inflating the figure by ~2pp here.
    import gc

    gc.collect()
    gc.freeze()

    walls_noop, walls_obs = [], []
    wall_obs, times = float("inf"), []
    for rep in range(reps):
        # Alternate which side runs first each rep so slow-container drift
        # within a pair biases half the reps each way. Container noise is
        # spiky (occasional +10% hiccups on one drain), so the estimator
        # is a ratio of per-side *medians* — a hiccup inflates one sample,
        # which the median ignores, where a min- or mean-based estimate
        # would either chase the noise floor or average the spike in.
        if rep % 2 == 0:
            wn = _noop_drain()
            t, w, _served = _obs_drain()
        else:
            t, w, _served = _obs_drain()
            wn = _noop_drain()
        walls_noop.append(wn)
        walls_obs.append(w)
        if w < wall_obs:
            wall_obs, times = w, t
    gc.unfreeze()
    med_noop = float(np.median(walls_noop))
    med_obs = float(np.median(walls_obs))
    qps_noop = round(n / med_noop, 2)
    qps_obs = round(n / med_obs, 2)
    overhead_pct = round((med_obs / max(med_noop, 1e-9) - 1.0) * 100.0, 2)
    rows.append(_row(
        f"inflight-{SLOTS}x{QUANTUM}-instrumented", SLOTS, times, wall_obs, n,
        skew, qps_noop=qps_noop, obs_overhead_pct=overhead_pct,
    ))
    global OBS_SNAPSHOT
    OBS_SNAPSHOT = {
        "overhead": {
            "qps_noop": qps_noop,
            "qps_instrumented": qps_obs,
            "overhead_pct": overhead_pct,
        },
        "registry": obs.snapshot(),
        "profiler": obs.profiler.snapshot(),
    }
    obs.close()

    common.save_result("inflight", rows)
    return rows


if __name__ == "__main__":
    for row in run(small="--small" in sys.argv):
        print(row)
