"""In-flight (slot-swapping) serving vs micro-batching at saturating load.

Both servers get the identical query log submitted in one burst (offered
load far above capacity) and drain it completely; per-query latency is
queue wait + service (identical attribution on both paths), throughput is
end-to-end wall clock.

The effect under test: a micro-batch's vmapped dispatch runs until its
*slowest* lane finishes (``lax.cond`` lowers to ``select``), so a batch
pays ``batch x max(ranges)`` lane-iterations while the straggler holds
finished batchmates' slots idle. The in-flight loop refills a lane the
quantum after it exits, so lane-iterations track ``sum(ranges)`` instead —
decisively better q/s and p99 when per-query work is skewed, which safe
termination makes the common case on clustered indexes.
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks import common
from repro.serving import (
    BatchEngine,
    BucketSpec,
    InflightServer,
    MicroBatchServer,
    SlaBudgeter,
)

SLOTS = 8  # lanes: micro-batch max_batch == in-flight n_slots
QUANTUM = 2

# Filled by run(): metrics-registry snapshot + instrumented-vs-noop q/s
# from the observability overhead row. run.py folds it into BENCH_<id>.json.
OBS_SNAPSHOT = None
BUDGET = 20_000  # postings — the anytime knob for the budgeted rows
LIGHT_PER_HEAVY = 3  # skewed mix: navigational 1-term : exploratory log


class FixedBudgeter(SlaBudgeter):
    """Constant postings cap: makes the two servers' budgets identical."""

    def __init__(self, cap):
        super().__init__(sla_ms=float("inf"))
        self.cap = cap

    def budgets(self, n, plans=None):
        return np.full(n, self.cap, dtype=np.int32)


def _row(server, lanes, times_ms, wall_s, n, skew, budget="unlimited", **extra):
    return {
        "bench": "inflight",
        "server": server,
        "lanes": lanes,
        "budget": budget,
        "qps": round(n / wall_s, 2),
        **{k + "_ms": round(v, 3) for k, v in common.percentiles(times_ms).items()},
        "ranges_skew_p99_over_p50": skew,
        **extra,
    }


def _drain_micro(eng, queries, budgeter):
    beng = BatchEngine(eng, BucketSpec(max_batch=SLOTS))
    plans = [eng.plan(q) for q in queries]
    beng.warmup(sorted({beng.spec.width_bucket(p.blk_tab.shape[1]) for p in plans}))
    srv = MicroBatchServer(beng, budgeter, max_batch=SLOTS)
    t0 = time.perf_counter()
    for q in queries:
        srv.submit(q)
    served = []
    while srv.pending:
        served.extend(srv.drain_once())
    wall = time.perf_counter() - t0
    return [s.latency_ms for s in served], wall, served


def _drain_inflight(eng, queries, budgeter, obs=None):
    beng = BatchEngine(eng, BucketSpec(max_batch=SLOTS))
    # Warm the (n_slots, width) programs outside the timed region.
    warm = InflightServer(
        beng, SlaBudgeter(sla_ms=float("inf")), n_slots=SLOTS, quantum=QUANTUM
    )
    warm.replay(queries[: 2 * SLOTS])
    kw = {"obs": obs} if obs is not None else {}
    srv = InflightServer(beng, budgeter, n_slots=SLOTS, quantum=QUANTUM, **kw)
    t0 = time.perf_counter()
    for q in queries:
        srv.submit(q)
    served = srv.run_until_idle()
    wall = time.perf_counter() - t0
    return [s.latency_ms for s in served], wall, served


def _skewed_mix(ql, n_terms: int, seed: int = 1):
    """Interleave exploratory log queries with 3x as many navigational
    1-term queries. The light queries safe-terminate after a few ranges;
    the heavy ones traverse most of the order — the per-query work skew
    that makes a micro-batch convoy around its slowest lane."""
    rng = np.random.default_rng(seed)
    heavy = [ql.terms[i] for i in range(ql.n_queries)]
    light = [
        np.asarray([t], np.int32)
        for t in rng.integers(0, n_terms, size=LIGHT_PER_HEAVY * len(heavy))
    ]
    mix = heavy + light
    rng.shuffle(mix)
    return mix


def run(small: bool | None = None):
    if small is None:
        small = os.environ.get("REPRO_BENCH_SMALL") == "1"
    if small:
        from repro.data.synth import make_corpus, make_query_log

        corpus = make_corpus(n_docs=8000, n_terms=3000, n_topics=8,
                             mean_doc_len=120, seed=0)
        ql = make_query_log(corpus, n_queries=24, seed=7)
        idx = common.build_index_cached(
            corpus, cache_dir=common.CACHE, n_ranges=16, strategy="clustered",
        )
        n_terms = 3000
    else:
        corpus = common.bench_corpus()
        ql = common.bench_queries(corpus, n=32, seed=7)
        idx = common.bench_index(corpus, "clustered_bp")
        n_terms = common.N_TERMS
    eng = common.make_engine(idx, k=10)
    queries = _skewed_mix(ql, n_terms)
    n = len(queries)

    # Workload skew (what slot-swapping exploits): ranges processed to safe
    # termination per query, p99/p50.
    ranges = [
        int(eng.traverse(eng.plan(q)).ranges_processed) for q in queries
    ]
    pr = common.percentiles(ranges)
    skew = round(pr["p99"] / max(pr["p50"], 1e-9), 2)

    rows = []
    for budget, label, mk in (
        (None, "unlimited", lambda: SlaBudgeter(sla_ms=float("inf"))),
        (BUDGET, str(BUDGET), lambda: FixedBudgeter(BUDGET)),
    ):
        times, wall, served = _drain_micro(eng, queries, mk())
        rows.append(_row(f"microbatch-{SLOTS}", SLOTS, times, wall, n, skew,
                         budget=label))
        times, wall, served = _drain_inflight(eng, queries, mk())
        mean_q = round(float(np.mean([s.quanta for s in served])), 2)
        rows.append(_row(f"inflight-{SLOTS}x{QUANTUM}", SLOTS, times, wall, n,
                         skew, budget=label, mean_quanta=mean_q))

    for r in rows:
        if r["server"].startswith("inflight"):
            base = next(
                x for x in rows
                if x["server"].startswith("microbatch") and x["budget"] == r["budget"]
            )
            r["qps_vs_microbatch"] = round(r["qps"] / max(base["qps"], 1e-9), 2)
            r["p99_vs_microbatch"] = round(
                r["p99_ms"] / max(base["p99_ms"], 1e-9), 3
            )

    # Observability overhead (ISSUE 8 acceptance: < 5% q/s regression):
    # drain the unlimited in-flight workload with a no-op handle and with
    # full instrumentation — metrics plus tracing at sample rate 1.0 —
    # back to back, best-of-N each, so both sides see the same warm caches
    # and the comparison is not single-shot timing noise. Both q/s numbers
    # land in OBS_SNAPSHOT, which run.py attaches to BENCH_<id>.json.
    from repro.obs import Instrumentation

    reps = 5  # container timing jitter is ~10%; best-of-5 interleaved tames it
    obs = Instrumentation.make(sample_rate=1.0)
    wall_noop = float("inf")
    wall_obs, times = float("inf"), []
    for _ in range(reps):
        wall_noop = min(
            wall_noop,
            _drain_inflight(eng, queries, SlaBudgeter(sla_ms=float("inf")))[1],
        )
        t, w, _served = _drain_inflight(
            eng, queries, SlaBudgeter(sla_ms=float("inf"), obs=obs), obs=obs
        )
        if w < wall_obs:
            wall_obs, times = w, t
    qps_noop = round(n / wall_noop, 2)
    qps_obs = round(n / wall_obs, 2)
    overhead_pct = round((qps_noop - qps_obs) / max(qps_noop, 1e-9) * 100.0, 2)
    rows.append(_row(
        f"inflight-{SLOTS}x{QUANTUM}-instrumented", SLOTS, times, wall_obs, n,
        skew, qps_noop=qps_noop, obs_overhead_pct=overhead_pct,
    ))
    global OBS_SNAPSHOT
    OBS_SNAPSHOT = {
        "overhead": {
            "qps_noop": qps_noop,
            "qps_instrumented": qps_obs,
            "overhead_pct": overhead_pct,
        },
        "registry": obs.snapshot(),
    }
    obs.close()

    common.save_result("inflight", rows)
    return rows


if __name__ == "__main__":
    for row in run(small="--small" in sys.argv):
        print(row)
