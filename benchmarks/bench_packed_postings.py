"""S7: bit-packed docid deltas in HBM — space ratio, latency, parity.

The acceptance claim of DESIGN.md §12: packing per-block docid deltas at
fixed widths (with the merged int32 per-block directory) cuts the
postings-docid HBM footprint by >= 2x on the default synthetic corpus,
while the decode-in-scorer path stays *bitwise* identical to the raw
int32 gather — measured here, not assumed. Rows report, per docs_format:
HBM docid bytes, end-to-end q/s and latency percentiles on the same
query log, and a bitwise top-k parity bit against the int32 engine.
"""

from __future__ import annotations

import os

import numpy as np

from benchmarks import common
from repro.core.range_daat import Engine

N_TIMED_QUERIES = 100
N_TIMED_QUERIES_SMALL = 30


def _topk(engine: Engine, q: np.ndarray):
    res = engine.traverse(engine.plan(q))
    return (
        np.asarray(res.state.ids).tolist(),
        np.asarray(res.state.vals).tolist(),
        int(res.state.postings),
        int(res.state.blocks),
    )


def run(small: bool | None = None):
    if small is None:
        small = os.environ.get("REPRO_BENCH_SMALL") == "1"
    if small:
        from repro.data.synth import make_corpus, make_query_log

        corpus = make_corpus(n_docs=4000, n_terms=3000, n_topics=8,
                             mean_doc_len=80, seed=0)
        n_timed = N_TIMED_QUERIES_SMALL
        queries = make_query_log(corpus, n_queries=n_timed, seed=1)
        index = common.build_index_cached(
            corpus, cache_dir=common.CACHE, n_ranges=8, strategy="clustered",
        )
    else:
        corpus = common.bench_corpus()
        n_timed = N_TIMED_QUERIES
        queries = common.bench_queries(corpus, n=n_timed)
        index = common.bench_index(corpus, "clustered_bp")

    terms = [queries.terms[i] for i in range(queries.n_queries)]
    ref_answers = None
    rows = []
    for docs_format in ("int32", "packed"):
        eng = Engine(index, k=10, impact_dtype="int8", docs_format=docs_format)
        common.warmup_engine(eng, terms)
        answers = [_topk(eng, q) for q in terms]
        if ref_answers is None:
            ref_answers = answers
        lat = []
        with common.Timer() as t_all:
            for q in terms:
                with common.Timer() as t:
                    eng.traverse(eng.plan(q)).state.vals.block_until_ready()
                lat.append(t.ms)
        dev = index.space_report("int8", docs_format)["device_bytes"]
        rows.append(
            {
                "bench": "S7_packed",
                "docs_format": docs_format,
                "nnz": index.nnz,
                "n_blocks": index.n_blocks,
                "hbm_docid_bytes": dev["docs"],
                "hbm_postings_bytes": dev["postings"],
                "qps": round(len(terms) / (t_all.ms / 1e3), 1),
                **{k: round(v, 3) for k, v in common.percentiles(lat).items()},
                # Bitwise parity of ids, scores, and the postings/blocks
                # counters against the raw-int32 engine (the §12 contract).
                "parity_bitwise": answers == ref_answers,
            }
        )
    i32 = rows[0]
    for r in rows:
        r["docid_hbm_ratio_vs_int32"] = round(
            i32["hbm_docid_bytes"] / r["hbm_docid_bytes"], 2
        )
        r["postings_hbm_ratio_vs_int32"] = round(
            i32["hbm_postings_bytes"] / r["hbm_postings_bytes"], 2
        )
        r["qps_vs_int32"] = round(r["qps"] / max(i32["qps"], 1e-9), 2)
    common.save_result("S7_packed", rows)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
