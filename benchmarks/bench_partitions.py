"""Table 7: stability across random 50% partitions (§7.2).

Ten random half-collections, each indexed (clustered+BP) and run under
Predictive(alpha=2) at a ladder of SLAs; the claim is that the ten
subcollections behave consistently (small max-min ranges), justifying the
one-node experimental method. Uses a smaller corpus (10 index builds).
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.anytime import Predictive, run_query_anytime
from repro.core.clustered_index import build_index_cached
from repro.core.metrics import rbo
from repro.core.oracle import exhaustive_topk
from repro.core.range_daat import Engine
from repro.data.synth import Corpus, make_corpus, make_query_log

N_TRIALS = 10


def _half_corpus(corpus: Corpus, seed: int) -> Corpus:
    rng = np.random.default_rng(seed)
    keep = np.sort(rng.choice(corpus.n_docs, size=corpus.n_docs // 2, replace=False))
    ptr = [0]
    terms, tfs = [], []
    for d in keep:
        t, f = corpus.doc_slice(int(d))
        terms.append(t)
        tfs.append(f)
        ptr.append(ptr[-1] + len(t))
    return Corpus(
        n_docs=len(keep),
        n_terms=corpus.n_terms,
        doc_ptr=np.asarray(ptr, np.int64),
        doc_terms=np.concatenate(terms),
        doc_tfs=np.concatenate(tfs),
        doc_topic=corpus.doc_topic[keep],
        n_topics=corpus.n_topics,
    )


def run():
    corpus = make_corpus(n_docs=8000, n_terms=8000, n_topics=16,
                         mean_doc_len=60, seed=10)
    ql = make_query_log(corpus, n_queries=60, seed=11)
    queries = [ql.terms[i] for i in range(ql.n_queries)]

    # Per-trial measurements at each SLA fraction.
    sla_fracs = (0.5, 0.25, 0.1)
    per = {f: {"p50": [], "p95": [], "p99": [], "rbo": []} for f in sla_fracs}
    for trial in range(N_TRIALS):
        half = _half_corpus(corpus, seed=100 + trial)
        idx = build_index_cached(
            half, cache_dir=common.CACHE, n_ranges=16, strategy="clustered_bp",
        )
        eng = Engine(idx, k=10)
        common.warmup_engine(eng, queries)
        base = []
        exhaustive = {}
        for i, q in enumerate(queries):
            res = run_query_anytime(eng, eng.plan(q), policy=None)
            base.append(res.elapsed_ms)
            exhaustive[i] = exhaustive_topk(idx, q, 10)[0].tolist()
        p99 = float(np.percentile(base, 99))
        for frac in sla_fracs:
            budget = p99 * frac
            times, vals = [], []
            for i, q in enumerate(queries):
                res = run_query_anytime(
                    eng, eng.plan(q), policy=Predictive(2.0), budget_ms=budget
                )
                times.append(res.elapsed_ms)
                vals.append(rbo(res.doc_ids.tolist(), exhaustive[i], phi=0.8))
            per[frac]["p50"].append(float(np.percentile(times, 50)))
            per[frac]["p95"].append(float(np.percentile(times, 95)))
            per[frac]["p99"].append(float(np.percentile(times, 99)))
            per[frac]["rbo"].append(float(np.mean(vals)))

    rows = []
    for frac in sla_fracs:
        row = {"bench": "T7_partitions", "sla_frac_of_p99": frac,
               "n_trials": N_TRIALS}
        for m in ("p50", "p95", "p99", "rbo"):
            xs = np.asarray(per[frac][m])
            row[f"{m}_mean"] = round(float(xs.mean()), 4)
            row[f"{m}_range"] = round(float(xs.max() - xs.min()), 4)
            row[f"{m}_range_pct"] = round(
                100 * float((xs.max() - xs.min()) / max(xs.mean(), 1e-9)), 2
            )
        rows.append(row)
    common.save_result("T7_partitions", rows)
    return rows
