"""Quantization fidelity (paper §2.1): 8-10 bits suffice for large
collections. Sweep b in {4, 6, 8, 10} and measure RBO of the quantized
engine's top-k against FLOAT BM25 exhaustive scoring.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.bm25 import invert
from repro.core.clustered_index import build_index
from repro.core.metrics import rbo
from repro.core.range_daat import Engine
from repro.core.reorder import arrange


def _float_topk(post, q_terms, k):
    acc = np.zeros(post.n_docs, dtype=np.float64)
    for t in np.asarray(q_terms).reshape(-1):
        if t < 0:
            continue
        s, e = post.ptr[int(t)], post.ptr[int(t) + 1]
        np.add.at(acc, post.docs[s:e], post.scores[s:e])
    order = np.lexsort((np.arange(acc.shape[0]), -acc))[:k]
    return order[acc[order] > 0]


def run():
    corpus = common.bench_corpus()
    ql = common.bench_queries(corpus, n=60, seed=9)
    arr = arrange(corpus, n_ranges=common.N_RANGES, strategy="clustered_bp")
    post = invert(corpus, arr.doc_order)

    rows = []
    for bits in (4, 6, 8, 10):
        idx = build_index(corpus, arrangement=arr, bits=bits)
        eng = Engine(idx, k=10)
        vals = []
        for i in range(ql.n_queries):
            q = ql.terms[i]
            res = eng.traverse(eng.plan(q))
            ids, _ = eng.topk_docs(res.state)
            gold = _float_topk(post, q, 10)
            vals.append(rbo(ids.tolist(), gold.tolist(), phi=0.8))
        rows.append(
            {
                "bench": "Q_quantization",
                "bits": bits,
                "rbo_vs_float": round(float(np.mean(vals)), 4),
            }
        )
    common.save_result("Q_quantization", rows)
    return rows
