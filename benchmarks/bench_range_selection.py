"""Table 4: effectiveness of range orderings vs ranges processed.

Orderings: BoundSum (ours), Oracle (RBP-weighted per Eqs. 1-2 over the
exhaustive ranking), and CSI-Sample — a central-sample-index baseline
standing in for the paper's LTRR (a learned ranker we do not train; the
CSI is the classic selective-search selector [35], so the comparison stays
real). Metrics: RBP(0.8), AP@1000 against planted qrels, RBO(0.99) vs
exhaustive. n ranges in {1, 5, 10, 20, All}.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.metrics import average_precision, rbo, rbp
from repro.core.oracle import exhaustive_topk
from repro.core.range_daat import Engine
from repro.data.synth import planted_qrels

PHI_ORACLE = 0.99


def oracle_order(index, q, k=10_000):
    """Eq. 1-2: ranges by aggregate RBP weight of the exhaustive ranking."""
    oid, _ = exhaustive_topk(index, q, k)
    r_of = np.searchsorted(index.range_ends, oid, side="right")
    w = np.zeros(index.n_ranges)
    np.add.at(w, r_of, (1 - PHI_ORACLE) * PHI_ORACLE ** np.arange(len(oid)))
    return np.argsort(-w, kind="stable").astype(np.int32)


def csi_sample_order(index, q, sample_frac=0.02, seed=7):
    """Central sample index: score a 2% sample per range, order by best."""
    rng = np.random.default_rng(seed)
    from repro.core.oracle import exhaustive_scores

    scores = exhaustive_scores(index, q)
    best = np.zeros(index.n_ranges)
    for r in range(index.n_ranges):
        lo, hi = index.range_starts[r], index.range_ends[r]
        n = max(1, int((hi - lo) * sample_frac))
        sample = rng.integers(lo, hi, size=n)
        best[r] = scores[sample].max() if n else 0
    return np.argsort(-best, kind="stable").astype(np.int32)


def run_with_order(engine, plan, order, n_ranges):
    """Re-run the device traversal under an externally supplied ordering."""
    import jax.numpy as jnp

    bsums = np.asarray(plan.ordered_bounds)[np.argsort(plan.order_host)]
    new_bounds = bsums[order]
    plan2 = plan.__class__(
        q_terms=plan.q_terms,
        blk_tab=plan.blk_tab,
        rest_tab=plan.rest_tab,
        order=jnp.asarray(order),
        ordered_bounds=jnp.asarray(new_bounds.astype(np.int32)),
        order_host=order,
        bounds_host=new_bounds.astype(np.int64),
    )
    res = engine.traverse(plan2, max_ranges=n_ranges, safe_stop=n_ranges >= 10**8)
    ids, _ = engine.topk_docs(res.state)
    return ids


def run():
    corpus = common.bench_corpus()
    ql = common.bench_queries(corpus, n=60, seed=2)
    qrels = planted_qrels(corpus, ql)
    idx = common.bench_index(corpus, "clustered_bp")
    eng = Engine(idx, k=1000)

    rows = []
    budgets = [1, 5, 10, 20, 10**9]
    metrics = {b: {m: {"BndSum": [], "CSI": [], "Oracle": []}
                   for m in ("rbp", "ap", "rbo")} for b in budgets}
    for qi in range(ql.n_queries):
        q = ql.terms[qi]
        plan = eng.plan(q)
        oid, _ = exhaustive_topk(idx, q, 1000)
        orders = {
            "BndSum": plan.order_host,
            "CSI": csi_sample_order(idx, q),
            "Oracle": oracle_order(idx, q),
        }
        for b in budgets:
            for name, order in orders.items():
                ids = run_with_order(eng, plan, order, b)
                metrics[b]["rbp"][name].append(rbp(ids, qrels[qi], phi=0.8))
                metrics[b]["ap"][name].append(
                    average_precision(ids, list(qrels[qi]), k=1000)
                )
                metrics[b]["rbo"][name].append(
                    rbo(ids.tolist(), oid.tolist(), phi=0.99)
                )

    for b in budgets:
        row = {"bench": "T4_range_selection",
               "ranges": "All" if b >= 10**8 else b}
        for m in ("rbp", "ap", "rbo"):
            for name in ("BndSum", "CSI", "Oracle"):
                row[f"{m}_{name}"] = round(float(np.mean(metrics[b][m][name])), 4)
        rows.append(row)
    common.save_result("T4_range_selection", rows)
    return rows
