"""Figure 10 / Table 6: Reactive feedback on a long query stream.

Predictive(alpha=1, 2) vs Reactive(beta in {1.5, 1.2, 1.1}) on a longer
stream (the bench log repeated in shuffled order, the paper's 60k-query
analogue), strict SLA (10% of exhaustive P99). Traces alpha over the
stream (sawtooth of Fig 10) and checks the ~1%-miss targeting property.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.anytime import Predictive, Reactive, run_query_anytime
from repro.core.metrics import rbo
from repro.core.oracle import exhaustive_topk
from repro.core.range_daat import Engine

STREAM_REPEATS = 5


def run():
    corpus = common.bench_corpus()
    ql = common.bench_queries(corpus, n=120, seed=6)
    base_queries = [ql.terms[i] for i in range(ql.n_queries)]
    idx = common.bench_index(corpus, "clustered_bp")
    eng = Engine(idx, k=10)
    common.warmup_engine(eng, base_queries)

    rng = np.random.default_rng(0)
    stream = []
    for r in range(STREAM_REPEATS):
        order = rng.permutation(len(base_queries))
        stream.extend(int(i) for i in order)

    base_times = []
    exhaustive = {}
    for i, q in enumerate(base_queries):
        res = run_query_anytime(eng, eng.plan(q), policy=None)
        base_times.append(res.elapsed_ms)
        exhaustive[i] = exhaustive_topk(idx, q, 10)[0].tolist()
    budget = float(np.percentile(base_times, 99)) * 0.1

    def run_stream(policy, name):
        times, vals = [], []
        for qi in stream:
            plan = eng.plan(base_queries[qi])
            res = run_query_anytime(eng, plan, policy=policy, budget_ms=budget)
            times.append(res.elapsed_ms)
            vals.append(rbo(res.doc_ids.tolist(), exhaustive[qi], phi=0.8))
        t = np.asarray(times)
        return {
            "bench": "T6_reactive",
            "system": name,
            "budget_ms": round(budget, 2),
            **{k: round(v, 2) for k, v in common.percentiles(t).items()},
            "miss_pct": round(100 * float((t > budget).mean()), 2),
            "rbo": round(float(np.mean(vals)), 4),
            "alpha_trace_tail": (
                [round(a, 3) for a in policy.trace[-12:]]
                if isinstance(policy, Reactive) else None
            ),
            "alpha_final": (
                round(policy.alpha, 3) if isinstance(policy, Reactive) else None
            ),
        }

    rows = [
        run_stream(Predictive(1.0), "Predictive-a1"),
        run_stream(Predictive(2.0), "Predictive-a2"),
    ]
    for beta in (1.5, 1.2, 1.1):
        rows.append(run_stream(Reactive(alpha=1.0, beta=beta, q=0.01),
                               f"Reactive-b{beta}"))
    common.save_result("T6_reactive", rows)
    return rows
