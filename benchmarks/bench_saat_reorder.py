"""Table 3: effect of document reordering on SAAT (JASS) retrieval.

JASS-E (exhaustive) and JASS-A (rho = 10% of docs) on Random vs Reordered
indexes; wall latency percentiles, speedup ratio, and the paper's stated
mechanism — accumulator rows touched (§5.2) — measured directly.
"""

from __future__ import annotations

import time

from benchmarks import common
from repro.core.saat import build_impact_index, saat_query


def _run_variant(ii, queries, rho):
    times, rows, lines = [], 0, 0
    for q in queries:
        t0 = time.perf_counter()
        res = saat_query(ii, q, k=10, rho=rho)
        times.append((time.perf_counter() - t0) * 1e3)
        rows += res.rows_touched
        lines += res.lines_touched
    return times, rows, lines


def run():
    corpus = common.bench_corpus()
    ql = common.bench_queries(corpus)
    queries = [ql.terms[i] for i in range(ql.n_queries)]
    idx_rand = common.bench_index(corpus, "random", 1)
    idx_reord = common.bench_index(corpus, "clustered_bp")
    ii_rand = build_impact_index(idx_rand)
    ii_reord = build_impact_index(idx_reord)

    rows = []
    rho_a = corpus.n_docs // 10
    for algo, rho in (("JASS-E", None), ("JASS-A", rho_a)):
        t_rand, rows_rand, lines_rand = _run_variant(ii_rand, queries, rho)
        t_reord, rows_reord, lines_reord = _run_variant(ii_reord, queries, rho)
        pr, pd = common.percentiles(t_rand), common.percentiles(t_reord)
        rows.append(
            {
                "bench": "T3_saat_reorder",
                "algo": algo,
                **{f"random_{k}": round(v, 3) for k, v in pr.items()},
                **{f"reordered_{k}": round(v, 3) for k, v in pd.items()},
                "speedup_p50": round(pr["p50"] / max(pd["p50"], 1e-9), 3),
                "speedup_p99": round(pr["p99"] / max(pd["p99"], 1e-9), 3),
                "rows_touched_random": rows_rand,
                "rows_touched_reordered": rows_reord,
                "rows_ratio": round(rows_rand / max(rows_reord, 1), 3),
                "lines_touched_random": lines_rand,
                "lines_touched_reordered": lines_reord,
                "lines_ratio": round(lines_rand / max(lines_reord, 1), 3),
            }
        )
    common.save_result("T3_saat_reorder", rows)
    return rows
