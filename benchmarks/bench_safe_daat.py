"""Figure 5: rank-safe query processing — Default vs Clustered traversal.

Default = docid-order windows + listwise/global bounds (range-oblivious);
Clustered = BoundSum order + rangewise bounds + safe early termination.
Both rank-safe; compared on latency and work (postings scored, blocks).
k = 10 and k = 1000, as in the figure.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core.range_daat import Engine


def _measure(engine, queries):
    times, postings, blocks, ranges = [], [], [], []
    common.warmup_engine(engine, queries)
    for q in queries:
        plan = engine.plan(q)
        t0 = time.perf_counter()
        res = engine.traverse(plan)
        res.state.vals.block_until_ready()
        times.append((time.perf_counter() - t0) * 1e3)
        postings.append(int(res.state.postings))
        blocks.append(int(res.state.blocks))
        ranges.append(int(res.ranges_processed))
    return times, postings, blocks, ranges


def run():
    corpus = common.bench_corpus()
    ql = common.bench_queries(corpus)
    queries = [ql.terms[i] for i in range(ql.n_queries)]
    idx = common.bench_index(corpus, "clustered_bp")

    rows = []
    for k in (10, 1000):
        for mode, ordering, bounds in (
            ("Default-DAAT", "docid", "global"),
            ("Clustered-DAAT", "boundsum", "range"),
        ):
            eng = Engine(idx, k=k, ordering=ordering, bounds=bounds)
            times, postings, blocks, ranges = _measure(eng, queries)
            rows.append(
                {
                    "bench": "F5_safe_daat",
                    "k": k,
                    "mode": mode,
                    **{k2: round(v, 3) for k2, v in common.percentiles(times).items()},
                    "mean_ms": round(float(np.mean(times)), 3),
                    "mean_postings": int(np.mean(postings)),
                    "mean_blocks": int(np.mean(blocks)),
                    "mean_ranges": round(float(np.mean(ranges)), 2),
                }
            )
    common.save_result("F5_safe_daat", rows)
    return rows
