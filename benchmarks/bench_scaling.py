"""Figure 11: throughput scaling under concurrent query processing.

The paper scales OS threads on a 32-core server; this container has one
core, so the analogue is device-side batch parallelism: B queries traverse
concurrently via vmap(device_traverse) — exactly how a TPU serving node
would batch queries. Reported: queries/sec and per-query P99 vs batch size,
with the perfect-scaling line for reference, plus the postings-budget knob
(the device-side SLA control) showing throughput/SLA interplay.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from repro.core.range_daat import Engine, device_traverse


def run():
    corpus = common.bench_corpus()
    ql = common.bench_queries(corpus, n=64, seed=7)
    idx = common.bench_index(corpus, "clustered_bp")
    eng = Engine(idx, k=10)

    # Pre-plan all queries at a common pad width.
    plans = [eng.plan(ql.terms[i]) for i in range(ql.n_queries)]
    width = max(p.blk_tab.shape[1] for p in plans)
    plans = [
        eng.plan(ql.terms[i]) if plans[i].blk_tab.shape[1] == width else
        eng.plan(np.asarray(ql.terms[i]))
        for i in range(ql.n_queries)
    ]
    import jax.numpy as jnp

    def pad(p):
        b = p.blk_tab
        if b.shape[1] < width:
            padw = width - b.shape[1]
            return (
                jnp.pad(b, ((0, 0), (0, padw)), constant_values=-1),
                jnp.pad(p.rest_tab, ((0, 0), (0, padw))),
                p.order, p.ordered_bounds,
            )
        return (p.blk_tab, p.rest_tab, p.order, p.ordered_bounds)

    packed = [pad(p) for p in plans]
    blk = jnp.stack([x[0] for x in packed])
    rest = jnp.stack([x[1] for x in packed])
    order = jnp.stack([x[2] for x in packed])
    bounds = jnp.stack([x[3] for x in packed])

    batched = jax.jit(
        jax.vmap(
            lambda b, r, o, bd, budget: device_traverse(
                eng.dix, b, r, o, bd, s_pad=eng.s_pad, k=10,
                budget_postings=budget, safe_stop=True, impl="xla",
            ),
            in_axes=(0, 0, 0, 0, None),
        ),
        static_argnums=(),
    )

    # Work-sorted ordering (mitigation for lockstep while_loop batching —
    # EXPERIMENTS.md §Perf finding 8): group queries with similar predicted
    # work (total surviving blocks) into the same batch.
    est_work = np.asarray((blk >= 0).sum(axis=(1, 2)))
    sort_order = np.argsort(est_work)
    blk_s, rest_s = blk[sort_order], rest[sort_order]
    order_s, bounds_s = order[sort_order], bounds[sort_order]

    rows = []
    for budget in (2**31 - 1, corpus.nnz // 100):
        # Sorted-batch variant at B=16 only (the comparison point).
        B = 16
        reps = 4
        batched(blk_s[:B], rest_s[:B], order_s[:B], bounds_s[:B],
                np.int32(budget)).state.vals.block_until_ready()
        t0 = time.perf_counter()
        for r in range(reps):
            lo = r * B
            res = batched(blk_s[lo:lo + B], rest_s[lo:lo + B],
                          order_s[lo:lo + B], bounds_s[lo:lo + B],
                          np.int32(budget))
            res.state.vals.block_until_ready()
        dt = time.perf_counter() - t0
        rows.append({
            "bench": "F11_scaling",
            "budget": "unlimited" if budget > 2**30 else "1%-postings",
            "batch": 16, "sorted": True,
            "qps": round(reps * B / dt, 2),
            "ms_per_batch": round(1e3 * dt / reps, 2),
            "speedup_vs_b1": None,
        })
        for B in (1, 2, 4, 8, 16, 32, 64):
            reps = max(1, 64 // B)
            # warmup/compile
            batched(blk[:B], rest[:B], order[:B], bounds[:B],
                    np.int32(budget)).state.vals.block_until_ready()
            t0 = time.perf_counter()
            for r in range(reps):
                lo = (r * B) % (64 - B + 1) if B < 64 else 0
                res = batched(
                    blk[lo:lo + B], rest[lo:lo + B], order[lo:lo + B],
                    bounds[lo:lo + B], np.int32(budget),
                )
                res.state.vals.block_until_ready()
            dt = time.perf_counter() - t0
            qps = reps * B / dt
            rows.append(
                {
                    "bench": "F11_scaling",
                    "budget": "unlimited" if budget > 2**30 else "1%-postings",
                    "batch": B,
                    "qps": round(qps, 2),
                    "ms_per_batch": round(1e3 * dt / reps, 2),
                    "speedup_vs_b1": None,  # filled below
                }
            )
    # Fill speedups relative to batch=1 within each budget group.
    for group in ("unlimited", "1%-postings"):
        base = next(
            r["qps"] for r in rows
            if r["budget"] == group and r["batch"] == 1 and not r.get("sorted")
        )
        for r in rows:
            if r["budget"] == group:
                r["speedup_vs_b1"] = round(r["qps"] / base, 2)
    common.save_result("F11_scaling", rows)
    return rows
