"""Range-sharded serving: queries/sec and per-query P99 for 1/2/4 shards.

Compares, over the same index and query log:

  * ``batch-1shard``  — the unsharded ``BatchEngine`` (PR 1 baseline);
  * ``sharded-S``     — ``ShardedBatchEngine`` at S in {1, 2, 4} range
                        shards, one (batch x shard) dispatch per micro-batch.

Execution path is reported per row: ``shard_map mesh`` when the runtime
exposes >= S devices (run standalone with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` for a forced CPU
mesh), else the single-device ``vmap`` fallback — on 1 CPU core the vmap
rows measure sharding *overhead* (same math, extra lanes), which is the
honest number this container can produce; mesh rows measure the speedup.

A budgeted variant shows the anytime knob under sharding: the global
postings budget is split across shards proportionally to postings mass.
"""

from __future__ import annotations

import os
import sys

# Standalone invocation: force a 4-device CPU mesh before jax initializes.
if __name__ == "__main__" and "--mesh" in sys.argv and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import time

import numpy as np

from benchmarks import common

SHARDS = (1, 2, 4)
BATCH = 32
BUDGET = 20_000  # global postings budget for the budgeted rows


def _row(name, shards, path, times_ms, wall_s, n, budget="unlimited"):
    return {
        "bench": "sharded_serving",
        "engine": name,
        "shards": shards,
        "path": path,
        "batch": BATCH,
        "budget": budget,
        "qps": round(n / wall_s, 2),
        **{k + "_ms": round(v, 3) for k, v in common.percentiles(times_ms).items()},
    }


def _serve(beng, plans, budget=None):
    times, t0 = [], time.perf_counter()
    for lo in range(0, len(plans), BATCH):
        chunk = plans[lo : lo + BATCH]
        b = None if budget is None else np.full(len(chunk), budget)
        t1 = time.perf_counter()
        beng.run_batch(chunk, budget_postings=b)
        ms = (time.perf_counter() - t1) * 1e3
        times.extend([ms] * len(chunk))  # every member waits for the batch
    return times, time.perf_counter() - t0


def run(small: bool | None = None):
    import jax

    from repro.serving import BatchEngine, BucketSpec, ShardedBatchEngine, ShardedEngine

    if small is None:
        small = os.environ.get("REPRO_BENCH_SMALL") == "1"
    if small:
        from repro.data.synth import make_corpus, make_query_log

        corpus = make_corpus(n_docs=4000, n_terms=3000, n_topics=8,
                             mean_doc_len=80, seed=0)
        ql = make_query_log(corpus, n_queries=64, seed=7)
        idx = common.build_index_cached(
            corpus, cache_dir=common.CACHE, n_ranges=8, strategy="clustered",
        )
    else:
        corpus = common.bench_corpus()
        ql = common.bench_queries(corpus, n=96, seed=7)
        idx = common.bench_index(corpus, "clustered_bp")
    eng = common.make_engine(idx, k=10)
    queries = [ql.terms[i] for i in range(ql.n_queries)]
    n = len(queries)
    plans = [eng.plan(q) for q in queries]
    widths = sorted({BucketSpec().width_bucket(p.blk_tab.shape[1]) for p in plans})

    rows = []

    # Unsharded batch baseline (the engine sharding must not regress).
    beng = BatchEngine(eng, BucketSpec(max_batch=BATCH))
    beng.warmup(widths)
    times, wall = _serve(beng, plans)
    rows.append(_row("batch-1shard", 1, "vmap", times, wall, n))

    for s in SHARDS:
        if s > idx.n_ranges:
            continue
        se = ShardedEngine(eng, s, use_mesh=None if jax.device_count() >= s else False)
        path = "shard_map mesh" if se.mesh is not None else "vmap"
        sbeng = ShardedBatchEngine(se, BucketSpec(max_batch=BATCH))
        sbeng.warmup(widths)
        for budget, label in ((None, "unlimited"), (BUDGET, str(BUDGET))):
            times, wall = _serve(sbeng, plans, budget)
            r = _row(f"sharded-{s}", s, path, times, wall, n, budget=label)
            r["shard_mass"] = se.mass.tolist()
            rows.append(r)

    base_qps = rows[0]["qps"]
    for r in rows:
        r["speedup_vs_batch"] = round(r["qps"] / base_qps, 2)
    common.save_result("sharded_serving", rows)
    return rows


if __name__ == "__main__":
    for row in run(small="--small" in sys.argv):
        print(row)
