"""Table 5: SLA compliance of anytime processing regimes.

Two latency SLAs are derived from this machine's own exhaustive latency
distribution (the paper's 50/25 ms targets presume their hardware): B_loose
~= exhaustive P95, B_tight = B_loose / 2, P99-conformance required. Systems
mirror Table 5's blocks: safe baselines (no SLA control), fixed-work
(JASS-rho, Fixed-n), and monitored policies (Overshoot / Undershoot /
Predictive alpha=1). RBO(0.8) vs exhaustive, as in the table.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core.anytime import (
    Fixed,
    Overshoot,
    Predictive,
    Undershoot,
    run_query_anytime,
)
from repro.core.metrics import rbo
from repro.core.oracle import exhaustive_topk
from repro.core.range_daat import Engine
from repro.core.saat import build_impact_index, saat_query


def _policy_rows(eng, queries, exhaustive, policy, budget, name):
    times, vals = [], []
    for i, q in enumerate(queries):
        plan = eng.plan(q)
        res = run_query_anytime(eng, plan, policy=policy, budget_ms=budget)
        times.append(res.elapsed_ms)
        vals.append(rbo(res.doc_ids.tolist(), exhaustive[i], phi=0.8))
    return _summarize(name, times, vals, budget)


def _summarize(name, times, vals, budget):
    times = np.asarray(times)
    miss = times > budget
    over = times[miss] - budget
    return {
        "bench": "T5_sla",
        "system": name,
        "budget_ms": round(budget, 2),
        **{k: round(v, 2) for k, v in common.percentiles(times).items()},
        "miss": int(miss.sum()),
        "miss_pct": round(100 * miss.mean(), 2),
        "mean_over_ms": round(float(over.mean()), 3) if miss.any() else 0.0,
        "max_over_ms": round(float(over.max()), 3) if miss.any() else 0.0,
        "rbo": round(float(np.mean(vals)), 4),
        "sla_met": bool(np.percentile(times, 99) <= budget),
    }


def run():
    corpus = common.bench_corpus()
    ql = common.bench_queries(corpus, n=common.N_QUERIES, seed=4)
    queries = [ql.terms[i] for i in range(ql.n_queries)]
    idx = common.bench_index(corpus, "clustered_bp")
    ii = build_impact_index(idx)
    eng = Engine(idx, k=10)
    common.warmup_engine(eng, queries)

    exhaustive = {}
    base_times = []
    for i, q in enumerate(queries):
        plan = eng.plan(q)
        t0 = time.perf_counter()
        res = run_query_anytime(eng, plan, policy=None)
        base_times.append(res.elapsed_ms)
        exhaustive[i] = exhaustive_topk(idx, q, 10)[0].tolist()
    b_loose = float(np.percentile(base_times, 95))
    b_tight = b_loose / 2

    rows = []
    for budget in (b_loose, b_tight):
        # Safe baselines (no SLA control).
        rows.append(
            _summarize("Baseline-Clustered(safe)",
                       base_times,
                       [1.0] * len(base_times), budget)
        )
        # JASS fixed-work.
        for pct in (5, 2.5):
            rho = max(1, int(corpus.n_docs * pct / 100))
            times, vals = [], []
            for i, q in enumerate(queries):
                t0 = time.perf_counter()
                res = saat_query(ii, q, k=10, rho=rho)
                times.append((time.perf_counter() - t0) * 1e3)
                vals.append(rbo(res.doc_ids.tolist(), exhaustive[i], phi=0.8))
            rows.append(_summarize(f"JASS-{pct}", times, vals, budget))
        # Fixed-n ranges.
        for n in (20, 10):
            rows.append(
                _policy_rows(eng, queries, exhaustive, Fixed(n), budget, f"Fixed-{n}")
            )
        # Monitored policies.
        rows.append(_policy_rows(eng, queries, exhaustive, Overshoot(), budget, "Overshoot"))
        tmax = max(0.5, b_loose / 10)
        rows.append(
            _policy_rows(eng, queries, exhaustive, Undershoot(tmax), budget,
                         f"Undershoot(tmax={tmax:.1f})")
        )
        rows.append(
            _policy_rows(eng, queries, exhaustive, Predictive(1.0), budget,
                         "Predictive-a1")
        )
    common.save_result("T5_sla", rows)
    return rows
