"""Figures 6/7: latency vs ranges processed; efficiency-effectiveness
trade-off of BoundSum/Oracle range processing vs JASS-A (anytime SAAT).

Points: Fixed-n for n in {1,2,3,4,5,10,20,32}; JASS rho in {0.2,0.5,1,2,5,
10,20,50,100}% of |D|. RBO(0.99) vs exhaustive; median latency per query.
k = 10 and k = 1000 (the paper notes VBMW wins at 10, MaxScore at 1000 —
block pruning plays that role here).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.bench_range_selection import oracle_order, run_with_order
from repro.core.metrics import rbo
from repro.core.oracle import exhaustive_topk
from repro.core.range_daat import Engine
from repro.core.saat import build_impact_index, saat_query


def run():
    corpus = common.bench_corpus()
    ql = common.bench_queries(corpus, n=80, seed=3)
    queries = [ql.terms[i] for i in range(ql.n_queries)]
    idx = common.bench_index(corpus, "clustered_bp")
    ii = build_impact_index(idx)

    rows = []
    for k in (10, 1000):
        eng = Engine(idx, k=k)
        common.warmup_engine(eng, queries)
        exhaustive = {
            i: exhaustive_topk(idx, q, k)[0].tolist() for i, q in enumerate(queries)
        }
        # --- Fixed-n range processing (BoundSum + Oracle orderings)
        for n in (1, 2, 3, 4, 5, 10, 20, common.N_RANGES):
            for ordering in ("BndSum", "Oracle"):
                times, vals = [], []
                for i, q in enumerate(queries):
                    plan = eng.plan(q)
                    order = (
                        plan.order_host if ordering == "BndSum"
                        else oracle_order(idx, q)
                    )
                    t0 = time.perf_counter()
                    ids = run_with_order(eng, plan, order, n)
                    times.append((time.perf_counter() - t0) * 1e3)
                    vals.append(rbo(ids.tolist(), exhaustive[i], phi=0.99))
                rows.append(
                    {
                        "bench": "F7_tradeoff", "k": k, "system": ordering,
                        "setting": f"n={n}",
                        "p50_ms": round(float(np.median(times)), 3),
                        "rbo": round(float(np.mean(vals)), 4),
                    }
                )
        # --- JASS-A sweeps
        for pct in (0.2, 0.5, 1, 2, 5, 10, 20, 50, 100):
            rho = max(1, int(corpus.n_docs * pct / 100))
            times, vals = [], []
            for i, q in enumerate(queries):
                t0 = time.perf_counter()
                res = saat_query(ii, q, k=k, rho=rho)
                times.append((time.perf_counter() - t0) * 1e3)
                vals.append(rbo(res.doc_ids.tolist(), exhaustive[i], phi=0.99))
            rows.append(
                {
                    "bench": "F7_tradeoff", "k": k, "system": "JASS",
                    "setting": f"rho={pct}%",
                    "p50_ms": round(float(np.median(times)), 3),
                    "rbo": round(float(np.mean(vals)), 4),
                }
            )
    common.save_result("F7_tradeoff", rows)
    return rows
