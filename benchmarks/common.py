"""Shared benchmark substrate: corpora, indexes, query logs, timing.

All artifacts are disk-cached under .cache/ — the slow offline steps
(k-means, recursive graph bisection, inversion) run once. Collection sizes
are scaled to this container (1 CPU core); the paper's *claims* are about
ratios and orderings, which survive the scaling (DESIGN.md §6).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.clustered_index import ClusteredIndex, build_index_cached
from repro.core.range_daat import Engine
from repro.data.synth import Corpus, QueryLog, make_corpus, make_query_log

CACHE = os.path.join(os.path.dirname(__file__), "..", ".cache")
RESULTS = os.path.join(os.path.dirname(__file__), "results")

# Benchmark-scale collection (scaled ClueWeb09B stand-in). Doc length
# matters: topical clustering needs enough term overlap per doc pair
# (web docs average many hundreds of terms).
N_DOCS = 24_000
N_TERMS = 12_000
N_TOPICS = 24
N_RANGES = 32
MEAN_DOC_LEN = 220
N_QUERIES = 200


def bench_corpus(seed: int = 0) -> Corpus:
    return make_corpus(
        n_docs=N_DOCS, n_terms=N_TERMS, n_topics=N_TOPICS,
        mean_doc_len=MEAN_DOC_LEN, seed=seed,
    )


def bench_queries(corpus: Corpus, n: int = N_QUERIES, seed: int = 1) -> QueryLog:
    # Paper's length bias: 1..4 terms equally, then >=5.
    return make_query_log(corpus, n_queries=n, seed=seed)


def bench_index(corpus: Corpus, strategy: str, n_ranges: int = N_RANGES,
                bits: int = 8) -> ClusteredIndex:
    return build_index_cached(
        corpus, cache_dir=CACHE, n_ranges=n_ranges, strategy=strategy, bits=bits,
    )


def make_engine(index: ClusteredIndex, k: int = 10, **kw) -> Engine:
    return Engine(index, k=k, **kw)


def warmup_engine(engine: Engine, queries, n: int = 3):
    for i in range(min(n, len(queries))):
        plan = engine.plan(queries[i])
        engine.traverse(plan).state.vals.block_until_ready()


def percentiles(xs, ps=(50, 95, 99)):
    xs = np.asarray(xs, dtype=np.float64)
    return {f"p{p}": float(np.percentile(xs, p)) for p in ps}


def save_result(name: str, rows):
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.ms = (time.perf_counter() - self.t0) * 1e3
