"""Perf-regression gate over the benchmark trajectory (DESIGN.md §14).

Compares a fresh ``BENCH_<id>.json`` against the last N git-tracked
``benchmarks/trajectory.jsonl`` entries (same small/full preset, the
fresh run's own id excluded):

  * **q/s regressions** — every headline carrying a ``qps=...`` figure is
    checked against the median of its historical values; a drop beyond
    ``--tolerance`` (default 15%) is a regression. Small-preset runs
    (CI's 1-core containers jitter ~10%) only *warn* on these — the gate
    prints GitHub ``::warning`` annotations and exits 0 — while full-size
    runs fail.
  * **observability overhead** — the instrumented-vs-noop q/s gap from
    the S6 overhead row must stay under ``--max-overhead-pct`` (default
    5, the ISSUE 8/9 acceptance bar). Same warn-on-small policy.
  * **recompiles** — a dispatch site recompiling an already-seen shape is
    an anomaly by construction (leaked non-static arg, dtype drift);
    nonzero recompile counts in the fresh profiler snapshot always fail.
  * **metric-schema drift** — a metric name that the previous run's
    registry exported but the fresh run's does not means a dashboard or
    alert silently went dark; always fails, any preset.
  * **static-hazard findings** — each run records the ``repro.analysis``
    finding count (DESIGN.md §15); a count above the most recent
    historical run means new un-baselined lint debt landed. Always
    fails, any preset — the ratchet only tightens.

No history (first run on a branch, fresh clone) exits 0: the gate needs
a baseline before it can gate.

    python -m benchmarks.perf_gate BENCH_abc12345.json [--last 5]
        [--tolerance 0.15] [--max-overhead-pct 5]
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys

try:
    from benchmarks.run import TRAJECTORY, _obs_compact
except ImportError:  # executed as a script: benchmarks/ on path, root not
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )
    from benchmarks.run import TRAJECTORY, _obs_compact

_QPS = re.compile(r"(?:^|_)qps=([0-9.]+)")


def parse_qps(derived: str) -> float | None:
    """The ``qps=`` figure from a headline's derived string, if any."""
    m = _QPS.search(derived or "")
    return float(m.group(1)) if m else None


def read_history(
    path: str, exclude_id: str, small: bool, last: int
) -> list[dict]:
    """Last ``last`` same-preset trajectory entries, oldest first."""
    if not os.path.exists(path):
        return []
    entries = []
    with open(path, encoding="utf-8") as f:
        for ln in f:
            ln = ln.strip()
            if not ln:
                continue
            e = json.loads(ln)
            if e.get("id") == exclude_id or bool(e.get("small")) != small:
                continue
            entries.append(e)
    return entries[-last:]


def _median(vals: list[float]) -> float:
    s = sorted(vals)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def gate(
    fresh: dict,
    history: list[dict],
    tolerance: float = 0.15,
    max_overhead_pct: float = 5.0,
) -> tuple[list[str], list[str]]:
    """Returns (soft_regressions, hard_failures).

    Soft = q/s and overhead threshold breaches (warn-only on small
    presets). Hard = recompiles and metric-schema drift (always fail).
    """
    soft: list[str] = []
    hard: list[str] = []

    headlines = fresh.get("headlines") or {}
    for name in sorted(headlines):
        cur = parse_qps(headlines[name].get("derived", ""))
        if cur is None:
            continue
        past = [
            q
            for e in history
            if (q := parse_qps((e.get("headlines") or {}).get(name, "")))
            is not None
        ]
        if not past:
            continue
        base = _median(past)
        if cur < base * (1.0 - tolerance):
            soft.append(
                f"{name}: qps {cur:g} is {100 * (1 - cur / base):.1f}% below "
                f"the median of the last {len(past)} runs ({base:g}), "
                f"tolerance {tolerance:.0%}"
            )

    obs = _obs_compact(fresh.get("metrics"))
    for name, rec in sorted(obs.items()):
        overhead = rec.get("overhead_pct")
        if overhead is not None and overhead > max_overhead_pct:
            soft.append(
                f"{name}: instrumentation overhead {overhead:g}% exceeds "
                f"the {max_overhead_pct:g}% acceptance bar"
            )
        recompiles = (rec.get("profiler") or {}).get("recompiles", 0)
        if recompiles:
            hard.append(
                f"{name}: {recompiles} jit recompile(s) on already-seen "
                f"shapes — a leaked non-static argument or dtype drift"
            )

    fresh_static = fresh.get("static_findings")
    prev_static = next(
        (
            e["static_findings"]
            for e in reversed(history)
            if e.get("static_findings") is not None
        ),
        None,
    )
    if fresh_static is not None and prev_static is not None:
        cur_n = int(fresh_static.get("count", 0))
        prev_n = int(prev_static.get("count", 0))
        if cur_n > prev_n:
            hard.append(
                f"static_findings: {cur_n} repro.analysis finding(s) vs "
                f"{prev_n} in the last recorded run — new static-hazard "
                f"debt; fix it or ratchet analysis_baseline.json "
                f"consciously (by_rule: {fresh_static.get('by_rule')})"
            )

    prev_obs = next(
        (e["obs"] for e in reversed(history) if e.get("obs")), None
    )
    if prev_obs:
        for name, prev in sorted(prev_obs.items()):
            want = set(prev.get("metric_names") or [])
            if not want or name not in obs:
                continue
            have = set(obs[name].get("metric_names") or [])
            gone = sorted(want - have)
            if gone:
                hard.append(
                    f"{name}: metric(s) vanished from the registry "
                    f"(dashboards/alerts reading them went dark): "
                    f"{', '.join(gone)}"
                )
    return soft, hard


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.perf_gate")
    ap.add_argument("bench_json", help="fresh BENCH_<id>.json to gate")
    ap.add_argument(
        "--last", type=int, default=5, help="trajectory entries to baseline on"
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.15,
        help="allowed fractional q/s drop vs the historical median",
    )
    ap.add_argument(
        "--max-overhead-pct",
        type=float,
        default=5.0,
        help="instrumented-vs-noop q/s overhead acceptance bar",
    )
    ap.add_argument(
        "--trajectory", default=TRAJECTORY, help="trajectory JSONL to read"
    )
    args = ap.parse_args(argv)

    with open(args.bench_json, encoding="utf-8") as f:
        fresh = json.load(f)
    small = os.environ.get("REPRO_BENCH_SMALL") == "1"
    history = read_history(
        args.trajectory, fresh.get("id", ""), small, args.last
    )

    soft, hard = gate(
        fresh,
        history,
        tolerance=args.tolerance,
        max_overhead_pct=args.max_overhead_pct,
    )

    if not history:
        print("perf_gate: no comparable trajectory history; nothing to gate")
    for msg in soft:
        # Small presets run on noisy shared CI cores: annotate, don't block.
        print(f"::warning title=perf regression::{msg}" if small else msg)
    for msg in hard:
        print(f"::error title=perf gate::{msg}" if small else msg)
    if hard:
        return 1
    if soft and not small:
        return 1
    print(
        f"perf_gate: ok (id={fresh.get('id')}, baseline={len(history)} "
        f"run(s), {len(soft)} warning(s))"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
