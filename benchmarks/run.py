"""Benchmark entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the repo contract, where
us_per_call is the benchmark's headline per-query latency (microseconds)
where latency is meaningful, and ``derived`` carries the headline claim
metric. Full rows land in benchmarks/results/*.json for EXPERIMENTS.md,
and the per-run headline summary lands in a top-level ``BENCH_<id>.json``
(id = ``$BENCH_ID``, else the git short sha, else a timestamp) — the
perf-trajectory artifact CI uploads per commit. Because that artifact is
gitignored and expires with CI retention, every run also appends a compact
record to the git-tracked ``benchmarks/trajectory.jsonl``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
import traceback

MODULES = [
    ("T2_index_space", "benchmarks.bench_index_space"),
    ("T3_saat_reorder", "benchmarks.bench_saat_reorder"),
    ("F5_safe_daat", "benchmarks.bench_safe_daat"),
    ("T4_range_selection", "benchmarks.bench_range_selection"),
    ("F7_tradeoff", "benchmarks.bench_tradeoff"),
    ("T5_sla", "benchmarks.bench_sla"),
    ("F8_alpha", "benchmarks.bench_alpha"),
    ("T6_reactive", "benchmarks.bench_reactive"),
    ("T7_partitions", "benchmarks.bench_partitions"),
    ("F11_scaling", "benchmarks.bench_scaling"),
    ("S1_batch_serving", "benchmarks.bench_batch_serving"),
    ("S2_sharded_serving", "benchmarks.bench_sharded_serving"),
    ("S3_index_io", "benchmarks.bench_index_io"),
    ("S4_control_plane", "benchmarks.bench_control_plane"),
    ("S5_incremental", "benchmarks.bench_incremental"),
    ("S6_inflight", "benchmarks.bench_inflight"),
    ("T8_failures", "benchmarks.bench_failures"),
    ("Q_quantization", "benchmarks.bench_quantization"),
    ("S7_packed", "benchmarks.bench_packed_postings"),
]


def _headline(name: str, rows) -> tuple[float, str]:
    """(us_per_call, derived) summaries per benchmark."""
    try:
        if name == "T2_index_space":
            clustered = next(
                r for r in rows
                if r["index_type"] == "Clustered" and r["ordering"] == "Reordered"
            )
            return 0.0, f"clustered_overhead={clustered['overhead_vs_default']}x"
        if name == "T3_saat_reorder":
            r = rows[0]
            return (
                r["reordered_p50"] * 1e3,
                f"lines_ratio={r['lines_ratio']}x_speedup_p50={r['speedup_p50']}x",
            )
        if name == "F5_safe_daat":
            r = next(x for x in rows if x["k"] == 10 and "Clustered" in x["mode"])
            d = next(x for x in rows if x["k"] == 10 and "Default" in x["mode"])
            return r["p50"] * 1e3, f"clustered_vs_default_p50={d['p50']/max(r['p50'],1e-9):.2f}x"
        if name == "T4_range_selection":
            r10 = next(x for x in rows if x["ranges"] == 10)
            return 0.0, f"rbo10_bndsum={r10['rbo_BndSum']}_oracle={r10['rbo_Oracle']}"
        if name == "F7_tradeoff":
            r = next(x for x in rows if x["system"] == "BndSum" and x["setting"] == "n=10" and x["k"] == 10)
            return r["p50_ms"] * 1e3, f"rbo={r['rbo']}"
        if name == "T5_sla":
            r = next(x for x in rows if x["system"] == "Predictive-a1")
            return r["p99"] * 1e3, f"sla_met={r['sla_met']}_rbo={r['rbo']}"
        if name == "F8_alpha":
            r = next(x for x in rows if x["alpha"] == 2.0 and x["sla_frac_of_p99"] == 0.1)
            return r["p99"] * 1e3, f"sla_met={r['sla_met']}_rbo={r['rbo']}"
        if name == "T6_reactive":
            r = next(x for x in rows if x["system"] == "Reactive-b1.2")
            return r["p99"] * 1e3, f"miss_pct={r['miss_pct']}_rbo={r['rbo']}"
        if name == "T7_partitions":
            r = rows[-1]
            return 0.0, f"p99_range_pct={r['p99_range_pct']}%"
        if name == "F11_scaling":
            r = next(x for x in rows if x["batch"] == 32 and x["budget"] == "unlimited")
            return 1e6 / max(r["qps"], 1e-9), f"speedup_b32={r['speedup_vs_b1']}x"
        if name == "S1_batch_serving":
            r = next(
                x for x in rows if x["engine"] == "batch-32"
                and x["budget"] == "unlimited"
            )
            return (
                1e6 / max(r["qps"], 1e-9),
                f"qps_b32={r['qps']}_speedup={r['speedup_vs_seq_host']}x",
            )
        if name == "S2_sharded_serving":
            r = next(
                x for x in rows if x["engine"] == "sharded-4"
                and x["budget"] == "unlimited"
            )
            return (
                1e6 / max(r["qps"], 1e-9),
                f"qps_4shard={r['qps']}_path={r['path'].split()[0]}"
                f"_vs_batch={r['speedup_vs_batch']}x",
            )
        if name == "S3_index_io":
            r8 = next(x for x in rows if x["impact_dtype"] == "int8")
            return (
                r8["load_ms_eager"] * 1e3,
                f"disk_mb={r8['disk_mb']}"
                f"_hbm_impacts={r8['hbm_impacts_ratio_vs_int32']}x"
                f"_parity={r8['parity_bitwise']}",
            )
        if name == "S4_control_plane":
            r2 = next(x for x in rows if x["mode"] == "replicas-2")
            live = next(x for x in rows if x["mode"] == "reshard-live")
            return (
                1e6 / max(r2["qps"], 1e-9),
                f"qps_1rep={next(x for x in rows if x['mode'] == 'replicas-1')['qps']}"
                f"_2rep={r2['qps']}_reshard_qps={live['qps_during']}"
                f"_served_during={live['served_during']}",
            )
        if name == "S5_incremental":
            app = next(x for x in rows if x["op"] == "append+publish")
            reb = next(x for x in rows if x["op"] == "rebuild+publish")
            deep = next(
                x for x in rows if x["op"] == "reopen-chain"
                and x["chain_length"] == max(
                    y["chain_length"] for y in rows if y["op"] == "reopen-chain"
                )
            )
            comp = next(x for x in rows if x["op"] == "reopen-compacted")
            return (
                app["ms"] * 1e3,
                f"append={app['ms']}ms_rebuild={reb['ms']}ms_"
                f"speedup={reb['speedup_vs_rebuild']}x_"
                f"reopen{deep['chain_length']}={deep['ms']}ms_"
                f"compacted={comp['ms']}ms_parity={comp['parity_bitwise']}",
            )
        if name == "S6_inflight":
            r = next(
                x for x in rows if x["server"].startswith("inflight")
                and x["budget"] == "unlimited"
            )
            return (
                1e6 / max(r["qps"], 1e-9),
                f"qps={r['qps']}_vs_micro={r['qps_vs_microbatch']}x"
                f"_p99={r['p99_vs_microbatch']}x",
            )
        if name == "S7_packed":
            pk = next(x for x in rows if x["docs_format"] == "packed")
            return (
                1e6 / max(pk["qps"], 1e-9),
                f"docid_hbm_ratio={pk['docid_hbm_ratio_vs_int32']}x"
                f"_qps_vs_int32={pk['qps_vs_int32']}x"
                f"_parity={pk['parity_bitwise']}",
            )
        if name == "Q_quantization":
            r8 = next(x for x in rows if x["bits"] == 8)
            r4 = next(x for x in rows if x["bits"] == 4)
            return 0.0, f"rbo8bit={r8['rbo_vs_float']}_rbo4bit={r4['rbo_vs_float']}"
        if name == "T8_failures":
            r = rows[-1]
            if r.get("summary"):
                return 0.0, (
                    f"depth_low={r['mean_avg_depth_low_rbo']}"
                    f"_high={r['mean_avg_depth_high_rbo']}"
                )
    except (StopIteration, KeyError, IndexError):
        pass
    return 0.0, "see_json"


def _bench_id() -> str:
    """Stable id for this run's BENCH_<id>.json: env, git sha, or time."""
    env = os.environ.get("BENCH_ID")
    if env:
        return env
    try:
        proc = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
        # A nonzero exit (not a repo, detached worktree garbage) can still
        # print to stdout under some git versions — never trust it then.
        sha = proc.stdout.strip() if proc.returncode == 0 else ""
        if sha:
            return sha
    except (OSError, subprocess.SubprocessError):
        pass
    return time.strftime("%Y%m%d-%H%M%S")


TRAJECTORY = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "trajectory.jsonl")


def _obs_compact(metrics: dict | None) -> dict:
    """Compact per-module observability facts for the trajectory log.

    One small dict per module that exported an OBS_SNAPSHOT: the
    instrumented-vs-noop overhead, total profiler compile/recompile
    counts, and the registered metric names — the inputs perf_gate.py
    checks for q/s regressions and metric-schema drift.
    """
    out: dict = {}
    for name, snap in sorted((metrics or {}).items()):
        rec: dict = {}
        overhead = (snap.get("overhead") or {}).get("overhead_pct")
        if overhead is not None:
            rec["overhead_pct"] = overhead
        prof = snap.get("profiler") or {}
        if prof:
            rec["profiler"] = {
                "compiles": sum(s.get("compiles", 0) for s in prof.values()),
                "recompiles": sum(
                    s.get("recompiles", 0) for s in prof.values()
                ),
            }
        reg = snap.get("registry") or {}
        if reg:
            rec["metric_names"] = sorted(reg)
        if rec:
            out[name] = rec
    return out


def _static_findings(root: str) -> dict | None:
    """Static-hazard finding counts for this run's trajectory record.

    Sourced from ``repro.analysis`` (DESIGN.md §15) so perf_gate can fail
    a run whose finding count *rose* against history — the lint ratchet's
    CI twin. Analyzer unavailable (trimmed checkout) -> record nothing.
    """
    try:
        from repro.analysis import count_findings
    except ImportError:
        return None
    try:
        return count_findings(os.path.join(root, "src", "repro"))
    except (OSError, SyntaxError):
        return None


def append_trajectory(
    rid: str,
    headlines: dict,
    failures: list,
    metrics: dict | None = None,
    static_findings: dict | None = None,
) -> str:
    """Append one compact run record to the git-tracked trajectory log.

    ``BENCH_<id>.json`` is gitignored and CI only keeps it as an expiring
    artifact, which is why seven PRs of bench runs accumulated nothing.
    This JSONL file is tracked: every run (CI small presets included)
    appends one line — id, time, and the headline string per benchmark,
    no bulky per-row payloads — so the perf trajectory survives in-repo.
    A run with the same id (re-run of one commit) replaces its entry.
    """
    entry = {
        "id": rid,
        "unix_time": int(time.time()),
        "small": bool(os.environ.get("REPRO_BENCH_SMALL")),
        "headlines": {
            name: h["derived"] for name, h in sorted(headlines.items())
        },
        "us_per_call": {
            name: h["us_per_call"] for name, h in sorted(headlines.items())
        },
        "failures": failures,
    }
    obs = _obs_compact(metrics)
    if obs:
        entry["obs"] = obs
    if static_findings is not None:
        entry["static_findings"] = static_findings
    lines = []
    if os.path.exists(TRAJECTORY):
        with open(TRAJECTORY, encoding="utf-8") as f:
            lines = [ln for ln in f.read().splitlines() if ln.strip()]
        lines = [ln for ln in lines if json.loads(ln).get("id") != rid]
    lines.append(json.dumps(entry, sort_keys=True))
    with open(TRAJECTORY, "w", encoding="utf-8") as f:
        f.write("\n".join(lines) + "\n")
    return TRAJECTORY


def write_headline_file(
    headlines: dict, failures: list, metrics: dict | None = None
) -> str:
    """Write the top-level BENCH_<id>.json perf-trajectory snapshot."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rid = _bench_id()
    path = os.path.join(root, f"BENCH_{rid}.json")
    payload = {
        "id": rid,
        "unix_time": int(time.time()),
        "headlines": headlines,
        "failures": failures,
    }
    if metrics:
        payload["metrics"] = metrics
    findings = _static_findings(root)
    if findings is not None:
        payload["static_findings"] = findings
    with open(path, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    append_trajectory(
        rid, headlines, failures, metrics, static_findings=findings
    )
    return path


def main() -> None:
    import importlib

    only = sys.argv[1:] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = []
    headlines = {}
    metrics = {}
    for name, module in MODULES:
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(module)
            rows = mod.run()
            us, derived = _headline(name, rows)
            print(f"{name},{us:.1f},{derived}", flush=True)
            headlines[name] = {"us_per_call": round(us, 1), "derived": derived}
            # Bench modules that instrument a run export an OBS_SNAPSHOT
            # (metrics-registry dump + derived numbers); fold it into the
            # BENCH_<id>.json so overhead claims ship with the run record.
            snap = getattr(mod, "OBS_SNAPSHOT", None)
            if snap:
                metrics[name] = snap
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            print(f"{name},nan,FAILED:{type(e).__name__}", flush=True)
            failures.append(name)
        sys.stderr.write(f"# {name} took {time.time()-t0:.1f}s\n")
    if headlines or failures:
        path = write_headline_file(headlines, failures, metrics)
        sys.stderr.write(f"# headline trajectory -> {path}\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
