"""Quickstart: build a cluster-skipping index and run anytime queries.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import numpy as np

from repro import index_io
from repro.core import Engine, arrange, build_index
from repro.core.anytime import Predictive, run_query_anytime
from repro.core.metrics import rbo
from repro.core.oracle import exhaustive_topk
from repro.data.synth import make_corpus, make_query_log


def main():
    print("1) Synthetic planted-topic corpus (8k docs) ...")
    corpus = make_corpus(n_docs=8000, n_terms=6000, n_topics=16,
                         mean_doc_len=150, seed=0)
    queries = make_query_log(corpus, n_queries=10, seed=1)

    print("2) Topical clustering + per-cluster graph bisection + index ...")
    arr = arrange(corpus, n_ranges=16, strategy="clustered_bp", bp_rounds=4)
    index = build_index(corpus, arrangement=arr, bits=8)
    rep = index.space_report()
    print(f"   {index.nnz} postings, {index.n_blocks} blocks, "
          f"{index.n_ranges} ranges, {rep['total_gib']*1024:.1f} MiB")

    print("3) Queries: rank-safe vs anytime (Predictive alpha=1, 10 ms) ...")
    engine = Engine(index, k=10)
    for i in range(4):
        q = queries.terms[i]
        plan = engine.plan(q)
        safe = run_query_anytime(engine, plan, policy=None)
        fast = run_query_anytime(engine, plan, policy=Predictive(1.0),
                                 budget_ms=10.0)
        oid, _ = exhaustive_topk(index, q, 10)
        print(f"   q{i}: safe {safe.elapsed_ms:6.1f} ms "
              f"({safe.ranges_processed:2d} ranges, exit={safe.exit_reason}) | "
              f"anytime {fast.elapsed_ms:6.1f} ms "
              f"({fast.ranges_processed:2d} ranges) "
              f"RBO vs exhaustive = {rbo(fast.doc_ids.tolist(), oid.tolist()):.3f}")
        assert safe.doc_ids.tolist() == oid.tolist(), "safe mode must be exact"
    print("   safe mode reproduced the exhaustive oracle exactly.")

    print("4) Index lifecycle: save artifact (int8 impacts), reload, re-serve ...")
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "quickstart.art")
        index_io.save_index(index, path, impact_dtype="int8")
        loaded = Engine.from_artifact(path, k=10)
        dev8 = index.space_report("int8")["device_bytes"]
        dev32 = index.space_report("int32")["device_bytes"]
        print(f"   saved + reloaded (fingerprint {index.fingerprint()}); "
              f"impacts HBM {dev32['impacts']} B (int32) -> "
              f"{dev8['impacts']} B (int8), "
              f"{dev32['impacts'] / dev8['impacts']:.0f}x smaller")
        q = queries.terms[0]
        a = engine.traverse(engine.plan(q))
        b = loaded.traverse(loaded.plan(q))
        assert np.asarray(a.state.ids).tolist() == np.asarray(b.state.ids).tolist()
        assert np.asarray(a.state.vals).tolist() == np.asarray(b.state.vals).tolist()
        print("   loaded int8 artifact reproduced the in-memory top-k bitwise.")


if __name__ == "__main__":
    main()
