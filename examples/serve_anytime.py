"""End-to-end anytime serving driver (the paper's operating mode).

Two engines over the same cluster-skipping index:

  * ``--mode host`` — the paper's host-driven loop: one device step per
    range, wall-clock polled between ranges, Reactive (§6.4) alpha feedback
    per query;
  * ``--mode batch`` — the production path: a micro-batching request loop
    over the vmapped ``BatchEngine``. The SLA cannot be polled mid-dispatch,
    so ``SlaBudgeter`` compiles it into per-query postings budgets (EWMA
    throughput x Reactive alpha, see repro/serving/README.md);
  * ``--mode sharded`` — the batch loop over a range-sharded index
    (``--shards`` devices, DESIGN.md §4): one (batch x shard) dispatch per
    micro-batch, ``ShardedSlaBudgeter`` splitting the SLA into per-shard
    postings budgets. Falls back to the single-device vmap path when the
    runtime exposes fewer devices than shards (set
    XLA_FLAGS=--xla_force_host_platform_device_count=N for a CPU mesh);
  * ``--mode control`` — the full control plane (DESIGN.md §9): the same
    sharded serving under a ``ControlPlane`` with ``--replicas`` replica
    groups, BoundSum-aware budget allocation, a mid-stream shard outage
    (served degraded through the fidelity bound, then recovered), and a
    live reshard cutover with serving uninterrupted;
  * ``--mode inflight`` — the slot-swapping continuous loop (DESIGN.md
    §11): ``--batch-size`` slots stepped ``--quantum`` ranges per
    dispatch, exited queries replaced mid-flight from the queue. Same
    queue-wait-inclusive latency attribution as the batch mode, so the
    two modes' P99s compare directly.

All report percentile latencies, queries/sec, SLA compliance, and
effectiveness (RBO vs exhaustive). ``--trace out.jsonl`` records a
per-query trace (any mode, sample rate 1.0, DESIGN.md §13) for
``python -m repro.obs report out.jsonl`` / ``... slo out.jsonl``.
``--metrics snap.json`` exports the metrics registry (plus SLO/alert
state and the dispatch profiler, DESIGN.md §14) for
``python -m repro.obs watch snap.json``; in control mode the snapshot
refreshes every drain, so a concurrent ``watch`` follows the run live.

    PYTHONPATH=src python examples/serve_anytime.py
        [--mode host|batch|sharded|control|inflight] [--sla-ms 15]
        [--queries 300] [--batch-size 16] [--quantum 1] [--shards 2]
        [--replicas 1] [--trace out.jsonl] [--metrics snap.json]
"""

import argparse
import time

import numpy as np

from repro.core import Engine, arrange, build_index
from repro.core.anytime import Reactive, run_query_anytime
from repro.core.metrics import rbo
from repro.core.oracle import exhaustive_topk
from repro.data.synth import make_corpus, make_query_log
from repro.obs import NOOP, Instrumentation, write_snapshot
from repro.obs.detect import DriftMonitor, default_serving_detectors
from repro.obs.slo import SloTracker, default_serving_slos
from repro.serving import (
    BatchEngine,
    BucketSpec,
    InflightServer,
    MicroBatchServer,
    ShardedBatchEngine,
    ShardedEngine,
    ShardedSlaBudgeter,
    SlaBudgeter,
)


def build(args):
    corpus = make_corpus(n_docs=10_000, n_terms=8000, n_topics=16,
                         mean_doc_len=150, seed=0)
    log = make_query_log(corpus, n_queries=args.queries, seed=2)
    arr = arrange(corpus, n_ranges=16, strategy="clustered_bp", bp_rounds=4)
    index = build_index(corpus, arrangement=arr)
    return corpus, log, index, Engine(index, k=args.k)


def calibrate(engine, index, log, args):
    """Warmup + derive the SLA from this machine's exhaustive distribution."""
    base, rates, oracle = [], [], {}
    for i in range(min(64, log.n_queries)):
        plan = engine.plan(log.terms[i])
        res = run_query_anytime(engine, plan, policy=None)
        base.append(res.elapsed_ms)
        if res.elapsed_ms > 0:
            rates.append(res.postings / res.elapsed_ms)
        oracle[i] = exhaustive_topk(index, log.terms[i], args.k)[0].tolist()
    exh_p99 = float(np.percentile(base, 99))
    return exh_p99, oracle, float(np.median(rates))


def report(times, quality, sla, wall, n, extra=""):
    t = np.asarray(times)
    print(f"\nServed {n} queries in {wall:.1f}s ({n/wall:.1f} q/s){extra}")
    print(f"  P50 {np.percentile(t,50):6.2f} ms   P95 {np.percentile(t,95):6.2f} "
          f"ms   P99 {np.percentile(t,99):6.2f} ms")
    miss = (t > sla).mean() * 100
    print(f"  SLA misses: {miss:.2f}% (target <= 1%)")
    print(f"  mean RBO(0.8) vs exhaustive: {np.mean(quality):.4f}")
    print("  P99 SLA", "MET" if np.percentile(t, 99) <= sla else "MISSED")


def serve_host(engine, log, sla_arg, oracle, exh_p99, obs=NOOP):
    # Default SLA: 25% of this machine's host-driven exhaustive P99.
    sla = sla_arg or exh_p99 * 0.25
    print(f"SLA: P99 <= {sla:.2f} ms (exhaustive P99 was {exh_p99:.2f} ms)")
    policy = Reactive(alpha=1.0, beta=1.2, q=0.01)
    times, quality = [], []
    t0 = time.perf_counter()
    for i in range(log.n_queries):
        plan = engine.plan(log.terms[i])
        t_q = obs.clock() if obs.enabled else 0.0
        res = run_query_anytime(engine, plan, policy=policy, budget_ms=sla)
        times.append(res.elapsed_ms)
        if obs.enabled:
            # The host loop has no server in front of it, so it emits the
            # one-span trace itself (queue wait is zero by construction).
            obs.trace_begin(i)
            obs.trace_span(i, "service", t_q, obs.clock())
            obs.trace_attr(i, server="host", latency_ms=res.elapsed_ms,
                           exit_reason=res.exit_reason, sla_ms=sla)
            obs.trace_end(i)
        if i in oracle:
            quality.append(rbo(res.doc_ids.tolist(), oracle[i], phi=0.8))
    wall = time.perf_counter() - t0
    report(times, quality, sla, wall, log.n_queries,
           extra=f"   final alpha = {policy.alpha:.2f}")


def serve_batch(engine, log, sla_arg, oracle, batch_size, rate0, exh_p99,
                n_shards=None, obs=NOOP):
    spec = BucketSpec(max_batch=batch_size)
    if n_shards:
        seng = ShardedEngine(engine, n_shards, obs=obs)
        beng = ShardedBatchEngine(seng, spec)
        path = "shard_map mesh" if seng.mesh is not None else "vmap (1 device)"
        print(f"sharded: {seng.n_shards} range shards, {path}, "
              f"mass={seng.mass.tolist()}")
        mk_budgeter = lambda **kw: ShardedSlaBudgeter(n_shards=seng.n_shards, **kw)
    else:
        beng = BatchEngine(engine, spec)
        mk_budgeter = SlaBudgeter
    # Pre-compile every (batch_bucket, width) program the whole log can
    # produce before any timing (planning is host-side and cheap).
    widths = {beng.spec.width_bucket(engine.plan(log.terms[i]).blk_tab.shape[1])
              for i in range(log.n_queries)}
    beng.warmup(sorted(widths))

    # Default SLA: half of the *batched* unbudgeted P99 — a micro-batch
    # serializes its lanes on this 1-core container, so the host-loop
    # distribution understates what one dispatch costs.
    probe_n = min(4 * batch_size, log.n_queries)
    probe = MicroBatchServer(
        beng, mk_budgeter(sla_ms=float("inf"), rate=rate0), max_batch=batch_size
    )
    lat = [s.latency_ms for s in
           probe.replay([log.terms[i] for i in range(probe_n)],
                        batch_size=batch_size)]
    sla = sla_arg or float(np.percentile(lat, 99)) * 0.5
    print(f"SLA: P99 <= {sla:.2f} ms (unbudgeted batch P99 was "
          f"{np.percentile(lat, 99):.2f} ms; host exhaustive P99 "
          f"{exh_p99:.2f} ms)")

    budgeter = mk_budgeter(
        sla_ms=sla, policy=Reactive(alpha=1.0, beta=1.5, q=0.01), rate=rate0,
        obs=obs,
    )
    server = MicroBatchServer(beng, budgeter, max_batch=batch_size, obs=obs)
    # Let the budgeter see one real batch before timing; remember the rid
    # watermark so the timed replay's rids map back to query-log positions.
    server.replay([log.terms[i] for i in range(min(batch_size, log.n_queries))])
    rid0 = server._next_rid

    times, quality = [], []
    t0 = time.perf_counter()
    served = server.replay(
        [log.terms[i] for i in range(log.n_queries)], batch_size=batch_size
    )
    wall = time.perf_counter() - t0
    for s in served:
        times.append(s.latency_ms)
        qi = s.rid - rid0
        if qi in oracle:
            ids = s.result.doc_ids[np.lexsort((s.result.doc_ids, -s.result.scores))]
            quality.append(rbo(ids.tolist(), oracle[qi], phi=0.8))
    report(times, quality, sla, wall, log.n_queries,
           extra=(f"   batch={batch_size}, programs="
                  f"{sorted(beng.compiled_shapes)}, "
                  f"final alpha = {budgeter.policy.alpha:.2f}"))


def serve_inflight(engine, log, sla_arg, oracle, args, rate0, exh_p99,
                   obs=NOOP):
    """Slot-swapping continuous loop at saturating offered load."""
    spec = BucketSpec(max_batch=args.batch_size)
    beng = BatchEngine(engine, spec)
    queries = [log.terms[i] for i in range(log.n_queries)]

    # Warm the (n_slots, width) programs the log can produce.
    warm = InflightServer(
        beng, SlaBudgeter(sla_ms=float("inf"), rate=rate0),
        n_slots=args.batch_size, quantum=args.quantum,
    )
    lat = [s.latency_ms for s in
           warm.replay(queries[: min(4 * args.batch_size, log.n_queries)])]
    sla = sla_arg or float(np.percentile(lat, 99)) * 0.5
    print(f"SLA: P99 <= {sla:.2f} ms (unbudgeted in-flight P99 was "
          f"{np.percentile(lat, 99):.2f} ms; host exhaustive P99 "
          f"{exh_p99:.2f} ms)")

    budgeter = SlaBudgeter(
        sla_ms=sla, policy=Reactive(alpha=1.0, beta=1.5, q=0.01), rate=rate0,
        obs=obs,
    )
    server = InflightServer(
        beng, budgeter, n_slots=args.batch_size, quantum=args.quantum, obs=obs
    )
    times, quality = [], []
    t0 = time.perf_counter()
    served = server.replay(queries)
    wall = time.perf_counter() - t0
    for s in served:
        times.append(s.latency_ms)
        if s.rid in oracle:
            ids = s.result.doc_ids[np.lexsort((s.result.doc_ids, -s.result.scores))]
            quality.append(rbo(ids.tolist(), oracle[s.rid], phi=0.8))
    report(times, quality, sla, wall, log.n_queries,
           extra=(f"   slots={args.batch_size}, quantum={args.quantum}, "
                  f"steps={server.steps_run}, programs="
                  f"{sorted(server.compiled_shapes)}, "
                  f"final alpha = {budgeter.policy.alpha:.2f}"))


def serve_control(engine, log, sla_arg, oracle, args, obs=NOOP,
                  metrics_path=None):
    """Control-plane demo: outage + recovery + live reshard, one stream."""
    from repro.control import ControlPlane

    plane = ControlPlane(
        engine, n_shards=args.shards, n_replicas=args.replicas,
        sla_ms=sla_arg or float("inf"),
        spec=BucketSpec(max_batch=args.batch_size),
        obs=obs,
    )
    tracker = monitor = None
    alerts_seen = []
    if obs.enabled:
        # Operations loop (DESIGN.md §14): SLO burn-rate accounting plus
        # drift/skew detectors polled every drain, alerts feeding back into
        # the plane (skew arms maybe_reshard, burn marks degraded-SLO).
        tracker = SloTracker(obs, default_serving_slos(
            sla_ms=sla_arg, fidelity_ceiling=None))
        monitor = DriftMonitor(obs)
        default_serving_detectors(monitor, n_shards=args.shards,
                                  server="control")
        monitor.subscribe(lambda ev: alerts_seen.append(ev.to_dict()))
        plane.enable_operations(slos=tracker, monitor=monitor)
    st = plane.stats()
    print(f"control plane: {args.shards} shards x {args.replicas} replicas, "
          f"cuts={st['cuts']}, replica_mesh={st['replica_mesh']}, "
          f"budget mode={plane.budgeter.mode}")
    queries = [log.terms[i] for i in range(log.n_queries)]
    third = max(args.batch_size, log.n_queries // 3)
    # Pre-compile every (batch_bucket, width) program before any timing,
    # same discipline as serve_batch — percentiles measure serving, not XLA.
    widths = {plane.bengine.spec.width_bucket(
        engine.plan(log.terms[i]).blk_tab.shape[1])
        for i in range(log.n_queries)}
    plane.bengine.warmup(sorted(widths))

    times, quality, degraded = [], [], 0

    def consume(served):
        nonlocal degraded
        for s in served:
            times.append(s.latency_ms)
            r = s.result
            if "down" in r.shard_exit_reasons and not r.exact:
                degraded += 1
            qi = s.rid
            if qi in oracle:
                ids = r.doc_ids[np.lexsort((r.doc_ids, -r.scores))]
                quality.append(rbo(ids.tolist(), oracle[qi], phi=0.8))

    t0 = time.perf_counter()
    # Phase 1: healthy serving.
    consume(plane.replay(queries[:third], batch_size=args.batch_size))
    # Phase 2: shard 0 dies mid-stream; every query still answers.
    plane.mark_down(0)
    consume(plane.replay(queries[third : 2 * third],
                         batch_size=args.batch_size))
    print(f"  outage window: shard 0 down, {degraded} queries served "
          f"degraded (exact=False, bounded fidelity loss)")
    plane.mark_up(0)
    # Phase 3: live reshard while the rest of the log streams through.
    task = plane.start_reshard(plane.planner.propose()) \
        if plane.planner.should_reshard() else None
    if task is None and args.shards > 1:
        # Demo fallback: nudge the first boundary by one range (a single
        # shard has no interior boundary to move — nothing to demo).
        cuts = plane.cuts.copy()
        cuts[1] = cuts[1] - 1 if cuts[1] > 1 else cuts[1] + 1
        if cuts[1] < cuts[2] and not np.array_equal(cuts, plane.cuts):
            task = plane.start_reshard(cuts)
    def refresh_metrics():
        if metrics_path and obs.enabled:
            write_snapshot(
                metrics_path, obs.metrics,
                slo=tracker.evaluate() if tracker is not None else None,
                alerts=alerts_seen[-32:],
                profiler=(obs.profiler.snapshot()
                          if obs.profiler is not None else None),
                t=obs.clock(),
            )

    qi = 2 * third
    while qi < len(queries) or plane.reshard_task is not None:
        for q in queries[qi : qi + args.batch_size]:
            plane.submit(q)
        qi += args.batch_size
        consume(plane.drain_once())
        refresh_metrics()
    while plane.pending:
        consume(plane.drain_once())
        refresh_metrics()
    wall = time.perf_counter() - t0
    if task is not None:
        print(f"  live reshard -> cuts={plane.cuts.tolist()} in "
              f"{task.steps_done} steps; "
              f"{plane.queries_served_during_reshard} queries served "
              f"mid-cutover (serving never paused)")
    sla = sla_arg or float("inf")
    report(times, quality, sla, wall, len(times),
           extra=f"   degraded={degraded}, "
                 f"reshards={plane.reshards_completed}")
    if plane.stats().get("degraded_slo"):
        print("  SLO state: degraded (burn-rate alert firing)")
    return {"slo": tracker.evaluate() if tracker is not None else None,
            "alerts": alerts_seen}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode",
                    choices=("host", "batch", "sharded", "control", "inflight"),
                    default="batch")
    ap.add_argument("--quantum", type=int, default=1,
                    help="ranges per dispatch per slot for --mode inflight")
    ap.add_argument("--shards", type=int, default=2,
                    help="range shards for --mode sharded/control")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replica groups for --mode control")
    ap.add_argument("--sla-ms", type=float, default=None,
                    help="P99 budget; default: host mode = 25%% of the "
                         "host-driven exhaustive P99, batch mode = 50%% of "
                         "the unbudgeted batched P99")
    ap.add_argument("--queries", type=int, default=300)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a per-query JSONL trace (sample rate 1.0) "
                         "for `python -m repro.obs report PATH`")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write a registry+SLO+profiler snapshot (JSON) "
                         "for `python -m repro.obs watch PATH`")
    args = ap.parse_args()

    obs = (Instrumentation.make(sample_rate=1.0, trace_path=args.trace,
                                profile=bool(args.metrics))
           if args.trace or args.metrics else NOOP)
    _, log, index, engine = build(args)
    exh_p99, oracle, rate0 = calibrate(engine, index, log, args)
    extras = {}
    if args.mode == "host":
        serve_host(engine, log, args.sla_ms, oracle, exh_p99, obs=obs)
    elif args.mode == "control":
        extras = serve_control(engine, log, args.sla_ms, oracle, args,
                               obs=obs, metrics_path=args.metrics) or {}
    elif args.mode == "inflight":
        serve_inflight(engine, log, args.sla_ms, oracle, args, rate0, exh_p99,
                       obs=obs)
    else:
        serve_batch(engine, log, args.sla_ms, oracle, args.batch_size,
                    rate0, exh_p99,
                    n_shards=args.shards if args.mode == "sharded" else None,
                    obs=obs)
    if obs.enabled:
        if args.metrics:
            write_snapshot(
                args.metrics, obs.metrics,
                slo=extras.get("slo"), alerts=extras.get("alerts"),
                profiler=(obs.profiler.snapshot()
                          if obs.profiler is not None else None),
                t=obs.clock(),
            )
            print(f"\nmetrics snapshot -> {args.metrics}  "
                  f"(view: python -m repro.obs watch {args.metrics} --once)")
        obs.close()
        if args.trace:
            print(f"\ntrace: {obs.tracer.finished} records -> {args.trace}  "
                  f"(summarize: python -m repro.obs report {args.trace})")


if __name__ == "__main__":
    main()
