"""End-to-end anytime serving driver (the paper's operating mode).

Serves a stream of batched queries against a cluster-skipping index under a
P99 latency SLA with the Reactive policy (§6.4): latency is monitored
per range, alpha adapts per query, and the report shows percentile
latencies, SLA compliance, and effectiveness (RBO vs exhaustive).

    PYTHONPATH=src python examples/serve_anytime.py [--sla-ms 15] [--queries 300]
"""

import argparse
import time

import numpy as np

from repro.core import Engine, arrange, build_index
from repro.core.anytime import Reactive, run_query_anytime
from repro.core.metrics import rbo
from repro.core.oracle import exhaustive_topk
from repro.data.synth import make_corpus, make_query_log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sla-ms", type=float, default=None,
                    help="P99 budget; default = 25%% of exhaustive P99")
    ap.add_argument("--queries", type=int, default=300)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    corpus = make_corpus(n_docs=10_000, n_terms=8000, n_topics=16,
                         mean_doc_len=150, seed=0)
    log = make_query_log(corpus, n_queries=args.queries, seed=2)
    arr = arrange(corpus, n_ranges=16, strategy="clustered_bp", bp_rounds=4)
    index = build_index(corpus, arrangement=arr)
    engine = Engine(index, k=args.k)

    # Warmup + derive the SLA from this machine's exhaustive distribution.
    base = []
    oracle = {}
    for i in range(min(64, log.n_queries)):
        plan = engine.plan(log.terms[i])
        res = run_query_anytime(engine, plan, policy=None)
        base.append(res.elapsed_ms)
        oracle[i] = exhaustive_topk(index, log.terms[i], args.k)[0].tolist()
    sla = args.sla_ms or float(np.percentile(base, 99)) * 0.25
    print(f"SLA: P99 <= {sla:.2f} ms (exhaustive P99 was "
          f"{np.percentile(base, 99):.2f} ms)")

    policy = Reactive(alpha=1.0, beta=1.2, q=0.01)
    times, quality = [], []
    t0 = time.perf_counter()
    for i in range(log.n_queries):
        plan = engine.plan(log.terms[i])
        res = run_query_anytime(engine, plan, policy=policy, budget_ms=sla)
        times.append(res.elapsed_ms)
        if i in oracle:
            quality.append(rbo(res.doc_ids.tolist(), oracle[i], phi=0.8))
    wall = time.perf_counter() - t0

    t = np.asarray(times)
    print(f"\nServed {log.n_queries} queries in {wall:.1f}s "
          f"({log.n_queries/wall:.1f} q/s)")
    print(f"  P50 {np.percentile(t,50):6.2f} ms   P95 {np.percentile(t,95):6.2f} "
          f"ms   P99 {np.percentile(t,99):6.2f} ms")
    miss = (t > sla).mean() * 100
    print(f"  SLA misses: {miss:.2f}% (target <= 1%)   "
          f"final alpha = {policy.alpha:.2f}")
    print(f"  mean RBO(0.8) vs exhaustive: {np.mean(quality):.4f}")
    print("  P99 SLA", "MET" if np.percentile(t, 99) <= sla else "MISSED")


if __name__ == "__main__":
    main()
