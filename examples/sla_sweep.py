"""Policy x SLA compliance matrix (paper Table 5 in miniature).

    PYTHONPATH=src python examples/sla_sweep.py
"""

import numpy as np

from repro.core import Engine, arrange, build_index
from repro.core.anytime import (
    Fixed, Overshoot, Predictive, Reactive, Undershoot, run_query_anytime,
)
from repro.core.metrics import rbo
from repro.core.oracle import exhaustive_topk
from repro.data.synth import make_corpus, make_query_log


def main():
    corpus = make_corpus(n_docs=8000, n_terms=6000, n_topics=16,
                         mean_doc_len=150, seed=0)
    log = make_query_log(corpus, n_queries=120, seed=3)
    arr = arrange(corpus, n_ranges=16, strategy="clustered_bp", bp_rounds=4)
    index = build_index(corpus, arrangement=arr)
    engine = Engine(index, k=10)

    base, oracle = [], {}
    for i in range(log.n_queries):
        res = run_query_anytime(engine, engine.plan(log.terms[i]), policy=None)
        base.append(res.elapsed_ms)
        oracle[i] = exhaustive_topk(index, log.terms[i], 10)[0].tolist()
    p99 = float(np.percentile(base, 99))

    print(f"exhaustive P99 = {p99:.1f} ms")
    print(f"{'policy':<22} {'SLA(ms)':>8} {'P99':>8} {'miss%':>6} {'RBO':>6}")
    for frac in (0.5, 0.25, 0.1):
        budget = p99 * frac
        for mk in (
            lambda: Fixed(8),
            lambda: Overshoot(),
            lambda: Undershoot(max(0.5, budget / 8)),
            lambda: Predictive(1.0),
            lambda: Predictive(2.0),
            lambda: Reactive(alpha=1.0, beta=1.2),
        ):
            pol = mk()
            times, vals = [], []
            for i in range(log.n_queries):
                res = run_query_anytime(
                    engine, engine.plan(log.terms[i]), policy=pol,
                    budget_ms=budget,
                )
                times.append(res.elapsed_ms)
                vals.append(rbo(res.doc_ids.tolist(), oracle[i], phi=0.8))
            t = np.asarray(times)
            flag = "OK " if np.percentile(t, 99) <= budget else "MISS"
            print(f"{pol.name:<22} {budget:8.1f} {np.percentile(t,99):8.2f} "
                  f"{(t > budget).mean()*100:6.2f} {np.mean(vals):6.3f}  {flag}")


if __name__ == "__main__":
    main()
