"""Train a small LM with the full substrate (trainer/checkpoint/optimizer).

Uses the reduced qwen3-family config (same features: GQA + qk-norm + SwiGLU
+ scan/remat) on synthetic token streams; demonstrates checkpoint/restart:
run it twice and the second run resumes from the last checkpoint.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.models.transformer import lm_loss
from repro.train.checkpoint import Checkpointer
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default=".cache/train_lm_ckpt")
    args = ap.parse_args()

    arch = get_arch("qwen3-4b")
    cfg = arch.model_config(reduced=True)
    params = arch.init_params(jax.random.key(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name} ({n_params/1e6:.2f}M params)")

    def data_fn(step):  # deterministic in step -> exact resume
        rng = np.random.default_rng(step)
        return {"tokens": rng.integers(0, cfg.vocab, size=(8, 64)).astype(np.int32)}

    trainer = Trainer(
        loss_fn=lambda p, b: lm_loss(p, b["tokens"], cfg),
        params=params,
        cfg=TrainerConfig(
            total_steps=args.steps, log_every=20, checkpoint_every=50,
            lr=3e-4, warmup=20,
        ),
        data_fn=data_fn,
        checkpointer=Checkpointer(args.ckpt_dir, keep_last=2),
    )
    out = trainer.run()
    print(f"exit={out['exit']} at step {out['last_step']}")
    for h in out["history"]:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}  {h['time_s']*1e3:.0f} ms")
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'resumed mid-run or flat'})")


if __name__ == "__main__":
    main()
