"""repro.analysis — jit/Pallas static-hazard linter (DESIGN.md §15).

AST-based, stdlib-only checkers for the bug classes PR 6 and PR 9 fixed
after the fact: recompiles from leaked non-static args (RECOMPILE),
host syncs in dispatch hot loops (HOSTSYNC), unguarded int32 narrowing
(NARROW), unguarded telemetry in hot paths (OBSGUARD), non-atomic
artifact writes (ARTIFACT), and Python control flow on Pallas tracers
(PALLASCONST). Findings ratchet through ``analysis_baseline.json``;
intentional sites carry ``# analysis: allow[RULE]`` waivers.

CLI: ``python -m repro.analysis {check,baseline,explain}``.
"""

from repro.analysis.model import Finding, Project, SourceFile
from repro.analysis.registry import RULES, Rule, help_for, missing_help, rule
from repro.analysis import checkers  # noqa: F401  (registers the rules)
from repro.analysis.baseline import (
    diff_baseline,
    load_baseline,
    write_baseline,
)
from repro.analysis.runner import (
    AnalysisReport,
    analyze_paths,
    analyze_source,
    count_findings,
)

__all__ = [
    "AnalysisReport",
    "Finding",
    "Project",
    "RULES",
    "Rule",
    "SourceFile",
    "analyze_paths",
    "analyze_source",
    "count_findings",
    "diff_baseline",
    "help_for",
    "load_baseline",
    "missing_help",
    "rule",
    "write_baseline",
]
