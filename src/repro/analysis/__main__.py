"""CLI for the static-hazard analyzer (DESIGN.md §15).

    python -m repro.analysis check [PATH ...] [--baseline FILE] [--json OUT]
    python -m repro.analysis baseline [PATH ...] [--out FILE]
    python -m repro.analysis explain [RULE]

``check`` exits nonzero on any finding outside the baseline *and* on any
stale baseline entry (the ratchet only tightens). ``baseline`` rewrites
the pin file from the current findings. ``explain`` prints the per-rule
help catalog.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.baseline import (
    diff_baseline,
    load_baseline,
    write_baseline,
)
from repro.analysis.registry import RULES, help_for
from repro.analysis.runner import analyze_paths

DEFAULT_PATHS = ["src/repro"]
DEFAULT_BASELINE = "analysis_baseline.json"


def _cmd_check(args) -> int:
    rep = analyze_paths(args.paths or DEFAULT_PATHS)
    if args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(rep.to_json(), fh, indent=1, sort_keys=True)
            fh.write("\n")
    if args.baseline:
        baseline = load_baseline(args.baseline)
        new, stale = diff_baseline(rep.findings, baseline)
        for f in new:
            print(f"NEW     {f.render()}")
        for k in stale:
            ent = baseline[k]
            print(
                f"STALE   {ent.get('path')}: [{ent.get('rule')}] "
                f"{ent.get('scope')}: baseline entry no longer matches a "
                f"finding — the debt was paid; delete key {k}"
            )
        print(
            f"repro.analysis: {len(rep.findings)} finding(s) "
            f"({len(rep.waived)} waived) across {rep.files} file(s); "
            f"{len(new)} new, {len(stale)} stale vs {args.baseline}"
        )
        return 1 if (new or stale) else 0
    for f in rep.findings:
        print(f.render())
    print(
        f"repro.analysis: {len(rep.findings)} finding(s) "
        f"({len(rep.waived)} waived) across {rep.files} file(s)"
    )
    return 1 if rep.findings else 0


def _cmd_baseline(args) -> int:
    rep = analyze_paths(args.paths or DEFAULT_PATHS)
    write_baseline(args.out, rep.findings)
    print(
        f"repro.analysis: pinned {len(rep.findings)} finding(s) "
        f"({len(rep.waived)} waived) into {args.out}"
    )
    return 0


def _cmd_explain(args) -> int:
    names = [args.rule.upper()] if args.rule else sorted(RULES)
    unknown = [n for n in names if n not in RULES]
    if unknown:
        print(
            f"unknown rule(s): {', '.join(unknown)} — "
            f"registered: {', '.join(sorted(RULES))}",
            file=sys.stderr,
        )
        return 2
    for i, n in enumerate(names):
        if i:
            print()
        print(f"{n}\n{'-' * len(n)}")
        print(help_for(n))
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("check", help="run the checkers, gate on findings")
    p.add_argument("paths", nargs="*", help=f"roots (default {DEFAULT_PATHS})")
    p.add_argument(
        "--baseline",
        help=f"ratchet file (e.g. {DEFAULT_BASELINE}); nonzero exit on "
        f"new or stale findings",
    )
    p.add_argument("--json", help="also write the full report as JSON")
    p.set_defaults(fn=_cmd_check)

    p = sub.add_parser("baseline", help="pin current findings as the baseline")
    p.add_argument("paths", nargs="*", help=f"roots (default {DEFAULT_PATHS})")
    p.add_argument("--out", default=DEFAULT_BASELINE)
    p.set_defaults(fn=_cmd_baseline)

    p = sub.add_parser("explain", help="print the rule help catalog")
    p.add_argument("rule", nargs="?", help="one rule (default: all)")
    p.set_defaults(fn=_cmd_explain)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    try:
        raise SystemExit(main())
    except BrokenPipeError:  # `... | head` closed stdout; not an error
        raise SystemExit(0)
