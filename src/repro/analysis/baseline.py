"""The ratcheted finding baseline (DESIGN.md §15).

``analysis_baseline.json`` pins the findings the repo has consciously
deferred. The ratchet only tightens:

* a finding **not** in the baseline fails the check (new debt),
* a baseline entry with no matching finding **also** fails (the debt was
  paid — delete the entry so it can never regress silently).

Keys come from :attr:`Finding.key` — line-number-free and snippet-hashed,
so pure line drift neither breaks nor loosens the ratchet.
"""

from __future__ import annotations

import json
import os

from repro.analysis.model import Finding

__all__ = ["diff_baseline", "load_baseline", "write_baseline"]

VERSION = 1


def load_baseline(path: str) -> dict[str, dict]:
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    if data.get("version") != VERSION:
        raise ValueError(
            f"unsupported baseline version {data.get('version')!r} "
            f"in {path} (want {VERSION})"
        )
    return dict(data.get("findings") or {})


def write_baseline(path: str, findings: list[Finding]) -> None:
    payload = {
        "version": VERSION,
        "findings": {
            f.key: f.to_json()
            for f in sorted(findings, key=lambda f: f.key)
        },
    }
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)


def diff_baseline(
    findings: list[Finding], baseline: dict[str, dict]
) -> tuple[list[Finding], list[str]]:
    """(new findings not in the baseline, stale baseline keys)."""
    keys = {f.key for f in findings}
    new = [f for f in findings if f.key not in baseline]
    stale = sorted(k for k in baseline if k not in keys)
    return new, stale
