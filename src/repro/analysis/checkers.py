"""The six repo-specific hazard checkers (DESIGN.md §15).

Every rule here encodes a bug class this repo has actually shipped and
later fixed at runtime cost:

* PR 6 fixed an int64 -> int32 bounds wrap that silently disabled safe
  termination (NARROW) and a budgeter judging device time instead of
  end-to-end latency.
* PR 9 built a profiler that catches recompiles (leaked non-static args,
  RECOMPILE) and unguarded instrumentation overhead (OBSGUARD) — but
  only at runtime, after the regression is serving traffic.

The checkers are deliberately heuristic: they pattern-match the repo's
own idioms (``static_argnames`` partial-jit, ``saturate_bounds`` guards,
``if obs.enabled`` gating, staged-tmp + ``os.replace`` publishes) rather
than attempting whole-program dataflow. False positives are handled by
inline ``# analysis: allow[RULE]`` waivers; residual debt lives in the
committed ``analysis_baseline.json`` ratchet.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field

from repro.analysis.model import Finding, Project, SourceFile
from repro.analysis.registry import rule

__all__ = ["DRAIN_BOUNDARIES", "HOT_ROOTS"]

# --------------------------------------------------------------- helpers

STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
STATIC_FNS = {"len", "isinstance"}


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _stmt_of(node: ast.AST) -> ast.stmt:
    while not isinstance(node, ast.stmt):
        node = node.parent  # type: ignore[attr-defined]
    return node


def _func_of(node: ast.AST):
    cur = getattr(node, "parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = getattr(cur, "parent", None)
    return None


def _class_of(node: ast.AST):
    cur = getattr(node, "parent", None)
    while cur is not None:
        if isinstance(cur, ast.ClassDef):
            return cur
        cur = getattr(cur, "parent", None)
    return None


def _is_static_use(name: ast.Name, root: ast.AST) -> bool:
    """True when ``name`` is only used via shape/dtype/len/isinstance —
    i.e. trace-time-static even for traced values."""
    cur: ast.AST = name
    while cur is not root:
        parent = cur.parent  # type: ignore[attr-defined]
        if isinstance(parent, ast.Attribute) and parent.attr in STATIC_ATTRS:
            return True
        if isinstance(parent, ast.Call) and cur is not parent.func:
            fn = _dotted(parent.func)
            if fn in STATIC_FNS:
                return True
        cur = parent
    return False


def _value_refs(root: ast.AST, names: set[str]) -> list[str]:
    """Names from ``names`` referenced at *value* position under ``root``."""
    hits: list[str] = []
    for n in ast.walk(root):
        if (
            isinstance(n, ast.Name)
            and n.id in names
            and not _is_static_use(n, root)
        ):
            hits.append(n.id)
    return hits


def _scoped(sf: SourceFile, fragments: tuple[str, ...]) -> bool:
    return any(f in sf.path for f in fragments)


# ---------------------------------------------------- jit-site collection


@dataclass
class JitFn:
    """A jit-wrapped function: its def (if local), params, static names."""

    name: str
    node: ast.FunctionDef | None
    static: set[str] = field(default_factory=set)
    params: list[str] = field(default_factory=list)


_JIT_NAMES = {"jax.jit", "jit"}
_PARTIAL_NAMES = {"functools.partial", "partial"}


def _static_argnames(keywords: list[ast.keyword]) -> set[str]:
    out: set[str] = set()
    for kw in keywords:
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            out.add(v.value)
        elif isinstance(v, (ast.Tuple, ast.List)):
            out.update(
                e.value
                for e in v.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)
            )
    return out


def _params_of(fn: ast.FunctionDef) -> list[str]:
    names = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    return [n for n in names if n != "self"]


def collect_jits(sf: SourceFile) -> dict[str, JitFn]:
    """Every jit-wrapped callable defined in this module, by name."""
    jits: dict[str, JitFn] = {}
    module_fns = {
        n.name: n for n in sf.tree.body if isinstance(n, ast.FunctionDef)
    }
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                static: set[str] | None = None
                if _dotted(dec) in _JIT_NAMES:
                    static = set()
                elif isinstance(dec, ast.Call):
                    head = _dotted(dec.func)
                    if head in _JIT_NAMES:
                        static = _static_argnames(dec.keywords)
                    elif (
                        head in _PARTIAL_NAMES
                        and dec.args
                        and _dotted(dec.args[0]) in _JIT_NAMES
                    ):
                        static = _static_argnames(dec.keywords)
                if static is not None:
                    jits[node.name] = JitFn(
                        node.name, node, static, _params_of(node)
                    )
        elif isinstance(node, ast.Assign) and isinstance(
            node.value, ast.Call
        ):
            # g = jax.jit(f, static_argnames=(...))
            call = node.value
            if _dotted(call.func) in _JIT_NAMES and call.args:
                target = _dotted(call.args[0])
                inner = module_fns.get(target or "")
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        jits[t.id] = JitFn(
                            t.id,
                            inner,
                            _static_argnames(call.keywords),
                            _params_of(inner) if inner else [],
                        )
    return jits


# ------------------------------------------------------------- RECOMPILE

_HELP_RECOMPILE = """\
Recompile hazards around jit boundaries. Two patterns:

  1. Value-dependent `if`/`while` on a traced (non-static_argnames)
     parameter inside a jit'd function body. Shape/dtype/len() tests are
     fine (static at trace time); testing the *value* either fails to
     trace or silently recompiles per value. Use `lax.cond`/`jnp.where`,
     or move the flag into `static_argnames`.
  2. Call sites passing Python strings or tuple/list literals into
     non-static parameters of a module-local jit'd function: every
     distinct value compiles a fresh executable.

PR 9's dispatch profiler detects exactly this at runtime ("recompile on
an already-seen shape = leaked non-static arg, by construction"); this
rule catches it at review time. Waive with `# analysis: allow[RECOMPILE]`
when the branch is genuinely trace-time-static."""


def _is_py_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return True
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(isinstance(e, ast.Constant) for e in node.elts)
    return False


@rule("RECOMPILE", _HELP_RECOMPILE)
def check_recompile(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for sf in project.files:
        jits = collect_jits(sf)
        for jf in jits.values():
            if jf.node is None:
                continue
            traced = set(jf.params) - jf.static
            for sub in ast.walk(jf.node):
                if not isinstance(sub, (ast.If, ast.While)):
                    continue
                hits = _value_refs(sub.test, traced)
                if hits:
                    kind = "while" if isinstance(sub, ast.While) else "if"
                    out.append(
                        Finding(
                            "RECOMPILE",
                            sf.path,
                            sub.lineno,
                            sf.scope_of(sub),
                            f"value-dependent `{kind}` on traced "
                            f"parameter(s) {sorted(set(hits))} inside "
                            f"jit'd `{jf.name}` — use lax.cond/jnp.where "
                            f"or add to static_argnames",
                            snippet=sf.segment(sub.test),
                        )
                    )
        # Same-module call sites of the jitted functions.
        for call in ast.walk(sf.tree):
            if not isinstance(call, ast.Call):
                continue
            jf = jits.get(_dotted(call.func) or "")
            if jf is None:
                continue
            bad: list[str] = []
            for i, a in enumerate(call.args):
                if i < len(jf.params) and _is_py_literal(a):
                    if jf.params[i] not in jf.static:
                        bad.append(jf.params[i])
            for kw in call.keywords:
                if kw.arg and kw.arg not in jf.static and _is_py_literal(
                    kw.value
                ):
                    bad.append(kw.arg)
            if bad:
                out.append(
                    Finding(
                        "RECOMPILE",
                        sf.path,
                        call.lineno,
                        sf.scope_of(call),
                        f"Python literal passed into non-static "
                        f"parameter(s) {sorted(set(bad))} of jit'd "
                        f"`{jf.name}` — every distinct value recompiles; "
                        f"add to static_argnames",
                        snippet=sf.segment(call),
                    )
                )
    return out


# -------------------------------------------------------------- HOSTSYNC

_HELP_HOSTSYNC = """\
Host-device synchronization reachable from a serving hot loop. The
in-flight and micro-batch servers overlap host planning with device
scoring (DESIGN.md §11); any `jax.block_until_ready`, `jax.device_get`,
`.item()`, or `np.asarray`/`float()` on a dispatch result inside the
dispatch path stalls that overlap and serializes the quantum.

Detection: an intra-package call-graph walk from the hot roots
(InflightServer.step, MicroBatchServer.drain_once, BatchEngine.run_batch,
ShardedEngine.dispatch, ControlPlane.drain_once, ReplicaGroupEngine.dispatch,
...). Known drain boundaries (_carry_to_host, lane_result, _to_results) are
allowlisted — results must land on the host *somewhere*; the rule polices
where. Syncs inside `for`/`while` loops anywhere in the tree (e.g. a
training step loop) are also flagged.

Fix by slicing/reducing on-device and deferring the host copy to the
drain boundary. Intentional syncs (profiler timing fences, step-boundary
metrics) get `# analysis: allow[HOSTSYNC]` so the baseline holds only
real debt."""

HOT_ROOTS = {
    ("InflightServer", "step"),
    ("MicroBatchServer", "drain_once"),
    ("BatchEngine", "run_batch"),
    ("ShardedBatchEngine", "run_batch"),
    ("ShardedEngine", "dispatch"),
    ("ControlPlane", "drain_once"),
    ("ReplicaGroupEngine", "dispatch"),
}

DRAIN_BOUNDARIES = {"_carry_to_host", "lane_result", "_to_results"}

_SYNC_CALLS = {"jax.block_until_ready", "jax.device_get"}
_SYNC_ATTRS = {"block_until_ready", "item"}
_MATERIALIZE = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
_TAINT_SRC = re.compile(r"(traverse|dispatch|run_batch|resume|_fns\[)")


def _module_name(path: str) -> str:
    p = path.replace("\\", "/")
    if "src/" in p:
        p = p.split("src/", 1)[1]
    return p[:-3].replace("/", ".") if p.endswith(".py") else p


def _import_map(sf: SourceFile) -> dict[str, tuple[str, str]]:
    """local name -> (module, original name) for from-imports."""
    mod_parts = _module_name(sf.path).split(".")
    out: dict[str, tuple[str, str]] = {}
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.ImportFrom):
            continue
        if node.level:
            base = mod_parts[: -node.level]
            target = ".".join(base + (node.module or "").split("."))
        else:
            target = node.module or ""
        for alias in node.names:
            out[alias.asname or alias.name] = (target, alias.name)
    return out


@dataclass
class _DefIndex:
    """Project-wide (module, class, function) -> def node index."""

    defs: dict[tuple[str, str | None, str], tuple[SourceFile, ast.AST]] = (
        field(default_factory=dict)
    )
    modules: dict[str, SourceFile] = field(default_factory=dict)
    imports: dict[str, dict[str, tuple[str, str]]] = field(
        default_factory=dict
    )


def _index_defs(project: Project) -> _DefIndex:
    ix = _DefIndex()
    for sf in project.files:
        mod = _module_name(sf.path)
        ix.modules[mod] = sf
        ix.imports[mod] = _import_map(sf)
        for node in sf.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                ix.defs[(mod, None, node.name)] = (sf, node)
            elif isinstance(node, ast.ClassDef):
                for sub in node.body:
                    if isinstance(
                        sub, (ast.FunctionDef, ast.AsyncFunctionDef)
                    ):
                        ix.defs[(mod, node.name, sub.name)] = (sf, sub)
    return ix


def _edges(ix: _DefIndex, mod: str, cls: str | None, fnode) -> list[tuple]:
    """Resolvable callees: self-methods, module functions, from-imports."""
    out = []
    for call in ast.walk(fnode):
        if not isinstance(call, ast.Call):
            continue
        f = call.func
        if (
            isinstance(f, ast.Attribute)
            and isinstance(f.value, ast.Name)
            and f.value.id == "self"
            and cls is not None
            and (mod, cls, f.attr) in ix.defs
        ):
            out.append((mod, cls, f.attr))
        elif isinstance(f, ast.Name):
            if (mod, None, f.id) in ix.defs:
                out.append((mod, None, f.id))
            else:
                imp = ix.imports.get(mod, {}).get(f.id)
                if imp and (imp[0], None, imp[1]) in ix.defs:
                    out.append((imp[0], None, imp[1]))
    return out


def _taint_sets(sf: SourceFile, fnode) -> tuple[set[str], set[str]]:
    """(tainted data names, tainted callable names) for one function.

    Data taint: locals assigned from dispatch-shaped calls (name matches
    traverse/dispatch/run_batch/resume or a compiled-fn table lookup like
    ``self._mesh_fns[key]``). Callable taint: locals *bound to* such a
    callable; calls through them taint their targets too. Comprehension
    targets iterating a tainted name inherit the taint."""
    data: set[str] = set()
    fns: set[str] = set()
    for _ in range(3):  # tiny fixpoint: assignments are not in SSA order
        for node in ast.walk(fnode):
            if isinstance(node, ast.Assign):
                targets = [
                    t.id for t in node.targets if isinstance(t, ast.Name)
                ]
                for t in node.targets:
                    if isinstance(t, ast.Tuple):
                        targets += [
                            e.id for e in t.elts if isinstance(e, ast.Name)
                        ]
                if not targets:
                    continue
                v = node.value
                if isinstance(v, ast.Call):
                    fsrc = sf.segment(v.func)
                    fname = _dotted(v.func)
                    if _TAINT_SRC.search(fsrc) or (fname in fns):
                        data.update(targets)
                else:
                    if _TAINT_SRC.search(sf.segment(v)):
                        fns.update(targets)
            elif isinstance(
                node, (ast.GeneratorExp, ast.ListComp, ast.SetComp)
            ):
                for gen in node.generators:
                    if isinstance(gen.iter, ast.Name) and gen.iter.id in data:
                        if isinstance(gen.target, ast.Name):
                            data.add(gen.target.id)
    return data, fns


def _in_loop(node: ast.AST) -> bool:
    cur = getattr(node, "parent", None)
    while cur is not None and not isinstance(
        cur, (ast.FunctionDef, ast.AsyncFunctionDef)
    ):
        if isinstance(cur, (ast.For, ast.While)):
            return True
        cur = getattr(cur, "parent", None)
    return False


def _sync_kind(call: ast.Call) -> str | None:
    name = _dotted(call.func)
    if name in _SYNC_CALLS:
        return name
    f = call.func
    if isinstance(f, ast.Attribute) and f.attr in _SYNC_ATTRS:
        return f".{f.attr}()"
    return None


@rule("HOSTSYNC", _HELP_HOSTSYNC)
def check_hostsync(project: Project) -> list[Finding]:
    ix = _index_defs(project)
    # BFS the call graph from the hot roots.
    work = [
        (key, f"{key[1]}.{key[2]}")
        for key in ix.defs
        if (key[1], key[2]) in HOT_ROOTS
    ]
    seen = {key for key, _ in work}
    reachable: list[tuple[tuple, str]] = []
    while work:
        key, root = work.pop()
        reachable.append((key, root))
        sf, fnode = ix.defs[key]
        for nxt in _edges(ix, key[0], key[1], fnode):
            if nxt not in seen:
                seen.add(nxt)
                work.append((nxt, root))

    out: list[Finding] = []
    flagged: set[int] = set()
    for key, root in reachable:
        mod, cls, name = key
        if name in DRAIN_BOUNDARIES:
            continue
        sf, fnode = ix.defs[key]
        data, _fns = _taint_sets(sf, fnode)
        mat_seen: set[str] = set()
        for call in ast.walk(fnode):
            if not isinstance(call, ast.Call):
                continue
            kind = _sync_kind(call)
            if kind is not None:
                flagged.add(id(call))
                out.append(
                    Finding(
                        "HOSTSYNC",
                        sf.path,
                        call.lineno,
                        sf.scope_of(call),
                        f"`{kind}` in the dispatch hot path (reached from "
                        f"{root}) — stalls host/device overlap; move to a "
                        f"drain boundary or waive if timing-only",
                        snippet=sf.segment(call),
                    )
                )
                continue
            fname = _dotted(call.func)
            if fname in _MATERIALIZE or fname == "float":
                hit = next(
                    (
                        n.id
                        for a in call.args
                        for n in ast.walk(a)
                        if isinstance(n, ast.Name) and n.id in data
                    ),
                    None,
                )
                if hit is not None and hit not in mat_seen:
                    mat_seen.add(hit)  # one finding per materialized result
                    flagged.add(id(call))
                    out.append(
                        Finding(
                            "HOSTSYNC",
                            sf.path,
                            call.lineno,
                            sf.scope_of(call),
                            f"`{fname}` materializes dispatch result "
                            f"`{hit}` on the host (reached from {root}) — "
                            f"slice/reduce on-device, fetch at the drain "
                            f"boundary",
                            snippet=sf.segment(call),
                        )
                    )
    # Syncs inside explicit Python loops anywhere (training loops etc.).
    for sf in project.files:
        for call in ast.walk(sf.tree):
            if not isinstance(call, ast.Call) or id(call) in flagged:
                continue
            kind = _sync_kind(call)
            if kind is None or not _in_loop(call):
                continue
            fn = _func_of(call)
            if fn is not None and fn.name in DRAIN_BOUNDARIES:
                continue
            out.append(
                Finding(
                    "HOSTSYNC",
                    sf.path,
                    call.lineno,
                    sf.scope_of(call),
                    f"`{kind}` inside a Python loop — one device "
                    f"round-trip per iteration; batch the fetch or waive "
                    f"if the sync is the point (step timing)",
                    snippet=sf.segment(call),
                )
            )
    return out


# ---------------------------------------------------------------- NARROW

_HELP_NARROW = """\
Unguarded narrowing casts on bounds/docid/postings-shaped values. PR 6
shipped an int64 -> int32 BoundSum wrap that turned huge bounds negative
and silently disabled safe termination (`bound <= theta` held
everywhere). The repo idiom is `saturate_bounds` (serving/bucketing.py):
clip to INT32_MAX with a RuntimeWarning, raise on negative.

Flags `.astype(np.int32)` / `np.int32(x)` where the cast source or its
assignment target/keyword mentions bound/docid/docs/posting/budget/
maxdoc and the statement carries no clip/minimum/saturate/checked guard.
`dtype=np.int32` allocation kwargs are never flagged — fresh buffers
don't narrow anything. Fix with a saturating or checked cast; waive when
the value range is structurally proven elsewhere."""

_NARROW_SCOPE = ("core/", "serving/", "index_io/", "control/")
_WATCH = ("bound", "docid", "doc_id", "docs", "posting", "budget", "maxdoc")
_GUARD = ("clip", "minimum", "saturate", "checked", "iinfo")
_INT32 = {"np.int32", "numpy.int32", "jnp.int32"}


def _narrow_cast_expr(call: ast.Call) -> ast.AST | None:
    """The narrowed expression, if ``call`` is an int32 cast."""
    f = call.func
    if isinstance(f, ast.Attribute) and f.attr == "astype":
        targets = [a for a in call.args] + [
            k.value for k in call.keywords if k.arg == "dtype"
        ]
        for t in targets:
            if _dotted(t) in _INT32 or (
                isinstance(t, ast.Constant) and t.value == "int32"
            ):
                return f.value
        return None
    if _dotted(f) in _INT32 and len(call.args) == 1:
        a = call.args[0]
        return None if isinstance(a, ast.Constant) else a
    return None


@rule("NARROW", _HELP_NARROW)
def check_narrow(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for sf in project.files:
        if not _scoped(sf, _NARROW_SCOPE):
            continue
        for call in ast.walk(sf.tree):
            if not isinstance(call, ast.Call):
                continue
            expr = _narrow_cast_expr(call)
            if expr is None:
                continue
            names = sf.segment(expr).lower()
            cur: ast.AST = call
            while not isinstance(cur, ast.stmt):
                parent = cur.parent  # type: ignore[attr-defined]
                if isinstance(parent, ast.keyword) and parent.arg:
                    names += " " + parent.arg.lower()
                cur = parent
            stmt = cur
            if isinstance(stmt, ast.Assign):
                names += " " + " ".join(
                    sf.segment(t).lower() for t in stmt.targets
                )
            elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
                names += " " + sf.segment(stmt.target).lower()
            if not any(w in names for w in _WATCH):
                continue
            guard_ctx = sf.segment(stmt).lower() + " " + sf.scope_of(
                call
            ).lower()
            if any(g in guard_ctx for g in _GUARD):
                continue
            out.append(
                Finding(
                    "NARROW",
                    sf.path,
                    call.lineno,
                    sf.scope_of(call),
                    "unguarded int32 narrowing on a bounds/docid-shaped "
                    "value — values past 2^31-1 wrap negative (the PR 6 "
                    "safe-termination bug); use a saturating or checked "
                    "cast",
                    snippet=sf.segment(call),
                )
            )
    return out


# -------------------------------------------------------------- OBSGUARD

_HELP_OBSGUARD = """\
Telemetry calls in serving/control hot paths not dominated by an
`if obs.enabled` (or `prof is not None`) guard. The PR 8/9 acceptance
bar is <5% instrumentation overhead with obs *enabled* and bitwise-
identical results with obs *disabled*; an unguarded `obs.observe`/
`trace_span` in a drain loop pays dict/format cost per query even when
telemetry is off (NOOP attribute dispatch is cheap, argument
construction is not).

A call counts as guarded when an ancestor `if`/ternary mentions
`.enabled` or `is not None`, or an earlier top-level statement in the
same function is an `if ... enabled`/`is None` early-return. Fix by
hoisting the guard (or giving the helper an early return); waive only
for cold paths that merely live in a serving module."""

_OBS_SCOPE = ("serving/", "control/")
_OBS_METHODS = {
    "count",
    "observe",
    "gauge",
    "trace_begin",
    "trace_span",
    "trace_attr",
    "trace_end",
    "record_dispatch",
    "record_hbm_once",
}
_OBS_RECEIVER = re.compile(r"(^|\.)(obs|prof|profiler|metrics|tracer)$")
_GUARD_TEST = ("enabled", "is not None", "is None")


def _guarded(sf: SourceFile, call: ast.Call) -> bool:
    cur = getattr(call, "parent", None)
    while cur is not None and not isinstance(
        cur, (ast.FunctionDef, ast.AsyncFunctionDef)
    ):
        if isinstance(cur, (ast.If, ast.IfExp)):
            test = sf.segment(cur.test)
            if any(g in test for g in _GUARD_TEST):
                return True
        cur = getattr(cur, "parent", None)
    fn = cur
    if fn is None:
        return False
    # Early-return guard: `if not obs.enabled: return` before this stmt.
    top: ast.AST = call
    while getattr(top, "parent", None) is not fn:
        top = top.parent  # type: ignore[attr-defined]
    for stmt in fn.body:
        if stmt is top:
            break
        if isinstance(stmt, ast.If):
            test = sf.segment(stmt.test)
            has_return = any(
                isinstance(s, ast.Return) for s in ast.walk(stmt)
            )
            if has_return and any(g in test for g in _GUARD_TEST):
                return True
    return False


@rule("OBSGUARD", _HELP_OBSGUARD)
def check_obsguard(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for sf in project.files:
        if not _scoped(sf, _OBS_SCOPE):
            continue
        for call in ast.walk(sf.tree):
            if not isinstance(call, ast.Call):
                continue
            f = call.func
            if not (
                isinstance(f, ast.Attribute) and f.attr in _OBS_METHODS
            ):
                continue
            receiver = _dotted(f.value)
            if receiver is None or not _OBS_RECEIVER.search(receiver):
                continue
            if _guarded(sf, call):
                continue
            out.append(
                Finding(
                    "OBSGUARD",
                    sf.path,
                    call.lineno,
                    sf.scope_of(call),
                    f"`{receiver}.{f.attr}(...)` not dominated by an "
                    f"`if obs.enabled` guard — pays instrumentation cost "
                    f"per call even with telemetry off; hoist the guard "
                    f"or add an early return",
                    snippet=sf.segment(call),
                )
            )
    return out


# -------------------------------------------------------------- ARTIFACT

_HELP_ARTIFACT = """\
Durable writes without the staged-tmp + rename-aside idiom. The repo's
publish discipline (index_io/artifact.py, control/journal.py,
obs/trace.py): build under a unique `*.tmp-*` staging dir, `os.replace`
into place so readers never observe a half-written artifact; append-mode
journals fsync per record so a replay never sees a torn tail.

Flags `open(path, "w"/"a")` / `np.save*` in artifact-producing modules
when the enclosing function (or class) neither replaces/renames nor
fsyncs, and the path is not itself a tmp-stage. Fix by writing to
`path + ".tmp"` and `os.replace`-ing; waive for genuinely ephemeral
output (debug dumps, stdout mirrors)."""

_ART_SCOPE = (
    "index_io/",
    "control/",
    "obs/",
    "launch/",
    "train/",
    "serving/",
)
_NP_WRITERS = {
    "np.save",
    "np.savez",
    "np.savez_compressed",
    "np.savetxt",
    "numpy.save",
    "numpy.savez",
}


def _write_mode(call: ast.Call) -> str | None:
    mode = None
    if len(call.args) >= 2 and isinstance(call.args[1], ast.Constant):
        mode = call.args[1].value
    for kw in call.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
            mode = kw.value.value
    if isinstance(mode, str) and any(c in mode for c in "wax"):
        return mode
    return None


@rule("ARTIFACT", _HELP_ARTIFACT)
def check_artifact(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for sf in project.files:
        if not _scoped(sf, _ART_SCOPE):
            continue
        for call in ast.walk(sf.tree):
            if not isinstance(call, ast.Call):
                continue
            name = _dotted(call.func)
            if name == "open":
                mode = _write_mode(call)
                if mode is None:
                    continue
            elif name in _NP_WRITERS:
                mode = "w"
            else:
                continue
            if not call.args:
                continue
            path_src = sf.segment(call.args[0]).lower()
            if "tmp" in path_src or "devnull" in path_src:
                continue  # this *is* the staged write
            fn = _func_of(call)
            ctx = sf.segment(fn) if fn is not None else ""
            cls = _class_of(call)
            cls_src = sf.segment(cls) if cls is not None else sf.text
            if "os.replace" in ctx or "os.rename" in ctx:
                continue
            if "fsync" in ctx or ("a" in mode and "fsync" in cls_src):
                continue
            out.append(
                Finding(
                    "ARTIFACT",
                    sf.path,
                    call.lineno,
                    sf.scope_of(call),
                    f"durable write ({name}, mode={mode!r}) without "
                    f"staged-tmp + os.replace (or fsync for journals) — "
                    f"a crash mid-write publishes a torn file; stage to "
                    f"`*.tmp` and rename into place",
                    snippet=sf.segment(call),
                )
            )
    return out


# ----------------------------------------------------------- PALLASCONST

_HELP_PALLASCONST = """\
Pallas kernels using Python control flow on tracer values, or
`pallas_call` grids/BlockSpecs built from non-static parameters. Inside
a kernel body every Ref read is a tracer: a Python `if ref[0] > 0:`
either fails to trace or bakes one branch in permanently — use
`pl.when`/`lax.cond`; `for` must iterate `range()` over trace-time
constants (or move to `lax.fori_loop`). Grid and BlockSpec shapes must
come from `static_argnames` parameters or array shapes, never traced
values, or every call re-specializes the kernel (the PR 9 recompile
class, at Pallas cost).

See /opt/skills/guides for the accelerator-side rationale. Waive when a
Python branch is provably on a trace-time constant the heuristic cannot
see."""

_PALLAS_SCOPE = ("kernels/",)


def _kernel_defs(sf: SourceFile) -> list[ast.FunctionDef]:
    by_name = {
        n.name: n for n in ast.walk(sf.tree) if isinstance(n, ast.FunctionDef)
    }
    kernels = {
        n for name, n in by_name.items() if name.endswith("_kernel")
    }
    for call in ast.walk(sf.tree):
        if (
            isinstance(call, ast.Call)
            and (_dotted(call.func) or "").endswith("pallas_call")
            and call.args
            and isinstance(call.args[0], ast.Name)
            and call.args[0].id in by_name
        ):
            kernels.add(by_name[call.args[0].id])
    return sorted(kernels, key=lambda n: n.lineno)


@rule("PALLASCONST", _HELP_PALLASCONST)
def check_pallasconst(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for sf in project.files:
        if not _scoped(sf, _PALLAS_SCOPE):
            continue
        jits = collect_jits(sf)
        for kern in _kernel_defs(sf):
            params = set(_params_of(kern))
            for sub in ast.walk(kern):
                if isinstance(sub, (ast.If, ast.While)):
                    hits = _value_refs(sub.test, params)
                    if hits:
                        out.append(
                            Finding(
                                "PALLASCONST",
                                sf.path,
                                sub.lineno,
                                sf.scope_of(sub),
                                f"Python control flow on kernel Ref/param "
                                f"{sorted(set(hits))} — tracers cannot "
                                f"drive `if`/`while`; use pl.when or "
                                f"lax.cond",
                                snippet=sf.segment(sub.test),
                            )
                        )
                elif isinstance(sub, ast.For):
                    it = sub.iter
                    is_range = isinstance(it, ast.Call) and _dotted(
                        it.func
                    ) in {"range"}
                    if not is_range and _value_refs(it, params):
                        out.append(
                            Finding(
                                "PALLASCONST",
                                sf.path,
                                sub.lineno,
                                sf.scope_of(sub),
                                "Python `for` over a kernel Ref — use "
                                "lax.fori_loop with a static trip count",
                                snippet=sf.segment(it),
                            )
                        )
        # Grid/BlockSpec staticness inside jit'd wrappers.
        for call in ast.walk(sf.tree):
            if not (
                isinstance(call, ast.Call)
                and (_dotted(call.func) or "").endswith("pallas_call")
            ):
                continue
            fn = _func_of(call)
            jf = jits.get(fn.name) if fn is not None else None
            if jf is None or jf.node is not fn:
                continue
            nonstatic = set(jf.params) - jf.static
            locals_map = {
                t.id: a.value
                for a in ast.walk(fn)
                if isinstance(a, ast.Assign)
                for t in a.targets
                if isinstance(t, ast.Name)
            }
            spec_exprs = [
                kw.value
                for kw in call.keywords
                if kw.arg in {"grid", "in_specs", "out_specs", "out_shape"}
            ]
            for expr in spec_exprs:
                bad: set[str] = set()
                for nm in _value_refs(expr, nonstatic):
                    bad.add(nm)
                for n in ast.walk(expr):
                    if (
                        isinstance(n, ast.Name)
                        and n.id in locals_map
                        and not _is_static_use(n, expr)
                    ):
                        bad.update(
                            _value_refs(locals_map[n.id], nonstatic)
                        )
                if bad:
                    out.append(
                        Finding(
                            "PALLASCONST",
                            sf.path,
                            call.lineno,
                            sf.scope_of(call),
                            f"pallas_call grid/spec depends on non-static "
                            f"parameter(s) {sorted(bad)} — every call "
                            f"re-specializes the kernel; add to "
                            f"static_argnames or derive from shapes",
                            snippet=sf.segment(expr),
                        )
                    )
    return out
