"""Data model for the static-hazard analyzer (DESIGN.md §15).

A :class:`Finding` is one rule violation at one source location. Its
``key`` deliberately excludes the line number: it hashes the rule, the
file, the enclosing scope, and the *normalized source snippet*, so the
committed ``analysis_baseline.json`` ratchet survives unrelated edits
above a finding but invalidates (as "stale") when the flagged code is
actually changed or removed.

Inline waivers use the comment marker ``# analysis: allow[RULE]`` (or
``allow[RULE1,RULE2]``), placed on the flagged line or the line directly
above it. Waivers are extracted with :mod:`tokenize` so strings that
merely *look* like comments never waive anything.
"""

from __future__ import annotations

import ast
import hashlib
import io
import re
import tokenize
from dataclasses import dataclass, field

__all__ = ["Finding", "Project", "SourceFile", "WAIVER_RE"]

WAIVER_RE = re.compile(r"analysis:\s*allow\[([A-Za-z0-9_,\s]+)\]")


def _hash8(text: str) -> str:
    """First 8 hex chars of the whitespace-normalized snippet hash."""
    norm = " ".join(text.split())
    return hashlib.sha1(norm.encode("utf-8")).hexdigest()[:8]


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str
    line: int
    scope: str
    message: str
    snippet: str = ""

    @property
    def key(self) -> str:
        """Stable baseline key: line-number-free, snippet-hashed."""
        return (
            f"{self.rule}:{self.path}:{self.scope}:"
            f"{_hash8(self.snippet or self.message)}"
        )

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "scope": self.scope,
            "message": self.message,
        }

    def render(self) -> str:
        return (
            f"{self.path}:{self.line}: [{self.rule}] {self.scope}: "
            f"{self.message}"
        )


def _parse_waivers(text: str) -> dict[int, set[str]]:
    """Map line number -> set of waived rule names, via real comment tokens.

    A trailing waiver covers its own line. A waiver inside a comment-only
    block also covers the first code line after the block, so multi-line
    rationale comments above the flagged statement work naturally.
    """
    waivers: dict[int, set[str]] = {}
    lines = text.splitlines()

    def _comment_only(line_no: int) -> bool:
        if not 1 <= line_no <= len(lines):
            return False
        stripped = lines[line_no - 1].strip()
        return not stripped or stripped.startswith("#")

    try:
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type != tokenize.COMMENT:
                continue
            m = WAIVER_RE.search(tok.string)
            if not m:
                continue
            rules = {
                r.strip().upper() for r in m.group(1).split(",") if r.strip()
            }
            line = tok.start[0]
            waivers.setdefault(line, set()).update(rules)
            if _comment_only(line):
                nxt = line + 1
                while _comment_only(nxt) and nxt <= len(lines):
                    nxt += 1
                waivers.setdefault(nxt, set()).update(rules)
    except tokenize.TokenError:
        pass
    return waivers


class SourceFile:
    """A parsed source file: AST with parent links, waivers, snippets."""

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.tree = ast.parse(text)
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                child.parent = parent  # type: ignore[attr-defined]
        self.waivers = _parse_waivers(text)

    def segment(self, node: ast.AST) -> str:
        return ast.get_source_segment(self.text, node) or ""

    def scope_of(self, node: ast.AST) -> str:
        """Dotted enclosing class/function chain, or ``<module>``."""
        parts: list[str] = []
        cur: ast.AST | None = node
        while cur is not None:
            if isinstance(
                cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ):
                parts.append(cur.name)
            cur = getattr(cur, "parent", None)
        return ".".join(reversed(parts)) or "<module>"

    def is_waived(self, rule: str, line: int) -> bool:
        """Waiver on the flagged line or the line directly above it."""
        return rule in self.waivers.get(line, ()) or rule in self.waivers.get(
            line - 1, ()
        )


@dataclass
class Project:
    """The unit checkers operate on: every parsed file under the scan root."""

    files: list[SourceFile] = field(default_factory=list)

    def by_path(self, path: str) -> SourceFile | None:
        for sf in self.files:
            if sf.path == path:
                return sf
        return None
