"""Checker-plugin registry for the static-hazard analyzer (DESIGN.md §15).

Mirrors the ``obs/catalog.py`` discipline: every rule registers with a
non-empty help string, ``python -m repro.analysis explain <RULE>`` prints
it, and ``missing_help()`` lets a meta-test keep the catalog total. A new
checker is one decorated function::

    @rule("MYRULE", "What it catches, why it matters, how to fix/waive.")
    def check_myrule(project: Project) -> list[Finding]:
        ...

The check callable receives the whole :class:`~repro.analysis.model.Project`
(cross-file rules like HOSTSYNC need the call graph); per-file rules just
loop over ``project.files``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List

from repro.analysis.model import Finding, Project

__all__ = ["RULES", "Rule", "help_for", "missing_help", "rule"]


@dataclass(frozen=True)
class Rule:
    name: str
    help: str
    check: Callable[[Project], List[Finding]]


RULES: dict[str, Rule] = {}


def rule(name: str, help: str):
    """Register a checker under ``name`` with mandatory help text."""

    def deco(fn: Callable[[Project], List[Finding]]):
        RULES[name] = Rule(name=name, help=help, check=fn)
        return fn

    return deco


def help_for(name: str) -> str:
    """Help text for one rule; raises ``KeyError`` on unknown rules."""
    return RULES[name.upper()].help


def missing_help() -> list[str]:
    """Registered rules with empty help — must stay ``[]`` (meta-test)."""
    return sorted(n for n, r in RULES.items() if not r.help.strip())
