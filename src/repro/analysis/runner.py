"""Drive the registered checkers over a source tree (DESIGN.md §15).

``analyze_paths`` walks ``.py`` files, parses them into a
:class:`~repro.analysis.model.Project`, runs every registered rule, and
splits the results into active findings and waived ones (inline
``# analysis: allow[RULE]`` on the flagged line or the line above).
Paths are stored relative to ``rel_to`` (default: the current working
directory) so baseline keys are stable: run from the repo root they read
``src/repro/serving/inflight.py``.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field

from repro.analysis.model import Finding, Project, SourceFile
from repro.analysis.registry import RULES

__all__ = ["AnalysisReport", "analyze_paths", "analyze_source", "count_findings"]


@dataclass
class AnalysisReport:
    findings: list[Finding] = field(default_factory=list)
    waived: list[Finding] = field(default_factory=list)
    files: int = 0

    @property
    def by_rule(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out

    def to_json(self) -> dict:
        return {
            "files": self.files,
            "count": len(self.findings),
            "waived": len(self.waived),
            "by_rule": self.by_rule,
            "findings": [
                dict(f.to_json(), key=f.key) for f in self.findings
            ],
            "waivers": [
                dict(f.to_json(), key=f.key) for f in self.waived
            ],
        }


def _walk_py(paths: list[str]) -> list[str]:
    out: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [
                d for d in dirnames if not d.startswith((".", "__pycache__"))
            ]
            out.extend(
                os.path.join(dirpath, f)
                for f in filenames
                if f.endswith(".py")
            )
    return sorted(set(out))


def load_project(paths: list[str], rel_to: str | None = None) -> Project:
    rel_to = rel_to or os.getcwd()
    files: list[SourceFile] = []
    for fp in _walk_py(paths):
        with open(fp, encoding="utf-8") as fh:
            text = fh.read()
        try:
            rel = os.path.relpath(fp, rel_to)
        except ValueError:  # different drive (windows)
            rel = fp
        if rel.startswith(".."):
            rel = fp
        files.append(SourceFile(rel.replace(os.sep, "/"), text))
    return Project(files=files)


def analyze_project(project: Project) -> AnalysisReport:
    report = AnalysisReport(files=len(project.files))
    by_path = {sf.path: sf for sf in project.files}
    findings: list[Finding] = []
    for r in RULES.values():
        findings.extend(r.check(project))
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        sf = by_path.get(f.path)
        if sf is not None and sf.is_waived(f.rule, f.line):
            report.waived.append(f)
        else:
            report.findings.append(f)
    return report


def analyze_paths(
    paths: list[str], rel_to: str | None = None
) -> AnalysisReport:
    return analyze_project(load_project(paths, rel_to=rel_to))


def analyze_source(text: str, path: str = "fixture.py") -> AnalysisReport:
    """Analyze one in-memory snippet — the unit-test entry point.

    ``path`` participates in rule scoping: name it e.g.
    ``serving/fixture.py`` to put the snippet inside OBSGUARD's scope.
    """
    return analyze_project(Project(files=[SourceFile(path, text)]))


def count_findings(root: str = "src/repro") -> dict:
    """Compact finding counts for the benchmark trajectory (perf_gate)."""
    rep = analyze_paths([root])
    return {
        "count": len(rep.findings),
        "waived": len(rep.waived),
        "by_rule": rep.by_rule,
    }
