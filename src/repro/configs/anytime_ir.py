"""The paper's own system as a dry-run architecture: ``anytime-ir``.

One 256-chip node = 16 ISN shards (model axis) x 16-way query parallelism
(data axis); multi-pod doubles query throughput (pod axis = replication —
§7 of the paper). Sizes model a web-scale node: 64M docs / 4B postings
across shards, 256 topical ranges, 256-query batches, k=10.

The serve step is serve/distributed_ir.make_sharded_query_fn — per-shard
anytime traversal (postings budget = the per-ISN SLA quantum) + the broker
all_gather merge.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import Arch, ShapeInfo
from repro.distributed.sharding import ShardCtx
from repro.serve import distributed_ir as dir_mod

_FULL = dict(
    n_queries=256, n_shards=16, r_loc=16, b_width=512,
    nnz_loc=256_000_000, nb_loc=2_097_152, s_pad=262_144, k=10,
)
_REDUCED = dict(
    n_queries=8, n_shards=1, r_loc=8, b_width=64,
    nnz_loc=65_536, nb_loc=2048, s_pad=1024, k=10,
)


class AnytimeIRArch(Arch):
    name = "anytime-ir"
    family = "ir"
    # "i8": impacts stored at their native quantized width (int8) — the
    # paper quantizes to 8 bits anyway; storing them at int32 (baseline)
    # wastes 3 bytes/posting of HBM traffic. §Perf cell C.
    variants = ("baseline", "i8")

    def shapes(self):
        return {
            "serve_anytime": ShapeInfo(
                "serve_anytime", "serve",
                "256-query batch, 16 ISN shards, SLA postings budget",
            ),
            "serve_exhaustive": ShapeInfo(
                "serve_exhaustive", "serve",
                "same, unlimited budget (rank-safe baseline)",
            ),
        }

    def model_config(self, reduced: bool = False):
        return dict(_REDUCED if reduced else _FULL)

    def init_params(self, key, cfg):
        del key
        # "Params" = the sharded index arrays (stateless serving).
        arrays, _ = dir_mod.sharded_query_specs(**cfg)
        return arrays

    def param_shapes(self, cfg):
        return self.init_params(None, cfg)

    def _with_variant(self, cfg, variant):
        import jax.numpy as jnp

        if variant == "i8":
            return dict(cfg, impact_dtype=jnp.int8)
        return cfg

    def input_specs(self, cfg, shape):
        _, tables = dir_mod.sharded_query_specs(**cfg)
        return {"tables": tables}

    def make_batch(self, cfg, shape, seed: int = 0):
        raise NotImplementedError(
            "anytime-ir smoke coverage lives in tests/test_distributed_ir.py "
            "(real index build + oracle comparison)"
        )

    def build_step(self, cfg, shape, shard_ctx: ShardCtx | None = None,
                   variant: str = "baseline"):
        del variant  # the step is dtype-agnostic (int8 widens on gather)
        budget = 2**31 - 1 if shape == "serve_exhaustive" else cfg["nnz_loc"] // 64
        if shard_ctx is None:
            mesh = jax.make_mesh((1, 1), ("data", "model"))
            shard_ctx = ShardCtx(mesh=mesh, data_axes=("data",), model_axis="model")
        fn = dir_mod.make_sharded_query_fn(
            shard_ctx, s_pad=cfg["s_pad"], k=cfg["k"], budget=budget
        )

        def step(arrays, batch):
            return fn(arrays, batch["tables"])

        return step, "serve"

    def param_pspecs(self, cfg, params, variant: str = "baseline", ctx=None):
        del variant, ctx
        m = "model"
        return dir_mod.ShardedIndexArrays(
            docs=P(m, None), impacts=P(m, None), blk_start=P(m, None),
            blk_len=P(m, None), blk_maximp=P(m, None), range_starts=P(m, None),
            doc_base=P(m), s_pad=cfg["s_pad"], k=cfg["k"],
        )

    def batch_pspecs(self, cfg, shape, ctx: ShardCtx, variant: str = "baseline"):
        del variant
        da = ctx.data_axes
        m = ctx.model_axis
        return {
            "tables": (
                P(da, m, None, None), P(da, m, None, None),
                P(da, m, None), P(da, m, None),
            )
        }
