"""Uniform architecture interface: configs -> params/steps/specs.

Every assigned architecture is an ``Arch`` with:
  * ``model_config(reduced)``  — exact published config, or a reduced
    same-family config for CPU smoke tests;
  * ``shapes()``               — its assigned input-shape cells (kind =
    train | prefill | decode | serve | retrieval; ``skip`` marks cells the
    instructions exclude, e.g. long_500k on full-attention LMs);
  * ``input_specs(cfg, shape)``— global ShapeDtypeStructs for the dry-run;
  * ``make_batch(cfg, shape)`` — real (small) arrays for smoke tests;
  * ``build_step(cfg, shape)`` — the jittable train/serve step;
  * ``param_pspecs`` / ``batch_pspecs`` — PartitionSpecs for the mesh.

The dry-run lowers ``build_step`` with ``input_specs`` under the production
mesh; smoke tests run the same step eagerly with ``make_batch``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import ShardCtx
from repro.models import gnn as gnn_mod
from repro.models import recsys as rec_mod
from repro.models import transformer as tfm
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import make_train_step

__all__ = ["ShapeInfo", "Arch", "LMArch", "GNNArch", "RecArch"]


@dataclasses.dataclass(frozen=True)
class ShapeInfo:
    name: str
    kind: str  # "train" | "prefill" | "decode" | "serve" | "retrieval"
    desc: str
    skip: Optional[str] = None  # reason, if this cell is excluded


class Arch:
    name: str = ""
    family: str = ""

    def shapes(self) -> dict[str, ShapeInfo]:
        raise NotImplementedError

    def model_config(self, reduced: bool = False):
        raise NotImplementedError

    def init_params(self, key, cfg):
        raise NotImplementedError

    def param_shapes(self, cfg):
        """ShapeDtypeStruct pytree of the params (no allocation)."""
        return jax.eval_shape(lambda: self.init_params(jax.random.key(0), cfg))

    def input_specs(self, cfg, shape: str):
        raise NotImplementedError

    def make_batch(self, cfg, shape: str, seed: int = 0):
        raise NotImplementedError

    def build_step(self, cfg, shape: str, shard_ctx: ShardCtx | None = None):
        raise NotImplementedError

    def param_pspecs(self, cfg, params):
        raise NotImplementedError

    def batch_pspecs(self, cfg, shape: str, ctx: ShardCtx):
        raise NotImplementedError

    def moment_dtype(self, cfg) -> str:
        return "fp32"

    def model_flops_per_token(self, cfg) -> float:
        """6*N (dense) / 6*N_active (MoE) — §Roofline MODEL_FLOPS basis."""
        return 0.0


# ---------------------------------------------------------------------- LM


_LM_SHAPES = {
    "train_4k": ShapeInfo("train_4k", "train", "seq 4096, global batch 256"),
    "prefill_32k": ShapeInfo("prefill_32k", "prefill", "seq 32768, batch 32"),
    "decode_32k": ShapeInfo(
        "decode_32k", "decode", "1 new token, KV len 32768, batch 128"
    ),
    "long_500k": ShapeInfo(
        "long_500k",
        "decode",
        "seq 524288, batch 1",
        skip="pure full-attention arch: O(n^2) softmax attention; sub-quadratic "
        "attention required for 500k decode (DESIGN.md §5)",
    ),
}

_LM_DIMS = {
    "train_4k": dict(batch=256, seq=4096),
    "prefill_32k": dict(batch=32, seq=32768),
    "decode_32k": dict(batch=128, seq=32768),
    "long_500k": dict(batch=1, seq=524288),
}
_LM_REDUCED_DIMS = {
    "train_4k": dict(batch=2, seq=64),
    "prefill_32k": dict(batch=2, seq=64),
    "decode_32k": dict(batch=2, seq=64),
    "long_500k": dict(batch=1, seq=64),
}


class LMArch(Arch):
    family = "lm"

    def __init__(self, name: str, full_cfg: Callable[[], tfm.LMConfig],
                 reduced_cfg: Callable[[], tfm.LMConfig], moments: str = "fp32",
                 fsdp: bool = False):
        self.name = name
        self._full = full_cfg
        self._reduced = reduced_cfg
        self._moments = moments
        # FSDP-style param sharding over the data axis (in addition to TP):
        # required when N_params * 2B / n_model exceeds per-chip HBM
        # (deepseek-67b, deepseek-v3-671b, moonshot). GSPMD inserts the
        # per-layer all-gathers inside the scan.
        self.fsdp = fsdp

    def shapes(self):
        return dict(_LM_SHAPES)

    def model_config(self, reduced: bool = False):
        return self._reduced() if reduced else self._full()

    def init_params(self, key, cfg):
        return tfm.init_lm(key, cfg)

    def moment_dtype(self, cfg):
        return self._moments

    def model_flops_per_token(self, cfg):
        total, active = tfm.count_params(cfg)
        del total
        return 6.0 * active

    def _dims(self, cfg, shape):
        table = _LM_DIMS if cfg.max_seq > 1024 else _LM_REDUCED_DIMS
        return table[shape]

    def input_specs(self, cfg, shape):
        d = self._dims(cfg, shape)
        B, S = d["batch"], d["seq"]
        tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
        if shape == "train_4k":
            return {"tokens": tok(B, S)}
        if shape == "prefill_32k":
            return {"tokens": tok(B, S)}
        if shape in ("decode_32k", "long_500k"):
            cache = jax.eval_shape(lambda: tfm.init_cache(cfg, B, S))
            return {
                "tokens": tok(B, 1),
                "cache": cache,
                "pos": jax.ShapeDtypeStruct((), jnp.int32),
            }
        raise KeyError(shape)

    def make_batch(self, cfg, shape, seed: int = 0):
        d = self._dims(cfg, shape)
        B, S = d["batch"], d["seq"]
        rng = np.random.default_rng(seed)
        if shape in ("train_4k", "prefill_32k"):
            return {
                "tokens": rng.integers(0, cfg.vocab, size=(B, S)).astype(np.int32)
            }
        cache = tfm.init_cache(cfg, B, S)
        return {
            "tokens": rng.integers(0, cfg.vocab, size=(B, 1)).astype(np.int32),
            "cache": cache,
            "pos": np.int32(S // 2),
        }

    variants = ("baseline", "split_kv")

    def build_step(self, cfg, shape, shard_ctx=None, variant: str = "baseline"):
        if shape == "train_4k":
            loss = lambda p, b: tfm.lm_loss(p, b["tokens"], cfg, shard_ctx)
            return make_train_step(
                loss, AdamWConfig(moment_dtype=self._moments)
            ), "train"

        if shape == "prefill_32k":
            # CHUNKED prefill (Sarathi-style): scan over chunks with the
            # cache as carry. Single-shot 32k prefill peaks at 86 GiB/device
            # on deepseek-v3 — chunking bounds the live working set.
            # variant split_kv additionally seq-shards the cache (GQA archs:
            # chunk == per-rank slice, sequence-parallel partial-softmax
            # attention) so prefill and decode share one serving layout —
            # deepseek-67b's 25.5 GiB/device batch-sharded cache becomes
            # 1.6 GiB (§Perf cell A).
            impl = "split_kv" if variant == "split_kv" else "batch"

            def prefill(params, batch):
                B, S = batch["tokens"].shape
                cache = tfm.init_cache(cfg, B, S)
                if impl == "split_kv" and not isinstance(cfg.attn, tfm.MLAConfig):
                    ch = S // (shard_ctx.n_model if shard_ctx else 1)
                else:
                    ch = min(4096, S)
                nc = S // ch
                chunks = batch["tokens"].reshape(B, nc, ch).transpose(1, 0, 2)

                def body(cache, inp):
                    idx, toks = inp
                    logits, cache = tfm.lm_decode_step(
                        params, toks, cache, idx * ch, cfg, shard_ctx,
                        logits_last_only=True, decode_impl=impl,
                    )
                    return cache, logits

                cache, logits = jax.lax.scan(
                    body, cache, (jnp.arange(nc), chunks)
                )
                return logits[-1], cache
            return prefill, "serve"

        impl = "split_kv" if variant == "split_kv" else "batch"

        def decode(params, batch):
            return tfm.lm_decode_step(
                params, batch["tokens"], batch["cache"], batch["pos"], cfg,
                shard_ctx, logits_last_only=True, decode_impl=impl,
            )
        return decode, "serve"

    def param_pspecs(self, cfg, params, variant: str = "baseline", ctx=None):
        del params
        if variant == "split_kv":
            ep_grid_ok = bool(
                cfg.moe is not None
                and ctx is not None
                and cfg.moe.n_experts
                % (ctx.mesh.shape["data"] * ctx.n_model) == 0
            )
            return tfm.param_specs_splitkv(cfg, ep_grid_ok=ep_grid_ok)
        return tfm.param_specs(cfg)

    def batch_pspecs(self, cfg, shape, ctx: ShardCtx, variant: str = "baseline"):
        da = ctx.data_axes
        if shape in ("train_4k", "prefill_32k"):
            return {"tokens": P(da, None)}
        layout = "split" if variant == "split_kv" else "batch"
        return {
            "tokens": P(da, None),
            "cache": tfm.cache_specs(cfg, da, layout),
            "pos": P(),
        }


# --------------------------------------------------------------------- GNN


class GNNArch(Arch):
    family = "gnn"

    _SHAPES = {
        "full_graph_sm": ShapeInfo(
            "full_graph_sm", "train", "full-batch, 2708 nodes / 10556 edges"
        ),
        "minibatch_lg": ShapeInfo(
            "minibatch_lg", "train", "sampled 1024-node batch, fanout 15-10"
        ),
        "ogb_products": ShapeInfo(
            "ogb_products", "train", "full-batch 2.45M nodes / 61.9M edges"
        ),
        "molecule": ShapeInfo(
            "molecule", "train", "128 graphs x 30 nodes, graph classification"
        ),
    }

    # NOTE: edge counts are padded up to multiples of 512 (= pod*data*model
    # worst case) so edge arrays shard evenly; padding edges use src=-1 and
    # are dropped by the masked aggregation.
    _DIMS = {
        "full_graph_sm": dict(nodes=2708, edges=10752, d=1433, classes=7),
        "minibatch_lg": dict(
            nodes=180224, edges1=15360, edges2=163840, d=602, classes=41,
            batch=1024,
        ),
        "ogb_products": dict(nodes=2449029, edges=61860352, d=100, classes=47),
        "molecule": dict(batch=128, n_nodes=30, n_edges=64, d=64, classes=32),
    }
    _DIMS_REDUCED = {
        "full_graph_sm": dict(nodes=200, edges=800, d=32, classes=7),
        "minibatch_lg": dict(
            nodes=500, edges1=64, edges2=320, d=32, classes=8, batch=16
        ),
        "ogb_products": dict(nodes=400, edges=1600, d=16, classes=8),
        "molecule": dict(batch=8, n_nodes=10, n_edges=20, d=16, classes=4),
    }

    variants = ("baseline", "sharded")

    def __init__(self, name: str):
        self.name = name

    def shapes(self):
        return dict(self._SHAPES)

    def model_config(self, reduced: bool = False):
        return gnn_mod.SAGEConfig(
            n_layers=2,
            d_in=-1,  # resolved per shape
            d_hidden=32 if reduced else 128,
            n_classes=-1,
            sample_sizes=(25, 10),
        )

    @staticmethod
    def _pad512(n: int) -> int:
        return (n + 511) // 512 * 512

    def _dims(self, cfg, shape):
        return (self._DIMS_REDUCED if cfg.d_hidden < 128 else self._DIMS)[shape]

    def _resolved(self, cfg, shape):
        d = self._dims(cfg, shape)
        return dataclasses.replace(cfg, d_in=d["d"], n_classes=d["classes"])

    def init_params(self, key, cfg_shape):
        return gnn_mod.init_sage(key, cfg_shape)

    def input_specs(self, cfg, shape, variant: str = "baseline"):
        d = self._dims(cfg, shape)
        f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
        i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
        if shape in ("full_graph_sm", "ogb_products"):
            if variant == "sharded":
                # Nodes padded to a 512 multiple (even shards); edges binned
                # by dst-owner with 1.3x per-bin headroom (-1 padding);
                # agg0 = precomputed first-hop mean aggregate (SIGN trick).
                n_pad = self._pad512(d["nodes"])
                e_pad = self._pad512(int(d["edges"] * 1.3))
                return {
                    "feats": f32(n_pad, d["d"]),
                    "agg0": f32(n_pad, d["d"]),
                    "edges": i32(e_pad, 2),
                    "labels": i32(n_pad),
                }
            return {
                "feats": f32(d["nodes"], d["d"]),
                "edges": i32(d["edges"], 2),
                "labels": i32(d["nodes"]),
            }
        if shape == "minibatch_lg":
            return {
                "feats": f32(d["nodes"], d["d"]),
                "hop0_src": i32(d["edges1"]), "hop0_dst": i32(d["edges1"]),
                "hop1_src": i32(d["edges2"]), "hop1_dst": i32(d["edges2"]),
                "labels": i32(d["batch"]),
            }
        return {
            "feats": f32(d["batch"] * d["n_nodes"], d["d"]),
            "edges": i32(d["batch"] * d["n_edges"], 2),
            "graph_ids": i32(d["batch"] * d["n_nodes"]),
            "labels": i32(d["batch"]),
        }

    def make_batch(self, cfg, shape, seed: int = 0):
        from repro.data import graphs as G

        d = self._dims(cfg, shape)
        if shape in ("full_graph_sm", "ogb_products"):
            g = G.make_graph(d["nodes"], d["edges"] - 8, d["d"], d["classes"], seed)
            edges = np.full((d["edges"], 2), -1, np.int32)
            edges[: g.edges.shape[0]] = g.edges  # tail = -1 padding
            return {"feats": g.feats, "edges": edges, "labels": g.labels}
        if shape == "minibatch_lg":
            g = G.make_graph(d["nodes"], max(d["edges2"], 4 * d["nodes"]), d["d"],
                             d["classes"], seed)
            ptr, nbrs = G.to_csr(g.n_nodes, g.edges)
            rng = np.random.default_rng(seed)
            batch = rng.choice(g.n_nodes, size=d["batch"], replace=False)
            sub = G.sample_subgraph(ptr, nbrs, g.feats, g.labels, batch, (15, 10), seed)
            feats = np.zeros((d["nodes"], d["d"]), np.float32)
            feats[: sub["feats"].shape[0]] = sub["feats"][: d["nodes"]]
            def pad(a, n):
                out = np.full(n, -1, np.int32)
                out[: min(len(a), n)] = a[:n]
                return out
            return {
                "feats": feats,
                "hop0_src": pad(sub["hops"][0][0], d["edges1"]),
                "hop0_dst": pad(sub["hops"][0][1], d["edges1"]),
                "hop1_src": pad(sub["hops"][1][0], d["edges2"]),
                "hop1_dst": pad(sub["hops"][1][1], d["edges2"]),
                "labels": sub["labels"],
            }
        feats, edges, gids, labels = G.make_molecule_batch(
            d["batch"], d["n_nodes"], d["n_edges"], d["d"], d["classes"], seed
        )
        return {"feats": feats, "edges": edges, "graph_ids": gids, "labels": labels}

    def build_step(self, cfg, shape, shard_ctx=None, variant: str = "baseline"):
        rcfg = self._resolved(cfg, shape)

        if shape in ("full_graph_sm", "ogb_products"):
            if variant == "sharded" and shard_ctx is not None:
                n_nodes = self._pad512(self._dims(cfg, shape)["nodes"])

                def loss(p, b):
                    logits = gnn_mod.sage_forward_sharded(
                        p, b["feats"], b["agg0"], b["edges"], rcfg, n_nodes,
                        shard_ctx,
                    )
                    mask = b["labels"] >= 0
                    per = gnn_mod.sage_loss_per_node(logits, jnp.clip(b["labels"], 0))
                    return jnp.sum(per * mask) / jnp.maximum(mask.sum(), 1)

                return make_train_step(loss, AdamWConfig()), "train"

            def loss(p, b):
                logits = gnn_mod.sage_forward(p, b["feats"], b["edges"], rcfg)
                return gnn_mod.sage_loss(logits, b["labels"])
        elif shape == "minibatch_lg":
            def loss(p, b):
                hops = [(b["hop0_src"], b["hop0_dst"]), (b["hop1_src"], b["hop1_dst"])]
                logits = gnn_mod.sage_forward_sampled(
                    p, b["feats"], hops, rcfg, b["labels"].shape[0]
                )
                return gnn_mod.sage_loss(logits, b["labels"])
        else:
            def loss(p, b):
                logits = gnn_mod.sage_forward_graphs(
                    p, b["feats"], b["edges"], b["graph_ids"],
                    b["labels"].shape[0], rcfg,
                )
                return gnn_mod.sage_loss(logits, b["labels"])

        return make_train_step(loss, AdamWConfig()), "train"

    def param_pspecs(self, cfg, params, variant: str = "baseline", ctx=None):
        del params, variant, ctx
        return gnn_mod.sage_param_specs(cfg)

    def batch_pspecs(self, cfg, shape, ctx: ShardCtx, variant: str = "baseline"):
        da = ctx.data_axes
        if shape in ("full_graph_sm", "ogb_products"):
            if variant == "sharded":
                return {
                    "feats": P(da, None),
                    "agg0": P(da, None),
                    "edges": P(da, None),
                    "labels": P(da),
                }
            return {"feats": P(), "edges": P(da, None), "labels": P()}
        if shape == "minibatch_lg":
            return {
                "feats": P(),
                "hop0_src": P(da), "hop0_dst": P(da),
                "hop1_src": P(da), "hop1_dst": P(da),
                "labels": P(),
            }
        return {"feats": P(), "edges": P(da, None), "graph_ids": P(), "labels": P()}

    def model_flops_per_token(self, cfg):
        # per-edge message cost dominates: 2 * d_in * d_hidden per edge.
        return 0.0


# ------------------------------------------------------------------ RecSys


_REC_SHAPES = {
    "train_batch": ShapeInfo("train_batch", "train", "global batch 65536"),
    "serve_p99": ShapeInfo("serve_p99", "serve", "online batch 512"),
    "serve_bulk": ShapeInfo("serve_bulk", "serve", "offline batch 262144"),
    "retrieval_cand": ShapeInfo(
        "retrieval_cand", "retrieval", "1 query vs 1M candidates"
    ),
}
_REC_BATCH = {
    "train_batch": 65536,
    "serve_p99": 512,
    "serve_bulk": 262144,
    "retrieval_cand": 1,
}
_REC_BATCH_REDUCED = {
    "train_batch": 64,
    "serve_p99": 16,
    "serve_bulk": 64,
    "retrieval_cand": 1,
}
_REC_CANDIDATES = 1_000_000
_REC_CANDIDATES_REDUCED = 2048


class RecArch(Arch):
    family = "recsys"

    def __init__(self, name, full_cfg, reduced_cfg):
        self.name = name
        self._full = full_cfg
        self._reduced = reduced_cfg

    def shapes(self):
        return dict(_REC_SHAPES)

    def model_config(self, reduced: bool = False):
        return self._reduced() if reduced else self._full()

    def init_params(self, key, cfg):
        return rec_mod.init_rec(key, cfg)

    def _is_reduced(self, cfg):
        return cfg.name.endswith("-smoke")

    def _feature_specs(self, cfg, B: int, train: bool):
        i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
        f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
        out: dict[str, Any] = {}
        if cfg.arch in ("bst", "mind", "bert4rec"):
            out["history"] = i32(B, cfg.seq_len)
        if cfg.arch in ("bst", "mind", "bert4rec"):
            out["target"] = i32(B)
        if cfg.arch in ("bst", "autoint"):
            out["fields"] = i32(B, cfg.n_fields)
        if train:
            if cfg.arch in ("bst", "autoint"):
                out["label"] = f32(B)
            if cfg.arch == "bert4rec":
                m = max(1, cfg.seq_len // 10)
                out["mask_positions"] = i32(B, m)
                out["mask_labels"] = i32(B, m)
                out.pop("target")
        return out

    def input_specs(self, cfg, shape):
        B = (_REC_BATCH_REDUCED if self._is_reduced(cfg) else _REC_BATCH)[shape]
        specs = self._feature_specs(cfg, B, train=shape == "train_batch")
        if shape == "retrieval_cand":
            C = _REC_CANDIDATES_REDUCED if self._is_reduced(cfg) else _REC_CANDIDATES
            specs["candidates"] = jax.ShapeDtypeStruct((C,), jnp.int32)
        return specs

    def make_batch(self, cfg, shape, seed: int = 0):
        rng = np.random.default_rng(seed)
        specs = self.input_specs(cfg, shape)
        out = {}
        for k, s in specs.items():
            if k == "label":
                out[k] = rng.integers(0, 2, size=s.shape).astype(np.float32)
            elif k == "history":
                out[k] = rng.integers(-1, cfg.n_items, size=s.shape).astype(np.int32)
            elif k in ("target", "candidates", "mask_labels"):
                out[k] = rng.integers(0, cfg.n_items, size=s.shape).astype(np.int32)
            elif k == "mask_positions":
                out[k] = rng.integers(0, cfg.seq_len, size=s.shape).astype(np.int32)
            elif k == "fields":
                out[k] = rng.integers(0, cfg.field_vocab, size=s.shape).astype(
                    np.int32
                )
            else:
                raise KeyError(k)
        return out

    def build_step(self, cfg, shape, shard_ctx=None):
        if shape == "train_batch":
            loss = lambda p, b: rec_mod.rec_train_loss(p, b, cfg, shard_ctx)
            return make_train_step(loss, AdamWConfig()), "train"
        if shape in ("serve_p99", "serve_bulk"):
            def serve(params, batch):
                return rec_mod.rec_serve_scores(params, batch, cfg, shard_ctx)
            return serve, "serve"

        def retrieve(params, batch):
            feats = {k: v for k, v in batch.items() if k != "candidates"}
            return rec_mod.rec_retrieval_scores(
                params, feats, batch["candidates"], cfg, shard_ctx
            )
        return retrieve, "serve"

    def param_pspecs(self, cfg, params):
        return rec_mod.rec_param_specs(params, cfg)

    def batch_pspecs(self, cfg, shape, ctx: ShardCtx):
        da = ctx.data_axes
        specs = self.input_specs(cfg, shape)
        out = {}
        for k, s in specs.items():
            if k == "candidates":
                out[k] = P(da)  # candidates sharded; the user side is batch-1
            elif len(s.shape) >= 1 and s.shape[0] > 1:
                out[k] = P(da, *([None] * (len(s.shape) - 1)))
            else:
                out[k] = P(*([None] * len(s.shape)))
        return out
