"""The five assigned LM architectures — exact published configs.

Sources per the assignment block: qwen3-4b / qwen2.5-3b [hf], deepseek-67b
[arXiv:2401.02954], deepseek-v3-671b [arXiv:2412.19437], moonshot-v1-16b-a3b
[hf:moonshotai/Moonlight-16B-A3B]. Reduced configs keep the same family
features (qk-norm / bias / MLA / MoE / MTP) at smoke-test width.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import LMArch
from repro.models.attention import GQAConfig, MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig


def _qwen3_4b() -> LMConfig:
    return LMConfig(
        name="qwen3-4b",
        n_layers=36,
        d_model=2560,
        vocab=151936,
        attn=GQAConfig(
            d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
            qk_norm=True, rope_theta=1_000_000.0,
        ),
        d_ff=9728,
        max_seq=32768,
    )


def _qwen3_4b_reduced() -> LMConfig:
    return LMConfig(
        name="qwen3-4b-smoke",
        n_layers=2,
        d_model=64,
        vocab=512,
        attn=GQAConfig(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, qk_norm=True),
        d_ff=128,
        max_seq=64,
        dtype=jnp.float32,
        attn_chunk=32,
        loss_chunk=64,
    )


def _qwen25_3b() -> LMConfig:
    return LMConfig(
        name="qwen2.5-3b",
        n_layers=36,
        d_model=2048,
        vocab=151936,
        attn=GQAConfig(
            d_model=2048, n_heads=16, n_kv_heads=2, head_dim=128,
            qkv_bias=True, rope_theta=1_000_000.0,
        ),
        d_ff=11008,
        max_seq=32768,
    )


def _qwen25_3b_reduced() -> LMConfig:
    return LMConfig(
        name="qwen2.5-3b-smoke",
        n_layers=2,
        d_model=64,
        vocab=512,
        attn=GQAConfig(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, qkv_bias=True),
        d_ff=160,
        max_seq=64,
        dtype=jnp.float32,
        attn_chunk=32,
        loss_chunk=64,
    )


def _deepseek_67b() -> LMConfig:
    return LMConfig(
        name="deepseek-67b",
        n_layers=95,
        d_model=8192,
        vocab=102400,
        attn=GQAConfig(d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128),
        d_ff=22016,
        max_seq=32768,
    )


def _deepseek_67b_reduced() -> LMConfig:
    return LMConfig(
        name="deepseek-67b-smoke",
        n_layers=3,
        d_model=64,
        vocab=512,
        attn=GQAConfig(d_model=64, n_heads=8, n_kv_heads=2, head_dim=8),
        d_ff=192,
        max_seq=64,
        dtype=jnp.float32,
        attn_chunk=32,
        loss_chunk=64,
    )


def _deepseek_v3() -> LMConfig:
    return LMConfig(
        name="deepseek-v3-671b",
        n_layers=61,
        d_model=7168,
        vocab=129280,
        attn=MLAConfig(
            d_model=7168, n_heads=128, q_lora_rank=1536, kv_lora_rank=512,
            qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
        ),
        d_ff=18432,  # the 3 leading dense layers
        moe=MoEConfig(n_experts=256, top_k=8, d_ff=2048, n_shared=1),
        n_dense_layers=3,
        max_seq=32768,
        mtp=True,
    )


def _deepseek_v3_reduced() -> LMConfig:
    return LMConfig(
        name="deepseek-v3-smoke",
        n_layers=3,
        d_model=64,
        vocab=512,
        attn=MLAConfig(
            d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
            qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        ),
        d_ff=128,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=1),
        n_dense_layers=1,
        max_seq=64,
        dtype=jnp.float32,
        mtp=True,
        attn_chunk=32,
        loss_chunk=64,
    )


def _moonshot_16b() -> LMConfig:
    return LMConfig(
        name="moonshot-v1-16b-a3b",
        n_layers=48,
        d_model=2048,
        vocab=163840,
        attn=GQAConfig(d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128),
        d_ff=11264,  # dense first layer (moonlight-style)
        moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, n_shared=2),
        n_dense_layers=1,
        max_seq=32768,
    )


def _moonshot_16b_reduced() -> LMConfig:
    return LMConfig(
        name="moonshot-smoke",
        n_layers=3,
        d_model=64,
        vocab=512,
        attn=GQAConfig(d_model=64, n_heads=4, n_kv_heads=4, head_dim=16),
        d_ff=128,
        moe=MoEConfig(n_experts=8, top_k=3, d_ff=32, n_shared=2),
        n_dense_layers=1,
        max_seq=64,
        dtype=jnp.float32,
        attn_chunk=32,
        loss_chunk=64,
    )


LM_ARCHS = [
    LMArch("qwen3-4b", _qwen3_4b, _qwen3_4b_reduced),
    LMArch("qwen2.5-3b", _qwen25_3b, _qwen25_3b_reduced),
    LMArch("deepseek-67b", _deepseek_67b, _deepseek_67b_reduced, fsdp=True),
    LMArch(
        "deepseek-v3-671b", _deepseek_v3, _deepseek_v3_reduced,
        moments="int8", fsdp=True,
    ),
    LMArch("moonshot-v1-16b-a3b", _moonshot_16b, _moonshot_16b_reduced, fsdp=True),
]
