"""GNN and RecSys assigned architectures — exact published configs."""

from __future__ import annotations

from repro.configs.base import GNNArch, RecArch
from repro.models.recsys import RecConfig


def _bst() -> RecConfig:
    # Behavior Sequence Transformer [arXiv:1905.06874]
    return RecConfig(
        name="bst", arch="bst", n_items=4_194_304, embed_dim=32, seq_len=20,
        n_fields=8, field_vocab=100_000, n_blocks=1, n_heads=8,
        mlp=(1024, 512, 256),
    )


def _bst_reduced() -> RecConfig:
    return RecConfig(
        name="bst-smoke", arch="bst", n_items=1000, embed_dim=16, seq_len=8,
        n_fields=4, field_vocab=100, n_blocks=1, n_heads=4, mlp=(64, 32),
    )


def _mind() -> RecConfig:
    # MIND multi-interest [arXiv:1904.08030]
    return RecConfig(
        name="mind", arch="mind", n_items=8_388_608, embed_dim=64, seq_len=50,
        n_interests=4, capsule_iters=3,
    )


def _mind_reduced() -> RecConfig:
    return RecConfig(
        name="mind-smoke", arch="mind", n_items=1000, embed_dim=16, seq_len=8,
        n_interests=2, capsule_iters=2,
    )


def _autoint() -> RecConfig:
    # AutoInt [arXiv:1810.11921]: 39 sparse fields, 3 attn layers, 2 heads.
    return RecConfig(
        name="autoint", arch="autoint", n_items=16, embed_dim=16, n_fields=39,
        field_vocab=1_000_000, n_attn_layers=3, d_attn=32,
    )


def _autoint_reduced() -> RecConfig:
    return RecConfig(
        name="autoint-smoke", arch="autoint", n_items=16, embed_dim=8,
        n_fields=6, field_vocab=100, n_attn_layers=2, d_attn=8,
    )


def _bert4rec() -> RecConfig:
    # BERT4Rec [arXiv:1904.06690]
    return RecConfig(
        name="bert4rec", arch="bert4rec", n_items=1_048_576, embed_dim=64,
        seq_len=200, n_blocks=2, n_heads=2,
    )


def _bert4rec_reduced() -> RecConfig:
    return RecConfig(
        name="bert4rec-smoke", arch="bert4rec", n_items=500, embed_dim=16,
        seq_len=16, n_blocks=2, n_heads=2,
    )


OTHER_ARCHS = [
    GNNArch("graphsage-reddit"),
    RecArch("bst", _bst, _bst_reduced),
    RecArch("mind", _mind, _mind_reduced),
    RecArch("autoint", _autoint, _autoint_reduced),
    RecArch("bert4rec", _bert4rec, _bert4rec_reduced),
]
