"""Architecture registry: ``--arch <id>`` resolution for launch scripts."""

from __future__ import annotations

from repro.configs.anytime_ir import AnytimeIRArch
from repro.configs.base import Arch
from repro.configs.lm_archs import LM_ARCHS
from repro.configs.other_archs import OTHER_ARCHS

__all__ = ["ARCHS", "get_arch", "all_cells"]

ARCHS: dict[str, Arch] = {
    a.name: a for a in [*LM_ARCHS, *OTHER_ARCHS, AnytimeIRArch()]
}


def get_arch(name: str) -> Arch:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells(include_skipped: bool = False):
    """Every (arch, shape) dry-run cell, with skip annotations."""
    out = []
    for name, arch in ARCHS.items():
        for shape, info in arch.shapes().items():
            if info.skip and not include_skipped:
                continue
            out.append((name, shape, info))
    return out
