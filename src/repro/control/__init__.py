# Anytime serving control plane (DESIGN.md §9, §10): replicated shard
# groups, online reshard with staged live cutover, health-ledger-driven
# degraded failover — all above the §3/§4 serving engines — plus a durable
# topology journal replayed across process restarts.
from repro.control.health import HealthEvent, HealthLedger  # noqa: F401
from repro.control.journal import TopologyJournal  # noqa: F401
from repro.control.plane import ControlPlane  # noqa: F401
from repro.control.replica import ReplicaGroupEngine  # noqa: F401
from repro.control.reshard import ReshardPlanner, ReshardTask  # noqa: F401
