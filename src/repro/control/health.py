"""Shard/replica health ledger for degraded anytime serving (DESIGN.md §9).

The anytime contract makes failover cheap to reason about: a dead shard is
just a shard whose traversal terminated at zero postings, and the §4
fidelity accounting already knows how to certify what that costs — the
merged result keeps flowing with ``exact=False`` and a ``fidelity_bound``
widened by the dead shard's unprocessed BoundSum mass. This module is the
bookkeeping side: who is down, since when, and which mask the dispatch
should apply.

State is per (replica, shard) cell. A *shard* is down for serving only when
every replica of it is down (with one replica, that is the replica itself);
a *replica row* is healthy only when all its shards are up — the
``ReplicaGroupEngine`` falls back to a surviving replica when a row
degrades, so partial-replica outages cost throughput, not fidelity.
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

__all__ = ["HealthEvent", "HealthLedger"]


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    """One transition in the ledger, for observability and tests."""

    seq: int
    kind: str  # "down" | "up"
    shard: int
    replica: int | None  # None = every replica of the shard


class HealthLedger:
    """Boolean (replica, shard) availability matrix with an event log."""

    def __init__(self, n_shards: int, n_replicas: int = 1):
        if n_shards < 1 or n_replicas < 1:
            raise ValueError(
                f"need n_shards >= 1 and n_replicas >= 1, got "
                f"{n_shards}, {n_replicas}"
            )
        self.n_shards = n_shards
        self.n_replicas = n_replicas
        self._up = np.ones((n_replicas, n_shards), dtype=bool)
        self._seq = itertools.count()
        self.events: list[HealthEvent] = []

    # ------------------------------------------------------------ mutation
    def _check(self, shard: int, replica: int | None) -> None:
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} not in [0, {self.n_shards})")
        if replica is not None and not 0 <= replica < self.n_replicas:
            raise ValueError(f"replica {replica} not in [0, {self.n_replicas})")

    def mark_down(self, shard: int, replica: int | None = None) -> None:
        """Declare a shard dead on one replica (or on all when None)."""
        self._check(shard, replica)
        rows = slice(None) if replica is None else replica
        self._up[rows, shard] = False
        self.events.append(HealthEvent(next(self._seq), "down", shard, replica))

    def mark_up(self, shard: int, replica: int | None = None) -> None:
        """Declare a shard recovered on one replica (or on all when None)."""
        self._check(shard, replica)
        rows = slice(None) if replica is None else replica
        self._up[rows, shard] = True
        self.events.append(HealthEvent(next(self._seq), "up", shard, replica))

    def reset(self, n_shards: int | None = None) -> None:
        """Mark everything up (e.g. after a reshard replaces the layout)."""
        if n_shards is not None:
            self.n_shards = n_shards
        self._up = np.ones((self.n_replicas, self.n_shards), dtype=bool)

    # ------------------------------------------------------------- queries
    @property
    def all_up(self) -> bool:
        return bool(self._up.all())

    def shard_down_mask(self) -> np.ndarray:
        """[S] bool — True where NO replica of the shard is alive.

        This is the mask the dispatch applies (``serving.sharded
        .apply_down_mask``): only a shard with zero live replicas has to be
        served degraded; anything less is routed around at full fidelity.
        """
        return ~self._up.any(axis=0)

    def replica_healthy_mask(self) -> np.ndarray:
        """[n_replicas] bool — True where the replica has every shard up."""
        return self._up.all(axis=1)

    def n_healthy_replicas(self) -> int:
        return int(self.replica_healthy_mask().sum())

    def snapshot(self) -> dict:
        """JSON-able state for dashboards / the control-plane stats call."""
        return {
            "n_shards": self.n_shards,
            "n_replicas": self.n_replicas,
            "up": self._up.tolist(),
            "shard_down": self.shard_down_mask().tolist(),
            "healthy_replicas": int(self.n_healthy_replicas()),
            "events": len(self.events),
        }
