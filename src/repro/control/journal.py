"""Replayable topology journal (DESIGN.md §10).

The control plane's topology state — reshard commits and health
transitions — used to live only in process memory: a broker restart lost
the journaled layout and the ledger. ``TopologyJournal`` persists that
state as an append-only ``journal.jsonl`` *inside the index artifact the
plane serves from*, so the topology travels with the index it describes.

Record schema (one JSON object per line):

    {"seq": N, "fingerprint": "<index fp>", "kind": "reshard",
     "cuts": [0, ...], "reason": "planner" | "operator"}
    {"seq": N, "fingerprint": "<index fp>", "kind": "health",
     "event": "down" | "up", "shard": S, "replica": R | null}

Every record is stamped with the fingerprint of the live (materialized)
index, so replay can refuse a journal that belongs to a different index —
the same staleness stance ``ShardedEngine.from_artifact`` takes for shard
artifacts. Appends are flushed and fsynced per record; a torn final line
from a crash mid-append is ignored on read (the record it described never
committed anywhere else either, so dropping it is consistent).

``ControlPlane.from_artifact(path, ..., replay=True)`` reads the journal
back and reconstructs the cuts + ledger state across a process boundary.
"""

from __future__ import annotations

import json
import os

__all__ = ["JOURNAL_NAME", "TopologyJournal"]

JOURNAL_NAME = "journal.jsonl"


class TopologyJournal:
    """Append-only JSONL journal with crash-tolerant reads."""

    def __init__(self, path: str):
        self.path = path
        # Cached next sequence number: the journal is appended by exactly
        # one process, so after the first read every append is O(1) instead
        # of re-parsing the whole file.
        self._next_seq: int | None = None
        self._tail_repaired = False

    def __repr__(self) -> str:  # pragma: no cover — debugging nicety
        return f"TopologyJournal({self.path!r})"

    @property
    def exists(self) -> bool:
        return os.path.exists(self.path)

    def next_seq(self) -> int:
        if self._next_seq is None:
            records = self.records()
            self._next_seq = records[-1]["seq"] + 1 if records else 0
        return self._next_seq

    def _repair_torn_tail(self) -> None:
        """Truncate a crash-torn final line before the first append.

        ``records()`` merely *skips* a torn tail, but an append must not
        concatenate onto it (the merged line would corrupt the journal or
        silently swallow the new record). The torn fragment was never
        committed, so truncating it is consistent with what readers saw.
        Checked once per process: this writer always leaves a trailing
        newline behind.
        """
        if self._tail_repaired:
            return
        self._tail_repaired = True
        try:
            with open(self.path, "r+b") as f:
                f.seek(0, os.SEEK_END)
                size = f.tell()
                if size == 0:
                    return
                f.seek(size - 1)
                if f.read(1) == b"\n":
                    return
                f.seek(0)
                data = f.read()
                f.truncate(data.rfind(b"\n") + 1)
        except FileNotFoundError:
            return

    def append(self, record: dict) -> dict:
        """Durably append one record; fills in ``seq``, returns the record.

        The parent directory must exist (the journal lives inside a
        published artifact directory).
        """
        self._repair_torn_tail()
        record = dict(record)
        record.setdefault("seq", self.next_seq())
        line = json.dumps(record, sort_keys=True)
        with open(self.path, "a", encoding="utf-8") as f:
            f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())
        self._next_seq = int(record["seq"]) + 1
        return record

    def records(self) -> list[dict]:
        """All committed records, oldest first.

        A torn final line (crash mid-append) is skipped; a torn or foreign
        line anywhere *else* means the file is not our journal and raises
        ``ValueError`` rather than silently replaying half a history.
        """
        if not os.path.exists(self.path):
            return []
        with open(self.path, encoding="utf-8") as f:
            lines = f.read().split("\n")
        if lines and lines[-1] == "":
            lines.pop()
        out: list[dict] = []
        for i, line in enumerate(lines):
            try:
                rec = json.loads(line)
                if not isinstance(rec, dict):
                    raise ValueError(f"record {i} is not an object")
            except ValueError as e:
                if i == len(lines) - 1:
                    break  # torn tail from a crashed append — never committed
                raise ValueError(
                    f"{self.path}: corrupt journal record {i}: {e}"
                ) from e
            out.append(rec)
        return out
