"""The anytime serving control plane (DESIGN.md §9).

``ControlPlane`` sits above the §3/§4 serving stack and owns the pieces
that have to outlive any single engine: the request queue, the health
ledger, the reshard planner, and the live engine pointer. One object, four
cooperating behaviours:

  * **serving** — a ``MicroBatchServer`` loop (submit / drain) over the
    live engine: a ``ReplicaGroupEngine`` when replicas are configured and
    healthy, the plain ``ShardedEngine`` path otherwise;
  * **budgeting** — a ``ShardedSlaBudgeter`` in BoundSum mode by default:
    each query's postings budget concentrates on the shards whose ranges
    can actually score for its terms;
  * **failover** — ``mark_down``/``mark_up`` drive the ledger; dead shards
    get zero-budget dispatch slots so every query still returns, with
    ``exact=False`` and a ``fidelity_bound`` widened by the dead shard's
    unprocessed BoundSum mass; recovery is automatic on ``mark_up``;
  * **reshard** — the planner watches per-shard load EWMAs fed by the
    serving loop; ``maybe_reshard`` (or an explicit ``start_reshard``)
    opens a staged ``ReshardTask`` whose ``step()`` runs between
    micro-batches, and the engine pointer swaps only when the successor is
    built and warm — serving never pauses, and post-cutover results are
    bitwise-equal to a fresh build at the new layout. A reshard never
    starts during an outage: outage-skewed counters would re-seed the
    budgeter wrong and the cutover would restack from a possibly-dead
    device's arrays (``start_reshard`` refuses, or defers until the fleet
    recovers);
  * **durability** — with a ``TopologyJournal`` attached
    (``ControlPlane.from_artifact``), reshard commits and health
    transitions append to ``journal.jsonl`` inside the serving artifact,
    and ``from_artifact(..., replay=True)`` reconstructs the journaled
    cuts + ledger in a fresh process (DESIGN.md §10).
"""

from __future__ import annotations

import os

import numpy as np

from repro.control.health import HealthLedger
from repro.control.journal import JOURNAL_NAME, TopologyJournal
from repro.control.replica import ReplicaGroupEngine
from repro.control.reshard import ReshardPlanner, ReshardTask
from repro.core.clustered_index import range_postings_mass, shard_device_index
from repro.core.range_daat import Engine
from repro.obs import NOOP
from repro.serving.bucketing import BucketSpec
from repro.serving.microbatch import MicroBatchServer, ShardedSlaBudgeter
from repro.serving.sharded import ShardedBatchEngine, ShardedEngine

__all__ = ["ControlPlane"]


class _PlaneServer(MicroBatchServer):
    """MicroBatchServer whose dispatch and feedback route via the plane."""

    def __init__(self, plane: "ControlPlane", **kwargs):
        super().__init__(plane.bengine, plane.budgeter, **kwargs)
        self.plane = plane

    def _run_batch(self, plans, budgets):
        return self.plane._dispatch(plans, budgets)

    def _observe(self, batch_ms, results, latencies_ms=None):
        self.plane._observe(batch_ms, results, latencies_ms=latencies_ms)


class ControlPlane:
    """Replicated, reshardable, failure-tolerant anytime serving.

    ``n_replicas > 1`` builds a ``ReplicaGroupEngine`` over a
    (data x shard) mesh when the runtime has the devices (``use_mesh``
    as in ``ShardedEngine``: None = auto). ``budget_mode`` picks the
    ``ShardedSlaBudgeter`` allocation ("boundsum" default, "rate" for the
    §4 behaviour). ``sla_ms=inf`` serves unbudgeted (every query runs to
    safe/exhausted completion) — the mode the bitwise tests pin.
    """

    def __init__(
        self,
        engine: Engine,
        n_shards: int,
        n_replicas: int = 1,
        sla_ms: float = float("inf"),
        spec: BucketSpec | None = None,
        use_mesh: bool | None = None,
        budget_mode: str = "boundsum",
        reshard_trigger: float = 1.25,
        budgeter: ShardedSlaBudgeter | None = None,
        max_batch: int | None = None,
        clock=None,
        journal: TopologyJournal | None = None,
        obs=NOOP,
    ):
        self.engine = engine
        self.n_replicas = n_replicas
        self.spec = spec or BucketSpec()
        self._use_mesh = use_mesh
        self.obs = obs
        self.clock = clock if clock is not None else obs.clock
        self.health = HealthLedger(n_shards, n_replicas)
        self._install(ShardedEngine(engine, n_shards, use_mesh=use_mesh, obs=obs))
        self.budgeter = budgeter or ShardedSlaBudgeter(
            sla_ms=sla_ms,
            n_shards=n_shards,
            mode=budget_mode,
            shard_mass=self._shard_mass,
            obs=obs,
        )
        if getattr(self.budgeter, "down_mask", False) is None:
            # Base-API `observe` feedback must not credit postings to
            # health-ledger-down shards (their EWMAs stay frozen through an
            # outage) — wire the ledger in unless the caller already did.
            self.budgeter.down_mask = self.health.shard_down_mask
        self.planner = ReshardPlanner(
            range_mass=range_postings_mass(engine.index),
            cuts=self.sengine.cuts,
            trigger=reshard_trigger,
        )
        self.reshard_task: ReshardTask | None = None
        self.deferred_reshard: dict | None = None
        self.reshards_completed = 0
        self.batches_served = 0
        self.queries_served = 0
        self.queries_served_during_reshard = 0
        self._reshard_t0: float | None = None
        self.server = _PlaneServer(
            self, max_batch=max_batch, clock=self.clock, obs=obs
        )
        # Topology journal (DESIGN.md §10): records are stamped with the
        # served index's fingerprint so replay can refuse a foreign journal.
        # The fingerprint (a sha1 pass over the postings arrays) is computed
        # lazily — journal-less planes never pay for it.
        self.journal = journal
        self._journal_muted = False
        self._fp_cache: str | None = None
        # Operations layer (DESIGN.md §14): attached via enable_operations.
        self.monitor = None
        self.slo_tracker = None
        self.degraded_slo = False
        self._skew_alert = False
        self._ops_every = 1
        self._drains = 0
        if self.obs.enabled:
            self.obs.gauge("plane_available", 1.0)

    @property
    def _fingerprint(self) -> str:
        if self._fp_cache is None:
            self._fp_cache = self.engine.index.fingerprint()
        return self._fp_cache

    @classmethod
    def from_artifact(
        cls,
        path: str,
        n_shards: int,
        replay: bool = False,
        journal: bool = True,
        engine_kwargs: dict | None = None,
        **plane_kwargs,
    ) -> "ControlPlane":
        """Open a plane over a saved index artifact (or delta-chain head).

        ``journal=True`` attaches ``<path>/journal.jsonl`` so topology
        changes persist; ``replay=True`` additionally reconstructs the
        journaled cuts and health-ledger state before serving — a broker
        that died mid-reshard resumes at the last *committed* layout
        (uncommitted cutover work is simply re-planned). ``engine_kwargs``
        go to ``Engine.from_artifact`` (k, impact_dtype, ...); everything
        else to the plane constructor.
        """
        engine = Engine.from_artifact(path, **(engine_kwargs or {}))
        plane = cls(
            engine,
            n_shards,
            journal=(
                TopologyJournal(os.path.join(path, JOURNAL_NAME))
                if journal
                else None
            ),
            **plane_kwargs,
        )
        if replay:
            plane.replay_journal()
        return plane

    # ----------------------------------------------------------- installing
    def _install(self, sengine: ShardedEngine) -> None:
        """Point the plane at a (new) sharded engine + its replica group."""
        self.sengine = sengine
        self.replicas = (
            ReplicaGroupEngine(sengine, self.n_replicas, use_mesh=self._use_mesh)
            if self.n_replicas > 1
            else None
        )
        self.bengine = ShardedBatchEngine(self.replicas or sengine, self.spec)
        self.bengine_single = (
            ShardedBatchEngine(sengine, self.spec) if self.replicas else self.bengine
        )

    def _shard_mass(self, plan) -> np.ndarray:
        # Late-bound so a reshard swap retargets budget shaping too.
        return self.sengine.query_shard_mass(plan)

    @property
    def n_shards(self) -> int:
        return self.sengine.n_shards

    @property
    def cuts(self) -> np.ndarray:
        return self.sengine.cuts

    # -------------------------------------------------------------- serving
    def submit(self, q_terms: np.ndarray) -> int:
        return self.server.submit(q_terms)

    @property
    def pending(self) -> int:
        return self.server.pending

    def drain_once(self):
        """Serve one micro-batch, then advance any in-flight reshard.

        The reshard step runs strictly *between* dispatches, so the queue
        is never blocked behind cutover work; the swap happens here too,
        once the successor engine reports ready. When the operations layer
        is attached (``enable_operations``), the SLO tracker samples and
        the drift monitor polls here as well — and a sustained shard-skew
        alert arms ``maybe_reshard`` without waiting for an external
        caller.
        """
        served = self.server.drain_once()
        self.batches_served += 1 if served else 0
        self.queries_served += len(served)
        self._drains += 1
        if (
            (self.slo_tracker is not None or self.monitor is not None)
            and self._drains % self._ops_every == 0
        ):
            if self.slo_tracker is not None:
                self.slo_tracker.sample()
                self.slo_tracker.evaluate()
            if self.monitor is not None:
                self.monitor.poll()
        if self._skew_alert and self.reshard_task is None:
            self.maybe_reshard()
        if self.reshard_task is not None:
            if served:
                self.queries_served_during_reshard += len(served)
            self.reshard_task.step()
            if self.reshard_task.ready:
                self._cutover()
        return served

    def replay(self, queries, batch_size: int | None = None):
        """Offline replay through the plane's drain loop."""
        bs = max(1, min(batch_size or self.server.max_batch, self.server.max_batch))
        out = []
        for lo in range(0, len(queries), bs):
            for q in queries[lo : lo + bs]:
                self.submit(q)
            out.extend(self.drain_once())
        while self.pending:
            out.extend(self.drain_once())
        return out

    def _dispatch(self, plans, budgets):
        down = self.health.shard_down_mask()
        if (
            self.replicas is not None
            and self.health.n_healthy_replicas() < self.n_replicas
        ):
            # A degraded replica row cannot carry its slice of the batch;
            # reroute through the single-replica path (same math, fewer
            # devices) until the ledger clears — throughput, not fidelity.
            beng = self.bengine_single
        else:
            beng = self.bengine
        return beng.run_batch(
            plans,
            budget_postings=budgets,
            down_mask=down if down.any() else None,
        )

    def _observe(self, batch_ms, results, latencies_ms=None) -> None:
        per_shard = np.sum([r.shard_postings for r in results], axis=0)
        up = ~self.health.shard_down_mask()
        if self.obs.enabled:
            # Per-shard postings counters: the ShardSkewProbe's signal
            # (DESIGN.md §14) — same numbers the reshard planner EWMAs.
            for s in range(per_shard.shape[0]):
                self.obs.count(
                    "shard_postings", float(per_shard[s]), shard=s
                )
        self.budgeter.observe_sharded(
            batch_ms, per_shard, len(results), active_mask=up,
            latencies_ms=latencies_ms,
        )
        # The reshard planner only learns from a healthy fleet: a down
        # shard's zero counters say nothing about where load lives, and
        # would otherwise decay its EWMA until an outage armed a spurious
        # (and wrong-direction) reshard.
        if up.all():
            self.planner.observe(per_shard, len(results))

    # ----------------------------------------------------------- operations
    def enable_operations(
        self, slos=None, monitor=None, poll_every: int = 1
    ) -> None:
        """Attach the §14 operations layer to the drain loop.

        ``slos`` is an ``SloTracker`` (sampled + evaluated every
        ``poll_every`` drains, writing ``slo_*`` gauges into the plane's
        registry); ``monitor`` a ``DriftMonitor`` — the plane subscribes
        to its alerts: a ``shard_skew`` fire arms ``maybe_reshard`` on
        subsequent drains, an SLO-burn fire flips the plane into a
        degraded-SLO state (``stats()['degraded_slo']``), both clearing
        with the alert.
        """
        self.slo_tracker = slos
        self.monitor = monitor
        self._ops_every = max(1, int(poll_every))
        if monitor is not None:
            monitor.subscribe(self._on_alert)

    def _on_alert(self, event) -> None:
        firing = event.state == "fire"
        if event.detector == "shard_skew":
            self._skew_alert = firing
        elif "burn" in event.detector:
            self.degraded_slo = firing
            if self.obs.enabled:
                self.obs.gauge("plane_degraded_slo", 1.0 if firing else 0.0)

    # -------------------------------------------------------------- journal
    def _journal_append(self, record: dict) -> None:
        if self.journal is None or self._journal_muted:
            return
        self.journal.append({"fingerprint": self._fingerprint, **record})

    def replay_journal(self) -> int:
        """Reconstruct journaled topology state; returns records applied.

        The last committed reshard's cuts become the live layout (rebuilt
        via ``shard_device_index(cuts=...)`` — bitwise what the original
        cutover served), then health transitions re-drive the ledger in
        order. Records stamped with a different index fingerprint are
        refused: a journal describes exactly one materialized index.
        """
        if self.journal is None:
            raise RuntimeError("no topology journal attached")
        records = self.journal.records()
        foreign = [
            r for r in records if r.get("fingerprint") != self._fingerprint
        ]
        if foreign:
            from repro.index_io import ArtifactError

            raise ArtifactError(
                f"journal {self.journal.path} has {len(foreign)} record(s) "
                f"for index {foreign[0].get('fingerprint')}, but the live "
                f"index is {self._fingerprint} — refusing to replay a "
                f"foreign topology"
            )
        cuts, last_reshard = None, -1
        for i, r in enumerate(records):
            if r.get("kind") == "reshard":
                cuts, last_reshard = np.asarray(r["cuts"], np.int64), i
        if cuts is not None and not np.array_equal(cuts, self.sengine.cuts):
            self._adopt_layout(
                ShardedEngine(
                    self.engine,
                    int(cuts.shape[0] - 1),
                    use_mesh=self._use_mesh,
                    shards=shard_device_index(self.engine.index, cuts=cuts),
                    obs=self.obs,
                ),
                cuts,
            )
        self.reshards_completed = sum(
            1 for r in records if r.get("kind") == "reshard"
        )
        self._journal_muted = True
        try:
            # Only health records AFTER the last committed reshard apply:
            # the live _cutover reset the ledger at that point (shard
            # indices name different range bands across layouts), and
            # older records may reference shard ids the new layout no
            # longer has.
            for r in records[last_reshard + 1:]:
                if r.get("kind") != "health":
                    continue
                replica = r.get("replica")
                if r.get("event") == "down":
                    self.mark_down(int(r["shard"]), replica)
                else:
                    self.mark_up(int(r["shard"]), replica)
        finally:
            self._journal_muted = False
        return len(records)

    # ------------------------------------------------------------- failover
    def mark_down(self, shard: int, replica: int | None = None) -> None:
        self.health.mark_down(shard, replica)
        if self.obs.enabled:
            self.obs.count("health_transitions", event="down", shard=shard)
            self.obs.gauge(
                "plane_available", 1.0 if self.health.all_up else 0.0
            )
        self._journal_append(
            {"kind": "health", "event": "down", "shard": int(shard),
             "replica": None if replica is None else int(replica)}
        )

    def mark_up(self, shard: int, replica: int | None = None) -> None:
        self.health.mark_up(shard, replica)
        if self.obs.enabled:
            self.obs.count("health_transitions", event="up", shard=shard)
            self.obs.gauge(
                "plane_available", 1.0 if self.health.all_up else 0.0
            )
        self._journal_append(
            {"kind": "health", "event": "up", "shard": int(shard),
             "replica": None if replica is None else int(replica)}
        )
        if self.deferred_reshard is not None and self.health.all_up:
            pending, self.deferred_reshard = self.deferred_reshard, None
            self.start_reshard(**pending)

    # -------------------------------------------------------------- reshard
    def maybe_reshard(self) -> bool:
        """Open a staged reshard if the planner is armed; returns True then."""
        if (
            self.reshard_task is not None
            or not self.health.all_up  # outage-skewed EWMAs arm spuriously
            or not self.planner.should_reshard()
        ):
            return False
        self.start_reshard(self.planner.propose())
        return True

    def start_reshard(
        self,
        cuts,
        shards_path: str | None = None,
        warm_widths=None,
        defer_if_degraded: bool = False,
    ) -> ReshardTask | None:
        """Begin a live cutover to ``cuts``.

        Source arrays are the live engine's shards, or — with
        ``shards_path`` — a persisted ``index_io`` shard artifact, so a
        reshard can be driven entirely from disk without the full index.
        ``warm_widths`` pre-compiles those width buckets on the successor
        before the swap (defaults to every width the live engine has seen).

        Refused while any shard is health-ledger down: a cutover mid-outage
        would re-seed budgeter EWMAs from outage-skewed counters and
        restack from a possibly-dead device's arrays. Pass
        ``defer_if_degraded=True`` to queue the request instead — it
        starts automatically at the ``mark_up`` that restores full health
        (returns None in the deferred case).
        """
        if self.reshard_task is not None:
            raise RuntimeError("a reshard is already in flight")
        # Validate the request up front — also on the deferred path, so a
        # bad request fails at request time, never out of a later mark_up.
        cuts = np.asarray(cuts, np.int64)
        R = int(self.sengine.cuts[-1])
        if (
            cuts.ndim != 1
            or cuts.shape[0] < 2
            or cuts[0] != 0
            or cuts[-1] != R
            or np.any(np.diff(cuts) < 1)
        ):
            raise ValueError(
                f"cuts {cuts.tolist()} must rise strictly from 0 to "
                f"n_ranges={R} (every shard keeps >= 1 range)"
            )
        if np.array_equal(cuts, self.sengine.cuts):
            raise ValueError(f"cuts {cuts.tolist()} are already the live layout")
        if not self.health.all_up:
            if defer_if_degraded:
                self.deferred_reshard = dict(
                    cuts=cuts,
                    shards_path=shards_path,
                    warm_widths=warm_widths,
                )
                return None
            down = np.nonzero(self.health.shard_down_mask())[0].tolist()
            raise RuntimeError(
                f"refusing to start a reshard during an outage (ledger has "
                f"down shards {down}, degraded replicas "
                f"{(~self.health.replica_healthy_mask()).sum()}): cutover "
                f"would restack from possibly-dead arrays and re-seed "
                f"budgets from outage-skewed counters — mark_up first, or "
                f"pass defer_if_degraded=True"
            )
        if shards_path is not None:
            from repro import index_io

            src = index_io.read_manifest(shards_path).get("source_fingerprint")
            if src is None:
                # Same stance as ShardedEngine.from_artifact: an
                # unverifiable shard set is as dangerous as a stale one —
                # foreign arrays under the live planner serve garbage with
                # no error. Re-save with source_fingerprint= to opt in.
                raise index_io.ArtifactError(
                    f"shard artifact {shards_path} records no "
                    f"source_fingerprint; re-save with "
                    f"source_fingerprint=index.fingerprint()"
                )
            if src != self.engine.index.fingerprint():
                raise index_io.ArtifactError(
                    f"shard artifact {shards_path} was carved from index "
                    f"{src}, but the live index has fingerprint "
                    f"{self.engine.index.fingerprint()} — refusing to "
                    f"reshard from a stale layout"
                )
            source = index_io.load_shards(shards_path)
        else:
            source = self.sengine.shards
        if warm_widths is None:
            warm_widths = sorted({w for (_, w) in self.bengine.compiled_shapes})

        def build(new_shards):
            seng = ShardedEngine(
                self.engine,
                len(new_shards),
                use_mesh=self._use_mesh,
                shards=new_shards,
                obs=self.obs,
            )
            beng = ShardedBatchEngine(
                ReplicaGroupEngine(seng, self.n_replicas, use_mesh=self._use_mesh)
                if self.n_replicas > 1
                else seng,
                self.spec,
            )
            return seng, beng

        self.reshard_task = ReshardTask(source, cuts, build, warm_widths)
        if self.obs.enabled:
            self._reshard_t0 = self.clock()
            self.obs.count("reshard_started")
        return self.reshard_task

    def _cutover(self) -> None:
        """Atomic engine swap: the next micro-batch serves the new layout.

        The task's engines were built and warmed off the serving path, so
        the swap is pointer rebinding only. The health ledger resets —
        shard indices now name different range bands — and the planner
        adopts the new cuts with a fresh load EWMA.
        """
        task = self.reshard_task
        assert task is not None and task.ready
        self.sengine = task.sengine
        self.bengine = task.bengine
        self.replicas = task.bengine.sengine if self.n_replicas > 1 else None
        self.bengine_single = (
            ShardedBatchEngine(task.sengine, self.spec)
            if self.n_replicas > 1
            else task.bengine
        )
        self.server.bengine = self.bengine
        self.health.reset(task.n_shards)
        self._reseed_budgeter(task.n_shards)
        self.planner.committed(task.cuts)
        self.reshard_task = None
        self.reshards_completed += 1
        if self.obs.enabled:
            self.obs.count("reshard_cutovers")
            if self._reshard_t0 is not None:
                # Arm -> cutover wall time: how long serving carried the
                # staged successor before the pointer swap.
                self.obs.observe(
                    "reshard_ms", (self.clock() - self._reshard_t0) * 1e3
                )
                self._reshard_t0 = None
        self._journal_append(
            {"kind": "reshard", "cuts": [int(c) for c in task.cuts]}
        )

    def _reseed_budgeter(self, n_shards: int) -> None:
        if self.budgeter.n_shards != n_shards:
            # A layout change may change the shard count; re-seed the
            # per-shard throughput EWMAs at the old mean so budgets stay sane.
            self.budgeter.n_shards = n_shards
            self.budgeter.rates = np.full(
                n_shards, float(np.mean(self.budgeter.rates)), np.float64
            )

    def _adopt_layout(self, sengine: ShardedEngine, cuts: np.ndarray) -> None:
        """Swap to a layout built outside a live cutover (journal replay)."""
        self._install(sengine)
        self.server.bengine = self.bengine
        self.health.reset(sengine.n_shards)
        self._reseed_budgeter(sengine.n_shards)
        self.planner.committed(cuts)

    def save_shards(self, path: str, overwrite: bool = False) -> str:
        """Persist the live shard layout as an ``index_io`` artifact.

        Records the range cuts and the source index fingerprint, so a later
        ``start_reshard(shards_path=...)`` — possibly in a fresh process —
        can re-stack from disk and refuse a stale artifact.
        """
        from repro import index_io

        return index_io.save_shards(
            self.sengine.shards,
            path,
            quantizer=self.engine.index.quantizer,
            source_fingerprint=self.engine.index.fingerprint(),
            overwrite=overwrite,
        )

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        """JSON-able operating snapshot for dashboards and benchmarks."""
        return {
            "n_shards": self.n_shards,
            "n_replicas": self.n_replicas,
            "cuts": self.sengine.cuts.tolist(),
            "replica_mesh": bool(
                self.replicas is not None and self.replicas.group_mesh is not None
            ),
            "health": self.health.snapshot(),
            "load_ewma": self.planner.load.tolist(),
            "imbalance": round(self.planner.imbalance(), 4),
            "reshard_in_flight": (
                self.reshard_task.stage if self.reshard_task else None
            ),
            "reshard_deferred": self.deferred_reshard is not None,
            "journal": self.journal.path if self.journal else None,
            "reshards_completed": self.reshards_completed,
            "batches_served": self.batches_served,
            "queries_served": self.queries_served,
            "queries_served_during_reshard": self.queries_served_during_reshard,
            "alpha": round(float(self.budgeter.policy.alpha), 4),
            "degraded_slo": self.degraded_slo,
            "skew_alert": self._skew_alert,
            "alerts_firing": (
                self.monitor.firing() if self.monitor is not None else []
            ),
        }
