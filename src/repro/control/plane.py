"""The anytime serving control plane (DESIGN.md §9).

``ControlPlane`` sits above the §3/§4 serving stack and owns the pieces
that have to outlive any single engine: the request queue, the health
ledger, the reshard planner, and the live engine pointer. One object, four
cooperating behaviours:

  * **serving** — a ``MicroBatchServer`` loop (submit / drain) over the
    live engine: a ``ReplicaGroupEngine`` when replicas are configured and
    healthy, the plain ``ShardedEngine`` path otherwise;
  * **budgeting** — a ``ShardedSlaBudgeter`` in BoundSum mode by default:
    each query's postings budget concentrates on the shards whose ranges
    can actually score for its terms;
  * **failover** — ``mark_down``/``mark_up`` drive the ledger; dead shards
    get zero-budget dispatch slots so every query still returns, with
    ``exact=False`` and a ``fidelity_bound`` widened by the dead shard's
    unprocessed BoundSum mass; recovery is automatic on ``mark_up``;
  * **reshard** — the planner watches per-shard load EWMAs fed by the
    serving loop; ``maybe_reshard`` (or an explicit ``start_reshard``)
    opens a staged ``ReshardTask`` whose ``step()`` runs between
    micro-batches, and the engine pointer swaps only when the successor is
    built and warm — serving never pauses, and post-cutover results are
    bitwise-equal to a fresh build at the new layout.
"""

from __future__ import annotations

import time

import numpy as np

from repro.control.health import HealthLedger
from repro.control.replica import ReplicaGroupEngine
from repro.control.reshard import ReshardPlanner, ReshardTask
from repro.core.clustered_index import range_postings_mass
from repro.core.range_daat import Engine
from repro.serving.bucketing import BucketSpec
from repro.serving.microbatch import MicroBatchServer, ShardedSlaBudgeter
from repro.serving.sharded import ShardedBatchEngine, ShardedEngine

__all__ = ["ControlPlane"]


class _PlaneServer(MicroBatchServer):
    """MicroBatchServer whose dispatch and feedback route via the plane."""

    def __init__(self, plane: "ControlPlane", **kwargs):
        super().__init__(plane.bengine, plane.budgeter, **kwargs)
        self.plane = plane

    def _run_batch(self, plans, budgets):
        return self.plane._dispatch(plans, budgets)

    def _observe(self, batch_ms, results):
        self.plane._observe(batch_ms, results)


class ControlPlane:
    """Replicated, reshardable, failure-tolerant anytime serving.

    ``n_replicas > 1`` builds a ``ReplicaGroupEngine`` over a
    (data x shard) mesh when the runtime has the devices (``use_mesh``
    as in ``ShardedEngine``: None = auto). ``budget_mode`` picks the
    ``ShardedSlaBudgeter`` allocation ("boundsum" default, "rate" for the
    §4 behaviour). ``sla_ms=inf`` serves unbudgeted (every query runs to
    safe/exhausted completion) — the mode the bitwise tests pin.
    """

    def __init__(
        self,
        engine: Engine,
        n_shards: int,
        n_replicas: int = 1,
        sla_ms: float = float("inf"),
        spec: BucketSpec | None = None,
        use_mesh: bool | None = None,
        budget_mode: str = "boundsum",
        reshard_trigger: float = 1.25,
        budgeter: ShardedSlaBudgeter | None = None,
        max_batch: int | None = None,
        clock=time.perf_counter,
    ):
        self.engine = engine
        self.n_replicas = n_replicas
        self.spec = spec or BucketSpec()
        self._use_mesh = use_mesh
        self.health = HealthLedger(n_shards, n_replicas)
        self._install(ShardedEngine(engine, n_shards, use_mesh=use_mesh))
        self.budgeter = budgeter or ShardedSlaBudgeter(
            sla_ms=sla_ms,
            n_shards=n_shards,
            mode=budget_mode,
            shard_mass=self._shard_mass,
        )
        self.planner = ReshardPlanner(
            range_mass=range_postings_mass(engine.index),
            cuts=self.sengine.cuts,
            trigger=reshard_trigger,
        )
        self.reshard_task: ReshardTask | None = None
        self.reshards_completed = 0
        self.batches_served = 0
        self.queries_served = 0
        self.queries_served_during_reshard = 0
        self.server = _PlaneServer(self, max_batch=max_batch, clock=clock)

    # ----------------------------------------------------------- installing
    def _install(self, sengine: ShardedEngine) -> None:
        """Point the plane at a (new) sharded engine + its replica group."""
        self.sengine = sengine
        self.replicas = (
            ReplicaGroupEngine(sengine, self.n_replicas, use_mesh=self._use_mesh)
            if self.n_replicas > 1
            else None
        )
        self.bengine = ShardedBatchEngine(self.replicas or sengine, self.spec)
        self.bengine_single = (
            ShardedBatchEngine(sengine, self.spec) if self.replicas else self.bengine
        )

    def _shard_mass(self, plan) -> np.ndarray:
        # Late-bound so a reshard swap retargets budget shaping too.
        return self.sengine.query_shard_mass(plan)

    @property
    def n_shards(self) -> int:
        return self.sengine.n_shards

    @property
    def cuts(self) -> np.ndarray:
        return self.sengine.cuts

    # -------------------------------------------------------------- serving
    def submit(self, q_terms: np.ndarray) -> int:
        return self.server.submit(q_terms)

    @property
    def pending(self) -> int:
        return self.server.pending

    def drain_once(self):
        """Serve one micro-batch, then advance any in-flight reshard.

        The reshard step runs strictly *between* dispatches, so the queue
        is never blocked behind cutover work; the swap happens here too,
        once the successor engine reports ready.
        """
        served = self.server.drain_once()
        self.batches_served += 1 if served else 0
        self.queries_served += len(served)
        if self.reshard_task is not None:
            if served:
                self.queries_served_during_reshard += len(served)
            self.reshard_task.step()
            if self.reshard_task.ready:
                self._cutover()
        return served

    def replay(self, queries, batch_size: int | None = None):
        """Offline replay through the plane's drain loop."""
        bs = max(1, min(batch_size or self.server.max_batch, self.server.max_batch))
        out = []
        for lo in range(0, len(queries), bs):
            for q in queries[lo : lo + bs]:
                self.submit(q)
            out.extend(self.drain_once())
        while self.pending:
            out.extend(self.drain_once())
        return out

    def _dispatch(self, plans, budgets):
        down = self.health.shard_down_mask()
        if (
            self.replicas is not None
            and self.health.n_healthy_replicas() < self.n_replicas
        ):
            # A degraded replica row cannot carry its slice of the batch;
            # reroute through the single-replica path (same math, fewer
            # devices) until the ledger clears — throughput, not fidelity.
            beng = self.bengine_single
        else:
            beng = self.bengine
        return beng.run_batch(
            plans,
            budget_postings=budgets,
            down_mask=down if down.any() else None,
        )

    def _observe(self, batch_ms, results) -> None:
        per_shard = np.sum([r.shard_postings for r in results], axis=0)
        up = ~self.health.shard_down_mask()
        self.budgeter.observe_sharded(
            batch_ms, per_shard, len(results), active_mask=up
        )
        # The reshard planner only learns from a healthy fleet: a down
        # shard's zero counters say nothing about where load lives, and
        # would otherwise decay its EWMA until an outage armed a spurious
        # (and wrong-direction) reshard.
        if up.all():
            self.planner.observe(per_shard, len(results))

    # ------------------------------------------------------------- failover
    def mark_down(self, shard: int, replica: int | None = None) -> None:
        self.health.mark_down(shard, replica)

    def mark_up(self, shard: int, replica: int | None = None) -> None:
        self.health.mark_up(shard, replica)

    # -------------------------------------------------------------- reshard
    def maybe_reshard(self) -> bool:
        """Open a staged reshard if the planner is armed; returns True then."""
        if self.reshard_task is not None or not self.planner.should_reshard():
            return False
        self.start_reshard(self.planner.propose())
        return True

    def start_reshard(
        self, cuts, shards_path: str | None = None, warm_widths=None
    ) -> ReshardTask:
        """Begin a live cutover to ``cuts``.

        Source arrays are the live engine's shards, or — with
        ``shards_path`` — a persisted ``index_io`` shard artifact, so a
        reshard can be driven entirely from disk without the full index.
        ``warm_widths`` pre-compiles those width buckets on the successor
        before the swap (defaults to every width the live engine has seen).
        """
        if self.reshard_task is not None:
            raise RuntimeError("a reshard is already in flight")
        cuts = np.asarray(cuts, np.int64)
        if np.array_equal(cuts, self.sengine.cuts):
            raise ValueError(f"cuts {cuts.tolist()} are already the live layout")
        if shards_path is not None:
            from repro import index_io

            src = index_io.read_manifest(shards_path).get("source_fingerprint")
            if src is None:
                # Same stance as ShardedEngine.from_artifact: an
                # unverifiable shard set is as dangerous as a stale one —
                # foreign arrays under the live planner serve garbage with
                # no error. Re-save with source_fingerprint= to opt in.
                raise index_io.ArtifactError(
                    f"shard artifact {shards_path} records no "
                    f"source_fingerprint; re-save with "
                    f"source_fingerprint=index.fingerprint()"
                )
            if src != self.engine.index.fingerprint():
                raise index_io.ArtifactError(
                    f"shard artifact {shards_path} was carved from index "
                    f"{src}, but the live index has fingerprint "
                    f"{self.engine.index.fingerprint()} — refusing to "
                    f"reshard from a stale layout"
                )
            source = index_io.load_shards(shards_path)
        else:
            source = self.sengine.shards
        if warm_widths is None:
            warm_widths = sorted({w for (_, w) in self.bengine.compiled_shapes})

        def build(new_shards):
            seng = ShardedEngine(
                self.engine,
                len(new_shards),
                use_mesh=self._use_mesh,
                shards=new_shards,
            )
            beng = ShardedBatchEngine(
                ReplicaGroupEngine(seng, self.n_replicas, use_mesh=self._use_mesh)
                if self.n_replicas > 1
                else seng,
                self.spec,
            )
            return seng, beng

        self.reshard_task = ReshardTask(source, cuts, build, warm_widths)
        return self.reshard_task

    def _cutover(self) -> None:
        """Atomic engine swap: the next micro-batch serves the new layout.

        The task's engines were built and warmed off the serving path, so
        the swap is pointer rebinding only. The health ledger resets —
        shard indices now name different range bands — and the planner
        adopts the new cuts with a fresh load EWMA.
        """
        task = self.reshard_task
        assert task is not None and task.ready
        self.sengine = task.sengine
        self.bengine = task.bengine
        self.replicas = task.bengine.sengine if self.n_replicas > 1 else None
        self.bengine_single = (
            ShardedBatchEngine(task.sengine, self.spec)
            if self.n_replicas > 1
            else task.bengine
        )
        self.server.bengine = self.bengine
        self.health.reset(task.n_shards)
        if self.budgeter.n_shards != task.n_shards:
            # A cutover may change the shard count; re-seed the per-shard
            # throughput EWMAs at the old mean so budgets stay sane.
            self.budgeter.n_shards = task.n_shards
            self.budgeter.rates = np.full(
                task.n_shards, float(np.mean(self.budgeter.rates)), np.float64
            )
        self.planner.committed(task.cuts)
        self.reshard_task = None
        self.reshards_completed += 1

    def save_shards(self, path: str, overwrite: bool = False) -> str:
        """Persist the live shard layout as an ``index_io`` artifact.

        Records the range cuts and the source index fingerprint, so a later
        ``start_reshard(shards_path=...)`` — possibly in a fresh process —
        can re-stack from disk and refuse a stale artifact.
        """
        from repro import index_io

        return index_io.save_shards(
            self.sengine.shards,
            path,
            quantizer=self.engine.index.quantizer,
            source_fingerprint=self.engine.index.fingerprint(),
            overwrite=overwrite,
        )

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        """JSON-able operating snapshot for dashboards and benchmarks."""
        return {
            "n_shards": self.n_shards,
            "n_replicas": self.n_replicas,
            "cuts": self.sengine.cuts.tolist(),
            "replica_mesh": bool(
                self.replicas is not None and self.replicas.group_mesh is not None
            ),
            "health": self.health.snapshot(),
            "load_ewma": self.planner.load.tolist(),
            "imbalance": round(self.planner.imbalance(), 4),
            "reshard_in_flight": (
                self.reshard_task.stage if self.reshard_task else None
            ),
            "reshards_completed": self.reshards_completed,
            "batches_served": self.batches_served,
            "queries_served": self.queries_served,
            "queries_served_during_reshard": self.queries_served_during_reshard,
            "alpha": round(float(self.budgeter.policy.alpha), 4),
        }
