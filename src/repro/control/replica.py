"""Replicated shard groups: a query-parallel ``data`` axis over the shard
mesh (DESIGN.md §9).

``ReplicaGroupEngine`` composes the §4 range-shard axis with the
query-parallel ``data`` axis whose collective shape ``serve/distributed_ir``
already established: a 2-D (data x shard) mesh where every row holds a full
copy of the sharded index and each row serves a slice of the micro-batch.
The dispatch body is the *same* program as the single-replica mesh path
(``serving.sharded.make_mesh_dispatch`` with ``data_axis=``): the per-query
traversal and the ``range_daat.merge_topk`` broker merge never see the
replica axis, so an N-replica dispatch is **bitwise identical** to serving
the same queries on one replica — replication buys throughput, never a
different answer.

Fallbacks keep the engine total: with fewer than ``n_replicas * n_shards``
devices the group serves through the wrapped ``ShardedEngine`` unchanged
(its vmap or 1-D mesh path), and the control plane drops to the same path
when the health ledger reports a degraded replica row.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import replica_mesh
from repro.serving.sharded import ShardedEngine, make_mesh_dispatch

__all__ = ["ReplicaGroupEngine"]


class ReplicaGroupEngine:
    """N data-parallel replicas of a ``ShardedEngine``.

    Drop-in wherever a ``ShardedEngine`` is accepted (``ShardedBatchEngine``
    takes either): planning, budget splitting, and result unpacking delegate
    to the wrapped engine; only ``dispatch`` changes, sharding the batch
    axis over the replica rows of a (data x shard) mesh. ``use_mesh``:
    None = auto (replicate when the runtime has n_replicas * n_shards
    devices), True = require the 2-D mesh, False = always fall back to the
    wrapped engine's own path (useful on one device, where replica rows
    cannot add throughput but the scheduling logic still runs).
    """

    def __init__(
        self,
        sengine: ShardedEngine,
        n_replicas: int,
        use_mesh: bool | None = None,
        data_axis: str = "data",
        mesh_axis: str = "shard",
    ):
        if n_replicas < 1:
            raise ValueError(f"need n_replicas >= 1, got {n_replicas}")
        self.sengine = sengine
        self.n_replicas = n_replicas
        self._data_axis = data_axis
        self._shard_axis = mesh_axis
        need = n_replicas * sengine.n_shards
        if use_mesh is None:
            use_mesh = n_replicas > 1 and jax.device_count() >= need
        self.group_mesh = (
            replica_mesh(n_replicas, sengine.n_shards, data_axis, mesh_axis)
            if use_mesh
            else None
        )
        self._group_fns: dict = {}
        self.dispatches = 0  # replica-mesh dispatches actually issued

    def __getattr__(self, name):
        # Everything but dispatch (and the replica plumbing above) is the
        # wrapped engine's: shard_plan, split_*_budget, _to_results, shards,
        # cuts, query_shard_mass, ... — the ShardedBatchEngine contract.
        return getattr(self.sengine, name)

    # ------------------------------------------------------------- dispatch
    def dispatch(
        self, blk, rest, order, bounds, budgets, maxr,
        safe_stop: bool = True, prune_blocks: bool = True,
    ):
        """Run one (batch x shard) step across all replica rows.

        The batch axis is padded to a multiple of ``n_replicas`` with inert
        zero-budget lanes (the §3 dummy-lane discipline) so it divides
        evenly over the ``data`` axis; pad lanes are sliced off the output.
        """
        if self.group_mesh is None:
            return self.sengine.dispatch(
                blk, rest, order, bounds, budgets, maxr,
                safe_stop=safe_stop, prune_blocks=prune_blocks,
            )
        n = blk.shape[0]
        pad = (-n) % self.n_replicas
        if pad:
            zb = lambda a: np.concatenate(  # noqa: E731
                [np.asarray(a), np.zeros((pad,) + np.asarray(a).shape[1:],
                                         np.asarray(a).dtype)]
            )
            blk = np.concatenate(
                [np.asarray(blk), np.full((pad,) + np.asarray(blk).shape[1:],
                                          -1, np.int32)]
            )
            rest, order, bounds = zb(rest), zb(order), zb(bounds)
            budgets, maxr = zb(budgets), zb(maxr)

        key = (safe_stop, prune_blocks)
        if key not in self._group_fns:
            se = self.sengine
            self._group_fns[key] = make_mesh_dispatch(
                self.group_mesh,
                self._shard_axis,
                s_pad=se.s_pad,
                k=se.k,
                safe_stop=safe_stop,
                prune_blocks=prune_blocks,
                impl=se.impl,
                interpret=se.interpret,
                data_axis=self._data_axis,
                docs_format=se.docs_format,
            )
        out = self._group_fns[key](
            self.sengine.dix,
            self.sengine.doc_base,
            jnp.asarray(blk),
            jnp.asarray(rest),
            jnp.asarray(order),
            jnp.asarray(bounds),
            jnp.asarray(budgets, jnp.int32),
            jnp.asarray(maxr, jnp.int32),
        )
        self.dispatches += 1
        if self.obs.enabled:  # delegates to the wrapped engine's handle
            self.obs.count("replica_dispatches")
            self.obs.observe("replica_pad_lanes", pad)
        if pad:
            out = _slice_pad(out, n)
        return out


def _slice_pad(out: tuple, n: int) -> tuple:
    """Drop pad lanes *on-device*: a lazy slice per leaf, so the dispatch
    stays asynchronous and the host copy happens at the caller's drain
    boundary (``_to_results``), not mid-dispatch."""
    return tuple(x[:n] for x in out)
