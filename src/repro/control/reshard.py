"""Online reshard: load-driven range cuts + staged live cutover (DESIGN.md §9).

The §4 planner balances *static* postings mass, but traffic is not static:
query terms cluster topically, so a shard whose ranges hold the hot topics
does more work per query than its mass share predicts. ``ReshardPlanner``
watches the per-shard postings observations the serving loop already
produces (``MicroBatchServer`` -> ``ShardedSlaBudgeter``), maintains a
load EWMA per shard, and — when the imbalance crosses a trigger — proposes
new cuts by re-balancing the per-range mass *scaled by each shard's
observed/expected load ratio*: ranges living in an overloaded shard get
heavier, so the §4 cut balancer naturally shrinks that shard's band.

``ReshardTask`` executes the cutover without a serving pause: the work is
cut into small host-side steps (re-stack one shard per step via
``core.clustered_index.restack_shards`` — no full index rebuild, the
source arrays are the old shards or an ``index_io`` shard artifact — then
build the new engine, then pre-compile its programs one shape at a time).
The serving loop interleaves ``step()`` calls between micro-batches and
swaps engines only when the task reports ready; queries issued at any
point are served by whichever layout is live, and post-cutover results are
bitwise-equal to a fresh build at the new layout because ``restack_shards``
reproduces ``shard_device_index(cuts=...)`` array-for-array.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.clustered_index import (
    IndexShard,
    balance_range_shards,
    restack_prep,
    restack_shards,
)

__all__ = ["ReshardPlanner", "ReshardTask"]


@dataclasses.dataclass
class ReshardPlanner:
    """Per-shard load EWMAs -> proposed range cuts.

    ``range_mass`` is the static per-range postings mass ([R], the §4
    partitioning weight); ``cuts`` the live layout. ``observe`` feeds the
    per-shard postings actually traversed for a served batch — the same
    numbers ``ShardedSlaBudgeter.observe_sharded`` consumes.
    """

    range_mass: np.ndarray  # [R] int64 static postings mass per range
    cuts: np.ndarray  # [S + 1] current layout
    ema: float = 0.3
    trigger: float = 1.25  # max/mean load ratio that arms a reshard

    def __post_init__(self):
        self.range_mass = np.asarray(self.range_mass, np.int64)
        self.cuts = np.asarray(self.cuts, np.int64)
        self.load = np.zeros(self.n_shards, np.float64)  # postings/query EWMA
        self.batches_seen = 0

    @property
    def n_shards(self) -> int:
        return int(self.cuts.shape[0] - 1)

    # ----------------------------------------------------------- observing
    def observe(self, shard_postings: Sequence[float], n_queries: int) -> None:
        """Feed one served batch's per-shard postings counters."""
        if n_queries <= 0:
            return
        per_q = np.asarray(shard_postings, np.float64) / n_queries
        if per_q.shape != (self.n_shards,):
            raise ValueError(
                f"shard_postings shape {per_q.shape} != ({self.n_shards},)"
            )
        if self.batches_seen == 0:
            self.load = per_q
        else:
            self.load = (1 - self.ema) * self.load + self.ema * per_q
        self.batches_seen += 1

    def imbalance(self) -> float:
        """max/mean observed per-shard load (1.0 = perfectly even)."""
        mean = float(self.load.mean())
        if mean <= 0:
            return 1.0
        return float(self.load.max()) / mean

    # ------------------------------------------------------------ proposing
    def propose(self) -> np.ndarray:
        """New cuts balancing load-scaled range mass.

        Each shard's observed/expected ratio (load share over static mass
        share) scales the mass of its ranges; ``balance_range_shards`` then
        re-cuts the scaled mass. With no observations (or uniform load)
        this degenerates to the static §4 cut.
        """
        mass = np.maximum(self.range_mass, 1).astype(np.float64)
        if self.batches_seen and self.load.sum() > 0:
            static = mass.copy()  # freeze shares before any band is scaled
            load_share = self.load / self.load.sum()
            for s in range(self.n_shards):
                lo, hi = int(self.cuts[s]), int(self.cuts[s + 1])
                mass_share = static[lo:hi].sum() / static.sum()
                scale = load_share[s] / max(mass_share, 1e-12)
                mass[lo:hi] *= max(scale, 1e-6)
        weights = np.maximum(np.round(mass), 1).astype(np.int64)
        return balance_range_shards(weights, self.n_shards)

    def should_reshard(self) -> bool:
        """Armed when load is imbalanced AND the proposal actually moves a cut."""
        if self.batches_seen == 0 or self.imbalance() < self.trigger:
            return False
        return not np.array_equal(self.propose(), self.cuts)

    def committed(self, cuts: np.ndarray) -> None:
        """Adopt a new live layout (called by the plane after the cutover).

        The load EWMA is reset: old per-shard observations are measured
        against boundaries that no longer exist.
        """
        self.cuts = np.asarray(cuts, np.int64)
        self.load = np.zeros(self.n_shards, np.float64)
        self.batches_seen = 0


class ReshardTask:
    """Staged cutover to ``cuts``: a few milliseconds of work per ``step()``.

    Stages (one unit each): re-stack one new shard from the source shards;
    construct the successor engine; pre-compile one (width, batch-ladder)
    program group. ``ready`` turns True when the successor can serve
    every shape the caller warms — the plane then swaps atomically between
    micro-batches. The old engine is untouched throughout, so serving never
    pauses and a mid-flight abort costs nothing.
    """

    def __init__(
        self,
        source_shards: list[IndexShard],
        cuts: np.ndarray,
        build_engine,  # list[IndexShard] -> (sengine, bengine)
        warm_widths: Sequence[int] = (),
    ):
        self.cuts = np.asarray(cuts, np.int64)
        self._source = list(source_shards)
        # Validates source contiguity and the cuts *now*, so a malformed
        # layout fails at start_reshard time, never mid-serving; the
        # prepared geometry is reused by every carve step.
        self._prep = restack_prep(self._source, self.cuts)
        self._build_engine = build_engine
        self._warm = list(warm_widths)
        self.new_shards: list[IndexShard] = []
        self.sengine = None
        self.bengine = None
        self.steps_done = 0
        self._stage = "carve"

    @property
    def n_shards(self) -> int:
        return int(self.cuts.shape[0] - 1)

    @property
    def stage(self) -> str:
        return self._stage

    @property
    def ready(self) -> bool:
        return self._stage == "ready"

    def step(self) -> str:
        """Advance one unit of cutover work; returns the stage just run."""
        if self._stage == "carve":
            s = len(self.new_shards)
            (piece,) = restack_shards(
                self._source, self.cuts, only=s, prep=self._prep
            )
            self.new_shards.append(piece)
            self.steps_done += 1
            if len(self.new_shards) == self.n_shards:
                self._stage = "build"
            return "carve"
        if self._stage == "build":
            self.sengine, self.bengine = self._build_engine(self.new_shards)
            self.steps_done += 1
            self._stage = "warm" if self._warm else "ready"
            return "build"
        if self._stage == "warm":
            width = self._warm.pop(0)
            self.bengine.warmup([width])
            self.steps_done += 1
            if not self._warm:
                self._stage = "ready"
            return "warm"
        return "ready"
