# The paper's primary contribution: cluster-skipping document-ordered
# indexes with BoundSum range selection and anytime SLA-governed traversal.
from repro.core.anytime import (  # noqa: F401
    AnytimeResult,
    Fixed,
    Overshoot,
    Predictive,
    Reactive,
    Undershoot,
    run_query_anytime,
)
from repro.core.clustered_index import BLOCK, ClusteredIndex, build_index  # noqa: F401
from repro.core.range_daat import (  # noqa: F401
    Engine,
    TopKState,
    TraverseResult,
    batched_traverse,
    device_traverse,
)
from repro.core.reorder import Arrangement, arrange  # noqa: F401
