"""Anytime termination policies and the SLA-governed executor (paper §6).

Policies make a go/no-go decision *before each range* from monitored elapsed
time only — no feature-based latency prediction (§6.1 "Online Latency
Monitoring"). The Reactive policy adds the paper's Eq. (7) multiplicative
feedback on alpha after every query, turning the SLA percentile into a
target as well as a limit (§6.4).

The executor is host-driven: one jitted device step per range, wall-clock
measured between steps (std::chrono::steady_clock -> time.perf_counter).
This is exactly how the loop would drive a real TPU; on this container the
"device" is CPU XLA, so absolute times are only meaningful relative to each
other and SLA budgets in experiments are scaled accordingly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core.range_daat import Engine, QueryPlan, TopKState, theta

__all__ = [
    "Fixed",
    "Overshoot",
    "Undershoot",
    "Predictive",
    "Reactive",
    "AnytimeResult",
    "run_query_anytime",
]


class Policy:
    """Decide whether to Continue (True) given monitoring state."""

    def decide(self, t_ms: float, i: int, budget_ms: float) -> bool:
        raise NotImplementedError

    def on_query_end(self, t_ms: float, budget_ms: float) -> None:  # Reactive hook
        pass

    @property
    def name(self) -> str:
        return type(self).__name__


@dataclasses.dataclass
class Fixed(Policy):
    """Process at most n ranges (Fixed-n)."""

    n: int

    def decide(self, t_ms: float, i: int, budget_ms: float) -> bool:
        return i < self.n

    @property
    def name(self) -> str:
        return f"Fixed-{self.n}"


class Overshoot(Policy):
    """Continue while t_i < B — risks one range's overshoot (Eq. 3)."""

    def decide(self, t_ms: float, i: int, budget_ms: float) -> bool:
        return t_ms < budget_ms


@dataclasses.dataclass
class Undershoot(Policy):
    """Continue while t_i + t_max < B (Eq. 4) — never violates, may waste."""

    t_max_ms: float = 5.0

    def decide(self, t_ms: float, i: int, budget_ms: float) -> bool:
        return t_ms + self.t_max_ms < budget_ms


@dataclasses.dataclass
class Predictive(Policy):
    """Continue while t_i + alpha * (t_i / i) < B (Eq. 5)."""

    alpha: float = 1.0

    def decide(self, t_ms: float, i: int, budget_ms: float) -> bool:
        if i == 0:
            return True
        return t_ms + self.alpha * (t_ms / i) < budget_ms

    @property
    def name(self) -> str:
        return f"Predictive-a{self.alpha:g}"


@dataclasses.dataclass
class Reactive(Policy):
    """Predictive plus Eq. (7) feedback: alpha *= beta on an SLA miss,
    alpha *= (1/beta)^Q on a within-limit query (Q = SLA tolerance)."""

    alpha: float = 1.0
    beta: float = 1.2
    q: float = 0.01
    alpha_min: float = 0.1
    alpha_max: float = 64.0
    trace: list = dataclasses.field(default_factory=list)

    def decide(self, t_ms: float, i: int, budget_ms: float) -> bool:
        if i == 0:
            return True
        return t_ms + self.alpha * (t_ms / i) < budget_ms

    def on_query_end(self, t_ms: float, budget_ms: float) -> None:
        if t_ms > budget_ms:
            self.alpha *= self.beta
        else:
            self.alpha *= (1.0 / self.beta) ** self.q
        self.alpha = min(max(self.alpha, self.alpha_min), self.alpha_max)
        self.trace.append(self.alpha)

    @property
    def name(self) -> str:
        return f"Reactive-b{self.beta:g}"


@dataclasses.dataclass
class AnytimeResult:
    doc_ids: np.ndarray
    scores: np.ndarray
    elapsed_ms: float
    ranges_processed: int
    exit_reason: str  # "exhausted" | "safe" | "policy"
    range_times_ms: list
    postings: int
    blocks: int


def run_query_anytime(
    engine: Engine,
    plan: QueryPlan,
    policy: Optional[Policy] = None,
    budget_ms: float = float("inf"),
    safe_stop: bool = True,
    clock=time.perf_counter,
) -> AnytimeResult:
    """Host-driven anytime traversal of one query under an SLA budget."""
    state: TopKState = engine.init_state()
    n_ranges = plan.order_host.shape[0]
    t0 = clock()
    times: list[float] = []
    exit_reason = "exhausted"
    processed = 0

    for i in range(n_ranges):
        th = int(np.asarray(theta(state)))
        if safe_stop and th > 0 and plan.bounds_host[i] <= th:
            exit_reason = "safe"
            break
        elapsed = (clock() - t0) * 1e3
        if policy is not None and not policy.decide(elapsed, i, budget_ms):
            exit_reason = "policy"
            break
        state = engine.step(plan, state, i)
        # analysis: allow[HOSTSYNC] per-range latency measurement is the
        # point of the reference anytime loop (paper Alg. 2 timing).
        state.vals.block_until_ready()
        times.append((clock() - t0) * 1e3 - sum(times))
        processed += 1

    total = (clock() - t0) * 1e3
    if policy is not None:
        policy.on_query_end(total, budget_ms)

    ids, scores = engine.topk_docs(state)
    order = np.lexsort((ids, -scores))
    return AnytimeResult(
        doc_ids=ids[order],
        scores=scores[order],
        elapsed_ms=total,
        ranges_processed=processed,
        exit_reason=exit_reason,
        range_times_ms=times,
        postings=int(np.asarray(state.postings)),
        blocks=int(np.asarray(state.blocks)),
    )
