"""BM25 term-document contributions (paper §4.3: k1=0.4, b=0.9).

The first-stage ranker is additive over query terms:
    S(Q, d) = sum_t C(t, d)
with the BM25 contribution
    C(t, d) = idf(t) * tf * (k1 + 1) / (tf + k1 * (1 - b + b * len_d / avg_len))

All contributions are computed once at index-build time (numpy, host side)
and quantized to b-bit integer *impacts* (see quantize.py) — the engine then
works in integer space end-to-end, exactly like the paper's JASS arm, and the
PISA arm's float scores are a monotone rescaling of the same values.

Collection statistics (df, N, average document length) may be *frozen* as a
``CollectionStats`` and passed back in: incremental index extension
(DESIGN.md §10) scores appended documents against the statistics of the base
build, so existing postings keep bit-identical impacts — the classic
stale-statistics convention of updatable inverted indexes, refreshed only by
a full rebuild.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.synth import Corpus

__all__ = [
    "BM25Params",
    "CollectionStats",
    "bm25_contributions",
    "checked_int32",
    "collection_stats",
    "invert",
]

_INT32_MAX = np.iinfo(np.int32).max


def checked_int32(arr: np.ndarray, what: str = "values") -> np.ndarray:
    """Narrow to int32, raising instead of wrapping past 2^31-1.

    Build-time counterpart of ``serving.bucketing.saturate_bounds``: a
    docid or bound that silently wraps negative disables the engine's
    safe-termination test (``bound <= theta`` holds everywhere), so a
    corpus past the int32 docid space must fail the build loudly, not
    corrupt the index.
    """
    a = np.asarray(arr)
    if a.size and (int(a.max()) > _INT32_MAX or int(a.min()) < 0):
        raise OverflowError(
            f"{what} outside the int32 range [0, {_INT32_MAX}] "
            f"(min {int(a.min())}, max {int(a.max())}) — the document-"
            f"ordered index addresses docids/bounds in int32"
        )
    return a.astype(np.int32)


@dataclasses.dataclass(frozen=True)
class BM25Params:
    k1: float = 0.4
    b: float = 0.9


@dataclasses.dataclass(frozen=True)
class CollectionStats:
    """Frozen collection-level BM25 statistics.

    Captured at base-build time and carried through every incremental
    extension: idf and the length normalization of appended postings use
    *these* values, never the extended collection's, which is what keeps a
    compacted chain bitwise-equal to one fresh build at the same stats
    (DESIGN.md §10).
    """

    n_docs: int
    avg_doc_len: float
    df: np.ndarray  # [n_terms] int64 document frequency per term


def collection_stats(corpus: Corpus) -> CollectionStats:
    """Compute ``CollectionStats`` from a corpus (the base-build path)."""
    df = np.zeros(corpus.n_terms, dtype=np.int64)
    np.add.at(df, corpus.doc_terms, 1)
    return CollectionStats(
        n_docs=int(corpus.n_docs),
        avg_doc_len=(
            float(max(corpus.doc_lens.astype(np.float64).mean(), 1.0))
            if corpus.n_docs
            else 1.0
        ),
        df=df,
    )


@dataclasses.dataclass(frozen=True)
class Postings:
    """Document-ordered postings in CSR-by-term layout."""

    n_terms: int
    n_docs: int
    ptr: np.ndarray  # [n_terms+1] int64
    docs: np.ndarray  # [nnz] int32, ascending within each term
    scores: np.ndarray  # [nnz] float32, BM25 contribution C(t, d)

    @property
    def nnz(self) -> int:
        return int(self.docs.shape[0])

    def term_slice(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = self.ptr[t], self.ptr[t + 1]
        return self.docs[s:e], self.scores[s:e]


def bm25_contributions(
    corpus: Corpus,
    params: BM25Params = BM25Params(),
    stats: CollectionStats | None = None,
) -> np.ndarray:
    """Per-posting BM25 contribution aligned with corpus CSR order.

    ``stats`` substitutes frozen collection statistics (df, N, avg length)
    for the ones this corpus would yield — document lengths still come from
    the corpus itself. Incremental extension scores a delta corpus this way.
    """
    doc_lens = corpus.doc_lens.astype(np.float64)
    if stats is None:
        stats = collection_stats(corpus)
    avg_len = stats.avg_doc_len
    df = np.asarray(stats.df, dtype=np.int64)
    if df.shape != (corpus.n_terms,):
        raise ValueError(
            f"stats.df has shape {df.shape}, corpus has {corpus.n_terms} terms"
        )
    # Lucene/Anserini-style non-negative idf.
    idf = np.log(1.0 + (stats.n_docs - df + 0.5) / (df + 0.5))

    doc_of_posting = np.repeat(np.arange(corpus.n_docs), np.diff(corpus.doc_ptr))
    tf = corpus.doc_tfs.astype(np.float64)
    norm = params.k1 * (1.0 - params.b + params.b * doc_lens[doc_of_posting] / avg_len)
    contrib = idf[corpus.doc_terms] * tf * (params.k1 + 1.0) / (tf + norm)
    return contrib.astype(np.float32)


def invert(
    corpus: Corpus,
    doc_order: np.ndarray | None = None,
    params: BM25Params = BM25Params(),
    stats: CollectionStats | None = None,
) -> Postings:
    """Build document-ordered postings under a docid permutation.

    ``doc_order[new_id] = old_id`` — i.e. the permutation produced by the
    reordering stage. Postings come out sorted by (term, new docid).
    ``stats`` scores against frozen collection statistics (see
    :func:`bm25_contributions`).
    """
    contrib = bm25_contributions(corpus, params, stats=stats)
    doc_of_posting = np.repeat(
        np.arange(corpus.n_docs), np.diff(corpus.doc_ptr)
    ).astype(np.int64)
    if doc_order is None:
        new_ids = doc_of_posting
    else:
        inv = np.empty(corpus.n_docs, dtype=np.int64)
        inv[doc_order] = np.arange(corpus.n_docs)
        new_ids = inv[doc_of_posting]

    terms = corpus.doc_terms.astype(np.int64)
    key = terms * corpus.n_docs + new_ids
    order = np.argsort(key, kind="stable")
    sorted_terms = terms[order]
    ptr = np.zeros(corpus.n_terms + 1, dtype=np.int64)
    counts = np.bincount(sorted_terms, minlength=corpus.n_terms)
    ptr[1:] = np.cumsum(counts)
    return Postings(
        n_terms=corpus.n_terms,
        n_docs=corpus.n_docs,
        ptr=ptr,
        docs=checked_int32(new_ids[order], "postings docids"),
        scores=contrib[order],
    )
