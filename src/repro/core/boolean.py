"""Boolean conjunction over the clustered index (paper §3, closing remark).

"The modified index structure can still support traditional querying modes,
such as efficient Boolean conjunction." — the cluster-skipping structure
helps conjunctions directly: a range where ANY query term has no postings
(U[t, r] == 0) cannot contain a conjunctive match and is skipped without
touching postings; within surviving ranges, sorted-docid intersection runs
per-range (cache/VMEM-local, like the scorer).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.clustered_index import ClusteredIndex

__all__ = ["conjunctive_query"]


@dataclasses.dataclass
class BooleanResult:
    doc_ids: np.ndarray
    ranges_skipped: int
    ranges_visited: int
    postings_touched: int


def conjunctive_query(index: ClusteredIndex, q_terms) -> BooleanResult:
    """Docids containing ALL query terms, via range-skipped intersection."""
    terms = [int(t) for t in np.asarray(q_terms).reshape(-1) if t >= 0]
    if not terms:
        return BooleanResult(np.empty(0, np.int64), 0, 0, 0)

    # Range skip: conjunctions need every term present in the range.
    present = index.bounds_dense[terms] > 0  # [|q|, R]
    survivors = np.nonzero(present.all(axis=0))[0]
    skipped = index.n_ranges - survivors.size

    out: list[np.ndarray] = []
    touched = 0
    range_of = None
    for r in survivors:
        lo, hi = int(index.range_starts[r]), int(index.range_ends[r])
        cur: np.ndarray | None = None
        for t in terms:
            s, e = index.ptr[t], index.ptr[t + 1]
            d = index.docs[s:e]
            # SeekGEQ both ways: binary search the range's docid window.
            a = np.searchsorted(d, lo, side="left")
            b = np.searchsorted(d, hi, side="left")
            seg = d[a:b]
            touched += seg.shape[0]
            cur = seg if cur is None else np.intersect1d(cur, seg, assume_unique=True)
            if cur.size == 0:
                break
        if cur is not None and cur.size:
            out.append(cur.astype(np.int64))
    del range_of
    ids = np.concatenate(out) if out else np.empty(0, np.int64)
    return BooleanResult(
        doc_ids=np.sort(ids),
        ranges_skipped=int(skipped),
        ranges_visited=int(survivors.size),
        postings_touched=int(touched),
    )
