"""BoundSum range selection (paper §3 "Range Selection").

For query q and ranges 1..R, score(r) = sum_{t in q} U[t, r]; ranges are
processed in decreasing score order. The whole computation is an R-vector
gather-sum per term plus one sort — the paper's point is that this is cheap
enough to run inline at query time (unlike a CSI or a learned LTRR model),
and its cost IS included in all our measurements, as in the paper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["bound_sums", "range_order"]


@jax.jit
def bound_sums(bounds_dense: jnp.ndarray, q_terms: jnp.ndarray) -> jnp.ndarray:
    """Sum of per-range upper bounds over query terms. q_terms -1-padded."""
    valid = (q_terms >= 0)[:, None]
    rows = bounds_dense[jnp.clip(q_terms, 0, bounds_dense.shape[0] - 1)]
    return jnp.sum(jnp.where(valid, rows, 0), axis=0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("descending",))
def range_order(bsums: jnp.ndarray, descending: bool = True):
    """Sorted range ids and their bounds (ties broken by range id)."""
    key = -bsums if descending else bsums
    order = jnp.argsort(key, stable=True).astype(jnp.int32)
    return order, bsums[order]
