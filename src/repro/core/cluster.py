"""Topical clustering of documents (paper §3 "Document Arrangement").

The paper uses the QKLD-QInit clusters of Dai et al. [17]. We implement the
same *shape* of pipeline at index-build time: tf-idf document vectors,
dimensionality reduction by signed feature hashing (deterministic), spherical
k-means with kmeans++-style sampled init. Output is a cluster id per document;
the index builder turns clusters into contiguous docid ranges.

Runs in numpy on the host — clustering is an offline index-construction step,
not a query-time component, exactly as in the paper.
"""

from __future__ import annotations

import numpy as np

from repro.data.synth import Corpus

__all__ = ["hashed_tfidf", "spherical_kmeans", "topical_clusters"]


def hashed_tfidf(
    corpus: Corpus, dim: int = 256, seed: int = 7, stop_df_frac: float = 0.10
) -> np.ndarray:
    """Dense Gaussian random projection of tf-idf vectors, L2-normed.

    Terms appearing in more than ``stop_df_frac`` of documents are dropped
    (stopping — the paper's corpora are stemmed *and stopped*, so stopword
    mass never reaches clustering features there either). A dense Gaussian
    projection preserves cosine structure far better than single-slot
    feature hashing (collisions destroy the weak per-term signal); the
    per-posting accumulation uses reduceat over the CSR layout, chunked
    over feature dims to bound memory.
    """
    rng = np.random.default_rng(seed)
    proj = rng.standard_normal((corpus.n_terms, dim)).astype(np.float32)
    proj /= np.sqrt(dim)

    df = np.zeros(corpus.n_terms, dtype=np.int64)
    np.add.at(df, corpus.doc_terms, 1)
    idf = np.log(1.0 + corpus.n_docs / np.maximum(df, 1)).astype(np.float32)
    stopped = df > stop_df_frac * corpus.n_docs

    w = (1.0 + np.log(np.maximum(corpus.doc_tfs, 1))).astype(np.float32)
    w *= idf[corpus.doc_terms]
    w *= ~stopped[corpus.doc_terms]

    out = np.zeros((corpus.n_docs, dim), dtype=np.float32)
    starts = corpus.doc_ptr[:-1]
    nonempty = np.diff(corpus.doc_ptr) > 0
    chunk = max(32, min(dim, (1 << 27) // max(corpus.nnz, 1)))  # ~512MB cap
    for lo in range(0, dim, chunk):
        hi = min(lo + chunk, dim)
        vals = w[:, None] * proj[corpus.doc_terms, lo:hi]
        acc = np.add.reduceat(vals, starts.clip(max=max(corpus.nnz - 1, 0)), axis=0)
        out[nonempty, lo:hi] = acc[nonempty]
    norms = np.linalg.norm(out, axis=1, keepdims=True)
    return out / np.maximum(norms, 1e-9)


def spherical_kmeans(
    x: np.ndarray, k: int, iters: int = 25, seed: int = 11
) -> np.ndarray:
    """Spherical k-means; returns cluster id per row. Deterministic."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    k = min(k, n)
    # kmeans++-ish init on a sample.
    sample = rng.choice(n, size=min(n, 4096), replace=False)
    centers = [x[sample[rng.integers(sample.size)]]]
    for _ in range(k - 1):
        sims = np.max(np.stack([x[sample] @ c for c in centers], 0), 0)
        d2 = np.maximum(1.0 - sims, 1e-9)
        p = d2 / d2.sum()
        centers.append(x[sample[rng.choice(sample.size, p=p)]])
    c = np.stack(centers, 0)  # [k, dim]

    assign = np.zeros(n, dtype=np.int32)
    for _ in range(iters):
        sims = x @ c.T  # [n, k]
        new_assign = np.argmax(sims, axis=1).astype(np.int32)
        if np.array_equal(new_assign, assign):
            assign = new_assign
            break
        assign = new_assign
        for j in range(k):
            rows = x[assign == j]
            if rows.shape[0] == 0:
                # Re-seed empty cluster at the point farthest from its center.
                worst = np.argmin(np.max(sims, axis=1))
                c[j] = x[worst]
            else:
                m = rows.sum(0)
                c[j] = m / max(np.linalg.norm(m), 1e-9)
    return assign


def topical_clusters(
    corpus: Corpus, n_clusters: int, dim: int = 256, iters: int = 25, seed: int = 7
) -> np.ndarray:
    """Cluster id per document via hashed tf-idf + spherical k-means."""
    x = hashed_tfidf(corpus, dim=dim, seed=seed)
    return spherical_kmeans(x, n_clusters, iters=iters, seed=seed + 1)
