"""Cluster-skipping inverted index (paper §3, Figs 2-4).

Host-side structure (numpy, built offline):

  * document-ordered postings, CSR by term, docids under the arrangement's
    permutation, impacts globally quantized to b bits;
  * fixed-width posting *blocks* (BLOCK=128, the paper's SIMD-BP128 geometry
    and the TPU lane width) that never cross a range boundary, each with
    max-docid and max-impact metadata — this is the skip structure that makes
    SeekGEQ an O(1) indexed access in either direction;
  * a (term, range) directory with the per-range upper bounds U[t, r] used by
    BoundSum and by safe early termination;
  * the cluster map (range_ends) — the paper's C vector.

Device-side mirror (`DeviceIndex`) holds flat jnp arrays; traversal code in
range_daat.py / saat.py consumes it. TPU adaptation notes in DESIGN.md §2.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os

import numpy as np

from repro.core.bm25 import (
    BM25Params,
    CollectionStats,
    checked_int32,
    collection_stats,
    invert,
)
from repro.core.quantize import Quantizer, fit_quantizer
from repro.core.reorder import Arrangement, arrange
from repro.data.synth import Corpus

BLOCK = 128

# Legal per-block packed widths (bits per docid delta). Every width divides
# 32, so no delta ever straddles a uint32 word (lane j occupies bits
# [j*w, (j+1)*w) of the block's word run and j*w % 32 + w <= 32 for every w
# here); 24 is excluded exactly because lane 1 would straddle a word
# boundary. The tuple doubles as the width-code table: the device directory
# stores ``index(PACK_WIDTHS, w)`` in the top bits of each entry.
PACK_WIDTHS = (0, 4, 8, 16, 32)

# Device directory entry layout (DESIGN.md §12): bits [0, PACK_DIR_BITS)
# hold the block's word offset, bits above hold its PACK_WIDTHS code. Caps
# the packed stream at 2^27 words = 512 MiB per engine/shard upload.
PACK_DIR_BITS = 27

__all__ = [
    "BLOCK",
    "PACK_DIR_BITS",
    "PACK_WIDTHS",
    "ClusteredIndex",
    "IndexDelta",
    "IndexShard",
    "PackedPostings",
    "apply_delta",
    "balance_range_shards",
    "build_index",
    "build_index_cached",
    "device_bytes_report",
    "pack_dir_entries",
    "pack_docs",
    "unpack_docs",
    "extend_index",
    "extended_arrangement",
    "plan_delta",
    "range_postings_mass",
    "restack_prep",
    "restack_shards",
    "shard_cuts",
    "shard_device_index",
]


def device_bytes_report(
    nnz: int,
    n_blocks: int,
    n_terms: int,
    n_ranges: int,
    impact_dtype: str = "int32",
    docs_format: str = "int32",
    n_pack_words: int = 0,
) -> dict[str, int]:
    """HBM bytes of a ``DeviceIndex`` upload from index dimensions alone.

    The single source of the accounting formula: the built index delegates
    here (``ClusteredIndex.device_bytes``), and artifact tooling computes
    the same report straight from manifest metadata without loading any
    array (``python -m repro.index_io inspect``).

    With ``docs_format="packed"`` the ``docs`` entry covers the bit-packed
    delta word stream plus its per-block (word_start, width, first_doc)
    directory (DESIGN.md §12) — the int32 docid array is not uploaded.
    """
    if impact_dtype not in ("int32", "int8"):
        raise ValueError(f"impact_dtype {impact_dtype!r} not in ('int32', 'int8')")
    if docs_format not in ("int32", "packed"):
        raise ValueError(f"docs_format {docs_format!r} not in ('int32', 'packed')")
    imp_itemsize = 1 if impact_dtype == "int8" else 4
    if docs_format == "packed":
        # Word stream + the two int32 directory columns the engine uploads:
        # (word_start | width_code << PACK_DIR_BITS) and the first docid.
        docs_bytes = n_pack_words * 4 + 2 * n_blocks * 4
    else:
        docs_bytes = nnz * 4
    out = {
        "docs": docs_bytes,
        "impacts": nnz * imp_itemsize,
        "blk_start": n_blocks * 4,
        "blk_len": n_blocks * 4,
        "blk_maximp": n_blocks * 4,
        "bounds_dense": n_terms * n_ranges * 4,
        "range_starts": n_ranges * 4,
        "range_sizes": n_ranges * 4,
    }
    out["postings"] = out["docs"] + out["impacts"]
    out["total"] = sum(v for k, v in out.items() if k != "postings")
    return out


@dataclasses.dataclass(frozen=True)
class PackedPostings:
    """Per-block fixed-width bit-packed docid deltas (DESIGN.md §12).

    Block ``b`` stores its ``blk_len[b]`` docid deltas (``delta_0 = 0``
    explicitly, so lane ``j`` always reads bits ``[j*w, (j+1)*w)`` of the
    block's word run) at ``blk_width[b]`` bits each, starting at word
    ``blk_word_start[b]`` of the shared uint32 ``words`` stream; the
    absolute first docid lives out-of-band in ``blk_first``. Widths come
    from ``PACK_WIDTHS`` — the smallest that covers the block's max delta —
    so constant runs cost zero stream words.
    """

    words: np.ndarray  # [n_words] uint32 — packed delta stream
    blk_word_start: np.ndarray  # [NB] int64 — word offset per block
    blk_width: np.ndarray  # [NB] int32 — bits per delta (PACK_WIDTHS)
    blk_first: np.ndarray  # [NB] int32 — absolute first docid (0 if empty)
    n_postings: int

    @property
    def n_words(self) -> int:
        return int(self.words.shape[0])

    @property
    def n_blocks(self) -> int:
        return int(self.blk_word_start.shape[0])

    def device_nbytes(self) -> int:
        """Bytes of the device upload: word stream + merged int32 directory
        (:func:`pack_dir_entries`) + first-docid column."""
        return self.n_words * 4 + 2 * self.n_blocks * 4


def _segment_arange(lens: np.ndarray) -> np.ndarray:
    """[0..l0), [0..l1), ... as one flat int64 array."""
    lens = np.asarray(lens, np.int64)
    total = int(lens.sum())
    ends = np.cumsum(lens)
    return np.arange(total, dtype=np.int64) - np.repeat(ends - lens, lens)


def pack_docs(
    docs: np.ndarray, blk_start: np.ndarray, blk_len: np.ndarray
) -> PackedPostings:
    """Bit-pack per-block docid deltas into a uint32 word stream.

    Width selection: the smallest of ``PACK_WIDTHS`` covering the block's
    max delta (0 for constant runs — including every single-posting block).
    Words per block: ``ceil(len * width / 32)``. Docids must be
    non-negative and non-decreasing within each block; raises
    ``ValueError`` otherwise.
    """
    docs = np.asarray(docs)
    blk_start = np.asarray(blk_start, np.int64)
    blk_len = np.asarray(blk_len, np.int64)
    nb = int(blk_start.shape[0])
    if nb and int(blk_len.max(initial=0)) > BLOCK:
        raise ValueError(f"block length exceeds BLOCK={BLOCK}")
    lane = _segment_arange(blk_len)
    seg = np.repeat(np.arange(nb, dtype=np.int64), blk_len)
    vals = docs[blk_start[seg] + lane].astype(np.int64)
    total = int(vals.shape[0])
    if total and int(vals.min()) < 0:
        raise ValueError("docids must be non-negative")
    # Deltas with delta_0 := 0 at each block head.
    prev = np.empty_like(vals)
    if total:
        prev[1:] = vals[:-1]
    heads = lane == 0
    prev[heads] = vals[heads]
    delta = vals - prev
    if total and int(delta.min()) < 0:
        raise ValueError("docids must be non-decreasing within a block")
    maxd = np.zeros(nb, np.int64)
    np.maximum.at(maxd, seg, delta)
    width = np.select(
        [maxd < (1 << w) for w in PACK_WIDTHS[:-1]],
        list(PACK_WIDTHS[:-1]),
        default=PACK_WIDTHS[-1],
    ).astype(np.int32)
    firsts = np.zeros(nb, np.int32)
    nz = blk_len > 0
    seg_head = np.cumsum(blk_len) - blk_len
    firsts[nz] = vals[seg_head[nz]].astype(np.int32)
    wpb = (blk_len * width.astype(np.int64) + 31) // 32
    word_start = np.cumsum(wpb) - wpb
    n_words = int(wpb.sum())
    words = np.zeros(n_words, np.uint32)
    w_post = width.astype(np.int64)[seg]
    packed_lanes = w_post > 0
    bit = lane[packed_lanes] * w_post[packed_lanes]
    word_idx = word_start[seg[packed_lanes]] + bit // 32
    # Byte-aligned widths: shift + width <= 32, each delta lands in one word.
    contrib = delta[packed_lanes].astype(np.uint64) << (bit % 32).astype(np.uint64)
    np.bitwise_or.at(words, word_idx, contrib.astype(np.uint32))
    return PackedPostings(
        words=words,
        blk_word_start=word_start,
        blk_width=width,
        blk_first=firsts,
        n_postings=total,
    )


def pack_dir_entries(packed: PackedPostings) -> np.ndarray:
    """Merge (word_start, width) into one int32 directory column.

    Entry layout: ``word_start | PACK_WIDTHS.index(width) << PACK_DIR_BITS``.
    Folding the 3-bit width code into the word offset's headroom is what
    takes the per-block directory from three uploaded columns to two —
    without it the directory overhead on short blocks eats most of the
    packing win (DESIGN.md §12).
    """
    if packed.n_words >= (1 << PACK_DIR_BITS):
        raise ValueError(
            f"packed stream has {packed.n_words} words >= 2^{PACK_DIR_BITS}; "
            f"shard the index before packing"
        )
    codes = np.searchsorted(np.asarray(PACK_WIDTHS), packed.blk_width)
    return (
        packed.blk_word_start.astype(np.int64) | (codes << PACK_DIR_BITS)
    ).astype(np.int32)


def unpack_docs(
    packed: PackedPostings, blk_start: np.ndarray, blk_len: np.ndarray
) -> np.ndarray:
    """Exact inverse of :func:`pack_docs`: rebuild the int32 docid array.

    Each block's deltas are masked out of the word stream and
    prefix-summed from ``blk_first``; results scatter back to
    ``blk_start[b] + lane``. ``unpack_docs(pack_docs(x, s, l), s, l) == x``
    bitwise for any valid block geometry.
    """
    blk_start = np.asarray(blk_start, np.int64)
    blk_len = np.asarray(blk_len, np.int64)
    nb = int(blk_start.shape[0])
    lane = _segment_arange(blk_len)
    seg = np.repeat(np.arange(nb, dtype=np.int64), blk_len)
    total = int(lane.shape[0])
    w = packed.blk_width.astype(np.int64)[seg]
    delta = np.zeros(total, np.int64)
    nzl = w > 0
    bit = lane[nzl] * w[nzl]
    word = packed.words[packed.blk_word_start[seg[nzl]] + bit // 32]
    mask = (np.int64(1) << w[nzl]) - 1
    delta[nzl] = (
        word.astype(np.uint64) >> (bit % 32).astype(np.uint64)
    ).astype(np.int64) & mask
    cs = np.cumsum(delta)
    seg_head = np.cumsum(blk_len) - blk_len
    base = np.zeros(nb, np.int64)
    nz = blk_len > 0
    # cumsum *before* each head (head's own delta is 0 by construction).
    base[nz] = cs[seg_head[nz]] - delta[seg_head[nz]]
    vals = packed.blk_first.astype(np.int64)[seg] + cs - base[seg]
    n_out = int((blk_start + blk_len).max(initial=0))
    out = np.zeros(n_out, np.int32)
    out[blk_start[seg] + lane] = vals.astype(np.int32)
    return out


@dataclasses.dataclass
class ClusteredIndex:
    n_docs: int
    n_terms: int
    arrangement: Arrangement
    quantizer: Quantizer

    # Postings, CSR by term (docids are *new* ids under the arrangement).
    ptr: np.ndarray  # [V+1] int64
    docs: np.ndarray  # [nnz] int32
    impacts: np.ndarray  # [nnz] int32 (1 .. 2^b - 1)

    # Blocks (never straddle a range boundary).
    blk_start: np.ndarray  # [NB] int64 offset into docs/impacts
    blk_len: np.ndarray  # [NB] int32 (<= BLOCK)
    blk_maxdoc: np.ndarray  # [NB] int32
    blk_maximp: np.ndarray  # [NB] int32
    blk_term: np.ndarray  # [NB] int32
    blk_range: np.ndarray  # [NB] int32

    # (term, range) directory — CSR over terms.
    tr_ptr: np.ndarray  # [V+1] int64
    tr_range: np.ndarray  # [NTR] int32
    tr_blk_start: np.ndarray  # [NTR] int64  (block-id range for this (t, r))
    tr_blk_end: np.ndarray  # [NTR] int64
    tr_bound: np.ndarray  # [NTR] int32  U[t, r]

    # Dense helpers.
    term_bound: np.ndarray  # [V] int32 — global U_t (WAND/MaxScore bounds)
    bounds_dense: np.ndarray  # [V, R] int32 — U[t, r], 0 where absent

    # Frozen base-build collection statistics + scoring params (DESIGN.md
    # §10): incremental extension scores appended postings against THESE,
    # never the extended collection's, so existing arrays stay bitwise
    # stable. None on indexes loaded from pre-§10 artifacts (which then
    # cannot be extended — rebuild from the corpus first).
    stats: CollectionStats | None = None
    bm25: BM25Params = dataclasses.field(default_factory=BM25Params)

    @property
    def n_ranges(self) -> int:
        return self.arrangement.n_ranges

    @property
    def n_blocks(self) -> int:
        return int(self.blk_start.shape[0])

    @property
    def nnz(self) -> int:
        return int(self.docs.shape[0])

    @property
    def range_ends(self) -> np.ndarray:
        return self.arrangement.range_ends

    @property
    def range_starts(self) -> np.ndarray:
        return self.arrangement.range_starts

    @property
    def max_range_size(self) -> int:
        return int(self.arrangement.range_sizes.max())

    # ---------------------------------------------------------------- space
    def packed_postings(self) -> PackedPostings:
        """Bit-packed docid deltas for this index's block geometry (cached).

        Built indexes are never mutated in place (same contract the
        fingerprint cache relies on), so the packed mirror is computed once
        per index object and shared by every Engine upload / space report.
        """
        cached = self.__dict__.get("_packed_cache")
        if cached is None:
            cached = pack_docs(self.docs, self.blk_start, self.blk_len)
            self.__dict__["_packed_cache"] = cached
        return cached

    def device_bytes(
        self, impact_dtype: str = "int32", docs_format: str = "int32"
    ) -> dict[str, int]:
        """Actual HBM bytes per device array at the chosen impact dtype.

        Mirrors exactly what ``range_daat.Engine`` uploads as its
        ``DeviceIndex`` — one entry per device array (all int32 except
        ``impacts``, which is 1 B/posting under ``impact_dtype="int8"``,
        DESIGN.md §8) plus ``postings`` (docs + impacts) and ``total``
        aggregates; under ``docs_format="packed"`` the ``docs`` entry is
        the packed word stream + directory (DESIGN.md §12). Tests assert
        these equal the uploaded buffers' nbytes.
        """
        n_pack_words = (
            self.packed_postings().n_words if docs_format == "packed" else 0
        )
        return device_bytes_report(
            nnz=self.nnz,
            n_blocks=self.n_blocks,
            n_terms=self.n_terms,
            n_ranges=self.n_ranges,
            impact_dtype=impact_dtype,
            docs_format=docs_format,
            n_pack_words=n_pack_words,
        )

    def space_report(
        self, impact_dtype: str = "int32", docs_format: str = "int32"
    ) -> dict:
        """Logical space accounting in GiB at paper-matched widths (T2).

        docids at 4 B, impacts at ceil(bits/8) B, block metadata, the sparse
        (term, range) bound directory, listwise bounds, and the cluster map.
        The ``device_bytes`` section reports the *actual* HBM footprint of
        the device mirror at ``impact_dtype`` (see :meth:`device_bytes`).
        """
        gib = 1 / (1024**3)
        imp_bytes = (self.quantizer.bits + 7) // 8
        postings = self.nnz * (4 + imp_bytes)
        blocks = self.n_blocks * (8 + 4 + 4 + 4)  # start, len, maxdoc, maximp
        rangewise = self.tr_range.shape[0] * (4 + imp_bytes) + 8 * (
            self.n_terms + 1
        )
        listwise = self.n_terms * imp_bytes
        cluster_map = self.n_ranges * 8
        return {
            "postings_gib": postings * gib,
            "block_meta_gib": blocks * gib,
            "listwise_bounds_gib": listwise * gib,
            "rangewise_bounds_gib": rangewise * gib,
            "cluster_map_gib": cluster_map * gib,
            "total_gib": (postings + blocks + rangewise + listwise + cluster_map)
            * gib,
            "device_bytes": self.device_bytes(impact_dtype, docs_format),
        }

    # ------------------------------------------------------------- queries
    def query_block_table(
        self, q_terms: np.ndarray, pad_to: int | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """Per-range padded block-id table for a query (host-side, cheap).

        Returns (blk_ids [R, B] int64 with -1 padding, rest_bound [R, B]
        int32) where ``rest_bound[r, j] = BoundSum(r) - U[t_j, r]`` for the
        term owning block j — the quantity needed for block-level pruning
        (DESIGN.md §2: per-block bound = blk_maximp + rest_bound).
        """
        q = [int(t) for t in q_terms if t >= 0]
        R = self.n_ranges
        per_range: list[list[int]] = [[] for _ in range(R)]
        rests: list[list[int]] = [[] for _ in range(R)]
        bsum = self.bounds_dense[q].sum(axis=0).astype(np.int64) if q else np.zeros(R, np.int64)
        for t in q:
            s, e = self.tr_ptr[t], self.tr_ptr[t + 1]
            for i in range(s, e):
                r = int(self.tr_range[i])
                rest = int(bsum[r] - self.tr_bound[i])
                for b in range(int(self.tr_blk_start[i]), int(self.tr_blk_end[i])):
                    per_range[r].append(b)
                    rests[r].append(rest)
        width = max((len(x) for x in per_range), default=1)
        width = max(width, 1)
        if pad_to is not None:
            width = max(width, pad_to)
        blk = np.full((R, width), -1, dtype=np.int64)
        rest = np.zeros((R, width), dtype=np.int32)
        for r in range(R):
            n = len(per_range[r])
            if n:
                blk[r, :n] = per_range[r]
                rest[r, :n] = rests[r]
        return blk, rest

    def fingerprint(self) -> str:
        # Cached: a sha1 pass over the postings arrays is the dominant cost
        # of chain materialization (each link checks its parent's print and
        # its own result), and built indexes are never mutated in place.
        cached = self.__dict__.get("_fingerprint_cache")
        if cached is None:
            h = hashlib.sha1()
            for a in (self.ptr, self.docs, self.impacts, self.range_ends):
                h.update(np.ascontiguousarray(a).tobytes())
            cached = h.hexdigest()[:16]
            self.__dict__["_fingerprint_cache"] = cached
        return cached


def _build_blocks(
    ptr: np.ndarray,
    docs: np.ndarray,
    n_terms: int,
    impacts: np.ndarray,
    range_ends: np.ndarray,
) -> tuple[np.ndarray, ...]:
    """Split every term's postings into <=BLOCK runs within range boundaries.

    Takes raw CSR arrays (not a ``Postings``) so incremental extension can
    run it over a delta's postings lifted to global docids (DESIGN.md §10).
    """
    starts: list[int] = []
    lens: list[int] = []
    maxdoc: list[int] = []
    maximp: list[int] = []
    bterm: list[int] = []
    brange: list[int] = []
    tr_rows: list[tuple[int, int, int, int, int]] = []  # term, range, b0, b1, bound

    for t in range(n_terms):
        s, e = int(ptr[t]), int(ptr[t + 1])
        if s == e:
            continue
        d = docs[s:e]
        # Range id per posting; postings are docid-sorted so ranges appear as runs.
        rid = np.searchsorted(range_ends, d, side="right")
        run_starts = np.concatenate([[0], np.nonzero(np.diff(rid))[0] + 1])
        run_ends = np.concatenate([run_starts[1:], [d.shape[0]]])
        for rs, re_ in zip(run_starts, run_ends):
            r = int(rid[rs])
            b0 = len(starts)
            bound = 0
            for off in range(rs, re_, BLOCK):
                hi = min(off + BLOCK, re_)
                starts.append(s + off)
                lens.append(hi - off)
                maxdoc.append(int(d[hi - 1]))
                mi = int(impacts[s + off : s + hi].max())
                maximp.append(mi)
                bound = max(bound, mi)
                bterm.append(t)
                brange.append(r)
            tr_rows.append((t, r, b0, len(starts), bound))

    return (
        np.asarray(starts, dtype=np.int64),
        np.asarray(lens, dtype=np.int32),
        np.asarray(maxdoc, dtype=np.int32),
        np.asarray(maximp, dtype=np.int32),
        np.asarray(bterm, dtype=np.int32),
        np.asarray(brange, dtype=np.int32),
        tr_rows,
    )


def build_index(
    corpus: Corpus,
    arrangement: Arrangement | None = None,
    n_ranges: int = 32,
    strategy: str = "clustered_bp",
    bits: int = 8,
    params: BM25Params = BM25Params(),
    seed: int = 0,
    quantizer: Quantizer | None = None,
    stats: CollectionStats | None = None,
) -> ClusteredIndex:
    """Build the cluster-skipping index.

    ``quantizer`` may be supplied to share one global impact scale across
    sub-indexes (required when merging scores across shards — §7.2).
    ``stats`` substitutes frozen collection statistics for the corpus's own
    — how the incremental-extension invariant is verified: a from-scratch
    build on the concatenated corpus at the base's stats/quantizer/
    arrangement equals the compacted chain bitwise (DESIGN.md §10).
    """
    if arrangement is None:
        arrangement = arrange(corpus, n_ranges=n_ranges, strategy=strategy, seed=seed)
    stats = stats or collection_stats(corpus)
    post = invert(corpus, arrangement.doc_order, params, stats=stats)
    quant = quantizer or fit_quantizer(post.scores, bits=bits)
    impacts = quant.quantize(post.scores)

    (
        blk_start,
        blk_len,
        blk_maxdoc,
        blk_maximp,
        blk_term,
        blk_range,
        tr_rows,
    ) = _build_blocks(
        post.ptr, post.docs, post.n_terms, impacts, arrangement.range_ends
    )

    V = corpus.n_terms
    R = arrangement.n_ranges
    tr_ptr = np.zeros(V + 1, dtype=np.int64)
    tr_term = np.asarray([r[0] for r in tr_rows], dtype=np.int32)
    counts = np.bincount(tr_term, minlength=V) if tr_rows else np.zeros(V, np.int64)
    tr_ptr[1:] = np.cumsum(counts)
    tr_range = np.asarray([r[1] for r in tr_rows], dtype=np.int32)
    tr_blk_start = np.asarray([r[2] for r in tr_rows], dtype=np.int64)
    tr_blk_end = np.asarray([r[3] for r in tr_rows], dtype=np.int64)
    tr_bound = np.asarray([r[4] for r in tr_rows], dtype=np.int32)

    bounds_dense = np.zeros((V, R), dtype=np.int32)
    if tr_rows:
        bounds_dense[tr_term, tr_range] = tr_bound
    term_bound = bounds_dense.max(axis=1) if R else np.zeros(V, np.int32)

    return ClusteredIndex(
        n_docs=corpus.n_docs,
        n_terms=V,
        arrangement=arrangement,
        quantizer=quant,
        ptr=post.ptr,
        docs=post.docs,
        impacts=impacts,
        blk_start=blk_start,
        blk_len=blk_len,
        blk_maxdoc=blk_maxdoc,
        blk_maximp=blk_maximp,
        blk_term=blk_term,
        blk_range=blk_range,
        tr_ptr=tr_ptr,
        tr_range=tr_range,
        tr_blk_start=tr_blk_start,
        tr_blk_end=tr_blk_end,
        tr_bound=tr_bound,
        term_bound=checked_int32(term_bound, "term bounds"),
        bounds_dense=bounds_dense,
        stats=stats,
        bm25=params,
    )


# --------------------------------------------------------------------------
# Incremental extension: delta planning and exact tail-append (DESIGN.md §10)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class IndexDelta:
    """A tail-append against a parent ``ClusteredIndex``.

    Everything is *delta-local*: ``docs`` are new docids in [0, n_docs),
    ``doc_order``/``range_ends`` describe the delta's own arrangement, and
    the global picture only materializes in :func:`apply_delta` (delta docs
    land at the parent's docid tail, delta ranges after the parent's).
    Impacts are already quantized at the parent's shared scale, scored
    against the parent's *frozen* collection statistics, so applying the
    delta never touches a parent array value. ``parent_fingerprint`` pins
    the exact index this delta was planned against.
    """

    n_docs: int
    n_terms: int
    parent_fingerprint: str
    ptr: np.ndarray  # [V+1] int64 — delta postings CSR by term
    docs: np.ndarray  # [nnz_d] int32 delta-local new docids, ascending per term
    impacts: np.ndarray  # [nnz_d] int32 at the parent's quantizer scale
    doc_order: np.ndarray  # [n_docs] int64 delta-local permutation
    range_ends: np.ndarray  # [R_d] int64 delta-local, last == n_docs

    @property
    def nnz(self) -> int:
        return int(self.docs.shape[0])

    @property
    def n_ranges(self) -> int:
        return int(self.range_ends.shape[0])


def extended_arrangement(
    base: Arrangement, doc_order: np.ndarray, range_ends: np.ndarray
) -> Arrangement:
    """Append a delta-local arrangement at the docid tail of ``base``.

    The single definition both sides of the §10 invariant use: the applied
    delta *and* the verifying from-scratch build share this arrangement.
    """
    n = int(base.range_ends[-1])
    return Arrangement(
        doc_order=np.concatenate(
            [base.doc_order, np.asarray(doc_order, np.int64) + n]
        ),
        range_ends=np.concatenate(
            [base.range_ends, np.asarray(range_ends, np.int64) + n]
        ),
        strategy=base.strategy,
    )


def plan_delta(
    index: ClusteredIndex,
    corpus_delta: Corpus,
    n_ranges: int = 1,
    strategy: str = "clustered",
    seed: int = 0,
    arrangement: Arrangement | None = None,
) -> IndexDelta:
    """Score and arrange a delta corpus against a built index.

    The delta gets its own (small) arrangement — clustering/BP run only on
    the appended documents — and its postings are scored with the parent's
    frozen stats and quantizer, which is what makes the append exact: no
    existing impact, bound, or block can change.
    """
    if corpus_delta.n_terms != index.n_terms:
        raise ValueError(
            f"delta corpus has {corpus_delta.n_terms} terms, index has "
            f"{index.n_terms} — extension shares one vocabulary"
        )
    if corpus_delta.n_docs <= 0:
        raise ValueError("delta corpus is empty")
    if index.stats is None:
        raise ValueError(
            "index carries no frozen collection stats (loaded from a "
            "pre-incremental artifact?) — rebuild from the corpus to extend"
        )
    if arrangement is None:
        arrangement = arrange(
            corpus_delta, n_ranges=n_ranges, strategy=strategy, seed=seed
        )
    post = invert(corpus_delta, arrangement.doc_order, index.bm25, stats=index.stats)
    return IndexDelta(
        n_docs=int(corpus_delta.n_docs),
        n_terms=int(index.n_terms),
        parent_fingerprint=index.fingerprint(),
        ptr=post.ptr,
        docs=post.docs,
        impacts=index.quantizer.quantize(post.scores),
        doc_order=np.asarray(arrangement.doc_order, np.int64),
        range_ends=np.asarray(arrangement.range_ends, np.int64),
    )


def apply_delta(index: ClusteredIndex, delta: IndexDelta) -> ClusteredIndex:
    """Materialize ``index`` + ``delta`` into one extended ``ClusteredIndex``.

    Bitwise-exact merge: the result is array-for-array identical to
    ``build_index`` on the concatenated corpus at the extended arrangement
    with the parent's quantizer and frozen stats (pinned by tier-1 tests).
    Postings stay CSR-by-term — each term's delta postings (all at larger
    docids) slot in behind its base postings — and blocks/(term, range)
    rows re-interleave per term without recomputing any base value.
    """
    if delta.parent_fingerprint != index.fingerprint():
        raise ValueError(
            f"delta was planned against index {delta.parent_fingerprint}, "
            f"this index has fingerprint {index.fingerprint()}"
        )
    if delta.n_terms != index.n_terms:
        raise ValueError(
            f"delta vocabulary {delta.n_terms} != index {index.n_terms}"
        )
    V = index.n_terms
    base_n = index.n_docs
    R_base = index.n_ranges
    arrangement = extended_arrangement(
        index.arrangement, delta.doc_order, delta.range_ends
    )

    # Postings: per-term concat (base run, then delta run at larger docids).
    base_counts = np.diff(index.ptr)
    d_counts = np.diff(delta.ptr)
    new_ptr = index.ptr + delta.ptr
    docs = np.empty(index.nnz + delta.nnz, np.int32)
    impacts = np.empty(index.nnz + delta.nnz, np.int32)
    dest_base = np.arange(index.nnz, dtype=np.int64) + np.repeat(
        delta.ptr[:-1], base_counts
    )
    dest_delta = np.arange(delta.nnz, dtype=np.int64) + np.repeat(
        index.ptr[1:], d_counts
    )
    docs[dest_base] = index.docs
    docs[dest_delta] = delta.docs.astype(np.int64) + base_n
    impacts[dest_base] = index.impacts
    impacts[dest_delta] = delta.impacts

    # Delta blocks at global coordinates (docids lifted to the tail; the
    # extended range_ends sends them straight to global range ids >= R_base).
    (
        d_start,
        d_len,
        d_maxdoc,
        d_maximp,
        d_term,
        d_range,
        d_tr_rows,
    ) = _build_blocks(
        delta.ptr,
        delta.docs.astype(np.int64) + base_n,
        V,
        delta.impacts,
        arrangement.range_ends,
    )
    # Block starts move: base blocks shift by their term's delta postings
    # prefix; delta blocks land after their term's base postings.
    b_start_g = index.blk_start + delta.ptr[:-1][index.blk_term]
    d_start_g = d_start + index.ptr[1:][d_term] if d_term.size else d_start

    # Fresh-build block order is (term, docid run): sort the union by
    # (term, new start) — within a term every base block starts before
    # every delta block, so runs stay contiguous.
    all_start = np.concatenate([b_start_g, d_start_g])
    all_term = np.concatenate([index.blk_term, d_term])
    order = np.lexsort((all_start, all_term))
    newpos = np.empty(order.shape[0], np.int64)
    newpos[order] = np.arange(order.shape[0])

    # (term, range) directory: same per-term interleave, with block-id
    # windows remapped through the merged block order.
    d_tr_term = np.asarray([r[0] for r in d_tr_rows], np.int32)
    d_tr_range = np.asarray([r[1] for r in d_tr_rows], np.int32)
    d_tr_b0 = np.asarray([r[2] for r in d_tr_rows], np.int64)
    d_tr_b1 = np.asarray([r[3] for r in d_tr_rows], np.int64)
    d_tr_bound = np.asarray([r[4] for r in d_tr_rows], np.int32)
    d_tr_counts = (
        np.bincount(d_tr_term, minlength=V) if d_tr_rows else np.zeros(V, np.int64)
    )
    d_tr_ptr = np.zeros(V + 1, dtype=np.int64)
    d_tr_ptr[1:] = np.cumsum(d_tr_counts)

    NB_base = index.n_blocks
    NTR_b = int(index.tr_range.shape[0])
    NTR_d = int(d_tr_term.shape[0])
    base_tr_counts = np.diff(index.tr_ptr)
    dest_tr_b = np.arange(NTR_b, dtype=np.int64) + np.repeat(
        d_tr_ptr[:-1], base_tr_counts
    )
    dest_tr_d = np.arange(NTR_d, dtype=np.int64) + np.repeat(
        index.tr_ptr[1:], d_tr_counts
    )

    def interleave(base_vals, d_vals, dtype):
        out = np.empty(NTR_b + NTR_d, dtype)
        out[dest_tr_b] = base_vals
        out[dest_tr_d] = d_vals
        return out

    tr_blk_start = interleave(
        newpos[index.tr_blk_start],
        newpos[NB_base + d_tr_b0] if NTR_d else d_tr_b0,
        np.int64,
    )
    tr_blk_end = interleave(
        newpos[index.tr_blk_end - 1] + 1,
        newpos[NB_base + d_tr_b1 - 1] + 1 if NTR_d else d_tr_b1,
        np.int64,
    )

    # Refreshed BoundSum tail: new columns for the delta's ranges only.
    R_d = delta.n_ranges
    d_bounds = np.zeros((V, R_d), dtype=np.int32)
    if d_tr_rows:
        d_bounds[d_tr_term, d_tr_range - R_base] = d_tr_bound
    bounds_dense = np.hstack([np.asarray(index.bounds_dense), d_bounds])
    term_bound = checked_int32(
        np.maximum(np.asarray(index.term_bound), d_bounds.max(axis=1)),
        "term bounds",
    )

    return ClusteredIndex(
        n_docs=base_n + delta.n_docs,
        n_terms=V,
        arrangement=arrangement,
        quantizer=index.quantizer,
        ptr=new_ptr,
        docs=docs,
        impacts=impacts,
        blk_start=all_start[order],
        blk_len=np.concatenate([index.blk_len, d_len])[order],
        blk_maxdoc=np.concatenate([index.blk_maxdoc, d_maxdoc])[order],
        blk_maximp=np.concatenate([index.blk_maximp, d_maximp])[order],
        blk_term=all_term[order],
        blk_range=np.concatenate([index.blk_range, d_range])[order],
        tr_ptr=index.tr_ptr + d_tr_ptr,
        tr_range=interleave(index.tr_range, d_tr_range, np.int32),
        tr_blk_start=tr_blk_start,
        tr_blk_end=tr_blk_end,
        tr_bound=interleave(index.tr_bound, d_tr_bound, np.int32),
        term_bound=term_bound,
        bounds_dense=bounds_dense,
        stats=index.stats,
        bm25=index.bm25,
    )


def extend_index(
    index: ClusteredIndex,
    corpus_delta: Corpus,
    n_ranges: int = 1,
    strategy: str = "clustered",
    seed: int = 0,
    arrangement: Arrangement | None = None,
) -> ClusteredIndex:
    """Append a delta corpus at the docid tail of a built index.

    The cheap-update property the document-ordered layout buys (paper §1):
    only the delta is clustered, inverted, scored, and blocked; base arrays
    are re-interleaved, never recomputed. Equivalent to
    ``apply_delta(index, plan_delta(index, corpus_delta, ...))``.
    """
    return apply_delta(
        index,
        plan_delta(
            index,
            corpus_delta,
            n_ranges=n_ranges,
            strategy=strategy,
            seed=seed,
            arrangement=arrangement,
        ),
    )


@dataclasses.dataclass
class IndexShard:
    """A contiguous band of ranges carved out of a ``ClusteredIndex``.

    Everything is remapped to shard-local coordinates (DESIGN.md §4):
    ``docs`` holds local docids (global - ``doc_base``), ``blk_start``
    offsets into the shard-local postings array, ``range_starts`` /
    ``bounds_dense`` cover only this shard's ranges, and ``blk_map`` sends
    global block ids to shard-local ones (-1 for blocks owned elsewhere) so
    a globally-planned ``QueryPlan`` can be sliced per shard without
    replanning.
    """

    shard_id: int
    range_lo: int  # global range-id window [range_lo, range_hi)
    range_hi: int
    doc_base: int  # global docid of local doc 0
    n_docs: int
    postings: int  # postings mass carried by this shard

    docs: np.ndarray  # [nnz_s] int32 LOCAL docids
    impacts: np.ndarray  # [nnz_s] int32
    blk_start: np.ndarray  # [NB_s] int64 offsets into the LOCAL postings
    blk_len: np.ndarray  # [NB_s] int32
    blk_maxdoc: np.ndarray  # [NB_s] int32 LOCAL docids
    blk_maximp: np.ndarray  # [NB_s] int32
    blk_map: np.ndarray  # [NB_global] int32 global block id -> local (-1)

    range_starts: np.ndarray  # [R_s] int32 LOCAL docid space
    range_sizes: np.ndarray  # [R_s] int32
    bounds_dense: np.ndarray  # [V, R_s] int32 — U[t, r] for local ranges

    @property
    def n_ranges(self) -> int:
        return self.range_hi - self.range_lo


def balance_range_shards(mass: np.ndarray, n_shards: int) -> np.ndarray:
    """Contiguous range partition balancing postings mass.

    Returns ``cuts`` [n_shards + 1] with shard s owning ranges
    ``[cuts[s], cuts[s+1])``. Greedy prefix-sum cuts: each boundary lands on
    whichever side of the ideal s/n_shards mass quantile is closer, subject
    to every shard keeping at least one range. The range structure is the
    unit of partitioning — a topically-coherent shard boundary, unlike the
    random document split of the classic partitioned deployment (§7.2).
    """
    mass = np.asarray(mass, dtype=np.int64)
    R = int(mass.shape[0])
    if not 1 <= n_shards <= R:
        raise ValueError(f"need 1 <= n_shards={n_shards} <= n_ranges={R}")
    cum = np.cumsum(mass)
    total = int(cum[-1])
    cuts = [0]
    for s in range(1, n_shards):
        target = total * s / n_shards
        j = int(np.searchsorted(cum, target))  # first prefix >= target
        # Nearest cut: left mass is cum[j-1] cutting before range j,
        # cum[j] cutting after it — take whichever lands closer to target.
        left = int(cum[j - 1]) if j > 0 else 0
        if j < R and abs(int(cum[j]) - target) < abs(left - target):
            j += 1
        j = max(j, cuts[-1] + 1)  # every shard keeps >= 1 range
        j = min(j, R - (n_shards - s))
        cuts.append(j)
    cuts.append(R)
    return np.asarray(cuts, dtype=np.int64)


def range_postings_mass(index: ClusteredIndex) -> np.ndarray:
    """[R] int64 postings mass per global range (the partitioning weight)."""
    return np.bincount(
        index.blk_range, weights=index.blk_len, minlength=index.n_ranges
    ).astype(np.int64)


def _validate_cuts(cuts: np.ndarray, n_ranges: int) -> np.ndarray:
    cuts = np.asarray(cuts, dtype=np.int64)
    if (
        cuts.ndim != 1
        or cuts.shape[0] < 2
        or cuts[0] != 0
        or cuts[-1] != n_ranges
        or np.any(np.diff(cuts) < 1)
    ):
        raise ValueError(
            f"cuts {cuts.tolist()} must rise strictly from 0 to "
            f"n_ranges={n_ranges} (every shard keeps >= 1 range)"
        )
    return cuts


def shard_cuts(shards: list["IndexShard"]) -> np.ndarray:
    """[S + 1] int64 global range cuts recovered from a shard list."""
    return np.asarray(
        [sh.range_lo for sh in shards] + [shards[-1].range_hi], np.int64
    )


def shard_device_index(
    index: ClusteredIndex,
    n_shards: int | None = None,
    cuts: np.ndarray | None = None,
) -> list[IndexShard]:
    """Partition a built index along range boundaries into device shards.

    Ranges stay whole (blocks never straddle a range boundary, so a range
    boundary is also a block and postings boundary); contiguous bands of
    ranges are assigned to shards by :func:`balance_range_shards` so every
    shard carries a near-equal share of postings — or by explicit ``cuts``
    ([S + 1], rising from 0 to n_ranges), which is how the control plane's
    reshard planner places load-rebalanced boundaries (DESIGN.md §9). Each
    shard's arrays are rewritten to local coordinates — see
    :class:`IndexShard`. Scores need no recalibration across shards: the
    quantizer is global, so per-shard integer top-k lists merge exactly
    (DESIGN.md §4).
    """
    R = index.n_ranges
    mass = range_postings_mass(index)
    if cuts is None:
        if n_shards is None:
            raise ValueError("need n_shards or explicit cuts")
        cuts = balance_range_shards(mass, n_shards)
    else:
        cuts = _validate_cuts(cuts, R)
        if n_shards is not None and n_shards != cuts.shape[0] - 1:
            raise ValueError(
                f"n_shards={n_shards} != len(cuts)-1={cuts.shape[0] - 1}"
            )
        n_shards = cuts.shape[0] - 1

    NB = index.n_blocks
    range_starts = index.range_starts
    range_sizes = index.arrangement.range_sizes
    shards: list[IndexShard] = []
    for s in range(n_shards):
        lo, hi = int(cuts[s]), int(cuts[s + 1])
        doc_base = int(range_starts[lo])
        sel = (index.blk_range >= lo) & (index.blk_range < hi)
        gids = np.nonzero(sel)[0]
        lens = index.blk_len[gids].astype(np.int64)
        starts = index.blk_start[gids]
        local_start = np.zeros(gids.shape[0], dtype=np.int64)
        if gids.size:
            local_start[1:] = np.cumsum(lens)[:-1]
            tot = int(lens.sum())
            take = np.repeat(starts - local_start, lens) + np.arange(tot)
        else:
            take = np.empty(0, dtype=np.int64)

        blk_map = np.full(NB, -1, dtype=np.int32)
        blk_map[gids] = np.arange(gids.shape[0], dtype=np.int32)

        n_docs = int(
            (range_starts[hi] if hi < R else index.n_docs) - doc_base
        )
        shards.append(
            IndexShard(
                shard_id=s,
                range_lo=lo,
                range_hi=hi,
                doc_base=doc_base,
                n_docs=n_docs,
                postings=int(mass[lo:hi].sum()),
                docs=checked_int32(index.docs[take] - doc_base, "shard docids"),
                impacts=index.impacts[take].astype(np.int32),
                blk_start=local_start,
                blk_len=index.blk_len[gids].astype(np.int32),
                blk_maxdoc=checked_int32(
                    index.blk_maxdoc[gids] - doc_base, "shard block maxdocs"
                ),
                blk_maximp=index.blk_maximp[gids].astype(np.int32),
                blk_map=blk_map,
                range_starts=(range_starts[lo:hi] - doc_base).astype(np.int32),
                range_sizes=range_sizes[lo:hi].astype(np.int32),
                bounds_dense=index.bounds_dense[:, lo:hi],
            )
        )
    return shards


def _gather_block_postings(
    dst: np.ndarray,
    src: np.ndarray,
    dst_start: np.ndarray,
    src_start: np.ndarray,
    lens: np.ndarray,
    delta: int,
) -> None:
    """Copy per-block posting runs ``src[src_start:+len] + delta`` into
    ``dst[dst_start:+len]`` without a per-posting Python loop (the cumsum/
    repeat trick ``shard_device_index`` uses, generalized to scattered
    destinations)."""
    if lens.size == 0:
        return
    total = int(lens.sum())
    within = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(lens) - lens, lens
    )
    dst_idx = np.repeat(dst_start, lens) + within
    src_idx = np.repeat(src_start, lens) + within
    dst[dst_idx] = src[src_idx] + delta


@dataclasses.dataclass(frozen=True)
class _RestackPrep:
    """Shared geometry for re-carving one shard set: computed (and the
    source layout + cuts validated) once, reused by every per-shard carve
    step of a staged cutover."""

    shards: list  # sources, sorted by range_lo
    cuts: np.ndarray  # [S_new + 1] validated
    g_range_starts: np.ndarray  # [R] global docid of each range start
    g_range_sizes: np.ndarray  # [R]
    n_docs: int
    n_blocks: int  # global block-id space size
    src_gids: list  # per source shard: ascending global block ids
    src_ranges: list  # per source shard: global range id per block


def restack_prep(shards: list[IndexShard], cuts: np.ndarray) -> _RestackPrep:
    """Validate a restack and recover the global geometry from shard arrays.

    Range starts/sizes come from each source shard's local tables, block
    ownership from ``blk_map`` (whose ascending global ids correspond to
    local ids 0..NB_s-1), and each block's global range from ``blk_maxdoc``
    (blocks never straddle ranges). Raises on non-contiguous sources or
    malformed cuts — callers staging a cutover get the error up front,
    never mid-serving.
    """
    if not shards:
        raise ValueError("cannot restack an empty shard list")
    shards = sorted(shards, key=lambda sh: sh.range_lo)
    if shards[0].range_lo != 0 or any(
        a.range_hi != b.range_lo for a, b in zip(shards, shards[1:])
    ):
        raise ValueError("source shards must tile the range space contiguously")
    cuts = _validate_cuts(cuts, shards[-1].range_hi)

    g_range_starts = np.concatenate(
        [sh.range_starts.astype(np.int64) + sh.doc_base for sh in shards]
    )
    g_range_sizes = np.concatenate(
        [sh.range_sizes.astype(np.int64) for sh in shards]
    )
    src_gids, src_ranges = [], []
    for sh in shards:
        gids = np.nonzero(sh.blk_map >= 0)[0]
        if gids.shape[0] != sh.blk_len.shape[0]:
            raise ValueError(
                f"shard {sh.shard_id}: blk_map owns {gids.shape[0]} blocks "
                f"but arrays hold {sh.blk_len.shape[0]}"
            )
        r_loc = (
            np.searchsorted(sh.range_starts, sh.blk_maxdoc, side="right") - 1
        )
        src_gids.append(gids)
        src_ranges.append(r_loc.astype(np.int64) + sh.range_lo)
    return _RestackPrep(
        shards=shards,
        cuts=cuts,
        g_range_starts=g_range_starts,
        g_range_sizes=g_range_sizes,
        n_docs=int(g_range_starts[-1] + g_range_sizes[-1]),
        n_blocks=int(shards[0].blk_map.shape[0]),
        src_gids=src_gids,
        src_ranges=src_ranges,
    )


def restack_shards(
    shards: list[IndexShard],
    cuts: np.ndarray,
    only: int | None = None,
    prep: _RestackPrep | None = None,
) -> list[IndexShard]:
    """Re-carve a shard set to new range cuts from shard arrays alone.

    The online-reshard primitive (DESIGN.md §9): no full index is needed —
    every posting, block, and bound already lives in exactly one source
    shard, and a new contiguous band of ranges is assembled by slicing /
    concatenating those shard-local arrays and rebasing their coordinates.
    Blocks are re-sorted into global-block-id order (recovered from each
    shard's ``blk_map``), so the output is **array-for-array identical** to
    ``shard_device_index(index, cuts=cuts)`` on the original index — the
    bitwise-cutover guarantee the control plane's tests pin. Works directly
    on shards loaded from an ``index_io`` shard artifact.

    ``only`` carves just that output shard (a one-element list) — the unit
    of work the control plane's staged cutover performs per serving-loop
    step, so a reshard never blocks the queue for a whole re-stack; pass
    the :func:`restack_prep` result as ``prep`` to share the geometry
    scan across steps.
    """
    if prep is None:
        prep = restack_prep(shards, cuts)
    shards, cuts = prep.shards, prep.cuts
    g_range_starts, g_range_sizes = prep.g_range_starts, prep.g_range_sizes
    src_gids, src_ranges = prep.src_gids, prep.src_ranges
    n_docs, NB, R = prep.n_docs, prep.n_blocks, int(cuts[-1])

    targets = (
        range(cuts.shape[0] - 1)
        if only is None
        else range(only, only + 1)
    )
    out: list[IndexShard] = []
    for s in targets:
        lo, hi = int(cuts[s]), int(cuts[s + 1])
        doc_base = int(g_range_starts[lo])
        # (global id, source shard, source-local block id) for owned blocks.
        rows = []
        for si, (gids, g_r) in enumerate(zip(src_gids, src_ranges)):
            sel = (g_r >= lo) & (g_r < hi)
            loc = np.nonzero(sel)[0]
            rows.append((gids[loc], np.full(loc.shape[0], si), loc))
        gid = np.concatenate([r[0] for r in rows])
        src = np.concatenate([r[1] for r in rows]).astype(np.int64)
        loc = np.concatenate([r[2] for r in rows]).astype(np.int64)
        order = np.argsort(gid, kind="stable")  # fresh-carve block order
        gid, src, loc = gid[order], src[order], loc[order]

        lens = np.empty(gid.shape[0], np.int64)
        for si, sh in enumerate(shards):
            m = src == si
            lens[m] = sh.blk_len[loc[m]]
        new_start = np.zeros(gid.shape[0], dtype=np.int64)
        if gid.size:
            new_start[1:] = np.cumsum(lens)[:-1]
        nnz_s = int(lens.sum())

        docs = np.empty(nnz_s, np.int32)
        impacts = np.empty(nnz_s, np.int32)
        maxdoc = np.empty(gid.shape[0], np.int32)
        maximp = np.empty(gid.shape[0], np.int32)
        for si, sh in enumerate(shards):
            m = src == si
            if not m.any():
                continue
            delta = sh.doc_base - doc_base  # old-local -> new-local docids
            _gather_block_postings(
                docs, sh.docs, new_start[m], sh.blk_start[loc[m]],
                lens[m], delta,
            )
            _gather_block_postings(
                impacts, sh.impacts, new_start[m], sh.blk_start[loc[m]],
                lens[m], 0,
            )
            maxdoc[m] = sh.blk_maxdoc[loc[m]] + delta
            maximp[m] = sh.blk_maximp[loc[m]]

        blk_map = np.full(NB, -1, dtype=np.int32)
        blk_map[gid] = np.arange(gid.shape[0], dtype=np.int32)
        n_docs_s = int(
            (g_range_starts[hi] if hi < R else n_docs) - doc_base
        )
        bounds = np.hstack(
            [
                sh.bounds_dense[
                    :, max(lo, sh.range_lo) - sh.range_lo
                    : min(hi, sh.range_hi) - sh.range_lo
                ]
                for sh in shards
                if sh.range_hi > lo and sh.range_lo < hi
            ]
        )
        out.append(
            IndexShard(
                shard_id=s,
                range_lo=lo,
                range_hi=hi,
                doc_base=doc_base,
                n_docs=n_docs_s,
                postings=nnz_s,
                docs=docs,
                impacts=impacts,
                blk_start=new_start,
                blk_len=lens.astype(np.int32),
                blk_maxdoc=maxdoc,
                blk_maximp=maximp,
                blk_map=blk_map,
                range_starts=(g_range_starts[lo:hi] - doc_base).astype(
                    np.int32
                ),
                range_sizes=g_range_sizes[lo:hi].astype(np.int32),
                bounds_dense=np.ascontiguousarray(bounds),
            )
        )
    return out


def build_index_cached(
    corpus: Corpus,
    cache_dir: str = ".cache",
    **kwargs,
) -> ClusteredIndex:
    """Disk-cached index build (BP + k-means are the slow offline steps).

    Cached as a versioned ``repro.index_io`` artifact directory (DESIGN.md
    §8) — same sha1 cache-key scheme as the old pickle path, but the
    on-disk representation is the inspectable, version-checked format: a
    corrupt cache entry raises instead of silently unpickling, while an
    entry from an older format version is treated as a miss and rebuilt.
    """
    from repro import index_io  # local: index_io sits above core

    key = hashlib.sha1(
        (corpus.fingerprint() + repr(sorted(kwargs.items()))).encode()
    ).hexdigest()[:16]
    path = os.path.join(cache_dir, f"index_{key}")
    if os.path.isdir(path):
        try:
            return index_io.load_index(path)
        except index_io.VersionMismatchError:
            pass  # older format — self-heal: rebuild and overwrite below
    idx = build_index(corpus, **kwargs)
    os.makedirs(cache_dir, exist_ok=True)
    index_io.save_index(
        idx,
        path,
        build_params={k: repr(v) for k, v in sorted(kwargs.items())},
        overwrite=True,
    )
    return idx
