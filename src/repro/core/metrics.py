"""Effectiveness metrics: RBO, RBP, AP (paper §5.4).

RBO (Webber et al. [72]) is the paper's qrel-free surrogate for comparing an
early-terminated ranking against exhaustive evaluation; RBP [53] and AP are
used with (here: planted) relevance judgments.
"""

from __future__ import annotations

import numpy as np

__all__ = ["rbo", "rbp", "average_precision"]


def rbo(list_a, list_b, phi: float = 0.99, extrapolate: bool = True) -> float:
    """Rank-biased overlap of two ranked lists (higher = more similar).

    Uses the truncated form with the standard extrapolation term at the
    evaluation depth. Identical lists -> 1.0; disjoint -> 0.0.
    """
    a = list(map(int, list_a))
    b = list(map(int, list_b))
    depth = min(len(a), len(b))
    if depth == 0:
        return 1.0 if len(a) == len(b) else 0.0
    seen_a: set[int] = set()
    seen_b: set[int] = set()
    overlap = 0
    score = 0.0
    agreement = 0.0
    for d in range(depth):
        x, y = a[d], b[d]
        if x == y:
            overlap += 1
        else:
            if x in seen_b:
                overlap += 1
            if y in seen_a:
                overlap += 1
            seen_a.add(x)
            seen_b.add(y)
        agreement = overlap / (d + 1)
        score += (phi**d) * agreement
    out = (1 - phi) * score
    if extrapolate:
        out += agreement * (phi**depth)
    return float(min(out, 1.0))


def rbp(ranking, relevant, phi: float = 0.8) -> float:
    """Rank-biased precision with binary or graded (0..1) relevance."""
    if isinstance(relevant, dict):
        gains = [float(relevant.get(int(d), 0.0)) for d in ranking]
    else:
        rel = set(map(int, relevant))
        gains = [1.0 if int(d) in rel else 0.0 for d in ranking]
    return float((1 - phi) * sum(g * phi**i for i, g in enumerate(gains)))


def average_precision(ranking, relevant, k: int | None = None) -> float:
    """AP@k against a binary relevant set."""
    rel = set(map(int, relevant))
    if not rel:
        return 0.0
    ranking = list(ranking)[: k or len(ranking)]
    hits = 0
    total = 0.0
    for i, d in enumerate(ranking):
        if int(d) in rel:
            hits += 1
            total += hits / (i + 1)
    return float(total / min(len(rel), k or len(rel)))
