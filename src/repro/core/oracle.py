"""Exhaustive scoring oracle.

Ground truth for rank-safety tests and for the paper's RBO-vs-exhaustive
effectiveness surrogate (§5.4). Pure numpy on the host — deliberately
independent of the device engine so it can falsify it.
"""

from __future__ import annotations

import numpy as np

from repro.core.clustered_index import ClusteredIndex

__all__ = ["exhaustive_scores", "exhaustive_topk"]


def exhaustive_scores(index: ClusteredIndex, q_terms: np.ndarray) -> np.ndarray:
    """Integer score of every document for the query (quantized impacts)."""
    acc = np.zeros(index.n_docs, dtype=np.int64)
    for t in np.asarray(q_terms).reshape(-1):
        if t < 0:
            continue
        s, e = index.ptr[int(t)], index.ptr[int(t) + 1]
        np.add.at(acc, index.docs[s:e], index.impacts[s:e])
    return acc


def exhaustive_topk(
    index: ClusteredIndex, q_terms: np.ndarray, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Top-k (docids, scores), ties broken by ascending docid."""
    acc = exhaustive_scores(index, q_terms)
    k = min(k, acc.shape[0])
    # Full lexsort: boundary ties must resolve by ascending docid (argpartition
    # would pick an arbitrary subset of tied docs).
    order = np.lexsort((np.arange(acc.shape[0]), -acc))[:k]
    keep = acc[order] > 0
    return order[keep].astype(np.int64), acc[order][keep]
