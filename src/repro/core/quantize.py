"""Global b-bit impact quantization (paper §2.1, §4.3).

Each real-valued contribution C(t, d) is mapped to an integer impact in
[1, 2^b - 1] by a single global linear map — the paper's construction for the
JASS index (8 bits Gov2, 9 bits ClueWeb09B). Quantization is monotone, so
integer-space rankings approximate float-space rankings with fidelity set by
``bits``; safe early-termination proofs in the engine are exact *with respect
to the quantized scores*, matching the paper's "non-safe, fidelity set by the
quantization level" framing.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Quantizer", "fit_quantizer"]


@dataclasses.dataclass(frozen=True)
class Quantizer:
    bits: int
    scale: float  # impact = ceil(score * scale), clipped to [1, 2^bits - 1]

    @property
    def max_impact(self) -> int:
        return (1 << self.bits) - 1

    def quantize(self, scores: np.ndarray) -> np.ndarray:
        q = np.ceil(scores.astype(np.float64) * self.scale)
        return np.clip(q, 1, self.max_impact).astype(np.int32)

    def dequantize(self, impacts: np.ndarray) -> np.ndarray:
        return impacts.astype(np.float32) / np.float32(self.scale)


def fit_quantizer(scores: np.ndarray, bits: int = 8) -> Quantizer:
    m = float(scores.max()) if scores.size else 1.0
    m = max(m, 1e-9)
    return Quantizer(bits=bits, scale=((1 << bits) - 1) / m)
