"""Range-aware anytime DAAT traversal (paper §3, DESIGN.md §2).

Two execution modes over the same per-range scoring step:

  * host-driven — one jitted ``score_range_step`` per range with the go/no-go
    decision taken on the host between steps (the paper's steady_clock loop;
    this is also how a real TPU deployment would interleave device steps with
    SLA decisions), used by core.anytime;
  * device-driven — ``device_traverse`` runs the whole query in a
    ``lax.while_loop`` with a postings budget (the deterministic JASS-style
    proxy), fully jittable and vmappable for batched serving.

Baselines share this engine via flags (DESIGN.md §2 table):
  ordering="boundsum"|"docid"  ×  bounds="range"|"global"  ×  safe/budget/fixed.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bound_sum
from repro.core.clustered_index import BLOCK, ClusteredIndex, pack_dir_entries
from repro.kernels.range_scorer import ops as scorer_ops

__all__ = [
    "DOCS_FORMATS",
    "DeviceIndex",
    "IMPACT_BIAS",
    "IMPACT_DTYPES",
    "TopKState",
    "TraverseCarry",
    "TraverseResult",
    "QueryPlan",
    "Engine",
    "init_state",
    "init_carry",
    "batched_init_carry",
    "carry_done",
    "carry_result",
    "merge_topk",
    "pack_impacts",
    "score_range_step",
    "device_traverse",
    "batched_traverse",
    "batched_traverse_resume",
    "topk_docs",
    "batched_topk_docs",
    "exit_reason",
    "exit_reasons",
]

IMPACT_BIAS = scorer_ops.IMPACT_BIAS
IMPACT_DTYPES = ("int32", "int8")
DOCS_FORMATS = scorer_ops.DOCS_FORMATS


def pack_impacts(impacts: np.ndarray, impact_dtype: str) -> np.ndarray:
    """Host impacts (true int32 codes) -> device storage representation.

    ``"int32"`` uploads impacts verbatim; ``"int8"`` stores the biased code
    ``impact - IMPACT_BIAS`` so 8-bit quantized impacts (range [1, 255])
    fit a signed byte — 1 B/posting in HBM, widened back inside the scorer
    gather (DESIGN.md §8). Requires every impact <= 2^8 - 1; the caller
    (``Engine``) enforces this via the quantizer's bit width.
    """
    if impact_dtype == "int32":
        return np.asarray(impacts, np.int32)
    if impact_dtype == "int8":
        return (np.asarray(impacts, np.int64) - IMPACT_BIAS).astype(np.int8)
    raise ValueError(f"impact_dtype {impact_dtype!r} not in {IMPACT_DTYPES}")


class DeviceIndex(NamedTuple):
    """jnp mirror of the host index (flat arrays only — a valid pytree).

    Under ``docs_format="packed"`` (DESIGN.md §12), ``docs`` shrinks to a
    (1,)-placeholder (never gathered) and the three ``pack_*`` leaves carry
    the bit-packed delta stream plus its per-block merged directory
    (``pack_dir_entries``), parallel to ``blk_start``. They default to
    None in the raw-int32 layout; None leaves vanish from the pytree, so
    vmap/shard_map over either shape works unchanged.
    """

    docs: jnp.ndarray  # [nnz] int32 (packed: [1] placeholder)
    impacts: jnp.ndarray  # [nnz] int32, or int8 biased by IMPACT_BIAS (§8)
    blk_start: jnp.ndarray  # [NB] int32
    blk_len: jnp.ndarray  # [NB] int32
    blk_maximp: jnp.ndarray  # [NB] int32
    bounds_dense: jnp.ndarray  # [V, R] int32
    range_starts: jnp.ndarray  # [R] int32
    range_sizes: jnp.ndarray  # [R] int32
    pack_words: jnp.ndarray | None = None  # [n_words] uint32 delta stream
    pack_dir: jnp.ndarray | None = None  # [NB] int32 merged (start | width)
    pack_first: jnp.ndarray | None = None  # [NB] int32 first docid per block


class TopKState(NamedTuple):
    vals: jnp.ndarray  # [k] int32, sorted descending (0 = empty slot)
    ids: jnp.ndarray  # [k] int32 (-1 = empty)
    postings: jnp.ndarray  # scalar int32 — postings scored so far
    blocks: jnp.ndarray  # scalar int32 — blocks processed so far


def init_state(k: int) -> TopKState:
    return TopKState(
        vals=jnp.zeros((k,), jnp.int32),
        ids=jnp.full((k,), -1, jnp.int32),
        postings=jnp.zeros((), jnp.int32),
        blocks=jnp.zeros((), jnp.int32),
    )


def theta(state: TopKState) -> jnp.ndarray:
    """Heap-entry threshold: k-th largest score so far (0 while unfilled)."""
    return state.vals[-1]


def _merge_topk(
    vals_a: jnp.ndarray,
    ids_a: jnp.ndarray,
    vals_b: jnp.ndarray,
    ids_b: jnp.ndarray,
    k: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Deterministic top-k merge: higher score first, then smaller docid.

    The (score desc, docid asc) total order makes tie-breaking identical to
    the host oracle, so safe traversals reproduce the oracle ranking
    *exactly*, not merely as a score multiset. Sorting 2k int32 elements is
    cheap (k <= 1000) and stays in int32 for the TPU target. Delegates to
    ``merge_topk`` so the comparator is structurally shared with the
    sharded broker merge — the bitwise-parity contract of DESIGN.md §4.
    """
    return merge_topk(
        jnp.concatenate([vals_a, vals_b]), jnp.concatenate([ids_a, ids_b]), k
    )


def merge_topk(vals: jnp.ndarray, ids: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k of flat (vals, ids) candidates under the heap's total order.

    Identical comparator to ``_merge_topk`` — score descending, docid
    ascending, empty slots (id < 0) last — so any grouping of candidates
    (incremental per-range merges on one device, or per-shard heaps merged
    by a broker) yields the same k winners bit-for-bit (DESIGN.md §4).
    """
    i_key = jnp.where(ids >= 0, ids, jnp.iinfo(jnp.int32).max)
    sel = jnp.lexsort((i_key, -vals))[:k]
    return vals[sel], ids[sel]


@functools.partial(
    jax.jit,
    static_argnames=("s_pad", "k", "impl", "prune_blocks", "interpret", "docs_format"),
)
def score_range_step(
    dix: DeviceIndex,
    state: TopKState,
    blk_ids: jnp.ndarray,  # [B] int32/int64, -1 padded
    rest: jnp.ndarray,  # [B] int32 — bound sum of *other* terms for pruning
    range_start: jnp.ndarray,  # scalar int32
    *,
    s_pad: int,
    k: int,
    impl: str = "xla",
    prune_blocks: bool = True,
    interpret: bool = True,
    docs_format: str = "int32",
) -> TopKState:
    """Score one range and merge its top-k into the running state."""
    th = theta(state)
    safe_ids = jnp.clip(blk_ids, 0).astype(jnp.int32)
    starts = dix.blk_start[safe_ids]
    lens = dix.blk_len[safe_ids]
    maximp = dix.blk_maximp[safe_ids]
    keep = blk_ids >= 0
    if prune_blocks:
        # Block-level refinement (paper "Improved Pruning With Local Range
        # Bounds"): a block survives only if its own max impact plus the other
        # terms' bounds can beat the current threshold.
        keep = keep & (maximp + rest > th)

    pack_kw = {}
    if docs_format == "packed":
        # Per-block packed directory rows travel with the block table; the
        # shared word stream goes through whole (DESIGN.md §12).
        pack_kw = dict(
            pack_words=dix.pack_words,
            pack_dir=dix.pack_dir[safe_ids],
            pack_firsts=dix.pack_first[safe_ids],
        )
    acc = scorer_ops.score_blocks(
        dix.docs,
        dix.impacts,
        starts,
        lens,
        keep,
        range_start,
        s_pad=s_pad,
        impl=impl,
        interpret=interpret,
        docs_format=docs_format,
        **pack_kw,
    )

    vals, loc = jax.lax.top_k(acc, k)
    cand_ids = jnp.where(vals > 0, loc.astype(jnp.int32) + range_start, -1)
    nv, ni = _merge_topk(state.vals, state.ids, vals.astype(jnp.int32), cand_ids, k)
    return TopKState(
        vals=nv,
        ids=ni,
        postings=state.postings + jnp.sum(jnp.where(keep, lens, 0), dtype=jnp.int32),
        blocks=state.blocks + jnp.sum(keep, dtype=jnp.int32),
    )


class TraverseResult(NamedTuple):
    state: TopKState
    ranges_processed: jnp.ndarray  # int32
    exit_safe: jnp.ndarray  # bool — stopped because remaining bounds <= theta
    exit_budget: jnp.ndarray  # bool — stopped by postings budget / fixed-n


class TraverseCarry(NamedTuple):
    """Resumable mid-flight traversal state (DESIGN.md §11).

    Exactly the ``device_traverse`` while_loop carry: the cursor into the
    processing order, the running top-k heap state (whose ``postings``
    counter is cumulative, so the postings budget keeps its meaning across
    calls), and the two exit flags. Every leaf is int32/bool, so a carry
    round-trips bitwise through host numpy — a query stepped ``quantum``
    ranges at a time over many dispatches finishes with leaves identical
    to one uninterrupted ``device_traverse`` call.
    """

    i: jnp.ndarray  # int32 — cursor into the processing order
    state: TopKState
    exit_safe: jnp.ndarray  # bool
    exit_budget: jnp.ndarray  # bool


def init_carry(k: int) -> TraverseCarry:
    """A fresh single-query carry (cursor 0, empty heap, no exit flags)."""
    return TraverseCarry(
        i=jnp.zeros((), jnp.int32),
        state=init_state(k),
        exit_safe=jnp.zeros((), bool),
        exit_budget=jnp.zeros((), bool),
    )


def batched_init_carry(n: int, k: int, parked: bool = False) -> TraverseCarry:
    """[n]-lane host (numpy) carry, fresh on every lane.

    ``parked=True`` raises every lane's ``exit_budget`` flag: a parked lane
    is inert — the resume loop's condition fails before any work — which is
    how vacant in-flight slots ride along in a dispatch at zero cost.
    """
    return TraverseCarry(
        i=np.zeros(n, np.int32),
        state=TopKState(
            vals=np.zeros((n, k), np.int32),
            ids=np.full((n, k), -1, np.int32),
            postings=np.zeros(n, np.int32),
            blocks=np.zeros(n, np.int32),
        ),
        exit_safe=np.zeros(n, bool),
        exit_budget=np.full(n, parked, bool),
    )


def carry_done(carry: TraverseCarry, n_ranges: int) -> np.ndarray:
    """Host-side completion mask: an exit flag fired, or the order is spent.

    Matches ``device_traverse``'s exit condition exactly — a lane whose
    flags are still False but whose cursor reached R exited "exhausted".
    """
    return (
        np.asarray(carry.exit_safe)
        | np.asarray(carry.exit_budget)
        | (np.asarray(carry.i) >= n_ranges)
    )


def carry_result(carry: TraverseCarry) -> TraverseResult:
    """View a (finished) carry as the equivalent ``TraverseResult``."""
    return TraverseResult(
        state=carry.state,
        ranges_processed=carry.i,
        exit_safe=carry.exit_safe,
        exit_budget=carry.exit_budget,
    )


def _traverse_loop(
    dix: DeviceIndex,
    blk_tab: jnp.ndarray,  # [R, B]
    rest_tab: jnp.ndarray,  # [R, B]
    order: jnp.ndarray,  # [R]
    ordered_bounds: jnp.ndarray,  # [R]
    carry: TraverseCarry,
    budget: jnp.ndarray,  # scalar int32
    maxr: jnp.ndarray,  # scalar int32
    *,
    s_pad: int,
    k: int,
    quantum: int | None,
    safe_stop: bool,
    prune_blocks: bool,
    impl: str,
    interpret: bool,
    docs_format: str = "int32",
) -> TraverseCarry:
    """The one range-at-a-time while_loop both entry points share.

    ``quantum=None`` runs to an exit condition (``device_traverse``);
    ``quantum=Q`` additionally stops after Q loop iterations, returning the
    carry mid-flight. The per-iteration arithmetic is identical either way,
    which is what makes resumed traversals bitwise-equal to uninterrupted
    ones: the same ``score_range_step`` calls happen against the same
    states, only sliced across more dispatches. (The iteration that
    discovers an exit condition scores nothing and leaves the cursor alone,
    so resuming past a quantum boundary re-derives the same flags.)
    """
    R = blk_tab.shape[0]

    def cond(c):
        steps, i, state, stop_safe, stop_budget = c
        live = (i < R) & ~stop_safe & ~stop_budget
        if quantum is not None:
            live = live & (steps < quantum)
        return live

    def body(c):
        steps, i, state, stop_safe, stop_budget = c
        r = order[i]
        bound = ordered_bounds[i]
        th = theta(state)
        # Safe termination: every remaining range is bounded by this one.
        s_safe = safe_stop & (bound <= th) & (th > 0)
        s_budget = (state.postings >= budget) | (i >= maxr)
        do = ~(s_safe | s_budget)

        def run(st):
            return score_range_step(
                dix,
                st,
                blk_tab[r],
                rest_tab[r],
                dix.range_starts[r],
                s_pad=s_pad,
                k=k,
                impl=impl,
                prune_blocks=prune_blocks,
                interpret=interpret,
                docs_format=docs_format,
            )

        state = jax.lax.cond(do, run, lambda st: st, state)
        return (steps + 1, i + jnp.where(do, 1, 0), state, s_safe, s_budget)

    c0 = (
        jnp.zeros((), jnp.int32),
        jnp.asarray(carry.i, jnp.int32),
        carry.state,
        jnp.asarray(carry.exit_safe, bool),
        jnp.asarray(carry.exit_budget, bool),
    )
    _, i, state, s_safe, s_budget = jax.lax.while_loop(cond, body, c0)
    return TraverseCarry(i=i, state=state, exit_safe=s_safe, exit_budget=s_budget)


@functools.partial(
    jax.jit,
    static_argnames=(
        "s_pad", "k", "impl", "prune_blocks", "safe_stop", "interpret",
        "docs_format",
    ),
)
def device_traverse(
    dix: DeviceIndex,
    blk_tab: jnp.ndarray,  # [R, B] int32, -1 padded — per-range block ids
    rest_tab: jnp.ndarray,  # [R, B] int32
    order: jnp.ndarray,  # [R] int32 — processing order of ranges
    ordered_bounds: jnp.ndarray,  # [R] int32 — BoundSum of order[i] (0 if unused)
    *,
    s_pad: int,
    k: int,
    budget_postings: jnp.ndarray | int = 2**31 - 1,
    max_ranges: jnp.ndarray | int = 2**31 - 1,
    safe_stop: bool = True,
    prune_blocks: bool = True,
    impl: str = "xla",
    interpret: bool = True,
    docs_format: str = "int32",
) -> TraverseResult:
    """Whole-query traversal in a lax.while_loop (device-side anytime mode)."""
    carry = _traverse_loop(
        dix,
        blk_tab,
        rest_tab,
        order,
        ordered_bounds,
        init_carry(k),
        jnp.asarray(budget_postings, jnp.int32),
        jnp.asarray(max_ranges, jnp.int32),
        s_pad=s_pad,
        k=k,
        quantum=None,
        safe_stop=safe_stop,
        prune_blocks=prune_blocks,
        impl=impl,
        interpret=interpret,
        docs_format=docs_format,
    )
    return carry_result(carry)


@functools.partial(
    jax.jit,
    static_argnames=(
        "s_pad", "k", "impl", "prune_blocks", "safe_stop", "interpret",
        "docs_format",
    ),
)
def batched_traverse(
    dix: DeviceIndex,
    blk_tabs: jnp.ndarray,  # [N, R, B] int32, -1 padded
    rest_tabs: jnp.ndarray,  # [N, R, B] int32
    orders: jnp.ndarray,  # [N, R] int32
    ordered_bounds: jnp.ndarray,  # [N, R] int32
    budgets: jnp.ndarray,  # [N] int32 — per-query postings budgets
    max_ranges: jnp.ndarray,  # [N] int32 — per-query range budgets
    *,
    s_pad: int,
    k: int,
    safe_stop: bool = True,
    prune_blocks: bool = True,
    impl: str = "xla",
    interpret: bool = True,
    docs_format: str = "int32",
) -> TraverseResult:
    """vmapped ``device_traverse`` over a stacked batch of query plans.

    The index is broadcast (in_axes=None); every plan leaf and both budgets
    map over the leading batch axis, so one lagging query cannot consume
    another query's budget — each lane carries its own stop flags and the
    while_loop simply runs until the *last* lane finishes, with finished
    lanes masked to no-ops by their own ``exit_*`` state. The returned
    ``TraverseResult`` has batched leaves: ``state.vals`` is [N, k],
    ``ranges_processed`` / ``exit_safe`` / ``exit_budget`` are [N].
    """

    def one(bt, rt, o, ob, bud, mr):
        return device_traverse(
            dix,
            bt,
            rt,
            o,
            ob,
            s_pad=s_pad,
            k=k,
            budget_postings=bud,
            max_ranges=mr,
            safe_stop=safe_stop,
            prune_blocks=prune_blocks,
            impl=impl,
            interpret=interpret,
            docs_format=docs_format,
        )

    return jax.vmap(one)(
        blk_tabs, rest_tabs, orders, ordered_bounds, budgets, max_ranges
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "s_pad", "k", "quantum", "impl", "prune_blocks", "safe_stop", "interpret",
        "docs_format",
    ),
)
def batched_traverse_resume(
    dix: DeviceIndex,
    blk_tabs: jnp.ndarray,  # [N, R, B] int32, -1 padded
    rest_tabs: jnp.ndarray,  # [N, R, B] int32
    orders: jnp.ndarray,  # [N, R] int32
    ordered_bounds: jnp.ndarray,  # [N, R] int32
    budgets: jnp.ndarray,  # [N] int32 — per-lane postings budgets
    max_ranges: jnp.ndarray,  # [N] int32 — per-lane range budgets
    carry: TraverseCarry,  # [N]-batched leaves
    *,
    s_pad: int,
    k: int,
    quantum: int,
    safe_stop: bool = True,
    prune_blocks: bool = True,
    impl: str = "xla",
    interpret: bool = True,
    docs_format: str = "int32",
) -> TraverseCarry:
    """Resumable entry point: advance every lane at most ``quantum`` ranges.

    The in-flight serving primitive (DESIGN.md §11). Each lane carries one
    query's mid-flight ``TraverseCarry``; one dispatch steps all lanes by a
    bounded number of while_loop iterations and returns the updated carries.
    Lanes whose exit flags are already set (finished queries, parked slots)
    fail the loop condition immediately and ride along untouched, so a
    mixed batch of fresh, mid-flight, and vacant lanes costs one program.

    Chaining dispatches until ``carry_done`` is bitwise-equivalent to one
    ``device_traverse`` call per lane — same heap, counters, and exit flags
    (tests/test_inflight.py pins this tier-1).
    """

    def one(bt, rt, o, ob, bud, mr, c):
        return _traverse_loop(
            dix,
            bt,
            rt,
            o,
            ob,
            c,
            jnp.asarray(bud, jnp.int32),
            jnp.asarray(mr, jnp.int32),
            s_pad=s_pad,
            k=k,
            quantum=quantum,
            safe_stop=safe_stop,
            prune_blocks=prune_blocks,
            impl=impl,
            interpret=interpret,
            docs_format=docs_format,
        )

    return jax.vmap(one)(
        blk_tabs, rest_tabs, orders, ordered_bounds, budgets, max_ranges, carry
    )


def topk_docs(state: TopKState) -> tuple[np.ndarray, np.ndarray]:
    """(docids, scores) for one query's state with empty slots stripped."""
    vals = np.asarray(state.vals)
    ids = np.asarray(state.ids)
    keep = ids >= 0
    return ids[keep], vals[keep]


def batched_topk_docs(state: TopKState) -> list[tuple[np.ndarray, np.ndarray]]:
    """Per-query (docids, scores) lists from a vmapped [N, k] state."""
    vals = np.asarray(state.vals)
    ids = np.asarray(state.ids)
    out = []
    for n in range(ids.shape[0]):
        keep = ids[n] >= 0
        out.append((ids[n][keep], vals[n][keep]))
    return out


def exit_reason(safe: bool, budget: bool) -> str:
    """Collapse the two exit flags into the host-facing reason string."""
    if safe:
        return "safe"
    if budget:
        return "budget"
    return "exhausted"


def exit_reasons(result: TraverseResult) -> list[str]:
    """Per-query exit reason strings from a batched ``TraverseResult``."""
    safe = np.asarray(result.exit_safe).reshape(-1)
    budget = np.asarray(result.exit_budget).reshape(-1)
    return [exit_reason(bool(s), bool(b)) for s, b in zip(safe, budget)]


# --------------------------------------------------------------------------
# Host-facing engine
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Device-ready per-query traversal inputs."""

    q_terms: np.ndarray  # [L] int32, -1 padded
    blk_tab: jnp.ndarray  # [R, B] int32
    rest_tab: jnp.ndarray  # [R, B] int32
    order: jnp.ndarray  # [R] int32
    ordered_bounds: jnp.ndarray  # [R] int32
    order_host: np.ndarray  # same as order, on host
    bounds_host: np.ndarray  # ordered bounds, on host


def _next_pow2(n: int, lo: int = 32) -> int:
    v = lo
    while v < n:
        v *= 2
    return v


class Engine:
    """Cluster-skipping anytime query engine over a built index.

    ``ordering``: "boundsum" (the paper's proposal) or "docid" (range-
    oblivious baseline). ``bounds``: "range" (U[t,r], enables safe stop and
    tight block pruning) or "global" (listwise U_t only — the Default-index
    baseline; safe stop then uses the whole-collection bound).
    ``impact_dtype``: "int32" (default) or "int8" — native 8-bit postings
    impacts in HBM, widened only inside the scorer gather (DESIGN.md §8).
    ``docs_format``: "int32" (default) or "packed" — bit-packed per-block
    docid deltas in HBM, decoded inside the scorer (DESIGN.md §12); bitwise
    identical results by contract.
    """

    def __init__(
        self,
        index: ClusteredIndex,
        k: int = 10,
        ordering: str = "boundsum",
        bounds: str = "range",
        impl: str = "xla",
        interpret: bool = True,
        impact_dtype: str = "int32",
        docs_format: str = "int32",
        obs=None,
    ):
        from repro.obs import NOOP  # local: obs is import-cycle-free by design

        self.obs = obs if obs is not None else NOOP
        self.index = index
        self.k = k
        self.ordering = ordering
        self.bounds = bounds
        self.impl = impl
        self.interpret = interpret
        if impact_dtype not in IMPACT_DTYPES:
            raise ValueError(f"impact_dtype {impact_dtype!r} not in {IMPACT_DTYPES}")
        if impact_dtype == "int8" and index.quantizer.bits > 8:
            raise ValueError(
                f"impact_dtype='int8' needs quantizer.bits <= 8, "
                f"got {index.quantizer.bits}"
            )
        self.impact_dtype = impact_dtype
        if docs_format not in DOCS_FORMATS:
            raise ValueError(f"docs_format {docs_format!r} not in {DOCS_FORMATS}")
        self.docs_format = docs_format
        self.s_pad = int(
            (index.max_range_size + BLOCK - 1) // BLOCK * BLOCK
        ) or BLOCK
        if docs_format == "packed":
            packed = index.packed_postings()
            # The raw docid array stays on the host; the scorer never
            # gathers it, so a (1,)-placeholder keeps the pytree shape.
            docs_dev = jnp.zeros((1,), jnp.int32)
            pack_dev = dict(
                pack_words=jnp.asarray(packed.words, jnp.uint32),
                pack_dir=jnp.asarray(pack_dir_entries(packed), jnp.int32),
                pack_first=jnp.asarray(packed.blk_first, jnp.int32),
            )
        else:
            docs_dev = jnp.asarray(index.docs, jnp.int32)
            pack_dev = {}
        self.dix = DeviceIndex(
            docs=docs_dev,
            impacts=jnp.asarray(pack_impacts(index.impacts, impact_dtype)),
            blk_start=jnp.asarray(index.blk_start, jnp.int32),
            blk_len=jnp.asarray(index.blk_len, jnp.int32),
            blk_maximp=jnp.asarray(index.blk_maximp, jnp.int32),
            bounds_dense=jnp.asarray(index.bounds_dense, jnp.int32),
            range_starts=jnp.asarray(index.range_starts, jnp.int32),
            range_sizes=jnp.asarray(index.arrangement.range_sizes, jnp.int32),
            **pack_dev,
        )

    @classmethod
    def from_artifact(cls, path: str, **kwargs) -> "Engine":
        """Load a saved index artifact (``repro.index_io``) into an engine.

        ``impact_dtype`` and ``docs_format`` default to how the artifact
        was saved, so an int8/packed artifact serves int8/packed in HBM
        unless overridden.
        """
        from repro import index_io  # local: index_io sits above core

        index = index_io.load_index(path)
        manifest = index_io.read_manifest(path)
        kwargs.setdefault("impact_dtype", manifest["impact_dtype"])
        kwargs.setdefault("docs_format", manifest.get("docs_format", "int32"))
        return cls(index, **kwargs)

    # ------------------------------------------------------------- planning
    def plan(self, q_terms: np.ndarray) -> QueryPlan:
        q = np.asarray(q_terms, dtype=np.int32).reshape(-1)
        blk, rest_range = self.index.query_block_table(q)
        R, width = blk.shape
        pad = _next_pow2(width)
        if pad != width:
            blk = np.pad(blk, ((0, 0), (0, pad - width)), constant_values=-1)
            rest_range = np.pad(rest_range, ((0, 0), (0, pad - width)))

        bsums = self.index.bounds_dense[q[q >= 0]].sum(axis=0).astype(np.int64)
        if self.bounds == "global":
            # Listwise bounds only: rest = sum of other terms' GLOBAL bounds.
            gsum = int(self.index.term_bound[q[q >= 0]].sum())
            rest = np.where(
                blk >= 0,
                gsum - self.index.term_bound[self.index.blk_term[blk.clip(0)]],
                0,
            ).astype(np.int32)
            # Safe stop bound per range = whole-collection bound (loose).
            per_range_bound = np.full(R, gsum, dtype=np.int64)
        else:
            rest = rest_range.astype(np.int32)
            per_range_bound = bsums

        if self.ordering == "boundsum":
            order = np.argsort(-bsums, kind="stable").astype(np.int32)
        elif self.ordering == "docid":
            order = np.arange(R, dtype=np.int32)
        else:
            raise ValueError(f"unknown ordering {self.ordering!r}")
        # The device tables are int32; a BoundSum past 2^31 must saturate,
        # not wrap — a negative bound satisfies `bound <= theta` immediately
        # and defeats safe termination. Saturation only errs conservative
        # (the traversal keeps going). The host copy keeps true int64 mass
        # for budget allocation (`query_shard_mass`).
        bounds_host = per_range_bound[order].astype(np.int64)
        ordered_bounds = np.clip(bounds_host, 0, 2**31 - 1).astype(np.int32)

        return QueryPlan(
            q_terms=q,
            blk_tab=jnp.asarray(blk, jnp.int32),
            rest_tab=jnp.asarray(rest, jnp.int32),
            order=jnp.asarray(order, jnp.int32),
            ordered_bounds=jnp.asarray(ordered_bounds, jnp.int32),
            order_host=order,
            bounds_host=bounds_host,
        )

    # ------------------------------------------------------- execution modes
    def init_state(self) -> TopKState:
        return init_state(self.k)

    def step(self, plan: QueryPlan, state: TopKState, i: int) -> TopKState:
        """Host-driven: score the i-th range of the plan's order."""
        r = int(plan.order_host[i])
        return score_range_step(
            self.dix,
            state,
            plan.blk_tab[r],
            plan.rest_tab[r],
            self.dix.range_starts[r],
            s_pad=self.s_pad,
            k=self.k,
            impl=self.impl,
            prune_blocks=True,
            interpret=self.interpret,
            docs_format=self.docs_format,
        )

    def traverse(
        self,
        plan: QueryPlan,
        budget_postings: int = 2**31 - 1,
        max_ranges: int = 2**31 - 1,
        safe_stop: bool = True,
        prune_blocks: bool = True,
    ) -> TraverseResult:
        """Device-driven whole-query traversal."""
        res = device_traverse(
            self.dix,
            plan.blk_tab,
            plan.rest_tab,
            plan.order,
            plan.ordered_bounds,
            s_pad=self.s_pad,
            k=self.k,
            budget_postings=budget_postings,
            max_ranges=max_ranges,
            safe_stop=safe_stop,
            prune_blocks=prune_blocks,
            impl=self.impl,
            interpret=self.interpret,
            docs_format=self.docs_format,
        )
        if self.obs.enabled:
            # Reading the exit flags forces a device sync; instrumentation
            # is allowed to cost time, never to change results.
            self.obs.count(
                "engine_queries",
                reason=exit_reason(bool(res.exit_safe), bool(res.exit_budget)),
            )
            self.obs.observe("engine_postings", int(res.state.postings))
        return res

    # ----------------------------------------------------------------- util
    def topk_docs(self, state: TopKState):
        """(docids, scores) with empty slots stripped, host-side.

        Accepts a single-query [k] state or a vmapped [N, k] state; the
        latter returns a per-query list of (docids, scores) pairs.
        """
        if np.asarray(state.ids).ndim == 2:
            return batched_topk_docs(state)
        return topk_docs(state)
