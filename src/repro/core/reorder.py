"""Document identifier reassignment (paper §3 "Document Arrangement").

Implements the paper's composition: topical clustering (cluster.py) followed
by *recursive graph bisection* (Dhulipala et al. [21]) applied within each
cluster, with clusters concatenated into contiguous docid ranges. Also
provides the Random and global-BP ("Reordered") baselines used throughout the
paper's tables.

BP here is the standard log-gap-cost bisection on the document-term bipartite
graph, vectorized in numpy: at each recursion node the document set is split
in half and refined by gain-sorted pair swaps for a bounded number of rounds.
This is an offline index-build step (the paper uses the same algorithm via an
external tool); results are cached by the index builder.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cluster import topical_clusters
from repro.data.synth import Corpus

__all__ = ["Arrangement", "arrange", "graph_bisection_order"]


@dataclasses.dataclass(frozen=True)
class Arrangement:
    """A docid assignment plus range structure.

    ``doc_order[new_id] = old_id``; ``range_ends[i]`` is one past the last
    new docid of range i (the paper's cluster map C, with c_0 = 0 implicit).
    """

    doc_order: np.ndarray  # [n_docs] int64 permutation
    range_ends: np.ndarray  # [n_ranges] int64, increasing, last == n_docs
    strategy: str

    @property
    def n_ranges(self) -> int:
        return int(self.range_ends.shape[0])

    @property
    def range_starts(self) -> np.ndarray:
        return np.concatenate([[0], self.range_ends[:-1]])

    @property
    def range_sizes(self) -> np.ndarray:
        return np.diff(np.concatenate([[0], self.range_ends]))

    def range_of_newdoc(self) -> np.ndarray:
        """Range id for every new docid — the Range(d) function of Eq. (2)."""
        n_docs = int(self.range_ends[-1])
        # analysis: allow[NARROW] values are range ids, bounded by n_ranges
        return np.searchsorted(self.range_ends, np.arange(n_docs), side="right").astype(
            np.int32
        )


def _gain(deg: np.ndarray, n: int) -> np.ndarray:
    """Log-gap cost model term: deg * log2(n / (deg + 1)).

    Entries at deg = -1 (a hypothetical move out of an empty side) are never
    gathered by the caller; compute them as 0 to keep the math finite.
    """
    n = max(n, 1)
    safe = np.maximum(deg + 1.0, 1e-9)
    with np.errstate(divide="ignore", invalid="ignore"):
        out = deg * np.log2(n / safe)
    return np.nan_to_num(out, nan=0.0, posinf=0.0, neginf=0.0)


def _bisect_once(
    docs: np.ndarray,
    doc_ptr: np.ndarray,
    doc_terms: np.ndarray,
    rounds: int,
    rng: np.random.Generator,
) -> tuple[np.ndarray, np.ndarray]:
    """One bisection of ``docs`` (old ids) into two halves, refined by swaps."""
    n = docs.shape[0]
    half = n // 2
    order = docs.copy()
    rng.shuffle(order)
    left, right = order[:half], order[half:]

    def postings_of(ds: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        # Concatenated term ids for a doc set + posting->position map.
        counts = doc_ptr[ds + 1] - doc_ptr[ds]
        idx = np.repeat(doc_ptr[ds], counts) + (
            np.arange(counts.sum()) - np.repeat(np.cumsum(counts) - counts, counts)
        )
        return doc_terms[idx], np.repeat(np.arange(ds.shape[0]), counts)

    for _ in range(rounds):
        lt, lpos = postings_of(left)
        rt, rpos = postings_of(right)
        n_terms = int(max(lt.max(initial=-1), rt.max(initial=-1))) + 1
        if n_terms == 0:
            break
        degl = np.bincount(lt, minlength=n_terms).astype(np.float64)
        degr = np.bincount(rt, minlength=n_terms).astype(np.float64)

        cur = _gain(degl, left.shape[0]) + _gain(degr, right.shape[0])
        move_lr = _gain(degl - 1, left.shape[0]) + _gain(degr + 1, right.shape[0])
        move_rl = _gain(degl + 1, left.shape[0]) + _gain(degr - 1, right.shape[0])
        gain_l_term = cur - move_lr  # gain contribution if a left doc leaves
        gain_r_term = cur - move_rl

        gains_l = np.zeros(left.shape[0])
        np.add.at(gains_l, lpos, gain_l_term[lt])
        gains_r = np.zeros(right.shape[0])
        np.add.at(gains_r, rpos, gain_r_term[rt])

        ol = np.argsort(-gains_l, kind="stable")
        orr = np.argsort(-gains_r, kind="stable")
        m = min(ol.shape[0], orr.shape[0])
        pair_gain = gains_l[ol[:m]] + gains_r[orr[:m]]
        n_swap = int(np.searchsorted(-pair_gain, 0.0))  # pair_gain > 0 prefix
        if n_swap == 0:
            break
        li, ri = ol[:n_swap], orr[:n_swap]
        left[li], right[ri] = right[ri].copy(), left[li].copy()
    return left, right


def graph_bisection_order(
    corpus: Corpus,
    docs: np.ndarray | None = None,
    leaf_size: int = 32,
    rounds: int = 8,
    seed: int = 3,
) -> np.ndarray:
    """Recursive graph bisection ordering of ``docs`` (default: all docs)."""
    if docs is None:
        docs = np.arange(corpus.n_docs, dtype=np.int64)
    rng = np.random.default_rng(seed)
    out: list[np.ndarray] = []
    stack: list[np.ndarray] = [docs.astype(np.int64)]
    # Iterative DFS preserving left-to-right order.
    result: list[np.ndarray] = []

    def rec(ds: np.ndarray, depth: int) -> None:
        if ds.shape[0] <= leaf_size or depth > 40:
            result.append(ds)
            return
        left, right = _bisect_once(ds, corpus.doc_ptr, corpus.doc_terms, rounds, rng)
        rec(left, depth + 1)
        rec(right, depth + 1)

    rec(docs.astype(np.int64), 0)
    del out, stack
    return np.concatenate(result) if result else np.empty(0, np.int64)


def arrange(
    corpus: Corpus,
    n_ranges: int = 32,
    strategy: str = "clustered_bp",
    seed: int = 0,
    bp_rounds: int = 8,
    kmeans_iters: int = 25,
) -> Arrangement:
    """Produce a docid arrangement.

    Strategies (paper terminology):
      - ``random``        Random baseline; single range.
      - ``bp``            global recursive graph bisection ("Reordered"
                          Default index); single range.
      - ``clustered``     topical clusters concatenated, natural order inside.
      - ``clustered_bp``  the paper's proposal: clusters, BP inside each,
                          concatenated (Clustered "Reordered" index).
      - ``clustered_random`` clusters concatenated, shuffled inside — isolates
                          the range structure from within-range locality.
    """
    rng = np.random.default_rng(seed)
    if strategy == "random":
        order = rng.permutation(corpus.n_docs).astype(np.int64)
        ends = np.array([corpus.n_docs], dtype=np.int64)
        return Arrangement(order, ends, strategy)
    if strategy == "bp":
        order = graph_bisection_order(corpus, rounds=bp_rounds, seed=seed)
        ends = np.array([corpus.n_docs], dtype=np.int64)
        return Arrangement(order, ends, strategy)
    if strategy not in ("clustered", "clustered_bp", "clustered_random"):
        raise ValueError(f"unknown strategy {strategy!r}")

    assign = topical_clusters(corpus, n_ranges, iters=kmeans_iters, seed=seed + 7)
    pieces: list[np.ndarray] = []
    ends_list: list[int] = []
    total = 0
    for c in range(int(assign.max()) + 1 if assign.size else 0):
        members = np.nonzero(assign == c)[0].astype(np.int64)
        if members.size == 0:
            continue
        if strategy == "clustered_bp":
            members = graph_bisection_order(
                corpus, docs=members, rounds=bp_rounds, seed=seed + 13 + c
            )
        elif strategy == "clustered_random":
            rng.shuffle(members)
        pieces.append(members)
        total += members.size
        ends_list.append(total)
    order = np.concatenate(pieces) if pieces else np.empty(0, np.int64)
    return Arrangement(order, np.asarray(ends_list, dtype=np.int64), strategy)
