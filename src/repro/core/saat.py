"""Impact-ordered index + score-at-a-time traversal (JASS baseline, §2.1).

Postings per term are sorted by decreasing impact into *segments* (one per
distinct impact value); query processing walks segments across all query
terms in globally non-increasing impact order, adding each segment's impact
into a document accumulator. JASS-E processes everything; JASS-A stops after
a postings budget rho, checked at segment boundaries (paper §6.1).

Includes the accumulator-locality instrumentation used to explain Table 3:
the number of distinct accumulator rows (2-D accumulator of Jia et al. [27])
touched by the processed postings — reordering shrinks it, which is the
paper's stated mechanism for the SAAT speedup.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bm25 import checked_int32
from repro.core.clustered_index import ClusteredIndex

__all__ = ["ImpactIndex", "build_impact_index", "saat_query"]

ACC_ROW = 512  # accumulator row (page) width for the locality metric
CACHE_LINE = 8  # int64 accumulator slots per 64-byte cache line


@dataclasses.dataclass
class ImpactIndex:
    n_docs: int
    n_terms: int
    docs: np.ndarray  # [nnz] int32 — sorted by (term, -impact, docid)
    imps: np.ndarray  # [nnz] int32
    seg_term: np.ndarray  # [S] int32
    seg_impact: np.ndarray  # [S] int32
    seg_start: np.ndarray  # [S] int64 — into docs/imps
    seg_end: np.ndarray  # [S] int64
    term_seg_ptr: np.ndarray  # [V+1] int64 — segments per term

    def space_gib(self, bits: int) -> float:
        imp_bytes = (bits + 7) // 8
        postings = self.docs.shape[0] * 4
        segs = self.seg_term.shape[0] * (4 + imp_bytes + 8)
        return (postings + segs) / 1024**3


def build_impact_index(index: ClusteredIndex) -> ImpactIndex:
    """Impact-ordered view of the same postings/quantization as ``index``."""
    V = index.n_terms
    term_of = np.repeat(np.arange(V), np.diff(index.ptr)).astype(np.int64)
    order = np.lexsort((index.docs, -index.impacts, term_of))
    docs = index.docs[order]
    imps = index.impacts[order]
    terms = term_of[order]

    # Segment boundaries where (term, impact) changes.
    change = np.ones(docs.shape[0], dtype=bool)
    if docs.shape[0] > 1:
        change[1:] = (terms[1:] != terms[:-1]) | (imps[1:] != imps[:-1])
    seg_start = np.nonzero(change)[0].astype(np.int64)
    seg_end = np.concatenate([seg_start[1:], [docs.shape[0]]]).astype(np.int64)
    seg_term = terms[seg_start].astype(np.int32)
    seg_impact = imps[seg_start].astype(np.int32)

    term_seg_ptr = np.zeros(V + 1, dtype=np.int64)
    counts = np.bincount(seg_term, minlength=V)
    term_seg_ptr[1:] = np.cumsum(counts)
    return ImpactIndex(
        n_docs=index.n_docs,
        n_terms=V,
        docs=checked_int32(docs, "impact-index docids"),
        imps=imps.astype(np.int32),
        seg_term=seg_term,
        seg_impact=seg_impact,
        seg_start=seg_start,
        seg_end=seg_end,
        term_seg_ptr=term_seg_ptr,
    )


@dataclasses.dataclass
class SaatResult:
    doc_ids: np.ndarray
    scores: np.ndarray
    postings_processed: int
    segments_processed: int
    rows_touched: int  # accumulator pages touched (ACC_ROW-wide)
    lines_touched: int  # accumulator cache lines touched (64 B)


def saat_query(
    impact_index: ImpactIndex,
    q_terms: np.ndarray,
    k: int = 10,
    rho: int | None = None,
) -> SaatResult:
    """SAAT traversal; rho = postings budget (None = exhaustive JASS-E)."""
    ii = impact_index
    segs: list[int] = []
    for t in np.asarray(q_terms).reshape(-1):
        if t < 0:
            continue
        s, e = ii.term_seg_ptr[int(t)], ii.term_seg_ptr[int(t) + 1]
        segs.extend(range(int(s), int(e)))
    if not segs:
        return SaatResult(np.empty(0, np.int64), np.empty(0, np.int64), 0, 0, 0, 0)
    segs_arr = np.asarray(segs)
    # Strictly non-increasing impact order across all query terms.
    order = segs_arr[np.argsort(-ii.seg_impact[segs_arr], kind="stable")]

    lens = (ii.seg_end[order] - ii.seg_start[order]).astype(np.int64)
    cum = np.cumsum(lens)
    if rho is None:
        n_seg = order.shape[0]
    else:
        # Process whole segments until the budget is crossed (>= 1 segment).
        n_seg = int(np.searchsorted(cum, rho, side="left") + 1)
        n_seg = min(n_seg, order.shape[0])

    acc = np.zeros(ii.n_docs, dtype=np.int64)
    touched: set[int] = set()
    lines: set[int] = set()
    postings = 0
    for s in order[:n_seg]:
        lo, hi = int(ii.seg_start[s]), int(ii.seg_end[s])
        d = ii.docs[lo:hi]
        acc[d] += int(ii.seg_impact[s])
        postings += hi - lo
        touched.update(np.unique(d // ACC_ROW).tolist())
        lines.update(np.unique(d // CACHE_LINE).tolist())

    kk = min(k, ii.n_docs)
    part = np.argpartition(-acc, kk - 1)[:kk]
    top = part[np.lexsort((part, -acc[part]))]
    keep = acc[top] > 0
    return SaatResult(
        doc_ids=top[keep].astype(np.int64),
        scores=acc[top][keep],
        postings_processed=postings,
        segments_processed=n_seg,
        rows_touched=len(touched),
        lines_touched=len(lines),
    )
