"""Graph substrate: synthetic graphs, CSR adjacency, neighbor sampling.

JAX has no sparse message-passing primitives beyond BCOO; the framework's
GNN path therefore works on explicit edge lists with segment reductions
(kernel_taxonomy §GNN). The neighbor sampler here is a real fanout sampler
over CSR (GraphSAGE minibatch training), not a stub: it produces the layered
subgraph arrays the model consumes, with deterministic seeding and fixed
padded shapes so the training step stays jit-stable.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["GraphData", "make_graph", "to_csr", "sample_subgraph", "make_molecule_batch"]


@dataclasses.dataclass(frozen=True)
class GraphData:
    n_nodes: int
    edges: np.ndarray  # [E, 2] int32 (src, dst)
    feats: np.ndarray  # [N, d] float32
    labels: np.ndarray  # [N] int32


def make_graph(
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    n_classes: int = 16,
    seed: int = 0,
) -> GraphData:
    """Community-structured random graph with learnable labels."""
    rng = np.random.default_rng(seed)
    n_comm = max(2, n_classes)
    comm = rng.integers(0, n_comm, size=n_nodes)
    # 80% intra-community edges, 20% random (degree-skewed endpoints).
    n_intra = int(n_edges * 0.8)
    src = rng.integers(0, n_nodes, size=n_edges)
    dst = np.empty(n_edges, dtype=np.int64)
    # intra: pick dst from same community via sorted trick
    order = np.argsort(comm, kind="stable")
    starts = np.searchsorted(comm[order], np.arange(n_comm))
    ends = np.concatenate([starts[1:], [n_nodes]])
    cs = comm[src[:n_intra]]
    lo, hi = starts[cs], ends[cs]
    dst[:n_intra] = order[(lo + rng.random(n_intra) * np.maximum(hi - lo, 1)).astype(np.int64)]
    dst[n_intra:] = rng.integers(0, n_nodes, size=n_edges - n_intra)
    edges = np.stack([src, dst], 1).astype(np.int32)

    centers = rng.normal(0, 1, size=(n_comm, d_feat)).astype(np.float32)
    feats = centers[comm] + rng.normal(0, 0.8, size=(n_nodes, d_feat)).astype(
        np.float32
    )
    labels = (comm % n_classes).astype(np.int32)
    return GraphData(n_nodes=n_nodes, edges=edges, feats=feats, labels=labels)


def to_csr(n_nodes: int, edges: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """In-neighbour CSR: for each dst node, the list of srcs."""
    order = np.argsort(edges[:, 1], kind="stable")
    srcs = edges[order, 0]
    counts = np.bincount(edges[:, 1], minlength=n_nodes)
    ptr = np.zeros(n_nodes + 1, dtype=np.int64)
    ptr[1:] = np.cumsum(counts)
    return ptr, srcs.astype(np.int32)


def sample_subgraph(
    ptr: np.ndarray,
    nbrs: np.ndarray,
    feats: np.ndarray,
    labels: np.ndarray,
    batch_nodes: np.ndarray,
    fanouts: tuple[int, ...],
    seed: int = 0,
):
    """Layered fanout sampling (GraphSAGE).

    Returns dict with fixed shapes:
      nodes   [n_sub]           all touched node ids (batch first)
      feats   [n_sub, d]
      labels  [n_batch]
      hops    list over layers (outermost hop first) of (src_idx, dst_idx)
              index pairs into ``nodes``, each padded to batch*prod(fanouts).
    """
    rng = np.random.default_rng(seed)
    frontier = np.asarray(batch_nodes, dtype=np.int64)
    node_index: dict[int, int] = {int(n): i for i, n in enumerate(frontier)}
    nodes: list[int] = [int(n) for n in frontier]
    hops = []
    for f in fanouts:
        src_idx: list[int] = []
        dst_idx: list[int] = []
        nxt: list[int] = []
        for d in frontier:
            lo, hi = int(ptr[d]), int(ptr[d + 1])
            if hi == lo:
                continue
            take = rng.integers(lo, hi, size=f)
            for s in nbrs[take]:
                s = int(s)
                if s not in node_index:
                    node_index[s] = len(nodes)
                    nodes.append(s)
                    nxt.append(s)
                src_idx.append(node_index[s])
                dst_idx.append(node_index[int(d)])
        pad = len(frontier) * f
        src = np.full(pad, -1, dtype=np.int32)
        dst = np.full(pad, -1, dtype=np.int32)
        src[: len(src_idx)] = src_idx
        dst[: len(dst_idx)] = dst_idx
        hops.append((src, dst))
        frontier = np.asarray(nxt + list(frontier), dtype=np.int64)
    nodes_arr = np.asarray(nodes, dtype=np.int64)
    return {
        "nodes": nodes_arr,
        "feats": feats[nodes_arr].astype(np.float32),
        "labels": labels[np.asarray(batch_nodes, dtype=np.int64)].astype(np.int32),
        "hops": hops,
        "n_batch": len(batch_nodes),
    }


def make_molecule_batch(
    batch: int, n_nodes: int, n_edges: int, d_feat: int, n_classes: int, seed: int = 0
):
    """Block-diagonal batch of small graphs (the ``molecule`` shape)."""
    rng = np.random.default_rng(seed)
    feats = rng.normal(0, 1, size=(batch * n_nodes, d_feat)).astype(np.float32)
    src = rng.integers(0, n_nodes, size=(batch, n_edges))
    dst = rng.integers(0, n_nodes, size=(batch, n_edges))
    off = (np.arange(batch) * n_nodes)[:, None]
    edges = np.stack([(src + off).ravel(), (dst + off).ravel()], 1).astype(np.int32)
    graph_ids = np.repeat(np.arange(batch), n_nodes).astype(np.int32)
    labels = rng.integers(0, n_classes, size=batch).astype(np.int32)
    return feats, edges, graph_ids, labels
