"""Deterministic synthetic corpora with planted topical structure.

The paper evaluates on Gov2 / ClueWeb09B, neither of which is available in
this container. We instead generate Zipf-distributed corpora with *planted
topics*: each topic owns a permuted Zipf distribution over the vocabulary, so
documents drawn from the same topic share vocabulary mass and are clusterable
by construction. This preserves the structural property the paper relies on —
that a topical clustering of the collection concentrates each query's
high-scoring documents into a small number of docid ranges — while remaining
laptop-scale and fully deterministic.

Planted relevance: a query is generated from a topic's high-mass terms, and
documents of that topic that contain the most query mass are "relevant". This
gives graded qrels for the Table-4-style effectiveness experiments without
human judgments.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

__all__ = [
    "Corpus",
    "QueryLog",
    "concat_corpora",
    "make_corpus",
    "make_query_log",
    "planted_qrels",
]


@dataclasses.dataclass(frozen=True)
class Corpus:
    """Bag-of-words corpus in CSR layout (doc -> (term, tf))."""

    n_docs: int
    n_terms: int
    doc_ptr: np.ndarray  # [n_docs+1] int64
    doc_terms: np.ndarray  # [nnz] int32, term ids, sorted within doc
    doc_tfs: np.ndarray  # [nnz] int32
    doc_topic: np.ndarray  # [n_docs] int32 — planted topic (hidden label)
    n_topics: int

    @property
    def nnz(self) -> int:
        return int(self.doc_terms.shape[0])

    @property
    def doc_lens(self) -> np.ndarray:
        """Token count per document (sum of tfs)."""
        out = np.zeros(self.n_docs, np.int64)
        np.add.at(
            out,
            np.repeat(np.arange(self.n_docs), np.diff(self.doc_ptr)),
            self.doc_tfs,
        )
        return out

    def doc_slice(self, d: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = self.doc_ptr[d], self.doc_ptr[d + 1]
        return self.doc_terms[s:e], self.doc_tfs[s:e]

    def fingerprint(self) -> str:
        h = hashlib.sha1()
        for a in (self.doc_ptr, self.doc_terms, self.doc_tfs):
            h.update(np.ascontiguousarray(a).tobytes())
        return h.hexdigest()[:16]


def concat_corpora(base: Corpus, delta: Corpus) -> Corpus:
    """Concatenate two corpora over one vocabulary (delta docs at the tail).

    The old-docid space of the result is ``base`` followed by ``delta``
    shifted by ``base.n_docs`` — the corpus a from-scratch build sees when
    verifying an incremental extension (DESIGN.md §10).
    """
    if base.n_terms != delta.n_terms:
        raise ValueError(
            f"corpora share one vocabulary: base has {base.n_terms} terms, "
            f"delta {delta.n_terms}"
        )
    return Corpus(
        n_docs=base.n_docs + delta.n_docs,
        n_terms=base.n_terms,
        doc_ptr=np.concatenate([base.doc_ptr, delta.doc_ptr[1:] + base.nnz]),
        doc_terms=np.concatenate([base.doc_terms, delta.doc_terms]),
        doc_tfs=np.concatenate([base.doc_tfs, delta.doc_tfs]),
        doc_topic=np.concatenate(
            [base.doc_topic, delta.doc_topic + base.n_topics]
        ).astype(np.int32),
        n_topics=base.n_topics + delta.n_topics,
    )


@dataclasses.dataclass(frozen=True)
class QueryLog:
    """Fixed-width padded query batch (term id -1 = padding)."""

    terms: np.ndarray  # [n_queries, max_len] int32, -1 padded
    lengths: np.ndarray  # [n_queries] int32
    topic: np.ndarray  # [n_queries] int32 — generating topic

    @property
    def n_queries(self) -> int:
        return int(self.terms.shape[0])

    @property
    def max_len(self) -> int:
        return int(self.terms.shape[1])


def _topic_term_dists(
    rng: np.random.Generator, n_topics: int, n_terms: int, zipf_s: float
) -> tuple[np.ndarray, np.ndarray]:
    """Per-topic permutation of a shared Zipf pmf over terms.

    A fraction of the vocabulary ("common" head) keeps its global rank in all
    topics, modelling stopword-ish terms that appear everywhere; the rest is
    permuted per topic so topics own distinct content vocabulary.
    """
    ranks = np.arange(1, n_terms + 1, dtype=np.float64)
    pmf = ranks ** (-zipf_s)
    pmf /= pmf.sum()
    # Small shared head (function words). Kept small: the paper's pipeline
    # stems AND stops, so stopword mass never reaches its indexes at all.
    n_common = max(8, n_terms // 200)
    perms = np.empty((n_topics, n_terms), dtype=np.int64)
    base = np.arange(n_terms)
    for t in range(n_topics):
        perm = base.copy()
        tail = perm[n_common:]
        rng.shuffle(tail)
        perm[n_common:] = tail
        perms[t] = perm
    return pmf, perms


def make_corpus(
    n_docs: int = 20_000,
    n_terms: int = 20_000,
    n_topics: int = 32,
    mean_doc_len: int = 120,
    zipf_s: float = 1.1,
    seed: int = 0,
) -> Corpus:
    """Generate a planted-topic Zipf corpus. Deterministic in all arguments."""
    rng = np.random.default_rng(seed)
    pmf, perms = _topic_term_dists(rng, n_topics, n_terms, zipf_s)

    # Document topic assignment: mildly imbalanced (Dirichlet) like real shards.
    topic_weights = rng.dirichlet(np.full(n_topics, 4.0))
    doc_topic = rng.choice(n_topics, size=n_docs, p=topic_weights).astype(np.int32)

    # Document lengths: lognormal around the mean, >= 8 tokens.
    lens = np.maximum(
        8, rng.lognormal(np.log(mean_doc_len), 0.45, size=n_docs)
    ).astype(np.int64)

    # Draw terms per doc from its topic's distribution.  Vectorized per topic.
    doc_ptr = np.zeros(n_docs + 1, dtype=np.int64)
    terms_out: list[np.ndarray] = [np.empty(0, np.int32)] * n_docs
    tfs_out: list[np.ndarray] = [np.empty(0, np.int32)] * n_docs
    for t in range(n_topics):
        docs_t = np.nonzero(doc_topic == t)[0]
        if docs_t.size == 0:
            continue
        total = int(lens[docs_t].sum())
        draws = rng.choice(n_terms, size=total, p=pmf)  # ranks in topic order
        draws = perms[t][draws]  # map rank -> actual term id
        off = 0
        for d in docs_t:
            chunk = draws[off : off + lens[d]]
            off += lens[d]
            uniq, counts = np.unique(chunk, return_counts=True)
            terms_out[d] = uniq.astype(np.int32)
            tfs_out[d] = counts.astype(np.int32)
    for d in range(n_docs):
        doc_ptr[d + 1] = doc_ptr[d] + terms_out[d].shape[0]
    return Corpus(
        n_docs=n_docs,
        n_terms=n_terms,
        doc_ptr=doc_ptr,
        doc_terms=np.concatenate(terms_out) if n_docs else np.empty(0, np.int32),
        doc_tfs=np.concatenate(tfs_out) if n_docs else np.empty(0, np.int32),
        doc_topic=doc_topic,
        n_topics=n_topics,
    )


def make_query_log(
    corpus: Corpus,
    n_queries: int = 1000,
    max_len: int = 8,
    seed: int = 1,
    length_dist: tuple[float, ...] = (0.2, 0.2, 0.2, 0.2, 0.2),
    df_max_frac: float = 0.05,
    df_min: int = 20,
) -> QueryLog:
    """Sample queries biased by length like the paper's Million Query sample.

    ``length_dist[i]`` is the probability of length ``i+1``; the final bucket
    means ">= len(length_dist)" and is filled up to ``max_len``. Terms are
    drawn from the query topic's high tf-idf vocabulary, restricted to
    *content-word* document frequencies (df in [df_min, df_max_frac*N]) —
    real query logs are content terms, not stopwords, and the paper's range
    structure presumes exactly that.
    """
    rng = np.random.default_rng(seed)

    # Recover topic vocab empirically (top tf-idf mass per planted topic).
    n_topics = corpus.n_topics
    topic_term_mass = np.zeros((n_topics, corpus.n_terms), dtype=np.float64)
    doc_topic_rep = np.repeat(corpus.doc_topic, np.diff(corpus.doc_ptr))
    np.add.at(topic_term_mass, (doc_topic_rep, corpus.doc_terms), corpus.doc_tfs)
    df = np.zeros(corpus.n_terms, dtype=np.int64)
    np.add.at(df, corpus.doc_terms, 1)
    idf = np.log(1.0 + corpus.n_docs / np.maximum(df, 1))
    informative = topic_term_mass * idf[None, :]
    content = (df >= df_min) & (df <= max(df_min + 1, int(df_max_frac * corpus.n_docs)))
    if content.sum() >= 64:  # keep a usable pool on tiny corpora
        informative = informative * content[None, :]

    probs = np.asarray(length_dist, dtype=np.float64)
    probs /= probs.sum()
    lengths = np.empty(n_queries, dtype=np.int32)
    for i in range(n_queries):
        bucket = rng.choice(probs.size, p=probs)
        if bucket == probs.size - 1:
            lengths[i] = rng.integers(probs.size, max_len + 1)
        else:
            lengths[i] = bucket + 1

    terms = np.full((n_queries, max_len), -1, dtype=np.int32)
    topics = rng.integers(0, n_topics, size=n_queries).astype(np.int32)
    for i in range(n_queries):
        t = topics[i]
        top = np.argsort(-informative[t])[:256]
        w = informative[t][top]
        w = w / w.sum() if w.sum() > 0 else np.full(top.size, 1.0 / top.size)
        take = rng.choice(top, size=lengths[i], replace=False, p=w)
        terms[i, : lengths[i]] = np.sort(take)
    return QueryLog(terms=terms, lengths=lengths, topic=topics)


def planted_qrels(
    corpus: Corpus, qlog: QueryLog, n_rel: int = 20
) -> list[dict[int, float]]:
    """Graded relevance from the generative structure (for RBP/AP).

    A document is relevant to a query iff it shares the query's planted
    topic AND carries high query-term mass; the top n_rel such docs get
    graded gains (1.0 for the top half, 0.5 below). Computed from corpus
    structure only — independent of any index or traversal code.
    """
    df = np.zeros(corpus.n_terms, dtype=np.int64)
    np.add.at(df, corpus.doc_terms, 1)
    idf = np.log(1.0 + corpus.n_docs / np.maximum(df, 1))
    doc_of = np.repeat(np.arange(corpus.n_docs), np.diff(corpus.doc_ptr))

    out: list[dict[int, float]] = []
    for qi in range(qlog.n_queries):
        terms = set(int(t) for t in qlog.terms[qi] if t >= 0)
        mask = np.isin(corpus.doc_terms, list(terms))
        mass = np.zeros(corpus.n_docs)
        np.add.at(
            mass, doc_of[mask],
            corpus.doc_tfs[mask] * idf[corpus.doc_terms[mask]],
        )
        mass[corpus.doc_topic != qlog.topic[qi]] = 0.0  # same-topic constraint
        top = np.argsort(-mass)[:n_rel]
        top = top[mass[top] > 0]
        grades = {}
        for r, d in enumerate(top):
            grades[int(d)] = 1.0 if r < max(1, len(top) // 2) else 0.5
        out.append(grades)
    return out
