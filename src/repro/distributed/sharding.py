"""Mesh/sharding context shared by models, trainer, and dry-run."""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "ShardCtx",
    "named",
    "data_spec",
    "shard_map",
    "axis_size",
    "replica_mesh",
    "retrieval_mesh",
]


def retrieval_mesh(n_shards: int, axis: str = "shard") -> Mesh:
    """1-D mesh for range-sharded retrieval (DESIGN.md §4).

    One mesh axis carrying index shards; raises if the runtime exposes fewer
    devices than shards (tests force CPU host devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    n_dev = jax.device_count()
    if n_dev < n_shards:
        raise ValueError(
            f"retrieval_mesh needs {n_shards} devices, have {n_dev}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count before "
            "importing jax, or use the single-device vmap path"
        )
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh((n_shards,), (axis,))
    return Mesh(np.asarray(jax.devices()[:n_shards]), (axis,))


def replica_mesh(
    n_replicas: int,
    n_shards: int,
    data_axis: str = "data",
    shard_axis: str = "shard",
) -> Mesh:
    """2-D (data x shard) mesh for replicated shard groups (DESIGN.md §9).

    Rows carry full index replicas (query parallelism over ``data_axis``),
    columns carry range shards; needs ``n_replicas * n_shards`` devices.
    """
    need = n_replicas * n_shards
    n_dev = jax.device_count()
    if n_dev < need:
        raise ValueError(
            f"replica_mesh needs {n_replicas} x {n_shards} = {need} devices, "
            f"have {n_dev}; set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count before importing jax, or drop to 1 replica"
        )
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh((n_replicas, n_shards), (data_axis, shard_axis))
    return Mesh(
        np.asarray(jax.devices()[:need]).reshape(n_replicas, n_shards),
        (data_axis, shard_axis),
    )


def axis_size(name):
    """Mesh-axis size inside a mapped body; pre-0.5 jax lacks lax.axis_size."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=None):
    """``jax.shard_map`` with fallback to the pre-0.5 experimental API.

    Callers use the modern keyword surface (``axis_names`` = the manual mesh
    axes, ``check_vma``); on older jax this translates to
    ``jax.experimental.shard_map.shard_map`` where the equivalents are
    ``auto`` (the *complement*: axes left automatic) and ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = frozenset(axis_names)
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    if check_vma is not None:
        kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Which mesh axes carry data parallelism and which carry model/TP/EP.

    data_axes is ("pod", "data") on the multi-pod mesh, ("data",) otherwise.
    """

    mesh: Mesh
    data_axes: tuple = ("data",)
    model_axis: str = "model"

    @property
    def n_model(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def n_data(self) -> int:
        n = 1
        for a in self.data_axes:
            n *= self.mesh.shape[a]
        return n

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def named(ctx: ShardCtx | None, tree, specs):
    """Apply with_sharding_constraint when a ctx is present (no-op locally)."""
    if ctx is None:
        return tree
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, ctx.sharding(s)),
        tree,
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def data_spec(ctx: ShardCtx, *trailing) -> P:
    """Batch-sharded spec: first dim over all data axes."""
    return P(ctx.data_axes, *trailing)
