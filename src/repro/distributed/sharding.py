"""Mesh/sharding context shared by models, trainer, and dry-run."""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["ShardCtx", "named", "data_spec"]


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Which mesh axes carry data parallelism and which carry model/TP/EP.

    data_axes is ("pod", "data") on the multi-pod mesh, ("data",) otherwise.
    """

    mesh: Mesh
    data_axes: tuple = ("data",)
    model_axis: str = "model"

    @property
    def n_model(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def n_data(self) -> int:
        n = 1
        for a in self.data_axes:
            n *= self.mesh.shape[a]
        return n

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def named(ctx: ShardCtx | None, tree, specs):
    """Apply with_sharding_constraint when a ctx is present (no-op locally)."""
    if ctx is None:
        return tree
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, ctx.sharding(s)),
        tree,
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def data_spec(ctx: ShardCtx, *trailing) -> P:
    """Batch-sharded spec: first dim over all data axes."""
    return P(ctx.data_axes, *trailing)
