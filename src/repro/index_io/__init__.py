"""Index lifecycle subsystem (DESIGN.md §8).

Three pillars:

  * **artifact** — a versioned on-disk representation of ``ClusteredIndex``
    and per-shard ``IndexShard`` sets: a JSON manifest (format version,
    build params, quantizer state, fingerprint) plus one ``.npy`` per array
    with optional memory-mapped loading. ``save_index``/``load_index`` and
    ``save_shards``/``load_shards`` round-trip bitwise — the loaded artifact
    produces `device_traverse` results identical to the in-memory build.
  * **corpus_io** — a ``Corpus`` reader registry: TSV/JSONL collection
    readers that run anywhere, and CIFF / ``ir_datasets`` readers gated
    behind the optional ``repro[corpus]`` extra with a clean error when the
    dependency is absent.
  * **native int8 impact storage** — artifacts persist impacts as biased
    int8 codes (``impact - IMPACT_BIAS``) and engines built from them keep
    postings impacts int8 in HBM, widening only inside the scorer gather
    (``kernels/range_scorer/ref.py``).

Incremental artifacts (DESIGN.md §10): ``save_delta``/``load_chain`` store
corpus appends as delta segments chained by ``parent_fingerprint``;
``append_index`` is the one-call append-and-publish, ``compact`` squashes a
chain into a fresh base bitwise-equal to a from-scratch build.

Bit-packed docids (DESIGN.md §12): format v2 artifacts can persist the
docid stream as per-block fixed-width packed deltas (``docs_format=
"packed"``); ``repack`` migrates existing artifacts in place-for-place
with an identical fingerprint.

CLI: ``python -m repro.index_io
{build,append,compact,repack,log,inspect,validate}``.
"""

from repro.index_io.artifact import (  # noqa: F401
    FORMAT_VERSION,
    SUPPORTED_FORMAT_VERSIONS,
    ArtifactError,
    CorruptArtifactError,
    VersionMismatchError,
    append_index,
    clean_stale_staging,
    compact,
    iter_chain,
    load_chain,
    load_index,
    load_shards,
    read_manifest,
    repack,
    save_delta,
    save_index,
    save_shards,
    validate_artifact,
)
from repro.index_io.corpus_io import (  # noqa: F401
    MissingDependencyError,
    available_readers,
    get_reader,
    read_corpus,
    register_reader,
)

__all__ = [
    "FORMAT_VERSION",
    "SUPPORTED_FORMAT_VERSIONS",
    "ArtifactError",
    "CorruptArtifactError",
    "MissingDependencyError",
    "VersionMismatchError",
    "append_index",
    "available_readers",
    "clean_stale_staging",
    "compact",
    "get_reader",
    "iter_chain",
    "load_chain",
    "load_index",
    "load_shards",
    "read_corpus",
    "read_manifest",
    "register_reader",
    "repack",
    "save_delta",
    "save_index",
    "save_shards",
    "validate_artifact",
]
