"""Index lifecycle CLI (DESIGN.md §8, §10).

    python -m repro.index_io build    --out DIR [--reader synth|tsv|jsonl|ciff|ir_datasets]
                                      [--source PATH_OR_ID] [--impact-dtype int8|int32]
                                      [--docs-format int32|packed]
                                      [--shards N] [index-build options]
    python -m repro.index_io append   --parent DIR --out DIR [--reader ...]
                                      [--source ...] [--n-ranges N] [--strategy S]
    python -m repro.index_io compact  DIR --out DIR [--impact-dtype int8|int32]
    python -m repro.index_io repack   DIR --out DIR [--docs-format int32|packed]
                                      [--impact-dtype int8|int32]
    python -m repro.index_io log      DIR
    python -m repro.index_io inspect  DIR [--json]
    python -m repro.index_io validate DIR

``build`` ingests a corpus through the reader registry, builds the
cluster-skipping index, and saves a versioned artifact (optionally plus a
range-sharded artifact). ``append`` ingests a *delta* corpus and publishes
it as a chain link under an existing artifact (or chain head); ``compact``
squashes a chain into a fresh base; ``repack`` migrates an artifact to a
different docid encoding (DESIGN.md §12 bit-packed deltas) with an
identical fingerprint; ``log`` prints the chain links and any
topology-journal records at the head. ``inspect`` prints the manifest,
per-array table, and space report without loading postings eagerly.
``validate`` deep-checks checksums, dtypes/shapes, and the index
fingerprint (for a delta: the whole chain).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.index_io import artifact, corpus_io


def _build(args: argparse.Namespace) -> int:
    from repro.core.clustered_index import build_index, shard_device_index

    if args.impact_dtype == "int8" and args.bits > 8:
        raise ValueError(
            f"--impact-dtype int8 needs --bits <= 8 (got {args.bits}); "
            f"use --impact-dtype int32 for wider quantization"
        )

    reader_kw = {}
    if args.reader == "synth":
        reader_kw = dict(
            n_docs=args.n_docs, n_terms=args.n_terms, n_topics=args.n_topics,
            mean_doc_len=args.mean_doc_len, seed=args.seed,
        )
    elif args.max_docs is not None:
        reader_kw = dict(max_docs=args.max_docs)

    t0 = time.perf_counter()
    corpus = corpus_io.read_corpus(args.reader, args.source, **reader_kw)
    t1 = time.perf_counter()
    print(
        f"read [{args.reader}] {corpus.n_docs} docs, {corpus.n_terms} terms, "
        f"{corpus.nnz} doc-term pairs ({t1 - t0:.1f}s)"
    )

    index = build_index(
        corpus, n_ranges=args.n_ranges, strategy=args.strategy,
        bits=args.bits, seed=args.seed,
    )
    t2 = time.perf_counter()
    print(
        f"built index: {index.nnz} postings, {index.n_blocks} blocks, "
        f"{index.n_ranges} ranges ({t2 - t1:.1f}s)"
    )

    build_params = dict(
        reader=args.reader, source=args.source, n_ranges=args.n_ranges,
        strategy=args.strategy, bits=args.bits, seed=args.seed,
    )
    artifact.save_index(
        index, args.out, impact_dtype=args.impact_dtype,
        build_params=build_params, overwrite=args.overwrite,
        docs_format=args.docs_format,
    )
    print(
        f"saved {args.out} (impact_dtype={args.impact_dtype}, "
        f"docs_format={args.docs_format})"
    )

    if args.shards:
        shards = shard_device_index(index, args.shards)
        spath = args.out + f".shards{args.shards}"
        artifact.save_shards(
            shards, spath, impact_dtype=args.impact_dtype,
            quantizer=index.quantizer,
            source_fingerprint=index.fingerprint(),
            overwrite=args.overwrite,
        )
        print(f"saved {spath} ({args.shards} range shards)")
    return 0


def _append(args: argparse.Namespace) -> int:
    reader_kw = {}
    if args.reader == "synth":
        reader_kw = dict(
            n_docs=args.n_docs, n_terms=args.n_terms, n_topics=args.n_topics,
            mean_doc_len=args.mean_doc_len, seed=args.seed,
        )
    elif args.max_docs is not None:
        reader_kw = dict(max_docs=args.max_docs)

    t0 = time.perf_counter()
    delta_corpus = corpus_io.read_corpus(args.reader, args.source, **reader_kw)
    t1 = time.perf_counter()
    print(
        f"read [{args.reader}] delta: {delta_corpus.n_docs} docs, "
        f"{delta_corpus.nnz} doc-term pairs ({t1 - t0:.1f}s)"
    )
    extended = artifact.append_index(
        args.parent, delta_corpus, args.out,
        impact_dtype=args.impact_dtype, overwrite=args.overwrite,
        n_ranges=args.n_ranges, strategy=args.strategy, seed=args.seed,
    )
    t2 = time.perf_counter()
    head = artifact.read_manifest(args.out)
    print(
        f"appended -> {args.out}: chain length {head['chain_length']}, "
        f"{extended.n_docs} docs total, {extended.n_ranges} ranges, "
        f"fingerprint {extended.fingerprint()} ({t2 - t1:.1f}s)"
    )
    return 0


def _compact(args: argparse.Namespace) -> int:
    t0 = time.perf_counter()
    head = artifact.read_manifest(args.path)
    artifact.clean_stale_staging(args.out)
    artifact.compact(
        args.path, args.out,
        impact_dtype=args.impact_dtype, overwrite=args.overwrite,
    )
    t1 = time.perf_counter()
    print(
        f"compacted {args.path} (chain length "
        f"{head.get('chain_length', 0)}) -> {args.out} ({t1 - t0:.1f}s)"
    )
    return 0


def _repack(args: argparse.Namespace) -> int:
    t0 = time.perf_counter()
    src = artifact.read_manifest(args.path)
    artifact.repack(
        args.path, args.out,
        docs_format=args.docs_format, impact_dtype=args.impact_dtype,
        overwrite=args.overwrite,
    )
    t1 = time.perf_counter()
    head = artifact.read_manifest(args.out)
    print(
        f"repacked {args.path} "
        f"(docs_format={src.get('docs_format', 'int32')}) -> {args.out} "
        f"(docs_format={head['docs_format']}, "
        f"impact_dtype={head['impact_dtype']}), "
        f"fingerprint {head['fingerprint']} ({t1 - t0:.1f}s)"
    )
    return 0


def _log(args: argparse.Namespace) -> int:
    # Chain links, head first (iter_chain owns the walk + cycle guard).
    for path, manifest in artifact.iter_chain(args.path):
        if manifest["kind"] == "index_delta":
            print(
                f"{path}: delta +{manifest['n_docs']} docs "
                f"(total {manifest.get('n_docs_total', '?')}), "
                f"chain length {manifest.get('chain_length', '?')}, "
                f"fingerprint {manifest['fingerprint']} "
                f"<- parent {manifest['parent_fingerprint']}"
            )
        else:
            print(
                f"{path}: {manifest['kind']} base, "
                f"{manifest.get('n_docs', '?')} docs, "
                f"fingerprint {manifest.get('fingerprint', '?')}"
            )

    # Topology-journal records at the head (DESIGN.md §10).
    from repro.control.journal import JOURNAL_NAME, TopologyJournal

    journal = TopologyJournal(os.path.join(args.path, JOURNAL_NAME))
    records = journal.records()
    if not records:
        print("journal: (no records)")
        return 0
    print(f"journal: {len(records)} record(s)")
    for rec in records:
        kind = rec.get("kind")
        if kind == "reshard":
            detail = f"cuts={rec.get('cuts')}"
        elif kind == "health":
            detail = (
                f"{rec.get('event')} shard={rec.get('shard')} "
                f"replica={rec.get('replica')}"
            )
        else:
            detail = json.dumps({k: v for k, v in rec.items() if k != "kind"})
        print(f"  [{rec.get('seq')}] {kind}: {detail}")
    return 0


def _inspect(args: argparse.Namespace) -> int:
    manifest = artifact.read_manifest(args.path)
    if args.json:
        json.dump(manifest, sys.stdout, indent=1, sort_keys=True)
        print()
        return 0

    kind = manifest["kind"]
    print(f"{args.path}: {kind} (format v{manifest['format_version']})")
    if kind == "clustered_index":
        q = manifest["quantizer"]
        print(
            f"  {manifest['n_docs']} docs, {manifest['n_terms']} terms, "
            f"{manifest['arrangement']['n_ranges']} ranges "
            f"({manifest['arrangement']['strategy']}), "
            f"{q['bits']}-bit impacts stored as {manifest['impact_dtype']}, "
            f"docids {manifest.get('docs_format', 'int32')}"
        )
        print(f"  fingerprint {manifest['fingerprint']}")
        rows = manifest["arrays"].items()
    elif kind == "index_delta":
        print(
            f"  +{manifest['n_docs']} docs (total {manifest['n_docs_total']}), "
            f"{manifest['n_ranges']} delta ranges, chain length "
            f"{manifest['chain_length']}, impacts stored as "
            f"{manifest['impact_dtype']}"
        )
        print(
            f"  fingerprint {manifest['fingerprint']} <- parent "
            f"{manifest['parent_fingerprint']} ({manifest['parent']})"
        )
        rows = manifest["arrays"].items()
    else:
        print(
            f"  {manifest['n_shards']} shards, impacts stored as "
            f"{manifest['impact_dtype']}"
        )
        rows = [
            (f"shard_{r['shard_id']}/{n}", m)
            for r in manifest["shards"]
            for n, m in r["arrays"].items()
        ]
    print(f"  {'array':<28}{'dtype':<8}{'shape':<18}bytes")
    total = 0
    for name, meta in rows:
        nbytes = os.path.getsize(os.path.join(args.path, meta["file"]))
        total += nbytes
        print(f"  {name:<28}{meta['dtype']:<8}{str(meta['shape']):<18}{nbytes}")
    print(f"  on-disk total: {total / 1e6:.2f} MB")

    if kind == "clustered_index":
        # Manifest metadata alone — no array is read, so inspect stays
        # cheap on collection-scale artifacts.
        from repro.core.clustered_index import device_bytes_report

        docs_format = manifest.get("docs_format", "int32")
        arrays = manifest["arrays"]
        n_pack_words = (
            arrays["pack_words"]["shape"][0] if docs_format == "packed" else 0
        )
        dev = device_bytes_report(
            nnz=manifest.get("nnz", arrays["impacts"]["shape"][0]),
            n_blocks=arrays["blk_start"]["shape"][0],
            n_terms=manifest["n_terms"],
            n_ranges=manifest["arrangement"]["n_ranges"],
            impact_dtype=manifest["impact_dtype"],
            docs_format=docs_format,
            n_pack_words=n_pack_words,
        )
        print(
            f"  device (HBM) at {manifest['impact_dtype']}/{docs_format}: "
            f"postings={dev['postings']} B (docs={dev['docs']}, "
            f"impacts={dev['impacts']}), total={dev['total']} B"
        )
    return 0


def _validate(args: argparse.Namespace) -> int:
    problems = artifact.validate_artifact(args.path)
    if problems:
        print(f"INVALID: {args.path}")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"OK: {args.path} validates (checksums, shapes, fingerprint)")
    return 0


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.index_io", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = parser.add_subparsers(dest="cmd", required=True)

    b = sub.add_parser("build", help="ingest a corpus and save an index artifact")
    b.add_argument("--out", required=True, help="artifact directory to create")
    b.add_argument("--reader", default="synth",
                   help="corpus reader (see repro.index_io.available_readers)")
    b.add_argument("--source", default="",
                   help="reader source: file path, or ir_datasets id")
    b.add_argument("--impact-dtype", default="int8", choices=("int8", "int32"))
    b.add_argument("--docs-format", default="int32", choices=("int32", "packed"),
                   help="docid storage: raw int32 or bit-packed block deltas")
    b.add_argument("--overwrite", action="store_true")
    b.add_argument("--shards", type=int, default=0,
                   help="also save a range-sharded artifact with N shards")
    b.add_argument("--n-ranges", type=int, default=32)
    b.add_argument("--strategy", default="clustered_bp")
    b.add_argument("--bits", type=int, default=8)
    b.add_argument("--seed", type=int, default=0)
    b.add_argument("--max-docs", type=int, default=None,
                   help="cap ingested documents (tsv/jsonl/ciff/ir_datasets)")
    b.add_argument("--n-docs", type=int, default=8000, help="synth reader only")
    b.add_argument("--n-terms", type=int, default=6000, help="synth reader only")
    b.add_argument("--n-topics", type=int, default=16, help="synth reader only")
    b.add_argument("--mean-doc-len", type=int, default=150, help="synth reader only")
    b.set_defaults(fn=_build)

    a = sub.add_parser(
        "append", help="ingest a delta corpus and publish a chain link"
    )
    a.add_argument("--parent", required=True,
                   help="existing index artifact or chain head to extend")
    a.add_argument("--out", required=True, help="delta directory to create")
    a.add_argument("--reader", default="synth")
    a.add_argument("--source", default="",
                   help="reader source: file path, or ir_datasets id")
    a.add_argument("--impact-dtype", default=None, choices=("int8", "int32"),
                   help="delta impact storage (default: parent's dtype)")
    a.add_argument("--overwrite", action="store_true")
    a.add_argument("--n-ranges", type=int, default=1,
                   help="ranges to carve the delta into (appended at the tail)")
    a.add_argument("--strategy", default="clustered",
                   help="delta arrangement strategy")
    a.add_argument("--seed", type=int, default=0)
    a.add_argument("--max-docs", type=int, default=None,
                   help="cap ingested documents (tsv/jsonl/ciff/ir_datasets)")
    a.add_argument("--n-docs", type=int, default=500, help="synth reader only")
    a.add_argument("--n-terms", type=int, default=6000, help="synth reader only")
    a.add_argument("--n-topics", type=int, default=16, help="synth reader only")
    a.add_argument("--mean-doc-len", type=int, default=150, help="synth reader only")
    a.set_defaults(fn=_append)

    c = sub.add_parser(
        "compact", help="squash a delta chain into a fresh base artifact"
    )
    c.add_argument("path", help="chain head (or base) to compact")
    c.add_argument("--out", required=True, help="compacted artifact directory")
    c.add_argument("--impact-dtype", default=None, choices=("int8", "int32"),
                   help="storage dtype (default: the head's dtype)")
    c.add_argument("--overwrite", action="store_true")
    c.set_defaults(fn=_compact)

    r = sub.add_parser(
        "repack", help="re-save an artifact under another docid encoding"
    )
    r.add_argument("path", help="source index artifact")
    r.add_argument("--out", required=True, help="repacked artifact directory")
    r.add_argument("--docs-format", default="packed",
                   choices=("int32", "packed"),
                   help="target docid encoding (default: packed)")
    r.add_argument("--impact-dtype", default=None, choices=("int8", "int32"),
                   help="storage dtype (default: the source's dtype)")
    r.add_argument("--overwrite", action="store_true")
    r.set_defaults(fn=_repack)

    g = sub.add_parser(
        "log", help="print the delta chain and topology-journal records"
    )
    g.add_argument("path")
    g.set_defaults(fn=_log)

    i = sub.add_parser("inspect", help="print manifest, arrays, space report")
    i.add_argument("path")
    i.add_argument("--json", action="store_true", help="dump raw manifest JSON")
    i.set_defaults(fn=_inspect)

    v = sub.add_parser("validate", help="deep-check an artifact (exit 1 if bad)")
    v.add_argument("path")
    v.set_defaults(fn=_validate)

    args = parser.parse_args(argv)
    try:
        return args.fn(args)
    except (
        artifact.ArtifactError,
        corpus_io.MissingDependencyError,
        ValueError,  # bad build/reader parameters, malformed source lines
        OSError,
    ) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
