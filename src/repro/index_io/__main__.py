"""Index lifecycle CLI (DESIGN.md §8).

    python -m repro.index_io build    --out DIR [--reader synth|tsv|jsonl|ciff|ir_datasets]
                                      [--source PATH_OR_ID] [--impact-dtype int8|int32]
                                      [--shards N] [index-build options]
    python -m repro.index_io inspect  DIR [--json]
    python -m repro.index_io validate DIR

``build`` ingests a corpus through the reader registry, builds the
cluster-skipping index, and saves a versioned artifact (optionally plus a
range-sharded artifact). ``inspect`` prints the manifest, per-array table,
and space report without loading postings eagerly. ``validate``
deep-checks checksums, dtypes/shapes, and the index fingerprint.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.index_io import artifact, corpus_io


def _build(args: argparse.Namespace) -> int:
    from repro.core.clustered_index import build_index, shard_device_index

    if args.impact_dtype == "int8" and args.bits > 8:
        raise ValueError(
            f"--impact-dtype int8 needs --bits <= 8 (got {args.bits}); "
            f"use --impact-dtype int32 for wider quantization"
        )

    reader_kw = {}
    if args.reader == "synth":
        reader_kw = dict(
            n_docs=args.n_docs, n_terms=args.n_terms, n_topics=args.n_topics,
            mean_doc_len=args.mean_doc_len, seed=args.seed,
        )
    elif args.max_docs is not None:
        reader_kw = dict(max_docs=args.max_docs)

    t0 = time.perf_counter()
    corpus = corpus_io.read_corpus(args.reader, args.source, **reader_kw)
    t1 = time.perf_counter()
    print(
        f"read [{args.reader}] {corpus.n_docs} docs, {corpus.n_terms} terms, "
        f"{corpus.nnz} doc-term pairs ({t1 - t0:.1f}s)"
    )

    index = build_index(
        corpus, n_ranges=args.n_ranges, strategy=args.strategy,
        bits=args.bits, seed=args.seed,
    )
    t2 = time.perf_counter()
    print(
        f"built index: {index.nnz} postings, {index.n_blocks} blocks, "
        f"{index.n_ranges} ranges ({t2 - t1:.1f}s)"
    )

    build_params = dict(
        reader=args.reader, source=args.source, n_ranges=args.n_ranges,
        strategy=args.strategy, bits=args.bits, seed=args.seed,
    )
    artifact.save_index(
        index, args.out, impact_dtype=args.impact_dtype,
        build_params=build_params, overwrite=args.overwrite,
    )
    print(f"saved {args.out} (impact_dtype={args.impact_dtype})")

    if args.shards:
        shards = shard_device_index(index, args.shards)
        spath = args.out + f".shards{args.shards}"
        artifact.save_shards(
            shards, spath, impact_dtype=args.impact_dtype,
            quantizer=index.quantizer,
            source_fingerprint=index.fingerprint(),
            overwrite=args.overwrite,
        )
        print(f"saved {spath} ({args.shards} range shards)")
    return 0


def _inspect(args: argparse.Namespace) -> int:
    manifest = artifact.read_manifest(args.path)
    if args.json:
        json.dump(manifest, sys.stdout, indent=1, sort_keys=True)
        print()
        return 0

    kind = manifest["kind"]
    print(f"{args.path}: {kind} (format v{manifest['format_version']})")
    if kind == "clustered_index":
        q = manifest["quantizer"]
        print(
            f"  {manifest['n_docs']} docs, {manifest['n_terms']} terms, "
            f"{manifest['arrangement']['n_ranges']} ranges "
            f"({manifest['arrangement']['strategy']}), "
            f"{q['bits']}-bit impacts stored as {manifest['impact_dtype']}"
        )
        print(f"  fingerprint {manifest['fingerprint']}")
        rows = manifest["arrays"].items()
    else:
        print(
            f"  {manifest['n_shards']} shards, impacts stored as "
            f"{manifest['impact_dtype']}"
        )
        rows = [
            (f"shard_{r['shard_id']}/{n}", m)
            for r in manifest["shards"]
            for n, m in r["arrays"].items()
        ]
    print(f"  {'array':<28}{'dtype':<8}{'shape':<18}bytes")
    total = 0
    for name, meta in rows:
        nbytes = os.path.getsize(os.path.join(args.path, meta["file"]))
        total += nbytes
        print(f"  {name:<28}{meta['dtype']:<8}{str(meta['shape']):<18}{nbytes}")
    print(f"  on-disk total: {total / 1e6:.2f} MB")

    if kind == "clustered_index":
        # Manifest metadata alone — no array is read, so inspect stays
        # cheap on collection-scale artifacts.
        from repro.core.clustered_index import device_bytes_report

        dev = device_bytes_report(
            nnz=manifest["arrays"]["docs"]["shape"][0],
            n_blocks=manifest["arrays"]["blk_start"]["shape"][0],
            n_terms=manifest["n_terms"],
            n_ranges=manifest["arrangement"]["n_ranges"],
            impact_dtype=manifest["impact_dtype"],
        )
        print(
            f"  device (HBM) at {manifest['impact_dtype']}: "
            f"postings={dev['postings']} B (docs={dev['docs']}, "
            f"impacts={dev['impacts']}), total={dev['total']} B"
        )
    return 0


def _validate(args: argparse.Namespace) -> int:
    problems = artifact.validate_artifact(args.path)
    if problems:
        print(f"INVALID: {args.path}")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"OK: {args.path} validates (checksums, shapes, fingerprint)")
    return 0


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.index_io", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = parser.add_subparsers(dest="cmd", required=True)

    b = sub.add_parser("build", help="ingest a corpus and save an index artifact")
    b.add_argument("--out", required=True, help="artifact directory to create")
    b.add_argument("--reader", default="synth",
                   help="corpus reader (see repro.index_io.available_readers)")
    b.add_argument("--source", default="",
                   help="reader source: file path, or ir_datasets id")
    b.add_argument("--impact-dtype", default="int8", choices=("int8", "int32"))
    b.add_argument("--overwrite", action="store_true")
    b.add_argument("--shards", type=int, default=0,
                   help="also save a range-sharded artifact with N shards")
    b.add_argument("--n-ranges", type=int, default=32)
    b.add_argument("--strategy", default="clustered_bp")
    b.add_argument("--bits", type=int, default=8)
    b.add_argument("--seed", type=int, default=0)
    b.add_argument("--max-docs", type=int, default=None,
                   help="cap ingested documents (tsv/jsonl/ciff/ir_datasets)")
    b.add_argument("--n-docs", type=int, default=8000, help="synth reader only")
    b.add_argument("--n-terms", type=int, default=6000, help="synth reader only")
    b.add_argument("--n-topics", type=int, default=16, help="synth reader only")
    b.add_argument("--mean-doc-len", type=int, default=150, help="synth reader only")
    b.set_defaults(fn=_build)

    i = sub.add_parser("inspect", help="print manifest, arrays, space report")
    i.add_argument("path")
    i.add_argument("--json", action="store_true", help="dump raw manifest JSON")
    i.set_defaults(fn=_inspect)

    v = sub.add_parser("validate", help="deep-check an artifact (exit 1 if bad)")
    v.add_argument("path")
    v.set_defaults(fn=_validate)

    args = parser.parse_args(argv)
    try:
        return args.fn(args)
    except (
        artifact.ArtifactError,
        corpus_io.MissingDependencyError,
        ValueError,  # bad build/reader parameters, malformed source lines
        OSError,
    ) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
