"""Versioned on-disk artifacts for built indexes (DESIGN.md §8, §10).

Layout — one directory per artifact:

    <path>/
      manifest.json            format version, build params, quantizer
                               state, fingerprint, per-array metadata
      arrays/<name>.npy        ClusteredIndex arrays (kind "clustered_index")
      shard_00000/<name>.npy   per-shard arrays      (kind "index_shards")
      arrays/<name>.npy        IndexDelta arrays     (kind "index_delta")

A delta artifact (DESIGN.md §10) stores only the appended documents'
postings, impacts, and arrangement, plus a manifest whose
``parent_fingerprint`` chains it to its base: ``parent`` is a relative path
to the parent artifact (another delta, or the base ``clustered_index``).
``load_index`` on a chain head follows parents to the base and materializes
the extended index link by link (``core.clustered_index.apply_delta``);
``compact`` squashes a chain into a fresh base bitwise-equal to a
from-scratch build on the concatenated corpus at the base's frozen
collection statistics.

Every array is a plain ``.npy`` file so loading can be eager
(``np.load``) or memory-mapped (``mmap_mode="r"``) without any format
change. Impacts are persisted at the chosen ``impact_dtype``: ``"int32"``
verbatim, or ``"int8"`` as the biased code ``impact - IMPACT_BIAS`` (the
same convention the device upload path uses — ``core.range_daat
.pack_impacts``). Loading always widens impacts back to exact int32 on the
host, so ``ClusteredIndex.fingerprint()`` is stable across save/load at
either dtype and traversal over a loaded artifact is bitwise identical to
the in-memory build.

Writes are atomic at directory granularity: arrays and manifest land in a
``<path>.tmp`` staging directory that is renamed into place last, so a
crashed save never leaves a half-artifact where a loader finds it.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import time

import numpy as np

from repro.core.bm25 import BM25Params, CollectionStats
from repro.core.clustered_index import (
    ClusteredIndex,
    IndexDelta,
    IndexShard,
    PackedPostings,
    apply_delta,
    plan_delta,
    unpack_docs,
)
from repro.core.quantize import Quantizer
from repro.core.range_daat import IMPACT_BIAS, IMPACT_DTYPES, pack_impacts
from repro.core.reorder import Arrangement

__all__ = [
    "FORMAT",
    "FORMAT_VERSION",
    "ArtifactError",
    "CorruptArtifactError",
    "VersionMismatchError",
    "append_index",
    "clean_stale_staging",
    "compact",
    "iter_chain",
    "load_chain",
    "load_index",
    "load_shards",
    "read_manifest",
    "repack",
    "save_delta",
    "save_index",
    "save_shards",
    "validate_artifact",
]

FORMAT = "repro-index-artifact"
# Version history:
#   1 — initial artifact layout (raw int32 docs.npy always present).
#   2 — optional bit-packed docid deltas (DESIGN.md §12): manifest key
#       "docs_format", "packed" artifacts replace docs.npy with the
#       PACKED_ARRAYS below. v1 artifacts remain readable; writes are v2.
FORMAT_VERSION = 2
SUPPORTED_FORMAT_VERSIONS = (1, 2)

# Readers retry once on a path that vanished mid-read: the overwrite publish
# (``_atomic_publish``) swaps via rename-aside + rename-in, so a healthy
# artifact can be absent for the microseconds between the two renames.
_ENOENT_RETRY_S = 0.05

# A delta chain longer than this is assumed to be a parent-pointer cycle or
# a pathological artifact; compact long before here.
MAX_CHAIN_LENGTH = 4096

# ClusteredIndex fields persisted as arrays (arrangement flattened in).
INDEX_ARRAYS = (
    "ptr", "docs", "impacts",
    "blk_start", "blk_len", "blk_maxdoc", "blk_maximp", "blk_term", "blk_range",
    "tr_ptr", "tr_range", "tr_blk_start", "tr_blk_end", "tr_bound",
    "term_bound", "bounds_dense",
    "doc_order", "range_ends",
)

# Arrays replacing "docs" under docs_format="packed" (DESIGN.md §12): the
# shared uint32 delta word stream plus its per-block directory.
PACKED_ARRAYS = ("pack_words", "pack_start", "pack_width", "pack_first")

SHARD_ARRAYS = (
    "docs", "impacts", "blk_start", "blk_len", "blk_maxdoc", "blk_maximp",
    "blk_map", "range_starts", "range_sizes", "bounds_dense",
)

SHARD_SCALARS = ("shard_id", "range_lo", "range_hi", "doc_base", "n_docs", "postings")

# IndexDelta fields persisted as arrays (kind "index_delta").
DELTA_ARRAYS = ("ptr", "docs", "impacts", "doc_order", "range_ends")


class ArtifactError(Exception):
    """Base error for index artifact I/O."""


class CorruptArtifactError(ArtifactError):
    """Manifest unreadable, arrays missing, or metadata contradicts data."""


class VersionMismatchError(ArtifactError):
    """Artifact was written by an incompatible format version."""


# --------------------------------------------------------------------------
# Low-level helpers
# --------------------------------------------------------------------------


def _retry_enoent(fn):
    """Run ``fn``; on FileNotFoundError retry once after a short sleep.

    The reader half of the ``_atomic_publish`` contract: an overwrite swap
    admits a briefly-absent path, so one vanished open on a healthy artifact
    is expected, and only a *second* miss means the artifact is really gone.

    Scope: this protects against the absent-path window only. A reader that
    straddles a publish of *different content* (manifest from the old tree,
    arrays from the new) still gets a typed ``CorruptArtifactError`` from
    the dtype/shape/fingerprint checks — a clean retryable error, not
    torn data; full snapshot isolation would need versioned directories.
    """
    try:
        return fn()
    except FileNotFoundError:
        time.sleep(_ENOENT_RETRY_S)
        return fn()


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _write_array(root: str, rel: str, arr: np.ndarray) -> dict:
    path = os.path.join(root, rel)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    # analysis: allow[ARTIFACT] root is the caller's mkdtemp *.tmp-*
    # staging dir; write_artifact publishes it with one os.replace.
    np.save(path, np.ascontiguousarray(arr))
    return {
        "file": rel,
        "dtype": str(arr.dtype),
        "shape": list(arr.shape),
        "sha256": _sha256_file(path),
    }


def _read_array(root: str, meta: dict, name: str, mmap: bool) -> np.ndarray:
    path = os.path.join(root, meta["file"])
    try:
        arr = _retry_enoent(
            lambda: np.load(
                path, mmap_mode="r" if mmap else None, allow_pickle=False
            )
        )
    except FileNotFoundError:
        raise CorruptArtifactError(
            f"array {name!r}: missing file {meta['file']}"
        ) from None
    except (ValueError, OSError) as e:
        raise CorruptArtifactError(f"array {name!r}: unreadable ({e})") from e
    if str(arr.dtype) != meta["dtype"] or list(arr.shape) != list(meta["shape"]):
        raise CorruptArtifactError(
            f"array {name!r}: manifest says {meta['dtype']}{meta['shape']}, "
            f"file holds {arr.dtype}{list(arr.shape)}"
        )
    return arr


def _pack_disk_impacts(impacts: np.ndarray, impact_dtype: str, bits: int) -> np.ndarray:
    """Disk uses the same representation the device upload path does
    (``pack_impacts``); this wrapper only adds the bit-width eligibility
    check, so the two conventions cannot drift apart."""
    if impact_dtype == "int8" and bits > 8:
        raise ValueError(f"impact_dtype='int8' needs quantizer.bits <= 8, got {bits}")
    return pack_impacts(impacts, impact_dtype)


def _unpack_disk_impacts(arr: np.ndarray, manifest: dict) -> np.ndarray:
    if manifest["impact_dtype"] == "int8":
        bias = int(manifest.get("impact_bias", IMPACT_BIAS))
        return (np.asarray(arr, np.int64) + bias).astype(np.int32)
    return np.asarray(arr, np.int32)


def _staging_dir(path: str) -> str:
    """Unique per-save staging directory beside the target.

    Unique (not a fixed ``<path>.tmp``) so concurrent saves of the same
    artifact — e.g. two processes missing the same ``build_index_cached``
    key — cannot clobber each other's half-written staging area; whoever
    publishes last simply wins the final rename.
    """
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    return tempfile.mkdtemp(prefix=os.path.basename(path) + ".tmp-", dir=parent)


def clean_stale_staging(path: str, max_age_s: float = 3600.0) -> list[str]:
    """Remove crashed saves' leftover staging dirs for this artifact path.

    A save that died mid-write leaves a ``<name>.tmp-*`` sibling behind;
    loaders never look at it (they address ``path`` directly), so it is
    inert but wastes disk. Only directories older than ``max_age_s`` are
    swept, so a *live* concurrent save's staging area is never clobbered.
    Returns the names removed. The CLI append/compact paths call this
    before staging their own write.
    """
    parent = os.path.dirname(os.path.abspath(path))
    prefix = os.path.basename(path) + ".tmp-"
    removed: list[str] = []
    if not os.path.isdir(parent):
        return removed
    now = time.time()
    for entry in os.listdir(parent):
        if not entry.startswith(prefix):
            continue
        full = os.path.join(parent, entry)
        try:
            if os.path.isdir(full) and now - os.path.getmtime(full) >= max_age_s:
                shutil.rmtree(full, ignore_errors=True)
                removed.append(entry)
        except OSError:
            continue  # raced a concurrent publish; nothing to sweep
    return removed


def _atomic_publish(tmp: str, path: str, overwrite: bool) -> None:
    """Rename the staging dir into place without a half-deleted window.

    Overwrite swaps in two renames — live artifact aside to a private
    name, staging dir in, old tree dropped last — so a concurrent reader
    observes the complete old artifact, a briefly-absent path (a cache
    *miss*, which rebuilds), or the complete new artifact; never a
    partially deleted directory. A lost publish race leaves the winner's
    equivalent artifact in place.
    """
    old = None
    if os.path.exists(path):
        if not overwrite:
            shutil.rmtree(tmp)
            raise ArtifactError(f"artifact already exists: {path} (overwrite=False)")
        old = tmp + ".old"  # unique: tmp is mkdtemp-fresh
        try:
            os.replace(path, old)
        except FileNotFoundError:
            old = None  # a concurrent publisher already swapped it away
    try:
        os.replace(tmp, path)
    except OSError:
        # Lost a publish race; the winner's artifact is equivalent.
        shutil.rmtree(tmp, ignore_errors=True)
        if not os.path.isdir(path):
            raise
    if old is not None:
        shutil.rmtree(old, ignore_errors=True)


def _write_manifest(root: str, manifest: dict) -> None:
    # analysis: allow[ARTIFACT] root is the staged dir, see _write_array
    with open(os.path.join(root, "manifest.json"), "w", encoding="utf-8") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)


def read_manifest(path: str) -> dict:
    """Load and version-check an artifact manifest.

    Raises ``CorruptArtifactError`` for missing/unreadable/foreign JSON and
    ``VersionMismatchError`` when the format version is not ours. Retries
    once when the path is briefly absent under a concurrent overwrite
    publish (see ``_atomic_publish``).
    """
    mpath = os.path.join(path, "manifest.json")

    def _read():
        with open(mpath, encoding="utf-8") as f:
            return json.load(f)

    try:
        manifest = _retry_enoent(_read)
    except FileNotFoundError:
        raise CorruptArtifactError(f"no manifest.json under {path}") from None
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CorruptArtifactError(f"manifest.json unparseable: {e}") from e
    if not isinstance(manifest, dict) or manifest.get("format") != FORMAT:
        raise CorruptArtifactError(
            f"{mpath} is not a {FORMAT} manifest "
            f"(format={manifest.get('format') if isinstance(manifest, dict) else None!r})"
        )
    version = manifest.get("format_version")
    if version not in SUPPORTED_FORMAT_VERSIONS:
        raise VersionMismatchError(
            f"artifact format_version={version!r}, this reader supports "
            f"{SUPPORTED_FORMAT_VERSIONS} — rebuild the artifact or upgrade "
            f"the reader"
        )
    return manifest


# --------------------------------------------------------------------------
# ClusteredIndex save / load
# --------------------------------------------------------------------------


def _index_array(index: ClusteredIndex, name: str) -> np.ndarray:
    if name == "doc_order":
        return index.arrangement.doc_order
    if name == "range_ends":
        return index.arrangement.range_ends
    return getattr(index, name)


def save_index(
    index: ClusteredIndex,
    path: str,
    impact_dtype: str = "int32",
    build_params: dict | None = None,
    overwrite: bool = False,
    docs_format: str = "int32",
) -> str:
    """Persist a built index as a versioned artifact directory.

    ``impact_dtype="int8"`` stores postings impacts as biased int8 codes
    (4x smaller than int32); every other array keeps its native dtype.
    ``docs_format="packed"`` replaces ``docs.npy`` with the bit-packed
    delta stream + per-block directory (DESIGN.md §12); ``load_index``
    reconstructs the exact docid array, so the fingerprint — and therefore
    chain/shard compatibility — is unchanged. Returns ``path``.
    """
    tmp = _staging_dir(path)
    try:
        return _save_index_into(
            tmp, index, path, impact_dtype, build_params, overwrite, docs_format
        )
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)  # no orphaned staging dirs
        raise


def _save_index_into(
    tmp: str,
    index: ClusteredIndex,
    path: str,
    impact_dtype: str,
    build_params: dict | None,
    overwrite: bool,
    docs_format: str = "int32",
) -> str:
    if docs_format not in ("int32", "packed"):
        raise ValueError(f"docs_format {docs_format!r} not in ('int32', 'packed')")
    arrays = {}
    for name in INDEX_ARRAYS:
        if name == "docs" and docs_format == "packed":
            continue
        arr = _index_array(index, name)
        if name == "impacts":
            arr = _pack_disk_impacts(arr, impact_dtype, index.quantizer.bits)
        arrays[name] = _write_array(tmp, os.path.join("arrays", f"{name}.npy"), arr)
    if docs_format == "packed":
        packed = index.packed_postings()
        packed_arrs = {
            "pack_words": np.asarray(packed.words, np.uint32),
            "pack_start": np.asarray(packed.blk_word_start, np.int64),
            "pack_width": np.asarray(packed.blk_width, np.int32),
            "pack_first": np.asarray(packed.blk_first, np.int32),
        }
        for name, arr in packed_arrs.items():
            arrays[name] = _write_array(
                tmp, os.path.join("arrays", f"{name}.npy"), arr
            )
    collection = None
    if index.stats is not None:
        # Frozen collection statistics (DESIGN.md §10): df as an array (it
        # is NOT derivable from ptr once the index has been extended),
        # scalars + BM25 params in the manifest, so a reloaded artifact can
        # plan further deltas.
        arrays["stats_df"] = _write_array(
            tmp,
            os.path.join("arrays", "stats_df.npy"),
            np.asarray(index.stats.df, np.int64),
        )
        collection = {
            "n_docs": int(index.stats.n_docs),
            "avg_doc_len": float(index.stats.avg_doc_len),
            "bm25": {"k1": float(index.bm25.k1), "b": float(index.bm25.b)},
        }

    manifest = {
        "format": FORMAT,
        "format_version": FORMAT_VERSION,
        "kind": "clustered_index",
        "n_docs": int(index.n_docs),
        "n_terms": int(index.n_terms),
        "nnz": int(index.nnz),
        "impact_dtype": impact_dtype,
        "docs_format": docs_format,
        "quantizer": {
            "bits": int(index.quantizer.bits),
            "scale": float(index.quantizer.scale),
        },
        "arrangement": {
            "strategy": index.arrangement.strategy,
            "n_ranges": int(index.n_ranges),
        },
        "build_params": build_params or {},
        "fingerprint": index.fingerprint(),
        "arrays": arrays,
    }
    if collection is not None:
        manifest["collection"] = collection
    if impact_dtype == "int8":
        manifest["impact_bias"] = IMPACT_BIAS
    _write_manifest(tmp, manifest)
    _atomic_publish(tmp, path, overwrite)
    return path


def load_index(path: str, mmap: bool = False) -> ClusteredIndex:
    """Load a ``clustered_index`` artifact (or a delta-chain head) back
    into host memory.

    ``mmap=True`` memory-maps every array read-only instead of copying it —
    int8-stored impacts are the one exception, since they are widened back
    to exact int32 for the host structure (the device upload re-narrows via
    ``Engine(impact_dtype="int8")``). Pointing at an ``index_delta``
    artifact follows its parent chain and materializes the extended index
    (DESIGN.md §10).
    """
    manifest = read_manifest(path)
    if manifest.get("kind") == "index_delta":
        return load_chain(path, mmap=mmap)
    if manifest.get("kind") != "clustered_index":
        raise CorruptArtifactError(
            f"expected kind 'clustered_index', got {manifest.get('kind')!r}"
        )
    docs_format = manifest.get("docs_format", "int32")  # v1: always raw
    metas = manifest.get("arrays", {})
    if docs_format == "packed":
        expected = [n for n in INDEX_ARRAYS if n != "docs"] + list(PACKED_ARRAYS)
    else:
        expected = list(INDEX_ARRAYS)
    missing = [n for n in expected if n not in metas]
    if missing:
        raise CorruptArtifactError(f"manifest lacks arrays: {missing}")
    a = {n: _read_array(path, metas[n], n, mmap) for n in expected}
    a["impacts"] = _unpack_disk_impacts(a["impacts"], manifest)
    if docs_format == "packed":
        # Reconstruct the exact int32 docid array from the packed stream;
        # the fingerprint check below certifies the decode bitwise.
        packed = PackedPostings(
            words=np.asarray(a.pop("pack_words"), np.uint32),
            blk_word_start=np.asarray(a.pop("pack_start"), np.int64),
            blk_width=np.asarray(a.pop("pack_width"), np.int32),
            blk_first=np.asarray(a.pop("pack_first"), np.int32),
            n_postings=int(a["impacts"].shape[0]),
        )
        a["docs"] = unpack_docs(packed, a["blk_start"], a["blk_len"])

    q = manifest["quantizer"]
    arrangement = Arrangement(
        doc_order=a["doc_order"],
        range_ends=a["range_ends"],
        strategy=manifest["arrangement"]["strategy"],
    )
    stats = None
    bm25 = BM25Params()
    collection = manifest.get("collection")
    if (collection is None) != ("stats_df" not in metas):
        # Both or neither: a half-present stats record is corruption, not a
        # pre-§10 artifact — failing here beats an unexplainable "cannot
        # extend" much later.
        raise CorruptArtifactError(
            "inconsistent frozen collection stats: manifest 'collection' "
            "and arrays entry 'stats_df' must both be present or both absent"
        )
    if collection is not None and "stats_df" in metas:
        stats = CollectionStats(
            n_docs=int(collection["n_docs"]),
            avg_doc_len=float(collection["avg_doc_len"]),
            df=np.asarray(
                _read_array(path, metas["stats_df"], "stats_df", mmap), np.int64
            ),
        )
        bm25 = BM25Params(
            k1=float(collection["bm25"]["k1"]), b=float(collection["bm25"]["b"])
        )
    index = ClusteredIndex(
        n_docs=int(manifest["n_docs"]),
        n_terms=int(manifest["n_terms"]),
        arrangement=arrangement,
        quantizer=Quantizer(bits=int(q["bits"]), scale=float(q["scale"])),
        stats=stats,
        bm25=bm25,
        **{n: a[n] for n in INDEX_ARRAYS if n not in ("doc_order", "range_ends")},
    )
    if index.fingerprint() != manifest["fingerprint"]:
        raise CorruptArtifactError(
            f"fingerprint mismatch: manifest {manifest['fingerprint']}, "
            f"loaded arrays {index.fingerprint()}"
        )
    return index


def repack(
    path: str,
    out: str,
    docs_format: str = "packed",
    impact_dtype: str | None = None,
    overwrite: bool = False,
) -> str:
    """Re-save an existing index artifact under another ``docs_format``.

    The migration path for pre-v2 (and raw-int32 v2) artifacts: load,
    re-encode the docid stream, save at the current format version. The
    index arrays are untouched bytes-for-bytes — a repacked artifact's
    arrays are identical to saving the source index packed from scratch,
    and its fingerprint matches the source. ``impact_dtype`` defaults to
    whatever the source artifact stored. Returns ``out``.
    """
    manifest = read_manifest(path)
    index = load_index(path)
    if impact_dtype is None:
        impact_dtype = manifest.get("impact_dtype", "int32")
    params = dict(manifest.get("build_params") or {})
    params["repacked_from"] = os.path.abspath(path)
    return save_index(
        index,
        out,
        impact_dtype=impact_dtype,
        build_params=params,
        overwrite=overwrite,
        docs_format=docs_format,
    )


# --------------------------------------------------------------------------
# Delta segments + manifest chain (DESIGN.md §10)
# --------------------------------------------------------------------------


def save_delta(
    delta: IndexDelta,
    path: str,
    parent_path: str,
    result_fingerprint: str,
    impact_dtype: str = "int32",
    overwrite: bool = False,
) -> str:
    """Persist an ``IndexDelta`` as a chain link under ``parent_path``.

    The delta directory stores only the appended documents' arrays (a few
    percent of a full re-save for a small append); the manifest records a
    *relative* ``parent`` path (the chain moves as a tree) plus
    ``parent_fingerprint`` — refused unless it matches the parent artifact,
    so a delta can never silently chain to the wrong base.
    ``result_fingerprint`` is the fingerprint of the materialized extended
    index (``apply_delta(parent, delta).fingerprint()``), which loaders
    verify after materialization.
    """
    parent = read_manifest(parent_path)
    if parent.get("kind") not in ("clustered_index", "index_delta"):
        raise ArtifactError(
            f"parent {parent_path} has kind {parent.get('kind')!r}; a delta "
            f"chains to a clustered_index base or another delta"
        )
    if parent.get("fingerprint") != delta.parent_fingerprint:
        raise ArtifactError(
            f"delta was planned against index {delta.parent_fingerprint}, "
            f"but parent artifact {parent_path} holds "
            f"{parent.get('fingerprint')} — refusing a mis-chained delta"
        )
    chain_length = int(parent.get("chain_length", 0)) + 1
    if chain_length > MAX_CHAIN_LENGTH:
        raise ArtifactError(
            f"chain would be {chain_length} links long (max "
            f"{MAX_CHAIN_LENGTH}); compact the chain first"
        )
    quantizer = parent.get("quantizer")
    if quantizer is None:
        raise CorruptArtifactError(
            f"parent {parent_path} records no quantizer state"
        )
    tmp = _staging_dir(path)
    try:
        return _save_delta_into(
            tmp, delta, path, parent_path, parent, chain_length,
            result_fingerprint, impact_dtype, overwrite,
        )
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)  # no orphaned staging dirs
        raise


def _save_delta_into(
    tmp: str,
    delta: IndexDelta,
    path: str,
    parent_path: str,
    parent: dict,
    chain_length: int,
    result_fingerprint: str,
    impact_dtype: str,
    overwrite: bool,
) -> str:
    arrays = {}
    for name in DELTA_ARRAYS:
        arr = getattr(delta, name)
        if name == "impacts":
            arr = _pack_disk_impacts(
                arr, impact_dtype, int(parent["quantizer"]["bits"])
            )
        arrays[name] = _write_array(tmp, os.path.join("arrays", f"{name}.npy"), arr)

    manifest = {
        "format": FORMAT,
        "format_version": FORMAT_VERSION,
        "kind": "index_delta",
        "parent": os.path.relpath(
            os.path.abspath(parent_path), start=os.path.abspath(path)
        ),
        "parent_fingerprint": delta.parent_fingerprint,
        "fingerprint": result_fingerprint,
        "chain_length": chain_length,
        "n_docs": int(delta.n_docs),
        "n_docs_total": int(
            parent.get("n_docs_total", parent.get("n_docs", 0))
        ) + int(delta.n_docs),
        "n_terms": int(delta.n_terms),
        "n_ranges": int(delta.n_ranges),
        "impact_dtype": impact_dtype,
        "quantizer": dict(parent["quantizer"]),
        "arrays": arrays,
    }
    if impact_dtype == "int8":
        manifest["impact_bias"] = IMPACT_BIAS
    _write_manifest(tmp, manifest)
    _atomic_publish(tmp, path, overwrite)
    return path


def _resolve_parent(path: str, manifest: dict) -> str:
    rel = manifest.get("parent")
    if not isinstance(rel, str) or not rel:
        raise CorruptArtifactError(f"{path}: delta manifest lacks a parent path")
    return os.path.normpath(os.path.join(path, rel))


def _load_delta_record(path: str, manifest: dict, mmap: bool) -> IndexDelta:
    metas = manifest.get("arrays", {})
    missing = [n for n in DELTA_ARRAYS if n not in metas]
    if missing:
        raise CorruptArtifactError(f"delta manifest lacks arrays: {missing}")
    a = {n: _read_array(path, metas[n], n, mmap) for n in DELTA_ARRAYS}
    a["impacts"] = _unpack_disk_impacts(a["impacts"], manifest)
    return IndexDelta(
        n_docs=int(manifest["n_docs"]),
        n_terms=int(manifest["n_terms"]),
        parent_fingerprint=manifest["parent_fingerprint"],
        ptr=np.asarray(a["ptr"], np.int64),
        docs=np.asarray(a["docs"], np.int32),
        impacts=a["impacts"],
        doc_order=np.asarray(a["doc_order"], np.int64),
        range_ends=np.asarray(a["range_ends"], np.int64),
    )


def iter_chain(path: str):
    """Yield ``(path, manifest)`` per chain link — head first, base last.

    The one chain walk (shared by :func:`load_chain` and the CLI ``log``):
    resolves relative ``parent`` pointers, guards against cycles and
    over-long chains, and guarantees the final yielded link is the
    ``clustered_index`` base — anything else raises
    ``CorruptArtifactError``. A bare base artifact yields just itself.
    """
    seen: set[str] = set()
    p, manifest = path, read_manifest(path)
    while manifest.get("kind") == "index_delta":
        key = os.path.abspath(p)
        if key in seen or len(seen) >= MAX_CHAIN_LENGTH:
            raise CorruptArtifactError(
                f"delta chain at {path} cycles or exceeds "
                f"{MAX_CHAIN_LENGTH} links"
            )
        seen.add(key)
        yield p, manifest
        p = _resolve_parent(p, manifest)
        manifest = read_manifest(p)
    if manifest.get("kind") != "clustered_index":
        raise CorruptArtifactError(
            f"chain base {p} has kind {manifest.get('kind')!r}, expected "
            f"'clustered_index'"
        )
    yield p, manifest


def load_chain(path: str, mmap: bool = False) -> ClusteredIndex:
    """Materialize a delta-chain head into one extended ``ClusteredIndex``.

    Walks ``parent`` pointers to the ``clustered_index`` base, then applies
    each delta oldest-first. Every link is verified twice: ``apply_delta``
    refuses a delta whose ``parent_fingerprint`` does not match what the
    chain materialized so far, and the materialized fingerprint must equal
    each link's manifest ``fingerprint``. Cycles and over-long chains raise
    ``CorruptArtifactError``.
    """
    links = list(iter_chain(path))
    index = load_index(links[-1][0], mmap=mmap)
    for dp, dm in reversed(links[:-1]):
        delta = _load_delta_record(dp, dm, mmap)
        try:
            index = apply_delta(index, delta)
        except ValueError as e:
            raise CorruptArtifactError(f"{dp}: {e}") from e
        if index.fingerprint() != dm.get("fingerprint"):
            raise CorruptArtifactError(
                f"{dp}: materialized fingerprint {index.fingerprint()} != "
                f"manifest {dm.get('fingerprint')}"
            )
    return index


def compact(
    path: str,
    out: str,
    impact_dtype: str | None = None,
    overwrite: bool = False,
) -> str:
    """Squash a delta chain into a fresh base artifact.

    The compacted base is bitwise-equal to a from-scratch
    ``clustered_index`` build on the concatenated corpus (at the chain's
    shared arrangement, quantizer, and frozen collection statistics) — the
    §10 tier-1 invariant, pinned by tests. ``impact_dtype`` defaults to the
    chain head's storage dtype. Compacting an un-chained base is a plain
    re-save (useful to shed a long-gone chain's journal).
    """
    manifest = read_manifest(path)
    if impact_dtype is None:
        impact_dtype = manifest.get("impact_dtype", "int32")
    index = load_index(path, mmap=True)
    return save_index(
        index,
        out,
        impact_dtype=impact_dtype,
        build_params={
            "compacted_from": os.path.abspath(path),
            "chain_length": int(manifest.get("chain_length", 0)),
        },
        overwrite=overwrite,
    )


def append_index(
    parent_path: str,
    corpus_delta,
    path: str,
    impact_dtype: str | None = None,
    overwrite: bool = False,
    n_ranges: int = 1,
    strategy: str = "clustered",
    seed: int = 0,
) -> ClusteredIndex:
    """Extend a saved artifact (or chain head) with a delta corpus.

    Loads/materializes the parent, plans + applies the delta, publishes a
    new chain link at ``path``, and returns the extended in-memory index
    (ready to serve — no reload needed). ``impact_dtype`` defaults to the
    parent's storage dtype. Stale staging leftovers for ``path`` from a
    crashed earlier append are swept first.
    """
    parent_manifest = read_manifest(parent_path)
    if impact_dtype is None:
        impact_dtype = parent_manifest.get("impact_dtype", "int32")
    index = load_index(parent_path)
    delta = plan_delta(
        index, corpus_delta, n_ranges=n_ranges, strategy=strategy, seed=seed
    )
    extended = apply_delta(index, delta)
    clean_stale_staging(path)
    save_delta(
        delta,
        path,
        parent_path,
        result_fingerprint=extended.fingerprint(),
        impact_dtype=impact_dtype,
        overwrite=overwrite,
    )
    return extended


# --------------------------------------------------------------------------
# IndexShard save / load
# --------------------------------------------------------------------------


def save_shards(
    shards: list[IndexShard],
    path: str,
    impact_dtype: str = "int32",
    quantizer: Quantizer | None = None,
    source_fingerprint: str | None = None,
    overwrite: bool = False,
) -> str:
    """Persist a shard set (``shard_device_index`` output) as one artifact.

    One subdirectory per shard; scalar shard metadata lives in the
    manifest. ``quantizer`` (the *global* scale shared by all shards) is
    **required** for int8 storage — the bit width decides whether biased
    int8 codes can represent every impact, and guessing would let >8-bit
    impacts wrap silently. ``source_fingerprint`` records the fingerprint
    of the index the shards were carved from, so loaders
    (``ShardedEngine.from_artifact``) can refuse a stale shard set.
    """
    if not shards:
        raise ValueError("cannot save an empty shard list")
    if impact_dtype == "int8" and quantizer is None:
        raise ValueError(
            "impact_dtype='int8' requires quantizer= (its bit width decides "
            "whether impacts fit a biased int8 code)"
        )
    bits = quantizer.bits if quantizer is not None else 32
    tmp = _staging_dir(path)
    try:
        return _save_shards_into(
            tmp, shards, path, impact_dtype, bits, quantizer,
            source_fingerprint, overwrite,
        )
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)  # no orphaned staging dirs
        raise


def _save_shards_into(
    tmp: str,
    shards: list[IndexShard],
    path: str,
    impact_dtype: str,
    bits: int,
    quantizer: Quantizer | None,
    source_fingerprint: str | None,
    overwrite: bool,
) -> str:
    shard_rows = []
    for shard in shards:
        sdir = f"shard_{shard.shard_id:05d}"
        arrays = {}
        for name in SHARD_ARRAYS:
            arr = getattr(shard, name)
            if name == "impacts":
                arr = _pack_disk_impacts(arr, impact_dtype, bits)
            arrays[name] = _write_array(tmp, os.path.join(sdir, f"{name}.npy"), arr)
        row = {s: int(getattr(shard, s)) for s in SHARD_SCALARS}
        row["arrays"] = arrays
        shard_rows.append(row)

    manifest = {
        "format": FORMAT,
        "format_version": FORMAT_VERSION,
        "kind": "index_shards",
        "n_shards": len(shards),
        "impact_dtype": impact_dtype,
        "shards": shard_rows,
    }
    # Contiguous layouts additionally record their range cuts, so reshard
    # tooling (repro.control, DESIGN.md §9) can read the layout without
    # loading any array. Non-contiguous shard sets (not produced by
    # shard_device_index) simply omit the key.
    lows = sorted((s.range_lo, s.range_hi) for s in shards)
    if lows[0][0] == 0 and all(
        a[1] == b[0] for a, b in zip(lows, lows[1:])
    ):
        manifest["range_cuts"] = [lo for lo, _ in lows] + [lows[-1][1]]
    if impact_dtype == "int8":
        manifest["impact_bias"] = IMPACT_BIAS
    if quantizer is not None:
        manifest["quantizer"] = {
            "bits": int(quantizer.bits),
            "scale": float(quantizer.scale),
        }
    if source_fingerprint is not None:
        manifest["source_fingerprint"] = source_fingerprint
    _write_manifest(tmp, manifest)
    _atomic_publish(tmp, path, overwrite)
    return path


def load_shards(path: str, mmap: bool = False) -> list[IndexShard]:
    """Load an ``index_shards`` artifact back into ``IndexShard`` objects."""
    manifest = read_manifest(path)
    if manifest.get("kind") != "index_shards":
        raise CorruptArtifactError(
            f"expected kind 'index_shards', got {manifest.get('kind')!r}"
        )
    rows = manifest.get("shards", [])
    if len(rows) != manifest.get("n_shards"):
        raise CorruptArtifactError(
            f"manifest n_shards={manifest.get('n_shards')} but "
            f"{len(rows)} shard entries"
        )
    shards = []
    for row in rows:
        metas = row["arrays"]
        missing = [n for n in SHARD_ARRAYS if n not in metas]
        if missing:
            raise CorruptArtifactError(
                f"shard {row.get('shard_id')}: manifest lacks arrays {missing}"
            )
        a = {
            n: _read_array(path, metas[n], f"shard/{n}", mmap)
            for n in SHARD_ARRAYS
        }
        a["impacts"] = _unpack_disk_impacts(a["impacts"], manifest)
        shards.append(
            IndexShard(**{s: int(row[s]) for s in SHARD_SCALARS}, **a)
        )
    return shards


# --------------------------------------------------------------------------
# Validation
# --------------------------------------------------------------------------


def _iter_array_metas(manifest: dict):
    if manifest["kind"] in ("clustered_index", "index_delta"):
        yield from manifest.get("arrays", {}).items()
    else:
        for row in manifest.get("shards", []):
            for name, meta in row.get("arrays", {}).items():
                yield f"shard_{row.get('shard_id')}/{name}", meta


def validate_artifact(path: str) -> list[str]:
    """Deep-check an artifact; returns a list of problems (empty = valid).

    Verifies the manifest parses at our format version, every array file
    exists with the advertised dtype/shape and sha256, and — for index
    artifacts — that the arrays rebuild to the manifest's fingerprint
    (``load_index`` enforces that too; here it lands in the report instead
    of raising).
    """
    problems: list[str] = []
    try:
        manifest = read_manifest(path)
    except ArtifactError as e:
        return [str(e)]

    for name, meta in _iter_array_metas(manifest):
        fpath = os.path.join(path, meta["file"])
        try:
            digest = _retry_enoent(lambda: _sha256_file(fpath))
        except FileNotFoundError:
            problems.append(f"{name}: missing file {meta['file']}")
            continue
        if digest != meta["sha256"]:
            problems.append(
                f"{name}: sha256 mismatch (manifest {meta['sha256'][:12]}…, "
                f"file {digest[:12]}…)"
            )
        try:
            _read_array(path, meta, name, mmap=True)
        except CorruptArtifactError as e:
            problems.append(str(e))

    if not problems and manifest["kind"] == "clustered_index":
        try:
            load_index(path, mmap=True)
        except ArtifactError as e:
            problems.append(str(e))
    if not problems and manifest["kind"] == "index_delta":
        # A delta is only as valid as its chain: materialize it, which
        # checks every parent link's fingerprint on the way.
        try:
            load_chain(path, mmap=True)
        except ArtifactError as e:
            problems.append(str(e))
    return problems
