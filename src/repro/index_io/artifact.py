"""Versioned on-disk artifacts for built indexes (DESIGN.md §8).

Layout — one directory per artifact:

    <path>/
      manifest.json            format version, build params, quantizer
                               state, fingerprint, per-array metadata
      arrays/<name>.npy        ClusteredIndex arrays (kind "clustered_index")
      shard_00000/<name>.npy   per-shard arrays      (kind "index_shards")

Every array is a plain ``.npy`` file so loading can be eager
(``np.load``) or memory-mapped (``mmap_mode="r"``) without any format
change. Impacts are persisted at the chosen ``impact_dtype``: ``"int32"``
verbatim, or ``"int8"`` as the biased code ``impact - IMPACT_BIAS`` (the
same convention the device upload path uses — ``core.range_daat
.pack_impacts``). Loading always widens impacts back to exact int32 on the
host, so ``ClusteredIndex.fingerprint()`` is stable across save/load at
either dtype and traversal over a loaded artifact is bitwise identical to
the in-memory build.

Writes are atomic at directory granularity: arrays and manifest land in a
``<path>.tmp`` staging directory that is renamed into place last, so a
crashed save never leaves a half-artifact where a loader finds it.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile

import numpy as np

from repro.core.clustered_index import ClusteredIndex, IndexShard
from repro.core.quantize import Quantizer
from repro.core.range_daat import IMPACT_BIAS, IMPACT_DTYPES, pack_impacts
from repro.core.reorder import Arrangement

__all__ = [
    "FORMAT",
    "FORMAT_VERSION",
    "ArtifactError",
    "CorruptArtifactError",
    "VersionMismatchError",
    "load_index",
    "load_shards",
    "read_manifest",
    "save_index",
    "save_shards",
    "validate_artifact",
]

FORMAT = "repro-index-artifact"
FORMAT_VERSION = 1

# ClusteredIndex fields persisted as arrays (arrangement flattened in).
INDEX_ARRAYS = (
    "ptr", "docs", "impacts",
    "blk_start", "blk_len", "blk_maxdoc", "blk_maximp", "blk_term", "blk_range",
    "tr_ptr", "tr_range", "tr_blk_start", "tr_blk_end", "tr_bound",
    "term_bound", "bounds_dense",
    "doc_order", "range_ends",
)

SHARD_ARRAYS = (
    "docs", "impacts", "blk_start", "blk_len", "blk_maxdoc", "blk_maximp",
    "blk_map", "range_starts", "range_sizes", "bounds_dense",
)

SHARD_SCALARS = ("shard_id", "range_lo", "range_hi", "doc_base", "n_docs", "postings")


class ArtifactError(Exception):
    """Base error for index artifact I/O."""


class CorruptArtifactError(ArtifactError):
    """Manifest unreadable, arrays missing, or metadata contradicts data."""


class VersionMismatchError(ArtifactError):
    """Artifact was written by an incompatible format version."""


# --------------------------------------------------------------------------
# Low-level helpers
# --------------------------------------------------------------------------


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _write_array(root: str, rel: str, arr: np.ndarray) -> dict:
    path = os.path.join(root, rel)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.save(path, np.ascontiguousarray(arr))
    return {
        "file": rel,
        "dtype": str(arr.dtype),
        "shape": list(arr.shape),
        "sha256": _sha256_file(path),
    }


def _read_array(root: str, meta: dict, name: str, mmap: bool) -> np.ndarray:
    path = os.path.join(root, meta["file"])
    if not os.path.exists(path):
        raise CorruptArtifactError(f"array {name!r}: missing file {meta['file']}")
    try:
        arr = np.load(path, mmap_mode="r" if mmap else None, allow_pickle=False)
    except (ValueError, OSError) as e:
        raise CorruptArtifactError(f"array {name!r}: unreadable ({e})") from e
    if str(arr.dtype) != meta["dtype"] or list(arr.shape) != list(meta["shape"]):
        raise CorruptArtifactError(
            f"array {name!r}: manifest says {meta['dtype']}{meta['shape']}, "
            f"file holds {arr.dtype}{list(arr.shape)}"
        )
    return arr


def _pack_disk_impacts(impacts: np.ndarray, impact_dtype: str, bits: int) -> np.ndarray:
    """Disk uses the same representation the device upload path does
    (``pack_impacts``); this wrapper only adds the bit-width eligibility
    check, so the two conventions cannot drift apart."""
    if impact_dtype == "int8" and bits > 8:
        raise ValueError(f"impact_dtype='int8' needs quantizer.bits <= 8, got {bits}")
    return pack_impacts(impacts, impact_dtype)


def _unpack_disk_impacts(arr: np.ndarray, manifest: dict) -> np.ndarray:
    if manifest["impact_dtype"] == "int8":
        bias = int(manifest.get("impact_bias", IMPACT_BIAS))
        return (np.asarray(arr, np.int64) + bias).astype(np.int32)
    return np.asarray(arr, np.int32)


def _staging_dir(path: str) -> str:
    """Unique per-save staging directory beside the target.

    Unique (not a fixed ``<path>.tmp``) so concurrent saves of the same
    artifact — e.g. two processes missing the same ``build_index_cached``
    key — cannot clobber each other's half-written staging area; whoever
    publishes last simply wins the final rename.
    """
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    return tempfile.mkdtemp(prefix=os.path.basename(path) + ".tmp-", dir=parent)


def _atomic_publish(tmp: str, path: str, overwrite: bool) -> None:
    """Rename the staging dir into place without a half-deleted window.

    Overwrite swaps in two renames — live artifact aside to a private
    name, staging dir in, old tree dropped last — so a concurrent reader
    observes the complete old artifact, a briefly-absent path (a cache
    *miss*, which rebuilds), or the complete new artifact; never a
    partially deleted directory. A lost publish race leaves the winner's
    equivalent artifact in place.
    """
    old = None
    if os.path.exists(path):
        if not overwrite:
            shutil.rmtree(tmp)
            raise ArtifactError(f"artifact already exists: {path} (overwrite=False)")
        old = tmp + ".old"  # unique: tmp is mkdtemp-fresh
        try:
            os.replace(path, old)
        except FileNotFoundError:
            old = None  # a concurrent publisher already swapped it away
    try:
        os.replace(tmp, path)
    except OSError:
        # Lost a publish race; the winner's artifact is equivalent.
        shutil.rmtree(tmp, ignore_errors=True)
        if not os.path.isdir(path):
            raise
    if old is not None:
        shutil.rmtree(old, ignore_errors=True)


def _write_manifest(root: str, manifest: dict) -> None:
    with open(os.path.join(root, "manifest.json"), "w", encoding="utf-8") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)


def read_manifest(path: str) -> dict:
    """Load and version-check an artifact manifest.

    Raises ``CorruptArtifactError`` for unreadable/foreign JSON and
    ``VersionMismatchError`` when the format version is not ours.
    """
    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        raise CorruptArtifactError(f"no manifest.json under {path}")
    try:
        with open(mpath, encoding="utf-8") as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CorruptArtifactError(f"manifest.json unparseable: {e}") from e
    if not isinstance(manifest, dict) or manifest.get("format") != FORMAT:
        raise CorruptArtifactError(
            f"{mpath} is not a {FORMAT} manifest "
            f"(format={manifest.get('format') if isinstance(manifest, dict) else None!r})"
        )
    version = manifest.get("format_version")
    if version != FORMAT_VERSION:
        raise VersionMismatchError(
            f"artifact format_version={version!r}, this reader supports "
            f"{FORMAT_VERSION} — rebuild the artifact or upgrade the reader"
        )
    return manifest


# --------------------------------------------------------------------------
# ClusteredIndex save / load
# --------------------------------------------------------------------------


def _index_array(index: ClusteredIndex, name: str) -> np.ndarray:
    if name == "doc_order":
        return index.arrangement.doc_order
    if name == "range_ends":
        return index.arrangement.range_ends
    return getattr(index, name)


def save_index(
    index: ClusteredIndex,
    path: str,
    impact_dtype: str = "int32",
    build_params: dict | None = None,
    overwrite: bool = False,
) -> str:
    """Persist a built index as a versioned artifact directory.

    ``impact_dtype="int8"`` stores postings impacts as biased int8 codes
    (4x smaller than int32); every other array keeps its native dtype.
    Returns ``path``.
    """
    tmp = _staging_dir(path)
    try:
        return _save_index_into(tmp, index, path, impact_dtype, build_params, overwrite)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)  # no orphaned staging dirs
        raise


def _save_index_into(
    tmp: str,
    index: ClusteredIndex,
    path: str,
    impact_dtype: str,
    build_params: dict | None,
    overwrite: bool,
) -> str:
    arrays = {}
    for name in INDEX_ARRAYS:
        arr = _index_array(index, name)
        if name == "impacts":
            arr = _pack_disk_impacts(arr, impact_dtype, index.quantizer.bits)
        arrays[name] = _write_array(tmp, os.path.join("arrays", f"{name}.npy"), arr)

    manifest = {
        "format": FORMAT,
        "format_version": FORMAT_VERSION,
        "kind": "clustered_index",
        "n_docs": int(index.n_docs),
        "n_terms": int(index.n_terms),
        "impact_dtype": impact_dtype,
        "quantizer": {
            "bits": int(index.quantizer.bits),
            "scale": float(index.quantizer.scale),
        },
        "arrangement": {
            "strategy": index.arrangement.strategy,
            "n_ranges": int(index.n_ranges),
        },
        "build_params": build_params or {},
        "fingerprint": index.fingerprint(),
        "arrays": arrays,
    }
    if impact_dtype == "int8":
        manifest["impact_bias"] = IMPACT_BIAS
    _write_manifest(tmp, manifest)
    _atomic_publish(tmp, path, overwrite)
    return path


def load_index(path: str, mmap: bool = False) -> ClusteredIndex:
    """Load a ``clustered_index`` artifact back into host memory.

    ``mmap=True`` memory-maps every array read-only instead of copying it —
    int8-stored impacts are the one exception, since they are widened back
    to exact int32 for the host structure (the device upload re-narrows via
    ``Engine(impact_dtype="int8")``).
    """
    manifest = read_manifest(path)
    if manifest.get("kind") != "clustered_index":
        raise CorruptArtifactError(
            f"expected kind 'clustered_index', got {manifest.get('kind')!r}"
        )
    metas = manifest.get("arrays", {})
    missing = [n for n in INDEX_ARRAYS if n not in metas]
    if missing:
        raise CorruptArtifactError(f"manifest lacks arrays: {missing}")
    a = {n: _read_array(path, metas[n], n, mmap) for n in INDEX_ARRAYS}
    a["impacts"] = _unpack_disk_impacts(a["impacts"], manifest)

    q = manifest["quantizer"]
    arrangement = Arrangement(
        doc_order=a["doc_order"],
        range_ends=a["range_ends"],
        strategy=manifest["arrangement"]["strategy"],
    )
    index = ClusteredIndex(
        n_docs=int(manifest["n_docs"]),
        n_terms=int(manifest["n_terms"]),
        arrangement=arrangement,
        quantizer=Quantizer(bits=int(q["bits"]), scale=float(q["scale"])),
        **{n: a[n] for n in INDEX_ARRAYS if n not in ("doc_order", "range_ends")},
    )
    if index.fingerprint() != manifest["fingerprint"]:
        raise CorruptArtifactError(
            f"fingerprint mismatch: manifest {manifest['fingerprint']}, "
            f"loaded arrays {index.fingerprint()}"
        )
    return index


# --------------------------------------------------------------------------
# IndexShard save / load
# --------------------------------------------------------------------------


def save_shards(
    shards: list[IndexShard],
    path: str,
    impact_dtype: str = "int32",
    quantizer: Quantizer | None = None,
    source_fingerprint: str | None = None,
    overwrite: bool = False,
) -> str:
    """Persist a shard set (``shard_device_index`` output) as one artifact.

    One subdirectory per shard; scalar shard metadata lives in the
    manifest. ``quantizer`` (the *global* scale shared by all shards) is
    **required** for int8 storage — the bit width decides whether biased
    int8 codes can represent every impact, and guessing would let >8-bit
    impacts wrap silently. ``source_fingerprint`` records the fingerprint
    of the index the shards were carved from, so loaders
    (``ShardedEngine.from_artifact``) can refuse a stale shard set.
    """
    if not shards:
        raise ValueError("cannot save an empty shard list")
    if impact_dtype == "int8" and quantizer is None:
        raise ValueError(
            "impact_dtype='int8' requires quantizer= (its bit width decides "
            "whether impacts fit a biased int8 code)"
        )
    bits = quantizer.bits if quantizer is not None else 32
    tmp = _staging_dir(path)
    try:
        return _save_shards_into(
            tmp, shards, path, impact_dtype, bits, quantizer,
            source_fingerprint, overwrite,
        )
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)  # no orphaned staging dirs
        raise


def _save_shards_into(
    tmp: str,
    shards: list[IndexShard],
    path: str,
    impact_dtype: str,
    bits: int,
    quantizer: Quantizer | None,
    source_fingerprint: str | None,
    overwrite: bool,
) -> str:
    shard_rows = []
    for shard in shards:
        sdir = f"shard_{shard.shard_id:05d}"
        arrays = {}
        for name in SHARD_ARRAYS:
            arr = getattr(shard, name)
            if name == "impacts":
                arr = _pack_disk_impacts(arr, impact_dtype, bits)
            arrays[name] = _write_array(tmp, os.path.join(sdir, f"{name}.npy"), arr)
        row = {s: int(getattr(shard, s)) for s in SHARD_SCALARS}
        row["arrays"] = arrays
        shard_rows.append(row)

    manifest = {
        "format": FORMAT,
        "format_version": FORMAT_VERSION,
        "kind": "index_shards",
        "n_shards": len(shards),
        "impact_dtype": impact_dtype,
        "shards": shard_rows,
    }
    # Contiguous layouts additionally record their range cuts, so reshard
    # tooling (repro.control, DESIGN.md §9) can read the layout without
    # loading any array. Non-contiguous shard sets (not produced by
    # shard_device_index) simply omit the key.
    lows = sorted((s.range_lo, s.range_hi) for s in shards)
    if lows[0][0] == 0 and all(
        a[1] == b[0] for a, b in zip(lows, lows[1:])
    ):
        manifest["range_cuts"] = [lo for lo, _ in lows] + [lows[-1][1]]
    if impact_dtype == "int8":
        manifest["impact_bias"] = IMPACT_BIAS
    if quantizer is not None:
        manifest["quantizer"] = {
            "bits": int(quantizer.bits),
            "scale": float(quantizer.scale),
        }
    if source_fingerprint is not None:
        manifest["source_fingerprint"] = source_fingerprint
    _write_manifest(tmp, manifest)
    _atomic_publish(tmp, path, overwrite)
    return path


def load_shards(path: str, mmap: bool = False) -> list[IndexShard]:
    """Load an ``index_shards`` artifact back into ``IndexShard`` objects."""
    manifest = read_manifest(path)
    if manifest.get("kind") != "index_shards":
        raise CorruptArtifactError(
            f"expected kind 'index_shards', got {manifest.get('kind')!r}"
        )
    rows = manifest.get("shards", [])
    if len(rows) != manifest.get("n_shards"):
        raise CorruptArtifactError(
            f"manifest n_shards={manifest.get('n_shards')} but "
            f"{len(rows)} shard entries"
        )
    shards = []
    for row in rows:
        metas = row["arrays"]
        missing = [n for n in SHARD_ARRAYS if n not in metas]
        if missing:
            raise CorruptArtifactError(
                f"shard {row.get('shard_id')}: manifest lacks arrays {missing}"
            )
        a = {
            n: _read_array(path, metas[n], f"shard/{n}", mmap)
            for n in SHARD_ARRAYS
        }
        a["impacts"] = _unpack_disk_impacts(a["impacts"], manifest)
        shards.append(
            IndexShard(**{s: int(row[s]) for s in SHARD_SCALARS}, **a)
        )
    return shards


# --------------------------------------------------------------------------
# Validation
# --------------------------------------------------------------------------


def _iter_array_metas(manifest: dict):
    if manifest["kind"] == "clustered_index":
        yield from manifest.get("arrays", {}).items()
    else:
        for row in manifest.get("shards", []):
            for name, meta in row.get("arrays", {}).items():
                yield f"shard_{row.get('shard_id')}/{name}", meta


def validate_artifact(path: str) -> list[str]:
    """Deep-check an artifact; returns a list of problems (empty = valid).

    Verifies the manifest parses at our format version, every array file
    exists with the advertised dtype/shape and sha256, and — for index
    artifacts — that the arrays rebuild to the manifest's fingerprint
    (``load_index`` enforces that too; here it lands in the report instead
    of raising).
    """
    problems: list[str] = []
    try:
        manifest = read_manifest(path)
    except ArtifactError as e:
        return [str(e)]

    for name, meta in _iter_array_metas(manifest):
        fpath = os.path.join(path, meta["file"])
        if not os.path.exists(fpath):
            problems.append(f"{name}: missing file {meta['file']}")
            continue
        digest = _sha256_file(fpath)
        if digest != meta["sha256"]:
            problems.append(
                f"{name}: sha256 mismatch (manifest {meta['sha256'][:12]}…, "
                f"file {digest[:12]}…)"
            )
        try:
            _read_array(path, meta, name, mmap=True)
        except CorruptArtifactError as e:
            problems.append(str(e))

    if not problems and manifest["kind"] == "clustered_index":
        try:
            load_index(path, mmap=True)
        except ArtifactError as e:
            problems.append(str(e))
    return problems
