"""``Corpus`` reader registry — real-collection ingestion (DESIGN.md §8).

Readers turn an external collection into the repo's bag-of-words
``data.synth.Corpus`` (CSR doc -> (term, tf)), after which the whole
pipeline — arrangement, quantization, index build, artifacts, serving — is
source-agnostic. Built-in readers run anywhere:

  * ``synth``  — the planted-topic generator (parameters as kwargs);
  * ``tsv``    — one document per line, ``doc_id<TAB>text``;
  * ``jsonl``  — one JSON object per line with ``"text"`` (tokenized) or
    pre-tokenized ``"terms"``/``"tfs"`` integer lists.

MS MARCO-scale sources are gated behind the optional ``repro[corpus]``
extra and fail with a clean ``MissingDependencyError`` when absent:

  * ``ciff``        — Common Index File Format postings
    (Lin et al., OSIRRC 2020), via ``ciff-toolkit``;
  * ``ir_datasets`` — any ``ir_datasets`` docs corpus by dataset id.

Tokenization for text readers is deliberately simple and deterministic
(lowercase alphanumeric runs, vocabulary in sorted token order): the paper
stems and stops off-line, and the traversal machinery only ever sees term
ids, so fancier analysis belongs upstream of the reader.
"""

from __future__ import annotations

import importlib.util
import json
import re
from typing import Callable, Iterable

import numpy as np

from repro.data.synth import Corpus, make_corpus

__all__ = [
    "MissingDependencyError",
    "available_readers",
    "corpus_from_token_docs",
    "get_reader",
    "read_corpus",
    "register_reader",
]

_TOKEN = re.compile(r"[a-z0-9]+")

_READERS: dict[str, Callable[..., Corpus]] = {}
_OPTIONAL_DEP: dict[str, str] = {}  # reader name -> module it needs


class MissingDependencyError(ImportError):
    """An ingestion reader needs an optional dependency that is absent."""


def register_reader(name: str, requires: str | None = None):
    """Decorator: register ``fn(source, **kw) -> Corpus`` under ``name``.

    ``requires`` names a module the reader imports lazily; ``get_reader``
    then raises ``MissingDependencyError`` up front when it is missing, so
    the full test/benchmark suite stays green without the optional extra.
    """

    def deco(fn: Callable[..., Corpus]) -> Callable[..., Corpus]:
        _READERS[name] = fn
        if requires:
            _OPTIONAL_DEP[name] = requires
        return fn

    return deco


def available_readers() -> dict[str, bool]:
    """Reader name -> whether it can run in this environment."""
    return {
        name: _OPTIONAL_DEP.get(name) is None
        or importlib.util.find_spec(_OPTIONAL_DEP[name]) is not None
        for name in sorted(_READERS)
    }


def get_reader(name: str) -> Callable[..., Corpus]:
    if name not in _READERS:
        raise KeyError(
            f"unknown corpus reader {name!r}; registered: {sorted(_READERS)}"
        )
    dep = _OPTIONAL_DEP.get(name)
    if dep is not None and importlib.util.find_spec(dep) is None:
        raise MissingDependencyError(
            f"corpus reader {name!r} needs the optional module {dep!r} — "
            f"install the extra: pip install repro[corpus]"
        )
    return _READERS[name]


def read_corpus(name: str, source: str = "", **kwargs) -> Corpus:
    """Convenience: ``get_reader(name)(source, **kwargs)``."""
    return get_reader(name)(source, **kwargs)


# --------------------------------------------------------------------------
# Corpus assembly
# --------------------------------------------------------------------------


def corpus_from_token_docs(token_docs: Iterable[list[str]]) -> Corpus:
    """Build a ``Corpus`` from per-document token lists.

    Vocabulary ids are assigned in sorted token order — deterministic for a
    given collection regardless of document order of first occurrence.
    """
    docs = [d for d in token_docs]
    vocab: dict[str, int] = {
        tok: i for i, tok in enumerate(sorted({t for d in docs for t in d}))
    }
    return _assemble(
        [np.asarray([vocab[t] for t in d], np.int64) for d in docs],
        n_terms=len(vocab),
    )


def corpus_from_term_docs(
    term_docs: list[np.ndarray], n_terms: int | None = None
) -> Corpus:
    """Build a ``Corpus`` from per-document integer term-id arrays."""
    return _assemble(
        [np.asarray(d, np.int64) for d in term_docs], n_terms=n_terms
    )


def _assemble(term_docs: list[np.ndarray], n_terms: int | None) -> Corpus:
    n_docs = len(term_docs)
    if n_terms is None:
        n_terms = int(max((int(d.max()) for d in term_docs if d.size), default=-1)) + 1
    doc_ptr = np.zeros(n_docs + 1, np.int64)
    terms_out: list[np.ndarray] = []
    tfs_out: list[np.ndarray] = []
    for i, d in enumerate(term_docs):
        if d.size and (d.min() < 0 or d.max() >= n_terms):
            raise ValueError(
                f"doc {i}: term ids outside [0, {n_terms}) — bad source data"
            )
        uniq, counts = np.unique(d, return_counts=True)
        terms_out.append(uniq.astype(np.int32))
        tfs_out.append(counts.astype(np.int32))
        doc_ptr[i + 1] = doc_ptr[i] + uniq.shape[0]
    return Corpus(
        n_docs=n_docs,
        n_terms=n_terms,
        doc_ptr=doc_ptr,
        doc_terms=(
            np.concatenate(terms_out) if terms_out else np.empty(0, np.int32)
        ),
        doc_tfs=np.concatenate(tfs_out) if tfs_out else np.empty(0, np.int32),
        doc_topic=np.zeros(n_docs, np.int32),
        n_topics=1,
    )


def tokenize(text: str) -> list[str]:
    return _TOKEN.findall(text.lower())


# --------------------------------------------------------------------------
# Built-in readers (no optional deps)
# --------------------------------------------------------------------------


@register_reader("synth")
def read_synth(source: str = "", **kwargs) -> Corpus:
    """The planted-topic generator; ``source`` is unused."""
    return make_corpus(**kwargs)


@register_reader("tsv")
def read_tsv(source: str, max_docs: int | None = None) -> Corpus:
    """``doc_id<TAB>text`` per line (the MS MARCO collection.tsv shape)."""
    docs: list[list[str]] = []
    with open(source, encoding="utf-8") as f:
        for ln, line in enumerate(f, 1):
            line = line.rstrip("\n")
            if not line:
                continue
            _, sep, text = line.partition("\t")
            if not sep:
                raise ValueError(
                    f"{source}:{ln}: no tab separator — expected "
                    f"'doc_id<TAB>text' per line"
                )
            docs.append(tokenize(text))
            if max_docs is not None and len(docs) >= max_docs:
                break
    return corpus_from_token_docs(docs)


@register_reader("jsonl")
def read_jsonl(source: str, max_docs: int | None = None) -> Corpus:
    """One JSON object per line: ``{"text": …}`` or ``{"terms": …, "tfs": …}``.

    The two shapes cannot be mixed within one file — pre-tokenized term ids
    and a text-derived vocabulary would not share an id space.
    """
    token_docs: list[list[str]] = []
    term_docs: list[np.ndarray] = []
    with open(source, encoding="utf-8") as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            if "terms" in obj:
                terms = np.asarray(obj["terms"], np.int64)
                tfs = np.asarray(obj.get("tfs", np.ones(terms.shape[0])), np.int64)
                if terms.shape != tfs.shape:
                    raise ValueError(f"{source}:{ln}: terms/tfs length mismatch")
                term_docs.append(np.repeat(terms, tfs))
            elif "text" in obj:
                token_docs.append(tokenize(obj["text"]))
            else:
                raise ValueError(f"{source}:{ln}: need 'text' or 'terms'")
            if max_docs is not None and len(token_docs) + len(term_docs) >= max_docs:
                break
    if token_docs and term_docs:
        raise ValueError(f"{source}: mixes 'text' and 'terms' documents")
    if term_docs:
        return corpus_from_term_docs(term_docs)
    return corpus_from_token_docs(token_docs)


# --------------------------------------------------------------------------
# Gated readers — optional `repro[corpus]` extra
# --------------------------------------------------------------------------


@register_reader("ciff", requires="ciff_toolkit")
def read_ciff(source: str, max_docs: int | None = None) -> Corpus:
    """Common Index File Format postings -> doc-major ``Corpus``.

    CIFF ships an inverted (term-major) index; this transposes it back to
    the CSR doc -> (term, tf) layout the arrangement/build pipeline wants.
    """
    from ciff_toolkit.read import CiffReader  # noqa: PLC0415 — gated import

    term_ids: list[np.ndarray] = []
    doc_ids: list[np.ndarray] = []
    tfs: list[np.ndarray] = []
    n_docs = 0
    n_terms = 0
    with CiffReader(source) as reader:
        header = reader.read_header()
        n_docs = int(header.num_docs)
        for tid, plist in enumerate(reader.read_postings_lists()):
            n_terms = tid + 1
            docid = 0
            d, t = [], []
            for posting in plist.postings:
                docid += posting.docid  # CIFF d-gaps
                if max_docs is not None and docid >= max_docs:
                    break
                d.append(docid)
                t.append(posting.tf)
            if d:
                doc_ids.append(np.asarray(d, np.int64))
                tfs.append(np.asarray(t, np.int64))
                term_ids.append(np.full(len(d), tid, np.int64))
    if max_docs is not None:
        n_docs = min(n_docs, max_docs)
    return _transpose_postings(
        np.concatenate(term_ids) if term_ids else np.empty(0, np.int64),
        np.concatenate(doc_ids) if doc_ids else np.empty(0, np.int64),
        np.concatenate(tfs) if tfs else np.empty(0, np.int64),
        n_docs=n_docs,
        n_terms=n_terms,
    )


@register_reader("ir_datasets", requires="ir_datasets")
def read_ir_datasets(source: str, max_docs: int | None = None) -> Corpus:
    """Any ``ir_datasets`` docs corpus by dataset id (e.g. msmarco-passage)."""
    import ir_datasets  # noqa: PLC0415 — gated import

    ds = ir_datasets.load(source)
    docs: list[list[str]] = []
    for doc in ds.docs_iter():
        docs.append(tokenize(getattr(doc, "text", "") or ""))
        if max_docs is not None and len(docs) >= max_docs:
            break
    return corpus_from_token_docs(docs)


def _transpose_postings(
    term_ids: np.ndarray,
    doc_ids: np.ndarray,
    tfs: np.ndarray,
    n_docs: int,
    n_terms: int,
) -> Corpus:
    """(term, doc, tf) triples -> CSR doc-major Corpus."""
    order = np.lexsort((term_ids, doc_ids))
    doc_ids, term_ids, tfs = doc_ids[order], term_ids[order], tfs[order]
    doc_ptr = np.zeros(n_docs + 1, np.int64)
    counts = np.bincount(doc_ids, minlength=n_docs)
    doc_ptr[1:] = np.cumsum(counts)
    return Corpus(
        n_docs=n_docs,
        n_terms=n_terms,
        doc_ptr=doc_ptr,
        doc_terms=term_ids.astype(np.int32),
        doc_tfs=tfs.astype(np.int32),
        doc_topic=np.zeros(n_docs, np.int32),
        n_topics=1,
    )
