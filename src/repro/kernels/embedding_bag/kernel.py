"""Pallas TPU kernel: fused weighted bag-reduce for EmbeddingBag.

The gather (table rows) stays in XLA — on TPU that's the native
dynamic-gather / SparseCore path. This kernel fuses the masking, per-sample
weighting, and the L-way reduction so the [B, L, D] gathered block is read
from HBM exactly once into VMEM tiles and reduced on the fly:

    out[b, d] = sum_l weights[b, l] * rows[b, l, d]

Grid tiles over (B, D); each step loads a [B_TILE, L, D_TILE] slab plus a
[B_TILE, L] weight tile and contracts over L on the MXU (batched [1, L] @
[L, D_TILE]). L (bag width: 20-200 for the assigned recsys archs) fits VMEM
comfortably at these tile sizes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_B_TILE = 128
DEFAULT_D_TILE = 128

__all__ = ["bag_reduce_pallas"]


def _bag_kernel(rows_ref, w_ref, out_ref):
    rows = rows_ref[...]  # [B_TILE, L, D_TILE]
    w = w_ref[...]  # [B_TILE, L]
    acc = jnp.einsum(
        "bld,bl->bd",
        rows.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("b_tile", "d_tile", "interpret"))
def bag_reduce_pallas(
    rows: jnp.ndarray,  # [B, L, D]
    weights: jnp.ndarray,  # [B, L]
    *,
    b_tile: int = DEFAULT_B_TILE,
    d_tile: int = DEFAULT_D_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    B, L, D = rows.shape
    b_tile = min(b_tile, B)
    d_tile = min(d_tile, D)
    bp = (B + b_tile - 1) // b_tile * b_tile
    dp = (D + d_tile - 1) // d_tile * d_tile
    if bp != B or dp != D:
        rows = jnp.pad(rows, ((0, bp - B), (0, 0), (0, dp - D)))
        weights = jnp.pad(weights, ((0, bp - B), (0, 0)))

    out = pl.pallas_call(
        _bag_kernel,
        grid=(bp // b_tile, dp // d_tile),
        in_specs=[
            pl.BlockSpec((b_tile, L, d_tile), lambda b, d: (b, 0, d)),
            pl.BlockSpec((b_tile, L), lambda b, d: (b, 0)),
        ],
        out_specs=pl.BlockSpec((b_tile, d_tile), lambda b, d: (b, d)),
        out_shape=jax.ShapeDtypeStruct((bp, dp), rows.dtype),
        interpret=interpret,
    )(rows, weights)
    return out[:B, :D]
