"""Jitted wrapper for the fused bag-reduce kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import bag_reduce_pallas
from repro.kernels.embedding_bag.ref import bag_reduce_ref

__all__ = ["bag_reduce"]


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def bag_reduce(
    rows: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    impl: str = "pallas",
    interpret: bool = True,
) -> jnp.ndarray:
    if impl == "xla":
        return bag_reduce_ref(rows, weights)
    return bag_reduce_pallas(rows, weights, interpret=interpret)
