"""Pure-jnp oracle for the fused bag-reduce: out[b] = sum_l w[b,l]*rows[b,l]."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["bag_reduce_ref"]


def bag_reduce_ref(rows: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """rows [B, L, D], weights [B, L] -> [B, D]."""
    return jnp.einsum("bld,bl->bd", rows, weights)
