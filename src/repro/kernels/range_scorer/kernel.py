"""Pallas TPU kernel: range-local impact accumulation via one-hot MXU matmul.

TPU adaptation of the paper's inner scoring loop (DESIGN.md §2). The CPU
algorithm scatter-adds each posting's impact into an accumulator; TPUs have
no efficient per-element scatter, but the accumulator *tile* for one
topically-coherent range fits in VMEM — that locality is exactly what the
paper's reordering buys (its §5.2 "fewer cache misses" observation, moved up
one level of the memory hierarchy: HBM→VMEM instead of DRAM→L2).

The scatter is recast as a matmul: for an accumulator tile ``acc[s0:s0+S_TILE]``
and a tile of P gathered postings ``(local_id, val)``,

    acc[s] += sum_p val[p] * [local_id[p] == s]

i.e. ``vals[1, P] @ onehot[P, S_TILE]`` — an MXU-shaped contraction with both
dims multiples of 128. Grid = (n_s_tiles, n_p_tiles), postings innermost so
each accumulator tile is revisited while resident in VMEM; the one-hot is
built on the fly from an iota compare (never materialized in HBM).

Validated in interpret mode against ref.score_blocks_ref (exact: integer
impacts sum < 2^24 so fp32 accumulation is lossless).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_S_TILE = 512
DEFAULT_P_TILE = 1024

__all__ = ["scatter_accumulate_pallas"]


def _scatter_kernel(ids_ref, vals_ref, acc_ref, *, s_tile: int, p_tile: int):
    s_idx = pl.program_id(0)
    p_idx = pl.program_id(1)

    @pl.when(p_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = ids_ref[...]  # [p_tile] int32 (-1 or OOB = dropped)
    vals = vals_ref[...].astype(jnp.float32)  # [p_tile]
    s_base = (s_idx * s_tile).astype(jnp.int32)
    local = ids - s_base  # in-tile coordinate
    # One-hot compare: [p_tile, s_tile]. Rows whose id is outside the tile
    # (including padding -1) are all-zero and contribute nothing.
    cols = jax.lax.broadcasted_iota(jnp.int32, (p_tile, s_tile), 1)
    onehot = (local[:, None] == cols).astype(jnp.float32)
    contrib = jnp.dot(
        vals[None, :], onehot, preferred_element_type=jnp.float32
    )  # [1, s_tile]
    acc_ref[...] += contrib[0]


@functools.partial(
    jax.jit, static_argnames=("s_pad", "s_tile", "p_tile", "interpret")
)
def scatter_accumulate_pallas(
    ids: jnp.ndarray,  # [P] int32 local docids, -1/OOB dropped
    vals: jnp.ndarray,  # [P] int32 impacts
    *,
    s_pad: int,
    s_tile: int = DEFAULT_S_TILE,
    p_tile: int = DEFAULT_P_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """acc[s] = sum of vals at ids==s, for s in [0, s_pad). Returns int32."""
    P = ids.shape[0]
    s_tile = min(s_tile, s_pad)
    p_tile = min(p_tile, P)
    # Pad to tile multiples (padding ids = -1 → dropped).
    sp = (s_pad + s_tile - 1) // s_tile * s_tile
    pp = (P + p_tile - 1) // p_tile * p_tile
    if pp != P:
        ids = jnp.concatenate([ids, jnp.full((pp - P,), -1, jnp.int32)])
        vals = jnp.concatenate([vals, jnp.zeros((pp - P,), vals.dtype)])

    grid = (sp // s_tile, pp // p_tile)
    acc = pl.pallas_call(
        functools.partial(_scatter_kernel, s_tile=s_tile, p_tile=p_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p_tile,), lambda s, p: (p,)),
            pl.BlockSpec((p_tile,), lambda s, p: (p,)),
        ],
        out_specs=pl.BlockSpec((s_tile,), lambda s, p: (s,)),
        out_shape=jax.ShapeDtypeStruct((sp,), jnp.float32),
        interpret=interpret,
    )(ids, vals)
    return acc[:s_pad].astype(jnp.int32)
