"""Pallas TPU kernel: range-local impact accumulation via one-hot MXU matmul.

TPU adaptation of the paper's inner scoring loop (DESIGN.md §2). The CPU
algorithm scatter-adds each posting's impact into an accumulator; TPUs have
no efficient per-element scatter, but the accumulator *tile* for one
topically-coherent range fits in VMEM — that locality is exactly what the
paper's reordering buys (its §5.2 "fewer cache misses" observation, moved up
one level of the memory hierarchy: HBM→VMEM instead of DRAM→L2).

The scatter is recast as a matmul: for an accumulator tile ``acc[s0:s0+S_TILE]``
and a tile of P gathered postings ``(local_id, val)``,

    acc[s] += sum_p val[p] * [local_id[p] == s]

i.e. ``vals[1, P] @ onehot[P, S_TILE]`` — an MXU-shaped contraction with both
dims multiples of 128. Grid = (n_s_tiles, n_p_tiles), postings innermost so
each accumulator tile is revisited while resident in VMEM; the one-hot is
built on the fly from an iota compare (never materialized in HBM).

Validated in interpret mode against ref.score_blocks_ref (exact: integer
impacts sum < 2^24 so fp32 accumulation is lossless).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.clustered_index import PACK_DIR_BITS

DEFAULT_S_TILE = 512
DEFAULT_P_TILE = 1024

BLOCK = 128  # postings per block; matches core.clustered_index.BLOCK
# A width-32 block needs BLOCK*32/32 = BLOCK words; every narrower block
# needs fewer. Fixed-size per-block slices of this many words keep the
# decode gather-free (dynamic start, static size — pl.ds).
WORDS_PER_BLOCK = BLOCK
DEFAULT_B_TILE = 8

__all__ = ["scatter_accumulate_pallas", "unpack_locals_pallas"]


def _scatter_kernel(ids_ref, vals_ref, acc_ref, *, s_tile: int, p_tile: int):
    s_idx = pl.program_id(0)
    p_idx = pl.program_id(1)

    @pl.when(p_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = ids_ref[...]  # [p_tile] int32 (-1 or OOB = dropped)
    vals = vals_ref[...].astype(jnp.float32)  # [p_tile]
    s_base = (s_idx * s_tile).astype(jnp.int32)
    local = ids - s_base  # in-tile coordinate
    # One-hot compare: [p_tile, s_tile]. Rows whose id is outside the tile
    # (including padding -1) are all-zero and contribute nothing.
    cols = jax.lax.broadcasted_iota(jnp.int32, (p_tile, s_tile), 1)
    onehot = (local[:, None] == cols).astype(jnp.float32)
    contrib = jnp.dot(
        vals[None, :], onehot, preferred_element_type=jnp.float32
    )  # [1, s_tile]
    acc_ref[...] += contrib[0]


@functools.partial(
    jax.jit, static_argnames=("s_pad", "s_tile", "p_tile", "interpret")
)
def scatter_accumulate_pallas(
    ids: jnp.ndarray,  # [P] int32 local docids, -1/OOB dropped
    vals: jnp.ndarray,  # [P] int32 impacts
    *,
    s_pad: int,
    s_tile: int = DEFAULT_S_TILE,
    p_tile: int = DEFAULT_P_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """acc[s] = sum of vals at ids==s, for s in [0, s_pad). Returns int32."""
    P = ids.shape[0]
    s_tile = min(s_tile, s_pad)
    p_tile = min(p_tile, P)
    # Pad to tile multiples (padding ids = -1 → dropped).
    sp = (s_pad + s_tile - 1) // s_tile * s_tile
    pp = (P + p_tile - 1) // p_tile * p_tile
    if pp != P:
        ids = jnp.concatenate([ids, jnp.full((pp - P,), -1, jnp.int32)])
        vals = jnp.concatenate([vals, jnp.zeros((pp - P,), vals.dtype)])

    grid = (sp // s_tile, pp // p_tile)
    acc = pl.pallas_call(
        functools.partial(_scatter_kernel, s_tile=s_tile, p_tile=p_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p_tile,), lambda s, p: (p,)),
            pl.BlockSpec((p_tile,), lambda s, p: (p,)),
        ],
        out_specs=pl.BlockSpec((s_tile,), lambda s, p: (s,)),
        out_shape=jax.ShapeDtypeStruct((sp,), jnp.float32),
        interpret=interpret,
    )(ids, vals)
    return acc[:s_pad].astype(jnp.int32)


def _unpack_kernel(
    words_ref, dir_ref, fd_ref, ln_ref, vb_ref, rs_ref, out_ref,
    *, b_tile: int,
):
    """Decode one tile of blocks: packed deltas -> range-local docids.

    Per block: split the merged directory entry into (word_start, width),
    take a fixed-size WORDS_PER_BLOCK slice of the word stream (dynamic
    start, static size — no gather), then a *static* repeat/shift decode
    per legal width selected by ``jnp.where``: for width w, lane j's word
    is slice[j*w // 32], which for the word-aligned ladder is slice[:16]
    repeated 8x (w=4), slice[:32] repeated 4x (w=8), slice[:64] repeated
    2x (w=16), or the slice itself (w=32), with shift (j*w) % 32. Deltas
    past the block length are zeroed before the 128-lane inclusive
    cumsum, exactly like the oracle.
    """
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, BLOCK), 1)

    def per_block(b, carry):
        entry = dir_ref[b]
        ws = entry & ((1 << PACK_DIR_BITS) - 1)
        code = entry >> PACK_DIR_BITS
        # PACK_WIDTHS = (0, 4, 8, 16, 32): code c > 0 maps to 2 << c, and a
        # table lookup would capture a constant array (illegal in Pallas).
        w = jnp.where(code == 0, 0, 2 << code)
        chunk = words_ref[pl.ds(ws, WORDS_PER_BLOCK)].reshape(1, BLOCK)
        c4 = jnp.repeat(chunk[:, : BLOCK // 8], 8, axis=1)
        c8 = jnp.repeat(chunk[:, : BLOCK // 4], 4, axis=1)
        c16 = jnp.repeat(chunk[:, : BLOCK // 2], 2, axis=1)
        d4 = (c4 >> ((lane % 8) * 4).astype(jnp.uint32)) & jnp.uint32(0xF)
        d8 = (c8 >> ((lane % 4) * 8).astype(jnp.uint32)) & jnp.uint32(0xFF)
        d16 = (c16 >> ((lane % 2) * 16).astype(jnp.uint32)) & jnp.uint32(0xFFFF)
        delta = jnp.where(
            w == 4,
            d4,
            jnp.where(
                w == 8,
                d8,
                jnp.where(
                    w == 16, d16, jnp.where(w == 32, chunk, jnp.uint32(0))
                ),
            ),
        )
        in_len = lane < ln_ref[b]
        delta = jnp.where(in_len, delta, jnp.uint32(0)).astype(jnp.int32)
        docs = fd_ref[b] + jnp.cumsum(delta, axis=1)
        loc = jnp.where(in_len & (vb_ref[b] != 0), docs - rs_ref[0], -1)
        out_ref[pl.ds(b, 1), :] = loc.astype(jnp.int32)
        return carry

    jax.lax.fori_loop(0, b_tile, per_block, 0)


@functools.partial(jax.jit, static_argnames=("b_tile", "interpret"))
def unpack_locals_pallas(
    pack_words: jnp.ndarray,  # [n_words] uint32 packed delta stream
    starts: jnp.ndarray,  # [B] block start offsets (-1 pad ok; validity only)
    lens: jnp.ndarray,  # [B] int32 block lengths
    pack_dir: jnp.ndarray,  # [B] int32 merged (word_start | width code)
    pack_firsts: jnp.ndarray,  # [B] absolute first docid per block
    keep: jnp.ndarray,  # [B] bool survives pruning
    range_start: jnp.ndarray,  # scalar int32
    *,
    b_tile: int = DEFAULT_B_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas decode of packed blocks to [B*BLOCK] local ids (-1 invalid).

    Matches ``ref.gather_block_postings_packed``'s id lanes bitwise. The
    word stream is padded by WORDS_PER_BLOCK zero words so the fixed-size
    per-block slice never overruns, and pruned / padding rows decode to
    all -1 via the validity lane (their directory entries are clamped to
    a zero entry — word 0 width 0 — which is always in range).
    """
    B = starts.shape[0]
    words = jnp.concatenate(
        [pack_words.astype(jnp.uint32), jnp.zeros((WORDS_PER_BLOCK,), jnp.uint32)]
    )
    vb = (keep & (starts >= 0)).astype(jnp.int32)
    de = jnp.maximum(pack_dir.astype(jnp.int32), 0)
    fd = pack_firsts.astype(jnp.int32)
    ln = lens.astype(jnp.int32)
    rs = jnp.reshape(range_start.astype(jnp.int32), (1,))
    b_tile = min(b_tile, B)
    bp = (B + b_tile - 1) // b_tile * b_tile
    if bp != B:
        pad = bp - B
        zeros = jnp.zeros((pad,), jnp.int32)
        de = jnp.concatenate([de, zeros])
        fd = jnp.concatenate([fd, zeros])
        ln = jnp.concatenate([ln, zeros])
        vb = jnp.concatenate([vb, zeros])

    n_words = words.shape[0]
    dir_spec = pl.BlockSpec((b_tile,), lambda i: (i,))
    out = pl.pallas_call(
        functools.partial(_unpack_kernel, b_tile=b_tile),
        grid=(bp // b_tile,),
        in_specs=[
            pl.BlockSpec((n_words,), lambda i: (0,)),
            dir_spec, dir_spec, dir_spec, dir_spec,
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b_tile, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, BLOCK), jnp.int32),
        interpret=interpret,
    )(words, de, fd, ln, vb, rs)
    return out[:B].reshape(B * BLOCK)
