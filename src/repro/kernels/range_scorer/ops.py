"""Jitted public wrapper for the range scorer.

``score_blocks`` is the one entry point the traversal engine calls; ``impl``
selects the XLA scatter path (fast on CPU, the oracle) or the Pallas one-hot
MXU kernel (the TPU target, validated in interpret mode). ``docs_format``
selects how block docids reach the scorer: ``"int32"`` gathers the raw docid
array, ``"packed"`` decodes per-block bit-packed deltas in place
(DESIGN.md §12) — the two are bitwise-identical by contract.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.range_scorer import ref
from repro.kernels.range_scorer.kernel import (
    scatter_accumulate_pallas,
    unpack_locals_pallas,
)
from repro.kernels.range_scorer.ref import IMPACT_BIAS  # noqa: F401 — re-export

DOCS_FORMATS = ("int32", "packed")

__all__ = ["DOCS_FORMATS", "IMPACT_BIAS", "score_blocks"]


@functools.partial(
    jax.jit, static_argnames=("s_pad", "impl", "interpret", "docs_format")
)
def score_blocks(
    post_docs: jnp.ndarray,
    post_imps: jnp.ndarray,
    starts: jnp.ndarray,
    lens: jnp.ndarray,
    keep: jnp.ndarray,
    range_start: jnp.ndarray,
    *,
    s_pad: int,
    impl: str = "xla",
    interpret: bool = True,
    docs_format: str = "int32",
    pack_words: jnp.ndarray | None = None,
    pack_dir: jnp.ndarray | None = None,
    pack_firsts: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Accumulate surviving posting blocks into an int32 [s_pad] accumulator.

    Under ``docs_format="packed"``, ``post_docs`` is ignored (pass any
    placeholder) and the per-block merged directory (``pack_dir``, see
    ``core.clustered_index.pack_dir_entries``) and first-docid column plus
    the shared ``pack_words`` stream are required; impacts stay
    offset-addressed via ``starts`` in both formats.
    """
    if docs_format not in DOCS_FORMATS:
        raise ValueError(f"docs_format {docs_format!r} not in {DOCS_FORMATS}")
    if docs_format == "packed" and (
        pack_words is None or pack_dir is None or pack_firsts is None
    ):
        raise ValueError("docs_format='packed' requires all pack_* arrays")
    if impl == "xla":
        if docs_format == "packed":
            return ref.score_blocks_packed_ref(
                pack_words, post_imps, starts, lens,
                pack_dir, pack_firsts, keep, range_start, s_pad,
            )
        return ref.score_blocks_ref(
            post_docs, post_imps, starts, lens, keep, range_start, s_pad
        )
    if impl == "pallas":
        if docs_format == "packed":
            local = unpack_locals_pallas(
                pack_words, starts, lens,
                pack_dir, pack_firsts, keep, range_start,
                interpret=interpret,
            )
            valid = ref._lane_valid(starts, lens, keep)
            v = ref.gather_block_impacts(post_imps, starts)
            vals = jnp.where(valid, v, 0).astype(jnp.int32).reshape(local.shape)
        else:
            local, vals = ref.gather_block_postings(
                post_docs, post_imps, starts, lens, keep, range_start
            )
        return scatter_accumulate_pallas(
            local, vals, s_pad=s_pad, interpret=interpret
        )
    raise ValueError(f"unknown impl {impl!r}")
