"""Jitted public wrapper for the range scorer.

``score_blocks`` is the one entry point the traversal engine calls; ``impl``
selects the XLA scatter path (fast on CPU, the oracle) or the Pallas one-hot
MXU kernel (the TPU target, validated in interpret mode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.range_scorer import ref
from repro.kernels.range_scorer.kernel import scatter_accumulate_pallas
from repro.kernels.range_scorer.ref import IMPACT_BIAS  # noqa: F401 — re-export

__all__ = ["IMPACT_BIAS", "score_blocks"]


@functools.partial(jax.jit, static_argnames=("s_pad", "impl", "interpret"))
def score_blocks(
    post_docs: jnp.ndarray,
    post_imps: jnp.ndarray,
    starts: jnp.ndarray,
    lens: jnp.ndarray,
    keep: jnp.ndarray,
    range_start: jnp.ndarray,
    *,
    s_pad: int,
    impl: str = "xla",
    interpret: bool = True,
) -> jnp.ndarray:
    """Accumulate surviving posting blocks into an int32 [s_pad] accumulator."""
    if impl == "xla":
        return ref.score_blocks_ref(
            post_docs, post_imps, starts, lens, keep, range_start, s_pad
        )
    if impl == "pallas":
        local, vals = ref.gather_block_postings(
            post_docs, post_imps, starts, lens, keep, range_start
        )
        return scatter_accumulate_pallas(
            local, vals, s_pad=s_pad, interpret=interpret
        )
    raise ValueError(f"unknown impl {impl!r}")
