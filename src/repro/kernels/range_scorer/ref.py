"""Pure-jnp oracle for the range scorer.

Scores one document range: gathers the postings of the query's surviving
blocks and scatter-adds quantized impacts into a range-local accumulator.
This is the semantic reference the Pallas kernel must match exactly
(integer impacts; float32 accumulation is exact below 2^24).
"""

from __future__ import annotations

import jax.numpy as jnp

BLOCK = 128  # postings per block; matches core.clustered_index.BLOCK

# Zero-point for native int8 impact storage (DESIGN.md §8): quantized
# impacts live in [1, 2^b - 1] ⊆ [1, 255] for b <= 8, which overflows
# signed int8, so the stored code is ``impact - IMPACT_BIAS`` ∈ [-127, 127]
# and the gather widens with ``+ IMPACT_BIAS`` back into exact int32.
IMPACT_BIAS = 128

__all__ = ["BLOCK", "IMPACT_BIAS", "gather_block_postings", "score_blocks_ref"]


def gather_block_postings(
    post_docs: jnp.ndarray,  # [nnz] int32 docids (new ids)
    post_imps: jnp.ndarray,  # [nnz] int32 impacts
    starts: jnp.ndarray,  # [B] int32/int64 block start offsets (-1 pad ok)
    lens: jnp.ndarray,  # [B] int32 block lengths
    keep: jnp.ndarray,  # [B] bool survives pruning
    range_start: jnp.ndarray,  # scalar int32 first new-docid of the range
):
    """Gather block postings into dense [B*BLOCK] (local_id, value) pairs.

    Invalid lanes get local_id = -1 and value = 0 so any downstream
    accumulator drops them.
    """
    B = starts.shape[0]
    lane = jnp.arange(BLOCK, dtype=jnp.int32)
    offs = starts.astype(jnp.int32)[:, None] + lane[None, :]  # [B, BLOCK]
    valid = (lane[None, :] < lens[:, None]) & keep[:, None] & (starts >= 0)[:, None]
    nnz = post_docs.shape[0]
    offs_c = jnp.clip(offs, 0, nnz - 1)
    d = post_docs[offs_c]
    v = post_imps[offs_c]
    if post_imps.dtype == jnp.int8:
        # Native int8 impact storage: codes are biased by IMPACT_BIAS so the
        # widen is the only place the true impact is reconstructed — postings
        # stay 1 B/posting in HBM (DESIGN.md §8).
        v = v.astype(jnp.int32) + IMPACT_BIAS
    local = jnp.where(valid, d - range_start, -1).astype(jnp.int32)
    vals = jnp.where(valid, v, 0).astype(jnp.int32)
    return local.reshape(B * BLOCK), vals.reshape(B * BLOCK)


def score_blocks_ref(
    post_docs: jnp.ndarray,
    post_imps: jnp.ndarray,
    starts: jnp.ndarray,
    lens: jnp.ndarray,
    keep: jnp.ndarray,
    range_start: jnp.ndarray,
    s_pad: int,
) -> jnp.ndarray:
    """Accumulate surviving blocks into an int32 accumulator of size s_pad."""
    local, vals = gather_block_postings(
        post_docs, post_imps, starts, lens, keep, range_start
    )
    # local == -1 -> clamp to s_pad and drop via mode="drop".
    tgt = jnp.where(local < 0, s_pad, local)
    acc = jnp.zeros((s_pad,), jnp.int32)
    return acc.at[tgt].add(vals, mode="drop")
