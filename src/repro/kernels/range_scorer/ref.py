"""Pure-jnp oracle for the range scorer.

Scores one document range: gathers the postings of the query's surviving
blocks and scatter-adds quantized impacts into a range-local accumulator.
This is the semantic reference the Pallas kernel must match exactly
(integer impacts; float32 accumulation is exact below 2^24).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.clustered_index import PACK_DIR_BITS, PACK_WIDTHS

BLOCK = 128  # postings per block; matches core.clustered_index.BLOCK

# Zero-point for native int8 impact storage (DESIGN.md §8): quantized
# impacts live in [1, 2^b - 1] ⊆ [1, 255] for b <= 8, which overflows
# signed int8, so the stored code is ``impact - IMPACT_BIAS`` ∈ [-127, 127]
# and the gather widens with ``+ IMPACT_BIAS`` back into exact int32.
IMPACT_BIAS = 128

__all__ = [
    "BLOCK",
    "IMPACT_BIAS",
    "gather_block_impacts",
    "gather_block_postings",
    "gather_block_postings_packed",
    "score_blocks_packed_ref",
    "score_blocks_ref",
    "unpack_dir",
]


def _lane_valid(
    starts: jnp.ndarray, lens: jnp.ndarray, keep: jnp.ndarray
) -> jnp.ndarray:
    """[B, BLOCK] lane validity shared by both docid formats."""
    lane = jnp.arange(BLOCK, dtype=jnp.int32)
    return (lane[None, :] < lens[:, None]) & keep[:, None] & (starts >= 0)[:, None]


def gather_block_impacts(
    post_imps: jnp.ndarray,  # [nnz] int32 or biased int8 impacts
    starts: jnp.ndarray,  # [B] block start offsets (-1 pad ok)
) -> jnp.ndarray:
    """Gather [B, BLOCK] widened impact values by posting offset.

    Impacts stay offset-addressed in every docs format — packed blocks
    replace only the docid stream, so ``blk_start`` still indexes impacts.
    """
    lane = jnp.arange(BLOCK, dtype=jnp.int32)
    offs = starts.astype(jnp.int32)[:, None] + lane[None, :]  # [B, BLOCK]
    nnz = post_imps.shape[0]
    v = post_imps[jnp.clip(offs, 0, nnz - 1)]
    if post_imps.dtype == jnp.int8:
        # Native int8 impact storage: codes are biased by IMPACT_BIAS so the
        # widen is the only place the true impact is reconstructed — postings
        # stay 1 B/posting in HBM (DESIGN.md §8).
        v = v.astype(jnp.int32) + IMPACT_BIAS
    return v


def gather_block_postings(
    post_docs: jnp.ndarray,  # [nnz] int32 docids (new ids)
    post_imps: jnp.ndarray,  # [nnz] int32 impacts
    starts: jnp.ndarray,  # [B] int32/int64 block start offsets (-1 pad ok)
    lens: jnp.ndarray,  # [B] int32 block lengths
    keep: jnp.ndarray,  # [B] bool survives pruning
    range_start: jnp.ndarray,  # scalar int32 first new-docid of the range
):
    """Gather block postings into dense [B*BLOCK] (local_id, value) pairs.

    Invalid lanes get local_id = -1 and value = 0 so any downstream
    accumulator drops them.
    """
    B = starts.shape[0]
    lane = jnp.arange(BLOCK, dtype=jnp.int32)
    offs = starts.astype(jnp.int32)[:, None] + lane[None, :]  # [B, BLOCK]
    valid = _lane_valid(starts, lens, keep)
    nnz = post_docs.shape[0]
    d = post_docs[jnp.clip(offs, 0, nnz - 1)]
    v = gather_block_impacts(post_imps, starts)
    local = jnp.where(valid, d - range_start, -1).astype(jnp.int32)
    vals = jnp.where(valid, v, 0).astype(jnp.int32)
    return local.reshape(B * BLOCK), vals.reshape(B * BLOCK)


def unpack_dir(pack_dir: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Split merged directory entries into (word_start, width) columns.

    Entries come from ``core.clustered_index.pack_dir_entries``: the word
    offset in the low ``PACK_DIR_BITS`` bits, the ``PACK_WIDTHS`` code
    above it. Entries are non-negative, so the arithmetic shift is exact.
    """
    entry = pack_dir.astype(jnp.int32)
    ws = entry & ((1 << PACK_DIR_BITS) - 1)
    w = jnp.asarray(PACK_WIDTHS, jnp.int32)[entry >> PACK_DIR_BITS]
    return ws, w


def gather_block_postings_packed(
    pack_words: jnp.ndarray,  # [n_words] uint32 packed delta stream
    post_imps: jnp.ndarray,  # [nnz] int32 or biased int8 impacts
    starts: jnp.ndarray,  # [B] block start offsets into impacts (-1 pad ok)
    lens: jnp.ndarray,  # [B] int32 block lengths
    pack_dir: jnp.ndarray,  # [B] int32 merged (word_start | width code)
    pack_firsts: jnp.ndarray,  # [B] int32 absolute first docid per block
    keep: jnp.ndarray,  # [B] bool survives pruning
    range_start: jnp.ndarray,  # scalar int32 first new-docid of the range
):
    """Packed-format twin of :func:`gather_block_postings` — the oracle.

    Lane ``j`` of a width-``w`` block reads bits ``[j*w, (j+1)*w)`` of its
    word run (``delta_0 = 0`` is stored, so the layout is uniform), masks
    out the delta, and an inclusive prefix sum from the out-of-band first
    docid rebuilds absolute ids. Deltas of lanes past ``lens`` are zeroed
    *before* the cumsum so tail garbage can never leak into valid lanes.
    Returns the identical (local_id, value) contract, bitwise.
    """
    B = starts.shape[0]
    lane = jnp.arange(BLOCK, dtype=jnp.int32)
    valid = _lane_valid(starts, lens, keep)
    pack_starts, widths = unpack_dir(pack_dir)
    w = widths[:, None]  # [B, 1]
    bit = lane[None, :] * w  # [B, BLOCK]
    widx = pack_starts[:, None] + bit // 32
    n_words = pack_words.shape[0]
    word = pack_words[jnp.clip(widx, 0, max(n_words - 1, 0))]
    # Width mask in uint32 without ever shifting by >= 32 (w == 32 takes the
    # all-ones branch; the other branch still evaluates, so clamp to 31).
    wu = jnp.minimum(w, 31).astype(jnp.uint32)
    mask = jnp.where(
        w >= 32,
        jnp.uint32(0xFFFFFFFF),
        (jnp.uint32(1) << wu) - jnp.uint32(1),
    )
    shift = (bit % 32).astype(jnp.uint32)  # < 32 for every legal width
    delta = (word >> shift) & mask
    in_len = lane[None, :] < lens[:, None]
    delta = jnp.where(in_len, delta, jnp.uint32(0)).astype(jnp.int32)
    d = pack_firsts.astype(jnp.int32)[:, None] + jnp.cumsum(delta, axis=1)
    v = gather_block_impacts(post_imps, starts)
    local = jnp.where(valid, d - range_start, -1).astype(jnp.int32)
    vals = jnp.where(valid, v, 0).astype(jnp.int32)
    return local.reshape(B * BLOCK), vals.reshape(B * BLOCK)


def score_blocks_ref(
    post_docs: jnp.ndarray,
    post_imps: jnp.ndarray,
    starts: jnp.ndarray,
    lens: jnp.ndarray,
    keep: jnp.ndarray,
    range_start: jnp.ndarray,
    s_pad: int,
) -> jnp.ndarray:
    """Accumulate surviving blocks into an int32 accumulator of size s_pad."""
    local, vals = gather_block_postings(
        post_docs, post_imps, starts, lens, keep, range_start
    )
    # local == -1 -> clamp to s_pad and drop via mode="drop".
    tgt = jnp.where(local < 0, s_pad, local)
    acc = jnp.zeros((s_pad,), jnp.int32)
    return acc.at[tgt].add(vals, mode="drop")


def score_blocks_packed_ref(
    pack_words: jnp.ndarray,
    post_imps: jnp.ndarray,
    starts: jnp.ndarray,
    lens: jnp.ndarray,
    pack_dir: jnp.ndarray,
    pack_firsts: jnp.ndarray,
    keep: jnp.ndarray,
    range_start: jnp.ndarray,
    s_pad: int,
) -> jnp.ndarray:
    """Packed-format twin of :func:`score_blocks_ref` (same accumulator)."""
    local, vals = gather_block_postings_packed(
        pack_words, post_imps, starts, lens,
        pack_dir, pack_firsts, keep, range_start,
    )
    tgt = jnp.where(local < 0, s_pad, local)
    acc = jnp.zeros((s_pad,), jnp.int32)
    return acc.at[tgt].add(vals, mode="drop")
