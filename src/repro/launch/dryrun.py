import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (512 placeholder devices locked in) ---

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod);
  2. resolves the arch's full published config, param/optimizer/batch
     PartitionSpecs (TP/EP via param_specs, ZeRO-1 via zero1_state_specs,
     batch over (pod, data));
  3. ``jax.jit(step).lower(...).compile()`` with ShapeDtypeStruct inputs —
     no allocation anywhere;
  4. records memory_analysis (fits-per-device proof), cost_analysis
     (FLOPs/bytes for §Roofline), and the collective-byte census parsed
     from the post-SPMD HLO (all-gather/all-reduce/reduce-scatter/
     all-to-all/collective-permute operand sizes).

Results go to benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json; the
roofline collator (launch/roofline.py) and EXPERIMENTS.md read from there.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all [--mesh both] [--force]
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCHS, all_cells, get_arch
from repro.launch.mesh import make_ctx, make_production_mesh
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.train.trainer import zero1_state_specs

RESULTS_DIR = os.path.join("benchmarks", "results", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b"
)


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_census(hlo_text: str) -> dict:
    """Per-collective result bytes from the post-SPMD HLO.

    The optimized HLO names operands without inline types, so we size each
    collective by its RESULT type (the text between '=' and the op name) —
    the per-device landed bytes. For all-reduce / all-to-all / permute this
    equals the per-device payload; for all-gather it is the gathered size
    (what crosses links into each device); ``-done`` halves of async pairs
    are skipped so ops are not double-counted.
    """
    out: dict[str, int] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "=" not in line:
            continue
        if "-done" in line or "get-tuple-element" in line:
            continue
        kind = m.group(1)
        rhs = line.split("=", 1)[1]
        op_pos = rhs.find(kind)
        if op_pos <= 0:
            continue
        result_txt = rhs[:op_pos]
        b = _shape_bytes(result_txt)
        if b == 0:
            continue
        out[kind] = out.get(kind, 0) + b
        count[kind] = count.get(kind, 0) + 1
    return {
        "bytes_by_kind": out,
        "count_by_kind": count,
        "total_bytes": int(sum(out.values())),
    }


def _mem_dict(ma) -> dict:
    keys = [
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
        "host_argument_size_in_bytes",
        "peak_memory_in_bytes",
    ]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _sds(tree, specs, mesh):
    """Attach shardings to a ShapeDtypeStruct pytree."""
    def one(x, s):
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=NamedSharding(mesh, s))

    return jax.tree.map(
        one, tree, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
    )


def run_cell(arch_name: str, shape: str, mesh_kind: str,
             variant: str = "baseline") -> dict:
    t0 = time.time()
    arch = get_arch(arch_name)
    mesh = make_production_mesh(multi_pod=mesh_kind == "multi")
    ctx = make_ctx(mesh)
    cfg = arch.model_config(reduced=False)
    if arch.family == "gnn":
        cfg = arch._resolved(cfg, shape)
    if hasattr(arch, "_with_variant"):
        cfg = arch._with_variant(cfg, variant)

    params_sh = arch.param_shapes(cfg)
    if variant != "baseline":
        if variant not in getattr(arch, "variants", ("baseline",)):
            raise ValueError(f"{arch_name} does not support variant {variant}")
        p_specs = arch.param_pspecs(cfg, params_sh, variant=variant, ctx=ctx)
    else:
        p_specs = arch.param_pspecs(cfg, params_sh)
    if getattr(arch, "fsdp", False) and variant == "baseline":
        # FSDP: shard every large param over the data axis on its first
        # free divisible dim (on top of the TP spec). Small leaves stay
        # replicated to avoid pathological tiny collectives.
        from repro.train.trainer import zero1_spec

        mesh_shape = dict(mesh.shape)

        def _fsdp(spec, p):
            if int(np.prod(p.shape)) < 65536:
                return spec
            return zero1_spec(spec, p.shape, ctx.n_data, ctx.data_axes, mesh_shape)

        p_specs = jax.tree.map(
            _fsdp, p_specs, params_sh, is_leaf=lambda x: isinstance(x, P)
        )
    if variant != "baseline":
        step, kind = arch.build_step(cfg, shape, shard_ctx=ctx, variant=variant)
        try:
            batch_sh = arch.input_specs(cfg, shape, variant=variant)
        except TypeError:
            batch_sh = arch.input_specs(cfg, shape)
        b_specs = arch.batch_pspecs(cfg, shape, ctx, variant=variant)
    else:
        step, kind = arch.build_step(cfg, shape, shard_ctx=ctx)
        batch_sh = arch.input_specs(cfg, shape)
        b_specs = arch.batch_pspecs(cfg, shape, ctx)

    params_in = _sds(params_sh, p_specs, mesh)
    batch_in = _sds(batch_sh, b_specs, mesh)

    if kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=arch.moment_dtype(cfg))
        opt_sh = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params_sh)
        o_specs = zero1_state_specs(
            p_specs, params_sh, opt_sh, ctx.n_data, ctx.data_axes,
            mesh_shape=dict(mesh.shape),
        )
        opt_in = _sds(opt_sh, o_specs, mesh)
        jitted = jax.jit(step, donate_argnums=(0, 1))
        lowered = jitted.lower(params_in, opt_in, batch_in)
    else:
        jitted = jax.jit(step, donate_argnums=(1,) if "cache" in batch_sh else ())
        lowered = jitted.lower(params_in, batch_in)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    mem = _mem_dict(compiled.memory_analysis())
    hlo = compiled.as_text()
    coll = collective_census(hlo)

    n_dev = mesh.devices.size
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))

    result = {
        "arch": arch_name,
        "shape": shape,
        "mesh": mesh_kind,
        "variant": variant,
        "kind": kind,
        "n_devices": int(n_dev),
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "per_device_flops": flops,
        "per_device_bytes_accessed": bytes_acc,
        "collectives": coll,
        "model_flops_per_token": arch.model_flops_per_token(cfg),
        "hlo_bytes": len(hlo),
    }
    # Sanity proof requested by the contract: print on stdout.
    print(f"[{arch_name}/{shape}/{mesh_kind}] memory_analysis:")
    for k, v in mem.items():
        print(f"  {k}: {v/2**30:.3f} GiB")
    print(f"[{arch_name}/{shape}/{mesh_kind}] cost_analysis: flops={flops:.3e} "
          f"bytes={bytes_acc:.3e} collective_bytes={coll['total_bytes']:.3e}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default=RESULTS_DIR)
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a, s, _ in all_cells()]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        cells = [(args.arch, args.shape)]

    failures = []
    suffix = "" if args.variant == "baseline" else f"__v-{args.variant}"
    for arch_name, shape in cells:
        for mesh_kind in meshes:
            out_path = os.path.join(
                args.out_dir, f"{arch_name}__{shape}__{mesh_kind}{suffix}.json"
            )
            if os.path.exists(out_path) and not args.force:
                print(f"skip (cached): {out_path}")
                continue
            try:
                res = run_cell(arch_name, shape, mesh_kind, variant=args.variant)
            except Exception as e:  # noqa: BLE001 — record and continue
                traceback.print_exc()
                res = {
                    "arch": arch_name, "shape": shape, "mesh": mesh_kind,
                    "variant": args.variant,
                    "ok": False, "error": f"{type(e).__name__}: {e}",
                }
                failures.append((arch_name, shape, mesh_kind))
            with open(out_path + ".tmp", "w") as f:
                json.dump(res, f, indent=1)
            os.replace(out_path + ".tmp", out_path)
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("all requested cells compiled OK")


if __name__ == "__main__":
    main()
