"""Production mesh construction (multi-pod dry-run contract).

A FUNCTION, not a module constant — importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax import; tests
and benches run single-device).
"""

from __future__ import annotations

import jax

from repro.distributed.sharding import ShardCtx

__all__ = ["make_production_mesh", "make_ctx"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_ctx(mesh) -> ShardCtx:
    data_axes = (
        ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    )
    return ShardCtx(mesh=mesh, data_axes=data_axes, model_axis="model")
