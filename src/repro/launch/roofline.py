"""Roofline collation: three terms per (arch x shape x mesh) cell.

Reads the dry-run JSONs (launch/dryrun.py) and derives, per cell:

    compute term    = FLOPs        / (chips * 197e12  bf16 FLOP/s)
    memory term     = HLO bytes    / (chips * 819e9   B/s HBM)
    collective term = coll. bytes  / (         50e9   B/s per-link ICI)
                      (collective bytes are per-device landed bytes, so the
                       per-chip link bandwidth is the right denominator)

FLOPs sources — both are reported:
  * analytic MODEL_FLOPS (6*N_active*D for LM training, per-shape formulas
    below for serving/GNN/recsys cells) — the primary compute term;
  * XLA cost_analysis FLOPs — secondary: the CPU backend counts each
    lax.scan/while body ONCE (trip counts are opaque to it), so it
    undercounts layered/iterative programs by ~the trip count. The ratio
    MODEL_FLOPS / HLO_FLOPS is still reported per the contract, with this
    caveat recorded.

Output: benchmarks/results/roofline.json + a markdown table for
EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 per chip (TPU v5e)
HBM_BW = 819e9  # B/s per chip
LINK_BW = 50e9  # B/s per ICI link

RESULTS_DIR = os.path.join("benchmarks", "results", "dryrun")
OUT = os.path.join("benchmarks", "results", "roofline.json")

# ---------------------------------------------------------------- analytic


def _lm_flops(arch_cfg, shape: str, kind: str, n_dev: int) -> float:
    """Whole-step MODEL_FLOPS (global), then divided by devices by caller."""
    from repro.configs.registry import get_arch

    arch = get_arch(arch_cfg)
    cfg = arch.model_config()
    fpt = arch.model_flops_per_token(cfg)  # 6*N_active (train)
    dims = {
        "train_4k": (256, 4096), "prefill_32k": (32, 32768),
        "decode_32k": (128, 1), "long_500k": (1, 1),
    }[shape]
    tokens = dims[0] * dims[1]
    if kind == "train":
        return fpt * tokens  # 6*N*D includes fwd+bwd
    # Serving: forward only = 2*N_active per token (+ attention reads).
    return fpt / 3.0 * tokens


def _gnn_flops(shape: str) -> float:
    dims = {
        "full_graph_sm": (10752, 1433, 7),
        "ogb_products": (61860352, 100, 47),
        "minibatch_lg": (15360 + 163840, 602, 41),
        "molecule": (128 * 64, 64, 32),
    }[shape]
    e, d, c = dims
    d_h = 128
    # 2 layers: per-edge gather+add (~2*d per edge) + per-node matmuls;
    # dominate: layer matmuls 2*(d*d_h + d_h*c) per node, edges: copies.
    # Rough per-edge 2*d flops * 2 layers + node matmul terms folded in:
    train_mult = 3.0  # fwd + bwd
    return train_mult * (2 * e * (d + d_h) + 2 * e * (d_h + c))


def _rec_flops(arch: str, shape: str) -> float:
    B = {"train_batch": 65536, "serve_p99": 512, "serve_bulk": 262144,
         "retrieval_cand": 1_000_000}[shape]
    per = {
        # rough per-example forward flops
        "bst": 2 * (21 * 32 * 32 * 4 + 21 * 21 * 32 * 2 + 1024 * 832 + 1024 * 512 + 512 * 256),
        "mind": 2 * (50 * 64 * 64 * 3 + 4 * 64 * 50 * 3),
        "autoint": 2 * 3 * (39 * 16 * 64 * 3 + 39 * 39 * 64 * 2),
        "bert4rec": 2 * 2 * (200 * 64 * 64 * 4 + 200 * 200 * 64 * 2 + 200 * 64 * 256),
    }[arch]
    mult = 3.0 if shape == "train_batch" else 1.0
    return mult * per * B


def model_flops_for(arch: str, shape: str, kind: str, n_dev: int) -> float:
    try:
        if arch in ("qwen3-4b", "qwen2.5-3b", "deepseek-67b",
                    "deepseek-v3-671b", "moonshot-v1-16b-a3b"):
            return _lm_flops(arch, shape, kind, n_dev)
        if arch == "graphsage-reddit":
            return _gnn_flops(shape)
        if arch in ("bst", "mind", "autoint", "bert4rec"):
            return _rec_flops(arch, shape)
        if arch == "anytime-ir":
            # 256 queries x budgeted postings x ~2 flops/posting (+ top-k).
            return 256.0 * 4e6 * 2
    except Exception:  # noqa: BLE001
        return 0.0
    return 0.0


def collate(results_dir: str = RESULTS_DIR):
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        d = json.load(open(path))
        if not d.get("ok"):
            continue
        n = d["n_devices"]
        model_flops = model_flops_for(d["arch"], d["shape"], d["kind"], n)
        hlo_flops_total = d["per_device_flops"] * n
        compute_term = model_flops / (n * PEAK_FLOPS)
        compute_term_hlo = d["per_device_flops"] / PEAK_FLOPS
        memory_term = d["per_device_bytes_accessed"] / HBM_BW
        coll_term = d["collectives"]["total_bytes"] / LINK_BW
        terms = {
            "compute_s": compute_term,
            "memory_s": memory_term,
            "collective_s": coll_term,
        }
        dominant = max(terms, key=terms.get)
        bound_time = max(terms.values())
        rows.append(
            {
                "arch": d["arch"],
                "shape": d["shape"],
                "mesh": d["mesh"],
                "variant": d.get("variant", "baseline"),
                "kind": d["kind"],
                "n_devices": n,
                "model_flops": model_flops,
                "hlo_flops_total": hlo_flops_total,
                "useful_ratio": (
                    model_flops / hlo_flops_total if hlo_flops_total else None
                ),
                **{k: round(v, 6) for k, v in terms.items()},
                "compute_s_hlo": round(compute_term_hlo, 6),
                "dominant": dominant.replace("_s", ""),
                "roofline_fraction": (
                    round(compute_term / bound_time, 4) if bound_time else None
                ),
                "peak_gib_per_dev": round(
                    d["memory"].get("peak_memory_in_bytes", 0) / 2**30, 2
                ),
                "collective_breakdown": d["collectives"]["bytes_by_kind"],
            }
        )
    return rows


def to_markdown(rows, mesh: str = "single") -> str:
    lines = [
        "| arch | shape | dom. bottleneck | compute_s | memory_s | collective_s "
        "| roofline frac | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        tag = "" if r["variant"] == "baseline" else f" [{r['variant']}]"
        lines.append(
            f"| {r['arch']}{tag} | {r['shape']} | {r['dominant']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['roofline_fraction']} "
            f"| {r['peak_gib_per_dev']} |"
        )
    return "\n".join(lines)


def main():
    rows = collate()
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    tmp = OUT + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rows, f, indent=1)
    os.replace(tmp, OUT)
    print(to_markdown(rows, "single"))
    print(f"\n{len(rows)} cells collated -> {OUT}")


if __name__ == "__main__":
    main()
