"""Serving launcher: anytime IR under an SLA.

``python -m repro.launch.serve [--sla-ms B] [--policy reactive] [--queries N]``

Builds (or loads from .cache) a clustered index over the benchmark corpus
and serves a query stream under the chosen §6 termination policy,
reporting percentile latencies, SLA compliance, and RBO. This is the
single-node engine; the sharded multi-node form is exercised by the
anytime-ir dry-run cells and tests/test_distributed_ir.py.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks import common
from repro.core.anytime import (
    Fixed, Overshoot, Predictive, Reactive, Undershoot, run_query_anytime,
)
from repro.core.metrics import rbo
from repro.core.oracle import exhaustive_topk
from repro.core.range_daat import Engine

POLICIES = {
    "none": lambda a: None,
    "fixed": lambda a: Fixed(10),
    "overshoot": lambda a: Overshoot(),
    "undershoot": lambda a: Undershoot(2.0),
    "predictive": lambda a: Predictive(a),
    "reactive": lambda a: Reactive(alpha=a, beta=1.2, q=0.01),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sla-ms", type=float, default=None)
    ap.add_argument("--policy", default="reactive", choices=sorted(POLICIES))
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args(argv)

    corpus = common.bench_corpus()
    log = common.bench_queries(corpus, n=args.queries, seed=42)
    index = common.bench_index(corpus, "clustered_bp")
    engine = Engine(index, k=args.k)
    queries = [log.terms[i] for i in range(log.n_queries)]
    common.warmup_engine(engine, queries)

    base, oracle = [], {}
    for i, q in enumerate(queries[: min(64, len(queries))]):
        res = run_query_anytime(engine, engine.plan(q), policy=None)
        base.append(res.elapsed_ms)
        oracle[i] = exhaustive_topk(index, q, args.k)[0].tolist()
    budget = args.sla_ms or float(np.percentile(base, 99)) * 0.25
    print(f"policy={args.policy} SLA: P99 <= {budget:.2f} ms")

    policy = POLICIES[args.policy](args.alpha)
    times, vals = [], []
    t0 = time.perf_counter()
    for i, q in enumerate(queries):
        res = run_query_anytime(engine, engine.plan(q), policy=policy,
                                budget_ms=budget)
        times.append(res.elapsed_ms)
        if i in oracle:
            vals.append(rbo(res.doc_ids.tolist(), oracle[i], phi=0.8))
    wall = time.perf_counter() - t0
    t = np.asarray(times)
    print(f"{len(queries)} queries in {wall:.1f}s ({len(queries)/wall:.1f} q/s)")
    print(f"P50/P95/P99: {np.percentile(t,50):.2f} / {np.percentile(t,95):.2f} "
          f"/ {np.percentile(t,99):.2f} ms | miss {(t>budget).mean()*100:.2f}% "
          f"| RBO {np.mean(vals):.4f} | SLA "
          f"{'MET' if np.percentile(t,99) <= budget else 'MISSED'}")


if __name__ == "__main__":
    main()
