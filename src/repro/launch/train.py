"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the full substrate — config registry, AdamW (+ moment compression),
cosine schedule, async atomic checkpointing, preemption handling,
deterministic resume — on the reduced config by default (this container is
one CPU). On a real cluster the same entry point runs the full config under
the production mesh: pass --full and launch one process per host with
jax.distributed (the step/sharding code is identical to the dry-run's).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import ARCHS, get_arch
from repro.train.checkpoint import Checkpointer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, help="train shape (gnn/recsys)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--moments", default="fp32", choices=["fp32", "bf16", "int8"])
    ap.add_argument("--full", action="store_true",
                    help="full published config (needs a real cluster)")
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = arch.model_config(reduced=not args.full)
    shape = args.shape or {
        "lm": "train_4k", "gnn": "full_graph_sm", "recsys": "train_batch",
    }.get(arch.family)
    if shape is None:
        ap.error(f"{args.arch} has no train shape")
    if arch.family == "gnn":
        cfg = arch._resolved(cfg, shape)

    params = arch.init_params(jax.random.key(0), cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={args.arch} shape={shape} params={n/1e6:.2f}M "
          f"({'full' if args.full else 'reduced'})")

    step, kind = arch.build_step(cfg, shape)
    assert kind == "train", f"{shape} is not a train shape"

    def data_fn(s):  # deterministic in the step counter -> exact resume
        return arch.make_batch(cfg, shape, seed=s)

    ckpt = Checkpointer(args.ckpt_dir, keep_last=3) if args.ckpt_dir else None

    # Drive the arch step directly (it already includes the optimizer).
    from repro.optim.adamw import AdamWConfig, init_opt_state

    opt_state = init_opt_state(params, AdamWConfig(moment_dtype=args.moments))
    jstep = jax.jit(step, donate_argnums=(0, 1))
    start = 0
    if ckpt:
        restored = ckpt.restore_latest()
        if restored:
            params, opt_state = restored["params"], restored["opt_state"]
            start = int(jax.device_get(opt_state["step"]))
            print(f"resumed from step {start}")
    import time
    for s in range(start, args.steps):
        t0 = time.perf_counter()
        params, opt_state, metrics = jstep(params, opt_state, data_fn(s))
        if s % 20 == 0 or s == args.steps - 1:
            # Fetch only on log steps: a per-step device_get would stall
            # the async dispatch pipeline 20x more often than needed.
            loss = float(jax.device_get(metrics["loss"]))  # analysis: allow[HOSTSYNC]
            print(f"step {s:5d}  loss {loss:.4f}  "
                  f"{(time.perf_counter()-t0)*1e3:.0f} ms")
        if ckpt and (s + 1) % args.ckpt_every == 0:
            ckpt.save({"params": params, "opt_state": opt_state}, step=s + 1)
    if ckpt:
        ckpt.save({"params": params, "opt_state": opt_state},
                  step=args.steps, blocking=True)
    print("done")


if __name__ == "__main__":
    main()
