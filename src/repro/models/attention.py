"""Attention variants for the assigned LM architectures.

  * GQA with optional per-head QK RMSNorm (qwen3) and QKV bias (qwen2.5).
    Grouped einsums keep KV unreplicated — queries are reshaped to
    [B, S, KV, G, D] instead of repeating the KV heads G times.
  * MLA (DeepSeek-V2/V3): low-rank compressed KV — the decode cache stores
    only (c_kv, k_rope) per token, which is what makes deepseek-v3-671b
    decode_32k feasible (DESIGN.md §5).

Full-sequence paths (training / prefill) are *query-chunked*: scores never
materialize beyond [B, KV, G, chunk, T]. All score einsums run on bf16
operands with fp32 accumulation (preferred_element_type), the MXU-native
pattern; softmax in fp32.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import axis_size, shard_map

from repro.models.layers import apply_rope, dense_init, rms_norm, rms_norm_init

__all__ = ["GQAConfig", "MLAConfig", "init_gqa", "gqa", "init_mla", "mla"]

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class GQAConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv_heads


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0


def _grouped_attention(q5, k, v, *, q_positions, kv_valid_len, chunk: int):
    """Causal grouped attention.

    q5: [B, S, KV, G, Dk]; k: [B, T, KV, Dk]; v: [B, T, KV, Dv].
    q_positions: [S] absolute position of each query row.
    kv_valid_len: scalar — keys at index >= this are masked (cache tail);
                  causality additionally masks keys beyond each query's pos.
    Returns [B, S, KV, G, Dv].
    """
    B, S, KV, G, Dk = q5.shape
    T = k.shape[1]
    scale = 1.0 / np.sqrt(Dk)
    if S % chunk != 0:
        chunk = S
    nc = S // chunk
    kpos = jnp.arange(T)

    def one_chunk(qc, pc):
        # qc: [B, C, KV, G, Dk]; pc: [C] positions
        s = jnp.einsum(
            "bckgd,btkd->bkgct", qc, k, preferred_element_type=jnp.float32
        ) * scale
        mask = (kpos[None, :] <= pc[:, None]) & (kpos[None, :] < kv_valid_len)
        s = jnp.where(mask[None, None, None], s, _NEG)
        w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgct,btkd->bckgd", w, v)

    if nc == 1:
        return one_chunk(q5, q_positions)
    qr = q5.reshape(B, nc, chunk, KV, G, Dk).transpose(1, 0, 2, 3, 4, 5)
    pr = q_positions.reshape(nc, chunk)
    outs = jax.lax.map(lambda a: one_chunk(a[0], a[1]), (qr, pr))
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, KV, G, -1)


# --------------------------------------------------------------------- GQA


def init_gqa(key, cfg: GQAConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.head_dim, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * cfg.head_dim, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * cfg.head_dim, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.head_dim, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * cfg.head_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * cfg.head_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * cfg.head_dim,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rms_norm_init(cfg.head_dim, dtype)
        p["k_norm"] = rms_norm_init(cfg.head_dim, dtype)
    return p


def gqa(
    params,
    x: jnp.ndarray,  # [B, S, d]
    rope_table: jnp.ndarray,
    cfg: GQAConfig,
    *,
    positions: jnp.ndarray,  # [S] absolute positions of x's rows
    cache: dict | None = None,  # {"k": [B, Smax, KV, D], "v": ...}
    cache_pos: jnp.ndarray | None = None,  # scalar: tokens already cached
    chunk: int = 512,
):
    """Returns (out [B, S, d], new_cache)."""
    B, S, _ = x.shape
    q = jnp.dot(x, params["wq"])
    k = jnp.dot(x, params["wk"])
    v = jnp.dot(x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = apply_rope(q, rope_table, positions)
    k = apply_rope(k, rope_table, positions)
    q5 = q.reshape(B, S, cfg.n_kv_heads, cfg.group, cfg.head_dim)

    if cache is not None:
        k_all = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0)
        )
        v_all = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0)
        )
        new_cache = {"k": k_all, "v": v_all}
        valid = cache_pos + S
    else:
        k_all, v_all, new_cache = k, v, None
        valid = S

    out = _grouped_attention(
        q5, k_all, v_all, q_positions=positions, kv_valid_len=valid, chunk=chunk
    )
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return jnp.dot(out, params["wo"]), new_cache


# --------------------------------------------------------------------- MLA


def init_mla(key, cfg: MLAConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "w_dq": dense_init(ks[0], cfg.d_model, cfg.q_lora_rank, dtype),
        "q_norm": rms_norm_init(cfg.q_lora_rank, dtype),
        "w_uq": dense_init(ks[1], cfg.q_lora_rank, cfg.n_heads * qk_head, dtype),
        "w_dkv": dense_init(
            ks[2], cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype
        ),
        "kv_norm": rms_norm_init(cfg.kv_lora_rank, dtype),
        "w_uk": dense_init(
            ks[3], cfg.kv_lora_rank, cfg.n_heads * cfg.qk_nope_head_dim, dtype
        ),
        "w_uv": dense_init(
            ks[4], cfg.kv_lora_rank, cfg.n_heads * cfg.v_head_dim, dtype
        ),
        "wo": dense_init(ks[5], cfg.n_heads * cfg.v_head_dim, cfg.d_model, dtype),
    }


def mla(
    params,
    x: jnp.ndarray,
    rope_table: jnp.ndarray,
    cfg: MLAConfig,
    *,
    positions: jnp.ndarray,
    cache: dict | None = None,  # {"c_kv": [B, Smax, R], "k_rope": [B, Smax, rd]}
    cache_pos: jnp.ndarray | None = None,
    chunk: int = 512,
):
    """MLA attention. Cache stores the compressed (c_kv, k_rope) only.

    Baseline path expands the compressed cache to per-head K/V each call;
    the absorbed-matmul decode optimization is a §Perf hillclimb candidate.
    """
    B, S, _ = x.shape
    H = cfg.n_heads
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    cq = rms_norm(jnp.dot(x, params["w_dq"]), params["q_norm"])
    q = jnp.dot(cq, params["w_uq"]).reshape(B, S, H, qk_head)
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_head_dim :], rope_table, positions)

    dkv = jnp.dot(x, params["w_dkv"])
    c_kv = rms_norm(dkv[..., : cfg.kv_lora_rank], params["kv_norm"])
    k_rope = apply_rope(
        dkv[..., cfg.kv_lora_rank :][:, :, None, :], rope_table, positions
    )[:, :, 0, :]  # shared single rope head [B, S, rope_dim]

    if cache is not None:
        c_kv_all = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, cache_pos, 0)
        )
        k_rope_all = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, cache_pos, 0)
        )
        new_cache = {"c_kv": c_kv_all, "k_rope": k_rope_all}
        valid = cache_pos + S
    else:
        c_kv_all, k_rope_all, new_cache = c_kv, k_rope, None
        valid = S
    T = c_kv_all.shape[1]

    # Effective per-head keys: concat(up-projected nope, shared rope head).
    k_nope = jnp.dot(c_kv_all, params["w_uk"]).reshape(
        B, T, H, cfg.qk_nope_head_dim
    )
    k_eff = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_all[:, :, None, :], (B, T, H, cfg.qk_rope_head_dim))],
        axis=-1,
    )
    v = jnp.dot(c_kv_all, params["w_uv"]).reshape(B, T, H, cfg.v_head_dim)
    q_eff = jnp.concatenate([q_nope, q_rope], axis=-1)

    out = _grouped_attention(
        q_eff.reshape(B, S, H, 1, qk_head),
        k_eff,
        v,
        q_positions=positions,
        kv_valid_len=valid,
        chunk=chunk,
    )
    out = out.reshape(B, S, H * cfg.v_head_dim)
    return jnp.dot(out, params["wo"]), new_cache



# ----------------------------------------------------- split-KV decode (§Perf)
#
# Decode with the KV cache sharded along the SEQUENCE axis over the mesh
# ``model`` axis (flash-decoding / split-KV): every rank attends over its
# 1/M chunk of the context and the partial softmaxes merge with the classic
# (max, sumexp, weighted-sum) reduction. The batch-sharded baseline cache
# does not even fit HBM for the decode_32k cells (EXPERIMENTS.md §Roofline);
# this layout shards the cache batch x seq = data x model.
#
# Projection weights KEEP sharded storage: inputs to row-sharded weights
# arrive feature-sharded via shard_map in_specs (P(..., 'model')) and a psum
# completes the contraction; outputs of column-sharded weights are assembled
# with a tiled all_gather. No partition-indexed dynamic slices — besides
# being cleaner SPMD, the traced-index form trips an XLA-CPU partitioner
# crash on bf16 ("Invalid binary instruction opcode copy"), recorded in
# EXPERIMENTS.md §Perf as a refuted-implementation note.


def _splitkv_merge(mi, li, oi, axis_name):
    """Merge per-chunk partial softmax results across ``axis_name``.

    mi/li [..., 1] chunk max / sumexp; oi [..., D] chunk weighted sum.
    """
    M = jax.lax.pmax(mi, axis_name)
    scale = jnp.exp(mi - M)
    num = jax.lax.psum(oi * scale, axis_name)
    den = jax.lax.psum(li * scale, axis_name)
    return num / jnp.maximum(den, 1e-30)


def gqa_decode_splitkv(
    params, x, rope_table, cfg: GQAConfig, cache, cache_pos, shard_ctx,
):
    """GQA decode step with seq-sharded cache. x [B, 1, d] (B over data)."""
    from jax.sharding import PartitionSpec as P

    m_axis = shard_ctx.model_axis

    def body(p, k_cache, v_cache, xm, pos):
        # xm: [B, 1, d/M] — this rank's feature slice of x.
        B = xm.shape[0]
        S_loc = k_cache.shape[1]
        m = jax.lax.axis_index(m_axis)
        positions = pos + jnp.arange(1)

        q = jax.lax.psum(jnp.dot(xm, p["wq"]), m_axis)
        k = jax.lax.psum(jnp.dot(xm, p["wk"]), m_axis)
        v = jax.lax.psum(jnp.dot(xm, p["wv"]), m_axis)
        if cfg.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        q = q.reshape(B, 1, cfg.n_heads, cfg.head_dim)
        k = k.reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        v = v.reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"])
            k = rms_norm(k, p["k_norm"])
        q = apply_rope(q, rope_table, positions)
        k = apply_rope(k, rope_table, positions)

        # Insert the new token's K/V on the rank owning position ``pos``
        # (slice-level conditional write).
        owner = pos // S_loc
        local = jnp.where(owner == m, pos - owner * S_loc, 0)
        mine = owner == m

        def _masked_write(cache4, new4):
            cur = jax.lax.dynamic_slice(cache4, (0, local, 0, 0), new4.shape)
            val = jnp.where(mine, new4.astype(cache4.dtype), cur)
            return jax.lax.dynamic_update_slice(cache4, val, (0, local, 0, 0))

        k_cache = _masked_write(k_cache, k)
        v_cache = _masked_write(v_cache, v)

        # Partial attention over the local seq chunk.
        q5 = q.reshape(B, 1, cfg.n_kv_heads, cfg.group, cfg.head_dim)
        scale = 1.0 / np.sqrt(cfg.head_dim)
        logits = jnp.einsum(
            "bqkgd,bskd->bkgqs", q5, k_cache,
            preferred_element_type=jnp.float32,
        ) * scale  # [B, KV, G, 1, S_loc]
        kpos = m * S_loc + jnp.arange(S_loc)
        valid = (kpos <= pos)[None, None, None, None, :]
        logits = jnp.where(valid, logits, _NEG)
        mi = jnp.max(logits, axis=-1, keepdims=True)
        pexp = jnp.where(valid, jnp.exp(logits - mi), 0.0)
        li = jnp.sum(pexp, axis=-1, keepdims=True)
        oi = jnp.einsum("bkgqs,bskd->bkgqd", pexp, v_cache.astype(jnp.float32))

        out = _splitkv_merge(mi, li, oi, m_axis)  # [B,KV,G,1,D] merged
        out = out.transpose(0, 3, 1, 2, 4).reshape(
            B, 1, cfg.n_heads * cfg.head_dim
        ).astype(xm.dtype)
        # wo column-sharded on d_model: local part + tiled all_gather.
        y_part = jnp.dot(out, p["wo"])  # [B, 1, d/M]
        y = jax.lax.all_gather(y_part, m_axis, axis=2, tiled=True)
        return y, k_cache, v_cache

    p_specs = {
        "wq": P(m_axis, None), "wk": P(m_axis, None), "wv": P(m_axis, None),
        "wo": P(None, m_axis),
    }
    if cfg.qkv_bias:
        p_specs.update({"bq": P(), "bk": P(), "bv": P()})
    if cfg.qk_norm:
        p_specs.update({"q_norm": P(), "k_norm": P()})
    da = shard_ctx.data_axes
    # ALL-manual shard_map (every mesh axis listed): bf16 psum under
    # partial-manual shard_map hits an XLA-CPU partitioner crash
    # ("Invalid binary instruction opcode copy") — recorded in §Perf.
    fn = shard_map(
        body,
        mesh=shard_ctx.mesh,
        in_specs=(p_specs, P(da, m_axis, None, None),
                  P(da, m_axis, None, None), P(da, None, m_axis), P()),
        out_specs=(P(da, None, None), P(da, m_axis, None, None),
                   P(da, m_axis, None, None)),
        check_vma=False,
    )
    out, k_new, v_new = fn(params, cache["k"], cache["v"], x, cache_pos)
    return out, {"k": k_new, "v": v_new}


def mla_decode_splitkv(
    params, x, rope_table, cfg: MLAConfig, cache, cache_pos, shard_ctx,
):
    """MLA decode: seq-sharded compressed cache + ABSORBED matmuls.

    Beyond-paper wins stacked here (§Perf cell A'):
      * cache (c_kv, k_rope) sharded batch x seq — fits HBM at 32k;
      * absorbed q (q_nope @ w_uk folded per step) — attention runs in the
        512-dim compressed space; the baseline's per-step cache expansion
        (T x H x (nope+v) matmuls over the whole context) disappears;
      * w_uk/w_uv enter replicated (33 MB/layer) — the price of absorption;
        every other projection keeps sharded storage (row-sharded with
        feature-sharded inputs, or column-sharded with a tiled all_gather).
    """
    from jax.sharding import PartitionSpec as P

    m_axis = shard_ctx.model_axis
    H = cfg.n_heads
    R = cfg.kv_lora_rank

    def body(p, c_kv, k_rope, xm, pos):
        B = xm.shape[0]
        S_loc = c_kv.shape[1]
        m = jax.lax.axis_index(m_axis)
        positions = pos + jnp.arange(1)

        cq = rms_norm(
            jax.lax.psum(jnp.dot(xm, p["w_dq"]), m_axis), p["q_norm"]
        )  # [B, 1, q_lora] replicated
        qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        # w_uq column-sharded on (H*qk_head): parts -> tiled all_gather.
        q_part = jnp.dot(cq, p["w_uq"])  # [B, 1, H*qk/M]
        q = jax.lax.all_gather(q_part, m_axis, axis=2, tiled=True).reshape(
            B, 1, H, qk_head
        )
        q_nope = q[..., : cfg.qk_nope_head_dim]
        q_rope = apply_rope(q[..., cfg.qk_nope_head_dim :], rope_table, positions)

        dkv = jax.lax.psum(jnp.dot(xm, p["w_dkv"]), m_axis)
        c_new = rms_norm(dkv[..., :R], p["kv_norm"])
        kr_new = apply_rope(
            dkv[..., R:][:, :, None, :], rope_table, positions
        )[:, :, 0, :]

        owner = pos // S_loc
        local = jnp.where(owner == m, pos - owner * S_loc, 0)
        mine = owner == m

        def _masked_write3(cache3, new3):
            cur = jax.lax.dynamic_slice(cache3, (0, local, 0), new3.shape)
            val = jnp.where(mine, new3.astype(cache3.dtype), cur)
            return jax.lax.dynamic_update_slice(cache3, val, (0, local, 0))

        c_kv = _masked_write3(c_kv, c_new)
        k_rope = _masked_write3(k_rope, kr_new)

        # Absorbed query: fold w_uk into q once per step.
        w_uk = p["w_uk"].reshape(R, H, cfg.qk_nope_head_dim)
        q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)  # [B,1,H,R]
        scale = 1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
        logits = (
            jnp.einsum("bqhr,bsr->bhqs", q_abs, c_kv,
                       preferred_element_type=jnp.float32)
            + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope,
                         preferred_element_type=jnp.float32)
        ) * scale  # [B, H, 1, S_loc]
        kpos = m * S_loc + jnp.arange(S_loc)
        valid = (kpos <= pos)[None, None, None, :]
        logits = jnp.where(valid, logits, _NEG)
        mi = jnp.max(logits, axis=-1, keepdims=True)
        pexp = jnp.where(valid, jnp.exp(logits - mi), 0.0)
        li = jnp.sum(pexp, axis=-1, keepdims=True)
        o_c = jnp.einsum("bhqs,bsr->bhqr", pexp, c_kv.astype(jnp.float32))
        o_c = _splitkv_merge(mi, li, o_c, m_axis)  # [B,H,1,R]

        w_uv = p["w_uv"].reshape(R, H, cfg.v_head_dim)
        out = jnp.einsum("bhqr,rhd->bqhd", o_c.astype(xm.dtype), w_uv)
        out = out.reshape(B, 1, H * cfg.v_head_dim)
        y_part = jnp.dot(out, p["wo"])  # wo column-sharded on d_model
        y = jax.lax.all_gather(y_part, m_axis, axis=2, tiled=True)
        return y, c_kv, k_rope

    p_specs = {
        "w_dq": P(m_axis, None), "q_norm": P(), "w_uq": P(None, m_axis),
        "w_dkv": P(m_axis, None), "kv_norm": P(),
        "w_uk": P(), "w_uv": P(),  # replicated: absorbed-path operands
        "wo": P(None, m_axis),
    }
    da = shard_ctx.data_axes
    fn = shard_map(
        body,
        mesh=shard_ctx.mesh,
        in_specs=(p_specs, P(da, m_axis, None), P(da, m_axis, None),
                  P(da, None, m_axis), P()),
        out_specs=(P(da, None, None), P(da, m_axis, None),
                   P(da, m_axis, None)),
        check_vma=False,
    )
    out, c_new, kr_new = fn(params, cache["c_kv"], cache["k_rope"], x, cache_pos)
    return out, {"c_kv": c_new, "k_rope": kr_new}


def gqa_prefill_splitkv(
    params, x, rope_table, cfg: GQAConfig, cache, chunk_idx, shard_ctx,
    q_sub: int = 512,
):
    """One prefill chunk with the seq-sharded cache layout (§Perf cell A).

    x [B, C, d] where C == S_max / n_model — each chunk is owned by exactly
    one model rank, so the cache write is a masked full-slice set. Attention
    runs as sequence-parallel partial softmax: every rank scores the chunk's
    queries against ITS cache slice and the (max, sumexp, sum) merge psums
    combine — ring-attention-lite, one hop. q/k/v arrive via feature-sharded
    row contractions (psum); wo is column-sharded (tiled all_gather). The
    resulting cache layout is IDENTICAL to gqa_decode_splitkv's, so prefill
    and decode share one serving layout.
    """
    from jax.sharding import PartitionSpec as P

    m_axis = shard_ctx.model_axis
    da = shard_ctx.data_axes

    def body(p, k_cache, v_cache, xm, c_idx):
        B, C, _ = xm.shape
        S_loc = k_cache.shape[1]
        m = jax.lax.axis_index(m_axis)
        pos0 = c_idx * C
        positions = pos0 + jnp.arange(C)

        q = jax.lax.psum(jnp.dot(xm, p["wq"]), m_axis)
        k = jax.lax.psum(jnp.dot(xm, p["wk"]), m_axis)
        v = jax.lax.psum(jnp.dot(xm, p["wv"]), m_axis)
        if cfg.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        q = q.reshape(B, C, cfg.n_heads, cfg.head_dim)
        k = k.reshape(B, C, cfg.n_kv_heads, cfg.head_dim)
        v = v.reshape(B, C, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"])
            k = rms_norm(k, p["k_norm"])
        q = apply_rope(q, rope_table, positions)
        k = apply_rope(k, rope_table, positions)

        # Chunk C == S_loc: rank c_idx owns the whole write.
        mine = (c_idx % axis_size(m_axis)) == m
        k_cache = jnp.where(mine, k.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(mine, v.astype(v_cache.dtype), v_cache)

        # Sequence-parallel attention: q sub-chunks vs the local slice.
        q5 = q.reshape(B, C, cfg.n_kv_heads, cfg.group, cfg.head_dim)
        scale = 1.0 / np.sqrt(cfg.head_dim)
        kpos = m * S_loc + jnp.arange(S_loc)
        nsub = max(C // q_sub, 1)
        sub = C // nsub

        def one_sub(args):
            qc, qpos = args  # [B, sub, KV, G, D], [sub]
            logits = jnp.einsum(
                "bqkgd,bskd->bkgqs", qc, k_cache,
                preferred_element_type=jnp.float32,
            ) * scale
            valid = kpos[None, :] <= qpos[:, None]  # [sub, S_loc]
            vmask = valid[None, None, None]
            logits = jnp.where(vmask, logits, _NEG)
            mi = jnp.max(logits, axis=-1, keepdims=True)
            pexp = jnp.where(vmask, jnp.exp(logits - mi), 0.0)
            li = jnp.sum(pexp, axis=-1, keepdims=True)
            oi = jnp.einsum("bkgqs,bskd->bkgqd", pexp,
                            v_cache.astype(jnp.float32))
            return _splitkv_merge(mi, li, oi, m_axis)  # [B,KV,G,sub,D]

        qr = q5.reshape(B, nsub, sub, cfg.n_kv_heads, cfg.group,
                        cfg.head_dim).transpose(1, 0, 2, 3, 4, 5)
        pr = positions.reshape(nsub, sub)
        outs = jax.lax.map(one_sub, (qr, pr))  # [nsub, B, KV, G, sub, D]
        out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(
            B, C, cfg.n_heads * cfg.head_dim
        ).astype(xm.dtype)
        y_part = jnp.dot(out, p["wo"])
        y = jax.lax.all_gather(y_part, m_axis, axis=2, tiled=True)
        return y, k_cache, v_cache

    p_specs = {
        "wq": P(m_axis, None), "wk": P(m_axis, None), "wv": P(m_axis, None),
        "wo": P(None, m_axis),
    }
    if cfg.qkv_bias:
        p_specs.update({"bq": P(), "bk": P(), "bv": P()})
    if cfg.qk_norm:
        p_specs.update({"q_norm": P(), "k_norm": P()})
    fn = shard_map(
        body,
        mesh=shard_ctx.mesh,
        in_specs=(p_specs, P(da, m_axis, None, None),
                  P(da, m_axis, None, None), P(da, None, m_axis), P()),
        out_specs=(P(da, None, None), P(da, m_axis, None, None),
                   P(da, m_axis, None, None)),
        check_vma=False,
    )
    out, k_new, v_new = fn(params, cache["k"], cache["v"], x, chunk_idx)
    return out, {"k": k_new, "v": v_new}
