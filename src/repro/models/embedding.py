"""Embedding lookups for recsys: EmbeddingBag and row-sharded tables.

JAX has no native nn.EmbeddingBag or CSR sparse — per kernel_taxonomy §RecSys
the bag is built from ``jnp.take`` + masked reduction (ragged bags via
``jax.ops.segment_sum``). The fused weighted-reduce has a Pallas kernel in
kernels/embedding_bag; the gather itself stays in XLA (TPU-native path —
SparseCore/dynamic-gather on real hardware).

Sharded tables: rows are mod-placed over the mesh ``model`` axis; each rank
gathers its local hits and the combine is a psum — the DLRM all-to-all
analogue (DESIGN.md §5). The reduce-scatter variant is a §Perf candidate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map

__all__ = ["embedding_bag", "embedding_bag_ragged", "sharded_field_lookup"]


def embedding_bag(
    table: jnp.ndarray,  # [V, D]
    ids: jnp.ndarray,  # [B, L] int32, -1 = padding
    weights: jnp.ndarray | None = None,  # [B, L]
    combine: str = "sum",
    impl: str = "xla",
) -> jnp.ndarray:
    """Fixed-width multi-hot bag: out[b] = reduce_l table[ids[b, l]]."""
    mask = (ids >= 0).astype(table.dtype)
    w = mask if weights is None else weights.astype(table.dtype) * mask
    if impl == "pallas":
        from repro.kernels.embedding_bag.ops import bag_reduce

        rows = table[jnp.clip(ids, 0)]  # [B, L, D]
        out = bag_reduce(rows, w)
    else:
        rows = table[jnp.clip(ids, 0)]
        out = jnp.einsum("bld,bl->bd", rows, w)
    if combine == "mean":
        out = out / jnp.maximum(w.sum(-1, keepdims=True), 1.0)
    return out


def embedding_bag_ragged(
    table: jnp.ndarray,
    flat_ids: jnp.ndarray,  # [N] int32
    segment_ids: jnp.ndarray,  # [N] int32 bag index per id
    n_bags: int,
    combine: str = "sum",
) -> jnp.ndarray:
    """Ragged bags via segment_sum (true EmbeddingBag semantics)."""
    rows = table[jnp.clip(flat_ids, 0)]
    rows = jnp.where((flat_ids >= 0)[:, None], rows, 0)
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    if combine == "mean":
        cnt = jax.ops.segment_sum(
            (flat_ids >= 0).astype(table.dtype), segment_ids, num_segments=n_bags
        )
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def sharded_field_lookup(table, ids, shard_ctx):
    """Row-sharded (mod-placement) embedding lookup.

    table [V, D] sharded P(model, None) (contiguous row blocks); ids [...]
    global row ids. Each model rank resolves the ids that fall inside its
    block and the psum over ``model`` assembles full rows — the collective
    the roofline table attributes to recsys lookups.
    """
    if shard_ctx is None:
        return table[jnp.clip(ids, 0)] * (ids >= 0)[..., None].astype(table.dtype)

    m_axis = shard_ctx.model_axis

    def body(tbl_local, ids_local):
        m = jax.lax.axis_index(m_axis)
        rows_per = tbl_local.shape[0]  # V / n_model (contiguous blocks)
        owner = jnp.where(ids_local >= 0, ids_local // rows_per, -1)
        local_row = jnp.clip(ids_local - m * rows_per, 0, rows_per - 1)
        rows = tbl_local[local_row]
        rows = jnp.where((owner == m)[..., None], rows, 0)
        return jax.lax.psum(rows, m_axis)

    B = ids.shape
    flat = ids.reshape(-1)
    # Shard the id stream over data only when it divides; tiny id sets
    # (e.g. batch-1 retrieval user features) are replicated instead.
    n_data = 1
    for a in shard_ctx.data_axes:
        n_data *= shard_ctx.mesh.shape[a]
    ids_spec = P(shard_ctx.data_axes) if flat.shape[0] % n_data == 0 else P()
    fn = shard_map(
        body,
        mesh=shard_ctx.mesh,
        in_specs=(P(m_axis, None), ids_spec),
        out_specs=P(*ids_spec, None),
        check_vma=False,
    )
    out = fn(table, flat)
    return out.reshape(*B, table.shape[1])
