"""GraphSAGE (mean aggregator) over explicit edge lists.

Message passing = gather(src) -> segment_sum over dst -> degree-normalize,
the jax-native SpMM substitute (JAX sparse is BCOO-only; see DESIGN.md).
Three entry points share the same layer math:

  * full-graph forward (full_graph_sm / ogb_products shapes);
  * sampled layered-subgraph forward (minibatch_lg shape, hop k uses the
    k-th sampled edge set);
  * batched small graphs with mean readout (molecule shape).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_map

from repro.models.layers import dense_init

__all__ = ["SAGEConfig", "init_sage", "sage_forward", "sage_forward_sampled", "sage_forward_graphs", "sage_param_specs"]


@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    n_layers: int
    d_in: int
    d_hidden: int
    n_classes: int
    sample_sizes: tuple[int, ...] = (25, 10)
    dtype: Any = jnp.float32


def init_sage(key, cfg: SAGEConfig):
    layers = []
    d = cfg.d_in
    for i in range(cfg.n_layers):
        out = cfg.n_classes if i == cfg.n_layers - 1 else cfg.d_hidden
        k1, k2, key = jax.random.split(key, 3)
        layers.append(
            {
                "w_self": dense_init(k1, d, out, cfg.dtype),
                "w_neigh": dense_init(k2, d, out, cfg.dtype),
                "b": jnp.zeros((out,), cfg.dtype),
            }
        )
        d = out
    return {"layers": layers}


def _mean_aggregate(h, src_idx, dst_idx, n_nodes):
    """mean_{(s,d) in E} h[s] grouped by d; padded (-1) edges drop out."""
    valid = src_idx >= 0
    msgs = jnp.where(valid[:, None], h[jnp.clip(src_idx, 0)], 0)
    dst = jnp.where(valid, dst_idx, n_nodes)  # out-of-range -> dropped
    summed = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes + 1)[:-1]
    deg = jax.ops.segment_sum(
        valid.astype(h.dtype), dst, num_segments=n_nodes + 1
    )[:-1]
    return summed / jnp.maximum(deg, 1.0)[:, None]


def _sage_layer(layer, h, agg, last: bool):
    out = jnp.dot(h, layer["w_self"]) + jnp.dot(agg, layer["w_neigh"]) + layer["b"]
    if last:
        return out
    out = jax.nn.relu(out)
    norm = jnp.linalg.norm(out, axis=-1, keepdims=True)
    return out / jnp.maximum(norm, 1e-6)


def sage_forward(params, feats, edges, cfg: SAGEConfig):
    """Full-graph forward. feats [N, d]; edges [E, 2] (src, dst)."""
    h = feats.astype(cfg.dtype)
    n = feats.shape[0]
    for i, layer in enumerate(params["layers"]):
        agg = _mean_aggregate(h, edges[:, 0], edges[:, 1], n)
        h = _sage_layer(layer, h, agg, i == len(params["layers"]) - 1)
    return h  # [N, n_classes] at the last layer


def sage_forward_sampled(params, feats, hops, cfg: SAGEConfig, n_batch: int):
    """Layered-subgraph forward: hop k's edges feed layer k (outermost first).

    feats [n_sub, d] over the union node set; returns logits for the first
    n_batch nodes (the seed batch).
    """
    h = feats.astype(cfg.dtype)
    n = feats.shape[0]
    L = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        src, dst = hops[L - 1 - i]  # outermost hop aggregates first
        agg = _mean_aggregate(h, src, dst, n)
        h = _sage_layer(layer, h, agg, i == L - 1)
    return h[:n_batch]


def sage_forward_graphs(params, feats, edges, graph_ids, n_graphs, cfg: SAGEConfig):
    """Batched small graphs: node embeddings -> mean readout per graph."""
    h = feats.astype(cfg.dtype)
    n = feats.shape[0]
    for i, layer in enumerate(params["layers"]):
        agg = _mean_aggregate(h, edges[:, 0], edges[:, 1], n)
        h = _sage_layer(layer, h, agg, i == len(params["layers"]) - 1)
    summed = jax.ops.segment_sum(h, graph_ids, num_segments=n_graphs)
    counts = jax.ops.segment_sum(
        jnp.ones((n,), h.dtype), graph_ids, num_segments=n_graphs
    )
    return summed / jnp.maximum(counts, 1.0)[:, None]


def sage_loss(logits, labels):
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[:, None], axis=-1
    )[:, 0]
    return jnp.mean(lse - gold)


def sage_param_specs(cfg: SAGEConfig, model_axis: str = "model"):
    """Feature-dim TP for the hidden layers (hidden dims are small; the
    heavy axis for GNN is edges/data — handled by input sharding)."""
    from jax.sharding import PartitionSpec as P

    specs = []
    for i in range(cfg.n_layers):
        specs.append({"w_self": P(), "w_neigh": P(), "b": P()})
    return {"layers": specs}


# ------------------------------------------------- sharded full-batch (§Perf)
#
# Baseline full-batch training replicates node features and psums the
# aggregated messages — collective-dominant on ogb_products (EXPERIMENTS.md
# §Roofline). This variant partitions NODES contiguously over the data axis
# and EDGES by destination owner (host-side, free at load time): the
# segment_sum becomes LOCAL, and the only collective is one bf16 all_gather
# of the (much narrower) layer activations — an all-gather of N*d_hidden
# bf16 instead of an all-reduce of N*d_hidden fp32 per layer per direction.


def sage_forward_sharded(params, feats_loc, agg0_loc, edges_loc,
                         cfg: SAGEConfig, n_nodes: int, shard_ctx):
    """Node/dst-partitioned full-batch forward inside shard_map.

    feats_loc [N/D, d]  — this rank's node block (contiguous);
    agg0_loc  [N/D, d]  — PRECOMPUTED first-hop mean aggregate (the layer-1
                          neighbor mean is weight-independent, so it is a
                          data-pipeline constant — the SIGN trick — and its
                          feature gather disappears from every step);
    edges_loc [E/D, 2]  — edges whose dst lives in this block (-1 padded).
    Returns local logits [N/D, n_classes].
    """
    import jax
    from jax.sharding import PartitionSpec as P

    da = shard_ctx.data_axes

    def body(p, h_loc, a0_loc, e_loc):
        n_loc = h_loc.shape[0]
        # Linearized data-rank (axis-major order matches P(da) layout).
        d_rank = jnp.zeros((), jnp.int32)
        for a in da:
            d_rank = d_rank * shard_ctx.mesh.shape[a] + jax.lax.axis_index(a)
        base = d_rank * n_loc
        h = h_loc.astype(cfg.dtype)
        L = len(p["layers"])
        for i, layer in enumerate(p["layers"]):
            if i == 0:
                agg = a0_loc.astype(cfg.dtype)  # precomputed, zero collectives
            else:
                # bf16 all_gather of hidden activations (innermost axis
                # first so ordering matches the global layout).
                h_full = h.astype(jnp.bfloat16)
                for a in reversed(da):
                    h_full = jax.lax.all_gather(h_full, a, axis=0, tiled=True)
                src = e_loc[:, 0]
                dst_local = jnp.where(e_loc[:, 1] >= 0, e_loc[:, 1] - base, n_loc)
                valid = (src >= 0) & (dst_local >= 0) & (dst_local < n_loc)
                msgs = jnp.where(
                    valid[:, None], h_full[jnp.clip(src, 0)].astype(cfg.dtype), 0
                )
                summed = jax.ops.segment_sum(
                    msgs, jnp.where(valid, dst_local, n_loc),
                    num_segments=n_loc + 1,
                )[:-1]
                deg = jax.ops.segment_sum(
                    valid.astype(cfg.dtype), jnp.where(valid, dst_local, n_loc),
                    num_segments=n_loc + 1,
                )[:-1]
                agg = summed / jnp.maximum(deg, 1.0)[:, None]
            h = _sage_layer(layer, h, agg, i == L - 1)
        return h

    fn = shard_map(
        body,
        mesh=shard_ctx.mesh,
        in_specs=(P(), P(da, None), P(da, None), P(da, None)),
        out_specs=P(da, None),
        check_vma=False,
    )
    return fn(params, feats_loc, agg0_loc, edges_loc)


def sage_loss_per_node(logits, labels):
    """Per-node CE (no reduction) — sharded-variant loss masks padding."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[:, None], axis=-1
    )[:, 0]
    return lse - gold
