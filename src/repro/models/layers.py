"""Shared model layers: RMSNorm, RoPE, SwiGLU, initializers.

Functional style: parameters are nested dicts of jnp arrays; every layer is
a pure function. Compute dtype is configurable (bf16 for the production
meshes); normalization statistics and softmaxes accumulate in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dense_init",
    "rms_norm",
    "rms_norm_init",
    "rope_freqs",
    "apply_rope",
    "swiglu",
    "swiglu_init",
]


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16, scale: float = 1.0):
    std = scale / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def rms_norm_init(d: int, dtype=jnp.bfloat16):
    return jnp.ones((d,), dtype)


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, max_len: int, theta: float = 10000.0) -> jnp.ndarray:
    """[max_len, head_dim//2] complex-free rotary angle table (fp32)."""
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))
    t = np.arange(max_len, dtype=np.float64)
    ang = np.outer(t, inv)
    return jnp.asarray(np.stack([np.cos(ang), np.sin(ang)], axis=-1), jnp.float32)


def apply_rope(x: jnp.ndarray, rope: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs. x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    dt = x.dtype
    cs = rope[positions]  # [..., seq, hd//2, 2]
    cos = cs[..., 0][..., None, :]  # [..., seq, 1, hd//2]
    sin = cs[..., 1][..., None, :]
    xf = x.astype(jnp.float32)
    x1 = xf[..., 0::2]
    x2 = xf[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(dt)


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(params, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.dot(x, params["w_gate"])
    u = jnp.dot(x, params["w_up"])
    return jnp.dot(jax.nn.silu(g) * u, params["w_down"])
