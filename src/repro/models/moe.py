"""Mixture-of-Experts FFN with capacity-based top-k routing and EP sharding.

Expert parallelism is fused with tensor parallelism (DESIGN.md §5): experts
are sharded over the mesh ``model`` axis; tokens arrive replicated across
that axis (the standard TP activation layout), each model rank dispatches
only to the experts it owns, and the combine is a single ``psum`` over
``model`` — the same all-reduce a dense TP FFN would issue, so EP costs no
extra collective in the baseline. Dispatch uses a local argsort over
(token, slot) pairs — no global sort, no cross-shard data-dependent
communication. Tokens beyond an expert's capacity are dropped (standard
capacity-factor semantics; the aux load-balance loss keeps drops rare).

One math path, two entries: ``_moe_compute`` runs on a device-local token
block for the expert slice [e_lo, e_lo + E_loc); the single-device path uses
the full slice, the shard_map path derives the slice from axis_index.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map

from repro.models.layers import dense_init

__all__ = ["MoEConfig", "init_moe", "moe_ffn"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden width
    n_shared: int = 0  # shared experts, each d_ff wide
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


def init_moe(key, d_model: int, cfg: MoEConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    E, F = cfg.n_experts, cfg.d_ff
    p = {
        "router": dense_init(ks[0], d_model, E, jnp.float32),
        "w_gate": jax.vmap(lambda k: dense_init(k, d_model, F, dtype))(
            jax.random.split(ks[1], E)
        ),
        "w_up": jax.vmap(lambda k: dense_init(k, d_model, F, dtype))(
            jax.random.split(ks[2], E)
        ),
        "w_down": jax.vmap(lambda k: dense_init(k, F, d_model, dtype))(
            jax.random.split(ks[3], E)
        ),
    }
    if cfg.n_shared:
        fs = cfg.n_shared * cfg.d_ff
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, d_model, fs, dtype),
            "w_up": dense_init(k2, d_model, fs, dtype),
            "w_down": dense_init(k3, fs, d_model, dtype),
        }
    return p


def _route(router_w, x, cfg: MoEConfig):
    """fp32 routing: renormalized top-k probs + Switch-style aux loss."""
    logits = jnp.dot(x.astype(jnp.float32), router_w)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)  # [T, k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(0)  # mean router prob per expert
    ce = jax.nn.one_hot(topi[:, 0], cfg.n_experts, dtype=jnp.float32).mean(0)
    aux = cfg.n_experts * jnp.sum(me * ce)
    return topv, topi, aux


def _dispatch_ranks(flat_e: jnp.ndarray, n_buckets: int):
    """Rank of each (token, slot) within its bucket, via stable local argsort."""
    N = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(n_buckets), side="left")
    rank_sorted = jnp.arange(N, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    return jnp.zeros((N,), jnp.int32).at[order].set(rank_sorted)


def _expert_ffn(p, buf):
    """buf [E_loc, C, d] through each expert's SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"])


def _shared_ffn(p, x):
    g = jnp.dot(x, p["w_gate"])
    u = jnp.dot(x, p["w_up"])
    return jnp.dot(jax.nn.silu(g) * u, p["w_down"])


def _capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, int(c))


def _moe_compute(params, x, cfg: MoEConfig, e_lo, E_loc: int):
    """Dispatch/compute/combine for the expert slice [e_lo, e_lo + E_loc).

    ``params`` expert leaves hold the local slice [E_loc, ...]; ``e_lo`` may
    be a traced scalar (shard_map) or 0. Returns the partial output (zeros
    for slots owned by other ranks) and the aux loss.
    """
    T, d = x.shape
    topv, topi, aux = _route(params["router"], x, cfg)
    k = cfg.top_k
    C = _capacity(T, cfg)

    flat_e = topi.reshape(-1)  # [T*k] global expert ids
    local = (flat_e >= e_lo) & (flat_e < e_lo + E_loc)
    e_local = jnp.where(local, flat_e - e_lo, E_loc).astype(jnp.int32)  # E_loc = trash
    rank = _dispatch_ranks(e_local, E_loc + 1)
    keep = local & (rank < C)

    e_idx = jnp.where(keep, e_local, E_loc)
    c_idx = jnp.where(keep, rank, C - 1)
    x_rep = jnp.repeat(x, k, axis=0)  # [T*k, d]
    buf = jnp.zeros((E_loc + 1, C, d), x.dtype)
    buf = buf.at[e_idx, c_idx].add(jnp.where(keep[:, None], x_rep, 0))

    y_buf = _expert_ffn(params, buf[:E_loc])
    y_buf = jnp.concatenate([y_buf, jnp.zeros((1, C, d), y_buf.dtype)], 0)
    y_slots = jnp.where(keep[:, None], y_buf[e_idx, c_idx], 0)
    w = topv.reshape(-1).astype(x.dtype)
    y = (y_slots * w[:, None]).reshape(T, k, d).sum(axis=1)
    return y, aux


def moe_ffn(params, x2d: jnp.ndarray, cfg: MoEConfig, shard_ctx=None):
    """MoE FFN over flat tokens x2d [T, d]. Returns (y [T, d], aux scalar)."""
    if shard_ctx is None:
        y, aux = _moe_compute(params, x2d, cfg, 0, cfg.n_experts)
        if cfg.n_shared:
            y = y + _shared_ffn(params["shared"], x2d)
        return y, aux

    model_axis = shard_ctx.model_axis
    data_axes = shard_ctx.data_axes
    n_model = shard_ctx.mesh.shape[model_axis]
    E_loc = cfg.n_experts // n_model

    def body(p, x):
        e_lo = jax.lax.axis_index(model_axis) * E_loc
        y, aux = _moe_compute(p, x, cfg, e_lo, E_loc)
        if cfg.n_shared:
            # Shared expert hidden is sharded over the model axis; its
            # partial sums ride the same psum as the routed combine.
            y = y + _shared_ffn(p["shared"], x)
        y = jax.lax.psum(y, model_axis)
        aux = jax.lax.pmean(aux, data_axes)
        return y, aux

    param_specs = {
        "router": P(),
        "w_gate": P(model_axis, None, None),
        "w_up": P(model_axis, None, None),
        "w_down": P(model_axis, None, None),
    }
    if cfg.n_shared:
        param_specs["shared"] = {
            "w_gate": P(None, model_axis),
            "w_up": P(None, model_axis),
            "w_down": P(model_axis, None),
        }
    fn = shard_map(
        body,
        mesh=shard_ctx.mesh,
        in_specs=(param_specs, P(data_axes, None)),
        out_specs=(P(data_axes, None), P()),
        check_vma=False,
    )
    return fn(params, x2d)


def moe_ffn_decode_ep_all(params, x2d: jnp.ndarray, cfg: MoEConfig, shard_ctx):
    """Decode-time MoE with experts sharded over the WHOLE (data, model) grid.

    Serving deepseek-v3 on 256 chips cannot hold 256 experts at E/16 per
    chip (84 GB/device); with EP over data x model each chip owns exactly
    E / 256 experts. Token counts at decode are tiny (the whole batch is a
    few hundred rows), so the exchange is an all_gather of x (a few MB) and
    one psum of the combined output — negligible next to the weight
    residency it buys. Used by the split_kv decode variant (§Perf cell A').
    """
    # EP grid = data x model (pods replicate experts — the pod axis is the
    # throughput-replication axis, DESIGN.md §5).
    ep_axes = ("data", shard_ctx.model_axis)
    n_all = 1
    for a in ep_axes:
        n_all *= shard_ctx.mesh.shape[a]
    if cfg.n_experts % n_all != 0:
        # Fall back to model-axis EP when experts don't cover the grid.
        return moe_ffn(params, x2d, cfg, shard_ctx)
    E_loc = cfg.n_experts // n_all

    def body(p, x_loc):
        # Rebuild this pod's (tiny) token block on every rank.
        x_full = jax.lax.all_gather(x_loc, "data", axis=0, tiled=True)
        # Linearized rank over the (data, model) EP grid.
        rank = (
            jax.lax.axis_index("data") * shard_ctx.mesh.shape[shard_ctx.model_axis]
            + jax.lax.axis_index(shard_ctx.model_axis)
        )
        e_lo = rank * E_loc
        y_full, aux = _moe_compute(p, x_full, cfg, e_lo, E_loc)
        # fp32 psums: bf16 psum under partial-manual shard_map (pod stays
        # auto on the multi-pod mesh) trips an XLA-CPU crash; fp32 is also
        # the numerically right accumulator for a 256-way combine.
        y_full = jax.lax.psum(y_full.astype(jnp.float32), ep_axes)
        if cfg.n_shared:
            y_full = y_full + jax.lax.psum(
                _shared_ffn(p["shared"], x_full).astype(jnp.float32),
                shard_ctx.model_axis,
            )
        y_full = y_full.astype(x_full.dtype)
        # Slice back this data-shard's tokens.
        T_loc = x_loc.shape[0]
        d_rank = jax.lax.axis_index("data")
        y_loc = jax.lax.dynamic_slice_in_dim(y_full, d_rank * T_loc, T_loc, 0)
        return y_loc, aux

    param_specs = {
        "router": P(),
        "w_gate": P(ep_axes, None, None),
        "w_up": P(ep_axes, None, None),
        "w_down": P(ep_axes, None, None),
    }
    if cfg.n_shared:
        param_specs["shared"] = {
            "w_gate": P(None, shard_ctx.model_axis),
            "w_up": P(None, shard_ctx.model_axis),
            "w_down": P(shard_ctx.model_axis, None),
        }
    fn = shard_map(
        body,
        mesh=shard_ctx.mesh,
        in_specs=(param_specs, P("data", None)),
        out_specs=(P("data", None), P()),
        axis_names=frozenset(ep_axes),
        check_vma=False,
    )
    return fn(params, x2d)
