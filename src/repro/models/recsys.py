"""RecSys architectures: BST, MIND, AutoInt, BERT4Rec.

Shared substrate: huge embedding tables (row-sharded over the mesh ``model``
axis via models.embedding) feeding a small interaction network. The four
assigned archs cover the three interaction regimes: transformer-over-
sequence (BST, BERT4Rec), multi-interest capsule routing (MIND), and
self-attention over field embeddings (AutoInt).

Shapes contract (configs/*.py): ``train`` takes a feature dict + labels;
``serve`` scores (user, item) pairs; ``retrieval`` scores one user against
n_candidates items (the paper-representative anytime top-k cell — see
serve/retrieval.py for the clustered anytime scorer).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.embedding import embedding_bag, sharded_field_lookup
from repro.models.layers import dense_init, rms_norm_init, rms_norm

__all__ = ["RecConfig", "init_rec", "rec_train_loss", "rec_serve_scores", "rec_retrieval_scores", "rec_param_specs", "rec_user_embedding"]


@dataclasses.dataclass(frozen=True)
class RecConfig:
    name: str
    arch: str  # "bst" | "mind" | "autoint" | "bert4rec"
    n_items: int
    embed_dim: int
    seq_len: int = 0
    n_fields: int = 0
    field_vocab: int = 100_000
    n_blocks: int = 1
    n_heads: int = 8
    mlp: tuple = (1024, 512, 256)
    n_interests: int = 4
    capsule_iters: int = 3
    n_attn_layers: int = 3
    d_attn: int = 32
    dtype: Any = jnp.float32
    loss_chunk: int = 2048


# ------------------------------------------------------------ small blocks


def _init_block(key, d: int, n_heads: int, dtype):
    ks = jax.random.split(key, 7)
    return {
        "ln1": rms_norm_init(d, dtype),
        "wq": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wo": dense_init(ks[3], d, d, dtype),
        "ln2": rms_norm_init(d, dtype),
        "w1": dense_init(ks[4], d, 4 * d, dtype),
        "w2": dense_init(ks[5], 4 * d, d, dtype),
    }


def _block(p, x, n_heads: int, causal: bool = False):
    """Small pre-LN transformer block; x [B, S, d]; full attention."""
    B, S, d = x.shape
    hd = d // n_heads
    z = rms_norm(x, p["ln1"])
    q = jnp.dot(z, p["wq"]).reshape(B, S, n_heads, hd)
    k = jnp.dot(z, p["wk"]).reshape(B, S, n_heads, hd)
    v = jnp.dot(z, p["wv"]).reshape(B, S, n_heads, hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(hd))
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(B, S, d)
    x = x + jnp.dot(o, p["wo"])
    z = rms_norm(x, p["ln2"])
    return x + jnp.dot(jax.nn.gelu(jnp.dot(z, p["w1"])), p["w2"])


def _init_mlp(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {"w": dense_init(ks[i], dims[i], dims[i + 1], dtype), "b": jnp.zeros((dims[i + 1],), dtype)}
        for i in range(len(dims) - 1)
    ]


def _mlp(layers, x, final_act=False):
    for i, l in enumerate(layers):
        x = jnp.dot(x, l["w"]) + l["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# -------------------------------------------------------------------- init


def init_rec(key, cfg: RecConfig):
    ks = jax.random.split(key, 10)
    dt = cfg.dtype
    D = cfg.embed_dim
    p: dict = {"item_emb": dense_init(ks[0], cfg.n_items, D, dt, scale=1.0)}
    if cfg.seq_len:
        p["pos_emb"] = dense_init(ks[1], cfg.seq_len + 1, D, dt, scale=0.2)
    if cfg.n_fields:
        p["field_emb"] = dense_init(
            ks[2], cfg.n_fields * cfg.field_vocab, D, dt, scale=1.0
        )

    if cfg.arch == "bst":
        p["blocks"] = [
            _init_block(k, D, cfg.n_heads, dt)
            for k in jax.random.split(ks[3], cfg.n_blocks)
        ]
        d_cat = D * 2 + cfg.n_fields * D  # pooled seq + target + fields
        p["mlp"] = _init_mlp(ks[4], (d_cat, *cfg.mlp, 1), dt)
    elif cfg.arch == "mind":
        p["caps_bilinear"] = dense_init(ks[3], D, D, dt)
    elif cfg.arch == "autoint":
        p["attn"] = []
        for k in jax.random.split(ks[3], cfg.n_attn_layers):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            p["attn"].append(
                {
                    "wq": dense_init(k1, D, cfg.d_attn * 2, dt),
                    "wk": dense_init(k2, D, cfg.d_attn * 2, dt),
                    "wv": dense_init(k3, D, cfg.d_attn * 2, dt),
                    "wr": dense_init(k4, D, cfg.d_attn * 2, dt),
                }
            )
            D = cfg.d_attn * 2  # output width after first layer
        p["out"] = _init_mlp(ks[4], (cfg.n_fields * D, 1), dt)
    elif cfg.arch == "bert4rec":
        p["blocks"] = [
            _init_block(k, D, cfg.n_heads, dt)
            for k in jax.random.split(ks[3], cfg.n_blocks)
        ]
        p["final_norm"] = rms_norm_init(D, dt)
        p["mask_emb"] = dense_init(ks[5], 1, D, dt, scale=0.2)
    else:
        raise ValueError(cfg.arch)
    return p


# ------------------------------------------------------------ user encoders


def _lookup_fields(p, fields, cfg: RecConfig, shard_ctx):
    """fields [B, F] per-field ids -> [B, F, D]; rows offset per field."""
    offs = jnp.arange(cfg.n_fields, dtype=fields.dtype) * cfg.field_vocab
    gids = fields + offs[None, :]
    return sharded_field_lookup(p["field_emb"], gids, shard_ctx)


def _bst_seq(p, history, target, cfg: RecConfig):
    """history [B, S] (-1 pad), target [B] -> (pooled_seq [B, D], tgt [B, D])."""
    B, S = history.shape
    mask = (history >= 0).astype(p["item_emb"].dtype)
    seq = p["item_emb"][jnp.clip(history, 0)] * mask[..., None]
    tgt = p["item_emb"][jnp.clip(target, 0)]
    x = jnp.concatenate([seq, tgt[:, None, :]], axis=1)  # target joins the seq
    x = x + p["pos_emb"][None, : S + 1]
    for blk in p["blocks"]:
        x = _block(blk, x, cfg.n_heads)
    mask = jnp.concatenate(
        [history >= 0, jnp.ones((B, 1), bool)], axis=1
    ).astype(x.dtype)
    pooled = (x * mask[..., None]).sum(1) / jnp.maximum(mask.sum(1), 1.0)[:, None]
    return pooled, tgt


def _squash(v, axis=-1):
    n2 = jnp.sum(v * v, axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * v / jnp.sqrt(n2 + 1e-9)


def _mind_interests(p, history, cfg: RecConfig):
    """Capsule (B2I dynamic routing) multi-interest extraction [B, J, D]."""
    B, S = history.shape
    mask = (history >= 0).astype(cfg.dtype)
    e = p["item_emb"][jnp.clip(history, 0)] * mask[..., None]  # [B, S, D]
    eh = jnp.dot(e, p["caps_bilinear"])  # [B, S, D]
    # Fixed (non-trainable, deterministic) logit init as in MIND.
    b = jnp.zeros((B, S, cfg.n_interests), cfg.dtype)
    u = None
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(b, axis=-1) * mask[..., None]
        s = jnp.einsum("bsj,bsd->bjd", w, eh)
        u = _squash(s)
        b = b + jnp.einsum("bjd,bsd->bsj", u, eh)
    return u  # [B, J, D]


def _autoint_fields(p, emb, cfg: RecConfig):
    """emb [B, F, D] -> [B, F * d_out] via stacked interacting layers."""
    x = emb
    for layer in p["attn"]:
        q = jnp.dot(x, layer["wq"])
        k = jnp.dot(x, layer["wk"])
        v = jnp.dot(x, layer["wv"])
        H = 2  # two heads, d_attn each
        B, F, DD = q.shape
        hd = DD // H
        qh = q.reshape(B, F, H, hd)
        kh = k.reshape(B, F, H, hd)
        vh = v.reshape(B, F, H, hd)
        s = jnp.einsum("bqhd,bkhd->bhqk", qh, kh, preferred_element_type=jnp.float32)
        w = jax.nn.softmax(s / jnp.sqrt(jnp.float32(hd)), axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", w, vh).reshape(B, F, DD)
        x = jax.nn.relu(o + jnp.dot(x, layer["wr"]))
    return x.reshape(x.shape[0], -1)


def _bert4rec_hidden(p, history, cfg: RecConfig, mask_positions=None):
    """history [B, S]; optional masked positions replaced by [MASK] emb."""
    B, S = history.shape
    x = p["item_emb"][jnp.clip(history, 0)]
    if mask_positions is not None:
        m = jax.nn.one_hot(mask_positions, S, dtype=x.dtype)  # [B, M, S]
        is_masked = m.sum(1) > 0  # [B, S]
        x = jnp.where(is_masked[..., None], p["mask_emb"][0][None, None], x)
    x = x + p["pos_emb"][None, :S]
    for blk in p["blocks"]:
        x = _block(blk, x, cfg.n_heads)
    return rms_norm(x, p["final_norm"])


def rec_user_embedding(p, feats: dict, cfg: RecConfig):
    """User-side representation for retrieval (arch-dependent)."""
    if cfg.arch == "mind":
        return _mind_interests(p, feats["history"], cfg)  # [B, J, D]
    if cfg.arch == "bert4rec":
        h = _bert4rec_hidden(p, feats["history"], cfg)
        return h[:, -1:, :]  # [B, 1, D] last position
    raise ValueError(f"{cfg.arch} has no dot-product user embedding")


# ------------------------------------------------------------ score / loss


def rec_serve_scores(p, feats: dict, cfg: RecConfig, shard_ctx=None):
    """Pointwise scores for a batch of (user, item) examples -> [B]."""
    if cfg.arch == "bst":
        pooled, tgt = _bst_seq(p, feats["history"], feats["target"], cfg)
        fe = _lookup_fields(p, feats["fields"], cfg, shard_ctx)
        z = jnp.concatenate([pooled, tgt, fe.reshape(fe.shape[0], -1)], axis=-1)
        return _mlp(p["mlp"], z)[:, 0]
    if cfg.arch == "mind":
        u = _mind_interests(p, feats["history"], cfg)  # [B, J, D]
        t = p["item_emb"][jnp.clip(feats["target"], 0)]  # [B, D]
        return jnp.max(jnp.einsum("bjd,bd->bj", u, t), axis=-1)
    if cfg.arch == "autoint":
        emb = _lookup_fields(p, feats["fields"], cfg, shard_ctx)
        return _mlp(p["out"], _autoint_fields(p, emb, cfg))[:, 0]
    if cfg.arch == "bert4rec":
        h = _bert4rec_hidden(p, feats["history"], cfg)[:, -1]  # [B, D]
        t = p["item_emb"][jnp.clip(feats["target"], 0)]
        return jnp.sum(h * t, axis=-1)
    raise ValueError(cfg.arch)


def _bce(logits, labels):
    z = logits.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


def rec_train_loss(p, batch: dict, cfg: RecConfig, shard_ctx=None):
    if cfg.arch in ("bst", "autoint"):
        return _bce(rec_serve_scores(p, batch, cfg, shard_ctx), batch["label"])
    if cfg.arch == "mind":
        u = _mind_interests(p, batch["history"], cfg)  # [B, J, D]
        t = p["item_emb"][jnp.clip(batch["target"], 0)]  # [B, D]
        # Label-aware attention -> in-batch sampled softmax.
        ui = jnp.einsum("bjd,bd->bj", u, t)
        att = jax.nn.softmax(ui * 2.0, axis=-1)
        user = jnp.einsum("bj,bjd->bd", att, u)
        logits = jnp.dot(user, t.T).astype(jnp.float32)  # [B, B] in-batch
        labels = jnp.arange(logits.shape[0])
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold)
    if cfg.arch == "bert4rec":
        h = _bert4rec_hidden(p, batch["history"], cfg, batch["mask_positions"])
        B, M = batch["mask_positions"].shape
        hm = jnp.take_along_axis(
            h, batch["mask_positions"][..., None], axis=1
        )  # [B, M, D]
        flat = hm.reshape(B * M, cfg.embed_dim)
        labels = batch["mask_labels"].reshape(B * M)
        # Sampled softmax over a shared negative set (BERT4Rec-style):
        # full-vocab CE at batch 65536 x 20 masks x 1M items materializes
        # a [1.3M, 1M] logits block — 4096 shared negatives + the gold
        # column approximate it at 1/256 the traffic.
        n_neg = min(4096, cfg.n_items)
        # Deterministic strided negatives (jit-stable; per-step PRNG keys
        # would work equally — negatives just need vocab coverage).
        neg_ids = jnp.arange(n_neg) * (cfg.n_items // n_neg)
        neg_emb = p["item_emb"][neg_ids]  # [n_neg, D]
        gold_emb = p["item_emb"][jnp.clip(labels, 0)]  # [BM, D]
        neg_logits = jnp.dot(
            flat, neg_emb.T, preferred_element_type=jnp.float32
        )  # [BM, n_neg]
        gold_logit = jnp.sum(
            flat.astype(jnp.float32) * gold_emb.astype(jnp.float32), axis=-1
        )
        lse = jax.nn.logsumexp(
            jnp.concatenate([neg_logits, gold_logit[:, None]], axis=1), axis=-1
        )
        return jnp.mean(lse - gold_logit)
    raise ValueError(cfg.arch)


def rec_retrieval_scores(p, feats: dict, candidates: jnp.ndarray, cfg: RecConfig, shard_ctx=None):
    """Score ONE user against [C] candidate items -> [C].

    MIND/BERT4Rec: dot-product retrieval (user embedding vs item embeddings)
    — the shape served by the anytime clustered scorer (serve/retrieval.py).
    BST/AutoInt: the full ranking tower vectorized over candidates
    (offline bulk-scoring semantics).
    """
    C = candidates.shape[0]
    if cfg.arch in ("mind", "bert4rec"):
        u = rec_user_embedding(p, feats, cfg)[0]  # [J, D]
        t = p["item_emb"][jnp.clip(candidates, 0)]  # [C, D]
        return jnp.max(jnp.einsum("jd,cd->cj", u, t), axis=-1)
    if cfg.arch == "bst":
        pooled, _ = _bst_seq(
            p, feats["history"], jnp.zeros((1,), jnp.int32), cfg
        )  # [1, D] user side, target slot zeroed
        fe = _lookup_fields(p, feats["fields"], cfg, shard_ctx).reshape(1, -1)
        t = p["item_emb"][jnp.clip(candidates, 0)]  # [C, D]
        z = jnp.concatenate(
            [
                jnp.broadcast_to(pooled, (C, pooled.shape[-1])),
                t,
                jnp.broadcast_to(fe, (C, fe.shape[-1])),
            ],
            axis=-1,
        )
        return _mlp(p["mlp"], z)[:, 0]
    if cfg.arch == "autoint":
        # Candidate item takes the last field slot; user fields broadcast.
        f = jnp.broadcast_to(feats["fields"], (C, cfg.n_fields)).copy()
        f = f.at[:, -1].set(candidates % cfg.field_vocab)
        emb = _lookup_fields(p, f, cfg, shard_ctx)
        return _mlp(p["out"], _autoint_fields(p, emb, cfg))[:, 0]
    raise ValueError(cfg.arch)


def rec_param_specs(p_example, cfg: RecConfig, model_axis: str = "model"):
    """Embedding tables row-sharded over model; small nets replicated."""
    specs = jax.tree.map(lambda _: P(), p_example)
    specs["item_emb"] = P(model_axis, None)
    if "field_emb" in specs:
        specs["field_emb"] = P(model_axis, None)
    return specs
