"""Decoder-only transformer supporting all five assigned LM architectures.

  * GQA (qwen3 qk-norm, qwen2.5 QKV-bias, deepseek-67b llama-style) or MLA
    (deepseek-v3) attention;
  * dense SwiGLU FFN, optionally switching to MoE after n_dense_layers
    (deepseek-v3: 3 dense + 58 MoE; moonshot: 1 dense + 47 MoE);
  * optional MTP (multi-token prediction) auxiliary head (deepseek-v3);
  * layers stacked for lax.scan (small HLO, fast 512-device compiles) with a
    configurable remat policy;
  * chunked cross-entropy — logits never materialize beyond
    [chunk, vocab] (17 GB/device otherwise at train_4k on deepseek-v3);
  * decode path with per-layer KV caches (GQA) or compressed caches (MLA).

Parameter sharding specs are co-located here (param_specs) so the dry-run,
trainer, and checkpointing all derive layouts from one source of truth.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import (
    GQAConfig,
    MLAConfig,
    gqa,
    init_gqa,
    init_mla,
    mla,
)
from repro.models.layers import (
    dense_init,
    rms_norm,
    rms_norm_init,
    rope_freqs,
    swiglu,
    swiglu_init,
)
from repro.models.moe import MoEConfig, init_moe, moe_ffn

__all__ = ["LMConfig", "init_lm", "lm_forward", "lm_loss", "init_cache", "lm_decode_step", "param_specs", "count_params"]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    attn: Any  # GQAConfig | MLAConfig
    d_ff: int  # dense-FFN hidden width
    moe: Optional[MoEConfig] = None
    n_dense_layers: int = 0  # leading dense layers when moe is set
    max_seq: int = 4096
    dtype: Any = jnp.bfloat16
    mtp: bool = False
    mtp_weight: float = 0.3
    attn_chunk: int = 512
    remat: bool = True
    loss_chunk: int = 1024

    @property
    def n_moe_layers(self) -> int:
        return (self.n_layers - self.n_dense_layers) if self.moe else 0

    @property
    def n_dense_total(self) -> int:
        return self.n_layers - self.n_moe_layers


def _is_mla(cfg: LMConfig) -> bool:
    return isinstance(cfg.attn, MLAConfig)


def _init_attn(key, cfg: LMConfig):
    return init_mla(key, cfg.attn, cfg.dtype) if _is_mla(cfg) else init_gqa(
        key, cfg.attn, cfg.dtype
    )


def _attn(params, x, rope, cfg: LMConfig, **kw):
    fn = mla if _is_mla(cfg) else gqa
    return fn(params, x, rope, cfg.attn, chunk=cfg.attn_chunk, **kw)


def _init_layer(key, cfg: LMConfig, use_moe: bool):
    k1, k2 = jax.random.split(key)
    layer = {
        "ln1": rms_norm_init(cfg.d_model, cfg.dtype),
        "attn": _init_attn(k1, cfg),
        "ln2": rms_norm_init(cfg.d_model, cfg.dtype),
    }
    if use_moe:
        layer["moe"] = init_moe(k2, cfg.d_model, cfg.moe, cfg.dtype)
    else:
        layer["ffn"] = swiglu_init(k2, cfg.d_model, cfg.d_ff, cfg.dtype)
    return layer


def init_lm(key, cfg: LMConfig):
    kd, km, ke, kh, kt = jax.random.split(key, 5)
    params = {
        "embed": dense_init(ke, cfg.vocab, cfg.d_model, cfg.dtype, scale=1.0),
        "final_norm": rms_norm_init(cfg.d_model, cfg.dtype),
        "lm_head": dense_init(kh, cfg.d_model, cfg.vocab, cfg.dtype),
    }
    nd, nm = cfg.n_dense_total, cfg.n_moe_layers
    if nd:
        params["dense"] = jax.vmap(lambda k: _init_layer(k, cfg, False))(
            jax.random.split(kd, nd)
        )
    if nm:
        params["moe"] = jax.vmap(lambda k: _init_layer(k, cfg, True))(
            jax.random.split(km, nm)
        )
    if cfg.mtp:
        k1, k2, k3 = jax.random.split(kt, 3)
        params["mtp"] = {
            "proj": dense_init(k1, 2 * cfg.d_model, cfg.d_model, cfg.dtype),
            "ln_h": rms_norm_init(cfg.d_model, cfg.dtype),
            "ln_e": rms_norm_init(cfg.d_model, cfg.dtype),
            "block": _init_layer(k3, cfg, False),
            "final_norm": rms_norm_init(cfg.d_model, cfg.dtype),
        }
        del k2
    return params


# ------------------------------------------------------------------ forward


def _layer_apply(layer, x, rope, cfg: LMConfig, positions, use_moe, shard_ctx):
    h, _ = _attn(layer["attn"], rms_norm(x, layer["ln1"]), rope, cfg, positions=positions)
    x = x + h
    z = rms_norm(x, layer["ln2"])
    if use_moe:
        B, S, d = z.shape
        y, aux = moe_ffn(layer["moe"], z.reshape(B * S, d), cfg.moe, shard_ctx)
        return x + y.reshape(B, S, d), aux
    return x + swiglu(layer["ffn"], z), jnp.zeros((), jnp.float32)


def _scan_segment(params_seg, x, rope, cfg, positions, use_moe, shard_ctx):
    def body(carry, layer):
        x, aux = carry
        x, a = _layer_apply(layer, x, rope, cfg, positions, use_moe, shard_ctx)
        return (x, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params_seg)
    return x, aux


def lm_forward(params, tokens: jnp.ndarray, cfg: LMConfig, shard_ctx=None):
    """tokens [B, S] -> (hidden [B, S, d], aux_loss). Logits are computed by
    the loss (chunked) or by the caller via lm_head."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    rope = rope_freqs(
        cfg.attn.qk_rope_head_dim if _is_mla(cfg) else cfg.attn.head_dim,
        max(S, cfg.max_seq),
        cfg.attn.rope_theta,
    )
    positions = jnp.arange(S)
    aux = jnp.zeros((), jnp.float32)
    if "dense" in params:
        x, a = _scan_segment(params["dense"], x, rope, cfg, positions, False, shard_ctx)
        aux += a
    if "moe" in params:
        x, a = _scan_segment(params["moe"], x, rope, cfg, positions, True, shard_ctx)
        aux += a
    return rms_norm(x, params["final_norm"]), aux


def _chunked_xent(h2d, head, labels, chunk: int):
    """Mean CE over T tokens without materializing [T, V] logits."""
    T, d = h2d.shape
    if T % chunk != 0:
        chunk = T
    nc = T // chunk
    hr = h2d.reshape(nc, chunk, d)
    lr = labels.reshape(nc, chunk)

    def body(tot, xs):
        hc, lc = xs
        logits = jnp.dot(hc, head, preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[:, None], axis=-1)[:, 0]
        return tot + jnp.sum(lse - gold), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hr, lr))
    return tot / T


def lm_loss(params, tokens: jnp.ndarray, cfg: LMConfig, shard_ctx=None):
    """Next-token CE (+ MoE aux + MTP aux). tokens [B, S] int32."""
    B, S = tokens.shape
    h, aux = lm_forward(params, tokens, cfg, shard_ctx)
    h_pred = h[:, :-1].reshape(B * (S - 1), cfg.d_model)
    labels = tokens[:, 1:].reshape(B * (S - 1))
    loss = _chunked_xent(h_pred, params["lm_head"], labels, cfg.loss_chunk)

    if cfg.mtp and "mtp" in params:
        # Predict token t+2 from (h_t, embed(token_{t+1})) through one block.
        m = params["mtp"]
        h_in = rms_norm(h[:, :-1], m["ln_h"])
        e_in = rms_norm(params["embed"][tokens[:, 1:]], m["ln_e"])
        z = jnp.dot(jnp.concatenate([h_in, e_in], -1), m["proj"])
        rope = rope_freqs(
            cfg.attn.qk_rope_head_dim if _is_mla(cfg) else cfg.attn.head_dim,
            max(S, cfg.max_seq),
            cfg.attn.rope_theta,
        )
        z, _ = _layer_apply(
            m["block"], z, rope, cfg, jnp.arange(S - 1), False, shard_ctx
        )
        z = rms_norm(z, m["final_norm"])
        z_pred = z[:, :-1].reshape(B * (S - 2), cfg.d_model)
        mtp_labels = tokens[:, 2:].reshape(B * (S - 2))
        loss = loss + cfg.mtp_weight * _chunked_xent(
            z_pred, params["lm_head"], mtp_labels, cfg.loss_chunk
        )

    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux
    return loss


# ------------------------------------------------------------------- decode


def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    """Stacked per-segment KV caches (ShapeDtypeStruct-compatible)."""
    dtype = dtype or cfg.dtype
    out = {}

    def one(n_layers):
        if _is_mla(cfg):
            a = cfg.attn
            return {
                "c_kv": jnp.zeros((n_layers, batch, max_len, a.kv_lora_rank), dtype),
                "k_rope": jnp.zeros(
                    (n_layers, batch, max_len, a.qk_rope_head_dim), dtype
                ),
            }
        a = cfg.attn
        return {
            "k": jnp.zeros((n_layers, batch, max_len, a.n_kv_heads, a.head_dim), dtype),
            "v": jnp.zeros((n_layers, batch, max_len, a.n_kv_heads, a.head_dim), dtype),
        }

    if cfg.n_dense_total:
        out["dense"] = one(cfg.n_dense_total)
    if cfg.n_moe_layers:
        out["moe"] = one(cfg.n_moe_layers)
    return out


def _decode_segment(params_seg, cache_seg, x, rope, cfg, pos, use_moe, shard_ctx,
                    decode_impl: str = "batch"):
    from repro.models.attention import (
        gqa_decode_splitkv,
        gqa_prefill_splitkv,
        mla_decode_splitkv,
    )
    from repro.models.moe import moe_ffn_decode_ep_all

    def body(x, inp):
        layer, cache_layer = inp
        z1 = rms_norm(x, layer["ln1"])
        S_new = x.shape[1]
        if decode_impl == "split_kv" and shard_ctx is not None and (
            S_new == 1 or not _is_mla(cfg)
        ):
            if S_new == 1:
                fn = mla_decode_splitkv if _is_mla(cfg) else gqa_decode_splitkv
                h, new_cache = fn(
                    layer["attn"], z1, rope, cfg.attn, cache_layer, pos,
                    shard_ctx,
                )
            else:
                # Seq-parallel prefill chunk (chunk size == per-rank slice).
                h, new_cache = gqa_prefill_splitkv(
                    layer["attn"], z1, rope, cfg.attn, cache_layer,
                    pos // S_new, shard_ctx,
                )
        else:
            h, new_cache = _attn(
                layer["attn"], z1, rope, cfg,
                positions=pos + jnp.arange(x.shape[1]),
                cache=cache_layer, cache_pos=pos,
            )
        x = x + h
        z = rms_norm(x, layer["ln2"])
        if use_moe:
            B, S, d = z.shape
            if decode_impl == "split_kv" and shard_ctx is not None:
                y, _ = moe_ffn_decode_ep_all(
                    layer["moe"], z.reshape(B * S, d), cfg.moe, shard_ctx
                )
            else:
                y, _ = moe_ffn(layer["moe"], z.reshape(B * S, d), cfg.moe, shard_ctx)
            x = x + y.reshape(B, S, d)
        else:
            x = x + swiglu(layer["ffn"], z)
        return x, new_cache

    x, new_cache = jax.lax.scan(body, x, (params_seg, cache_seg))
    return x, new_cache


def lm_decode_step(
    params, tokens, cache, pos, cfg: LMConfig, shard_ctx=None,
    logits_last_only: bool = False, decode_impl: str = "batch",
):
    """One decode (or prefill) step. tokens [B, S_new]; pos = cache fill.

    decode_impl: "batch" (cache sharded over batch — baseline) or
    "split_kv" (cache sharded batch x seq with partial-softmax merge +
    absorbed MLA + full-grid MoE EP — the §Perf decode variant).
    Returns (logits [B, S_new, V] — or [B, 1, V] with logits_last_only, the
    prefill contract — and the updated cache).
    """
    B, S = tokens.shape
    x = params["embed"][tokens]
    rope = rope_freqs(
        cfg.attn.qk_rope_head_dim if _is_mla(cfg) else cfg.attn.head_dim,
        cfg.max_seq,
        cfg.attn.rope_theta,
    )
    new_cache = {}
    if "dense" in params:
        x, new_cache["dense"] = _decode_segment(
            params["dense"], cache["dense"], x, rope, cfg, pos, False, shard_ctx,
            decode_impl,
        )
    if "moe" in params:
        x, new_cache["moe"] = _decode_segment(
            params["moe"], cache["moe"], x, rope, cfg, pos, True, shard_ctx,
            decode_impl,
        )
    h = rms_norm(x, params["final_norm"])
    if logits_last_only:
        h = h[:, -1:]
    logits = jnp.dot(h, params["lm_head"], preferred_element_type=jnp.float32)
    return logits, new_cache


# ------------------------------------------------------------------- specs


def _attn_specs(cfg: LMConfig, m: str):
    if _is_mla(cfg):
        return {
            "w_dq": P(),
            "q_norm": P(),
            "w_uq": P(None, m),
            "w_dkv": P(),
            "kv_norm": P(),
            "w_uk": P(None, m),
            "w_uv": P(None, m),
            "wo": P(m, None),
        }
    a = cfg.attn
    kv_shardable = a.n_kv_heads * a.head_dim % 16 == 0 and a.n_kv_heads >= 16
    kv = P(None, m) if kv_shardable else P()
    s = {
        "wq": P(None, m),
        "wk": kv,
        "wv": kv,
        "wo": P(m, None),
    }
    if a.qkv_bias:
        s["bq"] = P(m)
        s["bk"] = P(m) if kv_shardable else P()
        s["bv"] = P(m) if kv_shardable else P()
    if a.qk_norm:
        s["q_norm"] = P()
        s["k_norm"] = P()
    return s


def _layer_specs(cfg: LMConfig, use_moe: bool, m: str):
    def stack(spec: P) -> P:
        return P(None, *spec)  # leading layer-stack dim

    attn = jax.tree.map(
        stack, _attn_specs(cfg, m), is_leaf=lambda x: isinstance(x, P)
    )
    layer = {"ln1": P(None), "attn": attn, "ln2": P(None)}
    if use_moe:
        moe = {
            "router": P(None),
            "w_gate": P(None, m, None, None),
            "w_up": P(None, m, None, None),
            "w_down": P(None, m, None, None),
        }
        if cfg.moe.n_shared:
            moe["shared"] = {
                "w_gate": P(None, None, m),
                "w_up": P(None, None, m),
                "w_down": P(None, m, None),
            }
        layer["moe"] = moe
    else:
        layer["ffn"] = {
            "w_gate": P(None, None, m),
            "w_up": P(None, None, m),
            "w_down": P(None, m, None),
        }
    return layer


def param_specs(cfg: LMConfig, model_axis: str = "model"):
    """PartitionSpec pytree matching init_lm's structure (TP over model)."""
    m = model_axis
    specs = {
        "embed": P(None, None),
        "final_norm": P(None),
        "lm_head": P(None, m),  # vocab-sharded output projection
    }
    if cfg.n_dense_total:
        specs["dense"] = _layer_specs(cfg, False, m)
    if cfg.n_moe_layers:
        specs["moe"] = _layer_specs(cfg, True, m)
    if cfg.mtp:
        block = _layer_specs(cfg, False, m)
        block = jax.tree.map(
            lambda s: P(*s[1:]), block, is_leaf=lambda x: isinstance(x, P)
        )  # un-stack (single layer)
        specs["mtp"] = {
            "proj": P(),
            "ln_h": P(None),
            "ln_e": P(None),
            "block": block,
            "final_norm": P(None),
        }
    return specs


def cache_specs(cfg: LMConfig, data_axes, layout: str = "batch") -> dict:
    """KV cache shardings. layout="batch": batch-only (baseline);
    layout="split": batch over data x sequence over model (split-KV)."""
    seq = "model" if layout == "split" else None
    if _is_mla(cfg):
        seg = {
            "c_kv": P(None, data_axes, seq, None),
            "k_rope": P(None, data_axes, seq, None),
        }
    else:
        seg = {
            "k": P(None, data_axes, seq, None, None),
            "v": P(None, data_axes, seq, None, None),
        }
    out = {}
    if cfg.n_dense_total:
        out["dense"] = seg
    if cfg.n_moe_layers:
        out["moe"] = seg
    return out


def param_specs_splitkv(cfg: LMConfig, model_axis: str = "model",
                        ep_grid_ok: bool = True):
    """Param shardings for the split-KV decode variant (§Perf cell A).

    Attention projections are row-sharded on d_model (matching the
    shard_map in gqa/mla_decode_splitkv); MLA w_uk/w_uv replicated
    (absorbed-path operands); MoE experts sharded over the full
    (data, model) grid when divisible; everything else as in training.
    """
    m = model_axis
    specs = param_specs(cfg, m)

    def attn_split():
        if _is_mla(cfg):
            return {
                "w_dq": P(None, m, None), "q_norm": P(None),
                "w_uq": P(None, m, None), "w_dkv": P(None, m, None),
                "kv_norm": P(None), "w_uk": P(None), "w_uv": P(None),
                "wo": P(None, m, None),
            }
        a = cfg.attn
        s = {
            "wq": P(None, m, None), "wk": P(None, m, None),
            "wv": P(None, m, None), "wo": P(None, m, None),
        }
        if a.qkv_bias:
            s.update({"bq": P(None), "bk": P(None), "bv": P(None)})
        if a.qk_norm:
            s.update({"q_norm": P(None), "k_norm": P(None)})
        return s

    for seg in ("dense", "moe"):
        if seg in specs:
            specs[seg]["attn"] = attn_split()
    if "moe" in specs and cfg.moe is not None and ep_grid_ok:
        # Full-grid EP when experts cover data x model (deepseek-v3: 256/256).
        specs["moe"]["moe"]["w_gate"] = P(None, ("data", m), None, None)
        specs["moe"]["moe"]["w_up"] = P(None, ("data", m), None, None)
        specs["moe"]["moe"]["w_down"] = P(None, ("data", m), None, None)
    return specs


def count_params(cfg: LMConfig) -> tuple[int, int]:
    """(total, active-per-token) parameter counts — for MODEL_FLOPS."""
    a = cfg.attn
    if _is_mla(cfg):
        qk_head = a.qk_nope_head_dim + a.qk_rope_head_dim
        attn = (
            cfg.d_model * a.q_lora_rank
            + a.q_lora_rank * a.n_heads * qk_head
            + cfg.d_model * (a.kv_lora_rank + a.qk_rope_head_dim)
            + a.kv_lora_rank * a.n_heads * (a.qk_nope_head_dim + a.v_head_dim)
            + a.n_heads * a.v_head_dim * cfg.d_model
        )
    else:
        attn = cfg.d_model * a.head_dim * (a.n_heads * 2 + a.n_kv_heads * 2)
    dense_ffn = 3 * cfg.d_model * cfg.d_ff
    embed = 2 * cfg.vocab * cfg.d_model
    total = embed + cfg.n_dense_total * (attn + dense_ffn)
    active = embed + cfg.n_dense_total * (attn + dense_ffn)
    if cfg.moe:
        moe_ffn_p = 3 * cfg.d_model * cfg.moe.d_ff
        shared = cfg.moe.n_shared * moe_ffn_p
        router = cfg.d_model * cfg.moe.n_experts
        total += cfg.n_moe_layers * (
            attn + moe_ffn_p * cfg.moe.n_experts + shared + router
        )
        active += cfg.n_moe_layers * (
            attn + moe_ffn_p * cfg.moe.top_k + shared + router
        )
    return int(total), int(active)
