"""Observability substrate: metrics, per-query traces, exposition.

``repro.obs`` deliberately imports nothing from the rest of ``repro`` —
any layer (core engine through control plane) can depend on it without
cycles. The one object most callers need is ``Instrumentation`` (or the
shared ``NOOP`` default); see DESIGN.md §13.
"""

from repro.obs.clock import DEFAULT_CLOCK, FakeClock
from repro.obs.export import json_snapshot, prometheus_text
from repro.obs.instrument import NOOP, Instrumentation, NoopInstrumentation
from repro.obs.metrics import (
    N_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.report import render, summarize
from repro.obs.trace import QueryTrace, Tracer, TraceSink, read_traces

__all__ = [
    "DEFAULT_CLOCK",
    "FakeClock",
    "Instrumentation",
    "NoopInstrumentation",
    "NOOP",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "N_BUCKETS",
    "Tracer",
    "TraceSink",
    "QueryTrace",
    "read_traces",
    "prometheus_text",
    "json_snapshot",
    "summarize",
    "render",
]
