"""Observability substrate + operations layer: metrics, traces, SLOs.

``repro.obs`` deliberately imports nothing from the rest of ``repro`` —
any layer (core engine through control plane) can depend on it without
cycles. The one object most callers need is ``Instrumentation`` (or the
shared ``NOOP`` default); see DESIGN.md §13. On top of the substrate sit
the operational components of DESIGN.md §14: the dispatch ``Profiler``,
declarative SLOs with burn-rate tracking (``slo``), online drift
detection (``detect``), and the ``watch``/``slo`` CLIs.
"""

from repro.obs.catalog import METRIC_HELP, help_for
from repro.obs.clock import DEFAULT_CLOCK, FakeClock
from repro.obs.detect import (
    AlertEvent,
    DriftMonitor,
    EwmaDetector,
    ShardSkewProbe,
    ThresholdDetector,
)
from repro.obs.export import json_snapshot, prometheus_text, write_snapshot
from repro.obs.instrument import NOOP, Instrumentation, NoopInstrumentation
from repro.obs.metrics import (
    N_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.profiler import Profiler, jit_cache_size
from repro.obs.report import render, summarize
from repro.obs.slo import (
    CounterRatio,
    GaugeTime,
    HistogramBelow,
    SloSpec,
    SloTracker,
    default_serving_slos,
)
from repro.obs.trace import QueryTrace, Tracer, TraceSink, read_traces

__all__ = [
    "DEFAULT_CLOCK",
    "FakeClock",
    "Instrumentation",
    "NoopInstrumentation",
    "NOOP",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "N_BUCKETS",
    "METRIC_HELP",
    "help_for",
    "Tracer",
    "TraceSink",
    "QueryTrace",
    "read_traces",
    "prometheus_text",
    "json_snapshot",
    "write_snapshot",
    "summarize",
    "render",
    "Profiler",
    "jit_cache_size",
    "SloSpec",
    "SloTracker",
    "HistogramBelow",
    "CounterRatio",
    "GaugeTime",
    "default_serving_slos",
    "AlertEvent",
    "EwmaDetector",
    "ThresholdDetector",
    "ShardSkewProbe",
    "DriftMonitor",
]
