"""CLI: ``python -m repro.obs {report,slo,watch} ...`` (DESIGN.md §13-§14).

* ``report <trace.jsonl>`` — latency/exit/fidelity summary of a trace;
* ``slo <trace.jsonl>`` — offline SLO burn-rate report over the same
  trace (windowed attainment, error budgets, alert-state per SLO);
* ``watch <snapshot.json>`` — live terminal dashboard over a snapshot
  file a serving process writes via ``export.write_snapshot``
  (``--once`` renders a single frame, for CI smokes).
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs.report import render, summarize
from repro.obs.slo import DEFAULT_WINDOWS, render_slo, replay_trace
from repro.obs.trace import read_traces
from repro.obs.watch import watch_loop


def _parse_windows(spec: str) -> dict[str, float]:
    out: dict[str, float] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            name, sec = part.split("=", 1)
            out[name.strip()] = float(sec)
        else:
            out[f"{part}s"] = float(part)
    if not out:
        raise argparse.ArgumentTypeError(f"no windows in {spec!r}")
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("report", help="summarize a JSONL query trace")
    rp.add_argument("trace", help="path to a TraceSink JSONL file")
    rp.add_argument(
        "--sla-ms",
        type=float,
        default=None,
        help="override the per-record SLA for compliance accounting",
    )
    rp.add_argument(
        "--json", action="store_true", help="emit the summary as JSON"
    )

    sp = sub.add_parser(
        "slo", help="SLO burn-rate report over a JSONL query trace"
    )
    sp.add_argument("trace", help="path to a TraceSink JSONL file")
    sp.add_argument(
        "--sla-ms",
        type=float,
        default=None,
        help="latency SLO threshold (default: max recorded sla_ms attr)",
    )
    sp.add_argument(
        "--fidelity-ceiling",
        type=float,
        default=None,
        help="fidelity-bound SLO ceiling (default: max recorded bound)",
    )
    sp.add_argument(
        "--windows",
        type=_parse_windows,
        default=None,
        metavar="NAME=SECONDS,...",
        help=f"burn windows (default {','.join(f'{k}={int(v)}' for k, v in DEFAULT_WINDOWS.items())})",
    )
    sp.add_argument(
        "--json", action="store_true", help="emit the report as JSON"
    )

    wp = sub.add_parser(
        "watch", help="terminal dashboard over a metrics snapshot file"
    )
    wp.add_argument(
        "snapshot", help="path written by repro.obs.export.write_snapshot"
    )
    wp.add_argument(
        "--interval", type=float, default=2.0, help="refresh period seconds"
    )
    wp.add_argument(
        "--once",
        action="store_true",
        help="render one frame and exit (nonzero if the file is unreadable)",
    )

    args = ap.parse_args(argv)

    if args.cmd == "watch":
        return watch_loop(args.snapshot, interval=args.interval, once=args.once)

    records = read_traces(args.trace)
    if not records:
        print(f"{args.trace}: no trace records", file=sys.stderr)
        return 1
    if args.cmd == "report":
        summary = summarize(records, sla_ms=args.sla_ms)
        print(
            json.dumps(summary, indent=2, sort_keys=True)
            if args.json
            else render(summary)
        )
        return 0
    report = replay_trace(
        records,
        sla_ms=args.sla_ms,
        fidelity_ceiling=args.fidelity_ceiling,
        windows=args.windows,
    )
    print(
        json.dumps(report, indent=2, sort_keys=True)
        if args.json
        else render_slo(report)
    )
    return 0


if __name__ == "__main__":
    try:
        raise SystemExit(main())
    except BrokenPipeError:  # e.g. `... report t.jsonl | head`
        raise SystemExit(0)
