"""CLI: ``python -m repro.obs report <trace.jsonl> [--sla-ms X] [--json]``."""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs.report import render, summarize
from repro.obs.trace import read_traces


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rp = sub.add_parser("report", help="summarize a JSONL query trace")
    rp.add_argument("trace", help="path to a TraceSink JSONL file")
    rp.add_argument(
        "--sla-ms",
        type=float,
        default=None,
        help="override the per-record SLA for compliance accounting",
    )
    rp.add_argument(
        "--json", action="store_true", help="emit the summary as JSON"
    )
    args = ap.parse_args(argv)

    records = read_traces(args.trace)
    if not records:
        print(f"{args.trace}: no trace records", file=sys.stderr)
        return 1
    summary = summarize(records, sla_ms=args.sla_ms)
    if args.json:
        print(json.dumps(summary, indent=2, sort_keys=True))
    else:
        print(render(summary))
    return 0


if __name__ == "__main__":
    try:
        raise SystemExit(main())
    except BrokenPipeError:  # e.g. `... report t.jsonl | head`
        raise SystemExit(0)
