"""Canonical help strings for every instrumented metric (DESIGN.md §13-§14).

``Instrumentation.count/gauge/observe`` look names up here so the Prometheus
exposition carries a real ``# HELP`` line for every metric the serving and
control layers emit. Keeping the catalog in one module (instead of help
kwargs scattered over call sites) makes "no metric without help" a single
registry-wide test (``tests/test_obs.py``) rather than a per-call-site
convention.

A name missing from the catalog still registers (with empty help) — the
test, not the runtime, is the enforcement point.
"""

from __future__ import annotations

METRIC_HELP: dict[str, str] = {
    # ------------------------------------------------------- engine / batch
    "engine_queries": "Queries traversed by the host Engine, by exit reason.",
    "engine_postings": "Postings scored per host Engine traversal.",
    "batch_engine_chunk_lanes": "Live lanes per padded BatchEngine chunk.",
    "batch_engine_queries": "Queries served by BatchEngine, by exit reason.",
    # ------------------------------------------------------------ budgeter
    "budgeter_alpha": "Reactive SLA policy alpha (Eq. 7 feedback state).",
    "budgeter_cap_postings": "Latest postings budget cap issued per query.",
    "budgeter_feedback_ms": "Batch latencies fed back into the SLA policy.",
    "budgeter_rate": "EWMA postings/ms service rate (JASS time proxy).",
    "budgeter_shard_cap": "Per-shard postings budget cap, by shard.",
    "budgeter_shard_rate": "Per-shard EWMA postings/ms rate, by shard.",
    # ------------------------------------------------------------- servers
    "submitted": "Queries submitted to a server, by server label.",
    "admissions": "Queries admitted into in-flight slots.",
    "parks": "Queries parked while all in-flight slots were busy.",
    "budget_postings": "Finite admission postings budgets (sentinel-free).",
    "unlimited_admissions": "Admissions with an unlimited (inf-SLA) budget.",
    "batch_size": "Queries per drained micro-batch.",
    "batch_ms": "Wall-clock per micro-batch dispatch.",
    "step_ms": "Wall-clock per in-flight quantum step.",
    "active_lanes": "Live lanes per in-flight step.",
    "slot_occupancy": "Occupied in-flight slots after the latest step.",
    "queue_depth": "Queries waiting in the server queue, by server.",
    "served_queries": "Completed queries, by server and exit reason.",
    "latency_ms": "End-to-end query latency (submit to serve), by server.",
    "quanta": "Resume quanta a query lived through before completing.",
    # ------------------------------------------------------------- sharded
    "sharded_queries": "Queries served through the sharded broker.",
    "shard_exits": "Per-shard exit reasons across sharded queries.",
    "sharded_exact": "Sharded results by exactness certificate (§9).",
    "fidelity_bound": "Score-gap fidelity bounds on inexact results.",
    # ------------------------------------------------------- control plane
    "replica_dispatches": "Batches dispatched to a replica group.",
    "replica_pad_lanes": "Padding lanes added to fill a replica dispatch.",
    "health_transitions": "HealthLedger up/down transitions, by shard.",
    "reshard_started": "Online reshard tasks opened.",
    "reshard_cutovers": "Reshard cutovers committed onto the plane.",
    "reshard_ms": "Wall-clock from reshard start to cutover.",
    "shard_postings": "Postings scored per shard (control-plane observed).",
    "plane_available": "1 when every shard is up, else 0 (HealthLedger).",
    "plane_degraded_slo": "1 while a sustained SLO burn alert is firing.",
    # ------------------------------------------------------------ profiler
    "profiler_dispatches": "Profiled device dispatches, by site.",
    "profiler_compiles": "Dispatches that grew the jit cache on a new shape.",
    "profiler_recompiles": "Anomalies: jit cache grew on an already-seen "
    "shape.",
    "profiler_plan_ms": "Host planning/staging time per dispatch, by site.",
    "profiler_dispatch_ms": "Host time to issue the device step (includes "
    "tracing when a compile happens).",
    "profiler_device_ms": "Device execution wait per dispatch "
    "(block_until_ready).",
    "profiler_transfer_ms": "Device-to-host result transfer per dispatch.",
    "hbm_bytes": "Live HBM bytes per device index array, by site and array.",
    "hbm_total_bytes": "Total live HBM bytes of the device index, by site.",
    # ----------------------------------------------------------------- slo
    "slo_attainment": "Windowed good/total attainment per SLO (3d window).",
    "slo_burn_rate": "Error-budget burn rate per SLO and window.",
    "slo_error_budget_remaining": "Fraction of the error budget left in the "
    "longest window.",
    "slo_state": "SLO alert state: 0 ok, 1 slow burn, 2 fast burn.",
    # -------------------------------------------------------------- detect
    "alerts": "Drift-detector alert events, by detector and state.",
}


def help_for(name: str) -> str:
    """Catalog lookup; empty string for uncataloged names."""
    return METRIC_HELP.get(name, "")
