"""Injectable clocks for serving, budgeting, and telemetry (DESIGN.md §13).

Every latency the stack reports — queue wait, device step time, Eq. (7)
SLA feedback, trace span timestamps — must come from *one* clock, or the
numbers stop composing: a trace whose spans are stamped by a different
clock than the budgeter's feedback loop cannot explain why alpha moved.
``MicroBatchServer``, ``InflightServer``, the budgeters, and
``Instrumentation`` all accept a ``clock`` callable (seconds, monotonic);
the default is ``time.perf_counter`` everywhere.

``FakeClock`` is the deterministic test double the suites share: each
reading advances a fixed ``dt``, so SLA/queueing assertions do not depend
on container timing noise. It lives here (not copy-pasted per test module)
so library code and tests provably read the same clock type.
"""

from __future__ import annotations

import time

__all__ = ["DEFAULT_CLOCK", "FakeClock"]

DEFAULT_CLOCK = time.perf_counter


class FakeClock:
    """Deterministic clock: every reading advances time by ``dt`` seconds.

    ``clock()`` semantics match ``time.perf_counter``: monotonically
    increasing floats in seconds. ``advance()`` jumps the clock without a
    reading, for tests that model idle wall time.
    """

    def __init__(self, dt: float = 0.0, start: float = 0.0):
        self.t = float(start)
        self.dt = float(dt)

    def __call__(self) -> float:
        self.t += self.dt
        return self.t

    def advance(self, seconds: float) -> None:
        self.t += float(seconds)
