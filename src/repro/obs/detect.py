"""Online drift/anomaly detection over the live metrics registry (§14).

The control plane's operational questions — "is one shard hot", "is the
queue building", "did the exit-reason mix shift", "is service time
drifting" — are all answerable from the registry the serving layers
already populate. This module closes the loop: lightweight online
detectors poll derived signals and emit structured **alert events** into
the same ``TraceSink`` JSONL stream the query traces use, plus a
subscription hook the ``ControlPlane`` registers to (a sustained
per-shard skew alert arms ``maybe_reshard``; a sustained burn-rate alert
marks the plane's degraded-SLO state).

Two detector kinds, both with fire/clear hysteresis (``patience``
consecutive anomalous samples to fire, ``clear_patience`` normal samples
to clear) so a single noisy poll can neither page nor silence:

  * :class:`EwmaDetector` — exponentially-weighted mean + variance,
    firing on ``|z| >= z_fire``. Adaptation freezes on anomalous samples
    (the baseline must not chase the anomaly it is reporting) and a
    relative/absolute std floor keeps z finite on constant baselines.
  * :class:`ThresholdDetector` — plain level threshold with the same
    hysteresis, for signals that are already ratios (shard skew, burn
    rate) where "normal" has a known scale.

:class:`DriftMonitor` owns the detectors, pairs each with a *probe*
(callable ``registry -> float | None``; ``None`` = no data this poll) and
fans alert events out to the sink, an ``alerts`` counter, and subscribers.
Probes for the standard signals (histogram p99, gauge level, counter
rates, exit-reason share, per-shard postings skew) are provided below;
rate-style probes keep last-poll state internally, so one probe instance
belongs to one monitor. Everything is driven by the injected clock —
deterministic under ``FakeClock``.
"""

from __future__ import annotations

import math

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry

__all__ = [
    "AlertEvent",
    "EwmaDetector",
    "ThresholdDetector",
    "DriftMonitor",
    "hist_percentile_probe",
    "gauge_probe",
    "counter_rate_probe",
    "counter_share_probe",
    "ShardSkewProbe",
    "default_serving_detectors",
]


class AlertEvent:
    """One fire/clear transition, JSONL-serializable (``kind="alert"``)."""

    __slots__ = ("detector", "state", "value", "zscore", "mean", "t", "labels")

    def __init__(
        self,
        detector: str,
        state: str,
        value: float,
        t: float,
        zscore: float | None = None,
        mean: float | None = None,
        labels: dict | None = None,
    ):
        self.detector = detector
        self.state = state  # "fire" | "clear"
        self.value = value
        self.zscore = zscore
        self.mean = mean
        self.t = t
        self.labels = labels or {}

    def to_dict(self) -> dict:
        out = {
            "kind": "alert",
            "detector": self.detector,
            "state": self.state,
            "value": round(float(self.value), 6),
            "t_ms": round(self.t * 1e3, 4),
        }
        if self.zscore is not None:
            out["zscore"] = round(float(self.zscore), 4)
        if self.mean is not None:
            out["mean"] = round(float(self.mean), 6)
        if self.labels:
            out.update(self.labels)
        return out


class _Hysteresis:
    """Shared fire/clear streak logic."""

    def __init__(self, name: str, patience: int, clear_patience: int):
        self.name = name
        self.patience = max(1, int(patience))
        self.clear_patience = max(1, int(clear_patience))
        self.firing = False
        self._hot = 0
        self._cool = 0

    def _step(self, anomalous: bool) -> str | None:
        """Returns "fire"/"clear" on a state transition, else None."""
        if anomalous:
            self._hot += 1
            self._cool = 0
            if not self.firing and self._hot >= self.patience:
                self.firing = True
                return "fire"
        else:
            self._cool += 1
            self._hot = 0
            if self.firing and self._cool >= self.clear_patience:
                self.firing = False
                return "clear"
        return None


class EwmaDetector(_Hysteresis):
    """EWMA mean/variance z-score detector with frozen-baseline hysteresis."""

    def __init__(
        self,
        name: str,
        alpha: float = 0.1,
        z_fire: float = 4.0,
        patience: int = 3,
        clear_patience: int = 3,
        min_samples: int = 8,
        direction: str = "both",  # "both" | "above" | "below"
        rel_floor: float = 0.05,
        abs_floor: float = 1e-9,
    ):
        super().__init__(name, patience, clear_patience)
        self.alpha = alpha
        self.z_fire = z_fire
        self.min_samples = max(1, int(min_samples))
        self.direction = direction
        self.rel_floor = rel_floor
        self.abs_floor = abs_floor
        self._n = 0
        self.mean = 0.0
        self._var = 0.0

    def _z(self, value: float) -> float:
        std = math.sqrt(max(self._var, 0.0))
        floor = max(abs(self.mean) * self.rel_floor, self.abs_floor)
        return (value - self.mean) / max(std, floor)

    def update(self, value: float, now: float) -> AlertEvent | None:
        value = float(value)
        if self._n < self.min_samples:
            # Warm-up: adopt the sample into the baseline, never alert.
            self._absorb(value)
            return None
        z = self._z(value)
        if self.direction == "above":
            anomalous = z >= self.z_fire
        elif self.direction == "below":
            anomalous = -z >= self.z_fire
        else:
            anomalous = abs(z) >= self.z_fire
        if not anomalous:
            self._absorb(value)  # freeze baseline while anomalous
        transition = self._step(anomalous)
        if transition is None:
            return None
        return AlertEvent(
            self.name, transition, value, now, zscore=z, mean=self.mean
        )

    def _absorb(self, value: float) -> None:
        if self._n == 0:
            self.mean = value
        else:
            d = value - self.mean
            self.mean += self.alpha * d
            self._var = (1 - self.alpha) * (self._var + self.alpha * d * d)
        self._n += 1


class ThresholdDetector(_Hysteresis):
    """Level threshold with hysteresis, for ratio-scaled signals."""

    def __init__(
        self,
        name: str,
        threshold: float,
        patience: int = 3,
        clear_patience: int = 3,
        direction: str = "above",
    ):
        super().__init__(name, patience, clear_patience)
        self.threshold = float(threshold)
        self.direction = direction

    def update(self, value: float, now: float) -> AlertEvent | None:
        value = float(value)
        if self.direction == "above":
            anomalous = value >= self.threshold
        else:
            anomalous = value <= self.threshold
        transition = self._step(anomalous)
        if transition is None:
            return None
        return AlertEvent(self.name, transition, value, now)


# --------------------------------------------------------------------------
# Probes: registry -> signal value (None = no data this poll)
# --------------------------------------------------------------------------


def hist_percentile_probe(metric: str, p: float = 99.0, **labels):
    def probe(registry: MetricsRegistry):
        m = registry.metrics().get(metric)
        if not isinstance(m, Histogram) or m.count(**labels) == 0:
            return None
        return m.percentile(p, **labels)

    return probe


def gauge_probe(metric: str, **labels):
    def probe(registry: MetricsRegistry):
        m = registry.metrics().get(metric)
        if not isinstance(m, Gauge):
            return None
        key = tuple(sorted((str(k), str(v)) for k, v in labels.items()))
        v = m._samples.get(key)
        return None if v is None else float(v)

    return probe


def counter_rate_probe(metric: str, clock, **labels):
    """Delta of a counter between polls, per second (stateful)."""
    state = {"t": None, "v": None}

    def probe(registry: MetricsRegistry):
        m = registry.metrics().get(metric)
        if not isinstance(m, Counter):
            return None
        now, v = clock(), m.value(**labels)
        t0, v0 = state["t"], state["v"]
        state["t"], state["v"] = now, v
        if t0 is None or now <= t0:
            return None
        return (v - v0) / (now - t0)

    return probe


def counter_share_probe(metric: str, part_labels: dict, **total_labels):
    """Share of a labeled counter subset in the total, over poll deltas.

    Tracks the exit-reason *mix*: e.g. the fraction of queries served with
    ``reason="budget"`` since the last poll. Returns None until the total
    moved.
    """
    state = {"part": None, "total": None}

    def _sum(m: Counter, labels: dict) -> float:
        want = {str(k): str(v) for k, v in labels.items()}
        return float(
            sum(
                v
                for key, v in m._samples.items()
                if all(dict(key).get(k) == w for k, w in want.items())
            )
        )

    def probe(registry: MetricsRegistry):
        m = registry.metrics().get(metric)
        if not isinstance(m, Counter):
            return None
        part = _sum(m, {**total_labels, **part_labels})
        total = _sum(m, total_labels)
        p0, t0 = state["part"], state["total"]
        state["part"], state["total"] = part, total
        if p0 is None or total <= t0:
            return None
        return (part - p0) / (total - t0)

    return probe


class ShardSkewProbe:
    """max/mean per-shard postings rate since the last poll (>= 1.0).

    Reads the control plane's ``shard_postings{shard=...}`` counters;
    returns None until every shard has reported and the deltas are
    nonzero. A balanced plane sits near 1.0; sustained values above the
    reshard trigger mean one shard is eating the workload.
    """

    def __init__(self, n_shards: int, metric: str = "shard_postings"):
        self.n_shards = int(n_shards)
        self.metric = metric
        self._last: list[float] | None = None

    def __call__(self, registry: MetricsRegistry):
        m = registry.metrics().get(self.metric)
        if not isinstance(m, Counter):
            return None
        cur = [m.value(shard=s) for s in range(self.n_shards)]
        last, self._last = self._last, cur
        if last is None:
            return None
        deltas = [max(0.0, c - p) for c, p in zip(cur, last)]
        total = sum(deltas)
        if total <= 0.0:
            return None
        mean = total / self.n_shards
        return max(deltas) / mean


class DriftMonitor:
    """Polls probes, runs detectors, fans out alert events.

    Alerts go three places: the ``alerts`` counter in the registry, the
    TraceSink JSONL stream (interleaved with query traces, tagged
    ``kind="alert"`` — the report/slo CLIs skip them), and every
    ``subscribe``d callback (the ``ControlPlane`` hook). ``poll()`` is
    cheap enough for a per-drain cadence: O(detectors) registry reads,
    no per-query state.
    """

    def __init__(self, obs, sink=None, clock=None):
        self.obs = obs
        self.sink = sink if sink is not None else getattr(
            getattr(obs, "tracer", None), "sink", None
        )
        self.clock = clock if clock is not None else obs.clock
        self._entries: list[tuple] = []  # (detector, probe)
        self._subscribers: list = []
        self.events: list[AlertEvent] = []

    def add(self, detector, probe) -> None:
        self._entries.append((detector, probe))

    def subscribe(self, fn) -> None:
        self._subscribers.append(fn)

    def firing(self) -> list[str]:
        return [d.name for d, _ in self._entries if d.firing]

    def poll(self, now: float | None = None) -> list[AlertEvent]:
        now = self.clock() if now is None else now
        registry = self.obs.metrics
        fired: list[AlertEvent] = []
        for detector, probe in self._entries:
            value = probe(registry)
            if value is None:
                continue
            event = detector.update(value, now)
            if event is not None:
                self._emit(event)
                fired.append(event)
        return fired

    def _emit(self, event: AlertEvent) -> None:
        self.events.append(event)
        self.obs.count("alerts", detector=event.detector, state=event.state)
        if self.sink is not None:
            self.sink.append(event.to_dict())
        for fn in self._subscribers:
            fn(event)


def default_serving_detectors(
    monitor: DriftMonitor,
    n_shards: int | None = None,
    server: str | None = None,
    skew_threshold: float = 2.0,
    burn_threshold: float = 14.4,
) -> DriftMonitor:
    """Wire the standard signal set into ``monitor`` and return it.

    p99 service time and queue depth (EWMA z-score), budget-exit share
    (EWMA on the exit-reason mix), per-shard postings skew and SLO fast
    burn (thresholds). ``server`` narrows the server-labeled signals;
    shard skew needs ``n_shards``.
    """
    labels = {"server": server} if server else {}
    monitor.add(
        EwmaDetector("p99_service_ms", direction="above"),
        hist_percentile_probe(
            "step_ms" if server == "inflight" else "batch_ms", 99.0
        ),
    )
    monitor.add(
        EwmaDetector("queue_depth", direction="above"),
        gauge_probe("queue_depth", **labels),
    )
    monitor.add(
        EwmaDetector("budget_exit_share", direction="above", z_fire=3.0),
        counter_share_probe(
            "served_queries", {"reason": "budget"}, **labels
        ),
    )
    if n_shards and n_shards > 1:
        monitor.add(
            ThresholdDetector("shard_skew", skew_threshold, patience=3),
            ShardSkewProbe(n_shards),
        )
    monitor.add(
        ThresholdDetector("slo_fast_burn", burn_threshold, patience=2),
        gauge_probe("slo_burn_rate", slo="latency_sla", window="5m"),
    )
    return monitor
