"""Exposition: Prometheus text format and a JSON snapshot (DESIGN.md §13).

``prometheus_text`` renders a ``MetricsRegistry`` in the Prometheus
text-based exposition format (version 0.0.4): counters as ``<name>_total``,
gauges verbatim, histograms as cumulative ``<name>_bucket{le="..."}``
series plus ``_sum``/``_count`` — so the process can be scraped by
anything Prometheus-shaped without taking a client-library dependency
(nothing to ``pip install``; the format is ~30 lines of string building).

``json_snapshot`` is the machine-readable sibling the benchmark driver
attaches to ``BENCH_<id>.json`` so the perf trajectory carries internal
counters (exit-reason mix, quanta, occupancy), not just headline q/s.

``write_snapshot`` serializes the registry (plus optional SLO report and
alert tail) to a file atomically — the handoff surface between a serving
process and the ``python -m repro.obs watch`` dashboard, which re-reads
the file at an interval from a separate process.
"""

from __future__ import annotations

import json
import os

from repro.obs.metrics import (
    BUCKET_EDGES,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)

__all__ = ["prometheus_text", "json_snapshot", "write_snapshot"]


def _fmt_labels(key: tuple, extra: tuple = ()) -> str:
    pairs = [f'{k}="{v}"' for k, v in key] + [f'{k}="{v}"' for k, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def _fmt_value(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


def prometheus_text(metrics: MetricsRegistry) -> str:
    """Render every metric in Prometheus text exposition format."""
    lines: list[str] = []
    for name, metric in sorted(metrics.metrics().items()):
        if metric.help:
            lines.append(f"# HELP {name} {metric.help}")
        if isinstance(metric, Counter):
            lines.append(f"# TYPE {name} counter")
            for key in metric._samples:
                lines.append(
                    f"{name}_total{_fmt_labels(key)} "
                    f"{_fmt_value(metric._samples[key])}"
                )
        elif isinstance(metric, Gauge):
            lines.append(f"# TYPE {name} gauge")
            for key in metric._samples:
                lines.append(
                    f"{name}{_fmt_labels(key)} "
                    f"{_fmt_value(metric._samples[key])}"
                )
        elif isinstance(metric, Histogram):
            lines.append(f"# TYPE {name} histogram")
            for key, st in metric._samples.items():
                cum = 0
                for i, n in enumerate(st.buckets):
                    cum += n
                    if n == 0 and BUCKET_EDGES[i] != float("inf"):
                        continue  # sparse: emit touched buckets plus +Inf
                    le = _fmt_value(BUCKET_EDGES[i])
                    lines.append(
                        f"{name}_bucket{_fmt_labels(key, (('le', le),))} {cum}"
                    )
                lines.append(
                    f"{name}_sum{_fmt_labels(key)} {_fmt_value(st.sum)}"
                )
                lines.append(f"{name}_count{_fmt_labels(key)} {st.count}")
    return "\n".join(lines) + ("\n" if lines else "")


def json_snapshot(metrics: MetricsRegistry, indent: int | None = None) -> str:
    """The registry's full state as a JSON document."""
    return json.dumps(metrics.snapshot(), indent=indent, sort_keys=True)


def write_snapshot(
    path: str,
    metrics: MetricsRegistry,
    slo: dict | None = None,
    alerts: list | None = None,
    profiler: dict | None = None,
    t: float | None = None,
) -> None:
    """Atomically write a dashboard snapshot file (tmp + rename).

    The reader (``watch`` CLI) therefore always sees a complete JSON
    document, never a torn write. ``alerts`` is a list of alert-event
    dicts (newest last); ``slo`` is an ``SloTracker.evaluate()`` report;
    ``profiler`` a ``Profiler.snapshot()``.
    """
    doc = {"t": t, "metrics": metrics.snapshot()}
    if slo is not None:
        doc["slo"] = slo
    if alerts is not None:
        doc["alerts"] = list(alerts)
    if profiler is not None:
        doc["profiler"] = profiler
    tmp = f"{path}.tmp"
    with open(tmp, "w") as fh:
        json.dump(doc, fh, sort_keys=True)
    os.replace(tmp, path)
