"""The ``Instrumentation`` handle every serving layer threads through.

One object bundles the three observability substrates — a
``MetricsRegistry``, an optional ``Tracer``, and the injected clock — so a
constructor signature stays one keyword: ``MicroBatchServer(...,
obs=...)``. No globals anywhere: layers receive the handle explicitly and
share time series by sharing the handle (metric registration is
idempotent by name).

The default is ``NOOP``, a shared do-nothing instance whose every method
returns immediately and whose ``enabled`` flag is False — hot loops guard
their per-item instrumentation blocks with ``if obs.enabled`` so an
uninstrumented server pays one attribute read per batch, nothing per
query. The acceptance bar (ISSUE 8) is < 5% q/s overhead with full
instrumentation and *zero* result drift: nothing in this module touches
budgets, plans, or device inputs, so instrumented results are bitwise
identical by construction (pinned in tests/test_obs.py).
"""

from __future__ import annotations

from repro.obs.catalog import help_for
from repro.obs.clock import DEFAULT_CLOCK
from repro.obs.metrics import MetricsRegistry
from repro.obs.profiler import Profiler
from repro.obs.trace import Tracer, TraceSink

__all__ = ["Instrumentation", "NoopInstrumentation", "NOOP"]


class Instrumentation:
    """Live metrics + tracing + clock bundle (+ optional profiler)."""

    enabled = True

    def __init__(
        self,
        metrics: MetricsRegistry | None = None,
        tracer: Tracer | None = None,
        clock=DEFAULT_CLOCK,
        profiler: Profiler | None = None,
    ):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer
        self.clock = clock
        self.profiler = profiler

    @classmethod
    def make(
        cls,
        sample_rate: float = 1.0,
        trace_path: str | None = None,
        ring: int = 1024,
        clock=DEFAULT_CLOCK,
        profile: bool = False,
    ) -> "Instrumentation":
        """Convenience constructor: metrics + a tracer (+ JSONL sink).

        ``profile=True`` attaches a :class:`~repro.obs.profiler.Profiler`;
        the serving dispatch sites pick it up via ``obs.profiler`` and add
        compile tracking + the host/device/transfer time split.
        """
        sink = TraceSink(trace_path) if trace_path else None
        obs = cls(
            MetricsRegistry(),
            Tracer(sample_rate=sample_rate, ring=ring, sink=sink),
            clock=clock,
        )
        if profile:
            obs.profiler = Profiler(obs)
        return obs

    # -------------------------------------------------------------- metrics
    def count(self, name: str, value: float = 1.0, **labels) -> None:
        self.metrics.counter(name, help_for(name)).inc(value, **labels)

    def gauge(self, name: str, value: float, **labels) -> None:
        self.metrics.gauge(name, help_for(name)).set(value, **labels)

    def observe(self, name: str, value: float, **labels) -> None:
        self.metrics.histogram(name, help_for(name)).observe(value, **labels)

    # -------------------------------------------------------------- tracing
    def trace_begin(self, rid: int) -> None:
        if self.tracer is not None:
            self.tracer.begin(rid)

    def trace_span(
        self, rid: int, name: str, t0: float, t1: float, **attrs
    ) -> None:
        if self.tracer is not None:
            tr = self.tracer.get(rid)
            if tr is not None:
                tr.span(name, t0, t1, **attrs)

    def trace_attr(self, rid: int, **attrs) -> None:
        if self.tracer is not None:
            tr = self.tracer.get(rid)
            if tr is not None:
                tr.attrs.update(attrs)

    def trace_end(self, rid: int) -> None:
        if self.tracer is not None:
            self.tracer.end(rid)

    # ------------------------------------------------------------ lifecycle
    def snapshot(self) -> dict:
        return self.metrics.snapshot()

    def close(self) -> None:
        if self.tracer is not None:
            self.tracer.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class NoopInstrumentation(Instrumentation):
    """Shared default: every hook is a no-op, ``enabled`` is False.

    Keeps the ``clock`` attribute (servers resolve their clock through the
    handle) and a metrics registry that is never written, so generic code
    can snapshot it and get ``{}``.
    """

    enabled = False

    def __init__(self):
        super().__init__(MetricsRegistry(), None, DEFAULT_CLOCK)

    def count(self, name, value=1.0, **labels):
        pass

    def gauge(self, name, value, **labels):
        pass

    def observe(self, name, value, **labels):
        pass

    def trace_begin(self, rid):
        pass

    def trace_span(self, rid, name, t0, t1, **attrs):
        pass

    def trace_attr(self, rid, **attrs):
        pass

    def trace_end(self, rid):
        pass


NOOP = NoopInstrumentation()
