"""Process-local metrics registry: counters, gauges, log2 histograms.

The serving stack needs in-process metrics that are cheap enough to sit on
the per-query path (the acceptance bar is < 5% q/s overhead at full
instrumentation) and rich enough to answer the paper's operating
questions — SLA compliance rate, queue-wait vs service split, exit-reason
mix, fidelity-bound percentiles — without retaining per-query state.

Three metric kinds, all labeled:

  * ``Counter`` — monotone float per label set (``inc``);
  * ``Gauge`` — last-write-wins float per label set (``set``);
  * ``Histogram`` — fixed log2 buckets per label set (``observe``).

Histogram layout (DESIGN.md §13): values land in 64 fixed buckets with
upper edges ``[1, 2, 4, ..., 2^62, +inf]`` — bucket ``i`` holds
``2^(i-1) <= v < 2^i`` for ``i >= 1`` and ``v < 1`` (including negatives
clamped to 0) in bucket 0. One ``int64`` add per observation, O(buckets) =
O(1) percentile reads regardless of sample count, and the per-bucket
``sum`` makes the mean exact. Quantiles interpolate linearly inside the
crossing bucket, so p50/p95/p99 carry at most one-octave error — the right
trade for latency distributions whose interesting structure is
multiplicative.

Everything is process-local and lock-free by design: the serving loops are
single-threaded per process, and cross-process aggregation happens at the
exposition layer (``repro.obs.export``), never here. No globals — a
registry is constructed and threaded explicitly (``Instrumentation``).
"""

from __future__ import annotations

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "N_BUCKETS"]

N_BUCKETS = 64  # bucket 0: v < 1; bucket i: 2^(i-1) <= v < 2^i; last: overflow

# Upper (exclusive) edge of every bucket; the final edge is +inf.
BUCKET_EDGES = [2.0**i for i in range(N_BUCKETS - 1)] + [float("inf")]


def bucket_index(value: float) -> int:
    """O(1) log2 bucket for ``value`` (negatives clamp into bucket 0)."""
    v = int(value)
    if v < 1:
        return 0
    return min(v.bit_length(), N_BUCKETS - 1)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Labeled:
    """Shared label-set bookkeeping for every metric kind."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._samples: dict[tuple, object] = {}

    def labelsets(self) -> list[tuple]:
        return list(self._samples.keys())


class Counter(_Labeled):
    """Monotone labeled counter."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        self._samples[key] = self._samples.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return float(self._samples.get(_label_key(labels), 0.0))

    def total(self) -> float:
        return float(sum(self._samples.values()))

    def snapshot(self) -> dict:
        return {
            "kind": self.kind,
            "help": self.help,
            "samples": {
                ",".join(f"{k}={v}" for k, v in key) or "": val
                for key, val in self._samples.items()
            },
        }


class Gauge(_Labeled):
    """Last-write-wins labeled gauge."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._samples[_label_key(labels)] = float(value)

    def value(self, **labels) -> float:
        return float(self._samples.get(_label_key(labels), 0.0))

    def snapshot(self) -> dict:
        return {
            "kind": self.kind,
            "help": self.help,
            "samples": {
                ",".join(f"{k}={v}" for k, v in key) or "": val
                for key, val in self._samples.items()
            },
        }


class _HistState:
    __slots__ = ("buckets", "count", "sum")

    def __init__(self):
        self.buckets = [0] * N_BUCKETS
        self.count = 0
        self.sum = 0.0


class Histogram(_Labeled):
    """Fixed-bucket log2 histogram with O(1) percentile reads."""

    kind = "histogram"

    def _state(self, labels: dict) -> _HistState:
        key = _label_key(labels)
        st = self._samples.get(key)
        if st is None:
            st = self._samples[key] = _HistState()
        return st

    def observe(self, value: float, **labels) -> None:
        st = self._state(labels)
        st.buckets[bucket_index(value)] += 1
        st.count += 1
        st.sum += value

    def count(self, **labels) -> int:
        key = _label_key(labels)
        st = self._samples.get(key)
        return st.count if st else 0

    def mean(self, **labels) -> float:
        key = _label_key(labels)
        st = self._samples.get(key)
        return st.sum / st.count if st and st.count else 0.0

    def percentile(self, p: float, **labels) -> float:
        """Linear interpolation inside the crossing log2 bucket.

        O(N_BUCKETS) — constant in the number of observations. Returns 0.0
        for an empty histogram.
        """
        st = self._samples.get(_label_key(labels))
        return _percentile_of(st, p) if st else 0.0

    def snapshot(self) -> dict:
        out = {}
        for key, st in self._samples.items():
            out[",".join(f"{k}={v}" for k, v in key) or ""] = {
                "count": st.count,
                "sum": round(st.sum, 6),
                "mean": round(st.sum / st.count, 6) if st.count else 0.0,
                "p50": round(_percentile_of(st, 50.0), 6),
                "p95": round(_percentile_of(st, 95.0), 6),
                "p99": round(_percentile_of(st, 99.0), 6),
                "buckets": {
                    str(BUCKET_EDGES[i]): n
                    for i, n in enumerate(st.buckets)
                    if n
                },
            }
        return {"kind": self.kind, "help": self.help, "samples": out}


def _percentile_of(st: _HistState, p: float) -> float:
    if st.count == 0:
        return 0.0
    target = st.count * min(max(p, 0.0), 100.0) / 100.0
    cum = 0
    for i, n in enumerate(st.buckets):
        if n == 0:
            continue
        if cum + n >= target:
            lo = 0.0 if i == 0 else 2.0 ** (i - 1)
            hi = BUCKET_EDGES[i]
            if hi == float("inf"):
                return lo  # overflow bucket: report its floor
            frac = (target - cum) / n
            return lo + (hi - lo) * frac
        cum += n
    return BUCKET_EDGES[-2]  # unreachable: cum covers count by the last bucket


class MetricsRegistry:
    """Named metric store. ``counter``/``gauge``/``histogram`` get-or-create
    (idempotent per name — re-registration returns the live metric, so every
    layer holding the same registry shares one time series per name)."""

    def __init__(self):
        self._metrics: dict[str, _Labeled] = {}

    def _get(self, cls, name: str, help: str):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"wanted {cls.kind}"
            )
        elif help and not m.help:
            # Backfill: a metric first touched through the raw registry
            # (empty help) adopts the catalog help the moment an
            # Instrumentation call names it.
            m.help = help
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def metrics(self) -> dict:
        return dict(self._metrics)

    def missing_help(self) -> list[str]:
        """Names of registered metrics with an empty help string (the
        registry-wide "no undocumented metric" test hook)."""
        return sorted(n for n, m in self._metrics.items() if not m.help)

    def snapshot(self) -> dict:
        """JSON-able state of every metric (the BENCH_*.json attachment)."""
        return {name: m.snapshot() for name, m in sorted(self._metrics.items())}
