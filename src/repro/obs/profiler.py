"""Device-dispatch profiler: compile tracking, time split, HBM gauges.

The serving layers dispatch padded batches through a small set of jitted
entry points whose program cache is keyed by the pow2 bucket ladder
(DESIGN.md §3/§11) — so in a healthy process the cache grows once per
``(batch, width)`` bucket and then never again. The profiler turns that
discipline into three observable facts per dispatch site:

  * **compiles** — the jit cache grew while dispatching a shape the site
    had not seen before (expected, once per bucket);
  * **recompiles** — the cache grew on an *already-seen* shape. That is an
    anomaly by construction (a leaked non-static argument, a dtype drift,
    cache eviction) and is counted separately so a perf gate can fail on
    ``recompiles > 0``;
  * the **host-plan / dispatch / device-step / transfer** wall-clock split,
    so a q/s regression can be attributed to the layer it lives in.

Cache growth is read from the jitted callable's ``_cache_size()`` hook via
:func:`jit_cache_size` — duck-typed, so this module imports neither jax nor
any other ``repro`` package (the ``repro.obs`` isolation rule). When the
hook is unavailable the profiler falls back to shape novelty: a new shape
counts as a compile and recompiles become undetectable (counted 0).

HBM residency is reported from *live* device buffers: callers hand
:meth:`Profiler.record_hbm_once` any mapping of name -> array and the
profiler duck-types ``.nbytes`` (plain ints also accepted, so the
``ClusteredIndex.device_bytes``/``space_report`` expected-bytes dicts can
be recorded the same way for cross-checks).

Everything funnels through the owning ``Instrumentation`` handle, so the
metrics land in the shared registry (with catalog help strings) and are
exported by the existing Prometheus/JSON surfaces untouched. Timing-only:
a profiled dispatch may add synchronization points, but never changes
results — the bitwise-neutrality contract of DESIGN.md §13 holds with the
profiler enabled.
"""

from __future__ import annotations

__all__ = ["Profiler", "jit_cache_size"]


def jit_cache_size(fn) -> int | None:
    """Compiled-program cache size of a jitted callable, or None.

    Duck-typed on the private-but-stable ``_cache_size`` hook so the obs
    package needs no jax import; any callable without the hook (or whose
    hook raises) simply opts out of compile detection.
    """
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:
        return None


class _SiteStats:
    __slots__ = (
        "dispatches",
        "compiles",
        "recompiles",
        "shapes",
        "plan_ms",
        "dispatch_ms",
        "device_ms",
        "transfer_ms",
        "hbm_bytes",
    )

    def __init__(self):
        self.dispatches = 0
        self.compiles = 0
        self.recompiles = 0
        self.shapes: set[tuple] = set()
        self.plan_ms = 0.0
        self.dispatch_ms = 0.0
        self.device_ms = 0.0
        self.transfer_ms = 0.0
        self.hbm_bytes: dict[str, int] | None = None


def _nbytes(value) -> int | None:
    nb = getattr(value, "nbytes", None)
    if nb is not None:
        return int(nb)
    if isinstance(value, (int, float)):
        return int(value)
    return None


class Profiler:
    """Per-site dispatch profiler attached to an ``Instrumentation``.

    Sites call :meth:`record_dispatch` with the cache size read before and
    after the device call (via :func:`jit_cache_size`) plus the wall-clock
    split they measured; the profiler does the compile/recompile
    classification, keeps plain-python tallies for :meth:`snapshot` (the
    BENCH ``OBS_SNAPSHOT`` attachment), and mirrors everything into the
    metrics registry through the obs handle.
    """

    def __init__(self, obs):
        self.obs = obs
        self._sites: dict[str, _SiteStats] = {}

    def _site(self, site: str) -> _SiteStats:
        st = self._sites.get(site)
        if st is None:
            st = self._sites[site] = _SiteStats()
        return st

    def record_dispatch(
        self,
        site: str,
        shape: tuple,
        *,
        cache_before: int | None = None,
        cache_after: int | None = None,
        plan_ms: float | None = None,
        dispatch_ms: float | None = None,
        device_ms: float | None = None,
        transfer_ms: float | None = None,
    ) -> None:
        st = self._site(site)
        st.dispatches += 1
        new_shape = shape not in st.shapes
        st.shapes.add(shape)
        if cache_before is not None and cache_after is not None:
            compiled = cache_after > cache_before
        else:
            compiled = new_shape  # novelty fallback: no cache introspection
        obs = self.obs
        obs.count("profiler_dispatches", site=site)
        if compiled and new_shape:
            st.compiles += 1
            obs.count("profiler_compiles", site=site)
        elif compiled:
            st.recompiles += 1
            obs.count("profiler_recompiles", site=site)
        for name, val in (
            ("profiler_plan_ms", plan_ms),
            ("profiler_dispatch_ms", dispatch_ms),
            ("profiler_device_ms", device_ms),
            ("profiler_transfer_ms", transfer_ms),
        ):
            if val is None:
                continue
            obs.observe(name, val, site=site)
            short = name[len("profiler_") : -len("_ms")]
            setattr(st, f"{short}_ms", getattr(st, f"{short}_ms") + val)

    def record_hbm_once(self, site: str, arrays) -> None:
        """Gauge live HBM residency for a site's device index, once.

        ``arrays`` is any name -> array-or-int mapping (``DeviceIndex.
        _asdict()``, ``device_bytes_report`` output, ...); entries without a
        byte size (None leaves, nested dicts) are skipped. Idempotent per
        site so the per-dispatch path stays O(1) after the first call.
        """
        st = self._site(site)
        if st.hbm_bytes is not None:
            return
        report: dict[str, int] = {}
        total = 0
        for name, value in dict(arrays).items():
            nb = _nbytes(value)
            if nb is None:
                continue
            report[name] = nb
            total += nb
            self.obs.gauge("hbm_bytes", nb, site=site, array=name)
        st.hbm_bytes = report
        self.obs.gauge("hbm_total_bytes", total, site=site)

    # -------------------------------------------------------------- report
    def recompiles(self) -> int:
        return sum(st.recompiles for st in self._sites.values())

    def snapshot(self) -> dict:
        """JSON-able per-site tallies (attached to BENCH ``OBS_SNAPSHOT``)."""
        out = {}
        for site, st in sorted(self._sites.items()):
            out[site] = {
                "dispatches": st.dispatches,
                "compiles": st.compiles,
                "recompiles": st.recompiles,
                "shapes": sorted(list(s) for s in st.shapes),
                "plan_ms": round(st.plan_ms, 3),
                "dispatch_ms": round(st.dispatch_ms, 3),
                "device_ms": round(st.device_ms, 3),
                "transfer_ms": round(st.transfer_ms, 3),
                "hbm_total_bytes": (
                    sum(st.hbm_bytes.values()) if st.hbm_bytes else None
                ),
            }
        return out
