"""Offline trace analysis: the `python -m repro.obs report` backend.

Consumes a JSONL trace file written by ``TraceSink`` (schema: DESIGN.md
§13) and summarizes the operating story the paper cares about:

  * **SLA compliance** — fraction of queries whose end-to-end latency met
    the SLA the budgeter was holding (each trace records the ``sla_ms`` it
    was admitted under, so a mid-run SLA change still reports honestly;
    ``--sla-ms`` overrides for what-if analysis);
  * **queue wait vs service split** — where the latency actually went:
    time parked in the queue vs time holding a slot/dispatch. An SLA miss
    with a fat queue split is an admission problem, not a traversal
    problem — the distinction Eq. (7) feedback needs (DESIGN.md §11);
  * **exit-reason mix** — safe/budget/exhausted(/down) counts: how often
    the anytime knob actually bit;
  * **quanta per query** — in-flight path: dispatches a query spanned;
  * **fidelity-bound percentiles** — the effectiveness half of the
    anytime contract: what score mass the latency SLA cost.
"""

from __future__ import annotations

import numpy as np

__all__ = ["summarize", "render"]


def _pcts(xs, ps=(50, 95, 99)) -> dict:
    arr = np.asarray(xs, dtype=np.float64)
    if arr.size == 0:
        return {f"p{p}": 0.0 for p in ps}
    return {f"p{p}": round(float(np.percentile(arr, p)), 4) for p in ps}


def _span_durs(rec: dict, name: str) -> float:
    return sum(
        s.get("dur_ms", 0.0) for s in rec.get("spans", []) if s["name"] == name
    )


def summarize(records: list[dict], sla_ms: float | None = None) -> dict:
    """Aggregate a trace-record list into the report dict.

    ``sla_ms`` overrides the per-record ``sla_ms`` attribute; records with
    neither (unbudgeted runs) are excluded from compliance but counted
    everywhere else.
    """
    lat, queue, service, quanta, fidelity = [], [], [], [], []
    reasons: dict[str, int] = {}
    met = judged = 0
    inexact = 0
    alerts = 0
    queries: list[dict] = []
    for rec in records:
        # Drift-detector alert events share the trace stream (DESIGN.md
        # §14) — they are not queries.
        if rec.get("kind") == "alert":
            alerts += 1
            continue
        queries.append(rec)
    for rec in queries:
        latency = rec.get("latency_ms")
        if latency is not None:
            lat.append(float(latency))
            sla = sla_ms if sla_ms is not None else rec.get("sla_ms")
            if sla is not None and float(sla) != float("inf"):
                judged += 1
                if float(latency) <= float(sla):
                    met += 1
        q = _span_durs(rec, "queue")
        queue.append(q)
        service.append(_span_durs(rec, "service") or max(
            (rec.get("latency_ms") or 0.0) - q, 0.0
        ))
        if rec.get("quanta") is not None:
            quanta.append(int(rec["quanta"]))
        if rec.get("fidelity_bound") is not None:
            fidelity.append(int(rec["fidelity_bound"]))
        if rec.get("exact") is False:
            inexact += 1
        r = rec.get("exit_reason")
        if r is not None:
            reasons[r] = reasons.get(r, 0) + 1

    n = len(queries)
    qsum, ssum = float(np.sum(queue)), float(np.sum(service))
    total = qsum + ssum
    return {
        "queries": n,
        "alerts": alerts,
        "sla": {
            "judged": judged,
            "met": met,
            "compliance": round(met / judged, 4) if judged else None,
        },
        "latency_ms": _pcts(lat),
        "queue_wait_ms": _pcts(queue),
        "service_ms": _pcts(service),
        "queue_share": round(qsum / total, 4) if total > 0 else 0.0,
        "exit_reasons": dict(sorted(reasons.items())),
        "quanta": {
            "mean": round(float(np.mean(quanta)), 2) if quanta else None,
            **(_pcts(quanta) if quanta else {}),
        },
        "fidelity_bound": {
            "nonzero": int(np.count_nonzero(fidelity)) if fidelity else 0,
            **(_pcts(fidelity) if fidelity else {}),
        },
        "inexact": inexact,
    }


def render(summary: dict) -> str:
    """Human-readable rendering of ``summarize``'s output."""
    s = summary
    lines = [f"queries: {s['queries']}"]
    sla = s["sla"]
    if sla["judged"]:
        lines.append(
            f"SLA compliance: {sla['met']}/{sla['judged']} "
            f"({100.0 * sla['compliance']:.2f}%)"
        )
    else:
        lines.append("SLA compliance: n/a (no budgeted queries in trace)")
    lines.append(
        "latency ms   p50 {p50:>9.3f}  p95 {p95:>9.3f}  p99 {p99:>9.3f}".format(
            **s["latency_ms"]
        )
    )
    lines.append(
        "queue ms     p50 {p50:>9.3f}  p95 {p95:>9.3f}  p99 {p99:>9.3f}".format(
            **s["queue_wait_ms"]
        )
    )
    lines.append(
        "service ms   p50 {p50:>9.3f}  p95 {p95:>9.3f}  p99 {p99:>9.3f}".format(
            **s["service_ms"]
        )
    )
    lines.append(f"queue share of wall: {100.0 * s['queue_share']:.1f}%")
    if s["exit_reasons"]:
        mix = "  ".join(f"{k}={v}" for k, v in s["exit_reasons"].items())
        lines.append(f"exit reasons: {mix}")
    if s["quanta"].get("mean") is not None:
        lines.append(
            f"quanta/query: mean {s['quanta']['mean']} "
            f"p99 {s['quanta'].get('p99', 0)}"
        )
    fb = s["fidelity_bound"]
    if fb.get("p50") is not None:
        lines.append(
            f"fidelity bound: nonzero {fb['nonzero']}/{s['queries']}  "
            f"p50 {fb['p50']}  p95 {fb['p95']}  p99 {fb['p99']}"
        )
    if s["inexact"]:
        lines.append(f"inexact results: {s['inexact']}")
    if s.get("alerts"):
        lines.append(f"alert events in trace: {s['alerts']}")
    return "\n".join(lines)
