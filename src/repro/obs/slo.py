"""Declarative SLOs with error budgets and multi-window burn rates (§14).

The anytime contract the paper sells — "answer within the SLA, report the
fidelity you gave up" — becomes operable only once the raw telemetry of
DESIGN.md §13 is folded into *objectives*: what fraction of queries must
meet the SLA, how tight the fidelity bound must stay, how often results
must be exact, how available the plane must be. This module is that fold.

An :class:`SloSpec` names an objective (target good/total fraction) and a
*source* that reads cumulative ``(good, total)`` event counts out of the
live :class:`~repro.obs.metrics.MetricsRegistry`:

  * :class:`HistogramBelow` — observations at or below a threshold, with
    linear interpolation inside the crossing log2 bucket (latency-SLA
    attainment over ``latency_ms``, fidelity-ceiling over
    ``fidelity_bound``);
  * :class:`CounterRatio` — one labeled counter subset over another
    (exactness rate over ``sharded_exact``);
  * :class:`GaugeTime` — time-weighted average of a 0..1 gauge, integrated
    between samples (availability over ``plane_available``, which the
    control plane drives from ``HealthLedger`` transitions).

The registry's histograms are cumulative and timestamp-free, so windowed
rates need an external time axis: :class:`SloTracker` keeps a ring of
clock-stamped source snapshots and differences them per window. Burn rate
follows the Google-SRE multi-window convention — with objective ``o`` and
windowed attainment ``a``, ``burn = (1 - a) / (1 - o)``; burn 1.0 spends
the error budget exactly at the objective boundary. Alerting state uses
two window pairs: *fast* (default 5m + 1h, both >= 14.4) and *slow*
(default 6h + 3d, both >= 6.0).

``evaluate()`` returns the full report **and** writes ``slo_*`` gauges
back into the registry, so the existing Prometheus/JSON exposition
(``repro.obs.export``) carries SLO state with zero changes to its callers.
Offline, ``python -m repro.obs slo trace.jsonl`` replays a recorded trace
through the same machinery (span timestamps are absolute clock readings).
"""

from __future__ import annotations

import dataclasses
from collections import deque

from repro.obs.metrics import (
    BUCKET_EDGES,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)

__all__ = [
    "SloSpec",
    "SloTracker",
    "HistogramBelow",
    "CounterRatio",
    "GaugeTime",
    "cdf_below",
    "default_serving_slos",
    "replay_trace",
    "render_slo",
    "DEFAULT_WINDOWS",
    "FAST_BURN",
    "SLOW_BURN",
]

# Window name -> seconds. Ordered short to long; the first two form the
# fast-burn pair, the last two the slow-burn pair.
DEFAULT_WINDOWS: dict[str, float] = {
    "5m": 300.0,
    "1h": 3600.0,
    "6h": 21600.0,
    "3d": 259200.0,
}
FAST_BURN = 14.4  # both fast windows at/above -> page-grade ("fast_burn")
SLOW_BURN = 6.0  # both slow windows at/above -> ticket-grade ("slow_burn")

_STATE_CODE = {"ok": 0, "slow_burn": 1, "fast_burn": 2}


def cdf_below(buckets: list[int], threshold: float) -> float:
    """Observations <= ``threshold`` in a log2-bucket histogram.

    Buckets fully below the threshold count whole; the crossing bucket is
    linearly interpolated (same one-octave error model as the percentile
    reads). The overflow bucket only counts under an infinite threshold.
    Thresholds on a bucket edge are exact — tests pin that.
    """
    if threshold < 0:
        return 0.0
    good = 0.0
    for i, n in enumerate(buckets):
        if n == 0:
            continue
        lo = 0.0 if i == 0 else 2.0 ** (i - 1)
        hi = BUCKET_EDGES[i]
        if hi <= threshold:
            good += n
        elif lo <= threshold:
            if hi == float("inf"):
                continue  # overflow bucket: no interpolable mass
            good += n * (threshold - lo) / (hi - lo)
    return good


def _labels_match(key: tuple, want: dict | None) -> bool:
    if not want:
        return True
    have = dict(key)
    return all(have.get(str(k)) == str(v) for k, v in want.items())


class HistogramBelow:
    """good = observations <= threshold; total = all observations."""

    def __init__(self, metric: str, threshold: float, labels: dict | None = None):
        self.metric = metric
        self.threshold = float(threshold)
        self.labels = labels

    def __call__(self, registry: MetricsRegistry, now: float):
        m = registry.metrics().get(self.metric)
        if not isinstance(m, Histogram):
            return 0.0, 0.0
        good = total = 0.0
        for key, st in m._samples.items():
            if not _labels_match(key, self.labels):
                continue
            good += cdf_below(st.buckets, self.threshold)
            total += st.count
        return good, total


class CounterRatio:
    """good = sum of one labeled counter subset; total = another."""

    def __init__(
        self,
        good_metric: str,
        total_metric: str,
        good_labels: dict | None = None,
        total_labels: dict | None = None,
    ):
        self.good_metric = good_metric
        self.total_metric = total_metric
        self.good_labels = good_labels
        self.total_labels = total_labels

    def _sum(self, registry: MetricsRegistry, metric: str, labels) -> float:
        m = registry.metrics().get(metric)
        if not isinstance(m, Counter):
            return 0.0
        return float(
            sum(
                v
                for key, v in m._samples.items()
                if _labels_match(key, labels)
            )
        )

    def __call__(self, registry: MetricsRegistry, now: float):
        return (
            self._sum(registry, self.good_metric, self.good_labels),
            self._sum(registry, self.total_metric, self.total_labels),
        )


class GaugeTime:
    """Time-weighted average of a 0..1 gauge (availability).

    Integrates between tracker samples: ``good`` accrues ``value * dt``
    seconds, ``total`` accrues ``dt``, using the gauge value held over the
    elapsed interval. Stateful — one instance per tracker.
    """

    def __init__(self, metric: str, labels: dict | None = None):
        self.metric = metric
        self.labels = labels
        self._last_t: float | None = None
        self._last_v = 1.0
        self._good = 0.0
        self._total = 0.0

    def _read(self, registry: MetricsRegistry) -> float:
        m = registry.metrics().get(self.metric)
        if not isinstance(m, Gauge):
            return 1.0  # unreported gauge -> assume up (no data, no burn)
        for key, v in m._samples.items():
            if _labels_match(key, self.labels):
                return float(v)
        return 1.0

    def __call__(self, registry: MetricsRegistry, now: float):
        if self._last_t is not None:
            dt = max(0.0, now - self._last_t)
            self._total += dt
            self._good += dt * min(max(self._last_v, 0.0), 1.0)
        self._last_t = now
        self._last_v = self._read(registry)
        return self._good, self._total


@dataclasses.dataclass
class SloSpec:
    """One objective: ``source`` must keep good/total >= ``objective``."""

    name: str
    objective: float  # target good/total fraction in (0, 1]
    source: object  # callable (registry, now) -> (good, total), cumulative
    description: str = ""


def _burn(attainment: float, objective: float) -> float:
    bad = 1.0 - attainment
    allowed = 1.0 - objective
    if allowed <= 0.0:
        return 0.0 if bad <= 0.0 else float("inf")
    return bad / allowed


class SloTracker:
    """Rings clock-stamped source snapshots; evaluates windowed burn rates.

    ``sample()`` reads every SLO source once and appends a snapshot;
    ``evaluate()`` differences the newest snapshot against the one at each
    window's horizon (falling back to the oldest available — early in a
    process the long windows degenerate to "since start", which is the
    conservative reading). Both are driven by the injected clock, so the
    whole pipeline is deterministic under ``FakeClock``.
    """

    def __init__(
        self,
        obs,
        slos: list[SloSpec],
        windows: dict[str, float] | None = None,
        fast_burn: float = FAST_BURN,
        slow_burn: float = SLOW_BURN,
        clock=None,
    ):
        if not slos:
            raise ValueError("SloTracker needs at least one SloSpec")
        self.obs = obs
        self.slos = list(slos)
        self.windows = dict(windows) if windows else dict(DEFAULT_WINDOWS)
        if not self.windows:
            raise ValueError("SloTracker needs at least one window")
        names = sorted(self.windows, key=self.windows.__getitem__)
        self._fast_pair = names[: min(2, len(names))]
        self._slow_pair = names[-min(2, len(names)) :]
        self._longest = names[-1]
        self.fast_burn = fast_burn
        self.slow_burn = slow_burn
        self.clock = clock if clock is not None else obs.clock
        self._ring: deque = deque()
        self._horizon = max(self.windows.values()) * 1.25

    # ------------------------------------------------------------- samples
    def sample(self, now: float | None = None) -> None:
        now = self.clock() if now is None else now
        registry = self.obs.metrics
        snap = {s.name: s.source(registry, now) for s in self.slos}
        self._ring.append((now, snap))
        while self._ring and self._ring[0][0] < now - self._horizon:
            self._ring.popleft()

    def _delta(self, name: str, window_s: float):
        t_cur, cur = self._ring[-1]
        base = self._ring[0]
        horizon = t_cur - window_s
        # Fast path: the window predates every snapshot (short process,
        # long window) — the oldest snapshot is the base, no scan. Keeps
        # evaluate() O(windows) instead of O(ring) per serving-loop poll.
        if base[0] > horizon:
            pass
        else:
            for t, snap in reversed(self._ring):
                if t <= horizon:
                    base = (t, snap)
                    break
        g0, n0 = base[1][name]
        g1, n1 = cur[name]
        return max(0.0, g1 - g0), max(0.0, n1 - n0)

    # ------------------------------------------------------------ evaluate
    def evaluate(self, now: float | None = None) -> dict:
        """Windowed attainment/burn per SLO; writes ``slo_*`` gauges."""
        if not self._ring:
            self.sample(now)
        report: dict = {}
        for spec in self.slos:
            windows = {}
            for wname, wsec in self.windows.items():
                good, total = self._delta(spec.name, wsec)
                attainment = good / total if total > 0 else 1.0
                windows[wname] = {
                    "good": round(good, 6),
                    "total": round(total, 6),
                    "attainment": round(attainment, 6),
                    "burn": round(_burn(attainment, spec.objective), 6),
                }
            fast = all(
                windows[w]["burn"] >= self.fast_burn for w in self._fast_pair
            )
            slow = all(
                windows[w]["burn"] >= self.slow_burn for w in self._slow_pair
            )
            state = "fast_burn" if fast else ("slow_burn" if slow else "ok")
            long_burn = windows[self._longest]["burn"]
            budget_remaining = max(0.0, 1.0 - long_burn)
            report[spec.name] = {
                "objective": spec.objective,
                "description": spec.description,
                "windows": windows,
                "events": windows[self._longest]["total"],
                "attainment": windows[self._longest]["attainment"],
                "budget_remaining": round(budget_remaining, 6),
                "state": state,
            }
            obs = self.obs
            obs.gauge("slo_attainment", report[spec.name]["attainment"], slo=spec.name)
            for wname, w in windows.items():
                obs.gauge("slo_burn_rate", w["burn"], slo=spec.name, window=wname)
            obs.gauge(
                "slo_error_budget_remaining", budget_remaining, slo=spec.name
            )
            obs.gauge("slo_state", _STATE_CODE[state], slo=spec.name)
        return report


def default_serving_slos(
    sla_ms: float | None = None,
    latency_objective: float = 0.99,
    fidelity_ceiling: float | None = None,
    fidelity_objective: float = 0.95,
    exactness_objective: float = 0.90,
    availability_objective: float = 0.999,
) -> list[SloSpec]:
    """The four paper-shaped serving SLOs over the standard metric names."""
    slos: list[SloSpec] = []
    if sla_ms is not None and sla_ms != float("inf"):
        slos.append(
            SloSpec(
                "latency_sla",
                latency_objective,
                HistogramBelow("latency_ms", sla_ms),
                f"queries served within the {sla_ms:g} ms SLA",
            )
        )
    if fidelity_ceiling is not None:
        slos.append(
            SloSpec(
                "fidelity_ceiling",
                fidelity_objective,
                HistogramBelow("fidelity_bound", fidelity_ceiling),
                f"fidelity bounds at or below {fidelity_ceiling:g}",
            )
        )
    slos.append(
        SloSpec(
            "exactness",
            exactness_objective,
            CounterRatio(
                "sharded_exact", "sharded_exact", good_labels={"exact": True}
            ),
            "sharded results carrying an exactness certificate",
        )
    )
    slos.append(
        SloSpec(
            "availability",
            availability_objective,
            GaugeTime("plane_available"),
            "time-weighted fraction with every shard up",
        )
    )
    return slos


def _record_time_s(rec: dict, fallback: float) -> float:
    """A record's completion time from its absolute span clocks."""
    spans = rec.get("spans") or []
    ends = [
        s["t0_ms"] + s.get("dur_ms", 0.0) for s in spans if "t0_ms" in s
    ]
    return max(ends) / 1e3 if ends else fallback


def replay_trace(
    records: list[dict],
    sla_ms: float | None = None,
    fidelity_ceiling: float | None = None,
    windows: dict[str, float] | None = None,
) -> dict:
    """Burn-rate report over a recorded trace (the ``slo`` CLI core).

    Replays query records in completion order (span timestamps are
    absolute readings of the recording process's clock) through a fresh
    registry + :class:`SloTracker`, sampling after every record, then
    evaluates at the final timestamp. Alert records (``kind="alert"``)
    are skipped as SLO events but counted. With no ``sla_ms`` override
    the per-record ``sla_ms`` attribute's maximum is used; if neither
    exists the latency SLO is omitted.
    """
    from repro.obs.instrument import Instrumentation

    alerts = [r for r in records if r.get("kind") == "alert"]
    queries = [r for r in records if r.get("kind") != "alert"]
    times: list[float] = []
    t = 0.0
    for rec in queries:
        t = _record_time_s(rec, t)
        times.append(t)
    order = sorted(range(len(queries)), key=times.__getitem__)

    if sla_ms is None:
        recorded = [r["sla_ms"] for r in queries if "sla_ms" in r]
        sla_ms = max(recorded) if recorded else None
    if fidelity_ceiling is None:
        bounds = [r["fidelity_bound"] for r in queries if "fidelity_bound" in r]
        fidelity_ceiling = max(bounds) if bounds else None

    obs = Instrumentation()
    slos = default_serving_slos(
        sla_ms=sla_ms, fidelity_ceiling=fidelity_ceiling
    )
    tracker = SloTracker(obs, slos, windows=windows)
    t0 = times[order[0]] if order else 0.0
    tracker.sample(now=t0)
    last = t0
    for i in order:
        rec = queries[i]
        if "latency_ms" in rec:
            obs.observe("latency_ms", rec["latency_ms"])
        if "fidelity_bound" in rec:
            obs.observe("fidelity_bound", rec["fidelity_bound"])
        if "exact" in rec:
            obs.count("sharded_exact", exact=bool(rec["exact"]))
        last = times[i]
        tracker.sample(now=last)
    report = tracker.evaluate(now=last)
    return {
        "queries": len(queries),
        "alerts": len(alerts),
        "span_s": round(max(0.0, last - t0), 6),
        "sla_ms": sla_ms,
        "fidelity_ceiling": fidelity_ceiling,
        "slos": report,
    }


def render_slo(report: dict) -> str:
    """Human-readable ``slo`` CLI output."""
    lines = [
        f"queries: {report['queries']}  alerts: {report['alerts']}  "
        f"span: {report['span_s']:.3f}s"
    ]
    for name, rep in sorted(report["slos"].items()):
        lines.append(
            f"{name}: objective={rep['objective']:g} "
            f"attainment={rep['attainment']:.4f} "
            f"budget_remaining={rep['budget_remaining']:.4f} "
            f"state={rep['state']}"
        )
        for wname, w in rep["windows"].items():
            lines.append(
                f"  {wname:>4}: good={w['good']:.1f}/{w['total']:.1f} "
                f"attain={w['attainment']:.4f} burn={w['burn']:.3f}"
            )
    return "\n".join(lines)
