"""Per-query tracing: spans, sampling, ring buffer, durable JSONL sink.

A ``QueryTrace`` is the life of one query through the serving stack as a
list of named spans (DESIGN.md §13 has the schema table):

    enqueue -> plan/bucket -> budget -> dispatch quanta -> shard merge
    -> retire

Spans carry wall-clock timestamps from the *injected* clock (the same one
the server and budgeter read — ``repro.obs.clock``) plus free-form numeric
attributes: admission budget, device step time, quanta count, per-shard
exit reasons, fidelity bound. Device-step attribution rides in span attrs
(``device_ms``) because the device timeline is only observable from the
host at dispatch granularity.

``Tracer`` owns the policy:

  * **sampling** — ``sample_rate`` in [0, 1]; the decision is a
    deterministic hash of the query's rid (Knuth multiplicative), so a
    given rid samples identically across runs and across processes —
    nothing about tracing consults an RNG, which keeps instrumented runs
    bit-reproducible;
  * **bounded memory** — finished traces land in a ring buffer
    (``maxlen=ring``) so a long-lived server holds a sliding window, not
    an unbounded log;
  * **durability** — with a ``sink`` attached, every finished trace is
    appended to a JSONL file with the same torn-tail discipline as
    ``control/journal.py``: a crash mid-append leaves at most one torn
    final line, which readers skip and the next append truncates.
    Traces are higher-volume than topology records, so fsync is amortised
    (every ``fsync_every`` records and on ``close``) instead of per record
    — a lost tail of *recent* traces is acceptable where a lost topology
    record is not.
"""

from __future__ import annotations

import json
import os
from collections import deque

__all__ = ["Span", "QueryTrace", "TraceSink", "Tracer", "read_traces"]

_KNUTH = 2654435761  # Knuth's multiplicative hash constant (mod 2^32)


def sampled(rid: int, rate: float) -> bool:
    """Deterministic per-rid sampling decision (no RNG, run-stable)."""
    if rate >= 1.0:
        return True
    if rate <= 0.0:
        return False
    return ((rid * _KNUTH) & 0xFFFFFFFF) / 2.0**32 < rate


class Span:
    """One named interval inside a trace."""

    __slots__ = ("name", "t0", "t1", "attrs")

    def __init__(self, name: str, t0: float, t1: float, attrs: dict | None):
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.attrs = attrs

    def to_dict(self) -> dict:
        d = {
            "name": self.name,
            "t0_ms": round(self.t0 * 1e3, 4),
            "dur_ms": round((self.t1 - self.t0) * 1e3, 4),
        }
        if self.attrs:
            d.update(self.attrs)
        return d


class QueryTrace:
    """Spans + attributes for one query, keyed by rid."""

    __slots__ = ("rid", "spans", "attrs")

    def __init__(self, rid: int):
        self.rid = rid
        self.spans: list[Span] = []
        self.attrs: dict = {}

    def span(self, name: str, t0: float, t1: float, **attrs) -> None:
        self.spans.append(Span(name, t0, t1, attrs or None))

    def to_dict(self) -> dict:
        return {
            "rid": self.rid,
            **self.attrs,
            "spans": [s.to_dict() for s in self.spans],
        }


class TraceSink:
    """Append-only JSONL trace file with crash-tolerant appends.

    Same stance as ``control.journal.TopologyJournal``: before the first
    append the writer truncates a crash-torn final line (readers only ever
    skip it, but appending onto it would merge two records); every write is
    flushed, and fsync happens every ``fsync_every`` records and on
    ``close`` — traces trade per-record durability for throughput.
    """

    def __init__(self, path: str, fsync_every: int = 64):
        self.path = path
        self.fsync_every = max(1, int(fsync_every))
        self._since_sync = 0
        self._file = None
        self.written = 0

    def _open(self):
        if self._file is None:
            _repair_torn_tail(self.path)
            self._file = open(self.path, "a", encoding="utf-8")
        return self._file

    def append(self, record: dict) -> None:
        f = self._open()
        f.write(json.dumps(record, sort_keys=True) + "\n")
        f.flush()
        self.written += 1
        self._since_sync += 1
        if self._since_sync >= self.fsync_every:
            os.fsync(f.fileno())
            self._since_sync = 0

    def close(self) -> None:
        if self._file is not None:
            self._file.flush()
            os.fsync(self._file.fileno())
            self._file.close()
            self._file = None
            self._since_sync = 0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def _repair_torn_tail(path: str) -> None:
    try:
        with open(path, "r+b") as f:
            f.seek(0, os.SEEK_END)
            size = f.tell()
            if size == 0:
                return
            f.seek(size - 1)
            if f.read(1) == b"\n":
                return
            f.seek(0)
            data = f.read()
            f.truncate(data.rfind(b"\n") + 1)
    except FileNotFoundError:
        return


def read_traces(path: str) -> list[dict]:
    """All committed trace records, oldest first; a torn tail is skipped.

    A malformed line anywhere *else* raises — half a trace file should not
    silently summarize as the whole story (mirrors journal semantics).
    """
    if not os.path.exists(path):
        return []
    with open(path, encoding="utf-8") as f:
        lines = f.read().split("\n")
    if lines and lines[-1] == "":
        lines.pop()
    out: list[dict] = []
    for i, line in enumerate(lines):
        try:
            rec = json.loads(line)
            if not isinstance(rec, dict):
                raise ValueError(f"record {i} is not an object")
        except ValueError as e:
            if i == len(lines) - 1:
                break  # torn tail from a crashed append
            raise ValueError(f"{path}: corrupt trace record {i}: {e}") from e
        out.append(rec)
    return out


class Tracer:
    """Sampling trace collector with a bounded ring and optional sink."""

    def __init__(
        self,
        sample_rate: float = 1.0,
        ring: int = 1024,
        sink: TraceSink | None = None,
    ):
        if not 0.0 <= sample_rate <= 1.0:
            raise ValueError(f"sample_rate {sample_rate} not in [0, 1]")
        self.sample_rate = float(sample_rate)
        self.ring: deque = deque(maxlen=max(1, int(ring)))
        self.sink = sink
        self._live: dict[int, QueryTrace] = {}
        self.started = 0
        self.finished = 0
        self.dropped = 0  # rids not sampled

    def begin(self, rid: int) -> QueryTrace | None:
        """Open a trace for ``rid`` if sampled; None means 'not tracing'."""
        if not sampled(rid, self.sample_rate):
            self.dropped += 1
            return None
        tr = QueryTrace(rid)
        self._live[rid] = tr
        self.started += 1
        return tr

    def get(self, rid: int) -> QueryTrace | None:
        return self._live.get(rid)

    def end(self, rid: int) -> QueryTrace | None:
        """Finish ``rid``'s trace: ring-buffer it and append to the sink."""
        tr = self._live.pop(rid, None)
        if tr is None:
            return None
        self.ring.append(tr)
        self.finished += 1
        if self.sink is not None:
            self.sink.append(tr.to_dict())
        return tr

    def close(self) -> None:
        if self.sink is not None:
            self.sink.close()
