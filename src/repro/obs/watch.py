"""Dependency-free terminal dashboard over a snapshot file (§14).

``python -m repro.obs watch <snapshot.json>`` renders the registry state a
serving process exports via :func:`repro.obs.export.write_snapshot` —
counters, gauges, histogram percentiles, the SLO burn-rate table, and the
alert tail — re-reading the file at an interval. Pure stdlib string
building (no curses, no rich): one ANSI home+clear escape per frame, so it
degrades to plain appended frames on a dumb terminal and stays usable over
``watch --once`` in CI.
"""

from __future__ import annotations

import json
import time

__all__ = ["render_dashboard", "watch_loop"]

_CLEAR = "\x1b[H\x1b[2J"
_STATE_MARK = {"ok": "ok", "slow_burn": "SLOW BURN", "fast_burn": "FAST BURN"}


def _fmt(v) -> str:
    if isinstance(v, float):
        if v != v:  # NaN
            return "nan"
        if abs(v) >= 1e6 or (0 < abs(v) < 1e-3):
            return f"{v:.3g}"
        return f"{v:,.3f}".rstrip("0").rstrip(".")
    return str(v)


def _rows(title: str, header: list[str], rows: list[list[str]]) -> list[str]:
    if not rows:
        return []
    widths = [
        max(len(header[i]), *(len(r[i]) for r in rows))
        for i in range(len(header))
    ]
    fmt_row = lambda r: "  ".join(  # noqa: E731
        c.ljust(w) for c, w in zip(r, widths)
    )
    return [f"-- {title}", fmt_row(header)] + [fmt_row(r) for r in rows] + [""]


def render_dashboard(snap: dict, max_alerts: int = 8) -> str:
    """One text frame from a ``write_snapshot`` document."""
    lines: list[str] = []
    t = snap.get("t")
    head = "repro.obs watch"
    if t is not None:
        head += f"  ·  snapshot t={_fmt(float(t))}s"
    lines.append(head)
    lines.append("=" * len(head))
    lines.append("")

    slo = snap.get("slo") or {}
    rows = []
    for name, rep in sorted(slo.items()):
        burns = rep.get("windows", {})
        rows.append(
            [
                name,
                _fmt(rep.get("objective", "")),
                _fmt(rep.get("attainment", "")),
                " ".join(f"{w}={_fmt(burns[w]['burn'])}" for w in burns),
                _fmt(rep.get("budget_remaining", "")),
                _STATE_MARK.get(rep.get("state", ""), rep.get("state", "")),
            ]
        )
    lines += _rows(
        "slo", ["slo", "obj", "attain", "burn", "budget", "state"], rows
    )

    metrics = snap.get("metrics") or {}
    counters, gauges, hists = [], [], []
    for name, m in sorted(metrics.items()):
        kind = m.get("kind")
        samples = m.get("samples", {})
        if kind == "counter":
            for labels, v in sorted(samples.items()):
                counters.append([name, labels or "-", _fmt(v)])
        elif kind == "gauge":
            for labels, v in sorted(samples.items()):
                gauges.append([name, labels or "-", _fmt(v)])
        elif kind == "histogram":
            for labels, st in sorted(samples.items()):
                hists.append(
                    [
                        name,
                        labels or "-",
                        _fmt(st.get("count", 0)),
                        _fmt(st.get("p50", 0.0)),
                        _fmt(st.get("p95", 0.0)),
                        _fmt(st.get("p99", 0.0)),
                    ]
                )
    lines += _rows("counters", ["name", "labels", "value"], counters)
    lines += _rows("gauges", ["name", "labels", "value"], gauges)
    lines += _rows(
        "histograms", ["name", "labels", "count", "p50", "p95", "p99"], hists
    )

    prof = snap.get("profiler") or {}
    rows = [
        [
            site,
            _fmt(st.get("dispatches", 0)),
            _fmt(st.get("compiles", 0)),
            _fmt(st.get("recompiles", 0)),
            _fmt(st.get("device_ms", 0.0)),
            _fmt(st.get("hbm_total_bytes") or 0),
        ]
        for site, st in sorted(prof.items())
    ]
    lines += _rows(
        "profiler",
        ["site", "dispatches", "compiles", "recompiles", "device_ms", "hbm_B"],
        rows,
    )

    alerts = snap.get("alerts") or []
    rows = [
        [
            ev.get("state", "?"),
            ev.get("detector", "?"),
            _fmt(ev.get("value", "")),
            _fmt(ev.get("zscore", "")) if "zscore" in ev else "-",
        ]
        for ev in alerts[-max_alerts:]
    ]
    lines += _rows("alerts (tail)", ["state", "detector", "value", "z"], rows)
    if not (slo or metrics or prof or alerts):
        lines.append("(empty snapshot)")
    return "\n".join(lines).rstrip() + "\n"


def watch_loop(
    path: str,
    interval: float = 2.0,
    once: bool = False,
    out=None,
    sleep=time.sleep,
) -> int:
    """Render ``path`` every ``interval`` seconds (or once). Returns exit
    status: 1 if ``once`` and the snapshot is missing/unreadable."""
    import sys

    out = out if out is not None else sys.stdout
    while True:
        try:
            with open(path) as fh:
                snap = json.load(fh)
            frame = render_dashboard(snap)
        except (OSError, ValueError) as e:
            if once:
                print(f"{path}: {e}", file=sys.stderr)
                return 1
            frame = f"waiting for snapshot at {path} ({e})\n"
        if once:
            out.write(frame)
            return 0
        out.write(_CLEAR + frame)
        out.flush()
        try:
            sleep(interval)
        except KeyboardInterrupt:
            return 0
