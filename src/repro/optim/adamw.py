"""AdamW with sharded (ZeRO-1) and optionally 8-bit-quantized moments.

Why this is first-class and not a toy (DESIGN.md §5): deepseek-v3-671b on
512 v5e chips has 8 TB of HBM total; fp32 Adam moments + fp32 master params
need 9.4 TB and do not fit. bf16 params + int8 moments ≈ 2.7 TB do.

Int8 moments use block-wise absmax scaling along the last axis (block 256,
the 8-bit-Adam construction) and *preserve leading dimensions*:
p [..., D] -> q [..., D/256, 256] + scale [..., D/256, 1]. That layout lets
moment shardings inherit the param PartitionSpec and additionally take a
ZeRO-1 data-axis shard on the first free dimension (train/trainer.py).

States are plain pytrees; shardings are applied by the caller.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "is_q8_leaf"]

_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "fp32"  # "fp32" | "bf16" | "int8"


def is_q8_leaf(x) -> bool:
    return isinstance(x, dict) and "q" in x and "scale" in x


def _q8(x: jnp.ndarray) -> dict:
    *lead, last = x.shape
    pad = (-last) % _BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    nb = (last + pad) // _BLOCK
    blocks = x.reshape(*lead, nb, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dq8(s: dict, like: jnp.ndarray) -> jnp.ndarray:
    *lead, last = like.shape
    flat = (s["q"].astype(jnp.float32) * s["scale"]).reshape(*lead, -1)
    return flat[..., :last]


def _encode(x: jnp.ndarray, dtype: str):
    if dtype == "int8":
        return _q8(x)
    if dtype == "bf16":
        return x.astype(jnp.bfloat16)
    return x.astype(jnp.float32)


def _decode(s, like: jnp.ndarray) -> jnp.ndarray:
    if is_q8_leaf(s):
        return _dq8(s, like)
    return s.astype(jnp.float32)


def init_opt_state(params, cfg: AdamWConfig):
    def zero_like(p):
        return _encode(jnp.zeros(p.shape, jnp.float32), cfg.moment_dtype)

    return {
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(params, grads, state, cfg: AdamWConfig, lr):
    """One AdamW step. Returns (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m_s, v_s):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * _decode(m_s, p) + (1 - cfg.b1) * g
        v = cfg.b2 * _decode(v_s, p) + (1 - cfg.b2) * g * g
        delta = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, _encode(m, cfg.moment_dtype), _encode(v, cfg.moment_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = jax.tree.flatten(state["m"], is_leaf=is_q8_leaf)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_q8_leaf)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
