"""Int8 error-feedback gradient compression for the cross-pod reduction.

On a multi-pod mesh the data-parallel gradient all-reduce crosses the
pod-interconnect (DCI), the slowest link in the system. This module cuts
that wire traffic ~4x by exchanging int8 block-quantized gradients
(all_gather of s8 payloads + fp32 block scales, local dequant-sum) instead
of an fp32 all-reduce. An error-feedback buffer re-injects the quantization
error next step (EF-SGD construction — convergence-neutral in practice).

Implementation: an ALL-manual ``shard_map`` whose body only references the
pod axis (non-pod axes are manual-but-unreferenced; partial-manual trips an
XLA-CPU partitioner crash). The s8 all-gather is visible in the
dry-run HLO — the §Perf collective table picks it up directly.

Error buffers carry a leading pod dimension (per-pod state); callers shard
them over (pod, data) so the fp32 buffer adds params/n_data bytes per chip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import axis_size, shard_map

__all__ = ["compress_psum_pod", "init_error_buffers"]

_BLOCK = 256


def _quantize(x: jnp.ndarray):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def init_error_buffers(params_like, n_pods: int):
    """Per-pod fp32 error state, leading dim = n_pods."""
    return jax.tree.map(
        lambda g: jnp.zeros((n_pods, *g.shape), jnp.float32), params_like
    )


def compress_psum_pod(grad_fn, mesh, pod_axis: str = "pod"):
    """Wrap a per-pod gradient fn with int8 EF compression over ``pod``.

    grad_fn(batch_shard) -> per-pod grads pytree (params are closed over and
    remain GSPMD-sharded on the non-pod axes). Returns
    fn(batch, err) -> (mean grads across pods, new err buffers).
    """

    def body(batch_shard, err):
        g = grad_fn(batch_shard)
        n_pods = axis_size(pod_axis)

        def one(gl, el):
            el = el[0]  # leading pod dim -> local slice
            gf = gl.astype(jnp.float32) + el
            q, scale = _quantize(gf)
            # Compressed exchange: s8 payload + fp32 block scales on the wire.
            q_all = jax.lax.all_gather(q, pod_axis)  # [P, blocks, 256] int8
            s_all = jax.lax.all_gather(scale, pod_axis)
            summed = jnp.sum(
                q_all.astype(jnp.float32) * s_all, axis=0
            ).reshape(-1)[: gf.size].reshape(gf.shape)
            new_e = gf - _dequantize(q, scale, gf.shape)
            return summed / n_pods, new_e[None]

        pairs = jax.tree.map(one, g, err)
        grads = jax.tree.map(
            lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple)
        )
        new_err = jax.tree.map(
            lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple)
        )
        return grads, new_err

    # ALL-manual (every mesh axis listed): pod-only partial-manual hits the
    # same XLA-CPU partitioner crash as the attention psums. Non-pod axes are
    # simply unreferenced in the body, so the collective pattern is unchanged.
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(pod_axis), P(pod_axis)),
        out_specs=(P(), P(pod_axis)),
        axis_names=frozenset(mesh.axis_names),
        check_vma=False,
    )
