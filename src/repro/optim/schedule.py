"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_with_warmup", "constant"]


def cosine_with_warmup(step, *, peak: float, warmup: int, total: int, floor: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = peak * jnp.minimum(step / max(warmup, 1), 1.0)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)


def constant(step, *, peak: float, **_):
    del step
    return jnp.float32(peak)
