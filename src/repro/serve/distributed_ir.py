"""Distributed anytime IR: ISN shards over the mesh + broker-merge collective.

The paper's deployment story (§1, §7): a collection too large for one node is
partitioned across index server nodes; every query runs on all partitions and
a broker merges per-node top-k lists. Here that maps onto one device mesh
(DESIGN.md §4-§5; serving/sharded.py is the range-partitioned sibling that
shards one index at range boundaries instead of building per-node
sub-indexes from a random document split):

  * the corpus is split into M = |model| shards, each a self-contained
    cluster-skipping sub-index (its own ranges, bounds, local docid space);
  * queries are sharded over (pod, data) — query parallelism/replication;
  * each model rank runs the *single-node* anytime traversal
    (core.range_daat.device_traverse, unchanged) over its shard with a
    per-shard work budget — the per-ISN SLA quantum;
  * the broker merge is one all_gather over ``model`` of [Q_loc, k]
    (vals, ids) + a top-k — the collective the roofline table shows for
    the anytime-ir cells.

Array convention: shard-major layouts [M, ...] sharded P("model", ...), so
the same code lowers for the production mesh and runs on 1 device (M=1).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import range_daat
from repro.core.clustered_index import build_index
from repro.core.range_daat import DeviceIndex
from repro.data.synth import Corpus
from repro.distributed.sharding import ShardCtx, shard_map

__all__ = ["ShardedIndexArrays", "build_sharded_index", "sharded_anytime_query", "sharded_query_specs"]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=(
        "docs", "impacts", "blk_start", "blk_len", "blk_maximp",
        "range_starts", "doc_base",
    ),
    meta_fields=("s_pad", "k"),
)
@dataclasses.dataclass(frozen=True)
class ShardedIndexArrays:
    """Shard-major device arrays (all leading dim = M shards)."""

    docs: jnp.ndarray  # [M, NNZ] int32 local docids
    impacts: jnp.ndarray  # [M, NNZ] int32
    blk_start: jnp.ndarray  # [M, NB] int32
    blk_len: jnp.ndarray  # [M, NB] int32
    blk_maximp: jnp.ndarray  # [M, NB] int32
    range_starts: jnp.ndarray  # [M, R_loc] int32 (local docid space)
    doc_base: jnp.ndarray  # [M] int32 — global docid offset per shard
    s_pad: int
    k: int


def build_sharded_index(
    corpus: Corpus, n_shards: int, n_ranges_per_shard: int = 8, bits: int = 8,
    strategy: str = "clustered_bp", seed: int = 0,
):
    """Round-robin partition the corpus, build one sub-index per shard.

    Returns (arrays, engines) — engines are the per-shard host Engine
    objects (for planning query block tables per shard).
    """
    from repro.core.bm25 import invert
    from repro.core.quantize import fit_quantizer
    from repro.core.range_daat import Engine
    from repro.data.synth import Corpus as C

    # One GLOBAL quantizer so per-shard integer scores are merge-compatible.
    global_quant = fit_quantizer(invert(corpus).scores, bits=bits)

    # Split docs round-robin (the random-partition policy of §7.2).
    shard_of = np.arange(corpus.n_docs) % n_shards
    engines = []
    sub_indexes = []
    for m in range(n_shards):
        docs_m = np.nonzero(shard_of == m)[0]
        remap = {int(d): i for i, d in enumerate(docs_m)}
        ptr = [0]
        terms = []
        tfs = []
        for d in docs_m:
            t, f = corpus.doc_slice(int(d))
            terms.append(t)
            tfs.append(f)
            ptr.append(ptr[-1] + len(t))
        sub = C(
            n_docs=len(docs_m),
            n_terms=corpus.n_terms,
            doc_ptr=np.asarray(ptr, np.int64),
            doc_terms=np.concatenate(terms) if terms else np.empty(0, np.int32),
            doc_tfs=np.concatenate(tfs) if tfs else np.empty(0, np.int32),
            doc_topic=corpus.doc_topic[docs_m],
            n_topics=corpus.n_topics,
        )
        idx = build_index(
            sub, n_ranges=n_ranges_per_shard, strategy=strategy, bits=bits,
            seed=seed + m, quantizer=global_quant,
        )
        sub_indexes.append(idx)
        engines.append(Engine(idx, k=10))
        del remap

    # Pad per-shard arrays to common sizes and stack shard-major.
    def stack(get, pad_val=0, dtype=np.int32):
        arrs = [np.asarray(get(e.index), dtype=dtype) for e in engines]
        width = max(a.shape[0] for a in arrs)
        out = np.full((n_shards, width), pad_val, dtype=dtype)
        for m, a in enumerate(arrs):
            out[m, : a.shape[0]] = a
        return jnp.asarray(out)

    s_pad = max(e.s_pad for e in engines)
    doc_base = np.zeros(n_shards, np.int32)
    # global id = base + local id; bases spaced by padded shard size
    sizes = [e.index.n_docs for e in engines]
    doc_base[1:] = np.cumsum(sizes)[:-1]

    arrays = ShardedIndexArrays(
        docs=stack(lambda i: i.docs),
        impacts=stack(lambda i: i.impacts),
        blk_start=stack(lambda i: i.blk_start),
        blk_len=stack(lambda i: i.blk_len),
        blk_maximp=stack(lambda i: i.blk_maximp),
        range_starts=stack(lambda i: i.range_starts),
        doc_base=jnp.asarray(doc_base),
        s_pad=s_pad,
        k=10,
    )
    return arrays, engines


def plan_queries(engines, q_terms_batch: np.ndarray):
    """Host-side per-shard plans -> stacked [Q, M, R, B] device tables."""
    M = len(engines)
    Q = q_terms_batch.shape[0]
    plans = [[e.plan(q_terms_batch[qi]) for e in engines] for qi in range(Q)]
    R = max(p.order_host.shape[0] for row in plans for p in row)
    B = max(p.blk_tab.shape[1] for row in plans for p in row)

    blk = np.full((Q, M, R, B), -1, np.int32)
    rest = np.zeros((Q, M, R, B), np.int32)
    order = np.zeros((Q, M, R), np.int32)
    bounds = np.zeros((Q, M, R), np.int32)
    for qi in range(Q):
        for m in range(M):
            p = plans[qi][m]
            r, b = p.blk_tab.shape
            blk[qi, m, :r, :b] = np.asarray(p.blk_tab)
            rest[qi, m, :r, :b] = np.asarray(p.rest_tab)
            order[qi, m, :r] = np.asarray(p.order)
            bounds[qi, m, :r] = np.asarray(p.ordered_bounds)
    return (
        jnp.asarray(blk), jnp.asarray(rest), jnp.asarray(order), jnp.asarray(bounds)
    )


def sharded_query_specs(
    *, n_queries: int, n_shards: int, r_loc: int, b_width: int, nnz_loc: int,
    nb_loc: int, s_pad: int, k: int, impact_dtype=jnp.int32,
):
    """ShapeDtypeStructs for the dry-run (web-scale sharded index).

    ``impact_dtype=jnp.int8`` stores quantized impacts at their native
    8-bit width (the paper's own quantization level) — §Perf cell C.
    """
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    arrays = ShardedIndexArrays(
        docs=i32(n_shards, nnz_loc),
        impacts=jax.ShapeDtypeStruct((n_shards, nnz_loc), impact_dtype),
        blk_start=i32(n_shards, nb_loc),
        blk_len=i32(n_shards, nb_loc),
        blk_maximp=i32(n_shards, nb_loc),
        range_starts=i32(n_shards, r_loc),
        doc_base=i32(n_shards),
        s_pad=s_pad,
        k=k,
    )
    tables = (
        i32(n_queries, n_shards, r_loc, b_width),
        i32(n_queries, n_shards, r_loc, b_width),
        i32(n_queries, n_shards, r_loc),
        i32(n_queries, n_shards, r_loc),
    )
    return arrays, tables


def _local_traverse(arrays_local, blk, rest, order, bounds, *, s_pad, k,
                    budget, prune_blocks=True):
    """Run the single-node traversal on this shard for one query."""
    dix = DeviceIndex(
        docs=arrays_local[0], impacts=arrays_local[1],
        blk_start=arrays_local[2], blk_len=arrays_local[3],
        blk_maximp=arrays_local[4],
        bounds_dense=jnp.zeros((1, 1), jnp.int32),  # bounds arrive via tables
        range_starts=arrays_local[5],
        range_sizes=jnp.zeros_like(arrays_local[5]),
    )
    res = range_daat.device_traverse(
        dix, blk, rest, order, bounds,
        s_pad=s_pad, k=k, budget_postings=budget, safe_stop=True,
        prune_blocks=prune_blocks, impl="xla", interpret=True,
    )
    return res.state.vals, res.state.ids, res.ranges_processed


def make_sharded_query_fn(ctx: ShardCtx, *, s_pad: int, k: int, budget: int):
    """Build the jittable sharded query step (the anytime-ir serve step)."""
    m_axis = ctx.model_axis
    da = ctx.data_axes

    def body(arr_tuple, doc_base, blk, rest, order, bounds):
        # Shapes here are per-shard local: arr [1, ...]; tables [Q_loc, 1, R, B].
        arr_local = tuple(a[0] for a in arr_tuple)
        base = doc_base[0]
        Q = blk.shape[0]

        def one(args):
            b, r_, o, bd = args
            vals, ids, nr = _local_traverse(
                arr_local, b[0], r_[0], o[0], bd[0],
                s_pad=s_pad, k=k, budget=budget,
            )
            gids = jnp.where(ids >= 0, ids + base, -1)
            return vals, gids, nr

        vals, gids, nr = jax.lax.map(one, (blk, rest, order, bounds))
        # Broker merge: gather per-shard top-k and take the global top-k.
        all_vals = jax.lax.all_gather(vals, m_axis)  # [M, Q_loc, k]
        all_ids = jax.lax.all_gather(gids, m_axis)
        mv = jnp.moveaxis(all_vals, 0, 1).reshape(Q, -1)
        mi = jnp.moveaxis(all_ids, 0, 1).reshape(Q, -1)
        sel = jnp.argsort(-mv, axis=1)[:, :k]
        out_v = jnp.take_along_axis(mv, sel, axis=1)
        out_i = jnp.take_along_axis(mi, sel, axis=1)
        return out_v, out_i, jax.lax.psum(jnp.sum(nr), m_axis)

    arr_specs = tuple([P(m_axis, None)] * 6)
    fn = shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(
            arr_specs,
            P(m_axis),
            P(da, m_axis, None, None),
            P(da, m_axis, None, None),
            P(da, m_axis, None),
            P(da, m_axis, None),
        ),
        out_specs=(P(da, None), P(da, None), P()),
        check_vma=False,
    )

    def step(arrays: ShardedIndexArrays, tables):
        blk, rest, order, bounds = tables
        return fn(
            (arrays.docs, arrays.impacts, arrays.blk_start, arrays.blk_len,
             arrays.blk_maximp, arrays.range_starts),
            arrays.doc_base, blk, rest, order, bounds,
        )

    return step


def sharded_anytime_query(arrays, tables, ctx, budget: int = 2**31 - 1):
    step = make_sharded_query_fn(
        ctx, s_pad=arrays.s_pad, k=arrays.k, budget=budget
    )
    return step(arrays, tables)
