"""Anytime top-k candidate retrieval — the paper's technique beyond text.

The recsys ``retrieval_cand`` shape (score one user against 1M items) is
exactly the paper's problem in dense-embedding form. This module applies the
full §3 recipe to maximum-inner-product retrieval:

  * candidate embeddings are k-means clustered into *ranges* (topical
    clustering — here literal vector clustering);
  * each range stores per-dimension extrema (lo[r, d], hi[r, d]); for a
    query q the range score bound is  sum_d max(q_d*lo, q_d*hi)  — the
    dense analogue of BoundSum's U[t, r] (exact for any q, cheap: one
    [R, D] pass);
  * ranges are scored in decreasing bound order on the MXU (chunked
    q @ E_r^T), a running top-k threshold theta enables the same safe
    early termination, and the §6 anytime policies cap work for SLA
    serving (budget in candidates scored).

This is recorded in EXPERIMENTS.md §Perf as the paper-representative cell.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cluster import spherical_kmeans

__all__ = ["ClusteredCandidates", "build_clustered_candidates", "anytime_mips"]

_NEG = jnp.float32(-3.0e38)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("emb", "ids", "lo", "hi"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class ClusteredCandidates:
    emb: jnp.ndarray  # [R, C, D] padded cluster members
    ids: jnp.ndarray  # [R, C] int32 original ids (-1 pad)
    lo: jnp.ndarray  # [R, D] per-dim minima
    hi: jnp.ndarray  # [R, D] per-dim maxima

    @property
    def n_ranges(self) -> int:
        return int(self.emb.shape[0])

    @property
    def capacity(self) -> int:
        return int(self.emb.shape[1])


def build_clustered_candidates(
    embeddings: np.ndarray, n_clusters: int = 64, seed: int = 0, iters: int = 12
) -> ClusteredCandidates:
    """Offline build: cluster + pad + per-dim extrema (index-build stage)."""
    x = np.asarray(embeddings, np.float32)
    n, d = x.shape
    norms = np.linalg.norm(x, axis=1, keepdims=True)
    assign = spherical_kmeans(x / np.maximum(norms, 1e-9), n_clusters, iters=iters, seed=seed)
    order = np.argsort(assign, kind="stable")
    counts = np.bincount(assign, minlength=n_clusters)
    cap = int(counts.max())
    R = n_clusters
    emb = np.zeros((R, cap, d), np.float32)
    ids = np.full((R, cap), -1, np.int32)
    lo = np.zeros((R, d), np.float32)
    hi = np.zeros((R, d), np.float32)
    off = 0
    for r in range(R):
        c = int(counts[r])
        members = order[off : off + c]
        off += c
        if c:
            emb[r, :c] = x[members]
            ids[r, :c] = members
            lo[r] = x[members].min(0)
            hi[r] = x[members].max(0)
    return ClusteredCandidates(
        emb=jnp.asarray(emb), ids=jnp.asarray(ids),
        lo=jnp.asarray(lo), hi=jnp.asarray(hi),
    )


class MIPSResult(NamedTuple):
    ids: jnp.ndarray  # [k] int32
    scores: jnp.ndarray  # [k] f32
    ranges_processed: jnp.ndarray
    candidates_scored: jnp.ndarray
    exit_safe: jnp.ndarray


@functools.partial(jax.jit, static_argnames=("k", "safe_stop"))
def anytime_mips(
    cc: ClusteredCandidates,
    q: jnp.ndarray,  # [D] (or [J, D] multi-interest: max over J)
    *,
    k: int = 10,
    budget_candidates: jnp.ndarray | int = 2**31 - 1,
    max_ranges: jnp.ndarray | int = 2**31 - 1,
    safe_stop: bool = True,
) -> MIPSResult:
    q2 = q if q.ndim == 2 else q[None]
    # BoundSum analogue: max over interests of the per-dim extrema bound.
    bound = jnp.max(
        jnp.sum(jnp.maximum(q2[:, None] * cc.lo[None], q2[:, None] * cc.hi[None]), -1),
        axis=0,
    )  # [R]
    order = jnp.argsort(-bound).astype(jnp.int32)
    sorted_bound = bound[order]
    R, C, D = cc.emb.shape
    budget = jnp.asarray(budget_candidates, jnp.int32)
    maxr = jnp.asarray(max_ranges, jnp.int32)

    def cond(carry):
        i, vals, ids, scored, stop_safe, stop_budget = carry
        return (i < R) & ~stop_safe & ~stop_budget

    def body(carry):
        i, vals, ids, scored, stop_safe, stop_budget = carry
        r = order[i]
        theta = vals[-1]
        filled = ids[-1] >= 0  # k-th slot occupied -> theta is real
        s_safe = safe_stop & filled & (sorted_bound[i] <= theta)
        s_budget = (scored >= budget) | (i >= maxr)
        do = ~(s_safe | s_budget)

        def run(vals, ids, scored):
            scores = jnp.max(
                jnp.einsum("jd,cd->jc", q2, cc.emb[r],
                           preferred_element_type=jnp.float32),
                axis=0,
            )  # [C]
            valid = cc.ids[r] >= 0
            scores = jnp.where(valid, scores, _NEG)
            cv, ci = jax.lax.top_k(scores, min(k, C))
            cand_ids = jnp.where(cv > _NEG, cc.ids[r][ci], -1)
            mv = jnp.concatenate([vals, cv])
            mi = jnp.concatenate([ids, cand_ids])
            order2 = jnp.argsort(-mv)[:k]
            return mv[order2], mi[order2], scored + jnp.sum(valid, dtype=jnp.int32)

        vals, ids, scored = jax.lax.cond(
            do, run, lambda v, i_, s: (v, i_, s), vals, ids, scored
        )
        return (i + jnp.where(do, 1, 0), vals, ids, scored, s_safe, s_budget)

    carry = (
        jnp.zeros((), jnp.int32),
        jnp.full((k,), _NEG, jnp.float32),
        jnp.full((k,), -1, jnp.int32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((), bool),
        jnp.zeros((), bool),
    )
    i, vals, ids, scored, s_safe, s_budget = jax.lax.while_loop(cond, body, carry)
    return MIPSResult(
        ids=ids, scores=vals, ranges_processed=i,
        candidates_scored=scored, exit_safe=s_safe,
    )
