# Batched anytime serving: shape-bucketed, vmapped device traversal with
# per-query budgets, plus the SLA-governed micro-batching request loop.
from repro.serving.batch_engine import BatchEngine, BatchResult, INT32_MAX  # noqa: F401
from repro.serving.bucketing import BatchedPlan, BucketSpec, bucket_pow2, stack_plans  # noqa: F401
from repro.serving.microbatch import MicroBatchServer, ServedQuery, SlaBudgeter  # noqa: F401
