# Batched anytime serving: shape-bucketed, vmapped device traversal with
# per-query budgets, the SLA-governed micro-batching request loop, the
# slot-swapping in-flight loop, and the range-sharded multi-device engine
# (DESIGN.md §3-§4, §11).
from repro.serving.batch_engine import BatchEngine, BatchResult, INT32_MAX  # noqa: F401
from repro.serving.bucketing import (  # noqa: F401
    BatchedPlan,
    BucketSpec,
    DoubleBuffer,
    SlotTable,
    bucket_pow2,
    stack_plans,
)
from repro.serving.inflight import InflightServer  # noqa: F401
from repro.serving.microbatch import (  # noqa: F401
    MicroBatchServer,
    ServedQuery,
    ShardedSlaBudgeter,
    SlaBudgeter,
    result_exit_reason,
)
from repro.serving.sharded import (  # noqa: F401
    ShardedBatchEngine,
    ShardedEngine,
    ShardedResult,
    shard_exit_reason,
    sharded_batched_traverse,
)
