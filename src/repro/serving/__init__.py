# Batched anytime serving: shape-bucketed, vmapped device traversal with
# per-query budgets, the SLA-governed micro-batching request loop, and the
# range-sharded multi-device engine (DESIGN.md §3-§4).
from repro.serving.batch_engine import BatchEngine, BatchResult, INT32_MAX  # noqa: F401
from repro.serving.bucketing import BatchedPlan, BucketSpec, bucket_pow2, stack_plans  # noqa: F401
from repro.serving.microbatch import (  # noqa: F401
    MicroBatchServer,
    ServedQuery,
    ShardedSlaBudgeter,
    SlaBudgeter,
)
from repro.serving.sharded import (  # noqa: F401
    ShardedBatchEngine,
    ShardedEngine,
    ShardedResult,
    shard_exit_reason,
    sharded_batched_traverse,
)
