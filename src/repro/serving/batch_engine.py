"""Batched anytime query engine (vmapped device traversal).

``BatchEngine`` wraps the single-query ``core.range_daat.Engine`` with a
batch execution path: plans are snapped to a small ladder of static shapes
(see ``bucketing``), stacked into one pytree per shape, and traversed by a
single ``batched_traverse`` dispatch per group. Budgets are **per query** —
the postings/range caps travel down the vmap lane with the plan, so a heavy
query exhausts *its* budget while light lanes in the same batch run to safe
or exhaustive completion. Results are bitwise identical to looping
``device_traverse`` over the same plans (tests/test_batch_serving.py).
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.range_daat import (
    Engine,
    QueryPlan,
    batched_traverse,
    exit_reason,
)
from repro.obs.profiler import jit_cache_size
from repro.serving.bucketing import (
    BucketSpec,
    batch_ladder,
    dummy_plan,
    iter_bucket_chunks,
    stack_plans,
)

__all__ = ["BatchResult", "BatchEngine", "INT32_MAX", "lane_result"]

INT32_MAX = 2**31 - 1


class BatchResult(NamedTuple):
    """Host-side per-query outcome of a batched traversal."""

    doc_ids: np.ndarray  # [<=k] int32, score-desc / docid-asc
    scores: np.ndarray  # [<=k] int32
    ranges_processed: int
    postings: int
    blocks: int
    exit_safe: bool
    exit_budget: bool

    @property
    def exit_reason(self) -> str:
        return exit_reason(self.exit_safe, self.exit_budget)


def lane_result(
    vals: np.ndarray,
    ids: np.ndarray,
    postings: np.ndarray,
    blocks: np.ndarray,
    ranges: np.ndarray,
    safe: np.ndarray,
    budg: np.ndarray,
    lane: int,
) -> BatchResult:
    """Unpack one lane of host-side batched traversal state.

    Shared by the micro-batch chunk path and the in-flight slot loop so
    both servers materialise byte-identical ``BatchResult``s from the same
    lane state.
    """
    keep = ids[lane] >= 0
    return BatchResult(
        doc_ids=ids[lane][keep],
        scores=vals[lane][keep],
        ranges_processed=int(ranges[lane]),
        postings=int(postings[lane]),
        blocks=int(blocks[lane]),
        exit_safe=bool(safe[lane]),
        exit_budget=bool(budg[lane]),
    )


def _per_query(value, n: int, default: int) -> np.ndarray:
    """Broadcast a scalar-or-sequence budget to an [n] int32 array."""
    if value is None:
        return np.full(n, default, dtype=np.int32)
    arr = np.asarray(value, dtype=np.int64)
    if arr.ndim == 0:
        arr = np.full(n, int(arr), dtype=np.int64)
    if arr.shape != (n,):
        raise ValueError(f"budget shape {arr.shape} != ({n},)")
    return np.clip(arr, 0, INT32_MAX).astype(np.int32)


class BatchEngine:
    """Micro-batch executor over a cluster-skipping index.

    Shape discipline: every dispatch has shape (batch_bucket, R,
    width_bucket) with R and s_pad fixed by the index, so the XLA program
    cache is bounded by ``len(width buckets) x len(batch buckets)``.
    ``compiled_shapes`` records which (batch, width) programs have been
    requested — tests use it to assert the recompile bound holds.
    """

    def __init__(
        self, engine: Engine, spec: BucketSpec | None = None, obs=None
    ):
        from repro.obs import NOOP

        self.engine = engine
        self.spec = spec or BucketSpec()
        self.obs = obs if obs is not None else NOOP
        self.compiled_shapes: set[tuple[int, int]] = set()
        self.batches_run = 0

    @classmethod
    def from_artifact(
        cls, path: str, spec: BucketSpec | None = None, **engine_kwargs
    ) -> "BatchEngine":
        """Serve a saved index artifact (DESIGN.md §8).

        ``engine_kwargs`` pass through to ``core.range_daat.Engine``;
        ``impact_dtype`` defaults to the artifact's stored dtype, so an
        int8 artifact serves with int8 postings impacts in HBM.
        """
        return cls(Engine.from_artifact(path, **engine_kwargs), spec)

    # ------------------------------------------------------------- planning
    def plan(self, q_terms: np.ndarray) -> QueryPlan:
        return self.engine.plan(q_terms)

    def plan_many(self, queries: Sequence[np.ndarray]) -> list[QueryPlan]:
        return [self.engine.plan(q) for q in queries]

    # ------------------------------------------------------------ execution
    def run_batch(
        self,
        plans: Sequence[QueryPlan],
        budget_postings=None,
        max_ranges=None,
        safe_stop: bool = True,
        prune_blocks: bool = True,
    ) -> list[BatchResult]:
        """Traverse ``plans`` in vmapped groups; results keep input order.

        ``budget_postings`` / ``max_ranges`` may be None (unbounded), a
        scalar applied to every query, or a length-len(plans) sequence of
        per-query caps.
        """
        n = len(plans)
        if n == 0:
            return []
        budgets = _per_query(budget_postings, n, INT32_MAX)
        maxr = _per_query(max_ranges, n, INT32_MAX)

        results: list[BatchResult | None] = [None] * n
        for width, chunk in iter_bucket_chunks(plans, self.spec):
            self._run_chunk(
                [plans[i] for i in chunk],
                chunk,
                width,
                budgets,
                maxr,
                safe_stop,
                prune_blocks,
                results,
            )
        return results  # type: ignore[return-value]

    def _run_chunk(
        self,
        chunk_plans: list[QueryPlan],
        chunk_idx: list[int],
        width: int,
        budgets: np.ndarray,
        maxr: np.ndarray,
        safe_stop: bool,
        prune_blocks: bool,
        results: list,
    ) -> None:
        batch = self.spec.batch_bucket(len(chunk_plans))
        prof = self.obs.profiler if self.obs.enabled else None
        if prof is not None:
            clk = self.obs.clock
            t_plan0 = clk()
        bp = stack_plans(chunk_plans, width, batch)

        # Dummy lanes get zero budgets -> they exit at i=0 having done no work.
        b = np.zeros(batch, dtype=np.int32)
        m = np.zeros(batch, dtype=np.int32)
        b[: len(chunk_idx)] = budgets[chunk_idx]
        m[: len(chunk_idx)] = maxr[chunk_idx]

        eng = self.engine
        if prof is not None:
            cache0 = jit_cache_size(batched_traverse)
            t_disp0 = clk()
        res = batched_traverse(
            eng.dix,
            bp.blk_tab,
            bp.rest_tab,
            bp.order,
            bp.ordered_bounds,
            jnp.asarray(b),
            jnp.asarray(m),
            s_pad=eng.s_pad,
            k=eng.k,
            safe_stop=safe_stop,
            prune_blocks=prune_blocks,
            impl=eng.impl,
            interpret=eng.interpret,
            docs_format=eng.docs_format,
        )
        self.compiled_shapes.add((batch, width))
        self.batches_run += 1
        if prof is not None:
            # Timing-only: the extra sync point moves the device wait out
            # of the np.asarray conversions below; results are untouched.
            t_dev0 = clk()
            jax.block_until_ready(res)  # analysis: allow[HOSTSYNC]
            t_dev1 = clk()

        # analysis: allow[HOSTSYNC] this IS run_batch's drain boundary:
        # every lane of the chunk is finished and consumed right below.
        vals = np.asarray(res.state.vals)
        ids = np.asarray(res.state.ids)
        postings = np.asarray(res.state.postings)
        blocks = np.asarray(res.state.blocks)
        ranges = np.asarray(res.ranges_processed)
        safe = np.asarray(res.exit_safe)
        budg = np.asarray(res.exit_budget)
        if prof is not None:
            t_xfer1 = clk()
            prof.record_dispatch(
                "batch_engine",
                (batch, width),
                cache_before=cache0,
                cache_after=jit_cache_size(batched_traverse),
                plan_ms=(t_disp0 - t_plan0) * 1e3,
                dispatch_ms=(t_dev0 - t_disp0) * 1e3,
                device_ms=(t_dev1 - t_dev0) * 1e3,
                transfer_ms=(t_xfer1 - t_dev1) * 1e3,
            )
            prof.record_hbm_once("batch_engine", eng.dix._asdict())
        for lane, qi in enumerate(chunk_idx):
            results[qi] = lane_result(
                vals, ids, postings, blocks, ranges, safe, budg, lane
            )
        if self.obs.enabled:
            self.obs.observe("batch_engine_chunk_lanes", len(chunk_idx))
            for lane in range(len(chunk_idx)):
                self.obs.count(
                    "batch_engine_queries",
                    reason=exit_reason(bool(safe[lane]), bool(budg[lane])),
                )

    # ---------------------------------------------------------------- misc
    def warmup(self, widths: Sequence[int] | None = None) -> None:
        """Pre-compile every (batch_bucket, width) program for given widths."""
        R = self.engine.index.n_ranges
        for w in widths or (self.spec.min_width,):
            dummy = dummy_plan(R, self.spec.width_bucket(w))
            for nb in batch_ladder(self.spec):
                self.run_batch([dummy] * nb)
