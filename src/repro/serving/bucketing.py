"""Shape bucketing for batched device traversal.

``device_traverse`` is jit-compiled per static shape, so a naive batch path
would recompile for every distinct (batch, block-table width) pair the query
stream produces. We instead snap both axes to a small geometric ladder:

  * width buckets — the block-table width B (the ragged per-query axis) is
    padded up to the next power of two >= ``min_width``. Padding columns are
    ``-1`` block ids, which the scorer drops before touching memory, so a
    padded plan is *bitwise* equivalent to the unpadded one.
  * batch buckets — a group of same-width plans is padded up to the next
    power of two with inert dummy lanes (``max_ranges = 0`` and
    ``budget = 0``) whose results are discarded on unstack.

With R (ranges) and s_pad fixed per index, the total number of XLA programs
the engine can ever compile is #width_buckets x #batch_buckets — typically
under a dozen — after which serving is allocation + dispatch only.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.range_daat import QueryPlan

__all__ = [
    "BucketSpec",
    "BatchedPlan",
    "DoubleBuffer",
    "SlotTable",
    "batch_ladder",
    "bucket_pow2",
    "dummy_plan",
    "iter_bucket_chunks",
    "saturate_bounds",
    "stack_plans",
]

INT32_MAX = 2**31 - 1


def bucket_pow2(n: int, lo: int = 1, hi: int | None = None) -> int:
    """Smallest power of two >= n, clamped to [lo, hi]."""
    if lo < 1:
        raise ValueError(f"bucket_pow2 needs lo >= 1, got lo={lo}")
    if hi is not None and hi < lo:
        raise ValueError(f"bucket_pow2 needs hi >= lo, got lo={lo} hi={hi}")
    v = lo
    while v < n:
        v *= 2
    if hi is not None:
        v = min(v, hi)
    return v


def saturate_bounds(bounds_host: np.ndarray) -> np.ndarray:
    """Narrow int64 per-range BoundSums to the device's int32 lattice.

    A BoundSum past 2^31 must *saturate*, never wrap: a wrapped-negative
    bound satisfies ``bound <= theta`` immediately and silently disables
    safe termination for that range. Saturation errs conservative (the
    range merely looks too promising to skip).
    """
    b = np.asarray(bounds_host)
    if np.any(b < 0):
        raise ValueError(
            "negative per-range BoundSum — upstream impact quantisation bug?"
        )
    if np.any(b > INT32_MAX):
        warnings.warn(
            "per-range BoundSum exceeds int32; saturating to 2^31-1 "
            "(safe termination stays conservative for the affected ranges)",
            RuntimeWarning,
            stacklevel=2,
        )
        b = np.minimum(b, INT32_MAX)
    return b.astype(np.int32)


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Static-shape ladder for the batch path."""

    min_width: int = 32  # floor for the block-table width bucket
    max_batch: int = 32  # batch lanes per device program (upper bucket)
    min_batch: int = 1  # floor for the batch-size bucket

    def __post_init__(self):
        if self.min_width < 1 or self.max_batch < 1 or self.min_batch < 1:
            raise ValueError(
                f"BucketSpec sizes must be >= 1, got min_width={self.min_width} "
                f"max_batch={self.max_batch} min_batch={self.min_batch}"
            )
        if self.min_batch > self.max_batch:
            raise ValueError(
                f"min_batch={self.min_batch} > max_batch={self.max_batch}"
            )

    def width_bucket(self, width: int) -> int:
        return bucket_pow2(width, lo=self.min_width)

    def batch_bucket(self, n: int) -> int:
        return bucket_pow2(n, lo=self.min_batch, hi=self.max_batch)


def iter_bucket_chunks(plans: Sequence[QueryPlan], spec: BucketSpec):
    """Group plan indices by width bucket, chunked to ``max_batch`` lanes.

    Yields ``(width_bucket, [plan indices])`` in deterministic (width, then
    arrival) order — the shared dispatch-grouping loop of the batch engines.
    """
    groups: dict[int, list[int]] = {}
    for i, p in enumerate(plans):
        groups.setdefault(spec.width_bucket(p.blk_tab.shape[1]), []).append(i)
    for width, idxs in sorted(groups.items()):
        for lo in range(0, len(idxs), spec.max_batch):
            yield width, idxs[lo : lo + spec.max_batch]


def batch_ladder(spec: BucketSpec) -> list[int]:
    """Every reachable batch bucket: powers of two from ``min_batch``, plus
    ``max_batch`` itself (``batch_bucket`` clamps there, so a non-power-of-
    two ``max_batch`` is a reachable shape the pow2 ladder would miss)."""
    out = []
    b = spec.min_batch
    while b <= spec.max_batch:
        out.append(b)
        b *= 2
    if out[-1] != spec.max_batch:
        out.append(spec.max_batch)
    return out


def dummy_plan(n_ranges: int, width: int) -> QueryPlan:
    """An inert all-padding plan (for warmup compiles and pad lanes)."""
    return QueryPlan(
        q_terms=np.asarray([-1], np.int32),
        blk_tab=jnp.full((n_ranges, width), -1, jnp.int32),
        rest_tab=jnp.zeros((n_ranges, width), jnp.int32),
        order=jnp.arange(n_ranges, dtype=jnp.int32),
        ordered_bounds=jnp.zeros((n_ranges,), jnp.int32),
        order_host=np.arange(n_ranges, dtype=np.int32),
        bounds_host=np.zeros(n_ranges, dtype=np.int64),
    )


class BatchedPlan(NamedTuple):
    """Stacked, padded pytree of query plans — direct ``batched_traverse`` input."""

    blk_tab: jnp.ndarray  # [N, R, B] int32, -1 padded
    rest_tab: jnp.ndarray  # [N, R, B] int32
    order: jnp.ndarray  # [N, R] int32
    ordered_bounds: jnp.ndarray  # [N, R] int32
    valid: np.ndarray  # [N] bool host mask — False on dummy pad lanes


def _pad_width(tab: np.ndarray, width: int, fill: int) -> np.ndarray:
    if tab.shape[1] == width:
        return tab
    return np.pad(tab, ((0, 0), (0, width - tab.shape[1])), constant_values=fill)


class SlotTable:
    """Mutable host-side staging for one device program's lane inputs.

    ``n_slots`` lanes x ``n_ranges`` x ``width`` block/rest tables, plus
    per-lane order, bounds, budget, and max_ranges. This is the slot
    state-machine's *plan* half (the traversal-carry half lives in
    ``range_daat.TraverseCarry``): an in-flight server writes admitted
    queries into vacant lanes, clears exited ones, and snapshots the whole
    table to device arrays once per dispatch.

    A cleared (vacant) lane is inert: all ``-1`` blocks, zero bounds, zero
    budget, ``max_ranges = 0`` — the traversal cond fails on the first
    iteration, so a vacant lane costs nothing per quantum.
    """

    def __init__(self, n_slots: int, n_ranges: int, width: int):
        if n_slots < 1 or n_ranges < 1 or width < 1:
            raise ValueError(
                f"SlotTable needs positive dims, got n_slots={n_slots} "
                f"n_ranges={n_ranges} width={width}"
            )
        self.n_slots = n_slots
        self.n_ranges = n_ranges
        self.width = width
        self.blk = np.full((n_slots, n_ranges, width), -1, dtype=np.int32)
        self.rest = np.zeros((n_slots, n_ranges, width), dtype=np.int32)
        self.order = np.tile(
            np.arange(n_ranges, dtype=np.int32), (n_slots, 1)
        )
        self.bounds = np.zeros((n_slots, n_ranges), dtype=np.int32)
        self.budget = np.zeros(n_slots, dtype=np.int32)
        self.maxr = np.zeros(n_slots, dtype=np.int32)
        self.valid = np.zeros(n_slots, dtype=bool)

    def write_lane(
        self,
        lane: int,
        plan: QueryPlan,
        budget: int = INT32_MAX,
        max_ranges: int = INT32_MAX,
    ) -> None:
        """Stage ``plan`` into ``lane``; bounds saturate (never wrap) to int32."""
        if plan.blk_tab.shape[0] != self.n_ranges:
            raise ValueError(
                f"plan has R={plan.blk_tab.shape[0]}, table has R={self.n_ranges}"
            )
        w = plan.blk_tab.shape[1]
        if w > self.width:
            raise ValueError(f"plan width {w} > table width {self.width}")
        self.blk[lane] = _pad_width(
            np.asarray(plan.blk_tab, dtype=np.int32), self.width, -1
        )
        self.rest[lane] = _pad_width(
            np.asarray(plan.rest_tab, dtype=np.int32), self.width, 0
        )
        self.order[lane] = plan.order_host
        self.bounds[lane] = saturate_bounds(plan.bounds_host)
        self.budget[lane] = min(int(budget), INT32_MAX)
        self.maxr[lane] = min(int(max_ranges), INT32_MAX)
        self.valid[lane] = True

    def clear_lane(self, lane: int) -> None:
        self.blk[lane] = -1
        self.rest[lane] = 0
        self.order[lane] = np.arange(self.n_ranges, dtype=np.int32)
        self.bounds[lane] = 0
        self.budget[lane] = 0
        self.maxr[lane] = 0
        self.valid[lane] = False

    def copy_from(self, other: "SlotTable") -> None:
        """Overwrite this table's contents with ``other``'s (same shape)."""
        if (other.n_slots, other.n_ranges, other.width) != (
            self.n_slots,
            self.n_ranges,
            self.width,
        ):
            raise ValueError("SlotTable shapes differ")
        for name in ("blk", "rest", "order", "bounds", "budget", "maxr", "valid"):
            getattr(self, name)[:] = getattr(other, name)

    def grow_width(self, width: int) -> "SlotTable":
        """A fresh table with a wider block-table axis, contents carried over.

        Width growth is the one event that changes the in-flight program
        shape; keeping it on the pow2 ladder bounds recompiles.
        """
        if width < self.width:
            raise ValueError(f"cannot shrink width {self.width} -> {width}")
        out = SlotTable(self.n_slots, self.n_ranges, width)
        out.blk[:, :, : self.width] = self.blk
        out.rest[:, :, : self.width] = self.rest
        out.order[:] = self.order
        out.bounds[:] = self.bounds
        out.budget[:] = self.budget
        out.maxr[:] = self.maxr
        out.valid[:] = self.valid
        return out

    def device_arrays(self):
        """Snapshot the staging arrays to device (jnp) inputs."""
        return (
            jnp.asarray(self.blk),
            jnp.asarray(self.rest),
            jnp.asarray(self.order),
            jnp.asarray(self.bounds),
            jnp.asarray(self.budget),
            jnp.asarray(self.maxr),
        )


class DoubleBuffer:
    """Front/back pair of ``SlotTable``s for overlap of admission and scoring.

    The *front* table is what the current device dispatch reads (its
    snapshot is already in flight under JAX's async dispatch); lane writes
    for the *next* quantum (clears for exited queries, admissions from the
    queue) land in the *back* table. ``swap()`` flips the roles between
    dispatches, so host-side planning overlaps device execution instead of
    serialising with it.
    """

    def __init__(self, n_slots: int, n_ranges: int, width: int):
        self.front = SlotTable(n_slots, n_ranges, width)
        self.back = SlotTable(n_slots, n_ranges, width)

    def swap(self) -> None:
        self.front, self.back = self.back, self.front
        # The new back starts as a copy of what is now in flight, so lane
        # writes are deltas against the live table, not a blank slate.
        self.back.copy_from(self.front)

    def grow_width(self, width: int) -> None:
        self.front = self.front.grow_width(width)
        self.back = self.back.grow_width(width)


def stack_plans(
    plans: Sequence[QueryPlan], width: int, batch: int
) -> BatchedPlan:
    """Stack ``plans`` into one [batch, R, width] pytree with dummy padding.

    Every plan must have block-table width <= ``width`` and the same R.
    Dummy lanes (indices >= len(plans)) get all ``-1`` block tables and zero
    bounds; callers must also zero their budgets so they exit immediately.
    Per-range bounds saturate (with a warning) rather than wrap when the
    int64 ``bounds_host`` exceeds int32.
    """
    n = len(plans)
    if n == 0 or n > batch:
        raise ValueError(f"need 0 < len(plans)={n} <= batch={batch}")
    R = plans[0].blk_tab.shape[0]

    table = SlotTable(batch, R, width)
    for i, p in enumerate(plans):
        if p.blk_tab.shape[0] != R:
            raise ValueError("all plans in a batch must share the same R")
        table.write_lane(i, p)

    return BatchedPlan(
        blk_tab=jnp.asarray(table.blk),
        rest_tab=jnp.asarray(table.rest),
        order=jnp.asarray(table.order),
        ordered_bounds=jnp.asarray(table.bounds),
        valid=table.valid.copy(),
    )
