"""Shape bucketing for batched device traversal.

``device_traverse`` is jit-compiled per static shape, so a naive batch path
would recompile for every distinct (batch, block-table width) pair the query
stream produces. We instead snap both axes to a small geometric ladder:

  * width buckets — the block-table width B (the ragged per-query axis) is
    padded up to the next power of two >= ``min_width``. Padding columns are
    ``-1`` block ids, which the scorer drops before touching memory, so a
    padded plan is *bitwise* equivalent to the unpadded one.
  * batch buckets — a group of same-width plans is padded up to the next
    power of two with inert dummy lanes (``max_ranges = 0`` and
    ``budget = 0``) whose results are discarded on unstack.

With R (ranges) and s_pad fixed per index, the total number of XLA programs
the engine can ever compile is #width_buckets x #batch_buckets — typically
under a dozen — after which serving is allocation + dispatch only.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.range_daat import QueryPlan

__all__ = [
    "BucketSpec",
    "BatchedPlan",
    "batch_ladder",
    "bucket_pow2",
    "dummy_plan",
    "iter_bucket_chunks",
    "stack_plans",
]


def bucket_pow2(n: int, lo: int = 1, hi: int | None = None) -> int:
    """Smallest power of two >= n, clamped to [lo, hi]."""
    v = lo
    while v < n:
        v *= 2
    if hi is not None:
        v = min(v, hi)
    return v


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Static-shape ladder for the batch path."""

    min_width: int = 32  # floor for the block-table width bucket
    max_batch: int = 32  # batch lanes per device program (upper bucket)
    min_batch: int = 1  # floor for the batch-size bucket

    def __post_init__(self):
        if self.min_width < 1 or self.max_batch < 1 or self.min_batch < 1:
            raise ValueError(
                f"BucketSpec sizes must be >= 1, got min_width={self.min_width} "
                f"max_batch={self.max_batch} min_batch={self.min_batch}"
            )
        if self.min_batch > self.max_batch:
            raise ValueError(
                f"min_batch={self.min_batch} > max_batch={self.max_batch}"
            )

    def width_bucket(self, width: int) -> int:
        return bucket_pow2(width, lo=self.min_width)

    def batch_bucket(self, n: int) -> int:
        return bucket_pow2(n, lo=self.min_batch, hi=self.max_batch)


def iter_bucket_chunks(plans: Sequence[QueryPlan], spec: BucketSpec):
    """Group plan indices by width bucket, chunked to ``max_batch`` lanes.

    Yields ``(width_bucket, [plan indices])`` in deterministic (width, then
    arrival) order — the shared dispatch-grouping loop of the batch engines.
    """
    groups: dict[int, list[int]] = {}
    for i, p in enumerate(plans):
        groups.setdefault(spec.width_bucket(p.blk_tab.shape[1]), []).append(i)
    for width, idxs in sorted(groups.items()):
        for lo in range(0, len(idxs), spec.max_batch):
            yield width, idxs[lo : lo + spec.max_batch]


def batch_ladder(spec: BucketSpec) -> list[int]:
    """Every reachable batch bucket: powers of two from ``min_batch``, plus
    ``max_batch`` itself (``batch_bucket`` clamps there, so a non-power-of-
    two ``max_batch`` is a reachable shape the pow2 ladder would miss)."""
    out = []
    b = spec.min_batch
    while b <= spec.max_batch:
        out.append(b)
        b *= 2
    if out[-1] != spec.max_batch:
        out.append(spec.max_batch)
    return out


def dummy_plan(n_ranges: int, width: int) -> QueryPlan:
    """An inert all-padding plan (for warmup compiles and pad lanes)."""
    return QueryPlan(
        q_terms=np.asarray([-1], np.int32),
        blk_tab=jnp.full((n_ranges, width), -1, jnp.int32),
        rest_tab=jnp.zeros((n_ranges, width), jnp.int32),
        order=jnp.arange(n_ranges, dtype=jnp.int32),
        ordered_bounds=jnp.zeros((n_ranges,), jnp.int32),
        order_host=np.arange(n_ranges, dtype=np.int32),
        bounds_host=np.zeros(n_ranges, dtype=np.int64),
    )


class BatchedPlan(NamedTuple):
    """Stacked, padded pytree of query plans — direct ``batched_traverse`` input."""

    blk_tab: jnp.ndarray  # [N, R, B] int32, -1 padded
    rest_tab: jnp.ndarray  # [N, R, B] int32
    order: jnp.ndarray  # [N, R] int32
    ordered_bounds: jnp.ndarray  # [N, R] int32
    valid: np.ndarray  # [N] bool host mask — False on dummy pad lanes


def _pad_width(tab: np.ndarray, width: int, fill: int) -> np.ndarray:
    if tab.shape[1] == width:
        return tab
    return np.pad(tab, ((0, 0), (0, width - tab.shape[1])), constant_values=fill)


def stack_plans(
    plans: Sequence[QueryPlan], width: int, batch: int
) -> BatchedPlan:
    """Stack ``plans`` into one [batch, R, width] pytree with dummy padding.

    Every plan must have block-table width <= ``width`` and the same R.
    Dummy lanes (indices >= len(plans)) get all ``-1`` block tables and zero
    bounds; callers must also zero their budgets so they exit immediately.
    """
    n = len(plans)
    if n == 0 or n > batch:
        raise ValueError(f"need 0 < len(plans)={n} <= batch={batch}")
    R = plans[0].blk_tab.shape[0]

    blk = np.full((batch, R, width), -1, dtype=np.int32)
    rest = np.zeros((batch, R, width), dtype=np.int32)
    order = np.zeros((batch, R), dtype=np.int32)
    bounds = np.zeros((batch, R), dtype=np.int32)
    order[:] = np.arange(R, dtype=np.int32)  # dummy lanes: identity order

    for i, p in enumerate(plans):
        if p.blk_tab.shape[0] != R:
            raise ValueError("all plans in a batch must share the same R")
        blk[i] = _pad_width(np.asarray(p.blk_tab, dtype=np.int32), width, -1)
        rest[i] = _pad_width(np.asarray(p.rest_tab, dtype=np.int32), width, 0)
        order[i] = p.order_host
        bounds[i] = np.asarray(p.bounds_host, dtype=np.int32)

    valid = np.zeros(batch, dtype=bool)
    valid[:n] = True
    return BatchedPlan(
        blk_tab=jnp.asarray(blk),
        rest_tab=jnp.asarray(rest),
        order=jnp.asarray(order),
        ordered_bounds=jnp.asarray(bounds),
        valid=valid,
    )
