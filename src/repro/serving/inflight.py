"""Continuous in-flight batching: a slot-based anytime serving loop.

``MicroBatchServer`` serves stop-and-go: collect a batch, pad, dispatch,
drain — under vmap every lane pays for the slowest lane's iterations
(``lax.cond`` lowers to ``select``), so one straggler query holds its whole
micro-batch hostage. That convoy effect is exactly the p99 behaviour the
anytime machinery exists to kill.

``InflightServer`` instead keeps one persistent device program hot and
treats each batch lane as a *slot* holding one query's traversal state —
top-k heap, range cursor, cumulative work counters, exit flags — stepped a
fixed range *quantum* at a time via ``range_daat.batched_traverse_resume``.
A query that exits (safe, budget, or exhausted) frees its slot mid-flight;
an admitted query from the queue swaps in on the next quantum without
recompiling and without waiting for its former batchmates. Vacant slots
ride along parked (``exit_budget`` raised), so the resume loop's condition
fails before any work and an empty lane costs nothing per dispatch.

Correctness contract (pinned tier-1 in tests/test_inflight.py): the carry
round-trips host<->device bitwise, so a query served across N quanta is
*identical* — doc ids, scores, work counters, exit reason — to the same
query served by one ``device_traverse`` call.

Staging is double-buffered (``bucketing.DoubleBuffer``): the front
``SlotTable``'s snapshot is what the in-flight dispatch reads, lane writes
(clears for exits, admissions from the queue) land in the back table, and
the buffers swap between dispatches. Combined with JAX's async dispatch,
host-side query planning (``_plan_lookahead``) overlaps device execution
instead of serialising with it.

Budgets are fixed at *admission time* from the shared ``SlaBudgeter``
machinery: the rate EWMA learns postings/ms/lane from per-step device time,
while Reactive Eq. (7) judges each query's end-to-end latency (queue wait
included) at completion — the split introduced for `MicroBatchServer`'s
queue-aware feedback applies unchanged here.
"""

from __future__ import annotations

from collections import deque
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.range_daat import (
    QueryPlan,
    TraverseCarry,
    batched_init_carry,
    batched_traverse_resume,
    carry_done,
)
from repro.serving.batch_engine import (
    INT32_MAX,
    BatchEngine,
    lane_result,
)
from repro.obs import NOOP
from repro.obs.profiler import jit_cache_size
from repro.serving.bucketing import DoubleBuffer
from repro.serving.microbatch import ServedQuery, SlaBudgeter, result_exit_reason

__all__ = ["InflightServer"]


def _carry_to_device(carry: TraverseCarry) -> TraverseCarry:
    return jax.tree_util.tree_map(jnp.asarray, carry)


def _carry_to_host(carry: TraverseCarry) -> TraverseCarry:
    # np.array (not asarray): the host copy is mutated in place on lane
    # admission/parking and must not alias a device buffer.
    return jax.tree_util.tree_map(lambda x: np.array(x), carry)


class InflightServer:
    """Slot-swapping continuous serving loop over one device program.

    Parameters
    ----------
    bengine: planning + engine access (``BatchEngine``); the in-flight path
        dispatches ``batched_traverse_resume`` itself rather than going
        through ``run_batch``.
    budgeter: ``SlaBudgeter`` (or subclass) — admission-time postings
        budgets plus the Eq. (7) feedback loop.
    n_slots: batch lanes in the persistent program. Unlike micro-batching
        there is no batch-size ladder: one program per (n_slots, width).
    quantum: ranges traversed per dispatch per lane. Small quanta swap
        slots promptly (better p99 under skew) at more dispatch overhead;
        large quanta amortise dispatch but re-introduce convoy time up to
        ``quantum - 1`` ranges.
    """

    def __init__(
        self,
        bengine: BatchEngine,
        budgeter: SlaBudgeter,
        n_slots: int = 8,
        quantum: int = 1,
        clock=None,
        obs=NOOP,
    ):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        self.bengine = bengine
        self.engine = bengine.engine
        self.budgeter = budgeter
        self.n_slots = int(n_slots)
        self.quantum = int(quantum)
        self.obs = obs
        # Same clock-resolution rule as MicroBatchServer: explicit wins,
        # else the instrumentation handle's (wall clock on NOOP).
        self.clock = clock if clock is not None else obs.clock
        self.n_ranges = int(self.engine.index.n_ranges)

        self.buffers = DoubleBuffer(
            self.n_slots, self.n_ranges, bengine.spec.min_width
        )
        # Host-resident carry: every lane starts parked (vacant).
        self.carry = batched_init_carry(self.n_slots, self.engine.k, parked=True)

        self.slot_rid = np.full(self.n_slots, -1, dtype=np.int64)
        self.slot_t_enq = np.zeros(self.n_slots, dtype=np.float64)
        self.slot_t_adm = np.zeros(self.n_slots, dtype=np.float64)
        self.slot_quanta = np.zeros(self.n_slots, dtype=np.int64)
        self._prev_postings = np.zeros(self.n_slots, dtype=np.int64)

        self._queue: deque[tuple[int, np.ndarray, float]] = deque()
        self._planned: deque[tuple[int, QueryPlan, float]] = deque()
        self._next_rid = 0

        self.compiled_shapes: set[tuple[int, int]] = set()
        self.steps_run = 0
        self.admissions = 0

    # ------------------------------------------------------------- ingress
    def submit(self, q_terms: np.ndarray) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, np.asarray(q_terms), self.clock()))
        if self.obs.enabled:
            self.obs.count("submitted", server="inflight")
            self.obs.trace_begin(rid)
        return rid

    @property
    def pending(self) -> int:
        """Queued + planned, not yet holding a slot."""
        return len(self._queue) + len(self._planned)

    @property
    def active(self) -> int:
        """Slots currently occupied by an in-flight query."""
        return int((self.slot_rid >= 0).sum())

    # ----------------------------------------------------------- admission
    def _plan_lookahead(self, limit: int) -> None:
        """Plan up to ``limit`` queued queries ahead of slot availability.

        Called right after a dispatch goes out: under async dispatch the
        planning work (term lookup, block-table build, range ordering)
        runs on the host while the device is still scoring the quantum.
        """
        while self._queue and len(self._planned) < limit:
            rid, q_terms, t_enq = self._queue.popleft()
            self._planned.append((rid, self.bengine.plan(q_terms), t_enq))

    def _admission_budget(self, plan: QueryPlan) -> int:
        b = np.asarray(self.budgeter.budgets(1, plans=[plan]), dtype=np.int64)
        if b.ndim == 2:  # sharded budgeter: one engine serves the sum
            b = b.sum(axis=1)
        return int(min(int(b[0]), INT32_MAX))

    def _reset_carry_lane(self, lane: int, parked: bool) -> None:
        self.carry.i[lane] = 0
        self.carry.state.vals[lane] = 0
        self.carry.state.ids[lane] = -1
        self.carry.state.postings[lane] = 0
        self.carry.state.blocks[lane] = 0
        self.carry.exit_safe[lane] = False
        self.carry.exit_budget[lane] = parked

    def _admit(self, lane: int, rid: int, plan: QueryPlan, t_enq: float) -> None:
        width = self.bengine.spec.width_bucket(plan.blk_tab.shape[1])
        if width > self.buffers.back.width:
            # Width growth is the only program-shape change; the pow2
            # ladder bounds how many (n_slots, width) compiles can occur.
            self.buffers.grow_width(width)
        budget = self._admission_budget(plan)
        self.buffers.back.write_lane(lane, plan, budget=budget)
        self._reset_carry_lane(lane, parked=False)
        self.slot_rid[lane] = rid
        self.slot_t_enq[lane] = t_enq
        self.slot_quanta[lane] = 0
        self._prev_postings[lane] = 0
        self.admissions += 1
        if self.obs.enabled:
            now = self.clock()
            self.slot_t_adm[lane] = now
            self.obs.count("admissions", server="inflight")
            if budget >= INT32_MAX:
                # Unlimited (inf-SLA) admissions would pin the histogram's
                # p50 at the INT32_MAX sentinel; count them separately and
                # keep the budget distribution finite-only (ISSUE 9).
                self.obs.count("unlimited_admissions", server="inflight")
            else:
                self.obs.observe("budget_postings", budget, server="inflight")
            self.obs.trace_span(rid, "queue", t_enq, now)
            self.obs.trace_attr(rid, budget_postings=budget, slot=lane)

    def _park(self, lane: int) -> None:
        self.buffers.back.clear_lane(lane)
        self._reset_carry_lane(lane, parked=True)
        self.slot_rid[lane] = -1
        self.slot_quanta[lane] = 0
        self._prev_postings[lane] = 0
        if self.obs.enabled:
            self.obs.count("parks", server="inflight")

    def _admit_vacant(self) -> None:
        for lane in np.nonzero(self.slot_rid < 0)[0]:
            if not self._planned:
                self._plan_lookahead(1)
                if not self._planned:
                    break
            rid, plan, t_enq = self._planned.popleft()
            self._admit(int(lane), rid, plan, t_enq)

    # ------------------------------------------------------------ stepping
    def step(self) -> list[ServedQuery]:
        """One quantum: admit, dispatch, fetch, retire exited slots."""
        self._admit_vacant()
        if self.active == 0:
            return []
        self.buffers.swap()  # pending lane writes go live
        front = self.buffers.front
        eng = self.engine

        prof = self.obs.profiler if self.obs.enabled else None
        if prof is not None:
            cache0 = jit_cache_size(batched_traverse_resume)
        t0 = self.clock()
        blk, rest, order, bounds, budget, maxr = front.device_arrays()
        out = batched_traverse_resume(
            eng.dix,
            blk,
            rest,
            order,
            bounds,
            budget,
            maxr,
            _carry_to_device(self.carry),
            s_pad=eng.s_pad,
            k=eng.k,
            quantum=self.quantum,
            impl=eng.impl,
            interpret=eng.interpret,
            docs_format=eng.docs_format,
        )
        self.compiled_shapes.add((self.n_slots, front.width))
        self.steps_run += 1
        if prof is not None:
            t_disp1 = self.clock()

        # Async dispatch: the device is scoring; overlap host-side planning
        # for the admissions this step's exits will make room for.
        self._plan_lookahead(self.n_slots)

        if prof is not None:
            # Timing-only sync: splits the device wait out of the carry
            # fetch below. Results are untouched.
            t_plan1 = self.clock()
            jax.block_until_ready(out)  # analysis: allow[HOSTSYNC]
            t_dev1 = self.clock()
        self.carry = _carry_to_host(out)  # blocks until the quantum lands
        t1 = self.clock()
        step_ms = (t1 - t0) * 1e3
        if prof is not None:
            prof.record_dispatch(
                "inflight",
                (self.n_slots, front.width),
                cache_before=cache0,
                cache_after=jit_cache_size(batched_traverse_resume),
                plan_ms=(t_plan1 - t_disp1) * 1e3,
                dispatch_ms=(t_disp1 - t0) * 1e3,
                device_ms=(t_dev1 - t_plan1) * 1e3,
                transfer_ms=(t1 - t_dev1) * 1e3,
            )
            prof.record_hbm_once("inflight", eng.dix._asdict())

        active = self.slot_rid >= 0
        postings = np.asarray(self.carry.state.postings, dtype=np.int64)
        delta = int((postings[active] - self._prev_postings[active]).sum())
        self._prev_postings[active] = postings[active]
        self.slot_quanta[active] += 1

        obs = self.obs
        if obs.enabled:
            obs.observe("step_ms", step_ms, server="inflight")
            obs.observe("active_lanes", int(active.sum()), server="inflight")
            obs.gauge(
                "slot_occupancy", float(active.sum()) / self.n_slots,
                server="inflight",
            )
            obs.gauge("queue_depth", float(self.pending), server="inflight")
            for lane in np.nonzero(active)[0]:
                # Device-step attribution: the quantum's host-observed wall
                # time, shared by every lane riding this dispatch.
                obs.trace_span(
                    int(self.slot_rid[lane]), "dispatch", t0, t1,
                    device_ms=round(step_ms, 4), step=self.steps_run,
                )

        served: list[ServedQuery] = []
        done = carry_done(self.carry, self.n_ranges) & active
        vals = self.carry.state.vals
        ids = self.carry.state.ids
        blocks = self.carry.state.blocks
        sla = getattr(self.budgeter, "sla_ms", None)
        for lane in np.nonzero(done)[0]:
            lane = int(lane)
            sq = ServedQuery(
                rid=int(self.slot_rid[lane]),
                result=lane_result(
                    vals,
                    ids,
                    postings,
                    blocks,
                    self.carry.i,
                    self.carry.exit_safe,
                    self.carry.exit_budget,
                    lane,
                ),
                latency_ms=(t1 - self.slot_t_enq[lane]) * 1e3,
                batch_size=self.n_slots,
                quanta=int(self.slot_quanta[lane]),
            )
            served.append(sq)
            if obs.enabled:
                reason = result_exit_reason(sq.result)
                obs.count("served_queries", server="inflight", reason=reason)
                obs.observe("latency_ms", sq.latency_ms, server="inflight")
                obs.observe("quanta", sq.quanta, server="inflight")
                obs.trace_span(
                    sq.rid, "service", float(self.slot_t_adm[lane]), t1,
                    quanta=sq.quanta,
                )
                attrs = dict(
                    server="inflight",
                    latency_ms=round(sq.latency_ms, 4),
                    exit_reason=reason,
                    quanta=sq.quanta,
                )
                if sla is not None and sla != float("inf"):
                    attrs["sla_ms"] = float(sla)
                obs.trace_attr(sq.rid, **attrs)
                obs.trace_end(sq.rid)
            self._park(lane)

        # Rate EWMA from device step time; Eq. (7) from end-to-end latency
        # of the queries that completed this quantum (none: rate-only).
        self.budgeter.observe(
            step_ms,
            delta,
            int(active.sum()),
            latencies_ms=[s.latency_ms for s in served],
        )
        return served

    # -------------------------------------------------------------- loops
    def run_until_idle(self, max_steps: int = 1_000_000) -> list[ServedQuery]:
        out: list[ServedQuery] = []
        steps = 0
        while self.pending or self.active:
            out.extend(self.step())
            steps += 1
            if steps >= max_steps:
                raise RuntimeError(
                    f"in-flight loop still busy after {max_steps} steps "
                    f"(pending={self.pending} active={self.active})"
                )
        return out

    def replay(self, queries: Sequence[np.ndarray]) -> list[ServedQuery]:
        """Offline replay: enqueue everything, slot-swap until drained."""
        for q in queries:
            self.submit(q)
        return self.run_until_idle()
