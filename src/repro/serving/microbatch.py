"""Micro-batching request loop with SLA-derived device budgets.

The host-driven anytime executor (core.anytime) takes its go/no-go decision
between ranges from a wall clock. The batch path cannot — one device
dispatch traverses the whole batch — so the SLA must be compiled *into* the
dispatch as per-query postings budgets (the paper's deterministic JASS-style
proxy for time). ``SlaBudgeter`` closes the loop:

  * an EWMA of observed postings scored per millisecond per lane converts
    the millisecond SLA into a postings cap;
  * a ``core.anytime.Reactive`` policy supplies Eq. (7) multiplicative
    feedback — its alpha divides the cap, so SLA misses shrink budgets and
    sustained compliance relaxes them, exactly the paper's §6.4 control
    loop transplanted from time-space into postings-space.

``MicroBatchServer`` is the request loop: enqueue, cut a batch at
``max_batch`` (or whatever is pending), serve it through ``BatchEngine``,
attribute the batch wall time plus queue wait to every member.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.anytime import Reactive
from repro.core.clustered_index import BLOCK
from repro.obs import NOOP
from repro.serving.batch_engine import INT32_MAX, BatchEngine, BatchResult

__all__ = ["SlaBudgeter", "ShardedSlaBudgeter", "ServedQuery", "MicroBatchServer"]


@dataclasses.dataclass
class SlaBudgeter:
    """Convert a wall-clock SLA into per-query postings budgets."""

    sla_ms: float
    policy: Reactive = dataclasses.field(default_factory=lambda: Reactive())
    rate: float = 100.0  # postings / ms / lane — EWMA, seeded conservatively
    ema: float = 0.3
    floor: int = BLOCK  # always admit at least one block per query
    obs: object = NOOP  # Instrumentation handle (alpha/rate/cap trajectories)

    def budgets(self, n: int, plans=None) -> np.ndarray:
        """[n] int32 postings budgets for the next batch.

        ``plans`` is accepted (and ignored here) so callers can pass the
        micro-batch's query plans uniformly; shard-aware budgeters use them
        to shape per-shard allocations (DESIGN.md §9).
        """
        cap = max(float(self.floor), self.rate * self.sla_ms / self.policy.alpha)
        cap = min(cap, float(2**31 - 1))  # inf SLA -> unbounded traversal
        if self.obs.enabled:
            self.obs.gauge("budgeter_alpha", float(self.policy.alpha))
            self.obs.gauge("budgeter_cap_postings", float(int(cap)))
        return np.full(n, int(cap), dtype=np.int32)

    def observe(
        self,
        elapsed_ms: float,
        total_postings: int,
        n: int,
        latencies_ms: Sequence[float] | None = None,
    ) -> None:
        """Feed back one served batch: throughput EWMA + Eq. (7) on alpha.

        ``elapsed_ms`` is *device* time for the dispatch — the right
        denominator for the postings/ms rate EWMA. ``latencies_ms`` is the
        per-query *end-to-end* latency (queue wait + planning + service);
        Eq. (7) judges SLA compliance against it, so queueing-induced
        misses tighten budgets too. Without it, device time stands in for
        both (the pre-queue-aware behaviour).
        """
        if elapsed_ms > 0 and n > 0:
            lane_rate = (total_postings / n) / elapsed_ms
            self.rate = (1 - self.ema) * self.rate + self.ema * max(lane_rate, 1e-6)
            if self.obs.enabled:
                self.obs.gauge("budgeter_rate", float(self.rate))
        self._feed_policy(elapsed_ms, latencies_ms)

    def _feed_policy(
        self, elapsed_ms: float, latencies_ms: Sequence[float] | None
    ) -> None:
        # Eq. (7) inputs: every (latency, SLA) pair the policy judges is
        # also recorded, so the alpha trajectory in the metrics can be
        # replayed against exactly what drove it.
        if latencies_ms is None:
            self.policy.on_query_end(elapsed_ms, self.sla_ms)
            if self.obs.enabled:
                self.obs.observe("budgeter_feedback_ms", float(elapsed_ms))
        else:
            for t_ms in latencies_ms:
                self.policy.on_query_end(float(t_ms), self.sla_ms)
                if self.obs.enabled:
                    self.obs.observe("budgeter_feedback_ms", float(t_ms))
        if self.obs.enabled:
            self.obs.gauge("budgeter_alpha", float(self.policy.alpha))


@dataclasses.dataclass
class ShardedSlaBudgeter(SlaBudgeter):
    """Split a millisecond SLA into per-shard postings budgets.

    Shards on different devices traverse concurrently, so each shard gets
    the *full* time budget converted at its *own* observed throughput: an
    independent postings/ms/lane EWMA per shard (a slow or overloaded shard
    self-reports a lower rate and receives a smaller cap). One shared
    Reactive alpha (Eq. 7) scales all shards from end-to-end SLA feedback —
    the SLA is on the merged result, not on any single shard.

    Two allocation modes (DESIGN.md §9):

      * ``mode="rate"`` — every query in the batch gets the same per-shard
        caps, shaped only by the throughput EWMAs (the §4 behaviour);
      * ``mode="boundsum"`` — each query's *total* postings budget (the sum
        of the rate-mode caps) is re-divided across shards proportionally
        to the per-shard BoundSum mass of that query's terms, obtained via
        ``shard_mass`` (``ShardedEngine.query_shard_mass``). A shard whose
        ranges cannot score for the query gets zero budget; the freed
        postings concentrate where the score mass actually lives, which
        tightens ``fidelity_bound`` under tight SLAs on skewed indexes.

    ``budgets(n, plans)`` returns [n, n_shards]; feed observations through
    ``observe_sharded`` (per-shard postings) — ``MicroBatchServer`` does so
    automatically when results carry ``shard_postings``.
    """

    n_shards: int = 1
    mode: str = "rate"  # "rate" | "boundsum"
    shard_mass: object = None  # callable QueryPlan -> [n_shards] mass
    down_mask: object = None  # callable -> [n_shards] bool, True = shard down

    def __post_init__(self):
        if self.mode not in ("rate", "boundsum"):
            raise ValueError(f"mode {self.mode!r} not in ('rate', 'boundsum')")
        if self.mode == "boundsum" and self.shard_mass is None:
            raise ValueError(
                "mode='boundsum' needs shard_mass= "
                "(e.g. ShardedEngine.query_shard_mass)"
            )
        self.rates = np.full(self.n_shards, self.rate, dtype=np.float64)

    def _rate_caps(self) -> np.ndarray:
        cap = np.maximum(
            float(self.floor), self.rates * self.sla_ms / self.policy.alpha
        )
        return np.minimum(cap, float(2**31 - 1))

    def budgets(self, n: int, plans=None) -> np.ndarray:
        """[n, n_shards] int32 per-(query, shard) postings budgets."""
        caps = self._rate_caps()
        if self.obs.enabled:
            self.obs.gauge("budgeter_alpha", float(self.policy.alpha))
            for s in range(self.n_shards):
                self.obs.gauge(
                    "budgeter_shard_cap", float(int(caps[s])), shard=s
                )
        out = np.tile(caps.astype(np.int64), (n, 1))
        unbounded = float(caps.max()) >= float(2**31 - 1)
        if self.mode == "boundsum" and plans is not None and not unbounded:
            total = float(caps.sum())
            for i, plan in enumerate(plans):
                mass = np.asarray(self.shard_mass(plan), np.float64)
                if mass.sum() <= 0:
                    continue  # no scoring shard at all: keep rate shares
                split = np.ceil(total * mass / mass.sum())
                # Scoring shards keep the one-block floor; zero-mass shards
                # provably cannot contribute a document, so they get zero.
                split = np.where(mass > 0, np.maximum(split, self.floor), 0)
                out[i] = np.minimum(split, float(2**31 - 1)).astype(np.int64)
        return np.clip(out, 0, 2**31 - 1).astype(np.int32)

    def observe_sharded(
        self,
        elapsed_ms: float,
        shard_postings: np.ndarray,
        n: int,
        active_mask: np.ndarray | None = None,
        latencies_ms: Sequence[float] | None = None,
    ) -> None:
        """Per-shard throughput EWMAs + shared Eq. (7) feedback on alpha.

        ``active_mask`` ([n_shards] bool) freezes the EWMA of shards that
        did no work for a structural reason (health-ledger down, DESIGN.md
        §9) — otherwise an outage would drag the dead shard's rate to ~0
        and starve it for many rounds after recovery.
        """
        if elapsed_ms > 0 and n > 0:
            lane_rates = np.asarray(shard_postings, np.float64) / n / elapsed_ms
            new = (1 - self.ema) * self.rates + self.ema * np.maximum(
                lane_rates, 1e-6
            )
            if active_mask is not None:
                new = np.where(np.asarray(active_mask, bool), new, self.rates)
            self.rates = new
            if self.obs.enabled:
                for s in range(self.n_shards):
                    self.obs.gauge(
                        "budgeter_shard_rate", float(self.rates[s]), shard=s
                    )
        self._feed_policy(elapsed_ms, latencies_ms)

    def observe(
        self,
        elapsed_ms: float,
        total_postings: int,
        n: int,
        latencies_ms: Sequence[float] | None = None,
    ) -> None:
        """Base-interface feedback: only a total is known, so spread it
        evenly over the shards that could actually have done the work.
        Keeps adaptation live for callers driving the plain ``SlaBudgeter``
        API (the inherited version would update the unused scalar ``rate``
        and silently freeze the per-shard caps); ``observe_sharded`` with
        real per-shard counters is more accurate.

        ``down_mask`` (when wired — the control plane passes its health
        ledger's ``shard_down_mask``) excludes dead shards from the spread:
        a down shard traversed zero postings, so crediting it a 1/S share
        would inflate its rate EWMA with phantom work and skew its budgets
        after recovery. Down shards' EWMAs stay frozen instead.
        """
        down = (
            np.asarray(self.down_mask(), bool)
            if self.down_mask is not None
            else np.zeros(self.n_shards, bool)
        )
        active = ~down
        n_active = int(active.sum())
        if n_active == 0:
            # Whole fleet down: nothing did the work, nothing to learn.
            self._feed_policy(elapsed_ms, latencies_ms)
            return
        per_shard = np.where(active, total_postings / n_active, 0.0)
        self.observe_sharded(
            elapsed_ms, per_shard, n, active_mask=active, latencies_ms=latencies_ms
        )


def result_exit_reason(res) -> str:
    """Merged exit reason for any result kind the serving stack produces.

    ``BatchResult`` carries its own reason; a ``ShardedResult`` merges its
    per-shard reasons with budget/down dominating (any shard cut short by
    the anytime knob or an outage makes the merged answer budget-limited).
    """
    reasons = getattr(res, "shard_exit_reasons", None)
    if reasons is None:
        return res.exit_reason
    if "budget" in reasons:
        return "budget"
    if "down" in reasons:
        return "down"
    if "safe" in reasons:
        return "safe"
    return "exhausted"


@dataclasses.dataclass
class ServedQuery:
    rid: int
    result: BatchResult
    latency_ms: float  # queue wait + batch service time
    batch_size: int
    quanta: int | None = None  # in-flight path: device quanta the query spanned


class MicroBatchServer:
    """Queue + cut + dispatch loop over a ``BatchEngine``."""

    def __init__(
        self,
        bengine: BatchEngine,
        budgeter: SlaBudgeter,
        max_batch: int | None = None,
        clock=None,
        obs=NOOP,
    ):
        self.bengine = bengine
        self.budgeter = budgeter
        self.max_batch = max_batch or bengine.spec.max_batch
        self.obs = obs
        # One clock for everything: an explicit ``clock=`` wins, otherwise
        # the instrumentation handle's (``NOOP`` carries the wall clock), so
        # trace timestamps and SLA feedback always read the same source.
        self.clock = clock if clock is not None else obs.clock
        self._queue: list[tuple[int, np.ndarray, float]] = []
        self._next_rid = 0

    def submit(self, q_terms: np.ndarray) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, np.asarray(q_terms), self.clock()))
        if self.obs.enabled:
            self.obs.count("submitted", server="micro")
            self.obs.trace_begin(rid)
        return rid

    @property
    def pending(self) -> int:
        return len(self._queue)

    def _run_batch(self, plans, budgets):
        """One engine dispatch — the control plane's override point: the
        ``ControlPlane`` routes through whichever engine is live and injects
        the health ledger's down mask here (DESIGN.md §9)."""
        return self.bengine.run_batch(plans, budget_postings=budgets)

    def _observe(self, batch_ms: float, results, latencies_ms=None) -> None:
        """Feed one served batch back to the budgeter (override point:
        the control plane adds its health mask and reshard planner here).

        ``batch_ms`` (device dispatch time) drives the throughput EWMA;
        ``latencies_ms`` (per-query end-to-end, queue wait included) drives
        Eq. (7) — so an overloaded queue tightens budgets even when each
        individual dispatch comfortably makes the SLA.
        """
        if hasattr(self.budgeter, "observe_sharded") and hasattr(
            results[0], "shard_postings"
        ):
            per_shard = np.sum([r.shard_postings for r in results], axis=0)
            self.budgeter.observe_sharded(
                batch_ms, per_shard, len(results), latencies_ms=latencies_ms
            )
        else:
            self.budgeter.observe(
                batch_ms,
                sum(r.postings for r in results),
                len(results),
                latencies_ms=latencies_ms,
            )

    def drain_once(self) -> list[ServedQuery]:
        """Serve one micro-batch from the head of the queue."""
        if not self._queue:
            return []
        obs = self.obs
        cut, self._queue = self._queue[: self.max_batch], self._queue[self.max_batch :]
        rids = [c[0] for c in cut]
        enq = [c[2] for c in cut]
        # Stage timestamps are taken only when instrumented, so a FakeClock
        # run without obs sees the exact pre-instrumentation read sequence.
        t_cut = self.clock() if obs.enabled else 0.0
        plans = self.bengine.plan_many([c[1] for c in cut])
        t_planned = self.clock() if obs.enabled else 0.0
        budgets = self.budgeter.budgets(len(plans), plans=plans)

        t0 = self.clock()
        results = self._run_batch(plans, budgets)
        served_at = self.clock()
        batch_ms = (served_at - t0) * 1e3

        latencies_ms = [(served_at - t_enq) * 1e3 for t_enq in enq]
        self._observe(batch_ms, results, latencies_ms=latencies_ms)
        served = [
            ServedQuery(
                rid=rid,
                result=res,
                latency_ms=(served_at - t_enq) * 1e3,
                batch_size=len(cut),
            )
            for rid, t_enq, res in zip(rids, enq, results)
        ]
        if obs.enabled:
            self._record_batch(
                served, enq, budgets, t_cut, t_planned, t0, served_at, batch_ms
            )
        return served

    def _record_batch(
        self, served, enq, budgets, t_cut, t_planned, t0, served_at, batch_ms
    ) -> None:
        """Metrics + trace spans for one drained batch (obs-enabled only)."""
        obs = self.obs
        if not obs.enabled:
            # Self-protecting: drain_once gates the call, but a subclass
            # or future caller must not pay per-query span cost silently.
            return
        obs.observe("batch_size", len(served), server="micro")
        obs.observe("batch_ms", batch_ms, server="micro")
        obs.gauge("queue_depth", float(len(self._queue)), server="micro")
        per_q = np.asarray(budgets, np.int64)
        if per_q.ndim == 2:  # sharded budgeter: [n, S] -> per-query totals
            per_q = per_q.sum(axis=1)
        sla = getattr(self.budgeter, "sla_ms", None)
        for sq, t_enq, bq in zip(served, enq, per_q):
            reason = result_exit_reason(sq.result)
            obs.count("served_queries", server="micro", reason=reason)
            obs.observe("latency_ms", sq.latency_ms, server="micro")
            if int(bq) >= INT32_MAX:
                # Inf-SLA sentinel budgets stay out of the histogram — they
                # would pin p50 at ~1.6e9 (ISSUE 9); count them instead.
                obs.count("unlimited_admissions", server="micro")
            else:
                obs.observe("budget_postings", int(bq), server="micro")
            obs.trace_span(sq.rid, "queue", t_enq, t_cut)
            obs.trace_span(sq.rid, "plan", t_cut, t_planned, batch=len(served))
            obs.trace_span(
                sq.rid, "budget", t_planned, t0, budget_postings=int(bq)
            )
            obs.trace_span(
                sq.rid, "service", t0, served_at, device_ms=round(batch_ms, 4)
            )
            attrs = dict(
                server="micro",
                latency_ms=round(sq.latency_ms, 4),
                exit_reason=reason,
                batch=len(served),
            )
            if sla is not None and sla != float("inf"):
                attrs["sla_ms"] = float(sla)
            fb = getattr(sq.result, "fidelity_bound", None)
            if fb is not None:
                attrs["fidelity_bound"] = int(fb)
                attrs["exact"] = bool(sq.result.exact)
            obs.trace_attr(sq.rid, **attrs)
            obs.trace_end(sq.rid)

    def replay(
        self, queries: Sequence[np.ndarray], batch_size: int | None = None
    ) -> list[ServedQuery]:
        """Offline replay of a query log in fixed-size micro-batches."""
        bs = max(1, min(batch_size or self.max_batch, self.max_batch))
        out: list[ServedQuery] = []
        for lo in range(0, len(queries), bs):
            for q in queries[lo : lo + bs]:
                self.submit(q)
            out.extend(self.drain_once())
        while self._queue:
            out.extend(self.drain_once())
        return out
