"""Range-sharded multi-device anytime retrieval (DESIGN.md §4).

The cluster-skipping index is separable at range boundaries — blocks never
straddle them and every (term, range) bound is self-contained — so the
natural distribution unit is a contiguous band of ranges, not a random
document split. ``core.clustered_index.shard_device_index`` carves the
built index into postings-mass-balanced bands; this module executes the
same ``device_traverse`` per shard and merges the per-shard heaps into a
global top-k under the heap's total order (score desc, docid asc), which
makes the merged list *bitwise identical* to the single-device traversal
whenever every shard runs its ranges to completion.

Two execution paths produce identical numbers:

  * **vmap** — the shard axis is a vmapped batch dimension on one device
    (development / single-host fallback; also what the parity tests pin);
  * **shard_map** — one shard per mesh device, broker merge via
    ``all_gather`` inside the compiled step (the deployment path; tests
    force host devices with ``XLA_FLAGS=--xla_force_host_platform_
    device_count``).

Budgets are per (query, shard): a global postings budget is split
proportionally to each shard's postings mass (``split_postings_budget``),
so the anytime knob degrades all shards evenly instead of truncating
whichever shard happens to be slow. Fidelity accounting: a shard that
exits on budget reports the max BoundSum of its unprocessed ranges; the
merged result carries ``fidelity_bound`` = max over shards, and any
document missing from the merged list scores at most that bound.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustered_index import (
    BLOCK,
    IndexShard,
    pack_dir_entries,
    pack_docs,
    shard_cuts,
    shard_device_index,
)
from repro.core.range_daat import (
    DeviceIndex,
    Engine,
    QueryPlan,
    device_traverse,
    merge_topk,
    pack_impacts,
)
from repro.distributed.sharding import retrieval_mesh, shard_map
from repro.obs.profiler import jit_cache_size
from repro.serving.bucketing import (
    BucketSpec,
    batch_ladder,
    dummy_plan,
    iter_bucket_chunks,
)

__all__ = [
    "INT32_MAX",
    "ShardedEngine",
    "ShardedBatchEngine",
    "ShardedResult",
    "apply_down_mask",
    "sharded_batched_traverse",
    "shard_exit_reason",
]

INT32_MAX = 2**31 - 1


# --------------------------------------------------------------------------
# Device dispatch — vmap path (single device) and shard_map path (mesh)
# --------------------------------------------------------------------------


def _merge_gathered(vals, gids, k):
    """[N, S, k] per-shard heaps -> ([N, k], [N, k]) merged global top-k."""
    n = vals.shape[0]
    return jax.vmap(lambda v, i: merge_topk(v, i, k))(
        vals.reshape(n, -1), gids.reshape(n, -1)
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "s_pad", "k", "safe_stop", "prune_blocks", "impl", "interpret",
        "docs_format",
    ),
)
def sharded_batched_traverse(
    dix: DeviceIndex,  # stacked shard-major leaves [S, ...]
    doc_base: jnp.ndarray,  # [S] int32 global docid offset per shard
    blk: jnp.ndarray,  # [N, S, R, B] int32, -1 padded
    rest: jnp.ndarray,  # [N, S, R, B] int32
    order: jnp.ndarray,  # [N, S, R] int32 (shard-local range ids)
    bounds: jnp.ndarray,  # [N, S, R] int32
    budgets: jnp.ndarray,  # [N, S] int32 per-(query, shard) postings budget
    maxr: jnp.ndarray,  # [N, S] int32 per-(query, shard) range budget
    *,
    s_pad: int,
    k: int,
    safe_stop: bool = True,
    prune_blocks: bool = True,
    impl: str = "xla",
    interpret: bool = True,
    docs_format: str = "int32",
):
    """(batch x shard) traversal on one device: vmap over both axes.

    Returns ``(vals [N,k], ids [N,k] GLOBAL docids, postings [N,S],
    blocks [N,S], ranges [N,S], exit_safe [N,S], exit_budget [N,S])``.
    """

    def one(dix1, bt, rt, o, ob, bud, mr):
        return device_traverse(
            dix1,
            bt,
            rt,
            o,
            ob,
            s_pad=s_pad,
            k=k,
            budget_postings=bud,
            max_ranges=mr,
            safe_stop=safe_stop,
            prune_blocks=prune_blocks,
            impl=impl,
            interpret=interpret,
            docs_format=docs_format,
        )

    over_shards = jax.vmap(one, in_axes=(0, 0, 0, 0, 0, 0, 0))
    res = jax.vmap(over_shards, in_axes=(None, 0, 0, 0, 0, 0, 0))(
        dix, blk, rest, order, bounds, budgets, maxr
    )
    # Leaves are [N, S, ...]; lift local docids to global, then broker-merge.
    vals = res.state.vals  # [N, S, k]
    gids = jnp.where(res.state.ids >= 0, res.state.ids + doc_base[None, :, None], -1)
    out_v, out_i = _merge_gathered(vals, gids, k)
    return (
        out_v,
        out_i,
        res.state.postings,
        res.state.blocks,
        res.ranges_processed,
        res.exit_safe,
        res.exit_budget,
    )


def make_mesh_dispatch(
    mesh,
    axis: str,
    *,
    s_pad: int,
    k: int,
    safe_stop: bool,
    prune_blocks: bool,
    impl: str,
    interpret: bool,
    data_axis: str | None = None,
    docs_format: str = "int32",
):
    """Compile the (batch x shard) step with one shard per mesh device.

    Same input/output contract as ``sharded_batched_traverse``; the shard
    axis is laid over the mesh via the ``distributed.sharding.shard_map``
    wrapper and the broker merge is an ``all_gather`` + lexsort top-k inside
    the compiled program, so one dispatch serves the whole batch on all
    shards (DESIGN.md §4).

    ``data_axis`` names a second mesh axis carrying query parallelism: the
    batch dimension of every plan table, budget, and output is sharded over
    it while the index arrays stay sharded over ``axis`` only (replicated
    across replicas) — the replicated-shard-group layout of DESIGN.md §9.
    The per-query math is untouched, so an N-replica dispatch is bitwise
    identical to running the same queries on one replica.
    """
    from jax.sharding import PartitionSpec as P

    def body(dix, doc_base, blk, rest, order, bounds, budgets, maxr):
        dix1 = jax.tree.map(lambda a: a[0], dix)  # local shard, leading 1 off
        base = doc_base[0]

        def one(bt, rt, o, ob, bud, mr):
            return device_traverse(
                dix1,
                bt,
                rt,
                o,
                ob,
                s_pad=s_pad,
                k=k,
                budget_postings=bud,
                max_ranges=mr,
                safe_stop=safe_stop,
                prune_blocks=prune_blocks,
                impl=impl,
                interpret=interpret,
                docs_format=docs_format,
            )

        res = jax.vmap(one)(
            blk[:, 0], rest[:, 0], order[:, 0], bounds[:, 0],
            budgets[:, 0], maxr[:, 0],
        )
        gids = jnp.where(res.state.ids >= 0, res.state.ids + base, -1)
        g = lambda x: jnp.moveaxis(  # noqa: E731 — gather [S, ...] -> [N, S, ...]
            jax.lax.all_gather(x, axis), 0, 1
        )
        out_v, out_i = _merge_gathered(g(res.state.vals), g(gids), k)
        diag = g  # [N, S] per-shard counters/flags
        return (
            out_v,
            out_i,
            diag(res.state.postings),
            diag(res.state.blocks),
            diag(res.ranges_processed),
            diag(res.exit_safe),
            diag(res.exit_budget),
        )

    pack_specs = {}
    if docs_format == "packed":
        pack_specs = dict(
            pack_words=P(axis, None),
            pack_dir=P(axis, None),
            pack_first=P(axis, None),
        )
    dix_specs = DeviceIndex(
        docs=P(axis, None),
        impacts=P(axis, None),
        blk_start=P(axis, None),
        blk_len=P(axis, None),
        blk_maximp=P(axis, None),
        bounds_dense=P(axis, None, None),
        range_starts=P(axis, None),
        range_sizes=P(axis, None),
        **pack_specs,
    )
    da = data_axis  # None -> batch replicated on every shard device (§4)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            dix_specs,
            P(axis),
            P(da, axis, None, None),
            P(da, axis, None, None),
            P(da, axis, None),
            P(da, axis, None),
            P(da, axis),
            P(da, axis),
        ),
        out_specs=tuple(P(da) for _ in range(7)),
        check_vma=False,
    )
    return jax.jit(fn)


# --------------------------------------------------------------------------
# Host-facing results
# --------------------------------------------------------------------------


def apply_down_mask(
    budgets: np.ndarray, maxr: np.ndarray, down_mask
) -> tuple[np.ndarray, np.ndarray]:
    """Zero dead shards' budget columns so they exit before any work.

    A down shard (health ledger, DESIGN.md §9) is given ``budget = 0`` and
    ``max_ranges = 0``: the device while_loop exits at i=0 having processed
    nothing, and every one of its ranges lands in the skipped-bounds
    accounting — which is exactly the "unprocessed BoundSum mass" the
    degraded fidelity bound must carry.
    """
    if down_mask is None:
        return budgets, maxr
    down = np.asarray(down_mask, bool)
    if down.shape != (budgets.shape[-1],):
        raise ValueError(
            f"down_mask shape {down.shape} != ({budgets.shape[-1]},)"
        )
    if not down.any():
        return budgets, maxr
    budgets = np.array(budgets, copy=True)
    maxr = np.array(maxr, copy=True)
    budgets[..., down] = 0
    maxr[..., down] = 0
    return budgets, maxr


def shard_exit_reason(safe: bool, budget: bool, rp: int, r_loc: int) -> str:
    """Per-shard exit reason with structural padding folded away.

    Shards are stacked to a common range count R_max; a shard with fewer
    ranges runs inert padded slots past ``r_loc``, whose zero bounds can
    trip the device safe/budget flags. Having processed all ``r_loc`` real
    ranges IS exhaustion, whatever flag fired at the padding.
    """
    if rp >= r_loc:
        return "exhausted"
    if safe:
        return "safe"
    if budget:
        return "budget"
    return "exhausted"


class ShardedResult(NamedTuple):
    """Merged global top-k plus per-shard diagnostics for one query."""

    doc_ids: np.ndarray  # [<=k] int32 GLOBAL docids, score-desc / docid-asc
    scores: np.ndarray  # [<=k] int32
    shard_postings: np.ndarray  # [S] int64
    shard_blocks: np.ndarray  # [S] int64
    shard_ranges: np.ndarray  # [S] int64 ranges processed (<= r_loc)
    shard_exit_reasons: tuple  # [S] of "safe" | "budget" | "exhausted" | "down"
    fidelity_bound: int  # max BoundSum over all unprocessed ranges (0 if none)
    exact: bool  # merged list provably equals the exhaustive top-k (see below)

    @property
    def postings(self) -> int:
        return int(self.shard_postings.sum())

    @property
    def blocks(self) -> int:
        return int(self.shard_blocks.sum())

    @property
    def exit_budget(self) -> bool:
        return "budget" in self.shard_exit_reasons


# --------------------------------------------------------------------------
# Engines
# --------------------------------------------------------------------------


class ShardedEngine:
    """Range-sharded executor over a single built ``ClusteredIndex``.

    Wraps a single-device ``Engine`` (whose ``plan`` stays the global
    planner) with ``n_shards`` shard-local device indexes. ``use_mesh``:
    None = auto (mesh when the runtime has >= n_shards devices), True =
    require a mesh, False = force the single-device vmap path.
    """

    def __init__(
        self,
        engine: Engine,
        n_shards: int,
        use_mesh: bool | None = None,
        mesh_axis: str = "shard",
        shards: list[IndexShard] | None = None,
        obs=None,
    ):
        from repro.obs import NOOP

        self.obs = obs if obs is not None else NOOP
        self.engine = engine
        self.k = engine.k
        self.s_pad = engine.s_pad
        self.impl = engine.impl
        self.interpret = engine.interpret
        self.impact_dtype = engine.impact_dtype
        self.docs_format = engine.docs_format
        if shards is None:
            shards = shard_device_index(engine.index, n_shards)
        elif len(shards) != n_shards:
            raise ValueError(
                f"preloaded shard count {len(shards)} != n_shards {n_shards}"
            )
        self.shards: list[IndexShard] = shards
        self.n_shards = len(self.shards)
        self.cuts = shard_cuts(self.shards)
        self.r_loc = np.asarray([sh.n_ranges for sh in self.shards], np.int64)
        self.r_max = int(self.r_loc.max())
        self.mass = np.asarray([sh.postings for sh in self.shards], np.int64)
        self.doc_base_host = np.asarray(
            [sh.doc_base for sh in self.shards], np.int64
        )

        def stack(field, pad=0, arrs=None):
            if arrs is None:
                arrs = [
                    np.asarray(getattr(sh, field), np.int32) for sh in self.shards
                ]
            w = max((a.shape[0] for a in arrs), default=1) or 1
            out = np.full((self.n_shards, w), pad, arrs[0].dtype if arrs else np.int32)
            for s, a in enumerate(arrs):
                out[s, : a.shape[0]] = a
            return jnp.asarray(out)

        # bounds_dense is a planning-time structure; traversal reads bounds
        # via the plan tables, so the device mirror carries a placeholder
        # (the real shard-local bounds live on IndexShard.bounds_dense).
        # Impacts upload at the engine's impact dtype — int8 keeps shard
        # postings at 1 B/posting in HBM (DESIGN.md §8); padding lanes are
        # never gathered (blocks only address real offsets), so the pad
        # value is inert at either dtype.
        if self.docs_format == "packed":
            # Pack each shard's local docid stream against its own block
            # geometry (deltas are shard-local, DESIGN.md §12); the stacked
            # [S, W] leaves pad with zero words / zero directory rows, which
            # decode to nothing because padded blocks are never addressed.
            packed = [
                pack_docs(sh.docs, sh.blk_start, sh.blk_len)
                for sh in self.shards
            ]
            docs_dev = jnp.zeros((self.n_shards, 1), jnp.int32)
            pack_dev = dict(
                pack_words=stack(
                    "words", arrs=[np.asarray(p.words, np.uint32) for p in packed]
                ),
                pack_dir=stack(
                    "pack_dir", arrs=[pack_dir_entries(p) for p in packed]
                ),
                pack_first=stack(
                    "pack_first", arrs=[p.blk_first for p in packed]
                ),
            )
        else:
            docs_dev = stack("docs")
            pack_dev = {}
        self.dix = DeviceIndex(
            docs=docs_dev,
            impacts=stack(
                "impacts",
                arrs=[
                    pack_impacts(sh.impacts, self.impact_dtype)
                    for sh in self.shards
                ],
            ),
            blk_start=stack("blk_start"),
            blk_len=stack("blk_len"),
            blk_maximp=stack("blk_maximp"),
            bounds_dense=jnp.zeros((self.n_shards, 1, 1), jnp.int32),
            range_starts=stack("range_starts"),
            range_sizes=stack("range_sizes"),
            **pack_dev,
        )
        self.doc_base = jnp.asarray(self.doc_base_host, jnp.int32)

        if use_mesh is None:
            use_mesh = self.n_shards > 1 and jax.device_count() >= self.n_shards
        self.mesh = retrieval_mesh(self.n_shards, mesh_axis) if use_mesh else None
        self._mesh_axis = mesh_axis
        self._mesh_fns: dict = {}

    @classmethod
    def from_artifact(
        cls,
        path: str,
        n_shards: int,
        shards_path: str | None = None,
        use_mesh: bool | None = None,
        mesh_axis: str = "shard",
        **engine_kwargs,
    ) -> "ShardedEngine":
        """Build a sharded engine from saved artifacts (DESIGN.md §8, §10).

        ``path`` is a ``clustered_index`` artifact or a delta-chain head
        (the global planner needs the full index, which a chain head
        materializes on load); ``shards_path`` optionally names a saved
        ``index_shards`` artifact to reuse instead of re-partitioning —
        rejected when its recorded ``source_fingerprint`` does not match
        the loaded (materialized) index, so a stale shard set cannot
        silently serve against a rebuilt *or extended* index: after an
        append, re-carve shards against the new chain head.
        """
        from repro import index_io  # local: index_io sits above serving

        engine = Engine.from_artifact(path, **engine_kwargs)
        shards = None
        if shards_path is not None:
            src = index_io.read_manifest(shards_path).get("source_fingerprint")
            if src is None:
                # An unverifiable shard set is as dangerous as a stale one:
                # mismatched docid spaces serve garbage with no error. Use
                # load_shards + ShardedEngine(shards=...) to bypass.
                raise index_io.ArtifactError(
                    f"shard artifact {shards_path} records no "
                    f"source_fingerprint; re-save with "
                    f"source_fingerprint=index.fingerprint()"
                )
            if src != engine.index.fingerprint():
                raise index_io.ArtifactError(
                    f"shard artifact {shards_path} was carved from index "
                    f"{src}, but {path} has fingerprint "
                    f"{engine.index.fingerprint()} — rebuild the shards"
                )
            shards = index_io.load_shards(shards_path)
        return cls(
            engine, n_shards, use_mesh=use_mesh, mesh_axis=mesh_axis,
            shards=shards,
        )

    # ------------------------------------------------------------- planning
    def plan(self, q_terms: np.ndarray) -> QueryPlan:
        return self.engine.plan(q_terms)

    def shard_plan(self, plan: QueryPlan, width: int | None = None):
        """Slice a global plan into stacked shard-local tables.

        Returns numpy ``(blk [S, R_max, B], rest, order, bounds)`` with
        block ids remapped through each shard's ``blk_map``, range rows in
        shard-local coordinates, and the global processing order restricted
        per shard (relative order preserved, so BoundSum-descending stays
        descending within every shard). Shards with fewer than R_max ranges
        point their padded order slots at an all--1 row — a no-op range.
        """
        g_blk = np.asarray(plan.blk_tab)
        g_rest = np.asarray(plan.rest_tab)
        w = g_blk.shape[1]
        B = width or w
        S, Rm = self.n_shards, self.r_max
        blk = np.full((S, Rm, B), -1, np.int32)
        rest = np.zeros((S, Rm, B), np.int32)
        order = np.zeros((S, Rm), np.int32)
        bounds = np.zeros((S, Rm), np.int32)
        for s, sh in enumerate(self.shards):
            rl = sh.n_ranges
            rows = g_blk[sh.range_lo : sh.range_hi]
            blk[s, :rl, :w] = np.where(rows >= 0, sh.blk_map[rows.clip(0)], -1)
            rest[s, :rl, :w] = g_rest[sh.range_lo : sh.range_hi]
            sel = (plan.order_host >= sh.range_lo) & (plan.order_host < sh.range_hi)
            order[s, :rl] = plan.order_host[sel] - sh.range_lo
            bounds[s, :rl] = np.clip(plan.bounds_host[sel], 0, INT32_MAX)
            if rl < Rm:
                order[s, rl:] = rl  # row rl is all -1: inert padding
        return blk, rest, order, bounds

    def query_shard_mass(self, plan: QueryPlan) -> np.ndarray:
        """[S] int64 per-shard BoundSum mass for this query's terms.

        Sum of the plan's per-range BoundSums over each shard's range band —
        the quantity shard-aware budget allocation splits postings budgets
        by (DESIGN.md §9): a shard whose ranges cannot score for this query
        carries zero mass and deserves none of its budget.
        """
        per_range = np.zeros(int(self.cuts[-1]), np.int64)
        per_range[plan.order_host] = plan.bounds_host
        return np.add.reduceat(per_range, self.cuts[:-1]).astype(np.int64)

    # -------------------------------------------------------------- budgets
    def split_postings_budget(self, budgets) -> np.ndarray:
        """[N] global postings budgets -> [N, S] proportional to shard mass.

        Ceil so shard slices never sum below the global budget; a *positive*
        budget is floored at one block per shard (mirror of
        ``SlaBudgeter.floor`` — a meaningful global budget must not starve a
        small shard below one block), while budget <= 0 stays 0 on every
        shard — same "no work, exit on budget" meaning as the unsharded
        engine. Unbounded stays unbounded.
        """
        b = np.asarray(budgets, np.int64).reshape(-1)
        shares = self.mass / max(int(self.mass.sum()), 1)
        out = np.ceil(b[:, None] * shares[None, :])
        out = np.where(b[:, None] > 0, np.maximum(out, BLOCK), 0)
        out = np.where(b[:, None] >= INT32_MAX, INT32_MAX, out)
        return np.clip(out, 0, INT32_MAX).astype(np.int32)

    def split_range_budget(self, maxr) -> np.ndarray:
        """[N] global range caps -> [N, S] proportional to shard range counts."""
        m = np.asarray(maxr, np.int64).reshape(-1)
        shares = self.r_loc / max(int(self.r_loc.sum()), 1)
        out = np.maximum(np.ceil(m[:, None] * shares[None, :]), 1)
        out = np.where(m[:, None] >= INT32_MAX, INT32_MAX, out)
        out = np.where(m[:, None] <= 0, 0, out)
        return np.clip(out, 0, INT32_MAX).astype(np.int32)

    # ------------------------------------------------------------- dispatch
    def dispatch(
        self, blk, rest, order, bounds, budgets, maxr,
        safe_stop: bool = True, prune_blocks: bool = True,
    ):
        """Run one (batch x shard) step; inputs are stacked numpy tables."""
        prof = self.obs.profiler if self.obs.enabled else None
        if prof is not None:
            t_plan0 = self.obs.clock()
        args = (
            self.dix,
            self.doc_base,
            jnp.asarray(blk),
            jnp.asarray(rest),
            jnp.asarray(order),
            jnp.asarray(bounds),
            jnp.asarray(budgets, jnp.int32),
            jnp.asarray(maxr, jnp.int32),
        )
        if self.mesh is not None:
            key = (safe_stop, prune_blocks)
            if key not in self._mesh_fns:
                self._mesh_fns[key] = make_mesh_dispatch(
                    self.mesh,
                    self._mesh_axis,
                    s_pad=self.s_pad,
                    k=self.k,
                    safe_stop=safe_stop,
                    prune_blocks=prune_blocks,
                    impl=self.impl,
                    interpret=self.interpret,
                    docs_format=self.docs_format,
                )
            fn = self._mesh_fns[key]
            kwargs = {}
        else:
            fn = sharded_batched_traverse
            kwargs = dict(
                s_pad=self.s_pad,
                k=self.k,
                safe_stop=safe_stop,
                prune_blocks=prune_blocks,
                impl=self.impl,
                interpret=self.interpret,
                docs_format=self.docs_format,
            )
        if prof is None:
            return fn(*args, **kwargs)
        clk = self.obs.clock
        cache0 = jit_cache_size(fn)
        t_disp0 = clk()
        out = fn(*args, **kwargs)
        t_dev0 = clk()
        # Timing-only sync: results are fetched by the caller; untouched.
        jax.block_until_ready(out)  # analysis: allow[HOSTSYNC]
        t_dev1 = clk()
        prof.record_dispatch(
            "sharded",
            (int(np.asarray(blk).shape[0]), int(np.asarray(blk).shape[-1])),
            cache_before=cache0,
            cache_after=jit_cache_size(fn),
            plan_ms=(t_disp0 - t_plan0) * 1e3,
            dispatch_ms=(t_dev0 - t_disp0) * 1e3,
            device_ms=(t_dev1 - t_dev0) * 1e3,
        )
        prof.record_hbm_once("sharded", self.dix._asdict())
        return out

    # ------------------------------------------------------------ execution
    def traverse(
        self,
        plan: QueryPlan,
        budget_postings=INT32_MAX,
        max_ranges=INT32_MAX,
        safe_stop: bool = True,
        prune_blocks: bool = True,
        down_mask: np.ndarray | None = None,
    ) -> ShardedResult:
        """Single-query sharded traversal (a batch of one).

        Scalar budgets are split across shards proportionally; a length-S
        sequence assigns per-shard budgets directly. ``down_mask`` ([S]
        bool) marks dead shards: they are assigned zero work and the result
        degrades through the fidelity bound (DESIGN.md §9).
        """
        blk, rest, order, bounds = self.shard_plan(plan)
        bud = self._one_query_budget(budget_postings, self.split_postings_budget)
        mr = self._one_query_budget(max_ranges, self.split_range_budget)
        bud, mr = apply_down_mask(bud, mr, down_mask)
        out = self.dispatch(
            blk[None], rest[None], order[None], bounds[None], bud, mr,
            safe_stop=safe_stop, prune_blocks=prune_blocks,
        )
        return self._to_results(out, bounds[None], down_mask=down_mask)[0]

    def _one_query_budget(self, value, split_fn) -> np.ndarray:
        arr = np.asarray(value, np.int64)
        if arr.ndim == 0:
            return split_fn([int(arr)])
        if arr.shape != (self.n_shards,):
            raise ValueError(f"per-shard budget shape {arr.shape} != ({self.n_shards},)")
        return np.clip(arr, 0, INT32_MAX).astype(np.int32)[None]

    # --------------------------------------------------------------- unpack
    def _to_results(
        self, out, bounds: np.ndarray, down_mask: np.ndarray | None = None
    ) -> list[ShardedResult]:
        """Device outputs + host bounds tables [N, S, R_max] -> results."""
        vals, ids, post, blocks, ranges, safe, budget = (np.asarray(x) for x in out)
        down = (
            np.zeros(self.n_shards, bool)
            if down_mask is None
            else np.asarray(down_mask, bool)
        )
        results = []
        for n in range(vals.shape[0]):
            keep = ids[n] >= 0
            reasons = tuple(
                "down"
                if down[s]
                else shard_exit_reason(
                    bool(safe[n, s]), bool(budget[n, s]),
                    int(ranges[n, s]), int(self.r_loc[s]),
                )
                for s in range(self.n_shards)
            )
            # fb: fidelity loss attributable to the anytime knob or to a
            # dead shard (budget/down exits — the §4/§9 bound surfaced to
            # callers). resid: max BoundSum over ALL skipped ranges, safe
            # exits included, used for the exactness certificate below.
            # down_resid: the dead shards' share of resid — degraded
            # results are never certified exact while it is nonzero.
            fb = 0
            resid = 0
            down_resid = 0
            for s in range(self.n_shards):
                rp, rl = int(ranges[n, s]), int(self.r_loc[s])
                if rp < rl:
                    r_bound = int(bounds[n, s, rp:rl].max())
                    resid = max(resid, r_bound)
                    if reasons[s] in ("budget", "down"):
                        fb = max(fb, r_bound)
                    if reasons[s] == "down":
                        down_resid = max(down_resid, r_bound)
            # Exactness certificate, strict about tie-breaks: a doc in a
            # skipped range can score up to that range's BoundSum, and at
            # equal score a smaller docid displaces the k-th entry under the
            # heap's total order — so the device's non-strict safe condition
            # (bound <= theta) is not enough by itself. Exact iff no skipped
            # range could hold a scoring doc (resid == 0; covers exhausted
            # shards and empty-for-query skipped ranges), or the list is
            # FULL and every skipped range is strictly below the k-th score.
            # With an under-filled list any unprocessed scoring doc belongs
            # in the top-k, so fullness is required. A down shard that could
            # have scored (down_resid > 0) always degrades to exact=False —
            # the deliberately conservative §9 contract, so operators can
            # alarm on inexact answers during an outage.
            n_found = int(keep.sum())
            exact = down_resid == 0 and (
                resid == 0
                or (n_found == self.k and resid < int(vals[n][keep][-1]))
            )
            results.append(
                ShardedResult(
                    doc_ids=ids[n][keep],
                    scores=vals[n][keep],
                    shard_postings=post[n].astype(np.int64),
                    shard_blocks=blocks[n].astype(np.int64),
                    shard_ranges=np.minimum(
                        ranges[n].astype(np.int64), self.r_loc
                    ),
                    shard_exit_reasons=reasons,
                    fidelity_bound=fb,
                    exact=exact,
                )
            )
            if self.obs.enabled:
                self.obs.count("sharded_queries")
                for s, r in enumerate(reasons):
                    self.obs.count("shard_exits", shard=s, reason=r)
                self.obs.count("sharded_exact", exact=exact)
                self.obs.observe("fidelity_bound", fb)
        return results


class ShardedBatchEngine:
    """Shape-bucketed (batch x shard) executor — the sharded ``BatchEngine``.

    Same static-shape discipline as ``BatchEngine``: plans snap to the
    ``BucketSpec`` width/batch ladder, so the XLA program cache stays
    bounded by #width_buckets x #batch_buckets per execution path. One
    dispatch covers every (lane, shard) pair.
    """

    def __init__(
        self, sengine: ShardedEngine, spec: BucketSpec | None = None, obs=None
    ):
        self.sengine = sengine
        self.engine = sengine.engine
        self.spec = spec or BucketSpec()
        # Default to the wrapped engine's handle so the whole sharded stack
        # shares one registry unless a caller deliberately splits them.
        self.obs = obs if obs is not None else sengine.obs
        self.compiled_shapes: set[tuple[int, int]] = set()
        self.batches_run = 0

    # ------------------------------------------------------------- planning
    def plan(self, q_terms: np.ndarray) -> QueryPlan:
        return self.engine.plan(q_terms)

    def plan_many(self, queries: Sequence[np.ndarray]) -> list[QueryPlan]:
        return [self.engine.plan(q) for q in queries]

    # ------------------------------------------------------------ execution
    def run_batch(
        self,
        plans: Sequence[QueryPlan],
        budget_postings=None,
        max_ranges=None,
        safe_stop: bool = True,
        prune_blocks: bool = True,
        down_mask: np.ndarray | None = None,
    ) -> list[ShardedResult]:
        """Traverse ``plans`` on all shards; results keep input order.

        Budgets may be None (unbounded), a scalar, an [n] per-query vector
        (split across shards proportionally), or an [n, S] matrix of
        explicit per-(query, shard) caps. ``down_mask`` ([S] bool) marks
        dead shards; their queries degrade through ``fidelity_bound`` and
        ``exact=False`` instead of failing (DESIGN.md §9).
        """
        n = len(plans)
        if n == 0:
            return []
        budgets = self._per_query_shard(
            budget_postings, n, self.sengine.split_postings_budget
        )
        maxr = self._per_query_shard(
            max_ranges, n, self.sengine.split_range_budget
        )
        budgets, maxr = apply_down_mask(budgets, maxr, down_mask)

        results: list[ShardedResult | None] = [None] * n
        for width, chunk in iter_bucket_chunks(plans, self.spec):
            self._run_chunk(
                [plans[i] for i in chunk], chunk, width, budgets, maxr,
                safe_stop, prune_blocks, results, down_mask,
            )
        return results  # type: ignore[return-value]

    def _per_query_shard(self, value, n: int, split_fn) -> np.ndarray:
        S = self.sengine.n_shards
        if value is None:
            return np.full((n, S), INT32_MAX, np.int32)
        arr = np.asarray(value, np.int64)
        if arr.ndim == 0:
            arr = np.full(n, int(arr), np.int64)
        if arr.shape == (n,):
            return split_fn(arr)
        if arr.shape == (n, S):
            return np.clip(arr, 0, INT32_MAX).astype(np.int32)
        raise ValueError(f"budget shape {arr.shape} not in {{({n},), ({n}, {S})}}")

    def _run_chunk(
        self, chunk_plans, chunk_idx, width, budgets, maxr,
        safe_stop, prune_blocks, results, down_mask=None,
    ) -> None:
        se = self.sengine
        batch = self.spec.batch_bucket(len(chunk_plans))
        S, Rm = se.n_shards, se.r_max
        blk = np.full((batch, S, Rm, width), -1, np.int32)
        rest = np.zeros((batch, S, Rm, width), np.int32)
        order = np.zeros((batch, S, Rm), np.int32)
        bounds = np.zeros((batch, S, Rm), np.int32)
        b = np.zeros((batch, S), np.int32)  # dummy lanes: zero budgets
        m = np.zeros((batch, S), np.int32)
        for lane, (qi, plan) in enumerate(zip(chunk_idx, chunk_plans)):
            blk[lane], rest[lane], order[lane], bounds[lane] = se.shard_plan(
                plan, width
            )
            b[lane] = budgets[qi]
            m[lane] = maxr[qi]

        out = se.dispatch(
            blk, rest, order, bounds, b, m,
            safe_stop=safe_stop, prune_blocks=prune_blocks,
        )
        self.compiled_shapes.add((batch, width))
        self.batches_run += 1
        unpacked = se._to_results(out, bounds, down_mask=down_mask)
        for lane, qi in enumerate(chunk_idx):
            results[qi] = unpacked[lane]

    # ---------------------------------------------------------------- misc
    def warmup(self, widths: Sequence[int] | None = None) -> None:
        """Pre-compile every (batch_bucket, width) program for given widths."""
        R = self.engine.index.n_ranges
        for w in widths or (self.spec.min_width,):
            dummy = dummy_plan(R, self.spec.width_bucket(w))
            for nb in batch_ladder(self.spec):
                self.run_batch([dummy] * nb)
