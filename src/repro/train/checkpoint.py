"""Async, atomic, elastic checkpointing.

Layout: <dir>/step_<N>/  shard files (npz per host) + manifest.json written
LAST and atomically (tmp + rename) — a checkpoint without a manifest is
invisible to restore, so a preemption mid-write can never corrupt state.

* async: array->host transfer happens on the caller thread (cheap device
  view), file IO on a background thread; ``wait()`` joins.
* elastic restore: arrays are restored from the manifest's logical shapes
  and re-sharded onto WHATEVER mesh the caller provides — changing DP width
  between runs (node loss, elastic scaling) is a restore-time reshard, not a
  format change.
* keep_last keeps disk bounded.

In this single-process container there is one host shard; the per-host
file naming (shard<i>.npz) is the multi-host layout.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v

    def listify(node):
        if not isinstance(node, dict):
            return node
        keys = list(node.keys())
        if keys and all(k.isdigit() for k in keys):
            return [listify(node[str(i)]) for i in range(len(keys))]
        return {k: listify(v) for k, v in node.items()}

    return listify(root)


class Checkpointer:
    def __init__(self, directory: str, keep_last: int = 3, host_id: int = 0):
        self.dir = directory
        self.keep_last = keep_last
        self.host_id = host_id
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, state, step: int, blocking: bool = False):
        flat = _flatten(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(host, step), daemon=True
        )
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, host: dict, step: int):
        path = os.path.join(self.dir, f"step_{step:08d}")
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"shard{self.host_id}.npz"), **host)
        manifest = {
            "step": step,
            "arrays": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in host.items()
            },
            "n_hosts": 1,
        }
        mtmp = os.path.join(tmp, "manifest.json.tmp")
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, os.path.join(tmp, "manifest.json"))
        os.replace(tmp, path)  # checkpoint becomes visible atomically
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(
                os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True
            )

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore
    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def restore(self, step: int, shardings=None):
        """Restore a step; optionally placing arrays with given shardings
        (a pytree of NamedSharding matching the state structure) — this is
        the elastic-reshard path."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, f"shard{self.host_id}.npz"))
        flat = {k: data[k] for k in manifest["arrays"]}
        tree = _unflatten(flat)
        if shardings is not None:
            flat_sh = _flatten(shardings)
            flat_arr = _flatten(tree)
            placed = {
                k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
                for k, v in flat_arr.items()
            }
            tree = _unflatten(placed)
        return tree

    def restore_latest(self, shardings=None):
        steps = self.list_steps()
        if not steps:
            return None
        return self.restore(steps[-1], shardings=shardings)
