"""Training loop substrate: step builder, ZeRO-1 specs, preemption safety.

``make_train_step`` assembles loss -> grad -> (optional int8 EF cross-pod
reduction) -> AdamW into one jittable step; ``zero1_state_specs`` derives
optimizer-state shardings from the param PartitionSpecs by adding a
data-axis shard on the first free, divisible dimension (ZeRO-1).

``Trainer`` is the host loop: microbatch accumulation, wall-clock step
watchdog (straggler hook), SIGTERM/SIGINT -> checkpoint-and-exit
(preemption safety), deterministic data resume via the step counter.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, is_q8_leaf
from repro.optim.schedule import cosine_with_warmup

__all__ = ["make_train_step", "zero1_state_specs", "Trainer", "TrainerConfig"]


def _axes_size(axes, mesh_shape: dict) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh_shape.get(axes, 1)
    n = 1
    for a in axes:
        n *= mesh_shape.get(a, 1)
    return n


def _fit_spec(spec: P, shape, mesh_shape: dict) -> P:
    """Drop spec axes that no longer divide their dimension (e.g. a
    model-sharded FFN dim after int8 block-reshaping)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    parts = parts[: len(shape)]
    for i, (s, d) in enumerate(zip(parts, shape)):
        if s is not None and d % _axes_size(s, mesh_shape) != 0:
            parts[i] = None
    return P(*parts)


def _uses_axes(parts, axes) -> bool:
    names = set(axes)
    for s in parts:
        if s is None:
            continue
        for a in (s,) if isinstance(s, str) else s:
            if a in names:
                return True
    return False


def zero1_spec(spec: P, shape, n_data: int, data_axes, mesh_shape=None) -> P:
    """Add a data-axis shard on the first unsharded divisible dim (idempotent:
    specs already carrying a data axis — e.g. FSDP params — pass through)."""
    if mesh_shape is not None:
        spec = _fit_spec(spec, shape, mesh_shape)
    parts = list(spec) + [None] * (len(shape) - len(spec))
    parts = parts[: len(shape)]
    if _uses_axes(parts, data_axes):
        return P(*parts)
    for i, (s, d) in enumerate(zip(parts, shape)):
        if s is None and d % n_data == 0 and d > 0:
            parts[i] = data_axes
            return P(*parts)
    return P(*parts)


def zero1_state_specs(param_specs, params, opt_state, n_data: int, data_axes,
                      mesh_shape: dict | None = None):
    """PartitionSpecs for the optimizer state matching init_opt_state.

    mesh_shape ({axis: size}) enables divisibility sanitization — required
    for int8 moments whose block reshaping can break param-spec alignment.
    """

    def moment_spec(p_spec: P, p, s):
        if is_q8_leaf(s):
            q_shape = s["q"].shape
            base = list(p_spec) + [None] * (len(q_shape) - len(p_spec))
            qspec = zero1_spec(P(*base), q_shape, n_data, data_axes, mesh_shape)
            sc_shape = s["scale"].shape
            scspec = zero1_spec(P(*base), sc_shape, n_data, data_axes, mesh_shape)
            return {"q": qspec, "scale": scspec}
        return zero1_spec(p_spec, p.shape, n_data, data_axes, mesh_shape)

    m_specs = jax.tree.map(
        moment_spec, param_specs, params, opt_state["m"],
        is_leaf=lambda x: isinstance(x, P),
    )
    v_specs = jax.tree.map(
        moment_spec, param_specs, params, opt_state["v"],
        is_leaf=lambda x: isinstance(x, P),
    )
    return {"m": m_specs, "v": v_specs, "step": P()}


def make_train_step(
    loss_fn: Callable,  # loss_fn(params, batch) -> scalar
    opt_cfg: AdamWConfig,
    *,
    accum: int = 1,
    lr_schedule: Callable | None = None,
    donate: bool = True,
):
    """Build a jittable train step: (params, opt_state, batch) -> updated."""
    lr_schedule = lr_schedule or (lambda step: jnp.float32(opt_cfg.lr))

    def step(params, opt_state, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # Microbatch accumulation: batch leaves carry a leading accum dim.
            def body(carry, micro):
                acc_loss, acc_g = carry
                l, g = jax.value_and_grad(loss_fn)(params, micro)
                return (acc_loss + l, jax.tree.map(jnp.add, acc_g, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), batch
            )
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        lr = lr_schedule(opt_state["step"])
        params, opt_state, metrics = adamw_update(
            params, grads, opt_state, opt_cfg, lr
        )
        metrics = dict(metrics, loss=loss, lr=lr)
        return params, opt_state, metrics

    return step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    step_timeout_s: float = 0.0  # >0 enables the straggler watchdog
    lr: float = 3e-4
    warmup: int = 10
    moment_dtype: str = "fp32"
    grad_clip: float = 1.0
    accum: int = 1


class Trainer:
    """Host training loop with fault tolerance.

    * SIGTERM/SIGINT trigger checkpoint-and-exit (preemption handling);
    * the data iterator is (re)seeded from the persisted step counter, so a
      restore resumes the exact batch sequence;
    * a per-step watchdog records steps exceeding ``step_timeout_s`` — on a
      real multi-host deployment this hook feeds replica-skip / backup-task
      straggler mitigation (single-process here: logged + counted).
    """

    def __init__(
        self,
        loss_fn,
        params,
        cfg: TrainerConfig,
        data_fn: Callable[[int], dict],  # step -> batch (deterministic)
        checkpointer=None,
    ):
        self.cfg = cfg
        self.opt_cfg = AdamWConfig(
            lr=cfg.lr, moment_dtype=cfg.moment_dtype, grad_clip=cfg.grad_clip
        )
        self.params = params
        self.opt_state = init_opt_state(params, self.opt_cfg)
        self.data_fn = data_fn
        self.checkpointer = checkpointer
        self.step_fn = jax.jit(
            make_train_step(
                loss_fn,
                self.opt_cfg,
                accum=cfg.accum,
                lr_schedule=lambda s: cosine_with_warmup(
                    s, peak=cfg.lr, warmup=cfg.warmup, total=cfg.total_steps
                ),
            ),
            donate_argnums=(0, 1),
        )
        self.history: list[dict] = []
        self.slow_steps = 0
        self._preempted = False

    def _handle_preemption(self, signum, frame):
        del signum, frame
        self._preempted = True

    def restore(self):
        if self.checkpointer is None:
            return 0
        state = self.checkpointer.restore_latest()
        if state is None:
            return 0
        self.params = state["params"]
        self.opt_state = state["opt_state"]
        return int(jax.device_get(self.opt_state["step"]))

    def run(self, install_signal_handlers: bool = True) -> dict:
        start_step = self.restore()
        if install_signal_handlers:
            signal.signal(signal.SIGTERM, self._handle_preemption)
        exit_reason = "completed"
        step = start_step
        for step in range(start_step, self.cfg.total_steps):
            t0 = time.perf_counter()
            batch = self.data_fn(step)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch
            )
            # analysis: allow[HOSTSYNC] step-boundary fence: dt must
            # measure the whole device step, not just dispatch latency.
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if self.cfg.step_timeout_s and dt > self.cfg.step_timeout_s:
                self.slow_steps += 1  # straggler hook
            if (step + 1) % self.cfg.log_every == 0 or step == start_step:
                self.history.append(
                    {
                        "step": step + 1,
                        # analysis: allow[HOSTSYNC] log-interval fetch only
                        "loss": float(jax.device_get(metrics["loss"])),
                        "grad_norm": float(jax.device_get(metrics["grad_norm"])),  # analysis: allow[HOSTSYNC]
                        "time_s": dt,
                    }
                )
            if self.checkpointer and (step + 1) % self.cfg.checkpoint_every == 0:
                self.checkpointer.save(
                    {"params": self.params, "opt_state": self.opt_state},
                    step=step + 1,
                )
            if self._preempted:
                if self.checkpointer:
                    self.checkpointer.save(
                        {"params": self.params, "opt_state": self.opt_state},
                        step=step + 1,
                        blocking=True,
                    )
                exit_reason = "preempted"
                break
        if self.checkpointer:
            self.checkpointer.wait()
        return {
            "exit": exit_reason,
            "last_step": step + 1,
            "history": self.history,
            "slow_steps": self.slow_steps,
        }
