"""Fallback shim so property tests collect and run without hypothesis.

When hypothesis is installed, this module re-exports the real ``given``,
``settings`` and ``strategies`` untouched. When it is absent (the minimal
container image), ``@given`` degrades to a fixed-seed example sweep: each
declared strategy is sampled from a deterministic ``numpy`` RNG seeded by
the test name, and the test body runs once per example. This keeps the
properties exercised everywhere — with real shrinking/coverage whenever
hypothesis is available — instead of erroring at collection time.

Only the strategy surface the test suite uses is implemented
(``integers``, ``sampled_from``, ``booleans``, ``floats``); extend it here
if a test needs more.
"""

from __future__ import annotations

try:  # pragma: no cover - trivially one branch per environment
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import hashlib
    import inspect
    import os

    import numpy as np

    # Cap the fallback sweep so interpret-mode kernel properties stay quick;
    # override with REPRO_COMPAT_MAX_EXAMPLES=0 to honor the declared count.
    _MAX_EXAMPLES_CAP = int(os.environ.get("REPRO_COMPAT_MAX_EXAMPLES", "6")) or None

    class _Strategy:
        def sample(self, rng: np.random.Generator):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, min_value: int, max_value: int):
            self.lo, self.hi = int(min_value), int(max_value)

        def sample(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def sample(self, rng):
            return self.elements[int(rng.integers(0, len(self.elements)))]

    class _Booleans(_Strategy):
        def sample(self, rng):
            return bool(rng.integers(0, 2))

    class _Floats(_Strategy):
        def __init__(self, min_value=0.0, max_value=1.0, **_kw):
            self.lo, self.hi = float(min_value), float(max_value)

        def sample(self, rng):
            return float(rng.uniform(self.lo, self.hi))

    class _StrategiesModule:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            return _SampledFrom(elements)

        @staticmethod
        def booleans() -> _Strategy:
            return _Booleans()

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **kw) -> _Strategy:
            return _Floats(min_value, max_value, **kw)

    st = strategies = _StrategiesModule()

    def settings(max_examples: int = 10, **_ignored):
        """Record the example count on the (already-wrapped) test."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        """Run the test once per deterministically-sampled example."""

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                declared = getattr(wrapper, "_compat_max_examples", 10)
                n = declared if _MAX_EXAMPLES_CAP is None else min(
                    declared, _MAX_EXAMPLES_CAP
                )
                seed = int.from_bytes(
                    hashlib.sha1(fn.__qualname__.encode()).digest()[:4], "little"
                )
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            # Hide the drawn params from pytest's fixture resolution (any
            # remaining params still resolve as fixtures, like hypothesis).
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for name, p in sig.parameters.items() if name not in strats
                ]
            )
            del wrapper.__dict__["__wrapped__"]
            return wrapper

        return deco


strategies = st

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st", "strategies"]
