"""Shared fixtures: a small planted-topic corpus and indexes over it.

Session-scoped because index building (BP + k-means) is the slow offline
step; all tests share the same deterministic artifacts. NOTE: device count
must stay 1 here — only launch/dryrun.py sets the 512-device XLA flag.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.clustered_index import build_index
from repro.core.range_daat import Engine
from repro.core.reorder import arrange
from repro.data.synth import make_corpus, make_query_log


@pytest.fixture(scope="session")
def corpus():
    return make_corpus(
        n_docs=2500, n_terms=3000, n_topics=8, mean_doc_len=120, seed=0
    )


@pytest.fixture(scope="session")
def query_log(corpus):
    return make_query_log(corpus, n_queries=12, seed=1)


@pytest.fixture(scope="session")
def clustered_arrangement(corpus):
    return arrange(corpus, n_ranges=8, strategy="clustered_bp", bp_rounds=4, seed=0)


@pytest.fixture(scope="session")
def index(corpus, clustered_arrangement):
    return build_index(corpus, arrangement=clustered_arrangement, bits=8)


@pytest.fixture(scope="session")
def random_index(corpus):
    arr = arrange(corpus, n_ranges=1, strategy="random", seed=0)
    return build_index(corpus, arrangement=arr, bits=8)


@pytest.fixture(scope="session")
def engine(index):
    return Engine(index, k=10)


@pytest.fixture(scope="session")
def queries(query_log):
    return [np.asarray(query_log.terms[i]) for i in range(query_log.n_queries)]
