"""Differential harness: bitwise parity between engine configurations.

The repo's correctness story leans on one invariant, stated many times in
DESIGN.md: every execution path over the same index — scorer impl, impact
storage dtype, docid encoding, shard count — must produce *bitwise*
identical results: doc ids, scores, tie-breaks, work counters, and exit
reasons. This module is the single place that invariant is mechanised so
each new representation (int8 impacts, packed docids, ...) pins itself
with one `assert_bitwise_equal_engines` call instead of ad-hoc loops.

Two layers:

  * ``EngineConfig`` + ``assert_bitwise_equal_engines`` — build two engines
    over one index and compare every host-observable of every query.
  * ``assert_batch_matches_sequential`` / ``assert_sharded_matches_engine``
    — the batched-vs-looped and sharded-vs-single parity assertions shared
    by the serving test suites.

All comparisons normalise through ``np.asarray(...).tolist()`` so device
arrays, numpy scalars, and plain ints compare by value, and a failure
names the query and both configurations.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.clustered_index import ClusteredIndex, build_index
from repro.core.range_daat import Engine
from repro.serving import ShardedEngine

__all__ = [
    "EngineConfig",
    "build_engine",
    "observe_query",
    "assert_results_equal",
    "assert_bitwise_equal_engines",
    "assert_batch_matches_sequential",
    "assert_sharded_matches_engine",
    "assert_exit_reason_conservation",
]

INT32_MAX = 2**31 - 1


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """One engine construction recipe; the harness compares two of these."""

    impact_dtype: str = "int32"
    docs_format: str = "int32"
    impl: str = "xla"
    interpret: bool = True
    n_shards: int = 1

    def describe(self) -> str:
        return (
            f"{self.impl}/{self.impact_dtype}/{self.docs_format}"
            f"/shards={self.n_shards}"
        )


def build_engine(index: ClusteredIndex, cfg: EngineConfig, k: int = 5):
    """An ``Engine`` (or vmap-path ``ShardedEngine``) per the config."""
    eng = Engine(
        index,
        k=k,
        impl=cfg.impl,
        interpret=cfg.interpret,
        impact_dtype=cfg.impact_dtype,
        docs_format=cfg.docs_format,
    )
    if cfg.n_shards > 1:
        return ShardedEngine(eng, cfg.n_shards, use_mesh=False)
    return eng


def observe_query(engine, plan, budget=None, max_ranges=None) -> dict:
    """Every host-observable of one traversal, as plain Python values."""
    kw = {}
    if budget is not None:
        kw["budget_postings"] = int(budget)
    if max_ranges is not None:
        kw["max_ranges"] = int(max_ranges)
    if isinstance(engine, ShardedEngine):
        r = engine.traverse(plan, **kw)
        return {
            "doc_ids": np.asarray(r.doc_ids).tolist(),
            "scores": np.asarray(r.scores).tolist(),
            "postings": int(r.postings),
            "blocks": int(r.blocks),
            "shard_postings": np.asarray(r.shard_postings).tolist(),
            "shard_ranges": np.asarray(r.shard_ranges).tolist(),
            "shard_exit_reasons": list(r.shard_exit_reasons),
            "fidelity_bound": int(r.fidelity_bound),
            "exact": bool(r.exact),
        }
    res = engine.traverse(plan, **kw)
    ids, vals = engine.topk_docs(res.state)
    return {
        "doc_ids": ids.tolist(),
        "scores": vals.tolist(),
        "postings": int(np.asarray(res.state.postings)),
        "blocks": int(np.asarray(res.state.blocks)),
        "ranges_processed": int(res.ranges_processed),
        "exit_safe": bool(res.exit_safe),
        "exit_budget": bool(res.exit_budget),
    }


def assert_results_equal(ra: dict, rb: dict, context: str = "") -> None:
    """Field-by-field equality with a failure message naming the field."""
    assert ra.keys() == rb.keys(), f"{context}: observable sets differ"
    for key in ra:
        assert ra[key] == rb[key], (
            f"{context}: {key} diverged\n  a: {ra[key]}\n  b: {rb[key]}"
        )


def assert_bitwise_equal_engines(
    cfg_a: EngineConfig,
    cfg_b: EngineConfig,
    corpus,
    queries: Sequence[np.ndarray],
    budgets=None,
    max_ranges=None,
    k: int = 5,
    n_ranges: int = 4,
    strategy: str = "clustered",
    bits: int = 8,
    seed: int = 0,
) -> None:
    """Pin two engine configs bitwise-equal over a corpus and query set.

    ``corpus`` may be a ``Corpus`` (an index is built with the keyword
    build parameters) or an already-built ``ClusteredIndex``. ``budgets``
    and ``max_ranges`` are optional per-query sequences; both sides of
    query ``i`` receive identical caps, so the assertion also covers
    budget-exit timing, not just exhaustive runs.
    """
    if cfg_a.n_shards != cfg_b.n_shards:
        raise ValueError(
            "differential configs must agree on n_shards (per-shard "
            f"observables aren't comparable): {cfg_a.n_shards} vs "
            f"{cfg_b.n_shards}"
        )
    if isinstance(corpus, ClusteredIndex):
        index = corpus
    else:
        index = build_index(
            corpus, n_ranges=n_ranges, strategy=strategy, bits=bits, seed=seed
        )
    ea = build_engine(index, cfg_a, k=k)
    eb = build_engine(index, cfg_b, k=k)
    planner = ea.engine if isinstance(ea, ShardedEngine) else ea
    for i, q in enumerate(queries):
        plan = planner.plan(q)
        b = None if budgets is None else budgets[i]
        m = None if max_ranges is None else max_ranges[i]
        assert_results_equal(
            observe_query(ea, plan, b, m),
            observe_query(eb, plan, b, m),
            context=f"query {i}: {cfg_a.describe()} vs {cfg_b.describe()}",
        )


def assert_batch_matches_sequential(
    eng: Engine, plans, batch_results, budgets=None, max_ranges=None
) -> None:
    """Batched serving results == looped single-query ``Engine.traverse``.

    ``batch_results`` is any sequence of ``BatchResult``-shaped records
    (``BatchEngine.run_batch`` output, or per-lane results from the
    in-flight loop); comparison covers ids, scores, exit flags, and the
    postings/blocks/ranges work counters.
    """
    for i, (plan, br) in enumerate(zip(plans, batch_results)):
        single = observe_query(
            eng,
            plan,
            None if budgets is None else budgets[i],
            None if max_ranges is None else max_ranges[i],
        )
        got = {
            "doc_ids": np.asarray(br.doc_ids).tolist(),
            "scores": np.asarray(br.scores).tolist(),
            "postings": int(br.postings),
            "blocks": int(br.blocks),
            "ranges_processed": int(br.ranges_processed),
            "exit_safe": bool(br.exit_safe),
            "exit_budget": bool(br.exit_budget),
        }
        assert_results_equal(got, single, context=f"query {i}: batch vs loop")


def assert_exit_reason_conservation(
    obs, counter_name: str, expected_reasons: Sequence[str],
    context: str = "", **fixed_labels
) -> None:
    """Telemetry exit-reason counters conserve queries (DESIGN.md §13).

    ``expected_reasons`` is the per-query exit reason list recomputed from
    the *returned* results — the ground truth the caller already holds.
    The counter named ``counter_name`` in ``obs``'s registry, restricted
    to label sets matching ``fixed_labels`` (e.g. ``server="inflight"``),
    must (a) sum to ``len(expected_reasons)`` — every query served is
    counted exactly once, none dropped, none double-counted — and (b)
    match the returned reasons as a multiset, so telemetry can never
    report an exit mix the results contradict.
    """
    import collections

    want = collections.Counter(str(r) for r in expected_reasons)
    counter = obs.metrics.counter(counter_name)
    fixed = {str(k): str(v) for k, v in fixed_labels.items()}
    got: collections.Counter = collections.Counter()
    for key in counter.labelsets():
        labels = dict(key)
        if any(labels.get(k) != v for k, v in fixed.items()):
            continue
        got[labels.get("reason", "")] += int(counter.value(**labels))
    ctx = context or counter_name
    assert sum(got.values()) == len(expected_reasons), (
        f"{ctx}: counted {sum(got.values())} queries in {counter_name}"
        f"{fixed or ''}, served {len(expected_reasons)}"
    )
    assert got == want, (
        f"{ctx}: exit-reason mix diverged\n  telemetry: {dict(got)}"
        f"\n  results:   {dict(want)}"
    )


def assert_sharded_matches_engine(
    se: ShardedEngine, plans, safe_stop: bool = True
) -> None:
    """Exhaustive-budget sharded top-k == single-device top-k, bitwise."""
    eng = se.engine
    for i, plan in enumerate(plans):
        single = eng.traverse(plan, safe_stop=safe_stop)
        sids, svals = eng.topk_docs(single.state)
        sh = se.traverse(plan, safe_stop=safe_stop)
        ctx = f"query {i}: {se.n_shards}-shard vs single"
        assert np.asarray(sh.doc_ids).tolist() == sids.tolist(), f"{ctx} ids"
        assert np.asarray(sh.scores).tolist() == svals.tolist(), f"{ctx} scores"
        assert sh.exact and sh.fidelity_bound == 0, ctx
        assert all(
            r in ("safe", "exhausted") for r in sh.shard_exit_reasons
        ), ctx
