"""Static-hazard analyzer tests (DESIGN.md §15).

Four layers:

* per-rule fixtures — a positive, a negative, and a waiver per checker;
* ratchet semantics — a new finding fails, a stale baseline entry fails;
* the repo gate — ``src/repro`` must stay clean against the committed
  ``analysis_baseline.json`` (this is the tier-1 wrapper the CI job runs);
* regressions for the true positives the first analyzer run burned down
  (checked docid casts, the replica pad-slice host sync, the replica
  ``docs_format`` threading, the `_record_batch` early guard).
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from types import SimpleNamespace

import numpy as np
import pytest

from repro.analysis import (
    RULES,
    analyze_paths,
    analyze_source,
    diff_baseline,
    help_for,
    load_baseline,
    missing_help,
    write_baseline,
)
from repro.analysis.__main__ import main as analysis_main

REPO = Path(__file__).resolve().parents[1]


def rules_of(report):
    return sorted({f.rule for f in report.findings})


# ----------------------------------------------------------- rule fixtures


class TestRecompile:
    def test_value_branch_on_traced_param_fires(self):
        rep = analyze_source(
            "import jax\n"
            "from functools import partial\n"
            "@partial(jax.jit, static_argnames=('k',))\n"
            "def f(x, y, k):\n"
            "    if y > 0:\n"
            "        return x * k\n"
            "    return x\n"
        )
        assert rules_of(rep) == ["RECOMPILE"]
        assert "y" in rep.findings[0].message

    def test_shape_branch_and_static_param_are_clean(self):
        rep = analyze_source(
            "import jax\n"
            "from functools import partial\n"
            "@partial(jax.jit, static_argnames=('k',))\n"
            "def f(x, y, k):\n"
            "    if x.shape[0] > 4 and k:\n"
            "        return x + y\n"
            "    return x\n"
        )
        assert rep.findings == []

    def test_string_literal_into_nonstatic_param_fires(self):
        rep = analyze_source(
            "import jax\n"
            "@jax.jit\n"
            "def g(x, mode):\n"
            "    return x\n"
            "def use(x):\n"
            "    return g(x, 'fast')\n"
        )
        assert rules_of(rep) == ["RECOMPILE"]
        assert "mode" in rep.findings[0].message

    def test_string_into_static_argnames_is_clean(self):
        rep = analyze_source(
            "import jax\n"
            "from functools import partial\n"
            "@partial(jax.jit, static_argnames=('mode',))\n"
            "def g(x, mode):\n"
            "    return x\n"
            "def use(x):\n"
            "    return g(x, mode='fast')\n"
        )
        assert rep.findings == []


class TestHostsync:
    HOT = (
        "import numpy as np\n"
        "import jax\n"
        "class InflightServer:\n"
        "    def step(self):\n"
        "        out = self.dispatch_quantum()\n"
        "        jax.block_until_ready(out)\n"
        "        host = np.asarray(out)\n"
        "        return host\n"
        "    def dispatch_quantum(self):\n"
        "        return 1\n"
    )

    def test_sync_and_materialize_in_hot_root_fire(self):
        rep = analyze_source(self.HOT)
        assert rules_of(rep) == ["HOSTSYNC"]
        msgs = " ".join(f.message for f in rep.findings)
        assert "jax.block_until_ready" in msgs and "np.asarray" in msgs

    def test_same_body_outside_hot_roots_is_clean(self):
        rep = analyze_source(self.HOT.replace("InflightServer", "Offline"))
        assert rep.findings == []

    def test_sync_inside_python_loop_fires_anywhere(self):
        rep = analyze_source(
            "import jax\n"
            "def train(xs):\n"
            "    for x in xs:\n"
            "        jax.device_get(x)\n"
        )
        assert rules_of(rep) == ["HOSTSYNC"]

    def test_waiver_suppresses_and_is_counted(self):
        waived = self.HOT.replace(
            "jax.block_until_ready(out)",
            "jax.block_until_ready(out)  # analysis: allow[HOSTSYNC]",
        ).replace(
            "host = np.asarray(out)",
            "host = np.asarray(out)  # analysis: allow[HOSTSYNC]",
        )
        rep = analyze_source(waived)
        assert rep.findings == []
        assert len(rep.waived) == 2

    def test_comment_block_waiver_covers_next_code_line(self):
        rep = analyze_source(
            "import jax\n"
            "def train(xs):\n"
            "    for x in xs:\n"
            "        # step timing is the point here\n"
            "        # analysis: allow[HOSTSYNC]\n"
            "        jax.device_get(x)\n"
        )
        assert rep.findings == [] and len(rep.waived) == 1


class TestNarrow:
    def test_unguarded_docid_cast_fires(self):
        rep = analyze_source(
            "import numpy as np\n"
            "def build(new_ids):\n"
            "    docs = new_ids.astype(np.int32)\n"
            "    return docs\n",
            path="core/fixture.py",
        )
        assert rules_of(rep) == ["NARROW"]

    def test_clipped_cast_and_unwatched_name_are_clean(self):
        rep = analyze_source(
            "import numpy as np\n"
            "def build(new_ids, arr):\n"
            "    docs = np.clip(new_ids, 0, 7).astype(np.int32)\n"
            "    lane = arr.astype(np.int32)\n"
            "    buf = np.zeros(4, dtype=np.int32)\n"
            "    return docs, lane, buf\n",
            path="core/fixture.py",
        )
        assert rep.findings == []

    def test_out_of_scope_module_is_clean(self):
        rep = analyze_source(
            "import numpy as np\n"
            "def build(new_ids):\n"
            "    docs = new_ids.astype(np.int32)\n"
            "    return docs\n",
            path="tools/fixture.py",
        )
        assert rep.findings == []


class TestObsguard:
    def test_unguarded_obs_call_fires(self):
        rep = analyze_source(
            "class S:\n"
            "    def drain(self):\n"
            "        self.obs.observe('x', 1)\n",
            path="serving/fixture.py",
        )
        assert rules_of(rep) == ["OBSGUARD"]

    def test_enabled_guard_and_early_return_are_clean(self):
        rep = analyze_source(
            "class S:\n"
            "    def drain(self):\n"
            "        if self.obs.enabled:\n"
            "            self.obs.observe('x', 1)\n"
            "    def record(self):\n"
            "        if not self.obs.enabled:\n"
            "            return\n"
            "        self.obs.count('y')\n",
            path="serving/fixture.py",
        )
        assert rep.findings == []


class TestArtifact:
    def test_bare_write_fires(self):
        rep = analyze_source(
            "import json\n"
            "def save(path, rows):\n"
            "    with open(path, 'w') as f:\n"
            "        json.dump(rows, f)\n",
            path="index_io/fixture.py",
        )
        assert rules_of(rep) == ["ARTIFACT"]

    def test_staged_replace_is_clean(self):
        rep = analyze_source(
            "import json, os\n"
            "def save(path, rows):\n"
            "    with open(path + '.tmp', 'w') as f:\n"
            "        json.dump(rows, f)\n"
            "    os.replace(path + '.tmp', path)\n",
            path="index_io/fixture.py",
        )
        assert rep.findings == []


class TestPallasconst:
    def test_python_branch_on_ref_fires(self):
        rep = analyze_source(
            "def scatter_kernel(ref, out_ref):\n"
            "    if ref[0] > 0:\n"
            "        out_ref[0] = 1\n",
            path="kernels/fixture.py",
        )
        assert rules_of(rep) == ["PALLASCONST"]

    def test_nonstatic_grid_param_fires(self):
        rep = analyze_source(
            "import jax\n"
            "from functools import partial\n"
            "import jax.experimental.pallas as pl\n"
            "def body_kernel(x_ref, o_ref):\n"
            "    o_ref[0] = x_ref[0]\n"
            "@partial(jax.jit, static_argnames=('tile',))\n"
            "def run(x, n, tile):\n"
            "    return pl.pallas_call(body_kernel, grid=(n,))(x)\n",
            path="kernels/fixture.py",
        )
        assert any(
            f.rule == "PALLASCONST" and "grid" in f.message
            for f in rep.findings
        )

    def test_static_grid_and_pl_when_are_clean(self):
        rep = analyze_source(
            "import jax\n"
            "from functools import partial\n"
            "import jax.experimental.pallas as pl\n"
            "def body_kernel(x_ref, o_ref):\n"
            "    pl.when(x_ref[0] > 0)\n"
            "@partial(jax.jit, static_argnames=('tile',))\n"
            "def run(x, tile):\n"
            "    g = x.shape[0] // tile\n"
            "    return pl.pallas_call(body_kernel, grid=(g,))(x)\n",
            path="kernels/fixture.py",
        )
        assert rep.findings == []


# -------------------------------------------------------- ratchet semantics


class TestBaselineRatchet:
    BAD = (
        "import numpy as np\n"
        "def build(new_ids):\n"
        "    docs = new_ids.astype(np.int32)\n"
        "    return docs\n"
    )

    def findings(self):
        return analyze_source(self.BAD, path="core/fixture.py").findings

    def test_pinned_finding_passes(self, tmp_path):
        f = self.findings()
        bl = tmp_path / "baseline.json"
        write_baseline(str(bl), f)
        new, stale = diff_baseline(f, load_baseline(str(bl)))
        assert new == [] and stale == []

    def test_new_finding_fails(self, tmp_path):
        bl = tmp_path / "baseline.json"
        write_baseline(str(bl), [])
        new, stale = diff_baseline(self.findings(), load_baseline(str(bl)))
        assert len(new) == 1 and stale == []

    def test_stale_baseline_entry_fails(self, tmp_path):
        bl = tmp_path / "baseline.json"
        write_baseline(str(bl), self.findings())
        new, stale = diff_baseline([], load_baseline(str(bl)))
        assert new == [] and len(stale) == 1

    def test_key_survives_line_drift(self):
        shifted = "# a new comment line\n\n" + self.BAD
        a = self.findings()
        b = analyze_source(shifted, path="core/fixture.py").findings
        assert [f.key for f in a] == [f.key for f in b]
        assert a[0].line != b[0].line

    def test_cli_check_baseline_roundtrip(self, tmp_path, monkeypatch):
        pkg = tmp_path / "core"
        pkg.mkdir()
        (pkg / "fixture.py").write_text(self.BAD)
        monkeypatch.chdir(tmp_path)
        assert analysis_main(["check", "core"]) == 1
        assert analysis_main(["baseline", "core", "--out", "b.json"]) == 0
        assert analysis_main(["check", "core", "--baseline", "b.json"]) == 0
        (pkg / "fixture.py").write_text("x = 1\n")  # debt paid -> stale pin
        assert analysis_main(["check", "core", "--baseline", "b.json"]) == 1

    def test_cli_json_report(self, tmp_path, monkeypatch):
        pkg = tmp_path / "core"
        pkg.mkdir()
        (pkg / "fixture.py").write_text(self.BAD)
        monkeypatch.chdir(tmp_path)
        analysis_main(["check", "core", "--json", "rep.json"])
        rep = json.loads((tmp_path / "rep.json").read_text())
        assert rep["count"] == 1 and rep["by_rule"] == {"NARROW": 1}


# ------------------------------------------------------- catalog discipline


def test_every_rule_has_help_text():
    # Same no-empty-help bar as obs/catalog.py (test_obs.py).
    assert missing_help() == []
    assert len(RULES) >= 6


def test_explain_cli_covers_every_rule(capsys):
    assert analysis_main(["explain"]) == 0
    out = capsys.readouterr().out
    for name in RULES:
        assert name in out
    assert help_for("narrow")  # case-insensitive lookup
    assert analysis_main(["explain", "NOSUCHRULE"]) == 2


# ------------------------------------------------------------ the repo gate


def test_repo_is_clean_against_committed_baseline():
    """The tier-1 wrapper: src/repro gated on analysis_baseline.json.

    Fails on any new finding AND on any stale pinned entry, so both
    regressions and silently-paid debt surface here (and in CI).
    """
    rep = analyze_paths([str(REPO / "src" / "repro")], rel_to=str(REPO))
    baseline = load_baseline(str(REPO / "analysis_baseline.json"))
    new, stale = diff_baseline(rep.findings, baseline)
    assert not new, "new findings:\n" + "\n".join(f.render() for f in new)
    assert not stale, "stale baseline keys:\n" + "\n".join(stale)
    # The burn-down left real waivers behind; the count only shrinks by
    # deleting the waived code, never by accident.
    assert len(rep.waived) >= 8


# ------------------------------------------------- perf-gate lint ratchet


def test_perf_gate_fails_when_finding_count_rises():
    from benchmarks.perf_gate import gate

    hist = [{"static_findings": {"count": 1}}]
    fresh = {
        "headlines": {},
        "static_findings": {"count": 3, "by_rule": {"NARROW": 3}},
    }
    _soft, hard = gate(fresh, hist)
    assert any("static_findings" in h for h in hard)

    _soft, hard = gate(
        {"headlines": {}, "static_findings": {"count": 1}}, hist
    )
    assert hard == []
    _soft, hard = gate(
        {"headlines": {}, "static_findings": {"count": 0}}, hist
    )
    assert hard == []  # burning debt down is always fine

    _soft, hard = gate({"headlines": {}}, [{"obs": {}}])
    assert hard == []  # no recorded counts on either side -> nothing to gate


# ------------------------------------- regressions for burned-down findings


class TestCheckedInt32:
    def test_raises_past_int32(self):
        from repro.core.bm25 import checked_int32

        with pytest.raises(OverflowError):
            checked_int32(np.array([0, 2**31], dtype=np.int64), "docids")
        with pytest.raises(OverflowError):
            checked_int32(np.array([-1], dtype=np.int64), "docids")

    def test_matches_plain_cast_in_range(self):
        from repro.core.bm25 import checked_int32

        rng = np.random.default_rng(7)
        a = rng.integers(0, 2**31 - 1, size=512, dtype=np.int64)
        np.testing.assert_array_equal(checked_int32(a), a.astype(np.int32))
        assert checked_int32(a).dtype == np.int32


def test_record_batch_early_returns_when_obs_disabled():
    # Before the OBSGUARD fix this crashed (np.asarray(None)) — the guard
    # lived only at drain_once's call site.
    from repro.obs import NOOP
    from repro.serving.microbatch import MicroBatchServer

    stub = SimpleNamespace(obs=NOOP)
    assert (
        MicroBatchServer._record_batch(
            stub, None, None, None, None, None, None, None, None
        )
        is None
    )


def test_replica_pad_slice_stays_on_device():
    import jax
    import jax.numpy as jnp

    from repro.control.replica import _slice_pad

    full = (jnp.arange(6).reshape(3, 2), jnp.ones(3))
    out = _slice_pad(full, 2)
    for x, ref in zip(out, full):
        assert isinstance(x, jax.Array) and not isinstance(x, np.ndarray)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(ref)[:2])


def test_replica_mesh_dispatch_threads_docs_format(monkeypatch):
    # The replica mesh must serve a packed-docids index with the same
    # decode the wrapped engine uses; before the fix docs_format was
    # silently dropped and packed indexes decoded as int32.
    import repro.control.replica as replica_mod
    from repro.obs import NOOP

    captured = {}

    def fake_make_mesh_dispatch(mesh, axis, **kwargs):
        captured.update(kwargs)
        return lambda *a: ("out",)

    monkeypatch.setattr(
        replica_mod, "make_mesh_dispatch", fake_make_mesh_dispatch
    )
    se = SimpleNamespace(
        n_shards=1, s_pad=4, k=8, impl="jax", interpret=False,
        docs_format="packed", dix=None, doc_base=None, obs=NOOP,
    )
    eng = replica_mod.ReplicaGroupEngine(se, n_replicas=1, use_mesh=True)
    blk = np.zeros((1, 2, 3), dtype=np.int32)
    z = np.zeros((1, 2), dtype=np.int32)
    eng.dispatch(blk, blk, z, z, z, z)
    assert captured["docs_format"] == "packed"
