"""Per-architecture smoke tests: reduced config, one real step per shape.

Every assigned architecture must instantiate and run one train/serve step on
CPU for each of its (non-skipped) shapes, producing finite outputs of the
right shape (deliverable f).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.optim.adamw import init_opt_state, AdamWConfig

# family "ir" smoke coverage lives in tests/test_distributed_ir.py (it needs
# a real index build + oracle, not random batches).
CASES = [
    (name, shape)
    for name, arch in ARCHS.items()
    for shape, info in arch.shapes().items()
    if info.skip is None and arch.family != "ir"
]


@pytest.mark.parametrize("name,shape", CASES, ids=[f"{n}-{s}" for n, s in CASES])
def test_arch_shape_smoke(name, shape):
    arch = ARCHS[name]
    cfg = arch.model_config(reduced=True)
    if arch.family == "gnn":
        rcfg = arch._resolved(cfg, shape)
        params = arch.init_params(jax.random.key(0), rcfg)
    else:
        params = arch.init_params(jax.random.key(0), cfg)
    batch = arch.make_batch(cfg, shape, seed=0)
    step, kind = arch.build_step(cfg, shape)

    if kind == "train":
        opt_state = init_opt_state(params, AdamWConfig())
        params2, opt_state2, metrics = jax.jit(step)(params, opt_state, batch)
        loss = float(jax.device_get(metrics["loss"]))
        assert np.isfinite(loss), f"{name}/{shape}: loss={loss}"
        # params actually moved
        delta = jax.tree.reduce(
            lambda a, b: a + float(jnp.sum(jnp.abs(b[0].astype(jnp.float32) - b[1].astype(jnp.float32)))),
            jax.tree.map(lambda x, y: (x, y), params, params2),
            0.0,
            is_leaf=lambda x: isinstance(x, tuple),
        )
        assert delta > 0
    else:
        out = jax.jit(step)(params, batch)
        leaves = jax.tree.leaves(out)
        assert leaves, f"{name}/{shape}: empty output"
        for l in leaves:
            assert np.all(np.isfinite(np.asarray(l, dtype=np.float32))) or l.dtype in (
                jnp.int32,
                jnp.bfloat16,
            ), f"{name}/{shape}: non-finite output"


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_full_configs_construct(name):
    """Full (published-scale) configs must instantiate without allocation."""
    arch = ARCHS[name]
    cfg = arch.model_config(reduced=False)
    for shape, info in arch.shapes().items():
        if info.skip:
            continue
        specs = arch.input_specs(cfg, shape)
        assert jax.tree.leaves(specs), f"{name}/{shape}: no input specs"


def test_lm_param_counts_match_published():
    """count_params must land near the published sizes (sanity on configs)."""
    from repro.models.transformer import count_params

    qwen3 = ARCHS["qwen3-4b"].model_config()
    total, _ = count_params(qwen3)
    assert 3.5e9 < total < 5.0e9, total

    ds67 = ARCHS["deepseek-67b"].model_config()
    total, _ = count_params(ds67)
    assert 60e9 < total < 72e9, total

    dsv3 = ARCHS["deepseek-v3-671b"].model_config()
    total, active = count_params(dsv3)
    assert 600e9 < total < 720e9, total
    assert 30e9 < active < 45e9, active  # ~37B active

    # NOTE: the assignment block pins moonshot at 48L x 64e top-6 — that is
    # ~28B total (Moonlight's published 16B uses 27 layers); we follow the
    # assigned spec, so assert against the spec-implied count.
    moon = ARCHS["moonshot-v1-16b-a3b"].model_config()
    total, active = count_params(moon)
    assert 24e9 < total < 32e9, total
    assert 2e9 < active < 6e9, active
