"""Batch/sequential parity for the vmapped serving engine.

The contract under test: ``BatchEngine`` (shape-bucketed, vmapped
``device_traverse``) is *bitwise* identical to looping the single-query
device traversal — same doc ids, scores, tie-breaks, exit flags, and work
counters — across ragged batches, heterogeneous per-query budgets, and
every bucket shape the stream produces.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest
from differential import assert_batch_matches_sequential

from repro.core.clustered_index import build_index
from repro.core.range_daat import Engine, batched_topk_docs, exit_reasons
from repro.data.synth import make_corpus, make_query_log
from repro.serving import (
    BatchEngine,
    BucketSpec,
    MicroBatchServer,
    SlaBudgeter,
    bucket_pow2,
    stack_plans,
)


def _small_setup(seed: int, n_ranges: int, k: int = 5):
    corpus = make_corpus(
        n_docs=900, n_terms=700, n_topics=4, mean_doc_len=50, seed=seed
    )
    idx = build_index(corpus, n_ranges=n_ranges, strategy="clustered")
    eng = Engine(idx, k=k)
    log = make_query_log(corpus, n_queries=12, seed=seed + 1)
    return eng, [log.terms[i] for i in range(log.n_queries)]


# Batched-vs-sequential parity lives in the shared differential harness
# (tests/differential.py) so the packed-docid suite pins the same contract.
_assert_parity = assert_batch_matches_sequential


# ------------------------------------------------------------------ bucketing


def test_bucket_pow2_ladder():
    assert bucket_pow2(1, lo=32) == 32
    assert bucket_pow2(33, lo=32) == 64
    assert bucket_pow2(64, lo=32) == 64
    assert bucket_pow2(100, lo=1, hi=32) == 32
    spec = BucketSpec(min_width=32, max_batch=16)
    assert spec.width_bucket(5) == 32
    assert spec.batch_bucket(9) == 16
    assert spec.batch_bucket(300) == 16


def test_bucket_pow2_rejects_inconsistent_clamp():
    with pytest.raises(ValueError):
        bucket_pow2(10, lo=8, hi=4)  # hi < lo: no consistent bucket exists
    with pytest.raises(ValueError):
        bucket_pow2(10, lo=0)
    # Boundary: hi == lo is a degenerate but consistent single-bucket ladder.
    assert bucket_pow2(100, lo=16, hi=16) == 16
    with pytest.raises(ValueError):
        BucketSpec(min_width=0)
    with pytest.raises(ValueError):
        BucketSpec(min_batch=8, max_batch=4)


def test_stack_plans_saturates_int64_bounds():
    """A BoundSum past 2^31 must saturate, not wrap negative: a wrapped
    bound satisfies ``bound <= theta`` immediately and silently disables
    safe termination for that range."""
    eng, queries = _small_setup(seed=1, n_ranges=4)
    plan = eng.plan(queries[0])
    huge = plan.bounds_host.astype(np.int64).copy()
    huge[0] = 2**31 + 12345  # would wrap to a negative int32
    big_plan = dataclasses.replace(plan, bounds_host=huge)
    with pytest.warns(RuntimeWarning, match="saturating"):
        bp = stack_plans([big_plan], width=plan.blk_tab.shape[1], batch=1)
    got = np.asarray(bp.ordered_bounds)[0]
    assert got[0] == 2**31 - 1  # saturated, positive
    assert np.all(got >= 0)
    assert got[1:].tolist() == huge[1:].astype(np.int64).tolist()

    neg_plan = dataclasses.replace(
        plan, bounds_host=np.where(np.arange(len(huge)) == 0, -5, huge)
    )
    with pytest.raises(ValueError, match="negative"):
        stack_plans([neg_plan], width=plan.blk_tab.shape[1], batch=1)


def test_stack_plans_pads_with_inert_dummies():
    eng, queries = _small_setup(seed=0, n_ranges=4)
    plans = [eng.plan(q) for q in queries[:3]]
    width = bucket_pow2(max(p.blk_tab.shape[1] for p in plans), lo=32)
    bp = stack_plans(plans, width, batch=8)
    assert bp.blk_tab.shape == (8, 4, width)
    assert bp.valid.tolist() == [True] * 3 + [False] * 5
    assert np.all(np.asarray(bp.blk_tab)[3:] == -1)  # dummy lanes: no blocks
    # Padding columns of real lanes are -1 too.
    w0 = plans[0].blk_tab.shape[1]
    assert np.all(np.asarray(bp.blk_tab)[0, :, w0:] == -1)


# ------------------------------------------------------ bitwise parity suite


@pytest.mark.parametrize("seed,n_ranges", [(0, 3), (7, 4), (13, 6)])
def test_batch_matches_sequential_bitwise(seed, n_ranges):
    """Random synthetic indexes: batched == looped device_traverse, bitwise."""
    eng, queries = _small_setup(seed=seed, n_ranges=n_ranges)
    beng = BatchEngine(eng, BucketSpec(max_batch=8))
    plans = beng.plan_many(queries)
    _assert_parity(eng, plans, beng.run_batch(plans))


def test_ragged_batch_heterogeneous_lengths_and_budgets():
    """Mixed query lengths (several width buckets) + per-query budgets."""
    eng, queries = _small_setup(seed=3, n_ranges=4)
    # Force raggedness: 1-term stubs and plain queries sit in the narrow
    # width bucket; "fat" union queries (dozens of terms -> wide block
    # tables) land in a wider one. An odd-sized narrow group also exercises
    # a second batch bucket (4,4,1 chunking under max_batch=4).
    stripped = [q[q >= 0] for q in queries]
    fat = np.unique(np.concatenate(stripped))
    ragged = [stripped[0][:1]] + stripped[:8] + [fat, fat[::2], fat[1:]]
    beng = BatchEngine(eng, BucketSpec(max_batch=4))
    plans = beng.plan_many(ragged)
    assert len({beng.spec.width_bucket(p.blk_tab.shape[1]) for p in plans}) >= 2

    rng = np.random.default_rng(0)
    budgets = rng.choice([150, 600, 2**31 - 1], size=len(plans))
    results = beng.run_batch(plans, budget_postings=budgets)
    _assert_parity(eng, plans, results, budgets=budgets)
    # The stream must have exercised >= 3 distinct (batch, width) shapes.
    assert len(beng.compiled_shapes) >= 3, beng.compiled_shapes


def test_per_query_budget_isolation():
    """A starved lane exits on budget; unbounded batchmates are unaffected."""
    eng, queries = _small_setup(seed=5, n_ranges=4)
    beng = BatchEngine(eng, BucketSpec(max_batch=8))
    plans = beng.plan_many(queries[:6])
    budgets = np.full(6, 2**31 - 1, dtype=np.int64)
    budgets[2] = 1  # starve one lane
    results = beng.run_batch(plans, budget_postings=budgets)
    assert results[2].exit_budget and results[2].exit_reason == "budget"
    free = beng.run_batch(plans)  # same batch, nobody starved
    for i in (0, 1, 3, 4, 5):
        assert results[i].doc_ids.tolist() == free[i].doc_ids.tolist()
        assert results[i].scores.tolist() == free[i].scores.tolist()


def test_max_ranges_parity_and_exit_reasons():
    eng, queries = _small_setup(seed=11, n_ranges=6)
    beng = BatchEngine(eng, BucketSpec(max_batch=8))
    plans = beng.plan_many(queries[:8])
    maxr = np.asarray([0, 1, 2, 3, 2**31 - 1, 2**31 - 1, 1, 2])
    results = beng.run_batch(plans, max_ranges=maxr)
    _assert_parity(eng, plans, results, max_ranges=maxr)
    assert results[0].ranges_processed == 0
    assert results[0].exit_reason == "budget"
    assert results[4].exit_reason in ("safe", "exhausted")


def test_recompile_bound_holds():
    """Program cache stays within #width_buckets x #batch_buckets."""
    eng, queries = _small_setup(seed=17, n_ranges=4)
    spec = BucketSpec(max_batch=8)
    beng = BatchEngine(eng, spec)
    rng = np.random.default_rng(0)
    for _ in range(6):
        n = int(rng.integers(1, 13))
        picks = [queries[int(j)] for j in rng.integers(0, len(queries), size=n)]
        beng.run_batch(beng.plan_many(picks))
    widths = {w for _, w in beng.compiled_shapes}
    batches = {b for b, _ in beng.compiled_shapes}
    assert len(beng.compiled_shapes) <= len(widths) * len(batches)
    assert all(b <= spec.max_batch and b & (b - 1) == 0 for b in batches)
    assert all(w >= spec.min_width and w & (w - 1) == 0 for w in widths)


def test_batched_state_roundtrip_helpers():
    """vmapped TraverseResult unstacks via batched_topk_docs/exit_reasons."""
    eng, queries = _small_setup(seed=19, n_ranges=4)
    beng = BatchEngine(eng, BucketSpec(max_batch=8))
    plans = beng.plan_many(queries[:4])
    # Drive batched_traverse directly through Engine.topk_docs' 2D path.
    from repro.core.range_daat import batched_traverse
    import jax.numpy as jnp

    width = max(beng.spec.width_bucket(p.blk_tab.shape[1]) for p in plans)
    bp = stack_plans(plans, width, batch=4)
    res = batched_traverse(
        eng.dix, bp.blk_tab, bp.rest_tab, bp.order, bp.ordered_bounds,
        jnp.full((4,), 2**31 - 1, jnp.int32), jnp.full((4,), 2**31 - 1, jnp.int32),
        s_pad=eng.s_pad, k=eng.k,
    )
    assert np.asarray(res.state.vals).shape == (4, eng.k)
    reasons = exit_reasons(res)
    assert len(reasons) == 4 and set(reasons) <= {"safe", "budget", "exhausted"}
    per_query = eng.topk_docs(res.state)  # 2D state -> list of pairs
    assert per_query[0][0].tolist() == batched_topk_docs(res.state)[0][0].tolist()
    for plan, (ids, vals) in zip(plans, per_query):
        sids, svals = eng.topk_docs(eng.traverse(plan).state)
        assert ids.tolist() == sids.tolist() and vals.tolist() == svals.tolist()


# ------------------------------------------------------------- request loop


def test_microbatch_server_serves_all_and_adapts():
    eng, queries = _small_setup(seed=23, n_ranges=4)
    beng = BatchEngine(eng, BucketSpec(max_batch=8))
    budgeter = SlaBudgeter(sla_ms=1e9)  # generous: no misses expected
    server = MicroBatchServer(beng, budgeter, max_batch=8)
    served = server.replay(queries, batch_size=8)
    assert sorted(s.rid for s in served) == list(range(len(queries)))
    assert server.pending == 0
    assert all(s.latency_ms >= 0 for s in served)

    # Reactive feedback: a missed batch must shrink the next budgets.
    tight = SlaBudgeter(sla_ms=10.0, rate=100.0)
    before = int(tight.budgets(1)[0])
    tight.observe(elapsed_ms=50.0, total_postings=500, n=1)  # SLA miss
    after = int(tight.budgets(1)[0])
    assert tight.policy.alpha > 1.0 and after < before
    # Budget floor: even a brutal miss streak still admits one block.
    for _ in range(50):
        tight.observe(elapsed_ms=1e5, total_postings=1, n=1)
    from repro.core.clustered_index import BLOCK

    assert int(tight.budgets(1)[0]) >= BLOCK
