"""Boolean conjunction on the clustered index (paper §3 closing claim)."""

from __future__ import annotations

import numpy as np

from repro.core.boolean import conjunctive_query


def _naive_and(index, terms):
    sets = []
    for t in terms:
        s, e = index.ptr[int(t)], index.ptr[int(t) + 1]
        sets.append(set(index.docs[s:e].tolist()))
    out = set.intersection(*sets) if sets else set()
    return np.asarray(sorted(out), dtype=np.int64)


def test_conjunction_matches_naive(index, queries):
    for q in queries:
        terms = [int(t) for t in q if t >= 0][:3]
        if len(terms) < 2:
            continue
        res = conjunctive_query(index, np.asarray(terms))
        np.testing.assert_array_equal(res.doc_ids, _naive_and(index, terms))


def test_range_skipping_engages(index, queries):
    """Rare-term conjunctions must skip ranges without touching postings."""
    skipped = 0
    for q in queries:
        terms = [int(t) for t in q if t >= 0]
        if len(terms) < 3:
            continue
        res = conjunctive_query(index, np.asarray(terms))
        skipped += res.ranges_skipped
    assert skipped > 0


def test_empty_and_single_term():
    import numpy as np

    from repro.core.clustered_index import build_index
    from repro.data.synth import make_corpus

    c = make_corpus(n_docs=200, n_terms=200, n_topics=4, seed=9)
    idx = build_index(c, n_ranges=4, strategy="clustered")
    res = conjunctive_query(idx, np.asarray([-1]))
    assert res.doc_ids.size == 0
    t = int(idx.blk_term[0])
    res1 = conjunctive_query(idx, np.asarray([t]))
    s, e = idx.ptr[t], idx.ptr[t + 1]
    np.testing.assert_array_equal(res1.doc_ids, np.sort(idx.docs[s:e]).astype(np.int64))
