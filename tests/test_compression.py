"""Int8 error-feedback gradient compression (optim/compression.py)."""

from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.optim.compression import _dequantize, _quantize


_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.1, size=(1000,)).astype(np.float32)
    import jax.numpy as jnp

    q, s = _quantize(jnp.asarray(x))
    back = np.asarray(_dequantize(q, s, x.shape))
    # Block absmax int8: error <= scale/2 = absmax/254 per block.
    assert np.max(np.abs(back - x)) <= np.abs(x).max() / 127.0 + 1e-7


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.optim.compression import compress_psum_pod, init_error_buffers

mesh = jax.make_mesh((2, 2), ("pod", "data"))
params = {"w": jnp.asarray(np.linspace(-1, 1, 64, dtype=np.float32))}

def loss(p, batch):
    return jnp.mean((jnp.dot(batch, p["w"]) - 1.0) ** 2)

def grad_fn(batch_shard):
    return jax.grad(loss)(params, batch_shard)

rng = np.random.default_rng(0)
batch = rng.normal(size=(8, 64)).astype(np.float32)
batch_dev = jax.device_put(batch, NamedSharding(mesh, P("pod", None)))
err = init_error_buffers(params, n_pods=2)
err = jax.device_put(err, NamedSharding(mesh, P("pod", None)))

fn = jax.jit(compress_psum_pod(grad_fn, mesh))
grads, new_err = fn(batch_dev, err)

# Reference: mean of per-pod fp32 grads.
g0 = jax.grad(loss)(params, jnp.asarray(batch[:4]))["w"]
g1 = jax.grad(loss)(params, jnp.asarray(batch[4:]))["w"]
ref = (np.asarray(g0) + np.asarray(g1)) / 2
got = np.asarray(grads["w"])
rel = np.max(np.abs(got - ref)) / (np.max(np.abs(ref)) + 1e-9)
assert rel < 2e-2, rel
# Error buffers hold the (nonzero) quantization residue per pod.
e = np.asarray(new_err["w"])
assert e.shape[0] == 2 and np.abs(e).max() > 0
print("COMPRESSION_OK", rel)
"""


@pytest.mark.slow
def test_compressed_pod_reduction_matches_mean():
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd=_REPO_ROOT, timeout=600,
    )
    assert "COMPRESSION_OK" in out.stdout, out.stdout[-1500:] + out.stderr[-2500:]
