"""Control plane: replica groups, online reshard, degraded failover.

The contracts under test (DESIGN.md §9):

  * **restack** — ``restack_shards`` re-carves a shard set to new range
    cuts from shard arrays alone, array-for-array identical to
    ``shard_device_index(index, cuts=...)`` on the original index;
  * **live cutover** — a reshard driven through ``ControlPlane.drain_once``
    never blocks serving (every drain during the cutover returns results),
    and post-cutover results are bitwise-equal to a fresh build at the new
    layout;
  * **failover** — a shard marked down keeps queries flowing with
    ``exact=False`` and a ``fidelity_bound`` equal to the dead shard's
    unprocessed BoundSum mass for the query, and recovery restores bitwise
    parity;
  * **replicas** — ``ReplicaGroupEngine`` over the (data x shard) mesh is
    bitwise identical to single-replica serving (subprocess, 4 forced CPU
    host devices);
  * **shard-aware budgets** — BoundSum-mode SLA allocation tightens
    ``fidelity_bound`` on a skewed planted index under a tight budget.
"""

from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.control import ControlPlane, HealthLedger, ReplicaGroupEngine, ReshardPlanner
from repro.core.clustered_index import (
    BLOCK,
    build_index,
    range_postings_mass,
    restack_shards,
    shard_cuts,
    shard_device_index,
)
from repro.core.range_daat import Engine
from repro.core.reorder import Arrangement
from repro.data.synth import Corpus, make_corpus, make_query_log
from repro.serving import (
    BucketSpec,
    ShardedBatchEngine,
    ShardedEngine,
    ShardedSlaBudgeter,
)

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
INT32_MAX = 2**31 - 1


def _small_setup(seed: int, n_ranges: int, k: int = 5, n_queries: int = 10):
    corpus = make_corpus(
        n_docs=900, n_terms=700, n_topics=4, mean_doc_len=50, seed=seed
    )
    idx = build_index(corpus, n_ranges=n_ranges, strategy="clustered")
    eng = Engine(idx, k=k)
    log = make_query_log(corpus, n_queries=n_queries, seed=seed + 1)
    return idx, eng, [log.terms[i] for i in range(log.n_queries)]


def _planted_setup(
    n_topics: int = 4,
    ranges_per_topic: int = 4,
    docs_per_range: int = 100,
    terms_per_topic: int = 40,
    doc_len: int = 20,
    seed: int = 0,
):
    """Fully planted topical index: topic t owns terms [t*T, (t+1)*T) and a
    contiguous band of ``ranges_per_topic`` ranges, so a topic-t query's
    BoundSum mass lives entirely in one shard of ``n_topics`` — maximal
    skew, deterministic by construction (no k-means in the loop)."""
    rng = np.random.default_rng(seed)
    docs_per_topic = ranges_per_topic * docs_per_range
    n_docs = n_topics * docs_per_topic
    n_terms = n_topics * terms_per_topic
    doc_terms, doc_tfs, ptr = [], [], [0]
    for d in range(n_docs):
        topic = d // docs_per_topic
        vocab = np.arange(
            topic * terms_per_topic, (topic + 1) * terms_per_topic
        )
        terms = np.sort(rng.choice(vocab, size=doc_len, replace=False))
        doc_terms.append(terms)
        doc_tfs.append(rng.integers(1, 5, size=doc_len))
        ptr.append(ptr[-1] + doc_len)
    corpus = Corpus(
        n_docs=n_docs,
        n_terms=n_terms,
        doc_ptr=np.asarray(ptr, np.int64),
        doc_terms=np.concatenate(doc_terms).astype(np.int32),
        doc_tfs=np.concatenate(doc_tfs).astype(np.int32),
        doc_topic=(np.arange(n_docs) // docs_per_topic).astype(np.int32),
        n_topics=n_topics,
    )
    n_ranges = n_topics * ranges_per_topic
    arrangement = Arrangement(
        doc_order=np.arange(n_docs, dtype=np.int64),
        range_ends=(np.arange(1, n_ranges + 1) * docs_per_range).astype(
            np.int64
        ),
        strategy="clustered",
    )
    idx = build_index(corpus, arrangement=arrangement)
    return corpus, idx, Engine(idx, k=10)


# ----------------------------------------------------------- health ledger


def test_health_ledger_masks_and_events():
    led = HealthLedger(n_shards=3, n_replicas=2)
    assert led.all_up and led.n_healthy_replicas() == 2
    led.mark_down(1, replica=0)
    # Shard 1 still alive on replica 1: not down for serving.
    assert not led.shard_down_mask()[1]
    assert led.replica_healthy_mask().tolist() == [False, True]
    led.mark_down(1, replica=1)
    assert led.shard_down_mask().tolist() == [False, True, False]
    led.mark_up(1)  # both replicas
    assert led.all_up
    assert [e.kind for e in led.events] == ["down", "down", "up"]
    led.reset()
    assert led.all_up
    with pytest.raises(ValueError):
        led.mark_down(3)
    with pytest.raises(ValueError):
        led.mark_down(0, replica=2)


# ---------------------------------------------------------------- restack


@pytest.mark.parametrize(
    "new_cuts", [[0, 1, 3, 6], [0, 5, 6], [0, 1, 2, 3, 4, 5, 6], [0, 6]]
)
def test_restack_shards_matches_fresh_carve_bitwise(new_cuts):
    """restack == shard_device_index(cuts=...) array-for-array, including
    cuts that split old shard bands mid-way."""
    idx, _, _ = _small_setup(seed=7, n_ranges=6)
    old = shard_device_index(idx, 3)
    cuts = np.asarray(new_cuts)
    fresh = shard_device_index(idx, cuts=cuts)
    restacked = restack_shards(old, cuts)
    for f, r in zip(fresh, restacked):
        for name in ("shard_id", "range_lo", "range_hi", "doc_base",
                     "n_docs", "postings"):
            assert getattr(f, name) == getattr(r, name), name
        for name in ("docs", "impacts", "blk_start", "blk_len", "blk_maxdoc",
                     "blk_maximp", "blk_map", "range_starts", "range_sizes",
                     "bounds_dense"):
            a, b = getattr(f, name), getattr(r, name)
            assert a.dtype == b.dtype, name
            np.testing.assert_array_equal(a, b, err_msg=name)
    # Staged variant: only= carves one output shard at a time.
    for s in range(len(new_cuts) - 1):
        (piece,) = restack_shards(old, cuts, only=s)
        np.testing.assert_array_equal(piece.docs, fresh[s].docs)
        assert piece.shard_id == s


def test_restack_shards_rejects_bad_inputs():
    idx, _, _ = _small_setup(seed=7, n_ranges=6)
    old = shard_device_index(idx, 3)
    with pytest.raises(ValueError):
        restack_shards(old, [0, 3])  # does not reach n_ranges
    with pytest.raises(ValueError):
        restack_shards(old, [0, 3, 3, 6])  # empty band
    with pytest.raises(ValueError):
        restack_shards([], [0, 6])
    with pytest.raises(ValueError):
        restack_shards(old[:2], [0, 6])  # holes in the range space


# ---------------------------------------------------------------- planner


def test_reshard_planner_arms_on_skewed_load():
    idx, _, _ = _small_setup(seed=3, n_ranges=8)
    shards = shard_device_index(idx, 4)
    planner = ReshardPlanner(
        range_mass=range_postings_mass(idx), cuts=shard_cuts(shards),
        trigger=1.25,
    )
    assert not planner.should_reshard()  # no observations yet
    # Uniform load: stays put.
    planner.observe(np.full(4, 1000.0), n_queries=4)
    assert planner.imbalance() == pytest.approx(1.0)
    assert not planner.should_reshard()
    # Shard 0 runs 8x hotter than its peers: planner arms, and the
    # proposal shrinks shard 0's band.
    for _ in range(10):
        planner.observe(np.asarray([8000.0, 1000.0, 1000.0, 1000.0]), 4)
    assert planner.imbalance() > planner.trigger
    assert planner.should_reshard()
    new_cuts = planner.propose()
    old_cuts = planner.cuts
    assert not np.array_equal(new_cuts, old_cuts)
    assert new_cuts[1] <= old_cuts[1]  # hot shard's band did not grow
    planner.committed(new_cuts)
    assert planner.batches_seen == 0 and not planner.should_reshard()


def test_reshard_planner_scales_against_static_shares():
    """Load scaling uses *frozen* static mass shares: 2 equal-mass shards
    at 3:1 observed load must re-weight to exactly 150/150/50/50 and cut
    the hot band down to one range."""
    planner = ReshardPlanner(
        range_mass=np.asarray([100, 100, 100, 100]),
        cuts=np.asarray([0, 2, 4]),
    )
    planner.observe(np.asarray([300.0, 100.0]), n_queries=1)
    # shard 0: load share 0.75 / mass share 0.5 -> x1.5; shard 1 -> x0.5.
    np.testing.assert_array_equal(planner.propose(), [0, 1, 4])


# ------------------------------------------------- plane: serving + failover


def test_control_plane_serves_identically_to_sharded_engine():
    _, eng, queries = _small_setup(seed=7, n_ranges=6, n_queries=12)
    plane = ControlPlane(
        eng, n_shards=3, spec=BucketSpec(max_batch=4), use_mesh=False
    )
    base = ShardedEngine(eng, 3, use_mesh=False)
    served = plane.replay(queries, batch_size=4)
    assert sorted(s.rid for s in served) == list(range(len(queries)))
    for s in served:
        b = base.traverse(eng.plan(queries[s.rid]))
        assert s.result.doc_ids.tolist() == b.doc_ids.tolist()
        assert s.result.scores.tolist() == b.scores.tolist()
        assert s.result.exact
    assert plane.queries_served == len(queries)


def test_degraded_serving_widens_fidelity_bound_and_recovers():
    """Down shard: queries return, exact=False, fidelity_bound == the dead
    shard's max unprocessed BoundSum for the query; recovery is bitwise."""
    _, eng, queries = _small_setup(seed=9, n_ranges=6, n_queries=8)
    plane = ControlPlane(
        eng, n_shards=3, spec=BucketSpec(max_batch=4), use_mesh=False
    )
    base = ShardedEngine(eng, 3, use_mesh=False)
    dead = 1
    plane.mark_down(dead)
    served = plane.replay(queries, batch_size=4)
    assert len(served) == len(queries)  # every query still returns
    degraded = 0
    for s in served:
        r = s.result
        plan = eng.plan(queries[s.rid])
        assert r.shard_exit_reasons[dead] == "down"
        assert r.shard_postings[dead] == 0
        # Expected widening: the dead shard's per-query BoundSum mass is
        # its ranges' max bound (nothing of it was processed).
        per_range = np.zeros(int(plane.cuts[-1]), np.int64)
        per_range[plan.order_host] = plan.bounds_host
        lo, hi = int(plane.cuts[dead]), int(plane.cuts[dead + 1])
        expect_fb = int(per_range[lo:hi].max())
        assert r.fidelity_bound == expect_fb
        if expect_fb > 0:
            assert not r.exact
            degraded += 1
    assert degraded > 0  # the outage actually cost something
    # Replica returns: ledger clears, results bitwise again.
    plane.mark_up(dead)
    for s in plane.replay(queries, batch_size=4):
        b = base.traverse(eng.plan(queries[s.rid % len(queries)]))
        r = s.result
        assert r.doc_ids.tolist() == b.doc_ids.tolist()
        assert r.scores.tolist() == b.scores.tolist()
        assert r.exact and "down" not in r.shard_exit_reasons


def test_down_mask_in_sharded_engine_traverse():
    """Engine-level degraded path (no plane): reasons, bound, recovery."""
    _, eng, queries = _small_setup(seed=11, n_ranges=6)
    se = ShardedEngine(eng, 4, use_mesh=False)
    down = np.zeros(4, bool)
    down[2] = True
    for q in queries[:4]:
        plan = eng.plan(q)
        r = se.traverse(plan, down_mask=down)
        assert r.shard_exit_reasons[2] == "down"
        assert r.shard_postings[2] == 0
        mass = se.query_shard_mass(plan)
        if mass[2] > 0:
            assert not r.exact and r.fidelity_bound > 0
        clean = se.traverse(plan)
        assert clean.exact or "budget" not in clean.shard_exit_reasons


def test_base_observe_api_never_credits_down_shards():
    """Bugfix regression: the base-API ``observe`` fallback used to spread
    ``total_postings`` evenly over ALL shards, so a health-ledger-down
    shard's rate EWMA absorbed phantom work. With the ledger mask wired,
    a down shard's EWMA stays frozen and the spread covers active shards."""
    down = np.zeros(4, bool)
    down[1] = True
    bud = ShardedSlaBudgeter(sla_ms=5.0, n_shards=4, down_mask=lambda: down)
    r0 = bud.rates.copy()
    bud.observe(10.0, total_postings=12000, n=3)
    assert bud.rates[1] == r0[1]  # frozen through the outage
    assert np.all(bud.rates[[0, 2, 3]] > r0[[0, 2, 3]])
    # Spread is total / n_active (=3), per-lane over n=3 queries, 10 ms.
    expect = (1 - bud.ema) * r0[0] + bud.ema * (12000 / 3 / 3 / 10.0)
    assert np.isclose(bud.rates[0], expect)
    # Whole fleet down: nothing learned, only the Reactive policy advances.
    bud_all = ShardedSlaBudgeter(
        sla_ms=5.0, n_shards=2, down_mask=lambda: np.ones(2, bool)
    )
    r_all = bud_all.rates.copy()
    bud_all.observe(10.0, 5000, 2)
    np.testing.assert_array_equal(bud_all.rates, r_all)
    # Unwired budgeter keeps the old even-spread behaviour.
    plain = ShardedSlaBudgeter(sla_ms=5.0, n_shards=4)
    plain.observe(10.0, 12000, 3)
    assert np.allclose(plain.rates, (1 - plain.ema) * 100.0 + plain.ema * (12000 / 4 / 3 / 10.0))

    # The plane wires its ledger into the default budgeter automatically.
    _, eng, _ = _small_setup(seed=5, n_ranges=6)
    plane = ControlPlane(
        eng, n_shards=3, spec=BucketSpec(max_batch=4), use_mesh=False
    )
    assert plane.budgeter.down_mask is not None
    plane.mark_down(1)
    frozen = plane.budgeter.rates[1]
    plane.budgeter.observe(7.0, 9000, 2)  # base API, mid-outage
    assert plane.budgeter.rates[1] == frozen


def test_reshard_refused_then_deferred_through_outage():
    """Bugfix regression: an explicit ``start_reshard`` during an outage is
    refused with a clear error (a cutover would restack from dead arrays
    and re-seed budgets from outage-skewed counters); the deferred variant
    waits for recovery and then cuts over bitwise."""
    idx, eng, queries = _small_setup(seed=19, n_ranges=6, n_queries=6)
    plane = ControlPlane(
        eng, n_shards=3, spec=BucketSpec(max_batch=4), use_mesh=False
    )
    plane.mark_down(1)
    with pytest.raises(RuntimeError, match="outage"):
        plane.start_reshard(np.asarray([0, 1, 4, 6]))
    # Even an armed planner must not fire mid-outage.
    plane.planner.load = np.asarray([9000.0, 100.0, 100.0])
    plane.planner.batches_seen = 5
    assert plane.planner.should_reshard()
    assert not plane.maybe_reshard()
    assert plane.reshard_task is None

    # A bad request fails at request time even on the deferred path — it
    # must never surface later out of the recovery mark_up.
    with pytest.raises(ValueError, match="already the live layout"):
        plane.start_reshard(plane.cuts.copy(), defer_if_degraded=True)
    with pytest.raises(ValueError, match="rise strictly"):
        plane.start_reshard(np.asarray([0, 4, 4, 6]), defer_if_degraded=True)
    assert plane.deferred_reshard is None

    # Deferred: queued, serving continues degraded, starts on recovery.
    assert plane.start_reshard(
        np.asarray([0, 1, 4, 6]), defer_if_degraded=True
    ) is None
    assert plane.stats()["reshard_deferred"]
    served = plane.replay(queries, batch_size=4)
    assert len(served) == len(queries) and plane.reshard_task is None
    plane.mark_up(1)
    assert plane.reshard_task is not None and plane.deferred_reshard is None
    while plane.reshard_task is not None:
        plane.drain_once()
    np.testing.assert_array_equal(plane.cuts, [0, 1, 4, 6])
    fresh = ShardedEngine(
        eng, 3, use_mesh=False,
        shards=shard_device_index(idx, cuts=np.asarray([0, 1, 4, 6])),
    )
    for q in queries[:4]:
        plan = eng.plan(q)
        a = plane.bengine.run_batch([plan])[0]
        b = fresh.traverse(plan)
        assert a.doc_ids.tolist() == b.doc_ids.tolist()
        assert a.scores.tolist() == b.scores.tolist()


# --------------------------------------------------- plane: online reshard


def test_live_reshard_never_pauses_and_cuts_over_bitwise():
    """Acceptance: serving continues through every cutover step, and the
    post-cutover engine equals a fresh build at the new layout bitwise."""
    idx, eng, queries = _small_setup(seed=7, n_ranges=6, n_queries=12)
    plane = ControlPlane(
        eng, n_shards=3, spec=BucketSpec(max_batch=4), use_mesh=False
    )
    new_cuts = np.asarray([0, 1, 4, 6])
    assert not np.array_equal(new_cuts, plane.cuts)
    task = plane.start_reshard(new_cuts)
    stages = []
    i = 0
    while plane.reshard_task is not None:
        plane.submit(queries[i % len(queries)])
        stages.append(task.stage)
        served = plane.drain_once()
        assert len(served) == 1  # serving never pauses mid-cutover
        assert served[0].result.doc_ids.shape[0] > 0
        i += 1
    assert plane.reshards_completed == 1
    assert "carve" in stages and "build" in stages
    np.testing.assert_array_equal(plane.cuts, new_cuts)
    assert plane.queries_served_during_reshard == len(stages)

    fresh = ShardedEngine(
        eng, 3, use_mesh=False, shards=shard_device_index(idx, cuts=new_cuts)
    )
    for q in queries:
        plan = eng.plan(q)
        a = plane.bengine.run_batch([plan])[0]
        b = fresh.traverse(plan)
        assert a.doc_ids.tolist() == b.doc_ids.tolist()
        assert a.scores.tolist() == b.scores.tolist()
        assert a.shard_postings.tolist() == b.shard_postings.tolist()
        assert a.shard_ranges.tolist() == b.shard_ranges.tolist()
    # A second reshard cannot start while one is pending.
    t2 = plane.start_reshard(np.asarray([0, 2, 4, 6]))
    with pytest.raises(RuntimeError):
        plane.start_reshard(np.asarray([0, 1, 2, 6]))
    while plane.reshard_task is not None:
        plane.drain_once()
    assert plane.reshards_completed == 2 and t2.ready


def test_reshard_from_saved_artifact(tmp_path):
    """Cutover driven from an index_io shard artifact on disk."""
    idx, eng, queries = _small_setup(seed=13, n_ranges=6, n_queries=6)
    plane = ControlPlane(
        eng, n_shards=3, spec=BucketSpec(max_batch=4), use_mesh=False
    )
    from repro import index_io

    path = str(tmp_path / "layout")
    plane.save_shards(path)
    manifest = index_io.read_manifest(path)
    assert manifest["range_cuts"] == plane.cuts.tolist()
    assert manifest["source_fingerprint"] == eng.index.fingerprint()

    # An artifact with no recorded source fingerprint is refused outright
    # (same stance as ShardedEngine.from_artifact), as is a stale one.
    bare = str(tmp_path / "bare")
    index_io.save_shards(plane.sengine.shards, bare)
    with pytest.raises(index_io.ArtifactError):
        plane.start_reshard(np.asarray([0, 1, 4, 6]), shards_path=bare)

    new_cuts = np.asarray([0, 1, 4, 6])
    if np.array_equal(new_cuts, plane.cuts):
        new_cuts = np.asarray([0, 2, 4, 6])
    plane.start_reshard(new_cuts, shards_path=path)
    while plane.reshard_task is not None:
        plane.drain_once()
    fresh = ShardedEngine(
        eng, 3, use_mesh=False, shards=shard_device_index(idx, cuts=new_cuts)
    )
    for q in queries:
        plan = eng.plan(q)
        a = plane.bengine.run_batch([plan])[0]
        b = fresh.traverse(plan)
        assert a.doc_ids.tolist() == b.doc_ids.tolist()
        assert a.scores.tolist() == b.scores.tolist()


def test_planner_driven_reshard_under_skewed_traffic():
    """Topic-skewed traffic arms the planner through the serving loop and
    maybe_reshard executes a full live cutover."""
    corpus, idx, eng = _planted_setup()
    plane = ControlPlane(
        eng, n_shards=4, spec=BucketSpec(max_batch=4), use_mesh=False,
        reshard_trigger=1.2,
    )
    # All traffic hits topic 0 (shard 0): load EWMA goes lopsided.
    rng = np.random.default_rng(5)
    topic_queries = [
        rng.choice(40, size=8, replace=False).astype(np.int32)
        for _ in range(16)
    ]
    plane.replay(topic_queries, batch_size=4)
    assert plane.planner.imbalance() > plane.planner.trigger
    assert plane.maybe_reshard()
    old_hot_band = int(plane.cuts[1] - plane.cuts[0])
    while plane.reshard_task is not None:
        plane.submit(topic_queries[0])
        assert len(plane.drain_once()) == 1
    assert plane.reshards_completed == 1
    assert int(plane.cuts[1] - plane.cuts[0]) <= old_hot_band
    # The new layout still serves correctly (vs fresh build at its cuts).
    fresh = ShardedEngine(
        eng, 4, use_mesh=False,
        shards=shard_device_index(idx, cuts=plane.cuts),
    )
    for q in topic_queries[:4]:
        plan = eng.plan(q)
        a = plane.bengine.run_batch([plan])[0]
        b = fresh.traverse(plan)
        assert a.doc_ids.tolist() == b.doc_ids.tolist()
        assert a.scores.tolist() == b.scores.tolist()


# -------------------------------------------- shard-aware range selection


def test_boundsum_budgets_concentrate_on_scoring_shards():
    corpus, idx, eng = _planted_setup()
    se = ShardedEngine(eng, 4, use_mesh=False)
    bud = ShardedSlaBudgeter(
        sla_ms=1.0, rate=float(2 * BLOCK), n_shards=4,
        mode="boundsum", shard_mass=se.query_shard_mass,
    )
    q = np.arange(8, dtype=np.int32)  # topic-0 terms only
    plans = [eng.plan(q)]
    b = bud.budgets(1, plans=plans)[0]
    mass = se.query_shard_mass(plans[0])
    assert mass[0] > 0 and np.all(mass[1:] == 0)
    # All of the batch budget lands on the only shard that can score.
    assert b[0] == 4 * 2 * BLOCK and np.all(b[1:] == 0)
    # Without plans (or in rate mode) the split is uniform.
    b_rate = ShardedSlaBudgeter(
        sla_ms=1.0, rate=float(2 * BLOCK), n_shards=4
    ).budgets(1, plans=plans)[0]
    assert np.all(b_rate == 2 * BLOCK)
    # Unbounded SLA: no redistribution, stays unbounded everywhere.
    b_inf = ShardedSlaBudgeter(
        sla_ms=float("inf"), n_shards=4, mode="boundsum",
        shard_mass=se.query_shard_mass,
    ).budgets(1, plans=plans)[0]
    assert np.all(b_inf == INT32_MAX)
    with pytest.raises(ValueError):
        ShardedSlaBudgeter(sla_ms=1.0, n_shards=4, mode="boundsum")
    with pytest.raises(ValueError):
        ShardedSlaBudgeter(sla_ms=1.0, n_shards=4, mode="nope")


def test_boundsum_budgets_improve_fidelity_on_skewed_index():
    """Satellite acceptance: same total budget, tighter fidelity_bound when
    allocated by per-shard BoundSum mass instead of static rate shares."""
    corpus, idx, eng = _planted_setup(seed=1)
    se = ShardedEngine(eng, 4, use_mesh=False)
    beng = ShardedBatchEngine(se, BucketSpec(max_batch=8))
    rng = np.random.default_rng(2)
    # Topic-0 queries: all scoring mass in shard 0's four ranges.
    queries = [
        rng.choice(40, size=12, replace=False).astype(np.int32)
        for _ in range(8)
    ]
    plans = beng.plan_many(queries)
    kw = dict(sla_ms=1.0, rate=float(4 * BLOCK), n_shards=4)
    b_rate = ShardedSlaBudgeter(**kw).budgets(len(plans), plans=plans)
    b_bs = ShardedSlaBudgeter(
        **kw, mode="boundsum", shard_mass=se.query_shard_mass
    ).budgets(len(plans), plans=plans)
    assert int(b_bs.sum()) <= int(b_rate.sum()) * 2  # same budget scale
    r_rate = beng.run_batch(plans, budget_postings=b_rate, safe_stop=False)
    r_bs = beng.run_batch(plans, budget_postings=b_bs, safe_stop=False)
    fb_rate = np.asarray([r.fidelity_bound for r in r_rate])
    fb_bs = np.asarray([r.fidelity_bound for r in r_bs])
    assert np.any(fb_rate > 0)  # the tight budget actually bound
    assert np.all(fb_bs <= fb_rate)
    assert fb_bs.mean() < fb_rate.mean()


# ------------------------------------------------------ replica group (CPU)


def test_replica_group_fallback_matches_sharded_engine():
    """On one device the group serves through the wrapped engine unchanged."""
    _, eng, queries = _small_setup(seed=17, n_ranges=6)
    se = ShardedEngine(eng, 2, use_mesh=False)
    rep = ReplicaGroupEngine(se, 2, use_mesh=False)
    assert rep.group_mesh is None
    beng = ShardedBatchEngine(rep, BucketSpec(max_batch=4))
    sbeng = ShardedBatchEngine(se, BucketSpec(max_batch=4))
    plans = beng.plan_many(queries)
    for a, b in zip(beng.run_batch(plans), sbeng.run_batch(plans)):
        assert a.doc_ids.tolist() == b.doc_ids.tolist()
        assert a.scores.tolist() == b.scores.tolist()
    with pytest.raises(ValueError):
        ReplicaGroupEngine(se, 0)


# ------------------------------------------------- multi-device subprocess

_REPLICA_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro.control import ControlPlane, ReplicaGroupEngine
from repro.core.clustered_index import build_index
from repro.core.range_daat import Engine
from repro.data.synth import make_corpus, make_query_log
from repro.serving import BucketSpec, ShardedBatchEngine, ShardedEngine

assert jax.device_count() == 4
corpus = make_corpus(n_docs=900, n_terms=700, n_topics=4, mean_doc_len=50, seed=7)
idx = build_index(corpus, n_ranges=6, strategy="clustered")
eng = Engine(idx, k=5)
log = make_query_log(corpus, n_queries=8, seed=8)
queries = [log.terms[i] for i in range(log.n_queries)]

# 2 replicas x 2 shards on the 2-D (data, shard) mesh.
se = ShardedEngine(eng, 2, use_mesh=True)
rep = ReplicaGroupEngine(se, 2)
assert rep.group_mesh is not None
beng = ShardedBatchEngine(rep, BucketSpec(max_batch=4))
single = ShardedBatchEngine(se, BucketSpec(max_batch=4))
plans = beng.plan_many(queries)
for a, b in zip(beng.run_batch(plans), single.run_batch(plans)):
    assert a.doc_ids.tolist() == b.doc_ids.tolist(), (a.doc_ids, b.doc_ids)
    assert a.scores.tolist() == b.scores.tolist()
    assert a.shard_postings.tolist() == b.shard_postings.tolist()
assert rep.dispatches > 0
# Odd batch: pad lanes divide the batch over replicas evenly.
a1 = beng.run_batch(plans[:3])
b1 = single.run_batch(plans[:3])
for a, b in zip(a1, b1):
    assert a.doc_ids.tolist() == b.doc_ids.tolist()

plane = ControlPlane(eng, n_shards=2, n_replicas=2, spec=BucketSpec(max_batch=4))
assert plane.stats()["replica_mesh"]
served = plane.replay(queries, batch_size=4)
for s in served:
    b = single.run_batch([eng.plan(queries[s.rid])])[0]
    assert s.result.doc_ids.tolist() == b.doc_ids.tolist()
# One replica row degrades: plane reroutes via the single path, full fidelity.
plane.mark_down(0, replica=1)
for s in plane.replay(queries[:4], batch_size=4):
    assert s.result.exact
print("REPLICA_MESH_OK", len(queries))
"""


@pytest.mark.slow
def test_replica_group_mesh_bitwise_parity_subprocess():
    """Acceptance: 2x2 (data x shard) replica mesh == single replica, bitwise."""
    out = subprocess.run(
        [sys.executable, "-c", _REPLICA_SUBPROC],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd=_REPO_ROOT,
        timeout=900,
    )
    assert "REPLICA_MESH_OK 8" in out.stdout, out.stdout + out.stderr


_DEGRADED_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro.control import ControlPlane
from repro.core.clustered_index import build_index
from repro.core.range_daat import Engine
from repro.data.synth import make_corpus, make_query_log
from repro.serving import BucketSpec, ShardedEngine

assert jax.device_count() == 4
corpus = make_corpus(n_docs=900, n_terms=700, n_topics=4, mean_doc_len=50, seed=9)
idx = build_index(corpus, n_ranges=8, strategy="clustered")
eng = Engine(idx, k=5)
log = make_query_log(corpus, n_queries=12, seed=10)
queries = [log.terms[i] for i in range(log.n_queries)]

plane = ControlPlane(eng, n_shards=4, spec=BucketSpec(max_batch=4))
assert plane.sengine.mesh is not None  # 4 shards on 4 devices
baseline = {}
for s in plane.replay(queries, batch_size=4):
    assert s.result.exact
    baseline[s.rid] = (s.result.doc_ids.tolist(), s.result.scores.tolist())

# Kill shard 2 mid-stream: the stream keeps flowing, degraded.
dead = 2
half = len(queries) // 2
for q in queries[:half]:
    plane.submit(q)
first = plane.drain_once()
plane.mark_down(dead)
rest = []
while plane.pending:
    rest.extend(plane.drain_once())
for q in queries[half:]:
    plane.submit(q)
while plane.pending:
    rest.extend(plane.drain_once())
assert len(first) + len(rest) == len(queries)
degraded = 0
for s in rest:
    r = s.result
    assert r.shard_exit_reasons[dead] == "down"
    plan = eng.plan(queries[s.rid % len(queries)])
    per_range = np.zeros(int(plane.cuts[-1]), np.int64)
    per_range[plan.order_host] = plan.bounds_host
    lo, hi = int(plane.cuts[dead]), int(plane.cuts[dead + 1])
    assert r.fidelity_bound == int(per_range[lo:hi].max())
    if r.fidelity_bound > 0:
        assert not r.exact
        degraded += 1
assert degraded > 0

# Replica returns: bitwise recovery.
plane.mark_up(dead)
for s in plane.replay(queries, batch_size=4):
    ids, scores = baseline[s.rid % len(queries)]
    assert s.result.doc_ids.tolist() == ids
    assert s.result.scores.tolist() == scores
    assert s.result.exact
print("DEGRADED_MESH_OK", degraded)
"""


@pytest.mark.slow
def test_degraded_failover_on_forced_mesh_subprocess():
    """Satellite acceptance: kill a shard mid-stream on a 4-device mesh;
    results degrade through the fidelity bound and recover bitwise."""
    out = subprocess.run(
        [sys.executable, "-c", _DEGRADED_SUBPROC],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd=_REPO_ROOT,
        timeout=900,
    )
    assert "DEGRADED_MESH_OK" in out.stdout, out.stdout + out.stderr
