"""Index construction invariants (paper §3 index organization)."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.clustered_index import BLOCK, build_index
from repro.core.quantize import fit_quantizer
from repro.core.reorder import arrange
from repro.data.synth import make_corpus


def test_corpus_deterministic():
    a = make_corpus(n_docs=300, n_terms=500, n_topics=4, seed=5)
    b = make_corpus(n_docs=300, n_terms=500, n_topics=4, seed=5)
    assert a.fingerprint() == b.fingerprint()
    c = make_corpus(n_docs=300, n_terms=500, n_topics=4, seed=6)
    assert a.fingerprint() != c.fingerprint()


def test_arrangement_is_permutation(corpus, clustered_arrangement):
    arr = clustered_arrangement
    assert np.array_equal(np.sort(arr.doc_order), np.arange(corpus.n_docs))
    assert arr.range_ends[-1] == corpus.n_docs
    assert np.all(np.diff(arr.range_ends) > 0)


def test_quantizer_monotone_and_bounded():
    scores = np.asarray([0.01, 0.5, 1.0, 3.7, 9.99], np.float32)
    q = fit_quantizer(scores, bits=8)
    imp = q.quantize(scores)
    assert np.all(imp >= 1) and np.all(imp <= 255)
    assert np.all(np.diff(imp) >= 0)  # monotone
    assert imp[-1] == 255  # max maps to top code


def test_blocks_partition_postings(index):
    # Every posting belongs to exactly one block; blocks never cross ranges.
    covered = np.zeros(index.nnz, dtype=np.int32)
    for b in range(index.n_blocks):
        s, l = int(index.blk_start[b]), int(index.blk_len[b])
        assert 0 < l <= BLOCK
        covered[s : s + l] += 1
        d = index.docs[s : s + l]
        r = index.blk_range[b]
        lo = index.range_starts[r]
        hi = index.range_ends[r]
        assert np.all((d >= lo) & (d < hi))
        assert int(index.blk_maxdoc[b]) == int(d[-1])
        assert int(index.blk_maximp[b]) == int(index.impacts[s : s + l].max())
    assert np.all(covered == 1)


def test_range_bounds_are_true_maxima(index):
    rng = np.random.default_rng(0)
    terms = rng.choice(index.n_terms, size=50, replace=False)
    range_of = np.searchsorted(index.range_ends, index.docs, side="right")
    for t in terms:
        s, e = index.ptr[t], index.ptr[t + 1]
        if s == e:
            assert np.all(index.bounds_dense[t] == 0)
            continue
        for r in range(index.n_ranges):
            mask = range_of[s:e] == r
            expect = int(index.impacts[s:e][mask].max()) if mask.any() else 0
            assert int(index.bounds_dense[t, r]) == expect
        assert int(index.term_bound[t]) == int(index.bounds_dense[t].max())


def test_postings_sorted_within_term(index):
    for t in range(0, index.n_terms, 97):
        s, e = index.ptr[t], index.ptr[t + 1]
        d = index.docs[s:e]
        assert np.all(np.diff(d) > 0)  # strictly increasing docids


def test_uniform_window_strategy():
    c = make_corpus(n_docs=400, n_terms=300, n_topics=4, seed=2)
    arr = arrange(c, n_ranges=1, strategy="bp", bp_rounds=2)
    idx = build_index(c, arrangement=arr)
    assert idx.n_ranges == 1
    assert idx.space_report()["total_gib"] > 0


@pytest.mark.parametrize("strategy", ["random", "clustered", "clustered_bp"])
def test_strategies_build(strategy):
    c = make_corpus(n_docs=300, n_terms=300, n_topics=4, seed=3)
    arr = arrange(c, n_ranges=4, strategy=strategy, bp_rounds=2)
    idx = build_index(c, arrangement=arr)
    assert idx.nnz == c.nnz
