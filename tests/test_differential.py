"""Seed suite for the differential harness (DESIGN.md §12 acceptance).

Pins the bit-packed docid decode path against the raw int32 path through
the public engine surface: same index, same queries, same budgets — every
observable identical. Crossed with impact storage dtype and shard count so
the packed decode is exercised under every representation combination the
serving stack supports, and under budget exits (the packed path must not
shift *when* a lane stops, only how docids are stored).
"""

from __future__ import annotations

import numpy as np
import pytest
from differential import (
    EngineConfig,
    assert_bitwise_equal_engines,
    assert_results_equal,
    build_engine,
    observe_query,
)

from repro.core.clustered_index import build_index
from repro.data.synth import make_corpus, make_query_log

INT32_MAX = 2**31 - 1


def _corpus_and_queries(seed: int, n_queries: int = 8):
    corpus = make_corpus(
        n_docs=900, n_terms=700, n_topics=4, mean_doc_len=50, seed=seed
    )
    log = make_query_log(corpus, n_queries=n_queries, seed=seed + 1)
    return corpus, [log.terms[i] for i in range(log.n_queries)]


@pytest.mark.parametrize("impact_dtype", ["int8", "int32"])
@pytest.mark.parametrize("n_shards", [1, 2])
def test_packed_docs_bitwise_equal_int32(impact_dtype, n_shards):
    """Tentpole invariant: packed decode == raw int32 gather, bitwise."""
    corpus, queries = _corpus_and_queries(seed=41)
    assert_bitwise_equal_engines(
        EngineConfig(impact_dtype=impact_dtype, docs_format="int32",
                     n_shards=n_shards),
        EngineConfig(impact_dtype=impact_dtype, docs_format="packed",
                     n_shards=n_shards),
        corpus,
        queries,
        n_ranges=4,
    )


def test_packed_parity_under_budget_exits():
    """Identical caps must produce identical budget-exit timing."""
    corpus, queries = _corpus_and_queries(seed=43)
    rng = np.random.default_rng(0)
    budgets = rng.choice([1, 150, 600, INT32_MAX], size=len(queries))
    maxr = rng.choice([0, 1, 2, INT32_MAX], size=len(queries))
    assert_bitwise_equal_engines(
        EngineConfig(impact_dtype="int8", docs_format="int32"),
        EngineConfig(impact_dtype="int8", docs_format="packed"),
        corpus,
        queries,
        budgets=budgets,
        max_ranges=maxr,
        n_ranges=6,
    )


def test_packed_parity_pallas_impl():
    """Pallas packed decode (interpret) == XLA int32 reference."""
    corpus, queries = _corpus_and_queries(seed=47, n_queries=4)
    assert_bitwise_equal_engines(
        EngineConfig(impact_dtype="int8", docs_format="int32", impl="xla"),
        EngineConfig(impact_dtype="int8", docs_format="packed", impl="pallas"),
        corpus,
        queries,
        n_ranges=3,
    )


def test_prebuilt_index_accepted_and_divergence_detected():
    """Harness plumbing: accepts a ClusteredIndex, and actually fails."""
    corpus, queries = _corpus_and_queries(seed=53, n_queries=3)
    index = build_index(corpus, n_ranges=3, strategy="clustered")
    assert_bitwise_equal_engines(
        EngineConfig(), EngineConfig(docs_format="packed"), index, queries
    )
    eng = build_engine(index, EngineConfig(), k=5)
    ra = observe_query(eng, eng.plan(queries[0]))
    rb = dict(ra, postings=ra["postings"] + 1)
    with pytest.raises(AssertionError, match="postings diverged"):
        assert_results_equal(ra, rb, context="injected")
    with pytest.raises(ValueError, match="n_shards"):
        assert_bitwise_equal_engines(
            EngineConfig(n_shards=1), EngineConfig(n_shards=2), index, queries
        )
