"""Sharded anytime IR: broker merge == per-shard oracles (partitioned §7.2).

The multi-device variant runs in a subprocess with 8 forced host devices
(tests themselves must stay single-device per the dry-run contract).
"""

from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.oracle import exhaustive_topk


_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.data.synth import make_corpus, make_query_log
from repro.serve.distributed_ir import (build_sharded_index, plan_queries,
                                        sharded_anytime_query)
from repro.core.oracle import exhaustive_topk
from repro.distributed.sharding import ShardCtx

corpus = make_corpus(n_docs=1600, n_terms=1200, n_topics=6, mean_doc_len=40, seed=3)
ql = make_query_log(corpus, n_queries=8, seed=4)
M = 4
arrays, engines = build_sharded_index(corpus, n_shards=M, n_ranges_per_shard=4)
tables = plan_queries(engines, ql.terms)
mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = ShardCtx(mesh=mesh, data_axes=("data",), model_axis="model")
vals, ids, ranges = sharded_anytime_query(arrays, tables, ctx)
vals = np.asarray(vals); ids = np.asarray(ids)

# Oracle: merge per-shard exhaustive top-k (same global quantizer).
ok = 0
for qi in range(ql.n_queries):
    merged = []
    for m, e in enumerate(engines):
        oid, osc = exhaustive_topk(e.index, ql.terms[qi], 10)
        merged.extend(osc.tolist())
    expect = sorted(merged, reverse=True)[:10]
    got = sorted([v for v in vals[qi].tolist() if v > 0], reverse=True)
    expect = [e for e in expect if e > 0]
    assert got == expect, (qi, got, expect)
    ok += 1
print("SHARDED_OK", ok)
"""


@pytest.mark.slow
def test_sharded_query_matches_merged_oracles():
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd=_REPO_ROOT,
        timeout=900,
    )
    assert "SHARDED_OK 8" in out.stdout, out.stdout + out.stderr


def test_single_shard_reduces_to_engine(corpus, engine, queries, index):
    """M=1 sharded build must reproduce the single-node engine results."""
    import jax

    from repro.distributed.sharding import ShardCtx
    from repro.serve.distributed_ir import (
        build_sharded_index,
        plan_queries,
        sharded_anytime_query,
    )

    arrays, engines = build_sharded_index(corpus, n_shards=1, n_ranges_per_shard=8)
    q = np.stack([queries[0], queries[1]])
    tables = plan_queries(engines, q)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = ShardCtx(mesh=mesh, data_axes=("data",), model_axis="model")
    vals, ids, _ = sharded_anytime_query(arrays, tables, ctx)
    for qi in range(2):
        _, osc = exhaustive_topk(engines[0].index, q[qi], 10)
        got = sorted([v for v in np.asarray(vals[qi]).tolist() if v > 0], reverse=True)
        assert got == sorted(osc.tolist(), reverse=True)
